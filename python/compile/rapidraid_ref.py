"""Reference RapidRAID code construction (placement, coefficients, G matrix).

Python mirror of rust/src/codes/rapidraid.rs, used by the pytest suite to
verify (a) that chaining `model.pipeline_stage` n times reproduces the
generator-matrix encoding G . o, and (b) the paper's Section IV claims (e.g.
the unique natural dependency {c1, c2, c5, c6} of the (8,4) code).

Placement (paper Section V): two replicas of the k-block object o over n
nodes, n <= 2k.  Node i (0-based) stores:

  * a block of the FIRST replica if i < k:          o_i
  * a block of the SECOND replica if i >= n - k:    o_{i - (n - k)}

For n = 2k each node stores exactly one block; for n < 2k the middle
2k - n nodes store two (the overlapped placement of the (6,4) example).
"""

from __future__ import annotations

import numpy as np

from . import gf


def placement(n: int, k: int) -> list[list[int]]:
    """blocks[i] = ordered list of object-block indices stored on node i."""
    if not (k < n <= 2 * k):
        raise ValueError(f"need k < n <= 2k, got (n={n}, k={k})")
    nodes: list[list[int]] = []
    for i in range(n):
        blocks = []
        if i < k:
            blocks.append(i)
        if i >= n - k:
            blocks.append(i - (n - k))
        nodes.append(blocks)
    return nodes


def draw_coeffs(n: int, k: int, w: int = 8, seed: int = 7):
    """Random nonzero psi/xi per (node, local block); deterministic by seed."""
    rng = np.random.default_rng(seed)
    place = placement(n, k)
    psi = [rng.integers(1, 1 << w, len(b)).astype(gf.DTYPE[w]) for b in place]
    xi = [rng.integers(1, 1 << w, len(b)).astype(gf.DTYPE[w]) for b in place]
    return psi, xi


def generator_matrix(n: int, k: int, psi, xi, w: int = 8) -> np.ndarray:
    """(n, k) matrix G with c = G . o, from the pipeline recurrences (3)/(4)."""
    place = placement(n, k)
    g = np.zeros((n, k), dtype=gf.DTYPE[w])
    xrow = np.zeros(k, dtype=gf.DTYPE[w])  # coefficients of x_{i-1,i}
    for i in range(n):
        crow = xrow.copy()
        for j, blk in enumerate(place[i]):
            crow[blk] ^= xi[i][j]
            xrow[blk] ^= psi[i][j]
        g[i] = crow
    return g


def encode_chain(obj: np.ndarray, psi, xi, n: int, w: int = 8) -> np.ndarray:
    """Encode by running the actual pipeline recurrence over data panels.

    obj: (k, B) object blocks.  Returns (n, B) codeword blocks.  Uses the
    numpy oracle; the pytest suite separately checks the Pallas kernel step
    against the oracle, and the Rust coordinator re-runs the same chain over
    a simulated network.
    """
    k, b = obj.shape
    place = placement(n, k)
    c = np.zeros((n, b), dtype=gf.DTYPE[w])
    x = np.zeros(b, dtype=gf.DTYPE[w])
    for i in range(n):
        c[i] = x.copy()
        for j, blk in enumerate(place[i]):
            c[i] ^= gf.mul_np(xi[i][j], obj[blk], w)
            x = x ^ gf.mul_np(psi[i][j], obj[blk], w)
    return c


def rank_gf(mat: np.ndarray, w: int = 8) -> int:
    """Rank over GF(2^w) by Gaussian elimination."""
    m = np.array(mat, dtype=gf.DTYPE[w])
    rows, cols = m.shape
    rank = 0
    for col in range(cols):
        piv = None
        for r in range(rank, rows):
            if m[r, col] != 0:
                piv = r
                break
        if piv is None:
            continue
        m[[rank, piv]] = m[[piv, rank]]
        inv = gf.inv_np(m[rank, col], w)
        m[rank] = gf.mul_np(m[rank], np.full(cols, inv, dtype=gf.DTYPE[w]), w)
        for r in range(rows):
            if r != rank and m[r, col] != 0:
                factor = np.full(cols, m[r, col], dtype=gf.DTYPE[w])
                m[r] = m[r] ^ gf.mul_np(factor, m[rank], w)
        rank += 1
        if rank == rows:
            break
    return rank
