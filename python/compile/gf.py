"""Finite-field (GF(2^w)) table construction shared by kernels, oracle and AOT.

RapidRAID performs all coding arithmetic in GF(2^8) or GF(2^16) (the paper's
RR8 / RR16 implementations, built on Jerasure).  We reproduce Jerasure /
gf-complete's default fields:

  * GF(2^8):  primitive polynomial x^8  + x^4 + x^3 + x^2 + 1       (0x11D)
  * GF(2^16): primitive polynomial x^16 + x^12 + x^3  + x   + 1     (0x1100B)

Multiplication is implemented with log/antilog tables:

    a * b = exp[(log[a] + log[b]) mod (2^w - 1)]        (a, b != 0)

The exp table is stored *doubled* (length 2*(2^w-1)+2) so the `mod` never has
to be evaluated inside the kernels: log[a] + log[b] <= 2*(2^w-2) always indexes
in range.  Zero operands are handled with an explicit mask (log[0] is
undefined; we park 0 there and guard).

The same tables are generated, with the same polynomials, on the Rust side
(rust/src/gf/tables.rs); python/tests/test_gf_tables.py pins golden values so
both sides provably agree.
"""

from __future__ import annotations

import functools

import numpy as np

# Primitive polynomials, including the x^w term, as used by gf-complete.
POLY8 = 0x11D
POLY16 = 0x1100B

ORDER = {8: 255, 16: 65535}
POLY = {8: POLY8, 16: POLY16}
DTYPE = {8: np.uint8, 16: np.uint16}


def mul_bitwise(a: int, b: int, w: int = 8) -> int:
    """Carry-less "Russian peasant" multiply, reduced mod the field polynomial.

    Bit-level ground truth used to build the tables and as the ultimate test
    oracle; intentionally slow and obvious.
    """
    poly = POLY[w]
    top = 1 << w
    mask = top - 1
    assert 0 <= a <= mask and 0 <= b <= mask
    r = 0
    while b:
        if b & 1:
            r ^= a
        b >>= 1
        a <<= 1
        if a & top:
            a ^= poly
    return r & mask


@functools.lru_cache(maxsize=None)
def tables(w: int = 8) -> tuple[np.ndarray, np.ndarray]:
    """(log, exp) tables for GF(2^w).

    log: length 2^w int32, log[0] = 0 (guarded by callers).
    exp: length 2*(2^w-1)+2 int32, doubled so log[a]+log[b] indexes directly.
    """
    order = ORDER[w]
    log = np.zeros(order + 1, dtype=np.int32)
    exp = np.zeros(2 * order + 2, dtype=np.int32)
    x = 1
    for i in range(order):
        exp[i] = x
        log[x] = i
        x = mul_bitwise(x, 2, w)
    assert x == 1, "polynomial is not primitive"
    # Double the exp table so (log[a] + log[b]) needs no modular reduction.
    exp[order : 2 * order] = exp[:order]
    exp[2 * order :] = exp[:2]
    return log, exp


def mul_np(a: np.ndarray, b: np.ndarray, w: int = 8) -> np.ndarray:
    """Vectorized numpy GF multiply (table based), used by the oracle."""
    log, exp = tables(w)
    a = np.asarray(a, dtype=DTYPE[w])
    b = np.asarray(b, dtype=DTYPE[w])
    s = log[a.astype(np.int64)] + log[b.astype(np.int64)]
    r = exp[s].astype(DTYPE[w])
    return np.where((a == 0) | (b == 0), DTYPE[w](0), r)


def inv_np(a: np.ndarray, w: int = 8) -> np.ndarray:
    """Multiplicative inverse; a must be nonzero."""
    log, exp = tables(w)
    a = np.asarray(a, dtype=DTYPE[w])
    if np.any(a == 0):
        raise ZeroDivisionError("inverse of 0 in GF(2^w)")
    order = ORDER[w]
    return exp[(order - log[a.astype(np.int64)]) % order].astype(DTYPE[w])
