"""AOT lowering: jax/Pallas graphs -> HLO *text* artifacts + manifest.

Run once at build time (`make artifacts`); the Rust runtime
(rust/src/runtime/) loads every artifact listed in artifacts/manifest.txt,
compiles it with the PJRT CPU client and executes it on the archival hot
path.  Python never runs at request time.

Interchange format is HLO TEXT, not `.serialize()`: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`).  The text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Manifest format (one artifact per line, space-separated key=value):

    name=<id> kind=<gemm|step> w=<8|16> m=.. k=.. r=.. b=.. file=<name>.hlo.txt

`b` counts field ELEMENTS (bytes for w=8, 16-bit words for w=16); every
artifact's payload panel is one 64 KiB network buffer, the coordinator's
streaming unit.
"""

from __future__ import annotations

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# One network buffer = 64 KiB, the coordinator's streaming unit (matching the
# paper's streamlined coding model where a node encodes buffer-by-buffer).
BUF_BYTES = 65536

# (w, m, k) gemm variants:
#   (16,11) parity m=5,k=11  - the paper's evaluation code, RR8 + RR16
#   (16,11) decode k=11      - inverse application
#   (8,4)   parity m=4,k=4 and decode k=4 - the paper's running example
GEMM_VARIANTS = [
    (8, 5, 11),
    (8, 11, 11),
    (8, 4, 4),
    (16, 5, 11),
    (16, 11, 11),
]

# (w, r) pipeline-stage variants: r=1 (n=2k placement), r=2 (overlapped).
STEP_VARIANTS = [(8, 1), (8, 2), (16, 1), (16, 2)]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants is ESSENTIAL: the default printer elides the GF
    # log/exp tables as `constant({...})`, which xla_extension 0.5.1's text
    # parser silently reads back as all-zero tables (caught by the PJRT
    # conformance tests — every GF product came back 0).
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # new-XLA metadata attributes (e.g. source_end_line) are unknown to the
    # 0.5.1 parser — drop metadata entirely.
    opts.print_metadata = False
    return comp.get_hlo_module().to_string(opts)


def _dt(w: int):
    return jnp.uint8 if w == 8 else jnp.uint16


def _elems(w: int) -> int:
    return BUF_BYTES // (w // 8)


def lower_gemm(w: int, m: int, k: int):
    b = _elems(w)
    fn = functools.partial(model.classical_parity, w=w)
    spec_g = jax.ShapeDtypeStruct((m, k), _dt(w))
    spec_d = jax.ShapeDtypeStruct((k, b), _dt(w))
    return jax.jit(fn).lower(spec_g, spec_d), b


def lower_step(w: int, r: int):
    b = _elems(w)
    fn = functools.partial(model.pipeline_stage, w=w)
    spec_x = jax.ShapeDtypeStruct((b,), _dt(w))
    spec_l = jax.ShapeDtypeStruct((r, b), _dt(w))
    spec_c = jax.ShapeDtypeStruct((r,), _dt(w))
    return jax.jit(fn).lower(spec_x, spec_l, spec_c, spec_c), b


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest_lines = []

    for w, m, k in GEMM_VARIANTS:
        name = f"gf{w}_gemm_m{m}_k{k}"
        lowered, b = lower_gemm(w, m, k)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        manifest_lines.append(
            f"name={name} kind=gemm w={w} m={m} k={k} r=0 b={b} file={fname}"
        )
        print(f"wrote {fname} ({len(text)} chars)")

    for w, r in STEP_VARIANTS:
        name = f"gf{w}_step_r{r}"
        lowered, b = lower_step(w, r)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        manifest_lines.append(
            f"name={name} kind=step w={w} m=0 k=0 r={r} b={b} file={fname}"
        )
        print(f"wrote {fname} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote manifest.txt ({len(manifest_lines)} artifacts)")


if __name__ == "__main__":
    main()
