"""L2: the RapidRAID compute graphs, composed from the L1 Pallas kernels.

Three jax functions cover every GF computation the Rust coordinator executes
on the archival hot path; each is AOT-lowered by aot.py to a fixed-shape HLO
artifact:

  * classical_parity - parity panel generation for the classical (CEC)
    encoder: the single coding node turns a (k, B) source panel into the
    (m, B) parity panel in one call.
  * pipeline_stage   - one RapidRAID pipeline node: fold r local blocks into
    the incoming partial combination, emitting both the forwarded x_out and
    the locally stored codeword block c (paper eqs. (3)/(4)).
  * decode_apply     - reconstruction: apply a precomputed k x k inverse
    (computed by the Rust Gauss solver from the surviving rows of G) to a
    (k, B) panel of surviving codeword blocks.  Mathematically the same GF
    gemm as classical_parity with m = k.

All functions are shape-polymorphic in python; aot.py freezes the (w, m, k,
r, B) combinations the Rust runtime needs and records them in the artifact
manifest.  Python never runs at request time - these graphs execute inside
the Rust PJRT client.
"""

from __future__ import annotations

from . import kernels


def classical_parity(gmat, data, *, w: int = 8):
    """(m, B) parity = G' (*) data over GF(2^w); G' (m, k), data (k, B)."""
    return (kernels.gf_gemm(gmat, data, w=w),)


def pipeline_stage(x_in, locals_, psi, xi, *, w: int = 8):
    """(x_out, c) for one RapidRAID pipeline stage (see kernels.pipeline_step)."""
    x_out, c = kernels.pipeline_step(x_in, locals_, psi, xi, w=w)
    return (x_out, c)


def decode_apply(inv, coded, *, w: int = 8):
    """(k, B) original blocks = inv (*) coded; inv (k, k), coded (k, B)."""
    return (kernels.gf_gemm(inv, coded, w=w),)
