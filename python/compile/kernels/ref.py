"""Pure-jnp oracle for the GF coding kernels (L1 correctness reference).

Every Pallas kernel in this package has an exact counterpart here, written in
straightforward jax.numpy with no Pallas, no tiling and no fusion tricks.
pytest compares kernel output against these (bit-exact; GF arithmetic has no
tolerance), and these in turn are validated against the bit-level
`gf.mul_bitwise` ground truth.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .. import gf


def _tables(w: int):
    log, exp = gf.tables(w)
    return jnp.asarray(log), jnp.asarray(exp)


def _jdtype(w: int):
    return jnp.uint8 if w == 8 else jnp.uint16


def gf_mul(a, b, w: int = 8):
    """Elementwise GF(2^w) multiply (broadcasting)."""
    log, exp = _tables(w)
    a = jnp.asarray(a, dtype=_jdtype(w))
    b = jnp.asarray(b, dtype=_jdtype(w))
    s = jnp.take(log, a.astype(jnp.int32)) + jnp.take(log, b.astype(jnp.int32))
    r = jnp.take(exp, s).astype(_jdtype(w))
    return jnp.where((a == 0) | (b == 0), jnp.zeros((), _jdtype(w)), r)


def gf_gemm(gmat, data, w: int = 8):
    """GF matrix product: out[i, :] = XOR_j gmat[i, j] * data[j, :].

    gmat: (m, k) coefficients; data: (k, B) payload; out: (m, B).
    The compute core of classical (Reed-Solomon style) erasure encoding.
    """
    gmat = jnp.asarray(gmat, dtype=_jdtype(w))
    data = jnp.asarray(data, dtype=_jdtype(w))
    prod = gf_mul(gmat[:, :, None], data[None, :, :], w)  # (m, k, B)
    acc = prod[:, 0, :]
    for j in range(1, prod.shape[1]):
        acc = acc ^ prod[:, j, :]
    return acc


def pipeline_step(x_in, locals_, psi, xi, w: int = 8):
    """One RapidRAID pipeline stage (paper eqs. (3) and (4)).

    x_in:    (B,)   partial combination received from the predecessor node
    locals_: (r, B) the r object blocks this node stores (r=1 for n=2k,
             r=2 for the overlapped placement when n < 2k)
    psi:     (r,)   forward coefficients  (one per local block)
    xi:      (r,)   codeword coefficients (one per local block)

    returns (x_out, c):
        x_out = x_in XOR sum_i psi[i]*locals_[i]   -> sent to the successor
        c     = x_in XOR sum_i xi[i] *locals_[i]   -> stored locally
    """
    x_in = jnp.asarray(x_in, dtype=_jdtype(w))
    locals_ = jnp.asarray(locals_, dtype=_jdtype(w))
    x_acc = x_in
    c_acc = x_in
    for i in range(locals_.shape[0]):
        x_acc = x_acc ^ gf_mul(psi[i], locals_[i], w)
        c_acc = c_acc ^ gf_mul(xi[i], locals_[i], w)
    return x_acc, c_acc


# ---------------------------------------------------------------------------
# numpy ground-truth versions (no jax), used to validate the jnp oracle itself
# against gf.mul_bitwise in the test-suite.
# ---------------------------------------------------------------------------


def gf_gemm_np(gmat, data, w: int = 8) -> np.ndarray:
    gmat = np.asarray(gmat, dtype=gf.DTYPE[w])
    data = np.asarray(data, dtype=gf.DTYPE[w])
    m, k = gmat.shape
    out = np.zeros((m, data.shape[1]), dtype=gf.DTYPE[w])
    for i in range(m):
        for j in range(k):
            out[i] ^= gf.mul_np(gmat[i, j], data[j], w)
    return out


def pipeline_step_np(x_in, locals_, psi, xi, w: int = 8):
    x_in = np.asarray(x_in, dtype=gf.DTYPE[w])
    locals_ = np.asarray(locals_, dtype=gf.DTYPE[w])
    x_acc = x_in.copy()
    c_acc = x_in.copy()
    for i in range(locals_.shape[0]):
        x_acc = x_acc ^ gf.mul_np(psi[i], locals_[i], w)
        c_acc = c_acc ^ gf.mul_np(xi[i], locals_[i], w)
    return x_acc, c_acc
