# L1: Pallas kernels for the GF coding hot-spots, plus the pure-jnp oracle.
from .gf_gemm import gf_gemm  # noqa: F401
from .pipeline_step import pipeline_step  # noqa: F401
