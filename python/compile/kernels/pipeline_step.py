"""Pallas kernel: one RapidRAID pipeline stage, fused dual-output.

Paper eqs. (3) and (4): node i receives the partial combination x_{i-1,i},
folds in its r local blocks (r = 1 when n = 2k; r = 2 for the overlapped
placement when n < 2k) and produces BOTH

    x_out = x_in  XOR_i  psi[i] (*) local[i]     -> forwarded to node i+1
    c     = x_in  XOR_i  xi[i]  (*) local[i]     -> final codeword block c_i

in a single pass.  Fusing the two outputs matters: `log(local)` - the only
gather over the streamed payload - is computed once and shared by the psi and
xi products, so the stage reads each payload byte exactly once.  This is the
kernel on the archival hot path: every network buffer that flows through the
pipeline chain goes through one invocation per node.

Same TPU mapping notes as gf_gemm.py: tables resident in VMEM, payload
streamed over a 1-D grid, VPU-bound, interpret=True for CPU PJRT.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import gf

TILE_B = 8192


def _jdtype(w: int):
    return jnp.uint8 if w == 8 else jnp.uint16


def _step_kernel(coef_ref, log_ref, exp_ref, x_ref, loc_ref,
                 xout_ref, c_ref, *, r, w):
    log_t = log_ref[...]
    exp_t = exp_ref[...]
    coef = coef_ref[...]          # (2, r): row 0 = psi, row 1 = xi
    x_in = x_ref[...]             # (tb,)
    loc = loc_ref[...]            # (r, tb)

    clog = jnp.take(log_t, coef.astype(jnp.int32))           # (2, r)
    llog = jnp.take(log_t, loc.astype(jnp.int32))            # (r, tb) ONCE
    lzero = loc == 0

    dt = _jdtype(w)
    zero = jnp.zeros((), dt)
    x_acc = x_in
    c_acc = x_in
    for i in range(r):  # static unroll; r is 1 or 2 in practice
        nz = ~lzero[i]
        xprod = jnp.take(exp_t, clog[0, i] + llog[i]).astype(dt)
        cprod = jnp.take(exp_t, clog[1, i] + llog[i]).astype(dt)
        x_acc = x_acc ^ jnp.where(nz & (coef[0, i] != 0), xprod, zero)
        c_acc = c_acc ^ jnp.where(nz & (coef[1, i] != 0), cprod, zero)
    xout_ref[...] = x_acc
    c_ref[...] = c_acc


@functools.partial(jax.jit, static_argnames=("w", "tile_b"))
def pipeline_step(x_in, locals_, psi, xi, *, w: int = 8, tile_b: int = TILE_B):
    """(x_out, c) for one pipeline stage; x_in (B,), locals_ (r, B).

    psi, xi: (r,) coefficient vectors.  B must be a multiple of tile_b.
    """
    (b,) = x_in.shape
    r, b2 = locals_.shape
    assert b2 == b, (b2, b)
    assert b % tile_b == 0, f"B={b} not a multiple of tile_b={tile_b}"
    log_np, exp_np = gf.tables(w)
    log_t = jnp.asarray(log_np)
    exp_t = jnp.asarray(exp_np)
    dt = _jdtype(w)
    coef = jnp.stack([jnp.asarray(psi, dt), jnp.asarray(xi, dt)])  # (2, r)

    grid = (b // tile_b,)
    return pl.pallas_call(
        functools.partial(_step_kernel, r=r, w=w),
        grid=grid,
        in_specs=[
            pl.BlockSpec((2, r), lambda i: (0, 0)),            # coefficients
            pl.BlockSpec(log_t.shape, lambda i: (0,)),
            pl.BlockSpec(exp_t.shape, lambda i: (0,)),
            pl.BlockSpec((tile_b,), lambda i: (i,)),           # x_in streamed
            pl.BlockSpec((r, tile_b), lambda i: (0, i)),       # locals streamed
        ],
        out_specs=[
            pl.BlockSpec((tile_b,), lambda i: (i,)),
            pl.BlockSpec((tile_b,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), dt),
            jax.ShapeDtypeStruct((b,), dt),
        ],
        interpret=True,
    )(coef, log_t, exp_t, x_in.astype(dt), locals_.astype(dt))
