"""Pallas kernel: GF(2^w) matrix x matrix multiply-accumulate (XOR).

This is the compute hot-spot of *classical* erasure encoding: given the
parity sub-matrix G' (m x k) of a systematic code and a panel of source data
(k x B bytes), produce the m parity rows

    parity[i, :] = XOR_j  G'[i, j] (*) data[j, :]        (GF multiply)

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's Jerasure
implementation is a CPU table-lookup loop.  On TPU the same math maps to two
VMEM-resident table gathers (log, exp) plus an int add and an XOR reduction
over k.  The MXU is useless for GF arithmetic, so the kernel is VPU /
memory-bound; the goal of the Pallas structure is purely the HBM<->VMEM
schedule:

  * grid over B: the (k, B) data panel is streamed tile-by-tile
    (k x TILE_B per grid step) while the 256/512-entry tables (GF(2^8):
    0.5 KiB, int32: 3 KiB) and the tiny (m, k) coefficient matrix stay
    resident across all grid steps.
  * the k-loop is unrolled at trace time (k is static), producing a pure
    gather/add/xor chain XLA fuses into a single elementwise loop - there is
    exactly ONE pass over the data tile.

The kernel MUST be lowered with interpret=True: real TPU lowering emits a
Mosaic custom-call which the CPU PJRT plugin (and the rust xla crate) cannot
execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import gf

# Default B-tile: 8 KiB of payload per grid step per source block; with
# k = 11 this keeps the working set (k x TILE_B in + m x TILE_B out, plus
# tables) comfortably inside a TPU core's ~16 MiB VMEM even at k = 32.
TILE_B = 8192


def _jdtype(w: int):
    return jnp.uint8 if w == 8 else jnp.uint16


def _gemm_kernel(gmat_ref, log_ref, exp_ref, data_ref, out_ref, *, m, k, w):
    """One grid step: out tile (m, tb) from data tile (k, tb)."""
    log_t = log_ref[...]          # (2^w,)        int32, VMEM resident
    exp_t = exp_ref[...]          # (2*(2^w-1)+2,) int32, VMEM resident
    gmat = gmat_ref[...]          # (m, k)        uint, VMEM resident
    data = data_ref[...]          # (k, tb)       uint, streamed

    # log of the data tile is computed ONCE and reused by every output row.
    dlog = jnp.take(log_t, data.astype(jnp.int32))          # (k, tb)
    dzero = data == 0                                       # (k, tb)
    glog = jnp.take(log_t, gmat.astype(jnp.int32))          # (m, k)

    out_dtype = _jdtype(w)
    acc = jnp.zeros(out_ref.shape, dtype=out_dtype)
    for j in range(k):  # static unroll: gather/add/xor chain, one data pass
        s = glog[:, j][:, None] + dlog[j][None, :]          # (m, tb)
        prod = jnp.take(exp_t, s).astype(out_dtype)         # (m, tb)
        nz = (gmat[:, j] != 0)[:, None] & ~dzero[j][None, :]
        acc = acc ^ jnp.where(nz, prod, jnp.zeros((), out_dtype))
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("w", "tile_b"))
def gf_gemm(gmat, data, *, w: int = 8, tile_b: int = TILE_B):
    """parity = gmat (*) data over GF(2^w); shapes (m,k) x (k,B) -> (m,B).

    B must be a multiple of tile_b (callers pad; the AOT artifacts fix B).
    """
    m, k = gmat.shape
    k2, b = data.shape
    assert k2 == k, (k2, k)
    assert b % tile_b == 0, f"B={b} not a multiple of tile_b={tile_b}"
    log_np, exp_np = gf.tables(w)
    log_t = jnp.asarray(log_np)
    exp_t = jnp.asarray(exp_np)
    dt = _jdtype(w)

    grid = (b // tile_b,)
    return pl.pallas_call(
        functools.partial(_gemm_kernel, m=m, k=k, w=w),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, k), lambda i: (0, 0)),            # gmat: resident
            pl.BlockSpec(log_t.shape, lambda i: (0,)),         # log:  resident
            pl.BlockSpec(exp_t.shape, lambda i: (0,)),         # exp:  resident
            pl.BlockSpec((k, tile_b), lambda i: (0, i)),       # data: streamed
        ],
        out_specs=pl.BlockSpec((m, tile_b), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, b), dt),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(gmat.astype(dt), log_t, exp_t, data.astype(dt))
