"""GF table construction: golden values + field axioms vs the bit-level oracle."""

import numpy as np
import pytest

from compile import gf


class TestBitwiseMul:
    def test_golden_gf256(self):
        # Hand-checked products in GF(2^8)/0x11D (match Jerasure/gf-complete).
        assert gf.mul_bitwise(0, 7, 8) == 0
        assert gf.mul_bitwise(1, 183, 8) == 183
        assert gf.mul_bitwise(2, 0x80, 8) == 0x1D  # alpha * x^7 wraps into poly
        assert gf.mul_bitwise(3, 7, 8) == 9
        assert gf.mul_bitwise(0xFF, 0xFF, 8) == 226
    def test_golden_gf65536(self):
        assert gf.mul_bitwise(0, 1234, 16) == 0
        assert gf.mul_bitwise(1, 54321, 16) == 54321
        assert gf.mul_bitwise(2, 0x8000, 16) == 0x100B  # alpha wrap: poly 0x1100B
        assert gf.mul_bitwise(0xFFFF, 0xFFFF, 16) == 1843

    @pytest.mark.parametrize("w", [8, 16])
    def test_commutative(self, w):
        rng = np.random.default_rng(1)
        hi = 1 << w
        for a, b in rng.integers(0, hi, (50, 2)):
            assert gf.mul_bitwise(int(a), int(b), w) == gf.mul_bitwise(int(b), int(a), w)

    @pytest.mark.parametrize("w", [8, 16])
    def test_associative_and_distributive(self, w):
        rng = np.random.default_rng(2)
        hi = 1 << w
        for a, b, c in rng.integers(0, hi, (30, 3)):
            a, b, c = int(a), int(b), int(c)
            ab_c = gf.mul_bitwise(gf.mul_bitwise(a, b, w), c, w)
            a_bc = gf.mul_bitwise(a, gf.mul_bitwise(b, c, w), w)
            assert ab_c == a_bc
            lhs = gf.mul_bitwise(a, b ^ c, w)
            rhs = gf.mul_bitwise(a, b, w) ^ gf.mul_bitwise(a, c, w)
            assert lhs == rhs


class TestTables:
    @pytest.mark.parametrize("w", [8, 16])
    def test_exp_log_roundtrip(self, w):
        log, exp = gf.tables(w)
        order = gf.ORDER[w]
        # every nonzero element appears exactly once in exp[:order]
        assert sorted(exp[:order].tolist()) == list(range(1, order + 1))
        for x in (1, 2, 3, 5, order):
            assert exp[log[x]] == x

    @pytest.mark.parametrize("w", [8, 16])
    def test_exp_doubling(self, w):
        log, exp = gf.tables(w)
        order = gf.ORDER[w]
        assert (exp[order : 2 * order] == exp[:order]).all()
        # max index reachable from log[a]+log[b] is 2*(order-1)
        assert len(exp) > 2 * (order - 1)

    @pytest.mark.parametrize("w", [8, 16])
    def test_table_mul_matches_bitwise(self, w):
        rng = np.random.default_rng(3)
        hi = 1 << w
        a = rng.integers(0, hi, 500).astype(gf.DTYPE[w])
        b = rng.integers(0, hi, 500).astype(gf.DTYPE[w])
        expect = np.array(
            [gf.mul_bitwise(int(x), int(y), w) for x, y in zip(a, b)],
            dtype=gf.DTYPE[w],
        )
        assert (gf.mul_np(a, b, w) == expect).all()

    @pytest.mark.parametrize("w", [8, 16])
    def test_inverse(self, w):
        rng = np.random.default_rng(4)
        hi = 1 << w
        a = rng.integers(1, hi, 200).astype(gf.DTYPE[w])
        inv = gf.inv_np(a, w)
        assert (gf.mul_np(a, inv, w) == 1).all()

    def test_inverse_of_zero_raises(self):
        with pytest.raises(ZeroDivisionError):
            gf.inv_np(np.array([0], dtype=np.uint8), 8)

    @pytest.mark.parametrize("w", [8, 16])
    def test_mul_by_zero_and_one(self, w):
        rng = np.random.default_rng(5)
        hi = 1 << w
        a = rng.integers(0, hi, 100).astype(gf.DTYPE[w])
        assert (gf.mul_np(a, np.zeros_like(a), w) == 0).all()
        assert (gf.mul_np(a, np.ones_like(a), w) == a).all()


class TestRustParity:
    """Golden rows pinned so rust/src/gf/tables.rs provably builds the same
    tables (the same values are asserted in the Rust unit tests)."""

    def test_gf256_exp_prefix(self):
        _, exp = gf.tables(8)
        assert exp[:10].tolist() == [1, 2, 4, 8, 16, 32, 64, 128, 29, 58]

    def test_gf256_log_prefix(self):
        log, _ = gf.tables(8)
        assert log[1:9].tolist() == [0, 1, 25, 2, 50, 26, 198, 3]

    def test_gf65536_exp_prefix(self):
        _, exp = gf.tables(16)
        assert exp[14:18].tolist() == [16384, 32768, 4107, 8214]
