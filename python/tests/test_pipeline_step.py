"""Pallas pipeline_step kernel vs oracle + full-chain == generator-matrix."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import gf, kernels, rapidraid_ref as rr
from compile.kernels import ref


def _rand(rng, shape, w):
    return rng.integers(0, 1 << w, shape).astype(gf.DTYPE[w])


@pytest.mark.parametrize("w", [8, 16])
@pytest.mark.parametrize("r", [1, 2])
def test_step_matches_oracle(w, r):
    rng = np.random.default_rng(w + r)
    b = 8192
    x = _rand(rng, (b,), w)
    loc = _rand(rng, (r, b), w)
    psi = _rand(rng, (r,), w)
    xi = _rand(rng, (r,), w)
    xo, c = kernels.pipeline_step(x, loc, psi, xi, w=w)
    exo, ec = ref.pipeline_step_np(x, loc, psi, xi, w)
    assert (np.asarray(xo) == exo).all()
    assert (np.asarray(c) == ec).all()


def test_step_multi_tile():
    rng = np.random.default_rng(20)
    b = 8192 * 4
    x = _rand(rng, (b,), 8)
    loc = _rand(rng, (2, b), 8)
    psi = _rand(rng, (2,), 8)
    xi = _rand(rng, (2,), 8)
    xo, c = kernels.pipeline_step(x, loc, psi, xi, w=8)
    exo, ec = ref.pipeline_step_np(x, loc, psi, xi, 8)
    assert (np.asarray(xo) == exo).all() and (np.asarray(c) == ec).all()


def test_step_zero_coefficients():
    """psi = xi = 0 must pass x through unchanged on both outputs."""
    rng = np.random.default_rng(21)
    b = 8192
    x = _rand(rng, (b,), 8)
    loc = _rand(rng, (1, b), 8)
    z = np.zeros(1, dtype=np.uint8)
    xo, c = kernels.pipeline_step(x, loc, z, z, w=8)
    assert (np.asarray(xo) == x).all() and (np.asarray(c) == x).all()


def test_step_first_node():
    """Node 1 has x_in = 0: outputs are pure multiples of the local block."""
    rng = np.random.default_rng(22)
    b = 8192
    loc = _rand(rng, (1, b), 8)
    x0 = np.zeros(b, dtype=np.uint8)
    psi = np.array([3], dtype=np.uint8)
    xi = np.array([7], dtype=np.uint8)
    xo, c = kernels.pipeline_step(x0, loc, psi, xi, w=8)
    assert (np.asarray(xo) == gf.mul_np(np.uint8(3), loc[0], 8)).all()
    assert (np.asarray(c) == gf.mul_np(np.uint8(7), loc[0], 8)).all()


@settings(max_examples=20, deadline=None)
@given(
    w=st.sampled_from([8, 16]),
    r=st.integers(1, 3),
    seed=st.integers(0, 2**31),
)
def test_step_hypothesis(w, r, seed):
    rng = np.random.default_rng(seed)
    b = 1024
    x = _rand(rng, (b,), w)
    loc = _rand(rng, (r, b), w)
    psi = _rand(rng, (r,), w)
    xi = _rand(rng, (r,), w)
    xo, c = kernels.pipeline_step(x, loc, psi, xi, w=w, tile_b=b)
    exo, ec = ref.pipeline_step_np(x, loc, psi, xi, w)
    assert (np.asarray(xo) == exo).all() and (np.asarray(c) == ec).all()


# ---------------------------------------------------------------------------
# Full-chain equivalence: pipeline recurrence == generator-matrix encode.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("w", [8, 16])
@pytest.mark.parametrize("n,k", [(8, 4), (6, 4), (16, 11), (12, 8)])
def test_chain_equals_generator_matrix(n, k, w):
    rng = np.random.default_rng(n * 31 + k)
    b = 512
    obj = _rand(rng, (k, b), w)
    psi, xi = rr.draw_coeffs(n, k, w, seed=5)
    g = rr.generator_matrix(n, k, psi, xi, w)
    chain = rr.encode_chain(obj, psi, xi, n, w)
    matrix = ref.gf_gemm_np(g, obj, w)
    assert (chain == matrix).all()


@pytest.mark.parametrize("n,k", [(8, 4), (6, 4)])
def test_chain_via_pallas_kernel(n, k):
    """Drive the chain with the actual Pallas kernel stage by stage."""
    w = 8
    rng = np.random.default_rng(47)
    b = 1024
    obj = _rand(rng, (k, b), w)
    psi, xi = rr.draw_coeffs(n, k, w, seed=3)
    place = rr.placement(n, k)
    x = np.zeros(b, dtype=gf.DTYPE[w])
    c_blocks = []
    for i in range(n):
        loc = np.stack([obj[j] for j in place[i]])
        xo, c = kernels.pipeline_step(x, loc, psi[i], xi[i], w=w, tile_b=b)
        c_blocks.append(np.asarray(c))
        x = np.asarray(xo)
    got = np.stack(c_blocks)
    expect = rr.encode_chain(obj, psi, xi, n, w)
    assert (got == expect).all()


def test_paper_84_natural_dependency():
    """Paper Section IV-B: the (8,4) code has exactly one natural dependency,
    {c1, c2, c5, c6} (1-based), no matter the coefficient values."""
    w = 16
    n, k = 8, 4
    bad = frozenset({0, 1, 4, 5})  # 0-based
    import itertools

    dep_sets = None
    for seed in range(4):  # natural = dependent under every random draw
        psi, xi = rr.draw_coeffs(n, k, w, seed=seed)
        g = rr.generator_matrix(n, k, psi, xi, w)
        deps = {
            frozenset(sub)
            for sub in itertools.combinations(range(n), k)
            if rr.rank_gf(g[list(sub)], w) < k
        }
        dep_sets = deps if dep_sets is None else (dep_sets & deps)
    assert dep_sets == {bad}


def test_placement_shapes():
    assert rr.placement(8, 4) == [[0], [1], [2], [3], [0], [1], [2], [3]]
    assert rr.placement(6, 4) == [[0], [1], [2, 0], [3, 1], [2], [3]]
    with pytest.raises(ValueError):
        rr.placement(9, 4)  # n > 2k
    with pytest.raises(ValueError):
        rr.placement(4, 4)  # n == k
