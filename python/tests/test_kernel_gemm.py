"""Pallas gf_gemm kernel vs the jnp/numpy oracle (bit-exact)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import gf, kernels
from compile.kernels import ref


def _rand(rng, shape, w):
    return rng.integers(0, 1 << w, shape).astype(gf.DTYPE[w])


@pytest.mark.parametrize("w", [8, 16])
@pytest.mark.parametrize("m,k", [(5, 11), (11, 11), (4, 4), (1, 1), (3, 7)])
def test_gemm_matches_oracle(w, m, k):
    rng = np.random.default_rng(m * 100 + k + w)
    b = 8192
    g = _rand(rng, (m, k), w)
    d = _rand(rng, (k, b), w)
    out = np.asarray(kernels.gf_gemm(g, d, w=w))
    assert out.dtype == gf.DTYPE[w]
    assert (out == ref.gf_gemm_np(g, d, w)).all()


@pytest.mark.parametrize("w", [8, 16])
def test_gemm_multi_tile(w):
    """B spanning several grid steps exercises the BlockSpec index maps."""
    rng = np.random.default_rng(9)
    m, k, b = 5, 11, 8192 * 3
    g = _rand(rng, (m, k), w)
    d = _rand(rng, (k, b), w)
    out = np.asarray(kernels.gf_gemm(g, d, w=w))
    assert (out == ref.gf_gemm_np(g, d, w)).all()


def test_gemm_small_tile_equals_large_tile():
    """Tiling must not change the result."""
    rng = np.random.default_rng(10)
    g = _rand(rng, (5, 11), 8)
    d = _rand(rng, (11, 16384), 8)
    a = np.asarray(kernels.gf_gemm(g, d, w=8, tile_b=2048))
    b = np.asarray(kernels.gf_gemm(g, d, w=8, tile_b=16384))
    assert (a == b).all()


def test_gemm_zero_matrix():
    rng = np.random.default_rng(11)
    d = _rand(rng, (4, 8192), 8)
    g = np.zeros((3, 4), dtype=np.uint8)
    assert (np.asarray(kernels.gf_gemm(g, d, w=8)) == 0).all()


def test_gemm_identity():
    rng = np.random.default_rng(12)
    d = _rand(rng, (4, 8192), 8)
    g = np.eye(4, dtype=np.uint8)
    assert (np.asarray(kernels.gf_gemm(g, d, w=8)) == d).all()


def test_gemm_extreme_values():
    """All-0xFF and single-nonzero inputs hit the table edges."""
    g = np.full((2, 3), 0xFF, dtype=np.uint8)
    d = np.full((3, 8192), 0xFF, dtype=np.uint8)
    out = np.asarray(kernels.gf_gemm(g, d, w=8))
    assert (out == ref.gf_gemm_np(g, d, 8)).all()
    d[:, ::2] = 0
    out = np.asarray(kernels.gf_gemm(g, d, w=8))
    assert (out == ref.gf_gemm_np(g, d, 8)).all()


def test_jnp_oracle_matches_numpy_oracle():
    """The jnp oracle itself is pinned to the table-free numpy path."""
    rng = np.random.default_rng(13)
    g = _rand(rng, (5, 11), 8)
    d = _rand(rng, (11, 4096), 8)
    assert (np.asarray(ref.gf_gemm(g, d, 8)) == ref.gf_gemm_np(g, d, 8)).all()


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 8),
    k=st.integers(1, 16),
    w=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31),
)
def test_gemm_hypothesis_shapes(m, k, w, seed):
    """Hypothesis sweep over kernel shapes/dtypes vs the oracle."""
    rng = np.random.default_rng(seed)
    b = 1024
    g = _rand(rng, (m, k), w)
    d = _rand(rng, (k, b), w)
    out = np.asarray(kernels.gf_gemm(g, d, w=w, tile_b=b))
    assert (out == ref.gf_gemm_np(g, d, w)).all()


@settings(max_examples=10, deadline=None)
@given(data=st.data())
def test_gemm_linearity(data):
    """G(x XOR y) == Gx XOR Gy — linearity of the code over GF(2^w)."""
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    w = data.draw(st.sampled_from([8, 16]))
    g = _rand(rng, (4, 6), w)
    x = _rand(rng, (6, 1024), w)
    y = _rand(rng, (6, 1024), w)
    gx = np.asarray(kernels.gf_gemm(g, x, w=w, tile_b=1024))
    gy = np.asarray(kernels.gf_gemm(g, y, w=w, tile_b=1024))
    gxy = np.asarray(kernels.gf_gemm(g, x ^ y, w=w, tile_b=1024))
    assert (gxy == (gx ^ gy)).all()
