"""L2 model functions + AOT lowering: HLO text round-trips and stays correct."""

import os

import numpy as np
import pytest

from compile import aot, gf, model
from compile.kernels import ref


def _rand(rng, shape, w):
    return rng.integers(0, 1 << w, shape).astype(gf.DTYPE[w])


class TestModelFunctions:
    def test_classical_parity(self):
        rng = np.random.default_rng(1)
        g = _rand(rng, (5, 11), 8)
        d = _rand(rng, (11, 8192), 8)
        (out,) = model.classical_parity(g, d, w=8)
        assert (np.asarray(out) == ref.gf_gemm_np(g, d, 8)).all()

    def test_decode_apply_inverts_parity(self):
        """decode_apply(inv(G_sub)) recovers the object — end-to-end L2 math."""
        from compile import rapidraid_ref as rr

        rng = np.random.default_rng(2)
        w, n, k, b = 8, 8, 4, 8192
        obj = _rand(rng, (k, b), w)
        psi, xi = rr.draw_coeffs(n, k, w, seed=11)
        g = rr.generator_matrix(n, k, psi, xi, w)
        coded = rr.encode_chain(obj, psi, xi, n, w)
        sub = [2, 3, 6, 7]  # an independent 4-subset
        gs = g[sub]
        assert rr.rank_gf(gs, w) == k
        # invert by solving gs . inv = I column by column (Gauss via rank code)
        inv = _gf_invert(gs, w)
        (rec,) = model.decode_apply(inv, coded[sub], w=w)
        assert (np.asarray(rec) == obj).all()

    def test_pipeline_stage_tuple(self):
        rng = np.random.default_rng(3)
        x = _rand(rng, (8192,), 8)
        loc = _rand(rng, (1, 8192), 8)
        psi = _rand(rng, (1,), 8)
        xi = _rand(rng, (1,), 8)
        x_out, c = model.pipeline_stage(x, loc, psi, xi, w=8)
        exo, ec = ref.pipeline_step_np(x, loc, psi, xi, 8)
        assert (np.asarray(x_out) == exo).all() and (np.asarray(c) == ec).all()


def _gf_invert(mat, w):
    """Tiny Gauss-Jordan inverse over GF(2^w) for the tests."""
    k = mat.shape[0]
    a = np.array(mat, dtype=gf.DTYPE[w])
    inv = np.eye(k, dtype=gf.DTYPE[w])
    for col in range(k):
        piv = next(r for r in range(col, k) if a[r, col] != 0)
        a[[col, piv]] = a[[piv, col]]
        inv[[col, piv]] = inv[[piv, col]]
        s = gf.inv_np(a[col, col], w)
        a[col] = gf.mul_np(a[col], np.full(k, s, gf.DTYPE[w]), w)
        inv[col] = gf.mul_np(inv[col], np.full(k, s, gf.DTYPE[w]), w)
        for r in range(k):
            if r != col and a[r, col] != 0:
                f = np.full(k, a[r, col], gf.DTYPE[w])
                a[r] = a[r] ^ gf.mul_np(f, a[col], w)
                inv[r] = inv[r] ^ gf.mul_np(f, inv[col], w)
    return inv


class TestAotLowering:
    @pytest.mark.parametrize("w,m,k", [(8, 5, 11), (8, 4, 4)])
    def test_gemm_lowers_to_hlo_text(self, w, m, k):
        lowered, b = aot.lower_gemm(w, m, k)
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text and "HloModule" in text
        assert f"u{w}[{k},{b}]" in text  # data param shape present

    @pytest.mark.parametrize("w,r", [(8, 1), (8, 2)])
    def test_step_lowers_to_hlo_text(self, w, r):
        lowered, b = aot.lower_step(w, r)
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text
        # dual output: the root tuple carries both x_out and c
        assert text.count(f"u{w}[{b}]") >= 2

    def test_no_serialized_protos(self):
        """Guard: artifacts must be HLO text (xla_extension 0.5.1 rejects
        jax>=0.5 serialized protos with 64-bit ids)."""
        lowered, _ = aot.lower_gemm(8, 4, 4)
        text = aot.to_hlo_text(lowered)
        assert text.lstrip().startswith("HloModule")

    def test_manifest_written(self, tmp_path):
        import subprocess
        import sys

        # run the real CLI end-to-end into a temp dir (slow-ish but complete)
        env = dict(os.environ)
        r = subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path)],
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            capture_output=True,
            text=True,
            env=env,
            timeout=1200,
        )
        assert r.returncode == 0, r.stderr
        manifest = (tmp_path / "manifest.txt").read_text().strip().splitlines()
        assert len(manifest) == len(aot.GEMM_VARIANTS) + len(aot.STEP_VARIANTS)
        for line in manifest:
            kv = dict(p.split("=", 1) for p in line.split())
            assert (tmp_path / kv["file"]).exists()
            assert kv["kind"] in ("gemm", "step")
            assert int(kv["b"]) * (int(kv["w"]) // 8) == aot.BUF_BYTES
