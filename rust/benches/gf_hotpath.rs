//! Bench: GF hot-path microbenchmarks (§Perf) — native slice ops and the
//! PJRT-executed Pallas kernels, in bytes/second.
//!
//! Not a paper table; this is the §Perf instrumentation used to drive the
//! optimization pass (EXPERIMENTS.md §Perf).
//!
//! Run: `cargo bench --bench gf_hotpath`

use std::sync::Arc;
use std::time::Instant;

use rapidraid::backend::{BackendHandle, NativeBackend, PjrtBackend, Width};
use rapidraid::gf::{bytes_as_gf256, bytes_as_gf256_mut, mul_slice_xor, Gf256};
use rapidraid::util::SplitMix64;

fn mib_s(bytes: usize, iters: usize, dt: std::time::Duration) -> f64 {
    (bytes * iters) as f64 / (1 << 20) as f64 / dt.as_secs_f64()
}

fn main() {
    let mut rng = SplitMix64::new(1);
    const LEN: usize = 1 << 20;
    let mut src = vec![0u8; LEN];
    rng.fill_bytes(&mut src);
    let mut dst = vec![0u8; LEN];
    rng.fill_bytes(&mut dst);

    // raw gf256 mul_slice_xor
    let iters = 200;
    let t0 = Instant::now();
    for i in 0..iters {
        let c = Gf256((i % 254 + 2) as u8);
        mul_slice_xor(c, bytes_as_gf256(&src), bytes_as_gf256_mut(&mut dst));
    }
    let dt = t0.elapsed();
    println!(
        "{:<44} {:>10.1} MiB/s",
        "gf256 mul_slice_xor (1 MiB)",
        mib_s(LEN, iters, dt)
    );

    // backend pipeline_step throughput, native vs pjrt
    let backends: Vec<(&str, BackendHandle)> = {
        let mut v: Vec<(&str, BackendHandle)> = vec![("native", Arc::new(NativeBackend::new()))];
        match PjrtBackend::load(&rapidraid::runtime::artifacts::default_dir()) {
            Ok(b) => v.push(("pjrt", Arc::new(b))),
            Err(e) => eprintln!("# pjrt skipped: {e}"),
        }
        v
    };
    let buf = 65536usize;
    let x = &src[..buf];
    let l = &dst[..buf];
    for (name, be) in &backends {
        for w in [Width::W8, Width::W16] {
            let iters = if *name == "native" { 400 } else { 100 };
            // warmup (compiles the artifact on pjrt)
            be.pipeline_step(w, x, &[l], &[7], &[9]).unwrap();
            let t0 = Instant::now();
            for _ in 0..iters {
                let out = be.pipeline_step(w, x, &[l], &[7], &[9]).unwrap();
                std::hint::black_box(out);
            }
            let dt = t0.elapsed();
            println!(
                "{:<44} {:>10.1} MiB/s",
                format!("{name} pipeline_step r=1 {w} (64 KiB)"),
                mib_s(buf, iters, dt)
            );
        }
    }

    // backend gemm throughput (5x11, the (16,11) parity shape)
    let data: Vec<Vec<u8>> = (0..11)
        .map(|_| {
            let mut d = vec![0u8; buf];
            rng.fill_bytes(&mut d);
            d
        })
        .collect();
    let refs: Vec<&[u8]> = data.iter().map(|d| d.as_slice()).collect();
    let mat: Vec<Vec<u32>> = (0..5)
        .map(|_| (0..11).map(|_| (rng.next_u64() & 0xFF) as u32).collect())
        .collect();
    for (name, be) in &backends {
        let iters = if *name == "native" { 100 } else { 30 };
        be.gemm(Width::W8, &mat, &refs).unwrap();
        let t0 = Instant::now();
        for _ in 0..iters {
            std::hint::black_box(be.gemm(Width::W8, &mat, &refs).unwrap());
        }
        let dt = t0.elapsed();
        println!(
            "{:<44} {:>10.1} MiB/s (source bytes)",
            format!("{name} gemm 5x11 gf8 (11 x 64 KiB)"),
            mib_s(11 * buf, iters, dt)
        );
    }
}
