//! Bench: GF hot-path microbenchmarks (§Perf) — the op × width × kernel ×
//! buffer-size sweep behind the SIMD dispatch layer, plus the calibration
//! series that feed `UniformCost::from_measured`.
//!
//! Not a paper table; this is the §Perf instrumentation used to drive the
//! optimization pass (EXPERIMENTS.md §Perf) and the measured-throughput
//! calibration loop: the `calibrate/{mac,xor,store,invert}` candles plus
//! the `calibrate_bytes`/`calibrate_invert_dim` params in the emitted
//! `BENCH_gf-hotpath.json` are exactly what
//! `UniformCost::from_measured(&BenchJson)` consumes.
//!
//! Two multi-output series ride along: `fused/…` compares the relay
//! stage's two-accumulator kernel (`mul2_xor8/16`, one source read) to
//! the two-pass decomposition it replaced, and `gemm_rows/…` compares the
//! row-batched L1-chunked GEMM schedule to one dispatched pass per matrix
//! cell. Their headline params are `fused_vs_two_pass_speedup` and
//! `gemm_batched_vs_per_cell_speedup`.
//!
//! Run: `cargo bench --bench gf_hotpath`
//! Env: SAMPLES (default 15, smoke 5), SEED (default 1), SMOKE=1 (small
//! buffers — the CI configuration), REQUIRE_SPEEDUP=1 (assert the ≥ 4×
//! GF(2^8) mul_slice_xor acceptance bar, and the ≥ 1.5× fused-vs-two-pass
//! bar, when a SIMD kernel is active).
//! Writes BENCH_gf-hotpath.json.

use std::sync::Arc;
use std::time::Instant;

use rapidraid::backend::{BackendHandle, NativeBackend, PjrtBackend, Width};
use rapidraid::gf::{invert, simd, Gf256, Kernel, Matrix};
use rapidraid::metrics::BenchJson;
use rapidraid::resources::UniformCost;
use rapidraid::util::bench::{bench, env_u64, throughput_mib_s};
use rapidraid::util::SplitMix64;

/// Coefficients with no 0/1 shortcut: every pass is a real table MAC.
const C8: u8 = 0x53;
const C16: u16 = 0x1234;

fn main() {
    let t_start = Instant::now();
    let smoke = std::env::var("SMOKE").is_ok();
    let samples = env_u64("SAMPLES", if smoke { 5 } else { 15 }) as usize;
    let sizes: &[usize] = if smoke {
        &[4 << 10, 64 << 10]
    } else {
        &[4 << 10, 64 << 10, 1 << 20]
    };
    let largest = *sizes.last().unwrap();
    let kernels = Kernel::available_kernels();
    let active = Kernel::active();

    let mut report = BenchJson::new("gf-hotpath")
        .param("smoke", smoke)
        .param("samples", samples)
        .param("active_kernel", active)
        .param(
            "kernels",
            kernels
                .iter()
                .map(|k| k.name())
                .collect::<Vec<_>>()
                .join(","),
        );

    let mut rng = SplitMix64::new(env_u64("SEED", 1));
    let mut src = vec![0u8; largest];
    rng.fill_bytes(&mut src);
    let mut dst = vec![0u8; largest];
    rng.fill_bytes(&mut dst);

    println!("# GF hot path sweep — active kernel: {active}");

    // --- op × width × kernel × size sweep -----------------------------
    let ops: [(&str, fn(Kernel, &[u8], &mut [u8])); 5] = [
        ("gf8/mul_slice_xor", |k, s, d| simd::mul_xor8(k, C8, s, d)),
        ("gf8/mul_slice", |k, s, d| simd::mul8(k, C8, s, d)),
        ("gf16/mul_slice_xor", |k, s, d| simd::mul_xor16(k, C16, s, d)),
        ("gf16/mul_slice", |k, s, d| simd::mul16(k, C16, s, d)),
        ("xor", |k, s, d| simd::xor_bytes(k, s, d)),
    ];
    // Inner repeats keep each sample well above timer resolution on the
    // small buffers.
    let target_bytes: usize = if smoke { 1 << 20 } else { 1 << 23 };
    let mut mxor8_medians: Vec<(Kernel, std::time::Duration)> = Vec::new();
    for (op_name, op) in &ops {
        for &size in sizes {
            for &k in &kernels {
                let iters = (target_bytes / size).max(1);
                let name = format!("{op_name}/{}/{}KiB", k.name(), size >> 10);
                let c = bench(&name, 1, samples, || {
                    for _ in 0..iters {
                        op(k, &src[..size], &mut dst[..size]);
                    }
                    std::hint::black_box(&dst);
                });
                let mibs = throughput_mib_s(size * iters, c.median());
                println!("{name:<44} {mibs:>10.1} MiB/s");
                if *op_name == "gf8/mul_slice_xor" && size == largest {
                    mxor8_medians.push((k, c.median()));
                }
                report.series.push(c);
            }
        }
    }

    // --- acceptance headline: GF(2^8) mul_slice_xor, active vs scalar --
    let median_of = |k: Kernel| {
        mxor8_medians
            .iter()
            .find(|(mk, _)| *mk == k)
            .map(|(_, d)| d.as_secs_f64())
            .expect("sweep covered the kernel")
    };
    let speedup = median_of(Kernel::Scalar) / median_of(active);
    println!("# gf8 mul_slice_xor: {active} is {speedup:.2}x scalar at {}KiB", largest >> 10);
    report = report.param("gf8_mul_slice_xor_speedup", format!("{speedup:.3}"));
    if env_u64("REQUIRE_SPEEDUP", 0) == 1 && active != Kernel::Scalar {
        assert!(
            speedup >= 4.0,
            "acceptance: expected >= 4x for gf8 mul_slice_xor on {active}, got {speedup:.2}x"
        );
    }

    // --- fused relay stage: one-pass mul2 vs the two-pass decomposition
    // Extra accumulator so the fused kernels get two distinct outputs.
    let mut acc2 = vec![0u8; largest];
    rng.fill_bytes(&mut acc2);
    let q8: u8 = 0x8E;
    let q16: u16 = 0x8001;
    let mut fused8_medians: Vec<(Kernel, bool, std::time::Duration)> = Vec::new();
    for &size in sizes {
        for &k in &kernels {
            let iters = (target_bytes / size).max(1);
            for one_pass in [false, true] {
                for (wname, w16) in [("gf8", false), ("gf16", true)] {
                    let variant = if one_pass { "one_pass" } else { "two_pass" };
                    let name =
                        format!("fused/{wname}/{variant}/{}/{}KiB", k.name(), size >> 10);
                    let c = bench(&name, 1, samples, || {
                        for _ in 0..iters {
                            match (one_pass, w16) {
                                (true, false) => simd::mul2_xor8(
                                    k,
                                    C8,
                                    q8,
                                    &src[..size],
                                    &mut dst[..size],
                                    &mut acc2[..size],
                                ),
                                (true, true) => simd::mul2_xor16(
                                    k,
                                    C16,
                                    q16,
                                    &src[..size],
                                    &mut dst[..size],
                                    &mut acc2[..size],
                                ),
                                (false, false) => {
                                    simd::mul_xor8(k, C8, &src[..size], &mut dst[..size]);
                                    simd::mul_xor8(k, q8, &src[..size], &mut acc2[..size]);
                                }
                                (false, true) => {
                                    simd::mul_xor16(k, C16, &src[..size], &mut dst[..size]);
                                    simd::mul_xor16(k, q16, &src[..size], &mut acc2[..size]);
                                }
                            }
                        }
                        std::hint::black_box((&dst, &acc2));
                    });
                    let mibs = throughput_mib_s(size * iters, c.median());
                    println!("{name:<44} {mibs:>10.1} MiB/s");
                    if !w16 && size == largest {
                        fused8_medians.push((k, one_pass, c.median()));
                    }
                    report.series.push(c);
                }
            }
        }
    }
    let fused_median_of = |k: Kernel, one_pass: bool| {
        fused8_medians
            .iter()
            .find(|(mk, mo, _)| *mk == k && *mo == one_pass)
            .map(|(_, _, d)| d.as_secs_f64())
            .expect("fused sweep covered the kernel")
    };
    let fused_speedup = fused_median_of(active, false) / fused_median_of(active, true);
    println!(
        "# gf8 fused relay stage: one pass is {fused_speedup:.2}x two-pass on {active} at {}KiB",
        largest >> 10
    );
    report = report.param("fused_vs_two_pass_speedup", format!("{fused_speedup:.3}"));
    if env_u64("REQUIRE_SPEEDUP", 0) == 1 && active != Kernel::Scalar {
        assert!(
            fused_speedup >= 1.5,
            "acceptance: expected >= 1.5x for the fused relay stage on {active}, got {fused_speedup:.2}x"
        );
    }

    // --- row-batched GEMM vs one dispatched pass per matrix cell -------
    let gemm_len: usize = if smoke { 16 << 10 } else { 256 << 10 };
    let gemm_m = 4usize;
    let gemm_k = 8usize;
    let gemm_data_own: Vec<Vec<u8>> = (0..gemm_k)
        .map(|_| {
            let mut d = vec![0u8; gemm_len];
            rng.fill_bytes(&mut d);
            d
        })
        .collect();
    let gemm_data: Vec<&[u8]> = gemm_data_own.iter().map(|d| d.as_slice()).collect();
    // All-general coefficients: every cell is a real MAC in both schedules.
    let gemm_mat: Vec<Vec<u32>> = (0..gemm_m)
        .map(|r| (0..gemm_k).map(|c| (2 + r * gemm_k + c) as u32).collect())
        .collect();
    report = report
        .param("gemm_rows_m", gemm_m)
        .param("gemm_rows_k", gemm_k)
        .param("gemm_rows_len", gemm_len);
    let mut gemm_medians: Vec<(Kernel, bool, std::time::Duration)> = Vec::new();
    for &k in &kernels {
        for batched in [false, true] {
            let variant = if batched { "batched" } else { "per_cell" };
            let name = format!("gemm_rows/{variant}/{}", k.name());
            let c = bench(&name, 1, samples, || {
                let mut out = vec![vec![0u8; gemm_len]; gemm_m];
                if batched {
                    simd::gemm_rows8(k, &gemm_mat, &gemm_data, &mut out);
                } else {
                    for (row, o) in gemm_mat.iter().zip(out.iter_mut()) {
                        for (&cf, d) in row.iter().zip(&gemm_data) {
                            simd::mul_xor8(k, cf as u8, d, o);
                        }
                    }
                }
                std::hint::black_box(&out);
            });
            let mibs = throughput_mib_s(gemm_len * gemm_m * gemm_k, c.median());
            println!("{name:<44} {mibs:>10.1} MiB/s (matrix bytes)");
            gemm_medians.push((k, batched, c.median()));
            report.series.push(c);
        }
    }
    let gemm_median_of = |k: Kernel, batched: bool| {
        gemm_medians
            .iter()
            .find(|(mk, mb, _)| *mk == k && *mb == batched)
            .map(|(_, _, d)| d.as_secs_f64())
            .expect("gemm sweep covered the kernel")
    };
    let gemm_speedup = gemm_median_of(active, false) / gemm_median_of(active, true);
    println!("# gemm: batched rows are {gemm_speedup:.2}x per-cell on {active}");
    report = report.param("gemm_batched_vs_per_cell_speedup", format!("{gemm_speedup:.3}"));

    // --- calibration series (one pass per sample, so rate = work/median)
    let cal_bytes: usize = if smoke { 64 << 10 } else { 1 << 20 };
    let cal_dim: usize = if smoke { 32 } else { 64 };
    report = report
        .param("calibrate_bytes", cal_bytes)
        .param("calibrate_invert_dim", cal_dim);
    let mac = bench("calibrate/mac", 1, samples, || {
        simd::mul_xor8(active, C8, &src[..cal_bytes], &mut dst[..cal_bytes]);
        std::hint::black_box(&dst);
    });
    let xor = bench("calibrate/xor", 1, samples, || {
        simd::xor_bytes(active, &src[..cal_bytes], &mut dst[..cal_bytes]);
        std::hint::black_box(&dst);
    });
    let store = bench("calibrate/store", 1, samples, || {
        dst[..cal_bytes].copy_from_slice(&src[..cal_bytes]);
        std::hint::black_box(&dst);
    });
    let m: Matrix<Gf256> = Matrix::cauchy(cal_dim, cal_dim);
    let inv = bench("calibrate/invert", 1, samples, || {
        std::hint::black_box(invert(&m).expect("cauchy matrices are invertible"));
    });
    for c in [mac, xor, store, inv] {
        println!("{:<44} median={:?}", c.name, c.median());
        report.series.push(c);
    }
    match UniformCost::from_measured(&report) {
        Ok(u) => println!(
            "# measured UniformCost: mac {:.3e} B/s, xor {:.3e} B/s, store {:.3e} B/s, invert {:.3e} elems/s",
            u.mac_bytes_per_sec, u.xor_bytes_per_sec, u.store_bytes_per_sec, u.invert_elems_per_sec
        ),
        Err(e) => eprintln!("# calibration failed: {e}"),
    }

    // --- end-to-end pipeline_step, native vs pjrt (non-smoke only) -----
    if !smoke {
        let backends: Vec<(&str, BackendHandle)> = {
            let mut v: Vec<(&str, BackendHandle)> =
                vec![("native", Arc::new(NativeBackend::new()))];
            match PjrtBackend::load(&rapidraid::runtime::artifacts::default_dir()) {
                Ok(b) => v.push(("pjrt", Arc::new(b))),
                Err(e) => eprintln!("# pjrt skipped: {e}"),
            }
            v
        };
        let buf = 64 << 10;
        let x = &src[..buf];
        let l = &dst[..buf];
        for (name, be) in &backends {
            for w in [Width::W8, Width::W16] {
                let c = bench(&format!("pipeline_step/{name}/{w}"), 1, samples, || {
                    std::hint::black_box(be.pipeline_step(w, x, &[l], &[7], &[9]).unwrap());
                });
                let mibs = throughput_mib_s(buf, c.median());
                println!("{:<44} {mibs:>10.1} MiB/s", c.name);
                report.spans.push(c);
            }
        }
    }

    report.wall = t_start.elapsed();
    let path = report
        .write_to_dir(std::path::Path::new("."))
        .expect("write BENCH json");
    println!("# wrote {}", path.display());
}
