//! Bench: paper Fig. 3 — dependency census for n ∈ {8, 12, 16}, all
//! n/2 ≤ k < n, plus census wall-time (the paper notes enumeration cost
//! grows as C(n,k); we report it).
//!
//! Run: `cargo bench --bench fig3_census`

use std::time::Instant;

use rapidraid::codes::census;

fn main() {
    println!("# Fig. 3 — linear dependencies of (n,k) RapidRAID codes");
    println!(
        "{:>4} {:>4} {:>10} {:>12} {:>14} {:>6} {:>12}",
        "n", "k", "subsets", "dependent", "%independent", "MDS", "census_time"
    );
    for n in [8usize, 12, 16] {
        for k in (n / 2)..n {
            let t0 = Instant::now();
            let r = census(n, k, 3, 1).expect("census");
            let dt = t0.elapsed();
            println!(
                "{:>4} {:>4} {:>10} {:>12} {:>13.4}% {:>6} {:>12.3?}",
                n,
                k,
                r.total_subsets,
                r.dependent_count(),
                r.percent_independent(),
                if r.is_mds() { "yes" } else { "no" },
                dt
            );
            // Conjecture 1 must hold on every bench run
            assert_eq!(r.is_mds(), k >= n - 3, "Conjecture 1 violated at ({n},{k})");
        }
    }
    println!("# Conjecture 1 (MDS iff k >= n-3) verified on this run.");
}
