//! Bench: the `rapidraid sweep` grid — repair triggers × chain policies ×
//! CPU cost profiles × pipeline topologies (chain + tree:2), each cell one
//! seeded long-run failure trace on the SimClock.
//!
//! Run: `cargo bench --bench sweep`
//! Env: VIRTUAL_SECS, NODES, OBJECTS, SEED (override the base trace),
//! SMOKE=1 (short traces, 8-cell grid — the CI configuration). Writes
//! BENCH_sweep.json.

use std::sync::Arc;

use rapidraid::backend::{BackendHandle, NativeBackend};
use rapidraid::util::bench::env_u64;
use rapidraid::workload::{run_sweep, LongRunConfig, SweepConfig};

fn main() {
    let smoke = std::env::var("SMOKE").is_ok();
    let mut base = if smoke {
        LongRunConfig::smoke()
    } else {
        LongRunConfig::paper_scale()
    };
    base.virtual_secs = env_u64("VIRTUAL_SECS", base.virtual_secs);
    base.nodes = env_u64("NODES", base.nodes as u64) as usize;
    base.objects = env_u64("OBJECTS", base.objects as u64) as usize;
    base.seed = env_u64("SEED", base.seed);
    let grid = if smoke {
        let mut g = SweepConfig::smoke();
        g.base = base;
        g
    } else {
        SweepConfig::default_grid(base)
    };

    let backend: BackendHandle = Arc::new(NativeBackend::new());
    let (rows, report) =
        run_sweep(&grid, &backend, &mut std::io::stdout().lock()).expect("sweep");
    assert!(
        rows.iter().all(|r| r.report.all_decodable()),
        "data loss in a sweep cell"
    );
    let path = report
        .write_to_dir(std::path::Path::new("."))
        .expect("write BENCH json");
    println!("# wrote {}", path.display());
}
