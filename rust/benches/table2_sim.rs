//! Bench: the `table2-sim` preset — the paper's Table-II classical-vs-
//! pipelined coding-time comparison reproduced on the discrete-event
//! SimClock, with per-node GF compute charged by the `UniformCost` and
//! heterogeneous `ProfileCost` models (k=8/n=11 and k=16/n=22).
//!
//! Run: `cargo bench --bench table2_sim`
//! Env: BLOCK_KIB (default 1024), SEED (default 5), SMOKE=1 (128 KiB
//! blocks — the CI configuration). Writes BENCH_table2-sim.json.

use std::sync::Arc;

use rapidraid::backend::{BackendHandle, NativeBackend};
use rapidraid::bench_scenarios::table2_sim;
use rapidraid::util::bench::env_u64;

fn main() {
    let block_kib = if std::env::var("SMOKE").is_ok() {
        128
    } else {
        env_u64("BLOCK_KIB", 1024) as usize
    };
    let seed = env_u64("SEED", 5);
    let backend: BackendHandle = Arc::new(NativeBackend::new());
    let (rows, report) = table2_sim(
        &backend,
        block_kib << 10,
        seed,
        &mut std::io::stdout().lock(),
    )
    .expect("table2-sim");
    assert_eq!(rows.len(), 4, "2 code sizes x 2 cost models expected");
    assert!(
        report
            .spans
            .iter()
            .any(|c| c.name.ends_with(".compute") && c.max() > std::time::Duration::ZERO),
        "cost models charged no compute"
    );
    let path = report
        .write_to_dir(std::path::Path::new("."))
        .expect("write BENCH json");
    println!("# wrote {}", path.display());
}
