//! Bench: the `topo-sim` preset — the pipeline-shape shootout. Chain vs
//! tree vs hybrid encoding of the same objects (k=8/n=11 and k=16/n=22)
//! under the `UniformCost` and heterogeneous `ProfileCost` models on a
//! jitter-free SimClock, with per-cell decode verification through the
//! topology-composed generator.
//!
//! Run: `cargo bench --bench topo_sim`
//! Env: BLOCK_KIB (default 512), SEED (default 5), SMOKE=1 (128 KiB
//! blocks — the CI configuration). Writes BENCH_topo-sim.json.

use std::sync::Arc;

use rapidraid::backend::{BackendHandle, NativeBackend};
use rapidraid::bench_scenarios::topo_sim;
use rapidraid::coordinator::Topology;
use rapidraid::util::bench::env_u64;

fn main() {
    let block_kib = if std::env::var("SMOKE").is_ok() {
        128
    } else {
        env_u64("BLOCK_KIB", 512) as usize
    };
    let seed = env_u64("SEED", 5);
    let backend: BackendHandle = Arc::new(NativeBackend::new());
    let (rows, report) = topo_sim(
        &backend,
        block_kib << 10,
        seed,
        &mut std::io::stdout().lock(),
    )
    .expect("topo-sim");
    assert_eq!(
        rows.len(),
        16,
        "2 code sizes x 2 cost models x (3 shapes + 1 placed cell) expected"
    );
    // the ec2-mix cells must show a non-chain winner (acceptance gate)
    for (n, k) in [(11usize, 8usize), (22, 16)] {
        let chain = rows
            .iter()
            .find(|r| {
                r.n == n && r.cost == "ec2-mix" && !r.placed && r.topology == Topology::Chain
            })
            .expect("chain cell");
        assert!(
            rows.iter().any(|r| r.n == n
                && r.cost == "ec2-mix"
                && !r.placed
                && r.topology != Topology::Chain
                && r.coding < chain.coding),
            "(n={n},k={k}) ec2-mix: no non-chain shape beat the chain"
        );
    }
    let path = report
        .write_to_dir(std::path::Path::new("."))
        .expect("write BENCH json");
    println!("# wrote {}", path.display());
}
