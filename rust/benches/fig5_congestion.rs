//! Bench: paper Fig. 5 — coding times under network congestion.
//!
//! Sweeps the number of netem-congested nodes (500 Mbps + 100±10 ms) for
//! single-object (5a) and 16-concurrent-object (5b) archival, CEC vs RR8.
//!
//! Run: `cargo bench --bench fig5_congestion`
//! Env: PRESET (default tpc; `tpc-sim` runs on the discrete-event
//! SimClock in wall-clock seconds), BLOCK_MIB (default 1), SAMPLES
//! (default 3), MAX_CONGESTED (default 8).

use std::sync::Arc;

use rapidraid::backend::{BackendHandle, NativeBackend};
use rapidraid::bench_scenarios::fig5_congestion;

fn main() {
    // 16 MiB default: keeps τ_block ≫ the netem 100 ms latency, as in the
    // paper (64 MiB at 1 GbE). At small blocks the +100 ms/hop latency
    // dominates the pipeline and flips the Fig. 5 shape (EXPERIMENTS.md).
    let block = std::env::var("BLOCK_MIB")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(16)
        << 20;
    let samples = std::env::var("SAMPLES")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(3);
    let max_congested = std::env::var("MAX_CONGESTED")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(8);
    let preset = std::env::var("PRESET").unwrap_or_else(|_| "tpc".to_string());
    let backend: BackendHandle = Arc::new(NativeBackend::new());
    let mut out = std::io::stdout().lock();

    // Fig. 5a: single object
    let report = fig5_congestion(&backend, &preset, max_congested, 1, block, samples, &mut out)
        .expect("fig5a");
    report
        .write_to_dir(std::path::Path::new("."))
        .expect("write BENCH json");
    println!();
    // Fig. 5b: 16 concurrent objects (quarter-size blocks + coarser sweep
    // to bound wall time; the per-object contention shape is preserved)
    let report = fig5_congestion(
        &backend,
        &preset,
        max_congested.min(4),
        16,
        block / 4,
        1.max(samples / 3),
        &mut out,
    )
    .expect("fig5b");
    report
        .write_to_dir(std::path::Path::new("."))
        .expect("write BENCH json");
}
