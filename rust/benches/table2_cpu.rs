//! Bench: paper Table II — CPU-only (16,11) coding time, CEC vs RR8 vs RR16.
//!
//! The paper swept three CPUs (Atom/Xeon/Core2); we sweep the backend
//! (native GF vs the PJRT-executed Pallas kernels) and the word size on the
//! host CPU, which exposes the same orderings (see DESIGN.md §3).
//!
//! Run: `cargo bench --bench table2_cpu`

use std::sync::Arc;

use rapidraid::backend::{BackendHandle, NativeBackend, PjrtBackend};
use rapidraid::bench_scenarios::table2_cpu;

fn main() {
    let block = std::env::var("BLOCK_MIB")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(4)
        << 20;
    let mut out = std::io::stdout().lock();

    let native: BackendHandle = Arc::new(NativeBackend::new());
    let report = table2_cpu(&native, block, &mut out).expect("native table2");
    report
        .write_to_dir(std::path::Path::new("."))
        .expect("write BENCH json");

    match PjrtBackend::load(&rapidraid::runtime::artifacts::default_dir()) {
        Ok(be) => {
            let be: BackendHandle = Arc::new(be);
            let report = table2_cpu(&be, block, &mut out).expect("pjrt table2");
            report
                .write_to_dir(std::path::Path::new("."))
                .expect("write BENCH json");
        }
        Err(e) => eprintln!("# pjrt backend skipped: {e} (run `make artifacts`)"),
    }
}
