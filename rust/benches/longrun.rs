//! Bench: long-run crash/revive/congestion trace on the discrete-event
//! SimClock — thousands of virtual seconds of 50-node cluster life per
//! wall-clock second.
//!
//! Run: `cargo bench --bench longrun`
//! Env: VIRTUAL_SECS (default 1000), EPOCH_SECS (default 10), NODES
//! (default 50), OBJECTS (default 8), SEED, SMOKE=1 (one guaranteed
//! crash+repair round — the CI configuration).

use std::sync::Arc;
use std::time::Instant;

use rapidraid::backend::{BackendHandle, NativeBackend};
use rapidraid::util::bench::env_u64;
use rapidraid::workload::{run_long_run, LongRunConfig};

fn main() {
    let mut cfg = if std::env::var("SMOKE").is_ok() {
        LongRunConfig::smoke()
    } else {
        LongRunConfig::paper_scale()
    };
    cfg.virtual_secs = env_u64("VIRTUAL_SECS", cfg.virtual_secs);
    cfg.epoch_secs = env_u64("EPOCH_SECS", cfg.epoch_secs).max(1);
    cfg.nodes = env_u64("NODES", cfg.nodes as u64) as usize;
    cfg.objects = env_u64("OBJECTS", cfg.objects as u64) as usize;
    cfg.seed = env_u64("SEED", cfg.seed);

    let backend: BackendHandle = Arc::new(NativeBackend::new());
    let wall = Instant::now();
    let report =
        run_long_run(&cfg, &backend, Some(&mut std::io::stdout().lock())).expect("longrun");
    let wall = wall.elapsed();
    println!(
        "# wall {:.3}s for {:.0}s virtual ({:.0}x time compression)",
        wall.as_secs_f64(),
        report.virtual_elapsed.as_secs_f64(),
        report.virtual_elapsed.as_secs_f64() / wall.as_secs_f64().max(1e-9)
    );
    assert!(report.all_decodable(), "data loss: {}", report.summary());
}
