//! Bench: the `straggler-sim` preset — the adaptive control plane against
//! every static pipeline shape (chain, tree:2, hybrid:4:2) on a
//! straggler-seeded SimClock pool (ec2-mix compute, two NICs clamped 10x,
//! one thinclient CPU, all inside the identity placement's first n ids).
//! The adaptive cell places, shapes and re-ranks from plan-boundary load
//! snapshots; its makespan must beat every static cell for both code
//! sizes.
//!
//! Run: `cargo bench --bench straggler_sim`
//! Env: BLOCK_KIB (default 256), SEED (default 5), SMOKE=1 (64 KiB
//! blocks — the CI configuration). Writes BENCH_straggler-sim.json.

use std::sync::Arc;

use rapidraid::backend::{BackendHandle, NativeBackend};
use rapidraid::bench_scenarios::straggler_sim;
use rapidraid::cluster::RuntimeKind;
use rapidraid::util::bench::env_u64;

fn main() {
    let block_kib = if std::env::var("SMOKE").is_ok() {
        64
    } else {
        env_u64("BLOCK_KIB", 256) as usize
    };
    let seed = env_u64("SEED", 5);
    let backend: BackendHandle = Arc::new(NativeBackend::new());
    let (rows, report) = straggler_sim(
        &backend,
        block_kib << 10,
        seed,
        RuntimeKind::Auto,
        &mut std::io::stdout().lock(),
    )
    .expect("straggler-sim");
    assert_eq!(rows.len(), 8, "2 code sizes x (3 static shapes + adaptive)");
    // acceptance gate: the closed loop beats every static shape per size
    for (n, k) in [(11usize, 8usize), (22, 16)] {
        let adaptive = rows
            .iter()
            .find(|r| r.n == n && r.adaptive)
            .expect("adaptive cell")
            .makespan;
        for r in rows.iter().filter(|r| r.n == n && !r.adaptive) {
            assert!(
                adaptive < r.makespan,
                "(n={n},k={k}) adaptive {adaptive:?} lost to static {} at {:?}",
                r.cell,
                r.makespan
            );
        }
    }
    let path = report
        .write_to_dir(std::path::Path::new("."))
        .expect("write BENCH json");
    println!("# wrote {}", path.display());
}
