//! Bench: the `scale-sim` preset — multiplexed-runtime scale acceptance.
//! 2,048 SimClock nodes (64 racks of 32) live through one virtual day of
//! epoch-batched rack-local archivals, all cooperatively scheduled on one
//! driver thread. Every epoch decode-verifies a seeded sample and drops
//! its blocks, so memory stays bounded at any virtual run length.
//!
//! Run: `cargo bench --bench scale_sim`
//! Env: SMOKE=1 (hourly epochs of small batches — the CI configuration,
//! same 2,048-node / one-virtual-day floors), NODES, RACK, VIRTUAL_SECS,
//! EPOCH_SECS, OBJECTS_PER_EPOCH, BLOCK_BYTES, SEED override the preset.
//! Writes BENCH_scale-sim.json.

use std::sync::Arc;
use std::time::Duration;

use rapidraid::backend::{BackendHandle, NativeBackend};
use rapidraid::bench_scenarios::{scale_sim, ScaleSimConfig};
use rapidraid::clock::{Clock, RealClock};
use rapidraid::util::bench::env_u64;

fn main() {
    let mut cfg = if std::env::var("SMOKE").is_ok() {
        ScaleSimConfig::smoke()
    } else {
        ScaleSimConfig::paper_scale()
    };
    cfg.nodes = env_u64("NODES", cfg.nodes as u64) as usize;
    cfg.rack = env_u64("RACK", cfg.rack as u64) as usize;
    cfg.virtual_secs = env_u64("VIRTUAL_SECS", cfg.virtual_secs);
    cfg.epoch_secs = env_u64("EPOCH_SECS", cfg.epoch_secs);
    cfg.objects_per_epoch = env_u64("OBJECTS_PER_EPOCH", cfg.objects_per_epoch as u64) as usize;
    cfg.block_bytes = env_u64("BLOCK_BYTES", cfg.block_bytes as u64) as usize;
    cfg.seed = env_u64("SEED", cfg.seed);

    let backend: BackendHandle = Arc::new(NativeBackend::new());
    let wall = RealClock::new();
    let (report, bench) =
        scale_sim(&cfg, &backend, &mut std::io::stdout().lock()).expect("scale-sim");

    // acceptance floors: thousands of nodes, at least one virtual day,
    // wall-clock seconds — the multiplexed runtime's raison d'être
    assert!(report.nodes >= 2000, "scale floor: {} nodes", report.nodes);
    assert!(
        report.virtual_elapsed >= Duration::from_secs(86_400),
        "virtual-day floor: {:?}",
        report.virtual_elapsed
    );
    assert_eq!(report.verified, report.epochs as usize, "every epoch verifies");
    let elapsed = wall.now();
    assert!(
        elapsed < Duration::from_secs(60),
        "wall budget blown: {elapsed:?}"
    );

    let path = bench
        .write_to_dir(std::path::Path::new("."))
        .expect("write BENCH json");
    println!("# wrote {}", path.display());
}
