//! Bench: single-block repair time — star (classical k-transfer) vs
//! pipelined (Li et al. 2019) — under the paper's netem congestion sweep.
//!
//! Run: `cargo bench --bench fig_repair`
//! Env: PRESET (default tpc; `tpc-sim` runs the identical sweep on the
//! discrete-event SimClock in wall-clock seconds), BLOCK_MIB (default 16),
//! SAMPLES (default 3), MAX_CONGESTED (default 4). CI runs this in smoke
//! mode (BLOCK_MIB=1, SAMPLES=1, MAX_CONGESTED=1) purely to keep the
//! repair path from bitrotting; the star-vs-pipelined comparison is only
//! meaningful at paper-faithful block sizes where bandwidth, not the netem
//! latency, dominates.

use std::sync::Arc;

use rapidraid::backend::{BackendHandle, NativeBackend};
use rapidraid::bench_scenarios::fig_repair;

fn main() {
    let preset = std::env::var("PRESET").unwrap_or_else(|_| "tpc".to_string());
    let block = std::env::var("BLOCK_MIB")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(16)
        << 20;
    let samples = std::env::var("SAMPLES")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(3);
    let max_congested = std::env::var("MAX_CONGESTED")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(4);
    let backend: BackendHandle = Arc::new(NativeBackend::new());
    let report = fig_repair(
        &backend,
        &preset,
        max_congested,
        block,
        samples,
        &mut std::io::stdout().lock(),
    )
    .expect("fig_repair");
    report
        .write_to_dir(std::path::Path::new("."))
        .expect("write BENCH json");
}
