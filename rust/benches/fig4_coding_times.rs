//! Bench: paper Fig. 4 — coding times of CEC / RR8 / RR16 on the TPC and
//! EC2 presets, single object (4a) and 16 concurrent objects (4b).
//!
//! Run: `cargo bench --bench fig4_coding_times`
//! Env: BLOCK_MIB (default 1), SAMPLES (default 5; 3 for the batch runs).

use std::sync::Arc;

use rapidraid::backend::{BackendHandle, NativeBackend};
use rapidraid::bench_scenarios::fig4_coding_times;

fn main() {
    let block = std::env::var("BLOCK_MIB")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(4)
        << 20;
    let samples = std::env::var("SAMPLES")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(5);
    let backend: BackendHandle = Arc::new(NativeBackend::new());
    let mut out = std::io::stdout().lock();

    for preset in ["tpc", "ec2"] {
        // Fig. 4a: one object on an idle cluster
        let report =
            fig4_coding_times(&backend, preset, 1, block, samples, &mut out).expect("fig4a");
        report
            .write_to_dir(std::path::Path::new("."))
            .expect("write BENCH json");
        println!();
        // Fig. 4b: 16 concurrent objects (fewer samples; each is 16 jobs)
        let report = fig4_coding_times(&backend, preset, 16, block, samples.div_ceil(2), &mut out)
            .expect("fig4b");
        report
            .write_to_dir(std::path::Path::new("."))
            .expect("write BENCH json");
        println!();
    }
}
