//! Property tests of the unified resource model (PR 4 acceptance):
//!
//! 1. **Cost models never change bytes** — archival + repair under
//!    `ZeroCost`, `UniformCost` and a heterogeneous `ProfileCost` produce
//!    byte-identical coded blocks for the same seed: cost models may only
//!    move virtual time, never data.
//! 2. **Slowing a chain node strictly increases the chain's virtual
//!    makespan** — heterogeneous profiles place the bottleneck on the
//!    slowest stage.
//! 3. **Charging compute strictly increases virtual time over
//!    `ZeroCost`** — compute genuinely occupies the timeline.

use std::sync::Arc;
use std::time::Duration;

use rapidraid::backend::{BackendHandle, NativeBackend};
use rapidraid::cluster::{Cluster, ClusterSpec};
use rapidraid::codes::rapidraid::RapidRaidCode;
use rapidraid::coordinator::{ingest_object, survey_coded, PipelineJob, PlanExecutor};
use rapidraid::gf::Gf256;
use rapidraid::repair::{PipelinedRepairJob, RepairJob};
use rapidraid::resources::{
    CostModelHandle, NodeProfile, ProfileCost, UniformCost, ZeroCost,
};
use rapidraid::storage::{BlockKey, ObjectId, ReplicaPlacement};
use rapidraid::util::with_timeout;

const N: usize = 8;
const K: usize = 4;
const BLOCK: usize = 64 * 1024;
const BUF: usize = 8 * 1024;

/// Archive one object and repair one crashed tail block under `cost`;
/// return every coded block's bytes (repaired position included) plus the
/// two end-to-end virtual durations.
fn run_under(cost: CostModelHandle) -> (Vec<Vec<u8>>, [Duration; 2]) {
    let mut spec = ClusterSpec::tpc(N + 1).sim().with_cost(cost);
    spec.jitter = Duration::ZERO; // exact timelines: only the cost model varies
    let cluster = Cluster::start(spec);
    let object = ObjectId(4100);
    let placement = ReplicaPlacement::new(object, K, (0..N).collect()).unwrap();
    ingest_object(&cluster, &placement, BLOCK).unwrap();
    let code = RapidRaidCode::<Gf256>::with_seed(N, K, 7).unwrap();
    let backend: BackendHandle = Arc::new(NativeBackend::new());
    let exec = PlanExecutor::new(&cluster, backend.clone());

    let job = PipelineJob::from_code(&code, &placement, BUF, BLOCK).unwrap();
    let t_archive = exec.run(&job.plan().unwrap()).unwrap();

    let lost = N - 1;
    cluster.fail_node(lost);
    let (avail, bb) = survey_coded(&cluster, &placement.chain, object);
    let rjob =
        RepairJob::from_code(&code, object, &placement.chain, lost, N, &avail, BUF, bb).unwrap();
    let t_repair = exec.run(&PipelinedRepairJob::new(rjob).plan().unwrap()).unwrap();

    let mut coded = Vec::with_capacity(N);
    for pos in 0..N {
        let holder = if pos == lost { N } else { placement.chain[pos] };
        let block = cluster
            .node(holder)
            .peek(BlockKey::coded(object, pos))
            .unwrap()
            .unwrap();
        coded.push((*block).clone());
    }
    (coded, [t_archive, t_repair])
}

#[test]
fn cost_models_never_change_bytes() {
    let (zero, t_zero) = with_timeout(120, || run_under(ZeroCost::handle()));
    let (uniform, t_uniform) = with_timeout(120, || run_under(UniformCost::handle()));
    let (hetero, _) = with_timeout(120, || {
        run_under(ProfileCost::handle(NodeProfile::ec2_mix()).unwrap())
    });
    assert_eq!(zero, uniform, "UniformCost changed coded bytes");
    assert_eq!(zero, hetero, "ProfileCost changed coded bytes");
    // ...but compute genuinely occupies the timeline: both the archival
    // chain and the repair chain take strictly longer than on free CPUs.
    for i in 0..2 {
        assert!(
            t_uniform[i] > t_zero[i],
            "charged run not slower: {:?} vs {:?}",
            t_uniform[i],
            t_zero[i]
        );
    }
}

/// Pipelined archival makespan of an (8,4) chain where every node runs
/// `fast` except `slow_node` (usize::MAX = nobody slowed).
fn chain_makespan(slow_node: usize) -> Duration {
    let fast = NodeProfile::EC2_LARGE;
    let slow = NodeProfile::custom("straggler", 0.25);
    let profiles: Vec<NodeProfile> = (0..N)
        .map(|i| if i == slow_node { slow } else { fast })
        .collect();
    let mut spec = ClusterSpec::tpc(N)
        .sim()
        .with_profiles(profiles)
        .unwrap();
    spec.jitter = Duration::ZERO;
    let cluster = Cluster::start(spec);
    let object = ObjectId(4200);
    let placement = ReplicaPlacement::new(object, K, (0..N).collect()).unwrap();
    ingest_object(&cluster, &placement, BLOCK).unwrap();
    let code = RapidRaidCode::<Gf256>::with_seed(N, K, 7).unwrap();
    let backend: BackendHandle = Arc::new(NativeBackend::new());
    let exec = PlanExecutor::new(&cluster, backend);
    let job = PipelineJob::from_code(&code, &placement, BUF, BLOCK).unwrap();
    exec.run(&job.plan().unwrap()).unwrap()
}

#[test]
fn slowing_any_chain_node_strictly_increases_makespan() {
    let baseline = with_timeout(120, || chain_makespan(usize::MAX));
    // head, middle and tail stragglers all delay the chain
    for slow in [0usize, N / 2, N - 1] {
        let slowed = with_timeout(120, move || chain_makespan(slow));
        assert!(
            slowed > baseline,
            "straggler at {slow} did not stretch the chain: {slowed:?} vs {baseline:?}"
        );
    }
}
