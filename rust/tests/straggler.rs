//! Acceptance for the adaptive control plane on the `straggler-sim`
//! preset: on a deliberately lopsided pool (ec2-mix compute, two NICs
//! clamped 10x, one thinclient CPU — all inside the identity placement)
//! the closed loop must beat **every** static pipeline shape for both
//! paper code sizes, and the whole comparison must be a pure function of
//! `(block_bytes, seed)` — run it twice, get tick-identical rows.

use std::sync::Arc;
use std::time::Duration;

use rapidraid::backend::{BackendHandle, NativeBackend};
use rapidraid::bench_scenarios::{straggler_sim, StragglerSimRow};
use rapidraid::cluster::RuntimeKind;
use rapidraid::util::with_timeout;

const BLOCK: usize = 32 * 1024;
const SEED: u64 = 5;

fn run(runtime: RuntimeKind) -> Vec<StragglerSimRow> {
    let backend: BackendHandle = Arc::new(NativeBackend::new());
    let (rows, _report) =
        straggler_sim(&backend, BLOCK, SEED, runtime, &mut Vec::<u8>::new()).unwrap();
    rows
}

#[test]
fn adaptive_beats_every_static_shape_for_both_code_sizes() {
    let rows = with_timeout(240, || run(RuntimeKind::Auto));
    // 2 code sizes × (chain + tree:2 + hybrid:4:2 + adaptive)
    assert_eq!(rows.len(), 8);
    for (n, k) in [(11usize, 8usize), (22, 16)] {
        let adaptive = rows
            .iter()
            .find(|r| r.n == n && r.adaptive)
            .expect("adaptive cell")
            .makespan;
        assert!(adaptive > Duration::ZERO);
        let statics: Vec<&StragglerSimRow> =
            rows.iter().filter(|r| r.n == n && !r.adaptive).collect();
        assert_eq!(statics.len(), 3, "chain, tree:2, hybrid:4:2");
        for r in statics {
            assert!(
                adaptive < r.makespan,
                "(n={n},k={k}) adaptive {adaptive:?} did not beat static {} at {:?}",
                r.cell,
                r.makespan
            );
        }
    }
}

#[test]
fn straggler_sim_rows_are_deterministic_per_seed() {
    let (a, b) = with_timeout(240, || (run(RuntimeKind::Auto), run(RuntimeKind::Auto)));
    assert_eq!(a, b, "straggler-sim rows diverged between identical runs");
}

#[test]
fn straggler_sim_rows_agree_across_runtimes() {
    // The adaptive loop reads load snapshots at plan boundaries; those
    // boundaries — and hence every ranking, shape choice and makespan —
    // must be runtime-invariant like the rest of the virtual timeline.
    let (threaded, multiplexed) = with_timeout(360, || {
        (run(RuntimeKind::Threaded), run(RuntimeKind::Multiplexed))
    });
    assert_eq!(
        threaded, multiplexed,
        "straggler-sim rows diverged across runtimes"
    );
}
