//! The multiplexed runtime's two contracts:
//!
//! 1. **Parity** — same seed ⇒ byte-identical coded blocks, tick-identical
//!    virtual durations/spans AND a byte-identical event trace, whether the
//!    dataplane runs thread-per-node (`RuntimeKind::Threaded`) or
//!    cooperatively scheduled on one driver (`RuntimeKind::Multiplexed`).
//!    The runtime is an execution strategy, never an observable.
//! 2. **Scale** — a cluster far past thread-per-node size (≥ 2,000 nodes)
//!    lives through at least one virtual day of archival in wall-clock
//!    seconds.

use std::sync::Arc;
use std::time::Duration;

use rapidraid::backend::{BackendHandle, NativeBackend};
use rapidraid::bench_scenarios::{scale_sim, ScaleSimConfig};
use rapidraid::clock::{Clock, RealClock, SimClock};
use rapidraid::cluster::{Cluster, ClusterSpec, RuntimeKind};
use rapidraid::codes::rapidraid::RapidRaidCode;
use rapidraid::codes::TopologyCode;
use rapidraid::coordinator::batch::{pipeline_jobs, run_batch};
use rapidraid::coordinator::{
    ingest_object, survey_coded, PipelineJob, PlanExecutor, Topology,
};
use rapidraid::gf::Gf256;
use rapidraid::metrics::Recorder;
use rapidraid::repair::{PipelinedRepairJob, RepairJob};
use rapidraid::storage::{BlockKey, ObjectId, ReplicaPlacement};
use rapidraid::util::with_timeout;

const N: usize = 16;
const K: usize = 11;
const BLOCK: usize = 64 * 1024;
const BUF: usize = 16 * 1024;

struct RunOutcome {
    /// Every coded block byte, in chain order (position N-1 repaired).
    coded: Vec<Vec<u8>>,
    /// End-to-end virtual durations: [archival, repair].
    durations: Vec<Duration>,
    /// Per-stage span series: (name, sorted samples).
    spans: Vec<(String, Vec<Duration>)>,
    /// Canonical JSONL of every dataplane event this run's clock stamped.
    trace: String,
}

/// One archival + tail-crash + pipelined repair on a fresh SimClock
/// cluster pinned to `kind`, with a per-clock trace sink recording every
/// event (per-clock install: parallel tests can't pollute each other).
fn run_once(kind: RuntimeKind, topology: Topology, code_seed: u64) -> RunOutcome {
    let clock = SimClock::handle();
    let sink = rapidraid::trace::JsonlSink::shared();
    let guard = rapidraid::trace::install(&clock, sink.clone());
    let cluster = Cluster::start(
        ClusterSpec::tpc(N + 1)
            .with_clock(clock.clone())
            .with_runtime(kind),
    );
    assert_eq!(cluster.runtime_kind(), kind);
    let object = ObjectId(77_000 + code_seed);
    let placement = ReplicaPlacement::new(object, K, (0..N).collect()).unwrap();
    ingest_object(&cluster, &placement, BLOCK).unwrap();
    let code = RapidRaidCode::<Gf256>::with_seed(N, K, code_seed).unwrap();
    let tcode = TopologyCode::new(code.clone(), topology.shape(N).unwrap()).unwrap();
    let backend: BackendHandle = Arc::new(NativeBackend::new());

    let rec = Recorder::new();
    let exec = PlanExecutor::new(&cluster, backend.clone()).with_spans(&rec, "rr/");
    let job =
        PipelineJob::from_code_with_topology(&code, &placement, topology, BUF, BLOCK).unwrap();
    let t_archive = exec.run(&job.plan().unwrap()).unwrap();

    let lost = N - 1;
    cluster.fail_node(lost);
    let (avail, bb) = survey_coded(&cluster, &placement.chain, object);
    let rjob =
        RepairJob::from_code(&tcode, object, &placement.chain, lost, N, &avail, BUF, bb).unwrap();
    let t_repair = exec
        .run(&PipelinedRepairJob::with_topology(rjob, topology).plan().unwrap())
        .unwrap();

    let mut coded = Vec::with_capacity(N);
    for pos in 0..N {
        let holder = if pos == lost { N } else { placement.chain[pos] };
        let block = cluster
            .node(holder)
            .peek(BlockKey::coded(object, pos))
            .unwrap()
            .unwrap();
        coded.push((*block).clone());
    }
    let spans = rec
        .candles()
        .into_iter()
        .map(|c| (c.name.clone(), c.samples))
        .collect();
    // shut the cluster down before reading the sink so late drop-path
    // events (if any) are in both runtimes' traces alike
    drop(exec);
    drop(cluster);
    drop(guard);
    RunOutcome {
        coded,
        durations: vec![t_archive, t_repair],
        spans,
        trace: sink.to_jsonl(),
    }
}

fn assert_parity(topology: Topology, code_seed: u64) {
    let threaded = run_once(RuntimeKind::Threaded, topology, code_seed);
    let multiplexed = run_once(RuntimeKind::Multiplexed, topology, code_seed);
    let tag = format!("{topology} / seed {code_seed}");
    assert_eq!(
        threaded.coded, multiplexed.coded,
        "{tag}: coded blocks diverged across runtimes"
    );
    assert_eq!(
        threaded.durations, multiplexed.durations,
        "{tag}: virtual end-to-end times diverged across runtimes"
    );
    assert_eq!(
        threaded.spans, multiplexed.spans,
        "{tag}: per-stage virtual spans diverged across runtimes"
    );
    assert_eq!(
        threaded.trace, multiplexed.trace,
        "{tag}: event traces diverged across runtimes"
    );
    // sanity: real measurements and a real trace, not trivial equalities
    assert!(threaded.durations.iter().all(|d| *d > Duration::ZERO));
    assert!(!threaded.trace.is_empty(), "{tag}: empty trace");
}

#[test]
fn chain_parity_across_runtimes_seed_5() {
    with_timeout(240, || assert_parity(Topology::Chain, 5));
}

#[test]
fn chain_parity_across_runtimes_seed_12() {
    with_timeout(240, || assert_parity(Topology::Chain, 12));
}

#[test]
fn tree_parity_across_runtimes_seed_5() {
    with_timeout(240, || assert_parity(Topology::Tree { fanout: 2 }, 5));
}

#[test]
fn tree_parity_across_runtimes_seed_12() {
    with_timeout(240, || assert_parity(Topology::Tree { fanout: 2 }, 12));
}

#[test]
fn concurrent_batch_ticks_match_across_runtimes() {
    // run_many's dispatch threads + the engine's collection phase must not
    // observe the runtime either: a 4-object concurrent batch lands on the
    // same virtual times under both.
    let batch = |kind: RuntimeKind| -> Vec<Duration> {
        let cluster = Cluster::start(
            ClusterSpec::tpc(24)
                .with_clock(SimClock::handle())
                .with_runtime(kind),
        );
        let code = RapidRaidCode::<Gf256>::with_seed(N, K, 5).unwrap();
        let backend: BackendHandle = Arc::new(NativeBackend::new());
        let mut placements = Vec::new();
        for i in 0..4usize {
            let object = ObjectId(88_000 + i as u64);
            let chain: Vec<usize> = (0..N).map(|j| (i * 5 + j) % 24).collect();
            let placement = ReplicaPlacement::new(object, K, chain).unwrap();
            ingest_object(&cluster, &placement, 16 * 1024).unwrap();
            placements.push(placement);
        }
        let jobs =
            pipeline_jobs(&code, &placements, Topology::Chain, 4 * 1024, 16 * 1024).unwrap();
        run_batch(&cluster, &backend, &jobs).unwrap()
    };
    let (threaded, multiplexed) = with_timeout(240, || {
        (batch(RuntimeKind::Threaded), batch(RuntimeKind::Multiplexed))
    });
    assert_eq!(threaded, multiplexed, "batch virtual times diverged");
    assert!(threaded.iter().all(|d| *d > Duration::ZERO));
}

#[test]
fn adaptive_batch_ticks_match_across_runtimes() {
    // The closed control loop reads NIC/CPU state at plan boundaries; those
    // reads — and hence every ranking, shape choice, placement and virtual
    // makespan — must be runtime-invariant like every other observable.
    use rapidraid::cluster::CongestionSpec;
    use rapidraid::coordinator::{run_batch_adaptive, LoadAwarePolicy};
    let run = |kind: RuntimeKind| -> Vec<(Vec<usize>, String, Duration)> {
        let cluster = Cluster::start(
            ClusterSpec::tpc(12)
                .with_clock(SimClock::handle())
                .with_runtime(kind),
        );
        cluster.congest(
            1,
            &CongestionSpec {
                bytes_per_sec: 12.5e6,
                extra_latency: Duration::ZERO,
                jitter: Duration::ZERO,
            },
        );
        let code = RapidRaidCode::<Gf256>::with_seed(8, 4, 7).unwrap();
        let backend: BackendHandle = Arc::new(NativeBackend::new());
        let objects = [ObjectId(89_000), ObjectId(89_001), ObjectId(89_002)];
        run_batch_adaptive(
            &cluster,
            &backend,
            &LoadAwarePolicy::adaptive(),
            &code,
            &objects,
            Topology::Chain,
            4 * 1024,
            16 * 1024,
            1, // re-rank between every wave
        )
        .unwrap()
        .iter()
        .map(|r| (r.placement.chain.clone(), r.topology.to_string(), r.makespan))
        .collect()
    };
    let (threaded, multiplexed) = with_timeout(240, || {
        (run(RuntimeKind::Threaded), run(RuntimeKind::Multiplexed))
    });
    assert_eq!(
        threaded, multiplexed,
        "adaptive batch placements/shapes/ticks diverged across runtimes"
    );
    assert!(threaded.iter().all(|(_, _, d)| *d > Duration::ZERO));
    assert!(
        threaded.iter().all(|(chain, _, _)| !chain.contains(&1)),
        "straggler placed: {threaded:?}"
    );
}

#[test]
fn scale_acceptance_2048_nodes_one_virtual_day_in_wall_seconds() {
    // The floors of the scale contract (≥ 2,000 nodes, ≥ 1 virtual day,
    // < 60 s wall) at a work level a debug test build handles comfortably;
    // `cargo bench --bench scale_sim` runs the full-throughput preset.
    let wall = RealClock::new();
    let cfg = ScaleSimConfig {
        objects_per_epoch: 2,
        block_bytes: 2 * 1024,
        buf_bytes: 1024,
        epoch_secs: 14_400, // 6 epochs over the virtual day
        ..ScaleSimConfig::paper_scale()
    };
    let backend: BackendHandle = Arc::new(NativeBackend::new());
    let (report, bench) = scale_sim(&cfg, &backend, &mut Vec::<u8>::new()).unwrap();
    assert!(report.nodes >= 2000, "scale floor: {} nodes", report.nodes);
    assert!(
        report.virtual_elapsed >= Duration::from_secs(86_400),
        "virtual-day floor: {:?}",
        report.virtual_elapsed
    );
    assert_eq!(report.verified, report.epochs as usize);
    assert_eq!(report.objects_archived, 12);
    assert_eq!(bench.get_param("runtime"), Some("Multiplexed"));
    let elapsed = wall.now();
    assert!(
        elapsed < Duration::from_secs(60),
        "wall budget blown: {elapsed:?}"
    );
}
