//! Shared fixture for the integration tests.

use std::sync::Arc;

use rapidraid::backend::{BackendHandle, NativeBackend};
use rapidraid::cluster::{Cluster, ClusterSpec};
use rapidraid::codes::rapidraid::RapidRaidCode;
use rapidraid::coordinator::{archive_pipeline, ingest_object, PipelineJob};
use rapidraid::gf::{GfElem, SliceOps};
use rapidraid::storage::{ObjectId, ReplicaPlacement};

/// Ingest + pipeline-archive an `(n, k)` seed-`seed` object on nodes 0..n
/// of a fresh `nodes`-node test cluster running at `bytes_per_sec`
/// (nodes beyond n are spares for repair newcomers).
#[allow(dead_code, clippy::too_many_arguments)] // each test binary uses a subset
pub fn archived<F: GfElem + SliceOps>(
    nodes: usize,
    n: usize,
    k: usize,
    seed: u64,
    object: ObjectId,
    block: usize,
    buf: usize,
    bytes_per_sec: f64,
) -> (Cluster, RapidRaidCode<F>, ReplicaPlacement, BackendHandle) {
    let mut spec = ClusterSpec::test(nodes);
    spec.bytes_per_sec = bytes_per_sec;
    let cluster = Cluster::start(spec);
    let placement = ReplicaPlacement::new(object, k, (0..n).collect()).unwrap();
    ingest_object(&cluster, &placement, block).unwrap();
    let code = RapidRaidCode::<F>::with_seed(n, k, seed).unwrap();
    let backend: BackendHandle = Arc::new(NativeBackend::new());
    let job = PipelineJob::from_code(&code, &placement, buf, block).unwrap();
    archive_pipeline(&cluster, &backend, &job).unwrap();
    (cluster, code, placement, backend)
}
