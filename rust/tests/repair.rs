//! Repair subsystem integration: both repair planners must regenerate a
//! lost coded block byte-identically through the shared PlanExecutor, and
//! repeated failure+repair cycles must preserve the code's full
//! decodability — plus the headline performance property, pipelined repair
//! beating star repair on a bandwidth-bound network.

use std::sync::Arc;

use rapidraid::backend::{BackendHandle, NativeBackend};
use rapidraid::cluster::{Cluster, ClusterSpec};
use rapidraid::codes::rapidraid::RapidRaidCode;
use rapidraid::codes::{Combinations, DecodeError};
use rapidraid::coordinator::{
    archive_pipeline, ingest_object, object_bytes, reconstruct, survey_coded, FifoPolicy,
    PipelineJob,
};
use rapidraid::gf::{Gf256, Gf65536, GfElem, SliceOps};
use rapidraid::repair::{
    run_pipelined_repair, run_star_repair, PipelinedRepairJob, RepairJob, RepairScheduler,
    RepairStrategy, RepairTrigger, StarRepairJob,
};
use rapidraid::storage::{BlockKey, ObjectId, ReplicaPlacement};
use rapidraid::util::prop::forall;
use rapidraid::util::with_timeout;

mod common;

fn native() -> BackendHandle {
    Arc::new(NativeBackend::new())
}

/// Ingest + pipeline-archive an (n, k) object on nodes 0..n of a
/// `nodes`-node test cluster (shared fixture, full-speed NICs).
fn archived<F: GfElem + SliceOps>(
    nodes: usize,
    n: usize,
    k: usize,
    seed: u64,
    object: ObjectId,
    block: usize,
) -> (Cluster, RapidRaidCode<F>, ReplicaPlacement, BackendHandle) {
    common::archived::<F>(nodes, n, k, seed, object, block, 1024, 1e9)
}

/// Crash the holder of `c_lost`, then repair it onto the spare node with
/// BOTH planners; each result must equal the pre-crash block exactly.
fn check_repair_identical<F: GfElem + SliceOps>(
    n: usize,
    k: usize,
    seed: u64,
    lost: usize,
    object: ObjectId,
    block: usize,
) {
    let (cluster, code, placement, backend) = archived::<F>(n + 1, n, k, seed, object, block);
    let newcomer = n; // the spare node
    let key = BlockKey::coded(object, lost);
    let original = (*cluster.node(lost).peek(key).unwrap().unwrap()).clone();
    cluster.fail_node(lost);

    let (avail, block_bytes) = survey_coded(&cluster, &placement.chain, object);
    assert_eq!(block_bytes, block);
    assert!(!avail.contains(&lost));
    let job = RepairJob::from_code(
        &code,
        object,
        &placement.chain,
        lost,
        newcomer,
        &avail,
        512,
        block_bytes,
    )
    .unwrap();

    run_star_repair(&cluster, &backend, &StarRepairJob::new(job.clone())).unwrap();
    let star = (*cluster.node(newcomer).peek(key).unwrap().unwrap()).clone();
    assert_eq!(star, original, "star repair differs (n={n},k={k},lost={lost})");

    cluster.node(newcomer).delete(key).unwrap();
    run_pipelined_repair(&cluster, &backend, &PipelinedRepairJob::new(job)).unwrap();
    let pipe = (*cluster.node(newcomer).peek(key).unwrap().unwrap()).clone();
    assert_eq!(pipe, original, "pipelined repair differs (n={n},k={k},lost={lost})");
}

#[test]
fn prop_repairs_byte_identical_gf8() {
    // Known-good GF(2^8) draws (accidental-dependency-free enough that any
    // n−1 survivors stay decodable); the property varies the lost position
    // and the object contents.
    const COMBOS: [(usize, usize, u64); 3] = [(8, 4, 7), (6, 4, 3), (16, 11, 7)];
    with_timeout(180, || {
        forall(8, 41, |rng| {
            let (n, k, seed) = COMBOS[rng.below(COMBOS.len() as u64) as usize];
            let lost = rng.below(n as u64) as usize;
            let object = ObjectId(500 + rng.below(1 << 20));
            check_repair_identical::<Gf256>(n, k, seed, lost, object, 4 * 1024);
        });
    });
}

#[test]
fn prop_repairs_byte_identical_gf16() {
    const COMBOS: [(usize, usize, u64); 3] = [(8, 4, 12), (6, 4, 5), (16, 11, 12)];
    with_timeout(180, || {
        forall(8, 43, |rng| {
            let (n, k, seed) = COMBOS[rng.below(COMBOS.len() as u64) as usize];
            let lost = rng.below(n as u64) as usize;
            let object = ObjectId(600 + rng.below(1 << 20));
            check_repair_identical::<Gf65536>(n, k, seed, lost, object, 4 * 1024);
        });
    });
}

#[test]
fn n_minus_k_failures_with_repairs_keep_every_independent_subset_decodable() {
    with_timeout(120, || {
        // (8,4) over GF(2^16), seed 12: exactly one dependent subset (the
        // natural {0,1,4,5}), so after n−k = 4 crash+repair rounds the full
        // census must still read 69 decodable subsets of 70 — repair is
        // byte-exact, so the generator semantics never drift.
        let object = ObjectId(800);
        let block = 2048;
        let (cluster, code, placement, backend) =
            archived::<Gf65536>(12, 8, 4, 12, object, block);
        let blocks: Vec<Vec<u8>> = (0..4).map(|i| object_bytes(object, i, block)).collect();
        let expect: Vec<Vec<Gf65536>> = blocks.iter().map(|b| gf16(b)).collect();

        let mut placements = [placement];
        let sched = RepairScheduler::new(RepairStrategy::Pipelined, RepairTrigger::Eager)
            .with_max_concurrent(2);
        for (round, pos) in [0usize, 2, 4, 6].into_iter().enumerate() {
            cluster.fail_node(placements[0].chain[pos]);
            // degraded read keeps working while the block is missing
            let rec =
                reconstruct(&cluster, &code, &placements[0].chain, object, &backend).unwrap();
            assert_eq!(rec, blocks, "degraded read wrong in round {round}");
            let report = sched
                .repair(&cluster, &code, &mut placements, &backend, &FifoPolicy, 512)
                .unwrap();
            assert_eq!(report.actions.len(), 1, "round {round}");
        }

        let chain = &placements[0].chain;
        let mut decoded = 0;
        for sub in Combinations::new(8, 4) {
            let have: Vec<(usize, Vec<Gf65536>)> = sub
                .iter()
                .map(|&pos| {
                    let b = cluster
                        .node(chain[pos])
                        .peek(BlockKey::coded(object, pos))
                        .unwrap()
                        .unwrap_or_else(|| panic!("block {pos} missing post-repair"));
                    (pos, gf16(&b))
                })
                .collect();
            match code.decode(&have) {
                Ok(rec) => {
                    decoded += 1;
                    assert_eq!(rec, expect, "subset {sub:?}");
                }
                Err(DecodeError::DependentSubset { .. }) => {
                    assert_eq!(sub, vec![0, 1, 4, 5], "unexpected dependency");
                }
                Err(e) => panic!("unexpected decode error {e:?} for {sub:?}"),
            }
        }
        assert_eq!(decoded, 69);
    });
}

#[test]
fn pipelined_repair_faster_than_star_on_slow_network() {
    with_timeout(180, || {
        // 25 MB/s keeps the comparison network-bound on a 1-CPU host (same
        // caveat as the decode-side speedup test): star repair serializes
        // k = 11 block downloads through the newcomer's NIC (~k·τ_block),
        // the pipelined chain overlaps them (~τ_block).
        let object = ObjectId(900);
        let block = 1 << 20;
        let mut spec = ClusterSpec::test(17);
        spec.bytes_per_sec = 25e6;
        let cluster = Cluster::start(spec);
        let placement = ReplicaPlacement::new(object, 11, (0..16).collect()).unwrap();
        ingest_object(&cluster, &placement, block).unwrap();
        let code = RapidRaidCode::<Gf65536>::with_seed(16, 11, 12).unwrap();
        let backend = native();
        let job = PipelineJob::from_code(&code, &placement, 65536, block).unwrap();
        archive_pipeline(&cluster, &backend, &job).unwrap();

        let lost = 4usize;
        let key = BlockKey::coded(object, lost);
        let original = (*cluster.node(lost).peek(key).unwrap().unwrap()).clone();
        cluster.fail_node(lost);
        let (avail, bb) = survey_coded(&cluster, &placement.chain, object);
        let rjob = RepairJob::from_code(
            &code,
            object,
            &placement.chain,
            lost,
            16,
            &avail,
            65536,
            bb,
        )
        .unwrap();

        let t_star =
            run_star_repair(&cluster, &backend, &StarRepairJob::new(rjob.clone())).unwrap();
        assert_eq!(*cluster.node(16).peek(key).unwrap().unwrap(), original);
        cluster.node(16).delete(key).unwrap();
        let t_pipe =
            run_pipelined_repair(&cluster, &backend, &PipelinedRepairJob::new(rjob)).unwrap();
        assert_eq!(*cluster.node(16).peek(key).unwrap().unwrap(), original);
        assert!(
            t_pipe < t_star,
            "pipelined repair {t_pipe:?} not faster than star {t_star:?}"
        );
    });
}

/// Reinterpret a little-endian byte block as GF(2^16) symbols.
fn gf16(b: &[u8]) -> Vec<Gf65536> {
    b.chunks_exact(2)
        .map(|p| Gf65536(u16::from_le_bytes([p[0], p[1]])))
        .collect()
}
