//! Integration: the PJRT runtime executes the real AOT artifacts and agrees
//! bit-for-bit with the native backend (which itself is pinned to the
//! bit-level GF oracle).  Requires `make artifacts` (skips with a clear
//! message otherwise).

use std::path::Path;
use std::sync::Arc;

use rapidraid::backend::{conformance_entry, EncodeBackend, NativeBackend, PjrtBackend, Width};
use rapidraid::util::SplitMix64;

fn artifacts_dir() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.txt").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: artifacts/manifest.txt missing — run `make artifacts`");
        None
    }
}

#[test]
fn pjrt_conformance_full_buffer() {
    let Some(dir) = artifacts_dir() else { return };
    let be = PjrtBackend::load(dir).expect("load artifacts");
    // exactly the AOT buffer size — no padding path
    conformance_entry(&be, 65536);
}

#[test]
fn pjrt_conformance_padded_buffer() {
    let Some(dir) = artifacts_dir() else { return };
    let be = PjrtBackend::load(dir).expect("load artifacts");
    // short buffers exercise zero-padding + truncation
    conformance_entry(&be, 4096);
}

#[test]
fn pjrt_matches_native_on_random_streams() {
    let Some(dir) = artifacts_dir() else { return };
    let pjrt = PjrtBackend::load(dir).unwrap();
    let native = NativeBackend::new();
    let mut rng = SplitMix64::new(42);
    for w in [Width::W8, Width::W16] {
        let cmask = match w {
            Width::W8 => 0xFF,
            Width::W16 => 0xFFFF,
        };
        for len in [65536usize, 8192, 2048] {
            let mut x = vec![0u8; len];
            rng.fill_bytes(&mut x);
            let mut l0 = vec![0u8; len];
            rng.fill_bytes(&mut l0);
            let psi = vec![(rng.next_u64() & cmask) as u32];
            let xi = vec![(rng.next_u64() & cmask) as u32];
            let a = pjrt.pipeline_step(w, &x, &[&l0], &psi, &xi).unwrap();
            let b = native.pipeline_step(w, &x, &[&l0], &psi, &xi).unwrap();
            assert_eq!(a, b, "w={w:?} len={len}");
        }
    }
}

#[test]
fn pjrt_gemm_shape_padding_16_11_and_4_4() {
    let Some(dir) = artifacts_dir() else { return };
    let pjrt = PjrtBackend::load(dir).unwrap();
    let native = NativeBackend::new();
    let mut rng = SplitMix64::new(7);
    // (m=5,k=11) exact artifact; (m=4,k=4) embedded artifact; (m=2,k=3) padded
    for (m, k) in [(5usize, 11usize), (4, 4), (2, 3), (11, 11)] {
        let mat: Vec<Vec<u32>> = (0..m)
            .map(|_| (0..k).map(|_| (rng.next_u64() & 0xFF) as u32).collect())
            .collect();
        let data: Vec<Vec<u8>> = (0..k)
            .map(|_| {
                let mut d = vec![0u8; 16384];
                rng.fill_bytes(&mut d);
                d
            })
            .collect();
        let refs: Vec<&[u8]> = data.iter().map(|d| d.as_slice()).collect();
        let a = pjrt.gemm(Width::W8, &mat, &refs).unwrap();
        let b = native.gemm(Width::W8, &mat, &refs).unwrap();
        assert_eq!(a, b, "(m={m},k={k})");
    }
}

#[test]
fn pjrt_rejects_oversize_and_unknown_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let pjrt = PjrtBackend::load(dir).unwrap();
    let big = vec![0u8; 65537]; // one byte over the artifact buffer
    let l = vec![0u8; 65537];
    assert!(pjrt
        .pipeline_step(Width::W8, &big, &[&l], &[1], &[1])
        .is_err());
    // r=3 step has no artifact
    let x = vec![0u8; 1024];
    let ls = [&x[..], &x[..], &x[..]];
    assert!(pjrt
        .pipeline_step(Width::W8, &x, &ls, &[1, 2, 3], &[1, 2, 3])
        .is_err());
    // gemm wider than any artifact
    let mat: Vec<Vec<u32>> = (0..12).map(|_| vec![1u32; 12]).collect();
    let data: Vec<Vec<u8>> = (0..12).map(|_| vec![0u8; 64]).collect();
    let refs: Vec<&[u8]> = data.iter().map(|d| d.as_slice()).collect();
    assert!(pjrt.gemm(Width::W8, &mat, &refs).is_err());
}

#[test]
fn compile_cache_reuses_executables() {
    let Some(dir) = artifacts_dir() else { return };
    let pjrt = PjrtBackend::load(dir).unwrap();
    let x = vec![1u8; 1024];
    let l = vec![2u8; 1024];
    pjrt.pipeline_step(Width::W8, &x, &[&l], &[3], &[5]).unwrap();
    let n1 = pjrt.engine().compiled_count();
    pjrt.pipeline_step(Width::W8, &x, &[&l], &[7], &[9]).unwrap();
    assert_eq!(pjrt.engine().compiled_count(), n1, "recompiled unnecessarily");
}

#[test]
fn end_to_end_pipeline_on_pjrt_backend() {
    // Full coordinator archival with the PJRT backend: L3→L2→L1 composition.
    let Some(dir) = artifacts_dir() else { return };
    use rapidraid::cluster::{Cluster, ClusterSpec};
    use rapidraid::codes::rapidraid::RapidRaidCode;
    use rapidraid::coordinator::{archive_pipeline, ingest_object, reconstruct, PipelineJob};
    use rapidraid::gf::Gf256;
    use rapidraid::storage::{ObjectId, ReplicaPlacement};

    let cluster = Cluster::start(ClusterSpec::test(8));
    let object = ObjectId(4242);
    let placement = ReplicaPlacement::new(object, 4, (0..8).collect()).unwrap();
    let blocks = ingest_object(&cluster, &placement, 128 * 1024).unwrap();
    let code = RapidRaidCode::<Gf256>::with_seed(8, 4, 7).unwrap();
    let backend: Arc<dyn EncodeBackend> = Arc::new(PjrtBackend::load(dir).unwrap());
    let job = PipelineJob::from_code(&code, &placement, 65536, 128 * 1024).unwrap();
    archive_pipeline(&cluster, &backend, &job).unwrap();
    let rec = reconstruct(&cluster, &code, &placement.chain, object, &backend).unwrap();
    assert_eq!(rec, blocks);
}
