//! Determinism under the SimClock: same seed ⇒ byte-identical blocks AND
//! identical virtual-time metrics across runs.
//!
//! This is the watchdog for wall-clock leakage: any residual
//! `Instant::now()` / `thread::sleep` in the dataplane, or any place where
//! virtual time depends on OS scheduling, shows up as a duration mismatch
//! here. The scenario keeps every NIC direction single-stream (one
//! pipelined archival chain, then one pipelined repair chain), which is the
//! regime where the discrete-event timeline is provably a function of the
//! inputs alone.

use std::sync::Arc;
use std::time::Duration;

use rapidraid::backend::{BackendHandle, NativeBackend};
use rapidraid::clock::SimClock;
use rapidraid::cluster::{Cluster, ClusterSpec};
use rapidraid::codes::rapidraid::RapidRaidCode;
use rapidraid::codes::TopologyCode;
use rapidraid::coordinator::{
    ingest_object, survey_coded, PipelineJob, PlanExecutor, Topology,
};
use rapidraid::gf::Gf256;
use rapidraid::metrics::Recorder;
use rapidraid::repair::{PipelinedRepairJob, RepairJob};
use rapidraid::storage::{BlockKey, ObjectId, ReplicaPlacement};
use rapidraid::util::with_timeout;

const N: usize = 16;
const K: usize = 11;
const BLOCK: usize = 128 * 1024;
const BUF: usize = 16 * 1024;

struct RunOutcome {
    /// Every coded block byte, in chain order (position N-1 is the
    /// repaired one).
    coded: Vec<Vec<u8>>,
    /// End-to-end virtual durations: [archival, repair].
    durations: Vec<Duration>,
    /// Per-stage span series: (name, sorted samples).
    spans: Vec<(String, Vec<Duration>)>,
}

fn run_once(topology: Topology) -> RunOutcome {
    // tpc preset: non-zero latency AND jitter, so the seeded-jitter path is
    // exercised by the determinism check too.
    let cluster = Cluster::start(ClusterSpec::tpc(N + 1).with_clock(SimClock::handle()));
    let object = ObjectId(900);
    let placement = ReplicaPlacement::new(object, K, (0..N).collect()).unwrap();
    ingest_object(&cluster, &placement, BLOCK).unwrap();
    let code = RapidRaidCode::<Gf256>::with_seed(N, K, 5).unwrap();
    // repair coefficients must come from the shape-composed generator
    let tcode = TopologyCode::new(code.clone(), topology.shape(N).unwrap()).unwrap();
    let backend: BackendHandle = Arc::new(NativeBackend::new());

    let rec = Recorder::new();
    let exec = PlanExecutor::new(&cluster, backend.clone()).with_spans(&rec, "rr/");
    let job =
        PipelineJob::from_code_with_topology(&code, &placement, topology, BUF, BLOCK).unwrap();
    let t_archive = exec.run(&job.plan().unwrap()).unwrap();

    // crash the pipeline tail position, repair onto the spare node N
    let lost = N - 1;
    cluster.fail_node(lost);
    let (avail, bb) = survey_coded(&cluster, &placement.chain, object);
    let rjob = RepairJob::from_code(
        &tcode,
        object,
        &placement.chain,
        lost,
        N,
        &avail,
        BUF,
        bb,
    )
    .unwrap();
    let t_repair = exec
        .run(&PipelinedRepairJob::with_topology(rjob, topology).plan().unwrap())
        .unwrap();

    let mut coded = Vec::with_capacity(N);
    for pos in 0..N {
        let holder = if pos == lost { N } else { placement.chain[pos] };
        let block = cluster
            .node(holder)
            .peek(BlockKey::coded(object, pos))
            .unwrap()
            .unwrap();
        coded.push((*block).clone());
    }
    // Samples are sorted per series: completion *values* are deterministic,
    // the recorder's insertion order (collector scheduling) is not.
    let spans = rec
        .candles()
        .into_iter()
        .map(|c| (c.name.clone(), c.samples))
        .collect();
    RunOutcome {
        coded,
        durations: vec![t_archive, t_repair],
        spans,
    }
}

#[test]
fn same_seed_same_bytes_and_same_virtual_times() {
    let (a, b) = with_timeout(120, || {
        (run_once(Topology::Chain), run_once(Topology::Chain))
    });
    assert_eq!(a.coded, b.coded, "coded blocks diverged between runs");
    assert_eq!(
        a.durations, b.durations,
        "virtual end-to-end times diverged — wall-clock leakage?"
    );
    assert_eq!(a.spans, b.spans, "per-stage virtual spans diverged");
    // sanity: the virtual times are real measurements, not zeros
    assert!(a.durations.iter().all(|d| *d > Duration::ZERO));
    assert_eq!(a.coded.len(), N);
    assert!(a.spans.iter().any(|(name, _)| name == "rr/fold"));
}

#[test]
fn tree_run_same_seed_same_bytes_and_same_virtual_times() {
    // The fan-out path (one fold feeding two subtrees, tree-shaped repair
    // aggregation) must be exactly as deterministic as the chain.
    let topo = Topology::Tree { fanout: 2 };
    let (a, b) = with_timeout(120, || (run_once(topo), run_once(topo)));
    assert_eq!(a.coded, b.coded, "tree coded blocks diverged between runs");
    assert_eq!(
        a.durations, b.durations,
        "tree virtual times diverged — wall-clock leakage on the fan-out path?"
    );
    assert_eq!(a.spans, b.spans, "tree per-stage virtual spans diverged");
    assert!(a.durations.iter().all(|d| *d > Duration::ZERO));
}

#[test]
fn traced_run_is_tick_and_byte_identical_to_untraced() {
    // The observability layer's core contract: installing a trace sink
    // must not move a single virtual tick or flip a single coded byte.
    // Global install is fine here — sinks only observe, and the assertion
    // compares the *runs*, not the sink contents.
    let (base, traced) = with_timeout(240, || {
        let base = run_once(Topology::Chain);
        let sink = rapidraid::trace::JsonlSink::shared();
        let guard = rapidraid::trace::install_global(sink.clone());
        let traced = run_once(Topology::Chain);
        drop(guard);
        assert!(!sink.is_empty(), "traced run emitted no events");
        (base, traced)
    });
    assert_eq!(base.coded, traced.coded, "tracing flipped coded bytes");
    assert_eq!(
        base.durations, traced.durations,
        "tracing shifted virtual end-to-end times"
    );
    assert_eq!(base.spans, traced.spans, "tracing shifted per-stage spans");
}

#[test]
fn archival_virtual_time_matches_pipeline_model_shape() {
    // Not a strict equality (jitter is seeded but non-zero), but the
    // pipelined archival of an 11×128 KiB object over 1 Gbps must land in
    // the right ballpark: ≥ one block-time, well under k serialized
    // block-times. Deterministic, so the bounds can be tight-ish.
    let out = with_timeout(120, || run_once(Topology::Chain));
    let block_time = Duration::from_secs_f64(BLOCK as f64 / 125e6);
    assert!(
        out.durations[0] >= block_time,
        "{:?} < one block-time {:?}",
        out.durations[0],
        block_time
    );
    assert!(
        out.durations[0] < block_time * (K as u32),
        "pipelining lost: {:?} vs {:?} serialized",
        out.durations[0],
        block_time * (K as u32)
    );
}
