//! Determinism under the SimClock: same seed ⇒ byte-identical blocks AND
//! identical virtual-time metrics across runs.
//!
//! This is the watchdog for wall-clock leakage: any residual
//! `Instant::now()` / `thread::sleep` in the dataplane, or any place where
//! virtual time depends on OS scheduling, shows up as a duration mismatch
//! here. The scenario keeps every NIC direction single-stream (one
//! pipelined archival chain, then one pipelined repair chain), which is the
//! regime where the discrete-event timeline is provably a function of the
//! inputs alone.

use std::sync::Arc;
use std::time::Duration;

use rapidraid::backend::{BackendHandle, NativeBackend};
use rapidraid::clock::SimClock;
use rapidraid::cluster::{Cluster, ClusterSpec, CongestionSpec};
use rapidraid::codes::rapidraid::RapidRaidCode;
use rapidraid::codes::TopologyCode;
use rapidraid::coordinator::batch::place_and_build_pipeline_jobs;
use rapidraid::coordinator::{
    ingest_object, run_batch, run_batch_adaptive, survey_coded, LoadAwarePolicy, PipelineJob,
    PlanExecutor, Topology,
};
use rapidraid::gf::Gf256;
use rapidraid::metrics::Recorder;
use rapidraid::repair::{PipelinedRepairJob, RepairJob};
use rapidraid::storage::{BlockKey, ObjectId, ReplicaPlacement};
use rapidraid::util::with_timeout;

const N: usize = 16;
const K: usize = 11;
const BLOCK: usize = 128 * 1024;
const BUF: usize = 16 * 1024;

struct RunOutcome {
    /// Every coded block byte, in chain order (position N-1 is the
    /// repaired one).
    coded: Vec<Vec<u8>>,
    /// End-to-end virtual durations: [archival, repair].
    durations: Vec<Duration>,
    /// Per-stage span series: (name, sorted samples).
    spans: Vec<(String, Vec<Duration>)>,
}

fn run_once(topology: Topology) -> RunOutcome {
    // tpc preset: non-zero latency AND jitter, so the seeded-jitter path is
    // exercised by the determinism check too.
    let cluster = Cluster::start(ClusterSpec::tpc(N + 1).with_clock(SimClock::handle()));
    let object = ObjectId(900);
    let placement = ReplicaPlacement::new(object, K, (0..N).collect()).unwrap();
    ingest_object(&cluster, &placement, BLOCK).unwrap();
    let code = RapidRaidCode::<Gf256>::with_seed(N, K, 5).unwrap();
    // repair coefficients must come from the shape-composed generator
    let tcode = TopologyCode::new(code.clone(), topology.shape(N).unwrap()).unwrap();
    let backend: BackendHandle = Arc::new(NativeBackend::new());

    let rec = Recorder::new();
    let exec = PlanExecutor::new(&cluster, backend.clone()).with_spans(&rec, "rr/");
    let job =
        PipelineJob::from_code_with_topology(&code, &placement, topology, BUF, BLOCK).unwrap();
    let t_archive = exec.run(&job.plan().unwrap()).unwrap();

    // crash the pipeline tail position, repair onto the spare node N
    let lost = N - 1;
    cluster.fail_node(lost);
    let (avail, bb) = survey_coded(&cluster, &placement.chain, object);
    let rjob = RepairJob::from_code(
        &tcode,
        object,
        &placement.chain,
        lost,
        N,
        &avail,
        BUF,
        bb,
    )
    .unwrap();
    let t_repair = exec
        .run(&PipelinedRepairJob::with_topology(rjob, topology).plan().unwrap())
        .unwrap();

    let mut coded = Vec::with_capacity(N);
    for pos in 0..N {
        let holder = if pos == lost { N } else { placement.chain[pos] };
        let block = cluster
            .node(holder)
            .peek(BlockKey::coded(object, pos))
            .unwrap()
            .unwrap();
        coded.push((*block).clone());
    }
    // Samples are sorted per series: completion *values* are deterministic,
    // the recorder's insertion order (collector scheduling) is not.
    let spans = rec
        .candles()
        .into_iter()
        .map(|c| (c.name.clone(), c.samples))
        .collect();
    RunOutcome {
        coded,
        durations: vec![t_archive, t_repair],
        spans,
    }
}

#[test]
fn same_seed_same_bytes_and_same_virtual_times() {
    let (a, b) = with_timeout(120, || {
        (run_once(Topology::Chain), run_once(Topology::Chain))
    });
    assert_eq!(a.coded, b.coded, "coded blocks diverged between runs");
    assert_eq!(
        a.durations, b.durations,
        "virtual end-to-end times diverged — wall-clock leakage?"
    );
    assert_eq!(a.spans, b.spans, "per-stage virtual spans diverged");
    // sanity: the virtual times are real measurements, not zeros
    assert!(a.durations.iter().all(|d| *d > Duration::ZERO));
    assert_eq!(a.coded.len(), N);
    assert!(a.spans.iter().any(|(name, _)| name == "rr/fold"));
}

#[test]
fn tree_run_same_seed_same_bytes_and_same_virtual_times() {
    // The fan-out path (one fold feeding two subtrees, tree-shaped repair
    // aggregation) must be exactly as deterministic as the chain.
    let topo = Topology::Tree { fanout: 2 };
    let (a, b) = with_timeout(120, || (run_once(topo), run_once(topo)));
    assert_eq!(a.coded, b.coded, "tree coded blocks diverged between runs");
    assert_eq!(
        a.durations, b.durations,
        "tree virtual times diverged — wall-clock leakage on the fan-out path?"
    );
    assert_eq!(a.spans, b.spans, "tree per-stage virtual spans diverged");
    assert!(a.durations.iter().all(|d| *d > Duration::ZERO));
}

#[test]
fn traced_run_is_tick_and_byte_identical_to_untraced() {
    // The observability layer's core contract: installing a trace sink
    // must not move a single virtual tick or flip a single coded byte.
    // Global install is fine here — sinks only observe, and the assertion
    // compares the *runs*, not the sink contents.
    let (base, traced) = with_timeout(240, || {
        let base = run_once(Topology::Chain);
        let sink = rapidraid::trace::JsonlSink::shared();
        let guard = rapidraid::trace::install_global(sink.clone());
        let traced = run_once(Topology::Chain);
        drop(guard);
        assert!(!sink.is_empty(), "traced run emitted no events");
        (base, traced)
    });
    assert_eq!(base.coded, traced.coded, "tracing flipped coded bytes");
    assert_eq!(
        base.durations, traced.durations,
        "tracing shifted virtual end-to-end times"
    );
    assert_eq!(base.spans, traced.spans, "tracing shifted per-stage spans");
}

#[test]
fn adaptation_off_driver_is_bit_identical_to_static_batch() {
    // `Adaptation::Off` is a hard identity, not an approximation: the
    // adaptive batch driver run with an Off policy must produce the same
    // placements, the same virtual times and the same coded bytes as the
    // explicit place-then-run static path — no snapshots, no re-ranking,
    // not one tick moved.
    let backend: BackendHandle = Arc::new(NativeBackend::new());
    let code = RapidRaidCode::<Gf256>::with_seed(8, 4, 7).unwrap();
    let objects = [ObjectId(931), ObjectId(932)];
    let block = 32 * 1024;
    let coded_bytes = |cluster: &Cluster, chain: &[usize], object: ObjectId| -> Vec<Vec<u8>> {
        chain
            .iter()
            .enumerate()
            .map(|(pos, &node)| {
                (*cluster
                    .node(node)
                    .peek(BlockKey::coded(object, pos))
                    .unwrap()
                    .unwrap())
                .clone()
            })
            .collect()
    };

    let (static_meta, adaptive_meta) = with_timeout(240, || {
        // static path: place, then run, as PR 9 callers do
        let cluster = Cluster::start(ClusterSpec::tpc(12).with_clock(SimClock::handle()));
        let policy = LoadAwarePolicy::default(); // Adaptation::Off
        let placed = place_and_build_pipeline_jobs(
            &cluster,
            &policy,
            &code,
            &objects,
            Topology::Chain,
            BUF,
            block,
        )
        .unwrap();
        let jobs: Vec<_> = placed.iter().map(|(_, j)| j.clone()).collect();
        let times = run_batch(&cluster, &backend, &jobs).unwrap();
        let static_meta: Vec<(Vec<usize>, Duration, Vec<Vec<u8>>)> = placed
            .iter()
            .zip(&times)
            .map(|((p, _), &t)| (p.chain.clone(), t, coded_bytes(&cluster, &p.chain, p.object)))
            .collect();

        // Off-mode adaptive driver, one wave spanning the whole batch
        let cluster = Cluster::start(ClusterSpec::tpc(12).with_clock(SimClock::handle()));
        let runs = run_batch_adaptive(
            &cluster,
            &backend,
            &LoadAwarePolicy::default(),
            &code,
            &objects,
            Topology::Chain,
            BUF,
            block,
            objects.len(),
        )
        .unwrap();
        let adaptive_meta: Vec<(Vec<usize>, Duration, Vec<Vec<u8>>)> = runs
            .iter()
            .map(|r| {
                (
                    r.placement.chain.clone(),
                    r.makespan,
                    coded_bytes(&cluster, &r.placement.chain, r.placement.object),
                )
            })
            .collect();
        (static_meta, adaptive_meta)
    });
    assert_eq!(
        static_meta, adaptive_meta,
        "Off-mode adaptive driver diverged from the static path"
    );
}

#[test]
fn adaptive_run_same_seed_same_bytes_and_same_virtual_times() {
    // With the loop closed (snapshots, re-ranking, shape auto-tuning) the
    // run must still be a pure function of the seed: same congested
    // cluster, same objects, twice ⇒ identical placements, shapes,
    // makespans and coded bytes.
    let run = || -> (Vec<(Vec<usize>, String, Duration)>, Vec<Vec<u8>>) {
        let cluster = Cluster::start(ClusterSpec::tpc(12).with_clock(SimClock::handle()));
        cluster.congest(
            1,
            &CongestionSpec {
                bytes_per_sec: 12.5e6,
                extra_latency: Duration::ZERO,
                jitter: Duration::ZERO,
            },
        );
        let code = RapidRaidCode::<Gf256>::with_seed(8, 4, 7).unwrap();
        let backend: BackendHandle = Arc::new(NativeBackend::new());
        let objects = [ObjectId(941), ObjectId(942)];
        let runs = run_batch_adaptive(
            &cluster,
            &backend,
            &LoadAwarePolicy::adaptive(),
            &code,
            &objects,
            Topology::Chain,
            BUF,
            32 * 1024,
            1, // re-rank between the two waves
        )
        .unwrap();
        let mut meta = Vec::new();
        let mut coded = Vec::new();
        for r in &runs {
            meta.push((r.placement.chain.clone(), r.topology.to_string(), r.makespan));
            for (pos, &node) in r.placement.chain.iter().enumerate() {
                let block = cluster
                    .node(node)
                    .peek(BlockKey::coded(r.placement.object, pos))
                    .unwrap()
                    .unwrap();
                coded.push((*block).clone());
            }
        }
        (meta, coded)
    };
    let (a, b) = with_timeout(240, || (run(), run()));
    assert_eq!(a.0, b.0, "adaptive placements/shapes/times diverged");
    assert_eq!(a.1, b.1, "adaptive coded bytes diverged");
    // the congested node must not host any slot (spares exist)
    assert!(
        a.0.iter().all(|(chain, _, _)| !chain.contains(&1)),
        "straggler placed: {:?}",
        a.0
    );
}

#[test]
fn archival_virtual_time_matches_pipeline_model_shape() {
    // Not a strict equality (jitter is seeded but non-zero), but the
    // pipelined archival of an 11×128 KiB object over 1 Gbps must land in
    // the right ballpark: ≥ one block-time, well under k serialized
    // block-times. Deterministic, so the bounds can be tight-ish.
    let out = with_timeout(120, || run_once(Topology::Chain));
    let block_time = Duration::from_secs_f64(BLOCK as f64 / 125e6);
    assert!(
        out.durations[0] >= block_time,
        "{:?} < one block-time {:?}",
        out.durations[0],
        block_time
    );
    assert!(
        out.durations[0] < block_time * (K as u32),
        "pipelining lost: {:?} vs {:?} serialized",
        out.durations[0],
        block_time * (K as u32)
    );
}
