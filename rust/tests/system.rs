//! System-level integration tests: both archival strategies over the
//! simulated cluster, cross-checked against the pure library encoders, plus
//! cross-cutting invariants (byte conservation, congestion monotonicity,
//! batch completeness).

use std::sync::Arc;
use std::time::Duration;

use rapidraid::backend::{BackendHandle, NativeBackend, Width};
use rapidraid::bench_scenarios::{build_jobs, cec_parity_rows, rr8_code, Impl, K, N};
use rapidraid::cluster::{Cluster, ClusterSpec, CongestionSpec};
use rapidraid::codes::rapidraid::RapidRaidCode;
use rapidraid::codes::ClassicalCode;
use rapidraid::coordinator::batch::{rotated_chain, run_batch};
use rapidraid::coordinator::{
    archive_classical, archive_pipeline, ingest_object, reconstruct, ClassicalJob, PipelineJob,
};
use rapidraid::gf::{Gf256, GfElem};
use rapidraid::storage::{BlockKey, ObjectId, ReplicaPlacement};
use rapidraid::util::prop::forall;

fn native() -> BackendHandle {
    Arc::new(NativeBackend::new())
}

#[test]
fn classical_and_pipeline_archive_the_same_object_consistently() {
    // Same object archived with both strategies on two clusters; each coded
    // form must decode back to the identical source bytes.
    let block = 64 * 1024;
    let backend = native();

    // pipeline
    let cluster = Cluster::start(ClusterSpec::test(16));
    let object = ObjectId(1);
    let placement = ReplicaPlacement::new(object, K, (0..N).collect()).unwrap();
    let blocks = ingest_object(&cluster, &placement, block).unwrap();
    let code = rr8_code();
    let job = PipelineJob::from_code(&code, &placement, 65536, block).unwrap();
    archive_pipeline(&cluster, &backend, &job).unwrap();
    let via_pipeline = reconstruct(&cluster, &code, &placement.chain, object, &backend).unwrap();
    assert_eq!(via_pipeline, blocks);

    // classical on a fresh cluster
    let cluster2 = Cluster::start(ClusterSpec::test(16));
    let placement2 = ReplicaPlacement::new(object, K, (0..N).collect()).unwrap();
    let blocks2 = ingest_object(&cluster2, &placement2, block).unwrap();
    assert_eq!(blocks2, blocks, "deterministic ingest must agree");
    let cjob = ClassicalJob {
        object,
        width: Width::W8,
        parity_rows: cec_parity_rows(),
        source_nodes: (0..K).collect(),
        coding_node: K,
        parity_nodes: (K..N).collect(),
        buf_bytes: 65536,
        block_bytes: block,
    };
    archive_classical(&cluster2, &backend, &cjob).unwrap();
    // classical decode: systematic part is the source itself; check parity
    // against the library encoder.
    let cls = ClassicalCode::<Gf256>::new(N, K).unwrap();
    let obj_gf: Vec<Vec<Gf256>> = blocks
        .iter()
        .map(|b| b.iter().map(|&x| Gf256(x)).collect())
        .collect();
    let parity = cls.encode_parity(&obj_gf);
    for i in 0..(N - K) {
        let got = cluster2
            .node(K + i)
            .peek(BlockKey::coded(object, K + i))
            .unwrap()
            .unwrap();
        let expect: Vec<u8> = parity[i].iter().map(|g| g.0).collect();
        assert_eq!(*got, expect, "parity {i}");
    }
}

#[test]
fn pipelined_beats_classical_on_idle_network() {
    // The headline claim at (16,11) scale. 50 MB/s keeps the experiment
    // network-bound: on this 1-CPU host all 16 "distributed" stages share
    // one core, so at high bandwidth compute (which the paper's 16 real
    // nodes did in parallel) would cap the speedup — a testbed artifact,
    // not a property of the codes (see DESIGN.md §3).
    let mut spec = ClusterSpec::test(N);
    spec.bytes_per_sec = 50e6;
    let block = 1 << 20;
    let backend = native();

    let cluster = Cluster::start(spec.clone());
    let cjobs = build_jobs(&cluster, Impl::Cec, 1, block, 10).unwrap();
    let t_cls = run_batch(&cluster, &backend, &cjobs).unwrap()[0];

    let cluster = Cluster::start(spec);
    let pjobs = build_jobs(&cluster, Impl::Rr8, 1, block, 20).unwrap();
    let t_pipe = run_batch(&cluster, &backend, &pjobs).unwrap()[0];

    // paper: ~90% reduction. Accept anything better than 60% on this host.
    let reduction = 1.0 - t_pipe.as_secs_f64() / t_cls.as_secs_f64();
    assert!(
        reduction > 0.6,
        "expected >60% reduction, got {:.1}% (cls {t_cls:?}, pipe {t_pipe:?})",
        reduction * 100.0
    );
}

#[test]
fn batch_archival_completes_every_block_exactly_once() {
    let block = 32 * 1024;
    let backend = native();
    let cluster = Cluster::start(ClusterSpec::test(N));
    let jobs = build_jobs(&cluster, Impl::Rr8, 8, block, 300).unwrap();
    let times = run_batch(&cluster, &backend, &jobs).unwrap();
    assert_eq!(times.len(), 8);
    // every object: n coded blocks, each exactly on its chain node
    for i in 0..8u64 {
        let object = ObjectId(300 + i);
        let chain = rotated_chain(N, N, i as usize);
        for (pos, &node) in chain.iter().enumerate() {
            assert!(
                cluster
                    .node(node)
                    .peek(BlockKey::coded(object, pos))
                    .unwrap()
                    .is_some(),
                "{object} block {pos} missing on node {node}"
            );
        }
        // block count conservation: coded blocks on the cluster for this
        // object == n (no duplicates anywhere else)
        let mut count = 0;
        for node in cluster.nodes() {
            for key in node.store.keys() {
                if key.object == object
                    && matches!(key.kind, rapidraid::storage::BlockKind::Coded)
                {
                    count += 1;
                }
            }
        }
        assert_eq!(count, N, "{object} coded-block count");
    }
}

#[test]
fn congestion_slows_archival_monotonically() {
    // More congested nodes must never make coding meaningfully FASTER.
    let block = 256 * 1024;
    let backend = native();
    let mild = CongestionSpec {
        bytes_per_sec: 50e6,
        extra_latency: Duration::from_millis(5),
        jitter: Duration::ZERO,
    };
    let mut last = Duration::ZERO;
    for congested in [0usize, 4, 8] {
        let mut spec = ClusterSpec::test(N);
        spec.bytes_per_sec = 500e6;
        let cluster = Cluster::start(spec);
        for node in 0..congested {
            cluster.congest(node, &mild);
        }
        let jobs = build_jobs(&cluster, Impl::Rr8, 1, block, 500 + congested as u64).unwrap();
        let t = run_batch(&cluster, &backend, &jobs).unwrap()[0];
        assert!(
            t + Duration::from_millis(10) >= last,
            "congested={congested}: {t:?} faster than previous {last:?}"
        );
        last = t;
    }
}

#[test]
fn prop_pipeline_roundtrip_over_params_on_cluster() {
    // Property: for random (n, k) and block sizes, archive+decode over the
    // cluster is the identity.
    let backend = native();
    forall(6, 1234, |rng| {
        let k = 3 + rng.below(4) as usize; // 3..=6
        let extra = 1 + rng.below(k as u64) as usize;
        let n = (k + extra).min(2 * k);
        let block = 1024 * (1 + rng.below(8) as usize);
        let cluster = Cluster::start(ClusterSpec::test(n));
        let object = ObjectId(rng.next_u64());
        let placement = ReplicaPlacement::new(object, k, (0..n).collect()).unwrap();
        let blocks = ingest_object(&cluster, &placement, block).unwrap();
        let code = RapidRaidCode::<Gf256>::with_seed(n, k, rng.next_u64()).unwrap();
        let job = PipelineJob::from_code(&code, &placement, 2048, block).unwrap();
        archive_pipeline(&cluster, &backend, &job).unwrap();
        let rec = reconstruct(&cluster, &code, &placement.chain, object, &backend).unwrap();
        assert_eq!(rec, blocks, "(n={n},k={k},block={block})");
    });
}

#[test]
fn classical_respects_source_locality() {
    // If the coding node already holds a source block, that block must not
    // be transferred: with all sources local, coding time collapses to the
    // upload side only.
    let block = 512 * 1024;
    let backend = native();
    let mut spec = ClusterSpec::test(6);
    spec.bytes_per_sec = 50e6; // 10.5 ms per block side
    let cluster = Cluster::start(spec);
    let object = ObjectId(9);
    // put ALL k=3 source blocks on the coding node 0
    for j in 0..3 {
        let data = rapidraid::coordinator::object_bytes(object, j, block);
        cluster.node(0).put(BlockKey::source(object, j), data).unwrap();
    }
    let cls = ClassicalCode::<Gf256>::new(6, 3).unwrap();
    let parity = cls.parity_matrix();
    let job = ClassicalJob {
        object,
        width: Width::W8,
        parity_rows: (0..parity.rows())
            .map(|i| parity.row(i).iter().map(|c| c.to_u32()).collect())
            .collect(),
        source_nodes: vec![0, 0, 0],
        coding_node: 0,
        parity_nodes: vec![0, 1, 2],
        buf_bytes: 65536,
        block_bytes: block,
    };
    let dt = archive_classical(&cluster, &backend, &job).unwrap();
    // 2 remote parity uploads through a 50 MB/s NIC = ~21 ms + compute;
    // with downloads it would be ≥ 31 ms.
    assert!(dt < Duration::from_millis(120), "locality ignored: {dt:?}");
    for i in 0..3 {
        let holder = [0usize, 1, 2][i];
        assert!(cluster
            .node(holder)
            .peek(BlockKey::coded(object, 3 + i))
            .unwrap()
            .is_some());
    }
}
