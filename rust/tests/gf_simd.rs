//! Differential tests for the SIMD GF kernels: every runtime-available
//! kernel must be **byte-identical** to the scalar kernel and to the
//! bitwise (carry-less shift/XOR) ground truth, for both field widths,
//! every coefficient class (0, 1, general), sub-vector tail lengths and
//! unaligned buffer offsets — and the `GfWork` a slice op reports must not
//! depend on which backend executed it.
//!
//! CI runs the whole suite as a forced-kernel matrix
//! (`RAPIDRAID_KERNEL=scalar|ssse3|avx2` plus a detection-default leg),
//! so each dispatchable kernel faces the same assertions in its own
//! process — the cross-process half of the byte-identity contract.

use rapidraid::backend::{EncodeBackend, NativeBackend, Width};
use rapidraid::gf::tables::mul_bitwise;
use rapidraid::gf::{
    bytes_as_gf256, bytes_as_gf65536, mul_slice, mul_slice_xor, simd, xor_slice, Gf256, Gf65536,
    Kernel,
};
use rapidraid::resources::GfWork;
use rapidraid::util::SplitMix64;

/// Lengths that exercise empty input, sub-vector tails, exact vector
/// multiples and large buffers (for GF(2^16) the odd entries are rounded
/// down to the nearest even byte count by the callers below).
const LENS: &[usize] = &[0, 1, 2, 3, 8, 15, 16, 17, 31, 32, 33, 63, 64, 65, 127, 128, 257, 1024];

/// Start offsets into an over-allocated buffer — defeats any accidental
/// reliance on 16/32-byte alignment.
const OFFSETS: &[usize] = &[0, 1, 3];

const SEEDS: &[u64] = &[1, 0xD1CE_F00D];

fn ref_mul8(c: u8, x: u8) -> u8 {
    mul_bitwise(c as u32, x as u32, 8) as u8
}

fn ref_mul16(c: u16, x: u16) -> u16 {
    mul_bitwise(c as u32, x as u32, 16) as u16
}

#[test]
fn gf8_kernels_match_bitwise_ground_truth() {
    let kernels = Kernel::available_kernels();
    assert!(kernels.contains(&Kernel::Scalar));
    for &seed in SEEDS {
        let mut rng = SplitMix64::new(seed);
        let mut src = vec![0u8; 1024 + 8];
        let mut dst0 = vec![0u8; 1024 + 8];
        rng.fill_bytes(&mut src);
        rng.fill_bytes(&mut dst0);
        let mut coeffs = vec![0u8, 1, 2, 0x53, 0x8E, 0xFF];
        coeffs.push(rng.next_u64() as u8);
        for &c in &coeffs {
            for &len in LENS {
                for &off in OFFSETS {
                    let s = &src[off..off + len];
                    let expect_xor: Vec<u8> = s
                        .iter()
                        .zip(&dst0[off..off + len])
                        .map(|(&x, &d)| ref_mul8(c, x) ^ d)
                        .collect();
                    let expect_mul: Vec<u8> = s.iter().map(|&x| ref_mul8(c, x)).collect();
                    for &k in &kernels {
                        let mut d = dst0.clone();
                        simd::mul_xor8(k, c, s, &mut d[off..off + len]);
                        assert_eq!(
                            d[off..off + len],
                            expect_xor[..],
                            "mul_xor8 {k} c={c:#x} len={len} off={off} seed={seed}"
                        );
                        let mut d = dst0.clone();
                        simd::mul8(k, c, s, &mut d[off..off + len]);
                        assert_eq!(
                            d[off..off + len],
                            expect_mul[..],
                            "mul8 {k} c={c:#x} len={len} off={off} seed={seed}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn gf16_kernels_match_bitwise_ground_truth() {
    let kernels = Kernel::available_kernels();
    for &seed in SEEDS {
        let mut rng = SplitMix64::new(seed);
        let mut src = vec![0u8; 1024 + 8];
        let mut dst0 = vec![0u8; 1024 + 8];
        rng.fill_bytes(&mut src);
        rng.fill_bytes(&mut dst0);
        let mut coeffs = vec![0u16, 1, 2, 0x1234, 0x8000, 0xFFFF];
        coeffs.push(rng.next_u64() as u16);
        for &c in &coeffs {
            for &raw_len in LENS {
                let len = raw_len & !1; // symbols are two bytes wide
                for &off in OFFSETS {
                    let s = &src[off..off + len];
                    let ref16 = |bytes: &[u8], d: &[u8], xor: bool| -> Vec<u8> {
                        let mut out = Vec::with_capacity(bytes.len());
                        for (p, dp) in bytes.chunks_exact(2).zip(d.chunks_exact(2)) {
                            let x = u16::from_le_bytes([p[0], p[1]]);
                            let mut r = ref_mul16(c, x);
                            if xor {
                                r ^= u16::from_le_bytes([dp[0], dp[1]]);
                            }
                            out.extend_from_slice(&r.to_le_bytes());
                        }
                        out
                    };
                    let expect_xor = ref16(s, &dst0[off..off + len], true);
                    let expect_mul = ref16(s, &dst0[off..off + len], false);
                    for &k in &kernels {
                        let mut d = dst0.clone();
                        simd::mul_xor16(k, c, s, &mut d[off..off + len]);
                        assert_eq!(
                            d[off..off + len],
                            expect_xor[..],
                            "mul_xor16 {k} c={c:#x} len={len} off={off} seed={seed}"
                        );
                        let mut d = dst0.clone();
                        simd::mul16(k, c, s, &mut d[off..off + len]);
                        assert_eq!(
                            d[off..off + len],
                            expect_mul[..],
                            "mul16 {k} c={c:#x} len={len} off={off} seed={seed}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn xor_kernels_match_reference() {
    let kernels = Kernel::available_kernels();
    let mut rng = SplitMix64::new(7);
    let mut src = vec![0u8; 1024 + 8];
    let mut dst0 = vec![0u8; 1024 + 8];
    rng.fill_bytes(&mut src);
    rng.fill_bytes(&mut dst0);
    for &len in LENS {
        for &off in OFFSETS {
            let s = &src[off..off + len];
            let expect: Vec<u8> = s
                .iter()
                .zip(&dst0[off..off + len])
                .map(|(&x, &d)| x ^ d)
                .collect();
            for &k in &kernels {
                let mut d = dst0.clone();
                simd::xor_bytes(k, s, &mut d[off..off + len]);
                assert_eq!(d[off..off + len], expect[..], "xor {k} len={len} off={off}");
            }
        }
    }
}

/// The SIMD kernels agree with each other, not just with the reference —
/// a direct pairwise check at a size large enough to hit every internal
/// stride (full vectors for SSE/AVX/NEON plus a ragged tail).
#[test]
fn kernels_are_pairwise_byte_identical() {
    let kernels = Kernel::available_kernels();
    let mut rng = SplitMix64::new(42);
    let mut src = vec![0u8; 4096 + 6];
    let mut dst0 = vec![0u8; 4096 + 6];
    rng.fill_bytes(&mut src);
    rng.fill_bytes(&mut dst0);
    let len = 4096 + 6; // ragged: not a multiple of 32
    let mut scalar8 = dst0.clone();
    simd::mul_xor8(Kernel::Scalar, 0xA7, &src[..len], &mut scalar8[..len]);
    let even = len & !1;
    let mut scalar16 = dst0.clone();
    simd::mul_xor16(Kernel::Scalar, 0xBEEF, &src[..even], &mut scalar16[..even]);
    for &k in &kernels {
        let mut d = dst0.clone();
        simd::mul_xor8(k, 0xA7, &src[..len], &mut d[..len]);
        assert_eq!(d, scalar8, "gf8 {k} diverges from scalar");
        let mut d = dst0.clone();
        simd::mul_xor16(k, 0xBEEF, &src[..even], &mut d[..even]);
        assert_eq!(d, scalar16, "gf16 {k} diverges from scalar");
    }
}

/// `GfWork` is part of the deterministic simulation contract: it is
/// derived from the coefficient class and length *before* kernel dispatch,
/// so a SIMD box and a scalar box charge identical virtual time. These
/// constants must hold no matter which kernel `Kernel::active()` resolved
/// to (CI re-runs this suite under `RAPIDRAID_FORCE_SCALAR=1`).
#[test]
fn gfwork_is_backend_independent() {
    let n = 257usize;
    let mut rng = SplitMix64::new(3);
    let mut bytes8 = vec![0u8; n];
    rng.fill_bytes(&mut bytes8);
    let src8: Vec<Gf256> = bytes_as_gf256(&bytes8).to_vec();
    let mut dst8 = src8.clone();

    // GF(2^8): general coefficient = one MAC pass; c == 1 on the XOR
    // variant = one XOR pass; c == 0 is free.
    assert_eq!(mul_slice_xor(Gf256(0x53), &src8, &mut dst8), GfWork::mac(n));
    assert_eq!(mul_slice_xor(Gf256(1), &src8, &mut dst8), GfWork::xor(n));
    assert_eq!(mul_slice_xor(Gf256(0), &src8, &mut dst8), GfWork::ZERO);
    assert_eq!(mul_slice(Gf256(0x53), &src8, &mut dst8), GfWork::mac(n));
    assert_eq!(xor_slice(&src8, &mut dst8), GfWork::xor(n));

    // GF(2^16): work is charged in bytes (2 per symbol).
    let mut bytes16 = vec![0u8; 2 * n];
    rng.fill_bytes(&mut bytes16);
    let src16: Vec<Gf65536> = bytes_as_gf65536(&bytes16).to_vec();
    let mut dst16 = src16.clone();
    assert_eq!(
        mul_slice_xor(Gf65536(0x1234), &src16, &mut dst16),
        GfWork::mac(2 * n)
    );
    assert_eq!(
        mul_slice_xor(Gf65536(1), &src16, &mut dst16),
        GfWork::xor(2 * n)
    );
    assert_eq!(xor_slice(&src16, &mut dst16), GfWork::xor(2 * n));
}

/// Slice-level ops (which dispatch through `Kernel::active()`) agree with
/// an explicit scalar-kernel pass over the same bytes — whatever kernel
/// the environment selected.
#[test]
fn active_kernel_slice_ops_match_forced_scalar() {
    let mut rng = SplitMix64::new(11);
    let mut bytes = vec![0u8; 513];
    rng.fill_bytes(&mut bytes);
    let src: Vec<Gf256> = bytes_as_gf256(&bytes).to_vec();

    let mut via_slice = src.clone();
    mul_slice_xor(Gf256(0xC3), &src, &mut via_slice);

    let mut via_scalar = bytes.clone();
    {
        let tmp = bytes.clone();
        simd::mul_xor8(Kernel::Scalar, 0xC3, &tmp, &mut via_scalar);
    }
    let expect: Vec<Gf256> = bytes_as_gf256(&via_scalar).to_vec();
    assert_eq!(via_slice, expect);
}

// ---------------------------------------------------------------------------
// Fused two-output kernels (mul2_slice_xor)
// ---------------------------------------------------------------------------

/// Coefficient classes {0, 1, general} for the fused pass — the full
/// cross-product, because the fused kernels must degenerate correctly
/// when either (or both) coefficients are trivial.
const CLASSES8: &[u8] = &[0, 1, 0x53];
const CLASSES16: &[u16] = &[0, 1, 0x1234];

#[test]
fn gf8_fused_mul2_matches_bitwise_ground_truth() {
    let kernels = Kernel::available_kernels();
    for &seed in SEEDS {
        let mut rng = SplitMix64::new(seed);
        let mut src = vec![0u8; 1024 + 8];
        let mut x0 = vec![0u8; 1024 + 8];
        let mut c0 = vec![0u8; 1024 + 8];
        rng.fill_bytes(&mut src);
        rng.fill_bytes(&mut x0);
        rng.fill_bytes(&mut c0);
        for &p in CLASSES8 {
            for &q in CLASSES8 {
                for &len in LENS {
                    for &off in OFFSETS {
                        let s = &src[off..off + len];
                        let expect_x: Vec<u8> = s
                            .iter()
                            .zip(&x0[off..off + len])
                            .map(|(&v, &d)| ref_mul8(p, v) ^ d)
                            .collect();
                        let expect_c: Vec<u8> = s
                            .iter()
                            .zip(&c0[off..off + len])
                            .map(|(&v, &d)| ref_mul8(q, v) ^ d)
                            .collect();
                        for &k in &kernels {
                            let mut x = x0.clone();
                            let mut c = c0.clone();
                            simd::mul2_xor8(k, p, q, s, &mut x[off..off + len], &mut c[off..off + len]);
                            assert_eq!(
                                x[off..off + len],
                                expect_x[..],
                                "mul2 x: {k} p={p:#x} q={q:#x} len={len} off={off} seed={seed}"
                            );
                            assert_eq!(
                                c[off..off + len],
                                expect_c[..],
                                "mul2 c: {k} p={p:#x} q={q:#x} len={len} off={off} seed={seed}"
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn gf16_fused_mul2_matches_bitwise_ground_truth() {
    let kernels = Kernel::available_kernels();
    for &seed in SEEDS {
        let mut rng = SplitMix64::new(seed.wrapping_add(99));
        let mut src = vec![0u8; 1024 + 8];
        let mut x0 = vec![0u8; 1024 + 8];
        let mut c0 = vec![0u8; 1024 + 8];
        rng.fill_bytes(&mut src);
        rng.fill_bytes(&mut x0);
        rng.fill_bytes(&mut c0);
        for &p in CLASSES16 {
            for &q in CLASSES16 {
                for &raw_len in LENS {
                    let len = raw_len & !1;
                    for &off in OFFSETS {
                        let s = &src[off..off + len];
                        let expect = |coef: u16, d0: &[u8]| -> Vec<u8> {
                            let mut out = Vec::with_capacity(len);
                            for (sp, dp) in s.chunks_exact(2).zip(d0.chunks_exact(2)) {
                                let v = u16::from_le_bytes([sp[0], sp[1]]);
                                let r = ref_mul16(coef, v) ^ u16::from_le_bytes([dp[0], dp[1]]);
                                out.extend_from_slice(&r.to_le_bytes());
                            }
                            out
                        };
                        let expect_x = expect(p, &x0[off..off + len]);
                        let expect_c = expect(q, &c0[off..off + len]);
                        for &k in &kernels {
                            let mut x = x0.clone();
                            let mut c = c0.clone();
                            simd::mul2_xor16(k, p, q, s, &mut x[off..off + len], &mut c[off..off + len]);
                            assert_eq!(
                                x[off..off + len],
                                expect_x[..],
                                "mul2 x: {k} p={p:#x} q={q:#x} len={len} off={off} seed={seed}"
                            );
                            assert_eq!(
                                c[off..off + len],
                                expect_c[..],
                                "mul2 c: {k} p={p:#x} q={q:#x} len={len} off={off} seed={seed}"
                            );
                        }
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Row-batched GEMM
// ---------------------------------------------------------------------------

/// The row-batched GEMM schedule (pairs of output rows per L1-chunked
/// source pass) must be byte-identical to the naive one-pass-per-cell
/// reference on every kernel — including matrices with zero/identity
/// cells, an odd row count, and lengths straddling the chunk size.
#[test]
fn gemm_rows_match_per_cell_ground_truth() {
    let kernels = Kernel::available_kernels();
    let mut rng = SplitMix64::new(0xBADC_0FFE);
    for &len in &[0usize, 2, 34, 4096, 4098, 8192 + 130] {
        let data_own: Vec<Vec<u8>> = (0..4)
            .map(|_| {
                let mut d = vec![0u8; len];
                rng.fill_bytes(&mut d);
                d
            })
            .collect();
        let data: Vec<&[u8]> = data_own.iter().map(|d| d.as_slice()).collect();
        let mat: Vec<Vec<u32>> = vec![
            vec![0, 0, 0, 0],
            vec![1, 0, 2, 0x53],
            vec![0x8E, 1, 0, 255],
            vec![7, 9, 1, 1],
            vec![0, 0, 0, 3],
        ];
        for &k in &kernels {
            for w in [Width::W8, Width::W16] {
                let mut out = vec![vec![0u8; len]; mat.len()];
                match w {
                    Width::W8 => simd::gemm_rows8(k, &mat, &data, &mut out),
                    Width::W16 => simd::gemm_rows16(k, &mat, &data, &mut out),
                }
                for (row, o) in mat.iter().zip(&out) {
                    let mut expect = vec![0u8; len];
                    for (&c, d) in row.iter().zip(&data) {
                        match w {
                            Width::W8 => simd::mul_xor8(Kernel::Scalar, c as u8, d, &mut expect),
                            Width::W16 => simd::mul_xor16(Kernel::Scalar, c as u16, d, &mut expect),
                        }
                    }
                    assert_eq!(o, &expect, "gemm_rows {k} {w} len={len} row={row:?}");
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// GFNI tier
// ---------------------------------------------------------------------------

/// Explicit GFNI coverage beyond the shared `available_kernels()` sweeps:
/// the affine-encoded products must match the carry-less ground truth for
/// a dense coefficient sample at both widths. Skips (trivially passes) on
/// hosts without GFNI — the forced-kernel CI matrix documents which legs
/// actually exercised it.
#[test]
fn gfni_matches_bitwise_ground_truth_when_available() {
    if !Kernel::Gfni.is_available() {
        return;
    }
    let mut rng = SplitMix64::new(0x6F41);
    let mut src = vec![0u8; 777];
    rng.fill_bytes(&mut src);
    for c in (0u32..256).step_by(17).chain([1, 2, 255]) {
        let mut dst = vec![0u8; src.len()];
        simd::mul8(Kernel::Gfni, c as u8, &src, &mut dst);
        for (i, (&s, &d)) in src.iter().zip(&dst).enumerate() {
            assert_eq!(d as u32, mul_bitwise(c, s as u32, 8), "c={c} i={i}");
        }
    }
    let even = src.len() & !1;
    for c in [1u32, 2, 0x1234, 0x8001, 0xFFFF, 0x100B] {
        let mut dst = vec![0u8; even];
        simd::mul16(Kernel::Gfni, c as u16, &src[..even], &mut dst);
        for (i, (sp, dp)) in src[..even].chunks_exact(2).zip(dst.chunks_exact(2)).enumerate() {
            let s = u16::from_le_bytes([sp[0], sp[1]]) as u32;
            let d = u16::from_le_bytes([dp[0], dp[1]]) as u32;
            assert_eq!(d, mul_bitwise(c, s, 16), "c={c:#x} word={i}");
        }
    }
}

// ---------------------------------------------------------------------------
// Backend routing + work accounting
// ---------------------------------------------------------------------------

/// The native backend's fused `pipeline_step` / paired `fold_parity` /
/// row-batched `gemm` must equal a naive scalar-kernel reference on
/// whatever kernel `Kernel::active()` resolved to — the in-process half
/// of the byte-identical-across-kernels acceptance bar (the forced-kernel
/// CI matrix covers the cross-process half).
#[test]
fn backend_entry_points_match_scalar_reference() {
    let be = NativeBackend::new();
    let mut rng = SplitMix64::new(0x5EED);
    let len = 4096 + 130; // straddles the GEMM chunk, even for W16
    let blocks: Vec<Vec<u8>> = (0..3)
        .map(|_| {
            let mut b = vec![0u8; len];
            rng.fill_bytes(&mut b);
            b
        })
        .collect();
    let mut x_in = vec![0u8; len];
    rng.fill_bytes(&mut x_in);
    let locals: Vec<&[u8]> = blocks.iter().map(|b| b.as_slice()).collect();
    let psi = [0u32, 1, 0x53];
    let xi = [7u32, 0, 1];
    for w in [Width::W8, Width::W16] {
        let (x_out, c) = be.pipeline_step(w, &x_in, &locals, &psi, &xi).unwrap();
        let mut ex = x_in.clone();
        let mut ec = x_in.clone();
        for (j, loc) in locals.iter().enumerate() {
            let mul_xor: fn(Kernel, u32, &[u8], &mut [u8]) = match w {
                Width::W8 => |k, c, s, d| simd::mul_xor8(k, c as u8, s, d),
                Width::W16 => |k, c, s, d| simd::mul_xor16(k, c as u16, s, d),
            };
            if psi[j] != 0 {
                mul_xor(Kernel::Scalar, psi[j], loc, &mut ex);
            }
            if xi[j] != 0 {
                mul_xor(Kernel::Scalar, xi[j], loc, &mut ec);
            }
        }
        assert_eq!(x_out, ex, "pipeline_step x_out {w}");
        assert_eq!(c, ec, "pipeline_step c {w}");

        // fold_parity with an odd row count (fused pair + single row).
        let coeffs = [3u32, 1, 0x53];
        let mut parity = vec![vec![0x11u8; len]; 3];
        be.fold_parity(w, &coeffs, &x_in, &mut parity).unwrap();
        for (cf, p) in coeffs.iter().zip(&parity) {
            let mut expect = vec![0x11u8; len];
            match w {
                Width::W8 => simd::mul_xor8(Kernel::Scalar, *cf as u8, &x_in, &mut expect),
                Width::W16 => simd::mul_xor16(Kernel::Scalar, *cf as u16, &x_in, &mut expect),
            }
            assert_eq!(p, &expect, "fold_parity {w} c={cf}");
        }

        // gemm through the backend (routes to the row-batched schedule).
        let mat = vec![vec![1u32, 0, 2], vec![0x53, 1, 0], vec![0, 0, 0]];
        let out = be.gemm(w, &mat, &locals).unwrap();
        for (row, o) in mat.iter().zip(&out) {
            let mut expect = vec![0u8; len];
            for (&cf, d) in row.iter().zip(&locals) {
                match w {
                    Width::W8 => simd::mul_xor8(Kernel::Scalar, cf as u8, d, &mut expect),
                    Width::W16 => simd::mul_xor16(Kernel::Scalar, cf as u16, d, &mut expect),
                }
            }
            assert_eq!(o, &expect, "gemm {w} row={row:?}");
        }
    }
}

/// `GfWork::pipeline_step` is a pure function of the coefficient classes
/// and the frame length — the charge a relay stage books is decided
/// before any kernel dispatch, so every kernel (Gfni included) books the
/// same virtual time for the same frame.
#[test]
fn pipeline_step_work_is_kernel_independent() {
    let psi = [0u32, 1, 0x53];
    let xi = [7u32, 0, 1];
    let len = 1500usize;
    let expect = GfWork::xor(2 * len) // x_out and c both start as x_in copies
        + GfWork::xor(len)            // psi[1] == 1
        + GfWork::mac(len)            // psi[2]
        + GfWork::mac(len)            // xi[0]
        + GfWork::xor(len); // xi[2] == 1
    assert_eq!(GfWork::pipeline_step(&psi, &xi, len), expect);
}
