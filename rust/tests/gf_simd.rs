//! Differential tests for the SIMD GF kernels: every runtime-available
//! kernel must be **byte-identical** to the scalar kernel and to the
//! bitwise (carry-less shift/XOR) ground truth, for both field widths,
//! every coefficient class (0, 1, general), sub-vector tail lengths and
//! unaligned buffer offsets — and the `GfWork` a slice op reports must not
//! depend on which backend executed it.
//!
//! CI runs the whole suite twice — once as-is and once under
//! `RAPIDRAID_FORCE_SCALAR=1` — so both the dispatcher's chosen kernel and
//! the forced-scalar path face the same assertions.

use rapidraid::gf::tables::mul_bitwise;
use rapidraid::gf::{
    bytes_as_gf256, bytes_as_gf65536, mul_slice, mul_slice_xor, simd, xor_slice, Gf256, Gf65536,
    Kernel,
};
use rapidraid::resources::GfWork;
use rapidraid::util::SplitMix64;

/// Lengths that exercise empty input, sub-vector tails, exact vector
/// multiples and large buffers (for GF(2^16) the odd entries are rounded
/// down to the nearest even byte count by the callers below).
const LENS: &[usize] = &[0, 1, 2, 3, 8, 15, 16, 17, 31, 32, 33, 63, 64, 65, 127, 128, 257, 1024];

/// Start offsets into an over-allocated buffer — defeats any accidental
/// reliance on 16/32-byte alignment.
const OFFSETS: &[usize] = &[0, 1, 3];

const SEEDS: &[u64] = &[1, 0xD1CE_F00D];

fn ref_mul8(c: u8, x: u8) -> u8 {
    mul_bitwise(c as u32, x as u32, 8) as u8
}

fn ref_mul16(c: u16, x: u16) -> u16 {
    mul_bitwise(c as u32, x as u32, 16) as u16
}

#[test]
fn gf8_kernels_match_bitwise_ground_truth() {
    let kernels = Kernel::available_kernels();
    assert!(kernels.contains(&Kernel::Scalar));
    for &seed in SEEDS {
        let mut rng = SplitMix64::new(seed);
        let mut src = vec![0u8; 1024 + 8];
        let mut dst0 = vec![0u8; 1024 + 8];
        rng.fill_bytes(&mut src);
        rng.fill_bytes(&mut dst0);
        let mut coeffs = vec![0u8, 1, 2, 0x53, 0x8E, 0xFF];
        coeffs.push(rng.next_u64() as u8);
        for &c in &coeffs {
            for &len in LENS {
                for &off in OFFSETS {
                    let s = &src[off..off + len];
                    let expect_xor: Vec<u8> = s
                        .iter()
                        .zip(&dst0[off..off + len])
                        .map(|(&x, &d)| ref_mul8(c, x) ^ d)
                        .collect();
                    let expect_mul: Vec<u8> = s.iter().map(|&x| ref_mul8(c, x)).collect();
                    for &k in &kernels {
                        let mut d = dst0.clone();
                        simd::mul_xor8(k, c, s, &mut d[off..off + len]);
                        assert_eq!(
                            d[off..off + len],
                            expect_xor[..],
                            "mul_xor8 {k} c={c:#x} len={len} off={off} seed={seed}"
                        );
                        let mut d = dst0.clone();
                        simd::mul8(k, c, s, &mut d[off..off + len]);
                        assert_eq!(
                            d[off..off + len],
                            expect_mul[..],
                            "mul8 {k} c={c:#x} len={len} off={off} seed={seed}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn gf16_kernels_match_bitwise_ground_truth() {
    let kernels = Kernel::available_kernels();
    for &seed in SEEDS {
        let mut rng = SplitMix64::new(seed);
        let mut src = vec![0u8; 1024 + 8];
        let mut dst0 = vec![0u8; 1024 + 8];
        rng.fill_bytes(&mut src);
        rng.fill_bytes(&mut dst0);
        let mut coeffs = vec![0u16, 1, 2, 0x1234, 0x8000, 0xFFFF];
        coeffs.push(rng.next_u64() as u16);
        for &c in &coeffs {
            for &raw_len in LENS {
                let len = raw_len & !1; // symbols are two bytes wide
                for &off in OFFSETS {
                    let s = &src[off..off + len];
                    let ref16 = |bytes: &[u8], d: &[u8], xor: bool| -> Vec<u8> {
                        let mut out = Vec::with_capacity(bytes.len());
                        for (p, dp) in bytes.chunks_exact(2).zip(d.chunks_exact(2)) {
                            let x = u16::from_le_bytes([p[0], p[1]]);
                            let mut r = ref_mul16(c, x);
                            if xor {
                                r ^= u16::from_le_bytes([dp[0], dp[1]]);
                            }
                            out.extend_from_slice(&r.to_le_bytes());
                        }
                        out
                    };
                    let expect_xor = ref16(s, &dst0[off..off + len], true);
                    let expect_mul = ref16(s, &dst0[off..off + len], false);
                    for &k in &kernels {
                        let mut d = dst0.clone();
                        simd::mul_xor16(k, c, s, &mut d[off..off + len]);
                        assert_eq!(
                            d[off..off + len],
                            expect_xor[..],
                            "mul_xor16 {k} c={c:#x} len={len} off={off} seed={seed}"
                        );
                        let mut d = dst0.clone();
                        simd::mul16(k, c, s, &mut d[off..off + len]);
                        assert_eq!(
                            d[off..off + len],
                            expect_mul[..],
                            "mul16 {k} c={c:#x} len={len} off={off} seed={seed}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn xor_kernels_match_reference() {
    let kernels = Kernel::available_kernels();
    let mut rng = SplitMix64::new(7);
    let mut src = vec![0u8; 1024 + 8];
    let mut dst0 = vec![0u8; 1024 + 8];
    rng.fill_bytes(&mut src);
    rng.fill_bytes(&mut dst0);
    for &len in LENS {
        for &off in OFFSETS {
            let s = &src[off..off + len];
            let expect: Vec<u8> = s
                .iter()
                .zip(&dst0[off..off + len])
                .map(|(&x, &d)| x ^ d)
                .collect();
            for &k in &kernels {
                let mut d = dst0.clone();
                simd::xor_bytes(k, s, &mut d[off..off + len]);
                assert_eq!(d[off..off + len], expect[..], "xor {k} len={len} off={off}");
            }
        }
    }
}

/// The SIMD kernels agree with each other, not just with the reference —
/// a direct pairwise check at a size large enough to hit every internal
/// stride (full vectors for SSE/AVX/NEON plus a ragged tail).
#[test]
fn kernels_are_pairwise_byte_identical() {
    let kernels = Kernel::available_kernels();
    let mut rng = SplitMix64::new(42);
    let mut src = vec![0u8; 4096 + 6];
    let mut dst0 = vec![0u8; 4096 + 6];
    rng.fill_bytes(&mut src);
    rng.fill_bytes(&mut dst0);
    let len = 4096 + 6; // ragged: not a multiple of 32
    let mut scalar8 = dst0.clone();
    simd::mul_xor8(Kernel::Scalar, 0xA7, &src[..len], &mut scalar8[..len]);
    let even = len & !1;
    let mut scalar16 = dst0.clone();
    simd::mul_xor16(Kernel::Scalar, 0xBEEF, &src[..even], &mut scalar16[..even]);
    for &k in &kernels {
        let mut d = dst0.clone();
        simd::mul_xor8(k, 0xA7, &src[..len], &mut d[..len]);
        assert_eq!(d, scalar8, "gf8 {k} diverges from scalar");
        let mut d = dst0.clone();
        simd::mul_xor16(k, 0xBEEF, &src[..even], &mut d[..even]);
        assert_eq!(d, scalar16, "gf16 {k} diverges from scalar");
    }
}

/// `GfWork` is part of the deterministic simulation contract: it is
/// derived from the coefficient class and length *before* kernel dispatch,
/// so a SIMD box and a scalar box charge identical virtual time. These
/// constants must hold no matter which kernel `Kernel::active()` resolved
/// to (CI re-runs this suite under `RAPIDRAID_FORCE_SCALAR=1`).
#[test]
fn gfwork_is_backend_independent() {
    let n = 257usize;
    let mut rng = SplitMix64::new(3);
    let mut bytes8 = vec![0u8; n];
    rng.fill_bytes(&mut bytes8);
    let src8: Vec<Gf256> = bytes_as_gf256(&bytes8).to_vec();
    let mut dst8 = src8.clone();

    // GF(2^8): general coefficient = one MAC pass; c == 1 on the XOR
    // variant = one XOR pass; c == 0 is free.
    assert_eq!(mul_slice_xor(Gf256(0x53), &src8, &mut dst8), GfWork::mac(n));
    assert_eq!(mul_slice_xor(Gf256(1), &src8, &mut dst8), GfWork::xor(n));
    assert_eq!(mul_slice_xor(Gf256(0), &src8, &mut dst8), GfWork::ZERO);
    assert_eq!(mul_slice(Gf256(0x53), &src8, &mut dst8), GfWork::mac(n));
    assert_eq!(xor_slice(&src8, &mut dst8), GfWork::xor(n));

    // GF(2^16): work is charged in bytes (2 per symbol).
    let mut bytes16 = vec![0u8; 2 * n];
    rng.fill_bytes(&mut bytes16);
    let src16: Vec<Gf65536> = bytes_as_gf65536(&bytes16).to_vec();
    let mut dst16 = src16.clone();
    assert_eq!(
        mul_slice_xor(Gf65536(0x1234), &src16, &mut dst16),
        GfWork::mac(2 * n)
    );
    assert_eq!(
        mul_slice_xor(Gf65536(1), &src16, &mut dst16),
        GfWork::xor(2 * n)
    );
    assert_eq!(xor_slice(&src16, &mut dst16), GfWork::xor(2 * n));
}

/// Slice-level ops (which dispatch through `Kernel::active()`) agree with
/// an explicit scalar-kernel pass over the same bytes — whatever kernel
/// the environment selected.
#[test]
fn active_kernel_slice_ops_match_forced_scalar() {
    let mut rng = SplitMix64::new(11);
    let mut bytes = vec![0u8; 513];
    rng.fill_bytes(&mut bytes);
    let src: Vec<Gf256> = bytes_as_gf256(&bytes).to_vec();

    let mut via_slice = src.clone();
    mul_slice_xor(Gf256(0xC3), &src, &mut via_slice);

    let mut via_scalar = bytes.clone();
    {
        let tmp = bytes.clone();
        simd::mul_xor8(Kernel::Scalar, 0xC3, &tmp, &mut via_scalar);
    }
    let expect: Vec<Gf256> = bytes_as_gf256(&via_scalar).to_vec();
    assert_eq!(via_slice, expect);
}
