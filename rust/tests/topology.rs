//! Topology acceptance properties:
//!
//! 1. **Lowering equivalence** — for random (n, k, seed) RapidRAID codes
//!    over GF(2^8) and GF(2^16), the distributed pipeline of every shape
//!    (chain, tree:2, tree:3, hybrid:2:2) produces codewords
//!    byte-identical to the atomic encode through the topology-composed
//!    generator, and every *independent* k-subset of the stored blocks
//!    decodes back to the object (dependent subsets are rejected).
//! 2. **Straggler isolation** — under `ProfileCost`, slowing the pipeline
//!    head/root hurts the chain strictly more than the fanout-2 tree: the
//!    chain re-paces all n stages behind the straggler, the tree only the
//!    root's own work (its children are already paced by the fan-out
//!    uplink).

use std::sync::Arc;
use std::time::Duration;

use rapidraid::backend::{BackendHandle, NativeBackend};
use rapidraid::cluster::{Cluster, ClusterSpec};
use rapidraid::codes::rapidraid::RapidRaidCode;
use rapidraid::codes::subsets::Combinations;
use rapidraid::codes::{DecodeError, TopologyCode};
use rapidraid::coordinator::{archive_pipeline, ingest_object, PipelineJob, Topology};
use rapidraid::gf::{Gf256, Gf65536, GfElem, SliceOps};
use rapidraid::resources::NodeProfile;
use rapidraid::storage::{BlockKey, ObjectId, ReplicaPlacement};
use rapidraid::util::prop::forall;

fn bytes_to_gf<F: GfElem>(data: &[u8]) -> Vec<F> {
    match F::BITS {
        8 => data.iter().map(|&b| F::from_u32(b as u32)).collect(),
        16 => data
            .chunks_exact(2)
            .map(|p| F::from_u32(u16::from_le_bytes([p[0], p[1]]) as u32))
            .collect(),
        other => panic!("unsupported width {other}"),
    }
}

fn shapes() -> Vec<Topology> {
    vec![
        Topology::Chain,
        Topology::Tree { fanout: 2 },
        Topology::Tree { fanout: 3 },
        Topology::Hybrid {
            chain_prefix: 2,
            tree_fanout: 2,
        },
    ]
}

/// The lowering-equivalence property, generic over the field.
fn equivalence_property<F: GfElem + SliceOps>(backend: &BackendHandle, cases: usize, seed: u64) {
    forall(cases, seed, |rng| {
        let k = 3 + rng.below(2) as usize; // 3..=4 keeps C(n,k) enumerable
        let extra = 1 + rng.below(k as u64) as usize; // 1..=k
        let n = (k + extra).min(2 * k);
        let block = 1024 * (1 + rng.below(3) as usize); // 1..3 KiB
        let object = ObjectId(rng.next_u64());
        let code = RapidRaidCode::<F>::with_seed(n, k, rng.next_u64()).unwrap();

        for topo in shapes() {
            let cluster = Cluster::start(ClusterSpec::test(n));
            let placement = ReplicaPlacement::new(object, k, (0..n).collect()).unwrap();
            let blocks = ingest_object(&cluster, &placement, block).unwrap();
            let job =
                PipelineJob::from_code_with_topology(&code, &placement, topo, 1024, block)
                    .unwrap();
            archive_pipeline(&cluster, backend, &job).unwrap();

            // 1. distributed pipeline ≡ atomic generator encode
            let tcode = TopologyCode::new(code.clone(), topo.shape(n).unwrap()).unwrap();
            let obj_gf: Vec<Vec<F>> = blocks.iter().map(|b| bytes_to_gf::<F>(b)).collect();
            let expect = tcode.encode_matrix(&obj_gf);
            let coded: Vec<Vec<F>> = (0..n)
                .map(|i| {
                    let raw = cluster
                        .node(i)
                        .peek(BlockKey::coded(object, i))
                        .unwrap()
                        .unwrap_or_else(|| panic!("({topo}) coded block {i} missing"));
                    bytes_to_gf::<F>(&raw)
                })
                .collect();
            assert_eq!(coded, expect, "(n={n},k={k},{topo}) pipeline != generator");

            // 2. every independent k-subset decodes to the object
            let mut independent = 0usize;
            for sub in Combinations::new(n, k) {
                let have: Vec<(usize, Vec<F>)> =
                    sub.iter().map(|&i| (i, coded[i].clone())).collect();
                match tcode.decode(&have) {
                    Ok(rec) => {
                        independent += 1;
                        assert_eq!(rec, obj_gf, "(n={n},k={k},{topo}) subset {sub:?}");
                    }
                    Err(DecodeError::DependentSubset { .. }) => {}
                    Err(e) => panic!("(n={n},k={k},{topo}) subset {sub:?}: unexpected {e:?}"),
                }
            }
            assert!(independent > 0, "(n={n},k={k},{topo}) nothing decodable");
        }
    });
}

#[test]
fn every_topology_matches_atomic_generator_gf8() {
    let be: BackendHandle = Arc::new(NativeBackend::new());
    equivalence_property::<Gf256>(&be, 3, 0x70_01);
}

#[test]
fn every_topology_matches_atomic_generator_gf16() {
    let be: BackendHandle = Arc::new(NativeBackend::new());
    equivalence_property::<Gf65536>(&be, 3, 0x70_02);
}

#[test]
fn tree_repair_regenerates_byte_identical_block() {
    use rapidraid::coordinator::survey_coded;
    use rapidraid::repair::{run_pipelined_repair, PipelinedRepairJob, RepairJob};
    // Archive over tree:2, crash a holder, aggregate the repair over the
    // same tree shape: the newcomer must receive the exact lost bytes.
    let topo = Topology::Tree { fanout: 2 };
    let cluster = Cluster::start(ClusterSpec::test(9));
    let object = ObjectId(0x7EE);
    let placement = ReplicaPlacement::new(object, 4, (0..8).collect()).unwrap();
    ingest_object(&cluster, &placement, 16 * 1024).unwrap();
    let code = RapidRaidCode::<Gf256>::with_seed(8, 4, 7).unwrap();
    let tcode = TopologyCode::new(code.clone(), topo.shape(8).unwrap()).unwrap();
    let backend: BackendHandle = Arc::new(NativeBackend::new());
    let job =
        PipelineJob::from_code_with_topology(&code, &placement, topo, 2048, 16 * 1024).unwrap();
    archive_pipeline(&cluster, &backend, &job).unwrap();

    let lost = 6usize;
    let original = (*cluster
        .node(lost)
        .peek(BlockKey::coded(object, lost))
        .unwrap()
        .unwrap())
    .clone();
    cluster.fail_node(lost);
    let (avail, bb) = survey_coded(&cluster, &placement.chain, object);
    let rjob = RepairJob::from_code(
        &tcode,
        object,
        &placement.chain,
        lost,
        8, // the spare 9th node
        &avail,
        2048,
        bb,
    )
    .unwrap();
    run_pipelined_repair(&cluster, &backend, &PipelinedRepairJob::with_topology(rjob, topo))
        .unwrap();
    let rebuilt = cluster
        .node(8)
        .peek(BlockKey::coded(object, lost))
        .unwrap()
        .unwrap();
    assert_eq!(*rebuilt, original, "tree repair changed the block bytes");
}

/// Archive one (8,4) object over `topo` on a jitter-free SimClock TPC
/// cluster with the given per-node profile mix; returns the virtual
/// coding time.
fn timed_archival(topo: Topology, profiles: Vec<NodeProfile>) -> Duration {
    let mut spec = ClusterSpec::tpc(8).sim().with_profiles(profiles).unwrap();
    spec.jitter = Duration::ZERO;
    let cluster = Cluster::start(spec);
    let object = ObjectId(0x57A6);
    let placement = ReplicaPlacement::new(object, 4, (0..8).collect()).unwrap();
    ingest_object(&cluster, &placement, 512 * 1024).unwrap();
    let code = RapidRaidCode::<Gf256>::with_seed(8, 4, 7).unwrap();
    let backend: BackendHandle = Arc::new(NativeBackend::new());
    let job =
        PipelineJob::from_code_with_topology(&code, &placement, topo, 64 * 1024, 512 * 1024)
            .unwrap();
    archive_pipeline(&cluster, &backend, &job).unwrap()
}

#[test]
fn slow_head_hurts_chain_strictly_more_than_tree() {
    // Straggler at position 0 (chain head == tree root). The chain's
    // whole stream re-paces behind the slow stage; the tree root's
    // children are paced by the fan-out uplink anyway, so the same
    // straggler costs the tree strictly less added makespan.
    let uniform = vec![NodeProfile::EC2_SMALL];
    let straggled = {
        let mut p = vec![NodeProfile::EC2_SMALL; 8];
        p[0] = NodeProfile::THINCLIENT; // half speed at the head
        p
    };
    let tree = Topology::Tree { fanout: 2 };
    let chain_fast = timed_archival(Topology::Chain, uniform.clone());
    let chain_slow = timed_archival(Topology::Chain, straggled.clone());
    let tree_fast = timed_archival(tree, uniform);
    let tree_slow = timed_archival(tree, straggled);
    let chain_hurt = chain_slow.saturating_sub(chain_fast);
    let tree_hurt = tree_slow.saturating_sub(tree_fast);
    assert!(
        chain_slow > chain_fast,
        "straggler did not slow the chain: {chain_slow:?} vs {chain_fast:?}"
    );
    assert!(
        chain_hurt > tree_hurt,
        "chain hurt {chain_hurt:?} not strictly above tree hurt {tree_hurt:?} \
         (chain {chain_fast:?}->{chain_slow:?}, tree {tree_fast:?}->{tree_slow:?})"
    );
}
