//! Failure injection: the coordinator must FAIL CLEANLY (error, no hang, no
//! partial silent state) when replicas are missing, chains break, or
//! decode prerequisites are violated.

use std::sync::Arc;
use std::time::Duration;

use rapidraid::backend::{BackendHandle, NativeBackend, Width};
use rapidraid::cluster::{Cluster, ClusterSpec};
use rapidraid::codes::rapidraid::RapidRaidCode;
use rapidraid::codes::DecodeError;
use rapidraid::coordinator::{
    archive_classical, archive_pipeline, ingest_object, reconstruct, ClassicalJob, PipelineJob,
};
use rapidraid::gf::{Gf256, GfElem};
use rapidraid::repair::{
    run_pipelined_repair, PipelinedRepairJob, RepairJob, RepairScheduler, RepairStrategy,
    RepairTrigger,
};
use rapidraid::storage::{BlockKey, ObjectId, ReplicaPlacement};
use rapidraid::util::with_timeout;

mod common;

fn native() -> BackendHandle {
    Arc::new(NativeBackend::new())
}

#[test]
fn pipeline_with_missing_replica_errors_cleanly() {
    let result = with_timeout(30, || {
        let cluster = Cluster::start(ClusterSpec::test(8));
        let object = ObjectId(1);
        let placement = ReplicaPlacement::new(object, 4, (0..8).collect()).unwrap();
        ingest_object(&cluster, &placement, 32 * 1024).unwrap();
        // sabotage: node 3 loses its replica of o_3 before archival
        cluster.node(3).delete(BlockKey::source(object, 3)).unwrap();
        let code = RapidRaidCode::<Gf256>::with_seed(8, 4, 7).unwrap();
        let backend = native();
        let job = PipelineJob::from_code(&code, &placement, 4096, 32 * 1024).unwrap();
        archive_pipeline(&cluster, &backend, &job)
    });
    let err = result.expect_err("must fail");
    assert!(err.to_string().contains("missing local block") || err.to_string().contains("dropped"),
        "unexpected error: {err}");
}

#[test]
fn classical_with_missing_source_errors_cleanly() {
    let result = with_timeout(30, || {
        let cluster = Cluster::start(ClusterSpec::test(8));
        let object = ObjectId(2);
        let placement = ReplicaPlacement::new(object, 4, (0..8).collect()).unwrap();
        ingest_object(&cluster, &placement, 16 * 1024).unwrap();
        cluster.node(1).delete(BlockKey::source(object, 1)).unwrap();
        let backend = native();
        let job = ClassicalJob {
            object,
            width: Width::W8,
            parity_rows: vec![vec![1, 2, 3, 4]; 4],
            source_nodes: vec![0, 1, 2, 3],
            coding_node: 4,
            parity_nodes: vec![4, 5, 6, 7],
            buf_bytes: 4096,
            block_bytes: 16 * 1024,
        };
        archive_classical(&cluster, &backend, &job)
    });
    assert!(result.is_err());
}

#[test]
fn archive_leaves_no_partial_codeword_on_sabotaged_chain() {
    with_timeout(30, || {
        let cluster = Cluster::start(ClusterSpec::test(6));
        let object = ObjectId(3);
        let placement = ReplicaPlacement::new(object, 4, (0..6).collect()).unwrap();
        ingest_object(&cluster, &placement, 16 * 1024).unwrap();
        // node 4 (a tail-side stage) loses its local replica
        cluster.node(4).delete(BlockKey::source(object, 2)).unwrap();
        let code = RapidRaidCode::<Gf256>::with_seed(6, 4, 3).unwrap();
        let backend = native();
        let job = PipelineJob::from_code(&code, &placement, 4096, 16 * 1024).unwrap();
        assert!(archive_pipeline(&cluster, &backend, &job).is_err());
        // node 4 and node 5 (downstream of the failure) must not claim a
        // complete coded block
        assert!(cluster.node(4).peek(BlockKey::coded(object, 4)).unwrap().is_none());
        assert!(cluster.node(5).peek(BlockKey::coded(object, 5)).unwrap().is_none());
    });
}

#[test]
fn decode_error_taxonomy() {
    let code = RapidRaidCode::<Gf256>::with_seed(8, 4, 7).unwrap();
    let b = vec![Gf256::ZERO; 64];
    // not enough blocks
    assert!(matches!(
        code.decode(&[(0, b.clone()), (1, b.clone())]),
        Err(DecodeError::NotEnoughBlocks { got: 2, need: 4 })
    ));
    // out-of-range index
    assert!(matches!(
        code.decode(&[(0, b.clone()), (1, b.clone()), (2, b.clone()), (9, b.clone())]),
        Err(DecodeError::BadIndex { index: 9, n: 8 })
    ));
    // duplicates are linearly dependent
    let dup = code.decode(&[(0, b.clone()), (0, b.clone()), (1, b.clone()), (2, b.clone())]);
    assert!(matches!(dup, Err(DecodeError::DependentSubset { .. })));
}

#[test]
fn reconstruct_fails_then_succeeds_after_block_returns() {
    let cluster = Cluster::start(ClusterSpec::test(8));
    let object = ObjectId(4);
    let placement = ReplicaPlacement::new(object, 4, (0..8).collect()).unwrap();
    let blocks = ingest_object(&cluster, &placement, 8 * 1024).unwrap();
    let code = RapidRaidCode::<Gf256>::with_seed(8, 4, 7).unwrap();
    let backend = native();
    let job = PipelineJob::from_code(&code, &placement, 2048, 8 * 1024).unwrap();
    archive_pipeline(&cluster, &backend, &job).unwrap();

    // keep only 3 coded blocks → unrecoverable
    let mut saved = Vec::new();
    for pos in 3..8 {
        let key = BlockKey::coded(object, pos);
        saved.push((pos, cluster.node(pos).peek(key).unwrap().unwrap()));
        cluster.node(pos).delete(key).unwrap();
    }
    assert!(reconstruct(&cluster, &code, &placement.chain, object, &backend).is_err());

    // one block comes back → recoverable again
    let (pos, data) = &saved[0];
    cluster
        .node(*pos)
        .put(BlockKey::coded(object, *pos), (**data).clone())
        .unwrap();
    let rec = reconstruct(&cluster, &code, &placement.chain, object, &backend).unwrap();
    assert_eq!(rec, blocks);
}

/// Archive an (8,4) object on the first 8 nodes of an `nodes`-node test
/// cluster (shared fixture; spares beyond node 7 serve as newcomers).
fn archived_84(
    nodes: usize,
    object: ObjectId,
    block: usize,
    bytes_per_sec: f64,
) -> (Cluster, RapidRaidCode<Gf256>, ReplicaPlacement, BackendHandle) {
    common::archived::<Gf256>(nodes, 8, 4, 7, object, block, 4096, bytes_per_sec)
}

#[test]
fn second_failure_before_repair_refuses_link_lowering() {
    with_timeout(30, || {
        let object = ObjectId(20);
        let (cluster, code, placement, backend) = archived_84(9, object, 16 * 1024, 1e9);
        cluster.fail_node(2);
        let (avail, block_bytes) =
            rapidraid::coordinator::survey_coded(&cluster, &placement.chain, object);
        let job = PipelinedRepairJob::new(
            RepairJob::from_code(&code, object, &placement.chain, 2, 8, &avail, 2048, block_bytes)
                .unwrap(),
        );
        // a survivor the plan depends on dies between planning and execution:
        // the executor must refuse to lower the plan, not hang
        let (victim, _) = job.job.sources[0];
        cluster.fail_node(victim);
        let err = run_pipelined_repair(&cluster, &backend, &job).unwrap_err();
        assert!(err.to_string().contains("failed"), "unexpected error: {err}");
    });
}

#[test]
fn second_failure_mid_repair_errors_cleanly() {
    with_timeout(60, || {
        // slow NICs (10 MB/s, 2 MiB blocks → ≥ ~840 ms of repair streaming)
        // so a survivor crash injected shortly after dispatch lands while
        // frames are still in flight; the guarded links must break the
        // stream with an error instead of hanging the executor.
        let object = ObjectId(21);
        let (cluster, code, placement, backend) = archived_84(9, object, 2 << 20, 10e6);
        cluster.fail_node(3);
        let (avail, block_bytes) =
            rapidraid::coordinator::survey_coded(&cluster, &placement.chain, object);
        let job = PipelinedRepairJob::new(
            RepairJob::from_code(&code, object, &placement.chain, 3, 8, &avail, 65536, block_bytes)
                .unwrap(),
        );
        let (victim, _) = job.job.sources[0];
        let result = std::thread::scope(|s| {
            s.spawn(|| {
                std::thread::sleep(Duration::from_millis(60));
                cluster.fail_node(victim);
            });
            run_pipelined_repair(&cluster, &backend, &job)
        });
        let err = result.expect_err("repair must fail when a survivor dies mid-stream");
        assert!(err.to_string().contains("failed") || err.to_string().contains("dropped"),
            "unexpected error: {err}");
        // the newcomer must not claim a complete repaired block
        assert!(cluster.node(8).peek(BlockKey::coded(object, 3)).unwrap().is_none());
    });
}

#[test]
fn scheduler_pass_after_crash_restores_decodability() {
    with_timeout(60, || {
        let object = ObjectId(22);
        let (cluster, code, placement, backend) = archived_84(10, object, 16 * 1024, 1e9);
        let blocks: Vec<Vec<u8>> = (0..4)
            .map(|i| rapidraid::coordinator::object_bytes(object, i, 16 * 1024))
            .collect();
        cluster.fail_node(1);
        // degraded read first: reconstruct works around the crash
        let rec = reconstruct(&cluster, &code, &placement.chain, object, &backend).unwrap();
        assert_eq!(rec, blocks);
        // then an eager scheduler pass heals the placement
        let mut placements = [placement];
        let sched = RepairScheduler::new(RepairStrategy::Pipelined, RepairTrigger::Eager);
        let report = sched
            .repair(
                &cluster,
                &code,
                &mut placements,
                &backend,
                &rapidraid::coordinator::FifoPolicy,
                4096,
            )
            .unwrap();
        assert_eq!(report.actions.len(), 1);
        assert_ne!(placements[0].chain[1], 1);
        let rec = reconstruct(&cluster, &code, &placements[0].chain, object, &backend).unwrap();
        assert_eq!(rec, blocks);
    });
}

#[test]
fn congestion_toggle_is_idempotent_and_restores_rates() {
    let cluster = Cluster::start(ClusterSpec::tpc(4));
    let base = cluster.spec().bytes_per_sec;
    let profile = rapidraid::cluster::CongestionSpec::paper_netem();
    for _ in 0..3 {
        cluster.congest(2, &profile);
        assert!((cluster.node(2).up.rate() - profile.bytes_per_sec).abs() < 1.0);
        cluster.uncongest(2);
        assert!((cluster.node(2).up.rate() - base).abs() < 1.0);
    }
}

#[test]
fn mismatched_job_parameters_are_rejected() {
    let cluster = Cluster::start(ClusterSpec::test(8));
    let object = ObjectId(5);
    let placement = ReplicaPlacement::new(object, 4, (0..8).collect()).unwrap();
    ingest_object(&cluster, &placement, 8 * 1024).unwrap();
    let backend = native();
    // parity matrix not m x k
    let job = ClassicalJob {
        object,
        width: Width::W8,
        parity_rows: vec![vec![1, 2, 3]; 4], // k=3 but 4 sources
        source_nodes: vec![0, 1, 2, 3],
        coding_node: 4,
        parity_nodes: vec![4, 5, 6, 7],
        buf_bytes: 2048,
        block_bytes: 8 * 1024,
    };
    assert!(archive_classical(&cluster, &backend, &job).is_err());

    // code/placement mismatch caught at job construction
    let code = RapidRaidCode::<Gf256>::with_seed(6, 4, 3).unwrap();
    assert!(PipelineJob::from_code(&code, &placement, 2048, 8 * 1024).is_err());
}
