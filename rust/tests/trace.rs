//! Integration tests for the deterministic trace layer: a real pipelined
//! archival on a SimClock cluster, observed through a per-clock JSONL
//! session, must (a) serialize byte-identically per seed, (b) leave the
//! virtual timeline untouched relative to an untraced run, (c) export a
//! well-formed Chrome-trace document with monotonic per-track timestamps,
//! and (d) let the critical-path analyzer partition 100% of the plan's
//! makespan across its slots.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use rapidraid::backend::{BackendHandle, NativeBackend};
use rapidraid::clock::{ClockHandle, SimClock};
use rapidraid::cluster::{Cluster, ClusterSpec};
use rapidraid::codes::rapidraid::RapidRaidCode;
use rapidraid::coordinator::{ingest_object, PipelineJob, PlanExecutor};
use rapidraid::gf::Gf256;
use rapidraid::metrics::{parse_json, JsonValue};
use rapidraid::resources::UniformCost;
use rapidraid::storage::{ObjectId, ReplicaPlacement};
use rapidraid::trace::{
    attribute_plans, chrome_trace, install, parse_jsonl, render_attribution, JsonlSink,
};
use rapidraid::util::with_timeout;

const BLOCK: usize = 32 * 1024;
const BUF: usize = 8 * 1024;

/// Pipeline-archive one `(n, k)` object on a fresh SimClock cluster with a
/// non-zero CPU cost model (so `cpu_charge` events carry real costs) and
/// return the plan's virtual makespan.
fn archive_on(n: usize, k: usize, seed: u64, clock: ClockHandle) -> Duration {
    let spec = ClusterSpec::test(n)
        .with_clock(clock)
        .with_cost(Arc::new(UniformCost::calibrated()));
    let cluster = Cluster::start(spec);
    let object = ObjectId(400 + seed);
    let placement = ReplicaPlacement::new(object, k, (0..n).collect()).unwrap();
    ingest_object(&cluster, &placement, BLOCK).unwrap();
    let code = RapidRaidCode::<Gf256>::with_seed(n, k, seed).unwrap();
    let backend: BackendHandle = Arc::new(NativeBackend::new());
    let exec = PlanExecutor::new(&cluster, backend);
    let job = PipelineJob::from_code(&code, &placement, BUF, BLOCK).unwrap();
    exec.run(&job.plan().unwrap()).unwrap()
}

/// [`archive_on`] with a per-clock JSONL session installed for the run.
/// Per-clock filtering keeps concurrently running tests (which own other
/// clocks) out of the returned sink.
fn traced_archival(n: usize, k: usize, seed: u64) -> (Arc<JsonlSink>, Duration) {
    let clock: ClockHandle = SimClock::handle();
    let sink = JsonlSink::shared();
    let guard = install(&clock, sink.clone());
    let makespan = archive_on(n, k, seed, clock);
    drop(guard);
    (sink, makespan)
}

#[test]
fn same_seed_traced_runs_serialize_byte_identically() {
    let ((sink_a, t_a), (sink_b, t_b)) =
        with_timeout(120, || (traced_archival(6, 4, 9), traced_archival(6, 4, 9)));
    let (doc_a, doc_b) = (sink_a.to_jsonl(), sink_b.to_jsonl());
    assert!(!doc_a.is_empty(), "traced run recorded nothing");
    assert_eq!(doc_a, doc_b, "same seed must yield byte-identical JSONL");
    assert_eq!(t_a, t_b, "same seed must yield the same virtual makespan");
    // the archival exercised every dataplane event family
    for ev in [
        "plan_start",
        "plan_end",
        "frame_sent",
        "frame_recvd",
        "nic_stall",
        "cpu_charge",
        "fold_start",
        "fold_end",
        "store_done",
        "queue_depth",
    ] {
        assert!(
            doc_a.contains(&format!("\"ev\":\"{ev}\"")),
            "trace is missing any `{ev}` event"
        );
    }
    // the reader is the serializer's exact inverse
    let parsed = parse_jsonl(&doc_a).unwrap();
    assert_eq!(parsed, sink_a.events(), "JSONL round-trip changed the events");
}

#[test]
fn tracing_does_not_perturb_the_virtual_timeline() {
    // Untraced baseline first, then the identical scenario under a sink:
    // recording must not move a single virtual tick.
    let (untraced, traced) = with_timeout(120, || {
        let untraced = archive_on(6, 4, 9, SimClock::handle());
        (untraced, traced_archival(6, 4, 9))
    });
    assert_eq!(
        untraced, traced.1,
        "installing a trace sink shifted the virtual timeline"
    );
}

#[test]
fn perfetto_export_is_well_formed_and_monotonic_per_track() {
    let (sink, _) = with_timeout(120, || traced_archival(5, 3, 11));
    let events = sink.events();
    assert!(!events.is_empty());
    let doc = chrome_trace(&events);
    let v = parse_json(&doc).unwrap();
    let entries = v
        .get("traceEvents")
        .and_then(JsonValue::as_arr)
        .expect("traceEvents array");
    assert!(entries.len() > 10, "only {} trace entries", entries.len());
    let mut last_ts: BTreeMap<(u64, u64), f64> = BTreeMap::new();
    for e in entries {
        let ph = e.get("ph").and_then(JsonValue::as_str).expect("ph field");
        if ph == "M" {
            continue; // metadata carries no timestamp
        }
        let pid = e.get("pid").and_then(JsonValue::as_u64).expect("pid");
        let tid = e.get("tid").and_then(JsonValue::as_u64).expect("tid");
        let ts = e.get("ts").and_then(JsonValue::as_f64).expect("ts");
        let prev = last_ts.insert((pid, tid), ts).unwrap_or(f64::MIN);
        assert!(
            ts >= prev,
            "track ({pid},{tid}) went backwards: {prev} -> {ts}"
        );
        if ph == "X" {
            let dur = e.get("dur").and_then(JsonValue::as_f64).expect("dur");
            assert!(dur >= 0.0, "negative span duration {dur}");
        }
    }
    // fold frame spans got stitched from their start/end events
    assert!(doc.contains("\"name\":\"fold\""), "no fold spans in export");
    assert!(doc.contains("\"ph\":\"C\""), "no queue-depth counters in export");
}

#[test]
fn critical_path_partitions_full_makespan_on_three_node_chain() {
    let (sink, makespan) = with_timeout(120, || traced_archival(3, 2, 5));
    let events = sink.events();
    let plans = attribute_plans(&events);
    assert_eq!(plans.len(), 1, "expected exactly the one archival plan");
    let p = &plans[0];
    assert_eq!(p.object, 405);
    assert!(p.makespan() > Duration::ZERO);
    assert!(makespan > Duration::ZERO);
    assert!(!p.slots.is_empty(), "plan has no attributed slots");
    for s in &p.slots {
        assert_eq!(
            s.compute + s.transfer + s.wait,
            p.makespan(),
            "slot {} does not account for 100% of the makespan",
            s.node
        );
    }
    // with UniformCost installed and frames on the wire, both compute and
    // transfer must show up somewhere in the partition
    assert!(
        p.slots.iter().any(|s| s.compute > Duration::ZERO),
        "no slot attributed any compute despite a non-zero cost model"
    );
    assert!(
        p.slots.iter().any(|s| s.transfer > Duration::ZERO),
        "no slot attributed any transfer time"
    );
    let table = render_attribution(&plans);
    assert!(table.contains("object=405"), "{table}");
}
