//! Plan/engine equivalence properties (the acceptance gate of the IR
//! refactor): for random (n, k, seed) RapidRAID codes over GF(2^8) and
//! GF(2^16),
//!
//! 1. the *pipelined* plan (chain of Fold steps) and the *classical/atomic*
//!    plan (one Gemm step lowering the same generator matrix, fed by
//!    Source streams, draining into Store steps) produce **byte-identical
//!    codewords** through the one shared PlanExecutor, and
//! 2. decode recovers the object from **every independent k-subset** of
//!    the stored blocks (dependent subsets are correctly rejected).
//!
//! Runs on the native backend unconditionally; the PJRT variant runs when
//! real artifacts exist (the `pjrt` feature + `make artifacts`), otherwise
//! skips with a message — without the feature `PjrtBackend::load` fails by
//! construction.

use std::path::Path;
use std::sync::Arc;

use rapidraid::backend::{BackendHandle, NativeBackend, PjrtBackend, Width};
use rapidraid::cluster::{Cluster, ClusterSpec};
use rapidraid::codes::rapidraid::RapidRaidCode;
use rapidraid::codes::subsets::Combinations;
use rapidraid::codes::DecodeError;
use rapidraid::coordinator::plan::{ArchivalPlan, GemmInput, GemmOutput, StepKind};
use rapidraid::coordinator::{archive_pipeline, ingest_object, PipelineJob, PlanExecutor};
use rapidraid::gf::{Gf256, Gf65536, GfElem, SliceOps};
use rapidraid::storage::{BlockKey, ObjectId, ReplicaPlacement};
use rapidraid::util::prop::forall;

fn width_of<F: GfElem>() -> Width {
    match F::BITS {
        8 => Width::W8,
        16 => Width::W16,
        other => panic!("unsupported width {other}"),
    }
}

fn bytes_to_gf<F: GfElem>(data: &[u8]) -> Vec<F> {
    match F::BITS {
        8 => data.iter().map(|&b| F::from_u32(b as u32)).collect(),
        16 => data
            .chunks_exact(2)
            .map(|p| F::from_u32(u16::from_le_bytes([p[0], p[1]]) as u32))
            .collect(),
        other => panic!("unsupported width {other}"),
    }
}

/// Atomic lowering of a full (non-systematic) generator: one coding node
/// (chain position 0) pulls the k source blocks — block 0 is already local
/// there by RapidRAID's placement — applies all n generator rows in one
/// Gemm step, keeps c_0 locally and streams c_1..c_{n-1} to their chain
/// nodes.
fn atomic_generator_plan<F: GfElem + SliceOps>(
    code: &RapidRaidCode<F>,
    placement: &ReplicaPlacement,
    buf_bytes: usize,
    block_bytes: usize,
) -> ArchivalPlan {
    let (n, k) = (code.n(), code.k());
    let object = placement.object;
    let coding_node = placement.chain[0];
    let rows: Vec<Vec<u32>> = (0..n)
        .map(|i| code.generator().row(i).iter().map(|c| c.to_u32()).collect())
        .collect();
    let inputs: Vec<GemmInput> = (0..k)
        .map(|j| {
            if j == 0 {
                GemmInput::Local(BlockKey::source(object, 0))
            } else {
                GemmInput::Stream
            }
        })
        .collect();
    let outputs: Vec<GemmOutput> = (0..n)
        .map(|i| {
            if i == 0 {
                GemmOutput::Store(BlockKey::coded(object, 0))
            } else {
                GemmOutput::Stream
            }
        })
        .collect();

    let mut plan = ArchivalPlan::new(object, width_of::<F>(), buf_bytes, block_bytes);
    let gemm = plan.add_step(coding_node, StepKind::Gemm { rows, inputs, outputs });
    for j in 1..k {
        // chain position j (< k) holds source block j per the placement
        let s = plan.add_step(
            placement.chain[j],
            StepKind::Source {
                key: BlockKey::source(object, j),
            },
        );
        plan.connect(s, 0, gemm, j);
    }
    for i in 1..n {
        let t = plan.add_step(
            placement.chain[i],
            StepKind::Store {
                key: BlockKey::coded(object, i),
            },
        );
        plan.connect(gemm, i, t, 0);
    }
    plan
}

fn coded_blocks(cluster: &Cluster, placement: &ReplicaPlacement) -> Vec<Vec<u8>> {
    placement
        .chain
        .iter()
        .enumerate()
        .map(|(pos, &node)| {
            (*cluster
                .node(node)
                .peek(BlockKey::coded(placement.object, pos))
                .unwrap()
                .unwrap_or_else(|| panic!("coded block {pos} missing on node {node}")))
            .clone()
        })
        .collect()
}

/// The property itself, generic over field and backend.
fn equivalence_property<F: GfElem + SliceOps>(backend: &BackendHandle, cases: usize, seed: u64) {
    forall(cases, seed, |rng| {
        let k = 3 + rng.below(2) as usize; // 3..=4 keeps C(n,k) enumerable
        let extra = 1 + rng.below(k as u64) as usize; // 1..=k
        let n = (k + extra).min(2 * k);
        let block = 1024 * (1 + rng.below(4) as usize); // 1..4 KiB
        let object = ObjectId(rng.next_u64());
        let code = RapidRaidCode::<F>::with_seed(n, k, rng.next_u64()).unwrap();

        // pipelined plan on cluster A
        let a = Cluster::start(ClusterSpec::test(n));
        let placement = ReplicaPlacement::new(object, k, (0..n).collect()).unwrap();
        let blocks = ingest_object(&a, &placement, block).unwrap();
        let job = PipelineJob::from_code(&code, &placement, 1024, block).unwrap();
        archive_pipeline(&a, backend, &job).unwrap();

        // atomic generator plan on cluster B (same deterministic object)
        let b = Cluster::start(ClusterSpec::test(n));
        let placement_b = ReplicaPlacement::new(object, k, (0..n).collect()).unwrap();
        let blocks_b = ingest_object(&b, &placement_b, block).unwrap();
        assert_eq!(blocks, blocks_b, "deterministic ingest must agree");
        let plan = atomic_generator_plan(&code, &placement_b, 1024, block);
        PlanExecutor::new(&b, backend.clone()).run(&plan).unwrap();

        // 1. byte-identical codewords
        let coded_a = coded_blocks(&a, &placement);
        let coded_b = coded_blocks(&b, &placement_b);
        assert_eq!(coded_a, coded_b, "(n={n},k={k}) plans disagree");

        // 2. decode from every k-subset of the stored blocks
        let obj_gf: Vec<Vec<F>> = blocks.iter().map(|bl| bytes_to_gf::<F>(bl)).collect();
        let mut independent = 0usize;
        for sub in Combinations::new(n, k) {
            let have: Vec<(usize, Vec<F>)> = sub
                .iter()
                .map(|&i| (i, bytes_to_gf::<F>(&coded_a[i])))
                .collect();
            match code.decode(&have) {
                Ok(rec) => {
                    independent += 1;
                    assert_eq!(rec, obj_gf, "(n={n},k={k}) subset {sub:?}");
                }
                Err(DecodeError::DependentSubset { .. }) => {}
                Err(e) => panic!("(n={n},k={k}) subset {sub:?}: unexpected {e:?}"),
            }
        }
        assert!(independent > 0, "(n={n},k={k}) no decodable subset");
    });
}

#[test]
fn classical_and_pipelined_plans_agree_gf8_native() {
    let be: BackendHandle = Arc::new(NativeBackend::new());
    equivalence_property::<Gf256>(&be, 4, 0xA11CE);
}

#[test]
fn classical_and_pipelined_plans_agree_gf16_native() {
    let be: BackendHandle = Arc::new(NativeBackend::new());
    equivalence_property::<Gf65536>(&be, 4, 0xB0B);
}

#[test]
fn classical_and_pipelined_plans_agree_on_pjrt() {
    // Behind the feature gate: without `--features pjrt` (or without real
    // artifacts) the load fails and the property is skipped, mirroring
    // rust/tests/pjrt_runtime.rs.
    match PjrtBackend::load(Path::new("artifacts")) {
        Ok(be) => {
            let be: BackendHandle = Arc::new(be);
            equivalence_property::<Gf256>(&be, 2, 0xCAFE);
            equivalence_property::<Gf65536>(&be, 2, 0xD00D);
        }
        Err(e) => eprintln!("SKIP pjrt equivalence: {e}"),
    }
}
