//! Acceptance test for the virtual-time simulation core: a paper-scale
//! long-run failure trace (50 nodes, ≥ 1000 virtual seconds of seeded
//! crash/revive/congestion over 8 archived objects) must complete in a few
//! wall-clock seconds under the SimClock, with every surviving object
//! still decodable byte-for-byte.

use std::sync::Arc;
use std::time::{Duration, Instant};

use rapidraid::backend::{BackendHandle, NativeBackend};
use rapidraid::resources::NodeProfile;
use rapidraid::workload::{run_long_run, LongRunConfig};

#[test]
fn paper_scale_trace_is_wall_fast_and_lossless() {
    let cfg = LongRunConfig::paper_scale();
    assert_eq!(cfg.nodes, 50);
    assert!(cfg.virtual_secs >= 1000);

    let backend: BackendHandle = Arc::new(NativeBackend::new());
    let wall = Instant::now();
    let report = run_long_run(&cfg, &backend, None).expect("long run");
    let wall = wall.elapsed();

    // ≥ 1000 virtual seconds of cluster life…
    assert!(
        report.virtual_elapsed >= Duration::from_secs(1000),
        "only {:?} virtual",
        report.virtual_elapsed
    );
    // …in under 5 wall seconds: the discrete-event clock never sleeps.
    assert!(
        wall < Duration::from_secs(5),
        "trace took {wall:?} of wall time — virtual clock leaking real waits?"
    );
    // the schedule actually exercised the failure machinery…
    assert!(report.crashes_total >= 3, "{}", report.summary());
    assert!(report.repairs_total >= 1, "{}", report.summary());
    // …and no object was lost.
    assert!(report.all_decodable(), "{}", report.summary());
    assert_eq!(report.epochs.len() as u64, 100);
}

#[test]
fn two_hundred_nodes_one_virtual_hour_with_compute_costs() {
    // Scale acceptance for the resource model: 200 nodes living through a
    // full virtual hour of seeded crash/revive/congestion with
    // heterogeneous CPU costs charged on every data-plane op — still a
    // bounded wall-time run, still lossless.
    let mut cfg = LongRunConfig::paper_scale();
    cfg.nodes = 200;
    cfg.virtual_secs = 3600; // one virtual hour
    cfg.epoch_secs = 60;
    cfg.objects = 8;
    cfg.block_bytes = 64 * 1024;
    cfg.buf_bytes = 16 * 1024;
    cfg.seed = 0xD00D_FEED;
    cfg.profiles = NodeProfile::ec2_mix(); // small/medium/large tiling

    let backend: BackendHandle = Arc::new(NativeBackend::new());
    let wall = Instant::now();
    let report = run_long_run(&cfg, &backend, None).expect("200-node long run");
    let wall = wall.elapsed();

    assert!(
        report.virtual_elapsed >= Duration::from_secs(3600),
        "only {:?} virtual",
        report.virtual_elapsed
    );
    assert_eq!(report.epochs.len(), 60);
    // wall budget: generous for slow CI hosts, but tight enough to catch a
    // virtual clock leaking real waits (3600 real seconds would time out).
    assert!(
        wall < Duration::from_secs(60),
        "200-node virtual hour took {wall:?} of wall time"
    );
    assert!(report.crashes_total >= 3, "{}", report.summary());
    assert!(report.all_decodable(), "{}", report.summary());
}

#[test]
fn smoke_config_runs_one_crash_repair_round() {
    let cfg = LongRunConfig::smoke();
    let backend: BackendHandle = Arc::new(NativeBackend::new());
    let mut log = Vec::new();
    let report = run_long_run(&cfg, &backend, Some(&mut log)).expect("smoke");
    assert!(report.crashes_total >= 1);
    assert!(report.repairs_total >= 1);
    assert!(report.all_decodable(), "{}", report.summary());
    let text = String::from_utf8(log).unwrap();
    assert!(text.contains("epoch"), "{text}");
}
