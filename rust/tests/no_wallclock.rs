//! Grep-enforcement of the virtual-time refactor: no wall-clock primitive
//! may appear in `cluster/`, `coordinator/`, `repair/`, `resources/`,
//! `util/` or `workload/` — all time goes through the `Clock` trait, whose
//! only wall implementation lives in `clock/` (RealClock). A reintroduced
//! `Instant::now()` or `thread::sleep` would silently break SimClock
//! determinism, so this test fails the build instead. (`resources/` is in
//! scope because the `CpuMeter` must charge compute on the cluster clock;
//! `workload/` because its traces are the determinism acceptance surface;
//! `util/` because the bench timer and watchdog sit on the measurement
//! path and must read wall time through `RealClock` only.)

use std::path::{Path, PathBuf};

const FORBIDDEN: &[&str] = &["Instant::now", "thread::sleep", "SystemTime"];
// `rust/src/coordinator` is walked recursively (so `coordinator/topology/`
// is already in scope); the explicit entry pins the topology layer even if
// it ever moves out of the coordinator tree.
const DIRS: &[&str] = &[
    "rust/src/cluster",
    "rust/src/control",
    "rust/src/coordinator",
    "rust/src/coordinator/topology",
    "rust/src/repair",
    "rust/src/resources",
    "rust/src/trace",
    "rust/src/util",
    "rust/src/workload",
];

fn rust_files(dir: &Path, out: &mut Vec<PathBuf>) {
    for entry in std::fs::read_dir(dir).expect("readable source dir") {
        let path = entry.expect("dir entry").path();
        if path.is_dir() {
            rust_files(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

#[test]
fn dataplane_sources_are_free_of_wall_clock_calls() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let mut violations = Vec::new();
    let mut checked = 0usize;
    for dir in DIRS {
        let mut files = Vec::new();
        rust_files(&root.join(dir), &mut files);
        assert!(!files.is_empty(), "{dir} has no Rust sources?");
        for file in files {
            checked += 1;
            let text = std::fs::read_to_string(&file).expect("readable source");
            for (lineno, line) in text.lines().enumerate() {
                for pat in FORBIDDEN {
                    if line.contains(pat) {
                        violations.push(format!(
                            "{}:{}: `{pat}` — use the cluster Clock instead",
                            file.strip_prefix(root).unwrap_or(&file).display(),
                            lineno + 1
                        ));
                    }
                }
            }
        }
    }
    assert!(checked >= 10, "suspiciously few files checked ({checked})");
    assert!(
        violations.is_empty(),
        "wall-clock primitives leaked back into the dataplane:\n{}",
        violations.join("\n")
    );
}
