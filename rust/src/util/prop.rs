//! Micro property-test harness (proptest is unavailable offline).
//!
//! Usage:
//! ```
//! use rapidraid::util::prop::forall;
//! forall(100, 42, |rng| {
//!     let x = rng.below(1000);
//!     assert!(x + 1 > x, "overflow at {x}");
//! });
//! ```
//!
//! On failure the panic message includes the case index and the derived seed
//! so the exact case can be re-run in isolation with [`case`].

use super::rng::SplitMix64;

/// Run `body` for `cases` deterministic pseudo-random cases.  Each case gets
/// an independent PRNG derived from (`seed`, case index), so shrinking a
/// failure to one case is trivial: re-run with [`case`].
pub fn forall(cases: usize, seed: u64, mut body: impl FnMut(&mut SplitMix64)) {
    for i in 0..cases {
        let case_seed = derive(seed, i as u64);
        let mut rng = SplitMix64::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut rng)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property failed at case {i}/{cases} (seed={seed}, case_seed={case_seed}): {msg}"
            );
        }
    }
}

/// Re-run a single failing case by its `case_seed` (from the failure message).
pub fn case(case_seed: u64, body: impl Fn(&mut SplitMix64)) {
    let mut rng = SplitMix64::new(case_seed);
    body(&mut rng);
}

fn derive(seed: u64, idx: u64) -> u64 {
    // One SplitMix64 step over a mixed seed — avoids correlated streams.
    SplitMix64::new(seed ^ idx.wrapping_mul(0x9E3779B97F4A7C15)).next_u64()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_when_property_holds() {
        forall(50, 1, |rng| {
            let x = rng.below(100);
            assert!(x < 100);
        });
    }

    #[test]
    fn reports_case_seed_on_failure() {
        let r = std::panic::catch_unwind(|| {
            forall(50, 2, |rng| {
                let x = rng.below(100);
                assert!(x != 7, "hit the forbidden value");
            })
        });
        // With 50 cases over below(100) we all but surely hit 7; if we did,
        // the panic must carry the replay info.
        if let Err(e) = r {
            let msg = e.downcast_ref::<String>().unwrap();
            assert!(msg.contains("case_seed="), "{msg}");
        }
    }

    #[test]
    fn deterministic_across_runs() {
        let mut seen_a = Vec::new();
        forall(10, 3, |rng| seen_a.push(rng.next_u64()));
        let mut seen_b = Vec::new();
        forall(10, 3, |rng| seen_b.push(rng.next_u64()));
        assert_eq!(seen_a, seen_b);
    }
}
