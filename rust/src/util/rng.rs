//! Deterministic PRNG (SplitMix64) — coefficient search, workload
//! generation and property tests all need reproducible randomness.

/// SplitMix64: tiny, fast, passes BigCrush; perfect for deterministic
/// simulation seeds. Not cryptographic (nothing here needs to be).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed. Equal seeds ⇒ equal streams.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)` (Lemire-style; bound > 0).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        // 128-bit multiply keeps the modulo bias negligible for our uses.
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi);
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fill a byte buffer with deterministic pseudo-random content.
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        let mut chunks = buf.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let w = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&w[..rem.len()]);
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `count` distinct indices from `0..n` (count <= n).
    pub fn sample_indices(&mut self, n: usize, count: usize) -> Vec<usize> {
        assert!(count <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(count);
        idx.sort_unstable();
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = SplitMix64::new(7);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(8);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn fill_bytes_covers_tail() {
        let mut r = SplitMix64::new(9);
        let mut buf = vec![0u8; 13];
        r.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = SplitMix64::new(10);
        let s = r.sample_indices(20, 8);
        assert_eq!(s.len(), 8);
        assert!(s.windows(2).all(|w| w[0] < w[1]));
        assert!(s.iter().all(|&i| i < 20));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(11);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
