//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup + repeated measurement with median/percentile reporting
//! in the same "candle" form the paper's Fig. 4 uses (median, 25–75%
//! percentiles, min–max whiskers).

use std::time::Duration;

use crate::clock::{Clock, RealClock};

/// Summary statistics over repeated runs of a benchmark body.
#[derive(Clone, Debug)]
pub struct Candle {
    /// Benchmark label (appears in reports).
    pub name: String,
    /// All raw samples, sorted ascending.
    pub samples: Vec<Duration>,
}

impl Candle {
    /// Percentile by nearest-rank (q in [0,1]).
    pub fn percentile(&self, q: f64) -> Duration {
        assert!(!self.samples.is_empty());
        let idx = ((self.samples.len() - 1) as f64 * q).round() as usize;
        self.samples[idx]
    }

    /// Median sample.
    pub fn median(&self) -> Duration {
        self.percentile(0.5)
    }

    /// Smallest sample.
    pub fn min(&self) -> Duration {
        self.samples[0]
    }

    /// Largest sample.
    pub fn max(&self) -> Duration {
        *self.samples.last().unwrap()
    }

    /// Arithmetic mean.
    pub fn mean(&self) -> Duration {
        let total: Duration = self.samples.iter().sum();
        total / self.samples.len() as u32
    }

    /// Population standard deviation in seconds.
    pub fn stddev_secs(&self) -> f64 {
        let mean = self.mean().as_secs_f64();
        let var = self
            .samples
            .iter()
            .map(|s| (s.as_secs_f64() - mean).powi(2))
            .sum::<f64>()
            / self.samples.len() as f64;
        var.sqrt()
    }

    /// One-line report: `name  median [p25 p75] (min..max) xN`.
    pub fn report(&self) -> String {
        format!(
            "{:<42} median={:>10.3?} p25={:>10.3?} p75={:>10.3?} min={:>10.3?} max={:>10.3?} n={}",
            self.name,
            self.median(),
            self.percentile(0.25),
            self.percentile(0.75),
            self.min(),
            self.max(),
            self.samples.len()
        )
    }
}

/// Run `body` `samples` times after `warmup` unmeasured runs. Wall time is
/// read through a [`RealClock`] — the only sanctioned wall-time source
/// (`util/` sits inside the no_wallclock grep perimeter).
pub fn bench(name: &str, warmup: usize, samples: usize, mut body: impl FnMut()) -> Candle {
    let wall = RealClock::handle();
    for _ in 0..warmup {
        body();
    }
    let mut out = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = wall.now();
        body();
        out.push(wall.now().saturating_sub(t0));
    }
    out.sort_unstable();
    Candle {
        name: name.to_string(),
        samples: out,
    }
}

/// Measure a single run (for long end-to-end scenarios).
pub fn once(name: &str, body: impl FnOnce()) -> Candle {
    let wall = RealClock::handle();
    let t0 = wall.now();
    body();
    Candle {
        name: name.to_string(),
        samples: vec![wall.now().saturating_sub(t0)],
    }
}

/// Throughput helper: bytes processed per second given a duration.
pub fn throughput_mib_s(bytes: usize, d: Duration) -> f64 {
    bytes as f64 / (1024.0 * 1024.0) / d.as_secs_f64()
}

/// Parse a `u64` knob from the environment, falling back to `default`
/// when unset or malformed — the bench binaries' shared option
/// convention (`BLOCK_KIB`, `SAMPLES`, `SEED`, …).
pub fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn candle_percentiles_ordered() {
        let c = bench("t", 1, 20, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(c.min() <= c.percentile(0.25));
        assert!(c.percentile(0.25) <= c.median());
        assert!(c.median() <= c.percentile(0.75));
        assert!(c.percentile(0.75) <= c.max());
        assert_eq!(c.samples.len(), 20);
    }

    #[test]
    fn throughput_sane() {
        let t = throughput_mib_s(1024 * 1024, Duration::from_secs(1));
        assert!((t - 1.0).abs() < 1e-9);
    }

    #[test]
    fn once_records_single_sample() {
        let c = once("single", || {});
        assert_eq!(c.samples.len(), 1);
        assert!(!c.report().is_empty());
    }
}
