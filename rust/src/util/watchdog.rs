//! Test watchdogs: bound an operation's wall-clock time, or assert a
//! bound on the *virtual* time it consumed.
//!
//! A hang in an error path is itself a bug this repo's failure-injection
//! tests want caught, so every integration test wraps risky operations in
//! [`with_timeout`] instead of trusting the harness' global timeout.
//! [`with_timeout`] is deliberately wall-clock even under a `SimClock`:
//! a deadlocked simulation is exactly the case where virtual time stops
//! advancing, so only a wall deadline can catch it. The complementary
//! [`assert_virtual_within`] bounds how much *simulated* time an operation
//! was allowed to consume — a perf regression guard that is exact and
//! noise-free because virtual elapsed time has no timer jitter.

use std::time::Duration;

use crate::clock::{Clock, ClockHandle};

/// Run `f` on a fresh thread and wait at most `secs` for it: panics with a
/// watchdog message when the deadline passes (the worker thread is leaked —
/// acceptable in a failing test), and propagates a panic inside `f` as a
/// panic here.
pub fn with_timeout<T: Send + 'static>(secs: u64, f: impl FnOnce() -> T + Send + 'static) -> T {
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = tx.send(f());
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Ok(v) => v,
        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
            panic!("operation hung (watchdog fired after {secs}s)")
        }
        // The worker dropped its sender without a value: f panicked.
        Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
            panic!("operation panicked under the watchdog")
        }
    }
}

/// Run `f` and panic unless it consumed at most `limit` of `clock` time.
/// Under a `SimClock` this bounds the operation's simulated duration
/// exactly; under a `RealClock` it degrades to a wall-clock budget check.
pub fn assert_virtual_within<T>(clock: &ClockHandle, limit: Duration, f: impl FnOnce() -> T) -> T {
    let t0 = clock.now();
    let v = f();
    let dt = clock.now().saturating_sub(t0);
    assert!(
        dt <= limit,
        "operation consumed {dt:?} of clock time (budget {limit:?})"
    );
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::SimClock;

    #[test]
    fn returns_value_in_time() {
        assert_eq!(with_timeout(5, || 41 + 1), 42);
    }

    #[test]
    #[should_panic(expected = "watchdog fired")]
    fn fires_on_hang() {
        with_timeout(1, || loop {
            std::thread::sleep(Duration::from_millis(50));
        });
    }

    #[test]
    #[should_panic(expected = "operation panicked")]
    fn propagates_inner_panic() {
        with_timeout(5, || panic!("inner"));
    }

    #[test]
    fn virtual_budget_passes_within_limit() {
        let clock = SimClock::handle();
        let out = assert_virtual_within(&clock, Duration::from_secs(2), || {
            clock.sleep(Duration::from_secs(1));
            42
        });
        assert_eq!(out, 42);
    }

    #[test]
    #[should_panic(expected = "clock time")]
    fn virtual_budget_panics_when_exceeded() {
        let clock = SimClock::handle();
        assert_virtual_within(&clock, Duration::from_millis(10), || {
            clock.sleep(Duration::from_secs(5));
        });
    }
}
