//! Test watchdog: bound an operation's wall-clock time.
//!
//! A hang in an error path is itself a bug this repo's failure-injection
//! tests want caught, so every integration test wraps risky operations in
//! [`with_timeout`] instead of trusting the harness' global timeout.

use std::time::Duration;

/// Run `f` on a fresh thread and wait at most `secs` for it: panics with a
/// watchdog message when the deadline passes (the worker thread is leaked —
/// acceptable in a failing test), and propagates a panic inside `f` as a
/// panic here.
pub fn with_timeout<T: Send + 'static>(secs: u64, f: impl FnOnce() -> T + Send + 'static) -> T {
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = tx.send(f());
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Ok(v) => v,
        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
            panic!("operation hung (watchdog fired after {secs}s)")
        }
        // The worker dropped its sender without a value: f panicked.
        Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
            panic!("operation panicked under the watchdog")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn returns_value_in_time() {
        assert_eq!(with_timeout(5, || 41 + 1), 42);
    }

    #[test]
    #[should_panic(expected = "watchdog fired")]
    fn fires_on_hang() {
        with_timeout(1, || loop {
            std::thread::sleep(Duration::from_millis(50));
        });
    }

    #[test]
    #[should_panic(expected = "operation panicked")]
    fn propagates_inner_panic() {
        with_timeout(5, || panic!("inner"));
    }
}
