//! Test watchdogs: bound an operation's wall-clock time, or assert a
//! bound on the *virtual* time it consumed.
//!
//! A hang in an error path is itself a bug this repo's failure-injection
//! tests want caught, so integration tests wrap risky operations in a
//! watchdog instead of trusting the harness' global timeout. Prefer the
//! clock-aware [`with_timeout_on`]: under a `RealClock` it arms the wall
//! deadline, while under a `SimClock` it runs the operation inline on the
//! calling thread — a simulated run is deterministic, so a wall deadline
//! adds no information, and keeping the caller's thread (and its clock
//! participant state) out of a disposable worker keeps the virtual
//! schedule byte-identical to an unwatched run. [`with_timeout`] remains
//! for operations that are wall-bounded by construction. The complementary
//! [`assert_virtual_within`] bounds how much *simulated* time an operation
//! was allowed to consume — a perf regression guard that is exact and
//! noise-free because virtual elapsed time has no timer jitter.

use std::time::Duration;

use crate::clock::{Clock, ClockHandle};

/// Run `f` on a fresh thread and wait at most `secs` for it: panics with a
/// watchdog message when the deadline passes (the worker thread is leaked —
/// acceptable in a failing test), and propagates a panic inside `f` as a
/// panic here.
pub fn with_timeout<T: Send + 'static>(secs: u64, f: impl FnOnce() -> T + Send + 'static) -> T {
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = tx.send(f());
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Ok(v) => v,
        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
            panic!("operation hung (watchdog fired after {secs}s)")
        }
        // The worker dropped its sender without a value: f panicked.
        Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
            panic!("operation panicked under the watchdog")
        }
    }
}

/// Clock-aware [`with_timeout`]: arms the wall deadline only when `clock`
/// is wall time. Under a `SimClock`, `f` runs inline on the calling thread
/// with no watchdog — the run is deterministic, and moving it onto a
/// worker thread would perturb clock-participant bookkeeping for zero
/// diagnostic value (a deadlocked simulation still trips the harness'
/// global timeout).
pub fn with_timeout_on<T: Send + 'static>(
    clock: &ClockHandle,
    secs: u64,
    f: impl FnOnce() -> T + Send + 'static,
) -> T {
    if clock.as_sim().is_some() {
        f()
    } else {
        with_timeout(secs, f)
    }
}

/// Run `f` and panic unless it consumed at most `limit` of `clock` time.
/// Under a `SimClock` this bounds the operation's simulated duration
/// exactly; under a `RealClock` it degrades to a wall-clock budget check.
pub fn assert_virtual_within<T>(clock: &ClockHandle, limit: Duration, f: impl FnOnce() -> T) -> T {
    let t0 = clock.now();
    let v = f();
    let dt = clock.now().saturating_sub(t0);
    assert!(
        dt <= limit,
        "operation consumed {dt:?} of clock time (budget {limit:?})"
    );
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::SimClock;

    #[test]
    fn returns_value_in_time() {
        assert_eq!(with_timeout(5, || 41 + 1), 42);
    }

    #[test]
    #[should_panic(expected = "watchdog fired")]
    fn fires_on_hang() {
        // wall sleep routed through RealClock: `util/` is covered by the
        // no_wallclock grep, so even tests avoid the raw primitives
        let wall = crate::clock::RealClock::handle();
        with_timeout(1, move || loop {
            wall.sleep(Duration::from_millis(50));
        });
    }

    #[test]
    #[should_panic(expected = "operation panicked")]
    fn propagates_inner_panic() {
        with_timeout(5, || panic!("inner"));
    }

    #[test]
    fn virtual_budget_passes_within_limit() {
        let clock = SimClock::handle();
        let out = assert_virtual_within(&clock, Duration::from_secs(2), || {
            clock.sleep(Duration::from_secs(1));
            42
        });
        assert_eq!(out, 42);
    }

    #[test]
    #[should_panic(expected = "clock time")]
    fn virtual_budget_panics_when_exceeded() {
        let clock = SimClock::handle();
        assert_virtual_within(&clock, Duration::from_millis(10), || {
            clock.sleep(Duration::from_secs(5));
        });
    }

    #[test]
    fn clock_aware_watchdog_runs_sim_inline_and_arms_wall() {
        // SimClock: inline, no worker thread — the virtual sleep works and
        // no wall deadline interferes.
        let sim = SimClock::handle();
        let sim2 = sim.clone();
        let out = with_timeout_on(&sim, 1, move || {
            sim2.sleep(Duration::from_secs(3600)); // an hour of virtual time
            7
        });
        assert_eq!(out, 7);
        assert_eq!(sim.now(), Duration::from_secs(3600));
        // RealClock: delegates to the wall watchdog.
        let wall = crate::clock::RealClock::handle();
        assert_eq!(with_timeout_on(&wall, 5, || 42), 42);
    }
}
