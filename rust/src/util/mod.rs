//! Small self-contained utilities (the offline build has no external
//! rand/criterion/proptest, so the crate carries its own deterministic PRNG,
//! micro property-test harness and bench timer).

pub mod bench;
pub mod prop;
pub mod rng;
pub mod watchdog;

pub use rng::SplitMix64;
pub use watchdog::{assert_virtual_within, with_timeout, with_timeout_on};
