//! Adaptive control plane: measured per-node load folded into placement,
//! shape and repair-sourcing decisions.
//!
//! The paper's EC2 numbers (and Li et al.'s repair-pipelining analysis)
//! show the pipelined makespan is hostage to the slowest participant.
//! This module closes the loop from the observability the dataplane
//! already exposes to the decisions the coordinator makes:
//!
//! * [`LoadSnapshot::take`] freezes every node's load signals at a **plan
//!   boundary** on the cluster clock: in-flight command count
//!   ([`NodeHandle::inflight`](crate::cluster::NodeHandle)), queued
//!   compute ([`CpuMeter::backlog`](crate::resources::CpuMeter::backlog)),
//!   booked NIC wire time in both directions
//!   ([`RateLimiter::backlog`](crate::cluster::RateLimiter::backlog)),
//!   the current NIC rates, and the node's effective GF throughput priced
//!   through the cluster's own [`CostModel`](crate::resources::CostModel).
//!   All of it is pure state reads — no reservation, no sleep, no trace
//!   emit — so taking a snapshot never perturbs the virtual timeline, and
//!   because it happens between dispatches (never concurrently with
//!   workers) the values are a deterministic function of the seed.
//! * [`LoadSnapshot::rank`] orders candidate nodes best-first from those
//!   signals with node-id ascending as the final tie-break, so equal
//!   loads always rank identically across runs and runtimes.
//! * [`LoadSnapshot::predict_makespan`] is the small analytic cost model
//!   behind fanout auto-tuning and straggler-aware repair sourcing: for a
//!   candidate shape + slot binding it walks every root-to-leaf path,
//!   accumulating buffer-granular fill latency plus queued-backlog
//!   start-up delay per hop, and drains the block through the path's
//!   bottleneck seconds-per-byte (NIC direction shared across the slot's
//!   fan streams, or the priced CPU MAC rate, whichever is slower). It is
//!   the same structure `trace::critical` attributes measured makespans
//!   into (per-slot compute / transfer / upstream-wait), which is how the
//!   predictor's weights can be validated against recorded traces.
//! * [`LoadSnapshot::choose_topology`] evaluates candidate shapes
//!   ([`candidate_shapes`]) over the snapshot-ranked pool — slots bound
//!   heaviest-subtree-first via
//!   [`assign_slots`](crate::coordinator::topology::assign_slots), so
//!   measured stragglers sink to leaf slots — and returns the predicted
//!   argmin (first candidate wins ties).
//!
//! [`Adaptation`] gates every consumer: `Off` (the default) must leave
//! the pre-control-plane code paths **bit-for-bit** intact — no snapshot
//! is taken, no ranking changes, byte-identical blocks and tick-identical
//! spans (locked in by `tests/determinism.rs`). `On` runs are themselves
//! deterministic per seed across both execution runtimes, because every
//! snapshot read happens at a quiescent plan boundary.

use std::time::Duration;

use crate::clock::{Clock, Tick};
use crate::cluster::{Cluster, NodeId};
use crate::codes::TopologyShape;
use crate::coordinator::topology::{assign_slots, Topology};
use crate::resources::GfWork;

/// Whether a consumer runs its closed-loop adaptive path or the static
/// pre-control-plane behavior.
#[derive(Copy, Clone, Debug, Default, PartialEq, Eq)]
pub enum Adaptation {
    /// Static behavior: bit-for-bit the pre-control-plane code path (no
    /// snapshots taken, nothing re-ranked).
    #[default]
    Off,
    /// Closed-loop: snapshot at plan boundaries, re-rank, re-shape.
    On,
}

impl Adaptation {
    /// True for [`Adaptation::On`].
    pub fn is_on(self) -> bool {
        self == Adaptation::On
    }

    /// Parse a report/CLI label (`static`/`off` or `adaptive`/`on`).
    pub fn parse(s: &str) -> anyhow::Result<Adaptation> {
        match s {
            "static" | "off" => Ok(Adaptation::Off),
            "adaptive" | "on" => Ok(Adaptation::On),
            other => anyhow::bail!("unknown adaptation {other:?} (static | adaptive)"),
        }
    }

    /// Short label for report tables (`static` / `adaptive`).
    pub fn name(self) -> &'static str {
        match self {
            Adaptation::Off => "static",
            Adaptation::On => "adaptive",
        }
    }
}

impl std::fmt::Display for Adaptation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Reference MAC pass used to price a node's effective GF throughput
/// through the cluster's cost model (1 MiB keeps integer-nanosecond
/// rounding negligible).
const REF_MAC_BYTES: usize = 1 << 20;

/// Nominal sizes the shape predictor uses when the caller has no
/// job-specific ones at hand (shape *ranking* is insensitive to the exact
/// scale; these match the benchmark presets' order of magnitude).
pub const REF_BLOCK_BYTES: usize = 1 << 20;
/// Nominal pipeline buffer size companion of [`REF_BLOCK_BYTES`].
pub const REF_BUF_BYTES: usize = 64 << 10;

/// One node's load signals at the snapshot instant.
#[derive(Clone, Debug)]
pub struct NodeLoad {
    /// The node this row describes.
    pub node: NodeId,
    /// False while the node is crashed (never rank a dead node).
    pub alive: bool,
    /// Data-plane commands currently queued or executing on the node.
    pub inflight: usize,
    /// Queued compute ahead of a new charge ([`crate::resources::CpuMeter::backlog`]).
    pub cpu_backlog: Tick,
    /// Booked uplink wire time ([`crate::cluster::RateLimiter::backlog`]).
    pub up_backlog: Tick,
    /// Booked downlink wire time.
    pub down_backlog: Tick,
    /// Current uplink rate, bytes/second (congestion-clamped).
    pub up_rate: f64,
    /// Current downlink rate, bytes/second.
    pub down_rate: f64,
    /// Effective GF multiply-accumulate throughput in bytes/second, priced
    /// through the cluster's cost model (`f64::INFINITY` under `ZeroCost`:
    /// free compute never bottlenecks a prediction).
    pub mac_bytes_per_sec: f64,
}

impl NodeLoad {
    /// Total queued time ahead of new work on this node (CPU + both NIC
    /// directions) — the "how far behind is this node already" signal.
    pub fn queued(&self) -> Tick {
        self.cpu_backlog + self.up_backlog + self.down_backlog
    }

    /// The slowest of the node's three rates — what throttles a pipeline
    /// hop placed on it.
    pub fn effective_rate(&self) -> f64 {
        self.up_rate.min(self.down_rate).min(self.mac_bytes_per_sec)
    }
}

/// All nodes' load signals, frozen at one plan boundary.
#[derive(Clone, Debug)]
pub struct LoadSnapshot {
    /// Cluster-clock tick the snapshot was taken at.
    pub taken_at: Tick,
    loads: Vec<NodeLoad>,
}

impl LoadSnapshot {
    /// Snapshot every node of `cluster` at the current clock tick. Call
    /// only at plan boundaries (before dispatching, or after a batch
    /// completion) — concurrent workers would make the reads racy under
    /// the threaded runtime and non-deterministic across runtimes.
    pub fn take(cluster: &Cluster) -> LoadSnapshot {
        let model = cluster.cost();
        let ref_work = GfWork::mac(REF_MAC_BYTES);
        let loads = (0..cluster.len())
            .map(|id| {
                let node = cluster.node(id);
                let priced = model.cost(id, &ref_work);
                let mac_bytes_per_sec = if priced.is_zero() {
                    f64::INFINITY
                } else {
                    REF_MAC_BYTES as f64 / priced.as_secs_f64()
                };
                NodeLoad {
                    node: id,
                    alive: !node.is_failed(),
                    inflight: node.inflight(),
                    cpu_backlog: node.cpu.backlog(),
                    up_backlog: node.up.backlog(),
                    down_backlog: node.down.backlog(),
                    up_rate: node.up.rate(),
                    down_rate: node.down.rate(),
                    mac_bytes_per_sec,
                }
            })
            .collect();
        LoadSnapshot {
            taken_at: cluster.clock().now(),
            loads,
        }
    }

    /// The load row for `node`.
    pub fn load(&self, node: NodeId) -> &NodeLoad {
        &self.loads[node]
    }

    /// Number of nodes in the snapshot.
    pub fn len(&self) -> usize {
        self.loads.len()
    }

    /// True when the snapshot holds no nodes.
    pub fn is_empty(&self) -> bool {
        self.loads.is_empty()
    }

    /// Order `candidates` best-first: alive before crashed, then fewest
    /// in-flight commands, then least queued backlog, then fastest
    /// effective rate, then ascending node id — the deterministic
    /// tie-break that keeps equal-load rankings identical across runs and
    /// runtimes.
    pub fn rank(&self, candidates: &[NodeId]) -> Vec<NodeId> {
        let mut out: Vec<NodeId> = candidates.to_vec();
        out.sort_by(|&a, &b| {
            let (la, lb) = (&self.loads[a], &self.loads[b]);
            lb.alive
                .cmp(&la.alive)
                .then(la.inflight.cmp(&lb.inflight))
                .then(la.queued().cmp(&lb.queued()))
                .then(lb.effective_rate().total_cmp(&la.effective_rate()))
                .then(a.cmp(&b))
        });
        out
    }

    /// Predicted makespan of a pipeline over `shape` with `slots[i]`
    /// running position i: the worst root-to-leaf path's fill latency
    /// (one buffer through each hop's bottleneck, plus that slot's queued
    /// backlog) plus the block draining through the path's bottleneck
    /// seconds-per-byte. A slot's bottleneck is the slowest of its shared
    /// NIC directions (fan-in/fan-out streams divide the direction's
    /// rate) and its priced CPU MAC rate.
    pub fn predict_makespan(
        &self,
        shape: &TopologyShape,
        slots: &[NodeId],
        flow: Flow,
        block_bytes: usize,
        buf_bytes: usize,
    ) -> Duration {
        let n = shape.n();
        assert_eq!(slots.len(), n, "need exactly one node per slot");
        let children = shape.children();
        let buf = buf_bytes.min(block_bytes).max(1) as f64;
        let block = block_bytes.max(1) as f64;
        // positions are topologically ordered (parent index < child index
        // in every Topology expansion), so one forward pass accumulates
        // root-to-slot fill latency and the path bottleneck
        let mut fill = vec![0f64; n];
        let mut bottleneck = vec![0f64; n];
        let mut worst = 0f64;
        for i in 0..n {
            let l = self.load(slots[i]);
            let (in_streams, out_streams) = match flow {
                Flow::Diffusion => (usize::from(shape.parent(i).is_some()), children[i].len()),
                Flow::Aggregation => (children[i].len(), 1),
            };
            let down_spb = if in_streams > 0 { in_streams as f64 / l.down_rate } else { 0.0 };
            let up_spb = if out_streams > 0 { out_streams as f64 / l.up_rate } else { 0.0 };
            let cpu_spb = 1.0 / l.mac_bytes_per_sec; // 0.0 under ZeroCost
            let per_byte = down_spb.max(up_spb).max(cpu_spb);
            let (parent_fill, parent_bn) = match shape.parent(i) {
                Some(p) => (fill[p], bottleneck[p]),
                None => (0.0, 0.0),
            };
            fill[i] = parent_fill + l.queued().as_secs_f64() + per_byte * buf;
            bottleneck[i] = parent_bn.max(per_byte);
            worst = worst.max(fill[i] + bottleneck[i] * block);
        }
        Duration::from_secs_f64(worst)
    }

    /// Pick the predicted-fastest shape for an n-position pipeline over
    /// `pool`: ranks the pool, binds the top n to each candidate's slots
    /// (heaviest subtree first, so measured stragglers sink to leaves),
    /// and returns the argmin with its binding and predicted makespan.
    /// Ties keep the earliest candidate — deterministic by construction.
    pub fn choose_topology(
        &self,
        pool: &[NodeId],
        n: usize,
        candidates: &[Topology],
        flow: Flow,
        block_bytes: usize,
        buf_bytes: usize,
    ) -> anyhow::Result<(Topology, Vec<NodeId>, Duration)> {
        anyhow::ensure!(
            pool.len() >= n,
            "need {n} pipeline nodes, only {} candidates",
            pool.len()
        );
        anyhow::ensure!(!candidates.is_empty(), "no candidate shapes to choose from");
        let ranked = self.rank(pool);
        let top = &ranked[..n];
        let mut best: Option<(Topology, Vec<NodeId>, Duration)> = None;
        for &topo in candidates {
            let shape = topo.shape(n)?;
            let slots = assign_slots(&shape, top);
            let predicted = self.predict_makespan(&shape, &slots, flow, block_bytes, buf_bytes);
            if best.as_ref().is_none_or(|(_, _, t)| predicted < *t) {
                best = Some((topo, slots, predicted));
            }
        }
        Ok(best.expect("candidates is non-empty"))
    }
}

/// Which way payload moves through a shape — encode pipelines diffuse
/// from the root outward (interior slots fan *out*), repair aggregation
/// flows leaf-to-root (interior slots fan *in*).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Flow {
    /// Encode: root sources, every slot forwards to its children.
    Diffusion,
    /// Repair: leaves source, every slot combines its children's streams.
    Aggregation,
}

/// The shape families the auto-tuner weighs against each other: the
/// traffic-optimal chain, a fanout-f tree (short tail, duplicated
/// uplinks) and the half-chain hybrid between them. Degenerate n keeps
/// just the chain.
pub fn candidate_shapes(n: usize, fanout: usize) -> Vec<Topology> {
    let mut shapes = vec![Topology::Chain];
    if n >= 3 {
        shapes.push(Topology::Tree {
            fanout: fanout.max(1),
        });
        shapes.push(Topology::Hybrid {
            chain_prefix: (n / 2).max(1),
            tree_fanout: fanout.max(1),
        });
    }
    shapes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterSpec, CongestionSpec};
    use crate::resources::NodeProfile;

    fn sim_cluster(nodes: usize) -> Cluster {
        Cluster::start(ClusterSpec::test(nodes).sim())
    }

    #[test]
    fn snapshot_of_idle_cluster_is_uniform_and_ranks_by_id() {
        let cluster = sim_cluster(5);
        let snap = LoadSnapshot::take(&cluster);
        assert_eq!(snap.len(), 5);
        assert_eq!(snap.taken_at, Tick::ZERO);
        for id in 0..5 {
            let l = snap.load(id);
            assert!(l.alive);
            assert_eq!(l.inflight, 0);
            assert_eq!(l.queued(), Tick::ZERO);
            assert_eq!(l.mac_bytes_per_sec, f64::INFINITY, "ZeroCost prices free");
        }
        // equal loads: the node-id tie-break keeps ranking deterministic
        assert_eq!(snap.rank(&[4, 2, 0, 3, 1]), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn rank_sinks_congested_crashed_and_slow_nodes() {
        let spec = ClusterSpec::test(6)
            .sim()
            .with_profiles(vec![
                NodeProfile::EC2_SMALL,
                NodeProfile::EC2_SMALL,
                NodeProfile::EC2_SMALL,
                NodeProfile::THINCLIENT, // node 3: slow CPU
                NodeProfile::EC2_SMALL,
                NodeProfile::EC2_SMALL,
            ])
            .unwrap();
        let cluster = Cluster::start(spec);
        cluster.congest(
            1,
            &CongestionSpec {
                bytes_per_sec: 1e6,
                extra_latency: Duration::ZERO,
                jitter: Duration::ZERO,
            },
        );
        cluster.fail_node(4);
        let snap = LoadSnapshot::take(&cluster);
        let ranked = snap.rank(&[0, 1, 2, 3, 4, 5]);
        assert_eq!(ranked[5], 4, "crashed node ranks dead last");
        assert!(!snap.load(4).alive);
        let pos = |id: NodeId| ranked.iter().position(|&r| r == id).unwrap();
        assert!(pos(1) > pos(0), "congested node sinks below clean ones");
        assert!(pos(3) > pos(0), "slow-CPU node sinks below clean ones");
        assert!(
            snap.load(3).mac_bytes_per_sec < snap.load(0).mac_bytes_per_sec,
            "THINCLIENT prices slower through the cost model"
        );
    }

    #[test]
    fn predictor_prefers_chain_on_uniform_pool() {
        let cluster = sim_cluster(8);
        let snap = LoadSnapshot::take(&cluster);
        let pool: Vec<NodeId> = (0..8).collect();
        let (topo, slots, predicted) = snap
            .choose_topology(
                &pool,
                8,
                &candidate_shapes(8, 2),
                Flow::Diffusion,
                REF_BLOCK_BYTES,
                REF_BUF_BYTES,
            )
            .unwrap();
        assert_eq!(
            topo,
            Topology::Chain,
            "uniform idle pool keeps the traffic-optimal chain"
        );
        assert_eq!(slots, pool);
        assert!(predicted > Duration::ZERO);
    }

    #[test]
    fn predictor_switches_shape_and_sinks_straggler_when_pool_is_tight() {
        let cluster = sim_cluster(8);
        // node 6 clamped 20x: with pool == n it cannot be avoided, so the
        // tuner should pick a branching shape and leaf the straggler
        cluster.congest(
            6,
            &CongestionSpec {
                bytes_per_sec: 5e7,
                extra_latency: Duration::ZERO,
                jitter: Duration::ZERO,
            },
        );
        let snap = LoadSnapshot::take(&cluster);
        let pool: Vec<NodeId> = (0..8).collect();
        let shapes = candidate_shapes(8, 2);
        let (topo, slots, _) = snap
            .choose_topology(&pool, 8, &shapes, Flow::Diffusion, REF_BLOCK_BYTES, REF_BUF_BYTES)
            .unwrap();
        assert_ne!(topo, Topology::Chain, "tight pool with a straggler must branch");
        let shape = topo.shape(8).unwrap();
        let slot = slots.iter().position(|&v| v == 6).unwrap();
        assert!(
            shape.children()[slot].is_empty(),
            "the clamped node must sit on a leaf slot: {slots:?}"
        );
        // and the chain prediction is strictly worse than the winner's
        let chain_shape = Topology::Chain.shape(8).unwrap();
        let ranked = snap.rank(&pool);
        let chain_t = snap.predict_makespan(
            &chain_shape,
            &assign_slots(&chain_shape, &ranked[..8]),
            Flow::Diffusion,
            REF_BLOCK_BYTES,
            REF_BUF_BYTES,
        );
        let win_t =
            snap.predict_makespan(&shape, &slots, Flow::Diffusion, REF_BLOCK_BYTES, REF_BUF_BYTES);
        assert!(win_t < chain_t, "winner {win_t:?} must beat chain {chain_t:?}");
    }

    #[test]
    fn prediction_is_a_pure_function_of_the_snapshot() {
        let cluster = sim_cluster(6);
        cluster.congest(
            2,
            &CongestionSpec {
                bytes_per_sec: 1e7,
                extra_latency: Duration::ZERO,
                jitter: Duration::ZERO,
            },
        );
        let snap = LoadSnapshot::take(&cluster);
        let pool: Vec<NodeId> = (0..6).collect();
        let shapes = candidate_shapes(6, 2);
        let a = snap
            .choose_topology(&pool, 6, &shapes, Flow::Aggregation, 1 << 20, 1 << 16)
            .unwrap();
        let b = snap
            .choose_topology(&pool, 6, &shapes, Flow::Aggregation, 1 << 20, 1 << 16)
            .unwrap();
        assert_eq!(a.0, b.0);
        assert_eq!(a.1, b.1);
        assert_eq!(a.2, b.2);
    }

    #[test]
    fn adaptation_labels_roundtrip() {
        assert_eq!(Adaptation::default(), Adaptation::Off);
        assert!(!Adaptation::Off.is_on());
        assert!(Adaptation::On.is_on());
        for a in [Adaptation::Off, Adaptation::On] {
            assert_eq!(Adaptation::parse(a.name()).unwrap(), a);
        }
        assert_eq!(Adaptation::parse("on").unwrap(), Adaptation::On);
        assert_eq!(Adaptation::parse("off").unwrap(), Adaptation::Off);
        assert!(Adaptation::parse("maybe").is_err());
    }
}
