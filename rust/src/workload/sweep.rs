//! `rapidraid sweep`: grid a long-run failure trace over repair triggers ×
//! chain policies × CPU cost profiles × pipeline topologies and print a
//! comparison table.
//!
//! Every cell of the grid is one full [`run_long_run`] trace (same seed,
//! same crash/revive/congestion schedule — the schedule is a fixed
//! function of the seed, so the cells are directly comparable) with the
//! trigger, the newcomer-ranking policy, the per-node compute profiles
//! and the archival/repair pipeline shape swapped. This is ROADMAP's
//! "sweep repair schedules / placement policies over long traces", with
//! the resource model and the topology as further axes: a repair schedule
//! that looks fine on free compute can lose its margin when the newcomers
//! are the slow nodes, and a chain that looks fine on uniform hardware
//! loses to a tree once stragglers appear.

use std::io::Write;
use std::time::Duration;

use crate::backend::BackendHandle;
use crate::clock::{Clock, RealClock};
use crate::coordinator::engine::PolicyKind;
use crate::coordinator::topology::Topology;
use crate::metrics::{BenchJson, Candle};
use crate::repair::RepairTrigger;
use crate::resources::NodeProfile;

use super::{run_long_run, LongRunConfig, LongRunReport};

/// The sweep grid: a base trace plus the axes to vary.
#[derive(Clone, Debug)]
pub struct SweepConfig {
    /// Trace every cell runs (seed, scale, failure rates).
    pub base: LongRunConfig,
    /// Repair triggers to sweep.
    pub triggers: Vec<RepairTrigger>,
    /// Chain/newcomer ranking policies to sweep.
    pub policies: Vec<PolicyKind>,
    /// Named CPU profile mixes to sweep (empty mix = free compute).
    pub profiles: Vec<(&'static str, Vec<NodeProfile>)>,
    /// Pipeline shapes to sweep (archival and pipelined repair both use
    /// the cell's shape).
    pub topologies: Vec<Topology>,
}

impl SweepConfig {
    /// The full default grid: Eager / Lazy(2) / ReliabilityBudget(2×9)
    /// triggers × Fifo / CongestionAware / Adaptive policies × free /
    /// uniform / heterogeneous compute × chain / tree:2 shapes — 54
    /// traces. The Adaptive column is the control-plane axis: same
    /// schedule as its static neighbors, but newcomer ranking and repair
    /// sourcing read plan-boundary [`LoadSnapshot`](crate::control::LoadSnapshot)s.
    pub fn default_grid(base: LongRunConfig) -> Self {
        Self {
            base,
            triggers: vec![
                RepairTrigger::Eager,
                RepairTrigger::Lazy { min_missing: 2 },
                RepairTrigger::ReliabilityBudget {
                    min_nines: 2,
                    p_node: 0.05,
                },
            ],
            policies: vec![
                PolicyKind::Fifo,
                PolicyKind::CongestionAware,
                PolicyKind::Adaptive,
            ],
            profiles: vec![
                ("free", Vec::new()),
                ("uniform", vec![NodeProfile::EC2_SMALL]),
                ("ec2-mix", NodeProfile::ec2_mix()),
            ],
            topologies: vec![Topology::Chain, Topology::Tree { fanout: 2 }],
        }
    }

    /// CI smoke grid: one trigger, all three policies (static pair +
    /// adaptive), free vs heterogeneous compute, chain vs tree — 12 short
    /// traces.
    pub fn smoke() -> Self {
        let mut grid = Self::default_grid(LongRunConfig::smoke());
        grid.triggers = vec![RepairTrigger::Eager];
        grid.profiles = vec![("free", Vec::new()), ("ec2-mix", NodeProfile::ec2_mix())];
        grid
    }
}

/// One completed cell of the grid.
#[derive(Debug)]
pub struct SweepRow {
    /// Trigger of this cell.
    pub trigger: RepairTrigger,
    /// Policy of this cell.
    pub policy: PolicyKind,
    /// Profile-mix label of this cell.
    pub cost: &'static str,
    /// Pipeline shape of this cell.
    pub topology: Topology,
    /// The trace's outcome.
    pub report: LongRunReport,
    /// Wall time the cell took.
    pub wall: Duration,
}

/// Run the whole grid, printing one table row per cell as it completes.
/// Returns the rows plus a machine-readable twin (`BENCH_sweep.json`
/// material: one single-sample virtual-elapsed series per cell).
pub fn run_sweep(
    cfg: &SweepConfig,
    backend: &BackendHandle,
    out: &mut dyn Write,
) -> anyhow::Result<(Vec<SweepRow>, BenchJson)> {
    anyhow::ensure!(
        !cfg.triggers.is_empty()
            && !cfg.policies.is_empty()
            && !cfg.profiles.is_empty()
            && !cfg.topologies.is_empty(),
        "sweep grid has an empty axis"
    );
    let wall = RealClock::new();
    let cells =
        cfg.triggers.len() * cfg.policies.len() * cfg.profiles.len() * cfg.topologies.len();
    let policies = cfg
        .policies
        .iter()
        .map(|p| p.name())
        .collect::<Vec<_>>()
        .join(",");
    let mut json = BenchJson::new("sweep")
        .param("nodes", cfg.base.nodes)
        .param("objects", cfg.base.objects)
        .param("virtual_secs", cfg.base.virtual_secs)
        .param("seed", cfg.base.seed)
        .param("cells", cells)
        .param("policies", policies)
        .param("runtime", cfg.base.runtime.name());
    writeln!(
        out,
        "# sweep — {} nodes, {} objects, {} virtual secs per cell, seed {}",
        cfg.base.nodes, cfg.base.objects, cfg.base.virtual_secs, cfg.base.seed
    )?;
    writeln!(
        out,
        "{:>18} {:>17} {:>8} {:>10} {:>8} {:>8} {:>9} {:>8} {:>10} {:>8}",
        "trigger", "policy", "cost", "topology", "crashes", "repairs", "deferred", "missing", "decodable", "wall_s"
    )?;
    let mut rows = Vec::new();
    for &trigger in &cfg.triggers {
        for &policy in &cfg.policies {
            for (cost, profiles) in &cfg.profiles {
                for &topology in &cfg.topologies {
                    let cost = *cost;
                    let mut cell = cfg.base.clone();
                    cell.trigger = trigger;
                    cell.policy = policy;
                    cell.profiles = profiles.clone();
                    cell.topology = topology;
                    let t0 = wall.now();
                    let report = run_long_run(&cell, backend, None)?;
                    let cell_wall = wall.now().saturating_sub(t0);
                    let deferred: usize = report.epochs.iter().map(|e| e.deferred).sum();
                    writeln!(
                        out,
                        "{:>18} {:>17} {:>8} {:>10} {:>8} {:>8} {:>9} {:>8} {:>7}/{:<2} {:>8.2}",
                        trigger.to_string(),
                        policy.name(),
                        cost,
                        topology.to_string(),
                        report.crashes_total,
                        report.repairs_total,
                        deferred,
                        report.final_missing,
                        report.objects_decodable,
                        report.objects_total,
                        cell_wall.as_secs_f64(),
                    )?;
                    json.series.push(Candle {
                        name: format!("{trigger}/{}/{cost}/{topology}", policy.name()),
                        samples: vec![report.virtual_elapsed],
                    });
                    rows.push(SweepRow {
                        trigger,
                        policy,
                        cost,
                        topology,
                        report,
                        wall: cell_wall,
                    });
                }
            }
        }
    }
    json.wall = wall.now();
    Ok((rows, json))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::repair::RepairStrategy;
    use std::sync::Arc;

    fn tiny_base() -> LongRunConfig {
        LongRunConfig {
            nodes: 12,
            n: 8,
            k: 4,
            code_seed: 7,
            objects: 2,
            block_bytes: 8 * 1024,
            buf_bytes: 2 * 1024,
            virtual_secs: 30,
            epoch_secs: 10,
            seed: 42,
            p_crash: 1.0,
            p_congest: 0.0,
            max_down: 1,
            revive_after_epochs: 2,
            strategy: RepairStrategy::Pipelined,
            trigger: RepairTrigger::Eager,
            max_concurrent_repairs: 2,
            policy: PolicyKind::CongestionAware,
            profiles: Vec::new(),
            p_cpu_churn: 0.0,
            topology: Topology::Chain,
            calibration: None,
            runtime: crate::cluster::RuntimeKind::Auto,
        }
    }

    #[test]
    fn tiny_grid_covers_every_cell_losslessly() {
        let backend: BackendHandle = Arc::new(NativeBackend::new());
        let mut grid = SweepConfig::default_grid(tiny_base());
        // keep the test quick: 1 trigger × 3 policies × 2 costs × 2 shapes
        grid.triggers = vec![RepairTrigger::Eager];
        grid.profiles = vec![("free", Vec::new()), ("ec2-mix", NodeProfile::ec2_mix())];
        let mut out = Vec::new();
        let (rows, json) = run_sweep(&grid, &backend, &mut out).unwrap();
        assert_eq!(rows.len(), 12);
        for r in &rows {
            assert!(r.report.all_decodable(), "{}", r.report.summary());
            assert!(r.report.crashes_total >= 1);
        }
        assert!(rows.iter().any(|r| r.topology == Topology::Tree { fanout: 2 }));
        assert!(rows.iter().any(|r| r.policy == PolicyKind::Adaptive));
        assert_eq!(json.series.len(), 12);
        assert!(json
            .params
            .iter()
            .any(|(k, v)| k == "policies" && v.contains("adaptive")));
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("eager") && text.contains("congestion-aware"), "{text}");
        assert!(text.contains("adaptive"), "{text}");
        assert!(text.contains("ec2-mix"));
        assert!(text.contains("tree:2") && text.contains("chain"), "{text}");
    }

    #[test]
    fn empty_axis_is_rejected() {
        let backend: BackendHandle = Arc::new(NativeBackend::new());
        let mut grid = SweepConfig::default_grid(tiny_base());
        grid.policies.clear();
        assert!(run_sweep(&grid, &backend, &mut Vec::<u8>::new()).is_err());
    }
}
