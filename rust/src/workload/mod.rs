//! Long-run workload harness: a seeded crash/revive/congestion schedule
//! driving batch archival + the [`RepairScheduler`] over thousands of
//! *virtual* seconds.
//!
//! This is the payoff of the [`crate::clock`] refactor: the identical
//! cluster, codes and repair machinery that the paper-faithful wall-clock
//! benchmarks use — nothing is mocked — run here on a [`SimClock`], so a
//! 50-node cluster living through a multi-minute failure trace (the
//! regime XORing Elephants shows the interesting reliability questions
//! live in) costs milliseconds of wall time and is reproducible from a
//! single seed.
//!
//! Shape of a run:
//!
//! 1. ingest + pipeline-archive `objects` RapidRAID objects on rotated
//!    chains, then drop the source replicas (archival is the only
//!    redundancy, as after a completed migration);
//! 2. per epoch (fixed virtual length): revive nodes whose outage ended,
//!    maybe crash-stop a node (never beyond what repair can absorb —
//!    see [`LongRunConfig::max_down`]), churn one congestion profile,
//!    then run a scheduler pass and record an [`EpochStats`];
//! 3. finally decode every object (degraded reads allowed) and compare
//!    byte-for-byte against the ingested originals.

use std::io::Write;
use std::time::Duration;

use std::sync::Arc;

use crate::backend::BackendHandle;
use crate::clock::{Clock, SimClock};
use crate::cluster::{Cluster, ClusterSpec, CongestionSpec, NodeId, RuntimeKind};
use crate::codes::rapidraid::RapidRaidCode;
use crate::codes::{CodeView, TopologyCode};
use crate::coordinator::batch::{pipeline_jobs, rotated_chain, run_batch};
use crate::coordinator::decode::survey_coded;
use crate::coordinator::engine::PolicyKind;
use crate::coordinator::ingest::ingest_object;
use crate::coordinator::reconstruct;
use crate::coordinator::topology::Topology;
use crate::gf::Gf256;
use crate::repair::{RepairScheduler, RepairStrategy, RepairTrigger};
use crate::resources::{CostModelHandle, NodeProfile, ProfileCost, UniformCost};
use crate::storage::{BlockKey, ObjectId, ReplicaPlacement};
use crate::util::SplitMix64;

pub mod sweep;

pub use sweep::{run_sweep, SweepConfig, SweepRow};

/// Configuration of one long-run trace.
#[derive(Clone, Debug)]
pub struct LongRunConfig {
    /// Cluster size (the paper's deployment scale: 50 ThinClients).
    pub nodes: usize,
    /// Code length per object.
    pub n: usize,
    /// Message length per object.
    pub k: usize,
    /// Coefficient-search seed of the (n, k) RR8 code.
    pub code_seed: u64,
    /// Number of archived objects under test.
    pub objects: usize,
    /// Bytes per source block.
    pub block_bytes: usize,
    /// Network frame size.
    pub buf_bytes: usize,
    /// Total virtual runtime of the schedule, seconds.
    pub virtual_secs: u64,
    /// Virtual length of one epoch, seconds.
    pub epoch_secs: u64,
    /// Seed of the crash/revive/congestion schedule.
    pub seed: u64,
    /// Per-epoch probability of a crash attempt.
    pub p_crash: f64,
    /// Per-epoch probability of toggling the congestion profile.
    pub p_congest: f64,
    /// Cap on simultaneously crashed nodes. Crashes are also refused when
    /// any object would drop below `k + 1` decodable survivors, so a
    /// seeded schedule can never (by construction) lose data the final
    /// verification would miss.
    pub max_down: usize,
    /// Outage length: a crashed node revives after this many epochs.
    pub revive_after_epochs: u64,
    /// Repair planner used by every pass.
    pub strategy: RepairStrategy,
    /// Repair trigger policy.
    pub trigger: RepairTrigger,
    /// Concurrent-repair bound of the scheduler.
    pub max_concurrent_repairs: usize,
    /// Chain/newcomer ranking policy (ingest placement is fixed by the
    /// rotated layout; this drives repair newcomer selection —
    /// [`PolicyKind::Adaptive`] additionally turns on the scheduler's
    /// straggler-aware repair sourcing, see
    /// [`RepairScheduler::adaptation`](crate::repair::RepairScheduler)).
    pub policy: PolicyKind,
    /// Per-node CPU profiles: empty = free compute (`ZeroCost`, the PR 3
    /// behavior); one entry = uniform hardware at that speed; several =
    /// heterogeneous mix, node i charged as `profiles[i % len]` over the
    /// calibrated `UniformCost` baseline — long traces then exercise
    /// compute stragglers, not just congested NICs.
    pub profiles: Vec<NodeProfile>,
    /// Per-epoch probability of toggling a CPU-profile override: one
    /// roaming node is re-priced as a `THINCLIENT`-class straggler (then
    /// restored on the next toggle), exercising placement re-ranking
    /// mid-trace the way netem churn does. The toggle schedule (and its
    /// rng draws) advances even when `profiles` is empty — the override
    /// is then a pricing no-op but sweep cells with and without cost
    /// models keep identical crash/congestion schedules per seed.
    pub p_cpu_churn: f64,
    /// Pipeline shape used for every archival AND every pipelined repair
    /// of the trace; decode verification runs through the matching
    /// topology-composed generator.
    pub topology: Topology,
    /// Measured compute rates replacing the EC2-era `UniformCost`
    /// baseline: `None` keeps the default behavior (free compute without
    /// profiles, `UniformCost::calibrated()` under them); `Some(rates)` —
    /// typically [`crate::resources::UniformCost::from_measured`] over a
    /// `gf-hotpath` bench report — prices compute at this machine's
    /// throughput, both as the uniform model and as the baseline profiles
    /// scale over.
    pub calibration: Option<UniformCost>,
    /// Execution runtime the cluster is driven with
    /// ([`RuntimeKind::Auto`] resolves to the multiplexed fast path under
    /// the trace's `SimClock`; `Threaded` forces the thread-per-node
    /// dataplane for parity runs).
    pub runtime: RuntimeKind,
}

impl LongRunConfig {
    /// Paper-scale trace: 50 nodes, 8 × (16,11) objects, ≥ 1000 virtual
    /// seconds of crash/revive/congestion in 10-second epochs. Finishes in
    /// well under 5 s of wall clock on a laptop-class host.
    pub fn paper_scale() -> Self {
        Self {
            nodes: 50,
            n: 16,
            k: 11,
            code_seed: 5,
            objects: 8,
            block_bytes: 128 * 1024,
            buf_bytes: 32 * 1024,
            virtual_secs: 1000,
            epoch_secs: 10,
            seed: 0xC0FF_EE00,
            p_crash: 0.4,
            p_congest: 0.25,
            max_down: 2,
            revive_after_epochs: 3,
            strategy: RepairStrategy::Pipelined,
            trigger: RepairTrigger::Eager,
            max_concurrent_repairs: 4,
            policy: PolicyKind::CongestionAware,
            profiles: Vec::new(),
            p_cpu_churn: 0.25,
            topology: Topology::Chain,
            calibration: None,
            runtime: RuntimeKind::Auto,
        }
    }

    /// CI smoke: same 50-node / 8-object scale, but a single guaranteed
    /// crash + repair round over a handful of epochs.
    pub fn smoke() -> Self {
        Self {
            virtual_secs: 30,
            p_crash: 1.0,
            p_congest: 0.0,
            max_down: 1,
            p_cpu_churn: 0.0,
            ..Self::paper_scale()
        }
    }

    /// Substitute the per-node CPU profile mix (see
    /// [`LongRunConfig::profiles`]).
    pub fn with_profiles(mut self, profiles: Vec<NodeProfile>) -> Self {
        self.profiles = profiles;
        self
    }

    /// Substitute the pipeline shape (see [`LongRunConfig::topology`]).
    pub fn with_topology(mut self, topology: Topology) -> Self {
        self.topology = topology;
        self
    }

    /// Price compute with measured rates (see
    /// [`LongRunConfig::calibration`]).
    pub fn with_calibration(mut self, rates: UniformCost) -> Self {
        self.calibration = Some(rates);
        self
    }

    /// Substitute the execution runtime (see [`LongRunConfig::runtime`]).
    pub fn with_runtime(mut self, runtime: RuntimeKind) -> Self {
        self.runtime = runtime;
        self
    }
}

/// What one epoch of the schedule did and observed.
#[derive(Clone, Debug, Default)]
pub struct EpochStats {
    /// Epoch index.
    pub epoch: u64,
    /// Virtual time at the epoch's start (since the run began).
    pub at: Duration,
    /// Nodes crash-stopped this epoch.
    pub crashed: Vec<NodeId>,
    /// Nodes revived this epoch.
    pub revived: Vec<NodeId>,
    /// Node whose congestion profile was toggled on, if any.
    pub congested: Option<NodeId>,
    /// Node whose congestion profile was toggled off, if any.
    pub uncongested: Option<NodeId>,
    /// Node re-priced as a CPU straggler this epoch, if any.
    pub cpu_churned: Option<NodeId>,
    /// Node whose CPU-profile override was restored this epoch, if any.
    pub cpu_restored: Option<NodeId>,
    /// Blocks successfully repaired by this epoch's scheduler pass.
    pub repaired: usize,
    /// Repairs that failed at execution (retried next pass).
    pub repair_failures: usize,
    /// Objects deferred by the trigger policy.
    pub deferred: usize,
    /// Objects the pass could not plan a repair for.
    pub unschedulable: usize,
    /// Coded blocks still missing across all objects after the pass.
    pub missing_after: usize,
}

/// Outcome of a whole long-run trace.
#[derive(Clone, Debug)]
pub struct LongRunReport {
    /// Per-epoch observations, in order.
    pub epochs: Vec<EpochStats>,
    /// Total virtual time the schedule covered.
    pub virtual_elapsed: Duration,
    /// Total blocks repaired across all passes.
    pub repairs_total: usize,
    /// Total crash events injected.
    pub crashes_total: usize,
    /// Objects that decoded byte-identically at the end.
    pub objects_decodable: usize,
    /// Objects under test.
    pub objects_total: usize,
    /// Coded blocks still missing at the end (after the final pass).
    pub final_missing: usize,
}

impl LongRunReport {
    /// True iff every object survived the whole schedule.
    pub fn all_decodable(&self) -> bool {
        self.objects_decodable == self.objects_total
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{} epochs / {:?} virtual: {} crashes, {} repairs, {}/{} objects decodable, {} blocks missing",
            self.epochs.len(),
            self.virtual_elapsed,
            self.crashes_total,
            self.repairs_total,
            self.objects_decodable,
            self.objects_total,
            self.final_missing
        )
    }
}

/// Would crash-stopping `pick` leave every object with a decodable margin?
/// Requires ≥ k+1 surviving blocks *and* an independent k-subset per
/// object after the hypothetical crash.
fn safe_to_crash(
    cluster: &Cluster,
    code: &TopologyCode<Gf256>,
    placements: &[ReplicaPlacement],
    pick: NodeId,
) -> bool {
    placements.iter().all(|p| {
        let (avail, _) = survey_coded(cluster, &p.chain, p.object);
        let remaining: Vec<usize> = avail
            .into_iter()
            .filter(|&pos| p.chain[pos] != pick)
            .collect();
        remaining.len() > p.k && code.find_decodable_subset(&remaining).is_some()
    })
}

/// Run one long-run trace on a fresh `SimClock` cluster. Per-epoch lines
/// go to `out` when given; the returned report carries everything a test
/// or harness needs to assert on.
pub fn run_long_run(
    cfg: &LongRunConfig,
    backend: &BackendHandle,
    mut out: Option<&mut dyn Write>,
) -> anyhow::Result<LongRunReport> {
    anyhow::ensure!(cfg.n <= cfg.nodes, "chain longer than the cluster");
    anyhow::ensure!(cfg.k < cfg.n, "need redundancy (k < n)");
    anyhow::ensure!(cfg.epoch_secs > 0, "epochs must have positive length");
    anyhow::ensure!(cfg.objects > 0, "need at least one object");
    cfg.topology.validate()?;

    let clock = SimClock::handle();
    let mut spec = ClusterSpec::tpc(cfg.nodes)
        .with_clock(clock.clone())
        .with_runtime(cfg.runtime);
    // Baseline rates the cost model scales over: measured calibration when
    // provided, the EC2-era constants otherwise.
    let base_rates = cfg
        .calibration
        .clone()
        .unwrap_or_else(UniformCost::calibrated);
    // A concrete ProfileCost handle is kept when profiles are configured,
    // so the epoch loop can churn per-node CPU overrides at runtime.
    let profile_cost: Option<Arc<ProfileCost>> = if cfg.profiles.is_empty() {
        None
    } else {
        Some(Arc::new(ProfileCost::new(base_rates.clone(), cfg.profiles.clone())?))
    };
    if let Some(pc) = &profile_cost {
        let handle: CostModelHandle = pc.clone();
        spec = spec.with_cost(handle);
    } else if cfg.calibration.is_some() {
        // No profile mix but measured rates: uniform calibrated compute
        // (the pre-calibration default stays free/ZeroCost).
        spec = spec.with_cost(Arc::new(base_rates));
    }
    let cluster = Cluster::start(spec);
    let policy = cfg.policy.policy();
    let code = RapidRaidCode::<Gf256>::with_seed(cfg.n, cfg.k, cfg.code_seed)?;
    // Every consumer below (crash safety, repair, decode verification)
    // works against the topology-composed generator.
    let code = TopologyCode::new(code, cfg.topology.shape(cfg.n)?)?;

    // Archive the fleet: rotated bindings spread the load over the cluster.
    let spread = (cfg.nodes / cfg.objects).max(1);
    let mut placements = Vec::with_capacity(cfg.objects);
    let mut originals = Vec::with_capacity(cfg.objects);
    for i in 0..cfg.objects {
        let object = ObjectId(0x10_0000 + i as u64);
        let chain = rotated_chain(cfg.nodes, cfg.n, i * spread);
        let placement = ReplicaPlacement::new(object, cfg.k, chain)?;
        let blocks = ingest_object(&cluster, &placement, cfg.block_bytes)?;
        originals.push(blocks);
        placements.push(placement);
    }
    let jobs = pipeline_jobs(
        code.code(),
        &placements,
        cfg.topology,
        cfg.buf_bytes,
        cfg.block_bytes,
    )?;
    run_batch(&cluster, backend, &jobs)?;
    // Post-migration state: coded blocks are the only redundancy.
    for p in &placements {
        for (node, idx) in p.replica_map() {
            cluster.node(node).delete(BlockKey::source(p.object, idx))?;
        }
    }

    let sched = RepairScheduler::new(cfg.strategy, cfg.trigger)
        .with_max_concurrent(cfg.max_concurrent_repairs)
        .with_topology(cfg.topology)
        .with_adaptation(cfg.policy.adaptation());
    let mut rng = SplitMix64::new(cfg.seed);
    let mut down: Vec<(NodeId, u64)> = Vec::new(); // (node, revive epoch)
    let mut congested: Option<NodeId> = None;
    let mut cpu_churned: Option<NodeId> = None;

    let t0 = clock.now();
    let epoch_len = Duration::from_secs(cfg.epoch_secs);
    let epochs = cfg.virtual_secs.div_ceil(cfg.epoch_secs);
    let mut report = LongRunReport {
        epochs: Vec::with_capacity(epochs as usize),
        virtual_elapsed: Duration::ZERO,
        repairs_total: 0,
        crashes_total: 0,
        objects_decodable: 0,
        objects_total: cfg.objects,
        final_missing: 0,
    };

    for e in 0..epochs {
        let epoch_start = clock.now();
        let mut stats = EpochStats {
            epoch: e,
            at: epoch_start.saturating_sub(t0),
            ..EpochStats::default()
        };

        // 1. outages end
        down.retain(|&(id, revive_at)| {
            if revive_at <= e {
                cluster.revive_node(id);
                stats.revived.push(id);
                false
            } else {
                true
            }
        });

        // 2. maybe crash a node (draws happen every epoch so the schedule
        // is a fixed function of the seed, not of prior outcomes)
        let crash_roll = rng.chance(cfg.p_crash);
        let crash_pick = {
            let alive = cluster.alive_nodes();
            alive[rng.below(alive.len() as u64) as usize]
        };
        if crash_roll
            && down.len() < cfg.max_down
            && safe_to_crash(&cluster, &code, &placements, crash_pick)
        {
            cluster.fail_node(crash_pick);
            down.push((crash_pick, e + cfg.revive_after_epochs));
            stats.crashed.push(crash_pick);
            report.crashes_total += 1;
        }

        // 3. congestion churn: one netem profile roams the cluster
        if rng.chance(cfg.p_congest) {
            match congested.take() {
                Some(id) => {
                    cluster.uncongest(id);
                    stats.uncongested = Some(id);
                }
                None => {
                    let alive = cluster.alive_nodes();
                    let id = alive[rng.below(alive.len() as u64) as usize];
                    cluster.congest(id, &CongestionSpec::mild());
                    congested = Some(id);
                    stats.congested = Some(id);
                }
            }
        }

        // 3b. CPU-profile churn: one straggler override roams the cluster
        // exactly like the netem profile. The toggle state machine AND its
        // rng draws advance identically whether or not a cost model is
        // configured — only the pricing side effect is gated — so every
        // sweep cell of one seed follows the same schedule and the cost
        // axis stays isolated.
        if rng.chance(cfg.p_cpu_churn) {
            match cpu_churned.take() {
                Some(id) => {
                    if let Some(pc) = &profile_cost {
                        pc.reset_profile(id);
                    }
                    stats.cpu_restored = Some(id);
                }
                None => {
                    let alive = cluster.alive_nodes();
                    let id = alive[rng.below(alive.len() as u64) as usize];
                    if let Some(pc) = &profile_cost {
                        pc.set_profile(id, NodeProfile::THINCLIENT);
                    }
                    cpu_churned = Some(id);
                    stats.cpu_churned = Some(id);
                }
            }
        }

        // 4. repair pass
        let pass = sched.repair(
            &cluster,
            &code,
            &mut placements,
            backend,
            policy.as_ref(),
            cfg.buf_bytes,
        )?;
        stats.repaired = pass.actions.len();
        stats.repair_failures = pass.failed.len();
        stats.deferred = pass.deferred.len();
        stats.unschedulable = pass.unschedulable.len();
        report.repairs_total += pass.actions.len();

        // 5. census after the pass
        stats.missing_after = placements
            .iter()
            .map(|p| {
                let (avail, _) = survey_coded(&cluster, &p.chain, p.object);
                p.n - avail.len()
            })
            .sum();
        crate::trace_emit!(
            clock,
            None::<NodeId>,
            crate::trace::EventKind::Epoch {
                epoch: stats.epoch,
                repaired: stats.repaired,
                missing: stats.missing_after
            }
        );

        if let Some(o) = out.as_deref_mut() {
            writeln!(
                o,
                "epoch {:>4} @ {:>6.1}s: crash={:?} revive={:?} congest={:?}/{:?} cpu={:?}/{:?} repaired={} failed={} deferred={} missing={}",
                stats.epoch,
                stats.at.as_secs_f64(),
                stats.crashed,
                stats.revived,
                stats.congested,
                stats.uncongested,
                stats.cpu_churned,
                stats.cpu_restored,
                stats.repaired,
                stats.repair_failures,
                stats.deferred,
                stats.missing_after,
            )?;
        }
        report.epochs.push(stats);

        // 6. epochs have a fixed virtual length; the idle remainder costs
        // nothing under the SimClock
        clock.sleep_until(epoch_start + epoch_len);
    }

    report.virtual_elapsed = clock.now().saturating_sub(t0);
    report.final_missing = report.epochs.last().map(|s| s.missing_after).unwrap_or(0);

    // Final verification: every object must still decode byte-identically
    // (degraded reads allowed — outstanding outages count as missing).
    for (p, blocks) in placements.iter().zip(&originals) {
        if let Ok(rec) = reconstruct(&cluster, &code, &p.chain, p.object, backend) {
            if rec == *blocks {
                report.objects_decodable += 1;
            }
        }
    }
    if let Some(o) = out.as_deref_mut() {
        writeln!(o, "{}", report.summary())?;
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use std::sync::Arc;

    fn tiny() -> LongRunConfig {
        LongRunConfig {
            nodes: 12,
            n: 8,
            k: 4,
            code_seed: 7,
            objects: 2,
            block_bytes: 8 * 1024,
            buf_bytes: 2 * 1024,
            virtual_secs: 60,
            epoch_secs: 10,
            seed: 42,
            p_crash: 1.0,
            p_congest: 0.5,
            max_down: 2,
            revive_after_epochs: 2,
            strategy: RepairStrategy::Pipelined,
            trigger: RepairTrigger::Eager,
            max_concurrent_repairs: 2,
            policy: PolicyKind::CongestionAware,
            profiles: Vec::new(),
            p_cpu_churn: 0.0,
            topology: Topology::Chain,
            calibration: None,
            runtime: RuntimeKind::Auto,
        }
    }

    #[test]
    fn adaptive_policy_trace_repairs_and_stays_decodable() {
        // The adaptive axis end to end: snapshot-ranked newcomers plus
        // straggler-aware repair sourcing, with congestion churn on, must
        // still regenerate every block byte-identically — and twice the
        // same seed must follow the identical schedule.
        let backend: BackendHandle = Arc::new(NativeBackend::new());
        let mut cfg = tiny().with_profiles(NodeProfile::ec2_mix());
        cfg.policy = PolicyKind::Adaptive;
        cfg.p_cpu_churn = 1.0;
        let a = run_long_run(&cfg, &backend, None).unwrap();
        assert!(a.crashes_total >= 1);
        assert!(a.repairs_total >= 1, "{}", a.summary());
        assert!(a.all_decodable(), "{}", a.summary());
        let b = run_long_run(&cfg, &backend, None).unwrap();
        let shape = |r: &LongRunReport| {
            r.epochs
                .iter()
                .map(|e| (e.epoch, e.crashed.clone(), e.revived.clone(), e.repaired))
                .collect::<Vec<_>>()
        };
        assert_eq!(shape(&a), shape(&b), "adaptive trace must be seed-deterministic");
        assert_eq!(a.virtual_elapsed, b.virtual_elapsed);
    }

    #[test]
    fn tree_topology_trace_repairs_and_stays_decodable() {
        // Same tiny trace archived AND repaired over tree:2 pipelines:
        // every epoch's pipelined repairs aggregate over the tree shape and
        // the final decode runs through the topology generator.
        let backend: BackendHandle = Arc::new(NativeBackend::new());
        let cfg = tiny().with_topology(Topology::Tree { fanout: 2 });
        let report = run_long_run(&cfg, &backend, None).unwrap();
        assert!(report.crashes_total >= 1);
        assert!(report.repairs_total >= 1, "{}", report.summary());
        assert!(report.all_decodable(), "{}", report.summary());
    }

    #[test]
    fn cpu_churn_toggles_and_stays_decodable() {
        let backend: BackendHandle = Arc::new(NativeBackend::new());
        let mut cfg = tiny().with_profiles(NodeProfile::ec2_mix());
        cfg.p_cpu_churn = 1.0; // toggle every epoch
        let report = run_long_run(&cfg, &backend, None).unwrap();
        let churns = report.epochs.iter().filter(|e| e.cpu_churned.is_some()).count();
        let restores = report.epochs.iter().filter(|e| e.cpu_restored.is_some()).count();
        assert!(churns >= 1, "churn never fired");
        assert!(restores >= 1, "override never restored");
        assert!(report.all_decodable(), "{}", report.summary());
    }

    #[test]
    fn profiled_trace_charges_compute_and_stays_decodable() {
        // Same tiny trace on a heterogeneous profile mix: epochs have a
        // fixed virtual length, so the observable contract is that the
        // compute-charged trace still completes losslessly (the makespan
        // property itself is covered by tests/resources.rs).
        let backend: BackendHandle = Arc::new(NativeBackend::new());
        let cfg = tiny().with_profiles(NodeProfile::ec2_mix());
        let report = run_long_run(&cfg, &backend, None).unwrap();
        assert!(report.crashes_total >= 1);
        assert!(report.all_decodable(), "{}", report.summary());
    }

    #[test]
    fn tiny_trace_repairs_and_stays_decodable() {
        let backend: BackendHandle = Arc::new(NativeBackend::new());
        let report = run_long_run(&tiny(), &backend, None).unwrap();
        assert_eq!(report.epochs.len(), 6);
        assert!(report.virtual_elapsed >= Duration::from_secs(60));
        assert!(report.crashes_total >= 1, "p_crash=1 must crash something");
        assert!(report.all_decodable(), "{}", report.summary());
    }

    #[test]
    fn trace_is_deterministic_per_seed() {
        let backend: BackendHandle = Arc::new(NativeBackend::new());
        let a = run_long_run(&tiny(), &backend, None).unwrap();
        let b = run_long_run(&tiny(), &backend, None).unwrap();
        let shape = |r: &LongRunReport| {
            r.epochs
                .iter()
                .map(|e| (e.epoch, e.crashed.clone(), e.revived.clone(), e.repaired))
                .collect::<Vec<_>>()
        };
        assert_eq!(shape(&a), shape(&b));
        assert_eq!(a.crashes_total, b.crashes_total);
        assert_eq!(a.virtual_elapsed, b.virtual_elapsed);
    }

    #[test]
    fn config_validation_rejects_nonsense() {
        let backend: BackendHandle = Arc::new(NativeBackend::new());
        let mut bad = tiny();
        bad.n = 20; // chain longer than the 12-node cluster
        assert!(run_long_run(&bad, &backend, None).is_err());
        let mut bad = tiny();
        bad.epoch_secs = 0;
        assert!(run_long_run(&bad, &backend, None).is_err());
    }
}
