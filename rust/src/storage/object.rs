//! Object and block identities.

/// Identifier of a stored object.
#[derive(Copy, Clone, PartialEq, Eq, Hash, PartialOrd, Ord, Debug)]
pub struct ObjectId(pub u64);

impl std::fmt::Display for ObjectId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "obj-{}", self.0)
    }
}

/// Kinds of blocks a node can hold for an object.
#[derive(Copy, Clone, PartialEq, Eq, Hash, Debug)]
pub enum BlockKind {
    /// Raw replica block `o_i` (pre-archival).
    Source,
    /// Erasure-coded block `c_i` (post-archival).
    Coded,
}

/// Key of one block in a node's block store.
#[derive(Copy, Clone, PartialEq, Eq, Hash, Debug)]
pub struct BlockKey {
    /// Owning object.
    pub object: ObjectId,
    /// Block index: source index `i` of `o_i`, or codeword index of `c_i`.
    pub index: usize,
    /// Source vs coded.
    pub kind: BlockKind,
}

impl BlockKey {
    /// Key of source block `o_index`.
    pub fn source(object: ObjectId, index: usize) -> Self {
        Self {
            object,
            index,
            kind: BlockKind::Source,
        }
    }

    /// Key of coded block `c_index`.
    pub fn coded(object: ObjectId, index: usize) -> Self {
        Self {
            object,
            index,
            kind: BlockKind::Coded,
        }
    }
}

/// Static description of an object's layout.
#[derive(Clone, Debug)]
pub struct ObjectSpec {
    /// Object identity.
    pub id: ObjectId,
    /// Number of source blocks (the code's k).
    pub k: usize,
    /// Bytes per block.
    pub block_bytes: usize,
}

impl ObjectSpec {
    /// Total object size in bytes.
    pub fn total_bytes(&self) -> usize {
        self.k * self.block_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keys_distinguish_kind_and_index() {
        let o = ObjectId(1);
        assert_ne!(BlockKey::source(o, 0), BlockKey::coded(o, 0));
        assert_ne!(BlockKey::source(o, 0), BlockKey::source(o, 1));
        assert_ne!(
            BlockKey::source(ObjectId(1), 0),
            BlockKey::source(ObjectId(2), 0)
        );
    }

    #[test]
    fn spec_total() {
        let spec = ObjectSpec {
            id: ObjectId(3),
            k: 11,
            block_bytes: 64 << 20,
        };
        assert_eq!(spec.total_bytes(), 11 * (64 << 20)); // the paper's 704 MB
    }

    #[test]
    fn display() {
        assert_eq!(ObjectId(7).to_string(), "obj-7");
    }
}
