//! Object/block model and per-node block stores.
//!
//! Objects are split into k equally sized blocks at ingest (64 MB in
//! GFS/HDFS and in the paper's evaluation; configurable here). Redundancy
//! starts as replication (each block on ≥2 nodes — exactly what RapidRAID
//! needs) and is later *migrated* to erasure coding by the coordinator.

pub mod blockstore;
pub mod object;
pub mod placement;

pub use blockstore::BlockStore;
pub use object::{BlockKey, BlockKind, ObjectId, ObjectSpec};
pub use placement::ReplicaPlacement;
