//! In-memory block store — each simulated storage node owns one.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use super::object::BlockKey;

/// Thread-safe in-memory block store.
///
/// Blocks are stored as `Arc<Vec<u8>>` so readers (e.g. a pipeline stage
/// streaming a local block) share the payload without copying.
#[derive(Clone, Default)]
pub struct BlockStore {
    inner: Arc<Mutex<HashMap<BlockKey, Arc<Vec<u8>>>>>,
}

impl BlockStore {
    /// Empty store.
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert (or replace) a block.
    pub fn put(&self, key: BlockKey, data: Vec<u8>) {
        self.inner.lock().unwrap().insert(key, Arc::new(data));
    }

    /// Insert unless `cancelled` is set, checking the flag *under the store
    /// lock*; returns whether the block was stored. Crash injection sets
    /// the flag before wiping the store (also under the lock), so a
    /// data-plane worker finishing concurrently with `fail_node` can never
    /// leave a block on a crashed node: either it observes the flag here,
    /// or its write is erased by the wipe ordered after it.
    pub fn put_unless(&self, key: BlockKey, data: Vec<u8>, cancelled: &AtomicBool) -> bool {
        let mut map = self.inner.lock().unwrap();
        if cancelled.load(Ordering::SeqCst) {
            return false;
        }
        map.insert(key, Arc::new(data));
        true
    }

    /// Fetch a block (shared, zero-copy).
    pub fn get(&self, key: &BlockKey) -> Option<Arc<Vec<u8>>> {
        self.inner.lock().unwrap().get(key).cloned()
    }

    /// Remove a block, returning whether it existed.
    pub fn delete(&self, key: &BlockKey) -> bool {
        self.inner.lock().unwrap().remove(key).is_some()
    }

    /// Drop every block (crash injection: the simulated disk dies with the
    /// node).
    pub fn clear(&self) {
        self.inner.lock().unwrap().clear();
    }

    /// Whether the block exists.
    pub fn contains(&self, key: &BlockKey) -> bool {
        self.inner.lock().unwrap().contains_key(key)
    }

    /// Number of blocks held.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total payload bytes held.
    pub fn used_bytes(&self) -> usize {
        self.inner.lock().unwrap().values().map(|v| v.len()).sum()
    }

    /// All keys currently stored (sorted for determinism).
    pub fn keys(&self) -> Vec<BlockKey> {
        let mut ks: Vec<BlockKey> = self.inner.lock().unwrap().keys().copied().collect();
        ks.sort_by_key(|k| (k.object.0, k.index, matches!(k.kind, super::object::BlockKind::Coded)));
        ks
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::object::{BlockKey, ObjectId};

    #[test]
    fn put_get_delete() {
        let s = BlockStore::new();
        let k = BlockKey::source(ObjectId(1), 0);
        assert!(s.get(&k).is_none());
        s.put(k, vec![1, 2, 3]);
        assert_eq!(*s.get(&k).unwrap(), vec![1, 2, 3]);
        assert!(s.contains(&k));
        assert_eq!(s.used_bytes(), 3);
        assert!(s.delete(&k));
        assert!(!s.delete(&k));
        assert!(s.is_empty());
    }

    #[test]
    fn put_unless_respects_cancel_flag_and_clear_empties() {
        let s = BlockStore::new();
        let k = BlockKey::source(ObjectId(3), 0);
        let flag = AtomicBool::new(false);
        assert!(s.put_unless(k, vec![1], &flag));
        flag.store(true, Ordering::SeqCst);
        assert!(!s.put_unless(k, vec![2], &flag));
        assert_eq!(*s.get(&k).unwrap(), vec![1]);
        s.clear();
        assert!(s.is_empty());
    }

    #[test]
    fn replace_updates_bytes() {
        let s = BlockStore::new();
        let k = BlockKey::coded(ObjectId(2), 5);
        s.put(k, vec![0; 100]);
        s.put(k, vec![0; 10]);
        assert_eq!(s.used_bytes(), 10);
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn shared_across_clones() {
        let s = BlockStore::new();
        let s2 = s.clone();
        s.put(BlockKey::source(ObjectId(1), 1), vec![9]);
        assert!(s2.contains(&BlockKey::source(ObjectId(1), 1)));
    }

    #[test]
    fn keys_sorted() {
        let s = BlockStore::new();
        s.put(BlockKey::coded(ObjectId(2), 0), vec![]);
        s.put(BlockKey::source(ObjectId(1), 1), vec![]);
        s.put(BlockKey::source(ObjectId(1), 0), vec![]);
        let ks = s.keys();
        assert_eq!(ks[0], BlockKey::source(ObjectId(1), 0));
        assert_eq!(ks[2], BlockKey::coded(ObjectId(2), 0));
    }
}
