//! Replica placement maps: which node holds which source block, and where
//! the coded blocks will live after archival.
//!
//! RapidRAID's precondition (paper Section V) is that the two replicas are
//! laid out so node i of the encoding chain already stores the block(s) it
//! must fold — `crate::codes::rapidraid::placement` gives the block→node
//! map; this module binds it to concrete cluster node ids.

use crate::codes::rapidraid;
use crate::storage::object::ObjectId;

/// Node identifier within a cluster.
pub type NodeId = usize;

/// Placement of one object's replicas over concrete nodes, pre-archival.
#[derive(Clone, Debug)]
pub struct ReplicaPlacement {
    /// Object this placement belongs to.
    pub object: ObjectId,
    /// Code parameters the archival will use.
    pub n: usize,
    /// Message length.
    pub k: usize,
    /// `chain[i]` = cluster node acting as pipeline position i; that node
    /// stores the source blocks `rapidraid::placement(n, k)[i]` and will
    /// store coded block `c_i` after archival.
    pub chain: Vec<NodeId>,
}

impl ReplicaPlacement {
    /// Bind the canonical RapidRAID placement to a chain of cluster nodes
    /// (chain.len() == n, all distinct).
    pub fn new(object: ObjectId, k: usize, chain: Vec<NodeId>) -> anyhow::Result<Self> {
        let n = chain.len();
        rapidraid::placement(n, k)?; // validates k < n <= 2k
        let mut sorted = chain.clone();
        sorted.sort_unstable();
        sorted.dedup();
        anyhow::ensure!(sorted.len() == n, "chain nodes must be distinct");
        Ok(Self {
            object,
            n,
            k,
            chain,
        })
    }

    /// Source-block indices node at chain position i must hold.
    pub fn locals(&self, position: usize) -> Vec<usize> {
        rapidraid::placement(self.n, self.k).expect("validated at construction")[position].clone()
    }

    /// All (node, source-block) pairs of the replicated layout.
    pub fn replica_map(&self) -> Vec<(NodeId, usize)> {
        let place = rapidraid::placement(self.n, self.k).expect("validated");
        let mut out = Vec::new();
        for (pos, blocks) in place.iter().enumerate() {
            for &b in blocks {
                out.push((self.chain[pos], b));
            }
        }
        out
    }

    /// Nodes holding a replica of source block `b` (always exactly two).
    pub fn holders_of(&self, b: usize) -> Vec<NodeId> {
        self.replica_map()
            .into_iter()
            .filter(|&(_, blk)| blk == b)
            .map(|(n, _)| n)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn replica_map_covers_each_block_twice() {
        let p = ReplicaPlacement::new(ObjectId(1), 4, (0..8).collect()).unwrap();
        for b in 0..4 {
            assert_eq!(p.holders_of(b).len(), 2, "block {b}");
        }
        assert_eq!(p.replica_map().len(), 8);
    }

    #[test]
    fn overlapped_chain_positions() {
        let p = ReplicaPlacement::new(ObjectId(2), 4, vec![10, 11, 12, 13, 14, 15]).unwrap();
        assert_eq!(p.locals(2), vec![2, 0]); // the (6,4) overlapped middle
        assert_eq!(p.holders_of(0), vec![10, 12]);
    }

    #[test]
    fn rejects_duplicate_nodes_and_bad_params() {
        assert!(ReplicaPlacement::new(ObjectId(1), 4, vec![0, 1, 2, 3, 4, 4, 5, 6]).is_err());
        assert!(ReplicaPlacement::new(ObjectId(1), 4, (0..9).collect()).is_err()); // n > 2k
        assert!(ReplicaPlacement::new(ObjectId(1), 4, (0..4).collect()).is_err()); // n == k
    }
}
