//! # RapidRAID — pipelined erasure codes for fast data archival
//!
//! Reproduction of *"RapidRAID: Pipelined Erasure Codes for Fast Data
//! Archival in Distributed Storage Systems"* (Pamies-Juarez, Datta, Oggier;
//! 2012) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the distributed-storage coordinator: a simulated
//!   cluster of storage nodes connected by rate-limited links (with
//!   crash-stop failure injection), a declarative archival-plan IR
//!   ([`coordinator::plan`]) with one unified execution engine
//!   ([`coordinator::engine`]) beneath the classical (atomic) encoder, the
//!   paper's pipelined RapidRAID encoder, the batch scheduler for
//!   concurrent object archival, pipelined reconstruction and the failure &
//!   repair subsystem ([`repair`]: degraded reads, star vs pipelined
//!   single-block repair, eager/lazy repair scheduling), plus
//!   fault-tolerance analytics (dependency census, static resilience) and
//!   the benchmark harnesses that regenerate every table and figure of the
//!   paper's evaluation section.
//! * **L2/L1 (python/, build time only)** — the GF(2^w) coding hot-spots as
//!   JAX graphs built from Pallas kernels, AOT-lowered to HLO text and
//!   executed from Rust through the PJRT CPU client ([`runtime`]).
//!
//! ## Crate map
//!
//! | module | contents |
//! |---|---|
//! | [`clock`] | pluggable time: `RealClock` (wall time) vs `SimClock` (deterministic discrete-event virtual time), clock channels, participant accounting |
//! | [`resources`] | unified resource model: `GfWork` units, `CostModel` (`ZeroCost`/`UniformCost`/`ProfileCost` + per-node multi-core `NodeProfile`s, runtime re-profiling), per-node `CpuMeter` charging compute in virtual time over core lanes (`backlog()` is the placement load signal) |
//! | [`gf`] | GF(2^8)/GF(2^16) arithmetic: tables (incl. shared `product_table8`/`product_tables16` constructors), bulk slice ops (work-reporting), matrices, Gauss; [`gf::simd`] runtime-dispatched kernels — scalar / SSSE3 / AVX2 / NEON split-nibble `PSHUFB`/`TBL` plus a GFNI `GF2P8AFFINEQB` tier, single-coefficient ops, fused two-output `mul2_xor8/16` and row-batched `gemm_rows8/16`, forced via `RAPIDRAID_FORCE_SCALAR` / `RAPIDRAID_KERNEL` |
//! | [`codes`] | classical Cauchy Reed-Solomon + RapidRAID code constructions, coefficient search, dependency census; [`codes::topology`] composes a schedule over any rooted shape into its generator (`TopologyShape`/`TopologyCode`), and `CodeView` is the generator-level surface decode/repair consume |
//! | [`reliability`] | static resilience (probability of data loss, "number of 9's") |
//! | [`cluster`] | simulated storage cluster: nodes, rate-limited links (zero-copy `Payload` frames — `Arc`-backed views, fan-out without memcpy), congestion, crash-stop failure injection (`fail_node`/`revive_node`); everything timed on the spec's clock. Pluggable execution runtimes (`RuntimeKind`): thread-per-node vs a multiplexed single-driver cooperative scheduler for thousands of SimClock nodes, `Auto`-resolved from the clock, observably identical (byte/tick/trace parity) |
//! | [`storage`] | objects, blocks, replica placement, block stores |
//! | [`coordinator`] | the archival system: ArchivalPlan IR + PlanExecutor engine, with classical/pipelined/batch/decode/migration as plan builders; degraded reads via `decode::survey_coded` |
//! | [`coordinator::topology`] | first-class pipeline shapes: `Topology` (`Chain`/`Tree`/`Hybrid`) expanded to ordered shapes, encode/aggregate lowerings onto the plan IR, and shape-aware `PlacementPolicy` placement (`FifoPolicy`/`CongestionAwarePolicy`/`LoadAwarePolicy`, slot-weighted binding) |
//! | [`control`] | adaptive control plane: plan-boundary [`control::LoadSnapshot`]s of measured per-node load (CPU/NIC backlogs, in-flight commands, rates, priced GF throughput), deterministic node ranking, the analytic shape-makespan predictor behind fanout auto-tuning and straggler-aware repair sourcing, all gated by [`control::Adaptation`] (`Off` is bit-for-bit the static behavior) |
//! | [`repair`] | failure repair as plan builders: star vs topology-shaped pipelined (Li et al. 2019) single-block repair, repair coefficients from the generator, eager/lazy/reliability-budget scheduler |
//! | [`runtime`] | PJRT executor loading the AOT artifacts (`artifacts/*.hlo.txt`); stubbed without the `pjrt` feature |
//! | [`backend`] | pluggable GF compute: native Rust vs PJRT artifacts |
//! | [`metrics`] | clock-timed spans ([`metrics::Span`], with compute/transfer splits), percentile candles, report emitters, `BENCH_*.json` output (self-describing: `schema_version` + preset param) and a serde-free JSON parser ([`metrics::json::parse_json`], `BenchJson::from_json`) |
//! | [`trace`] | deterministic dataplane tracing: typed [`trace::Event`] bus behind the zero-cost [`trace_emit!`] macro (frames, NIC stalls, CPU charges, fold/gemm spans, queue gauges, failure/repair/plan/epoch lifecycle), ring/JSONL sinks, Chrome-trace/Perfetto export, derived per-node/link counters and critical-path makespan attribution |
//! | [`workload`] | long-run workload harness: seeded crash/revive/congestion/CPU-churn schedules over batch archival + repair (with CPU profile mixes and any pipeline topology), thousands of virtual seconds per wall second under `SimClock`; [`workload::sweep`] grids triggers × policies × cost profiles × topologies; the `scale-sim` preset ([`bench_scenarios`]) drives 2,048 nodes through a virtual day on the multiplexed runtime |
//! | [`util`] | deterministic PRNG, mini property-test harness, bench timer |
//!
//! ## Quickstart
//!
//! ```
//! use rapidraid::codes::rapidraid::RapidRaidCode;
//! use rapidraid::gf::Gf256;
//!
//! // The paper's running example: an (8,4) pipelined code over GF(2^8).
//! let code = RapidRaidCode::<Gf256>::with_seed(8, 4, 7).unwrap();
//! let object: Vec<Vec<Gf256>> = (0..4u8).map(|i| vec![Gf256(i); 1024]).collect();
//! let coded = code.encode_chain(&object);
//! let recovered = code.decode(&[(2, coded[2].clone()), (3, coded[3].clone()),
//!                               (6, coded[6].clone()), (7, coded[7].clone())]).unwrap();
//! assert_eq!(recovered, object);
//! ```

pub mod backend;
pub mod bench_scenarios;
pub mod clock;
pub mod cluster;
pub mod codes;
pub mod control;
pub mod coordinator;
pub mod gf;
pub mod metrics;
pub mod reliability;
pub mod repair;
pub mod resources;
pub mod runtime;
pub mod storage;
pub mod trace;
pub mod util;
pub mod workload;
