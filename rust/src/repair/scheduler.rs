//! Repair scheduler: scan placements for missing coded blocks and drive
//! their regeneration through the shared engine.
//!
//! The scheduler is pure control plane: it surveys survivors
//! ([`crate::coordinator::decode::survey_coded`] — crashed nodes count as
//! missing), picks a newcomer per lost block through the executor's
//! [`ChainPolicy`] ranking (in-place when the holder is alive and only the
//! block is gone), lowers every repair with the configured planner, and
//! runs the whole batch through [`PlanExecutor::run_many_results`]. Chain
//! bindings commit *per repair*: successes rebind immediately, failures
//! (say a second crash mid-repair) are reported in
//! [`RepairReport::failed`] and retried by the next pass.
//!
//! *Eager* repair fires on any missing block; *lazy* repair defers an
//! object until it has lost at least `min_missing` blocks — the classical
//! trade of repair traffic against the risk window, worthwhile because a
//! deferred object can still serve degraded reads.

use std::collections::HashSet;
use std::time::Duration;

use crate::backend::BackendHandle;
use crate::cluster::{Cluster, NodeId};
use crate::codes::CodeView;
use crate::control::{candidate_shapes, Adaptation, Flow, LoadSnapshot};
use crate::coordinator::decode::survey_coded;
use crate::coordinator::engine::{ChainPolicy, PlanExecutor};
use crate::coordinator::plan::ArchivalPlan;
use crate::coordinator::topology::Topology;
use crate::gf::{GfElem, SliceOps};
use crate::reliability::{census_survival_prob, nines};
use crate::resources::GfWork;
use crate::storage::{ObjectId, ReplicaPlacement};

use super::pipeline::PipelinedRepairJob;
use super::star::StarRepairJob;
use super::RepairJob;

/// Which planner lowers each single-block repair.
#[derive(Copy, Clone, PartialEq, Eq, Debug)]
pub enum RepairStrategy {
    /// k survivors stream to the newcomer (classical baseline).
    Star,
    /// Chain of ψ-weighted folds across the survivors (Li et al., 2019).
    Pipelined,
}

/// When the scheduler acts on a degraded object.
#[derive(Copy, Clone, PartialEq, Debug)]
pub enum RepairTrigger {
    /// Repair every missing block as soon as it is observed.
    Eager,
    /// Defer an object until at least `min_missing` of its blocks are gone.
    Lazy {
        /// Missing-block threshold that triggers repair.
        min_missing: usize,
    },
    /// Defer while the object's *predicted* reliability stays at or above
    /// the budget: [`crate::reliability::census_survival_prob`] over the
    /// current survivor census (each surviving holder failing i.i.d. with
    /// `p_node` before the next pass), converted to
    /// [`crate::reliability::nines`]. An object whose census drops below
    /// `min_nines` nines is repaired eagerly; healthier degraded objects
    /// keep serving degraded reads — the Table-I reliability model driving
    /// the repair-traffic trade directly.
    ReliabilityBudget {
        /// Minimum acceptable number of 9's of survival probability.
        min_nines: u32,
        /// Per-node failure probability assumed for the risk window.
        p_node: f64,
    },
}

impl std::fmt::Display for RepairTrigger {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RepairTrigger::Eager => write!(f, "eager"),
            RepairTrigger::Lazy { min_missing } => write!(f, "lazy({min_missing})"),
            RepairTrigger::ReliabilityBudget { min_nines, p_node } => {
                write!(f, "budget({min_nines}x9,p={p_node})")
            }
        }
    }
}

/// One committed block move: `object`'s codeword position `position` now
/// lives on `new_node` (== `old_node` for an in-place repair).
#[derive(Copy, Clone, Debug)]
pub struct RepairAction {
    /// Repaired object.
    pub object: ObjectId,
    /// Codeword position regenerated.
    pub position: usize,
    /// Chain node that held (or still holds, crashed) the lost block.
    pub old_node: NodeId,
    /// Node now holding the regenerated block.
    pub new_node: NodeId,
}

/// Outcome of one scheduler pass.
#[derive(Debug, Default)]
pub struct RepairReport {
    /// Every successfully repaired block, in dispatch order.
    pub actions: Vec<RepairAction>,
    /// Per-repair end-to-end times (same order as `actions`).
    pub times: Vec<Duration>,
    /// Objects left degraded by a lazy trigger (below threshold).
    pub deferred: Vec<ObjectId>,
    /// Repairs whose plan failed at execution (e.g. a second crash
    /// mid-stream), with the error text; their chains were NOT rebound and
    /// a later pass will retry them.
    pub failed: Vec<(RepairAction, String)>,
    /// Objects the pass could not even plan a repair for (no surviving
    /// blocks, no independent k-subset, no alive newcomer), with the error
    /// text. They never abort the pass: the other objects' repairs still
    /// run.
    pub unschedulable: Vec<(ObjectId, String)>,
}

/// Drives failure repair over a set of placements.
pub struct RepairScheduler {
    /// Planner used for every repair in a pass.
    pub strategy: RepairStrategy,
    /// Eager vs threshold-triggered repair.
    pub trigger: RepairTrigger,
    /// Bound on concurrently running repair plans
    /// (`PlanExecutor::run_many_bounded`).
    pub max_concurrent: usize,
    /// Aggregation shape pipelined repairs are lowered through (ignored by
    /// the star planner, overridden per object when `adaptation` is on).
    pub topology: Topology,
    /// Straggler-aware repair sourcing gate: with [`Adaptation::On`] each
    /// pass snapshots the cluster once at its plan boundary
    /// ([`LoadSnapshot::take`]), orders every object's survivors by their
    /// holders' measured load before the independent k-subset is picked —
    /// so repairs source from fast, idle survivors — and replaces the
    /// fixed `topology` with the predicted-critical-path aggregation
    /// shape per repair. [`Adaptation::Off`] (the default) is bit-for-bit
    /// the static scheduler: no snapshot, survivor order untouched.
    pub adaptation: Adaptation,
}

impl RepairScheduler {
    /// Scheduler with the given strategy/trigger, chain-shaped pipelined
    /// repairs and a default concurrency bound of 4 repairs at a time.
    pub fn new(strategy: RepairStrategy, trigger: RepairTrigger) -> Self {
        Self {
            strategy,
            trigger,
            max_concurrent: 4,
            topology: Topology::Chain,
            adaptation: Adaptation::Off,
        }
    }

    /// Override the concurrent-repair bound.
    pub fn with_max_concurrent(mut self, max_concurrent: usize) -> Self {
        self.max_concurrent = max_concurrent.max(1);
        self
    }

    /// Substitute the aggregation shape pipelined repairs use.
    pub fn with_topology(mut self, topology: Topology) -> Self {
        self.topology = topology;
        self
    }

    /// Gate the closed-loop straggler-aware sourcing (see
    /// [`RepairScheduler::adaptation`]).
    pub fn with_adaptation(mut self, adaptation: Adaptation) -> Self {
        self.adaptation = adaptation;
        self
    }

    /// One scheduler pass: scan `placements` for missing coded blocks,
    /// repair what the trigger selects, and rebind each successfully
    /// repaired position in its placement's chain. Per-object planning
    /// failures (no survivors / no alive newcomer / unrepairable block)
    /// land in [`RepairReport::unschedulable`] and per-repair execution
    /// failures in [`RepairReport::failed`] — neither aborts the pass, so
    /// one doomed object can never starve the others of repair.
    pub fn repair<F: GfElem + SliceOps, C: CodeView<F>>(
        &self,
        cluster: &Cluster,
        code: &C,
        placements: &mut [ReplicaPlacement],
        backend: &BackendHandle,
        policy: &dyn ChainPolicy,
        buf_bytes: usize,
    ) -> anyhow::Result<RepairReport> {
        let mut report = RepairReport::default();
        let mut plans: Vec<ArchivalPlan> = Vec::new();
        let mut pending: Vec<(usize, RepairAction)> = Vec::new();
        // One snapshot per pass: planning happens entirely before the
        // batch dispatch, so the whole pass is one plan boundary and every
        // object's sourcing decision reads the same frozen load state.
        let snapshot = self
            .adaptation
            .is_on()
            .then(|| LoadSnapshot::take(cluster));

        for (pi, p) in placements.iter().enumerate() {
            let (avail, block_bytes) = survey_coded(cluster, &p.chain, p.object);
            let missing: Vec<usize> = (0..p.n).filter(|pos| !avail.contains(pos)).collect();
            if missing.is_empty() {
                continue;
            }
            match self.trigger {
                RepairTrigger::Eager => {}
                RepairTrigger::Lazy { min_missing } => {
                    if missing.len() < min_missing {
                        report.deferred.push(p.object);
                        continue;
                    }
                }
                RepairTrigger::ReliabilityBudget { min_nines, p_node } => {
                    let survive = census_survival_prob(code.generator(), &avail, p_node);
                    if nines(survive) >= min_nines {
                        report.deferred.push(p.object);
                        continue;
                    }
                }
            }
            match plan_object(
                cluster,
                code,
                policy,
                self.strategy,
                self.topology,
                snapshot.as_ref(),
                p,
                &avail,
                &missing,
                buf_bytes,
                block_bytes,
            ) {
                Ok(planned) => {
                    for (plan, action) in planned {
                        crate::trace_emit!(
                            cluster.clock(),
                            action.new_node,
                            crate::trace::EventKind::RepairTriggered {
                                object: action.object.0,
                                position: action.position
                            }
                        );
                        plans.push(plan);
                        pending.push((pi, action));
                    }
                }
                Err(e) => report.unschedulable.push((p.object, format!("{e:#}"))),
            }
        }

        // Execute the batch and commit per plan: a repair that failed (a
        // second crash mid-stream, say) must not discard the blocks the
        // other repairs already regenerated, so successes rebind their
        // chains and failures are reported for the next pass to retry.
        let exec = PlanExecutor::new(cluster, backend.clone());
        let outcomes = exec.run_many_results(&plans, self.max_concurrent)?;
        for ((pi, action), outcome) in pending.into_iter().zip(outcomes) {
            match outcome {
                Ok(t) => {
                    placements[pi].chain[action.position] = action.new_node;
                    crate::trace_emit!(
                        cluster.clock(),
                        action.new_node,
                        crate::trace::EventKind::RepairCommitted {
                            object: action.object.0,
                            position: action.position,
                            newcomer: action.new_node
                        }
                    );
                    report.actions.push(action);
                    report.times.push(t);
                }
                Err(e) => report.failed.push((action, format!("{e:#}"))),
            }
        }
        Ok(report)
    }
}

/// Plan every missing-block repair of one object: choose a newcomer per
/// lost block (in place when the holder survived, otherwise the policy's
/// best alive off-chain node) and lower it with `strategy`. With a
/// `snapshot` the survivor order — and through it the greedy independent
/// k-subset [`CodeView::repair_coefficients`] settles on — prefers the
/// holders with the least measured load, and each pipelined repair's
/// aggregation shape is the predicted-critical-path argmin over its
/// actual sources. Any error here makes the *object* unschedulable; it
/// never aborts the pass.
#[allow(clippy::too_many_arguments)]
fn plan_object<F: GfElem + SliceOps, C: CodeView<F>>(
    cluster: &Cluster,
    code: &C,
    policy: &dyn ChainPolicy,
    strategy: RepairStrategy,
    topology: Topology,
    snapshot: Option<&LoadSnapshot>,
    p: &ReplicaPlacement,
    avail: &[usize],
    missing: &[usize],
    buf_bytes: usize,
    block_bytes: usize,
) -> anyhow::Result<Vec<(ArchivalPlan, RepairAction)>> {
    anyhow::ensure!(
        block_bytes > 0,
        "object {}: no surviving coded blocks to repair from",
        p.object
    );
    // Straggler-aware sourcing: the greedy subset search keeps survivor
    // positions in `avail` order whenever their rows are independent, so
    // sorting positions by their holders' snapshot rank steers every
    // repair toward fast, idle survivors. Any independent k-subset
    // regenerates the same lost block, so the repaired bytes are
    // identical either way — only the sourcing (and its critical path)
    // changes. `None` leaves the survey order untouched (the static
    // path, byte-for-byte).
    let reordered: Vec<usize>;
    let avail: &[usize] = match snapshot {
        Some(snap) => {
            let holders: Vec<NodeId> = avail.iter().map(|&pos| p.chain[pos]).collect();
            let ranked = snap.rank(&holders);
            let goodness = |pos: usize| {
                ranked
                    .iter()
                    .position(|&n| n == p.chain[pos])
                    .expect("rank is a permutation of the holders")
            };
            let mut v = avail.to_vec();
            v.sort_by_key(|&pos| (goodness(pos), pos));
            reordered = v;
            &reordered
        }
        None => avail,
    };
    // Nodes that will hold a block of this object post-repair: survivors
    // keep theirs, each repair claims one more.
    let mut taken: HashSet<NodeId> = avail.iter().map(|&pos| p.chain[pos]).collect();
    let mut planned = Vec::with_capacity(missing.len());
    for &pos in missing {
        let old = p.chain[pos];
        let newcomer = if !cluster.is_failed(old) && !taken.contains(&old) {
            // the holder survived, only its block is gone: in place
            old
        } else {
            let candidates: Vec<NodeId> = cluster
                .alive_nodes()
                .into_iter()
                .filter(|n| !taken.contains(n))
                .collect();
            anyhow::ensure!(
                !candidates.is_empty(),
                "object {}: no alive newcomer for block {pos}",
                p.object
            );
            policy.rank(cluster, &candidates)[0]
        };
        taken.insert(newcomer);
        let job = RepairJob::from_code(
            code, p.object, &p.chain, pos, newcomer, avail, buf_bytes, block_bytes,
        )?;
        // ψ = g_lost · G_S⁻¹ just ran (a k×k Gauss-Jordan): charge it to
        // the newcomer driving the repair, so coefficient derivation
        // occupies virtual time like every other priced GF operation.
        cluster.node(newcomer).cpu.charge(&GfWork::invert(job.k()));
        let plan = match strategy {
            RepairStrategy::Star => StarRepairJob::new(job).plan()?,
            RepairStrategy::Pipelined => {
                // Fanout auto-tuning over the aggregation: predict each
                // candidate shape's critical path over the repair's actual
                // sources and take the argmin (sources are already ranked
                // best-first, matching the heaviest-subtree-first slot
                // binding the predictor assumes). Degenerate source sets
                // keep the configured shape.
                let shape = match snapshot {
                    Some(snap) => {
                        let holders: Vec<NodeId> =
                            job.sources.iter().map(|&(n, _)| n).collect();
                        let shapes = candidate_shapes(holders.len(), 2);
                        match snap.choose_topology(
                            &holders,
                            holders.len(),
                            &shapes,
                            Flow::Aggregation,
                            block_bytes,
                            buf_bytes,
                        ) {
                            Ok((topo, _, _)) => topo,
                            Err(_) => topology,
                        }
                    }
                    None => topology,
                };
                PipelinedRepairJob::with_topology(job, shape).plan()?
            }
        };
        planned.push((
            plan,
            RepairAction {
                object: p.object,
                position: pos,
                old_node: old,
                new_node: newcomer,
            },
        ));
    }
    Ok(planned)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{BackendHandle, NativeBackend};
    use crate::cluster::ClusterSpec;
    use crate::codes::rapidraid::RapidRaidCode;
    use crate::coordinator::engine::{CongestionAwarePolicy, FifoPolicy};
    use crate::coordinator::ingest::ingest_object;
    use crate::coordinator::pipeline::{archive_pipeline, PipelineJob};
    use crate::coordinator::reconstruct;
    use crate::gf::Gf256;
    use crate::storage::BlockKey;
    use std::sync::Arc;

    fn archived(
        nodes: usize,
        n: usize,
        k: usize,
        block: usize,
        object: ObjectId,
    ) -> (Cluster, RapidRaidCode<Gf256>, ReplicaPlacement, Vec<Vec<u8>>, BackendHandle) {
        let cluster = Cluster::start(ClusterSpec::test(nodes));
        let placement = ReplicaPlacement::new(object, k, (0..n).collect()).unwrap();
        let blocks = ingest_object(&cluster, &placement, block).unwrap();
        let code = RapidRaidCode::<Gf256>::with_seed(n, k, 7).unwrap();
        let backend: BackendHandle = Arc::new(NativeBackend::new());
        let job = PipelineJob::from_code(&code, &placement, 2048, block).unwrap();
        archive_pipeline(&cluster, &backend, &job).unwrap();
        (cluster, code, placement, blocks, backend)
    }

    #[test]
    fn eager_pass_repairs_crashed_node_onto_newcomer() {
        let object = ObjectId(300);
        let (cluster, code, placement, blocks, backend) = archived(10, 8, 4, 8 * 1024, object);
        let key = BlockKey::coded(object, 3);
        let original = (*cluster.node(3).peek(key).unwrap().unwrap()).clone();
        cluster.fail_node(3);

        let mut placements = [placement];
        let sched = RepairScheduler::new(RepairStrategy::Pipelined, RepairTrigger::Eager);
        let report = sched
            .repair(&cluster, &code, &mut placements, &backend, &FifoPolicy, 2048)
            .unwrap();
        assert_eq!(report.actions.len(), 1);
        assert_eq!(report.times.len(), 1);
        assert!(report.failed.is_empty(), "{:?}", report.failed);
        let a = report.actions[0];
        assert_eq!((a.object, a.position, a.old_node), (object, 3, 3));
        assert!(a.new_node == 8 || a.new_node == 9, "newcomer off-chain: {a:?}");
        assert_eq!(placements[0].chain[3], a.new_node);
        // byte-identical regeneration on the newcomer
        let rebuilt = cluster
            .node(a.new_node)
            .peek(BlockKey::coded(object, 3))
            .unwrap()
            .unwrap();
        assert_eq!(*rebuilt, original);
        // and the rebound chain decodes the object
        let rec = reconstruct(&cluster, &code, &placements[0].chain, object, &backend).unwrap();
        assert_eq!(rec, blocks);
    }

    #[test]
    fn bitrot_on_alive_node_is_repaired_in_place() {
        let object = ObjectId(301);
        let (cluster, code, placement, _blocks, backend) = archived(8, 8, 4, 4 * 1024, object);
        let key = BlockKey::coded(object, 5);
        let original = (*cluster.node(5).peek(key).unwrap().unwrap()).clone();
        cluster.node(5).delete(key).unwrap();

        let mut placements = [placement];
        let sched = RepairScheduler::new(RepairStrategy::Star, RepairTrigger::Eager);
        let report = sched
            .repair(&cluster, &code, &mut placements, &backend, &FifoPolicy, 1024)
            .unwrap();
        assert_eq!(report.actions.len(), 1);
        assert_eq!(report.actions[0].new_node, 5, "in-place repair expected");
        assert_eq!(placements[0].chain[5], 5);
        let rebuilt = cluster.node(5).peek(BlockKey::coded(object, 5)).unwrap().unwrap();
        assert_eq!(*rebuilt, original);
    }

    #[test]
    fn lazy_trigger_defers_below_threshold_then_fires() {
        let object = ObjectId(302);
        let (cluster, code, placement, _blocks, backend) = archived(12, 8, 4, 4 * 1024, object);
        cluster.fail_node(1);

        let mut placements = [placement];
        let sched = RepairScheduler::new(
            RepairStrategy::Pipelined,
            RepairTrigger::Lazy { min_missing: 2 },
        );
        let report = sched
            .repair(&cluster, &code, &mut placements, &backend, &FifoPolicy, 1024)
            .unwrap();
        assert!(report.actions.is_empty());
        assert_eq!(report.deferred, vec![object]);
        assert_eq!(placements[0].chain[1], 1, "deferred chain must not move");

        cluster.fail_node(6);
        let report = sched
            .repair(&cluster, &code, &mut placements, &backend, &FifoPolicy, 1024)
            .unwrap();
        assert_eq!(report.actions.len(), 2);
        assert!(report.deferred.is_empty());
        for a in &report.actions {
            assert!(!cluster.is_failed(a.new_node));
            assert!(cluster
                .node(a.new_node)
                .peek(BlockKey::coded(object, a.position))
                .unwrap()
                .is_some());
        }
    }

    #[test]
    fn reliability_budget_breach_triggers_eager_repair() {
        use crate::coordinator::survey_coded;
        use crate::reliability::{census_survival_prob, nines};
        let object = ObjectId(306);
        let (cluster, code, placement, _blocks, backend) = archived(10, 8, 4, 4 * 1024, object);
        cluster.fail_node(2);
        let (avail, _) = survey_coded(&cluster, &placement.chain, object);
        assert_eq!(avail.len(), 7);
        let p_node = 0.1;
        let have = nines(census_survival_prob(code.generator(), &avail, p_node));

        // budget above the current census -> breach -> repair fires
        let mut placements = [placement];
        let sched = RepairScheduler::new(
            RepairStrategy::Pipelined,
            RepairTrigger::ReliabilityBudget {
                min_nines: have + 1,
                p_node,
            },
        );
        let report = sched
            .repair(&cluster, &code, &mut placements, &backend, &FifoPolicy, 1024)
            .unwrap();
        assert_eq!(report.actions.len(), 1, "budget breach must repair");
        assert!(report.deferred.is_empty());
        assert!(!cluster.is_failed(report.actions[0].new_node));
        assert!(cluster
            .node(report.actions[0].new_node)
            .peek(BlockKey::coded(object, 2))
            .unwrap()
            .is_some());
    }

    #[test]
    fn reliability_budget_within_budget_defers() {
        use crate::coordinator::survey_coded;
        use crate::reliability::{census_survival_prob, nines};
        let object = ObjectId(307);
        let (cluster, code, placement, _blocks, backend) = archived(10, 8, 4, 4 * 1024, object);
        cluster.fail_node(4);
        let (avail, _) = survey_coded(&cluster, &placement.chain, object);
        let p_node = 0.1;
        let have = nines(census_survival_prob(code.generator(), &avail, p_node));
        assert!(have >= 1, "7 survivors of an (8,4) code clear one nine");

        // census still meets the budget -> the degraded object is deferred
        let mut placements = [placement];
        let sched = RepairScheduler::new(
            RepairStrategy::Star,
            RepairTrigger::ReliabilityBudget {
                min_nines: have,
                p_node,
            },
        );
        let report = sched
            .repair(&cluster, &code, &mut placements, &backend, &FifoPolicy, 1024)
            .unwrap();
        assert!(report.actions.is_empty());
        assert_eq!(report.deferred, vec![object]);
        assert_eq!(placements[0].chain[4], 4, "deferred chain must not move");
    }

    #[test]
    fn newcomer_ranking_avoids_congested_spare() {
        let object = ObjectId(303);
        let (cluster, code, placement, _blocks, backend) = archived(10, 8, 4, 4 * 1024, object);
        // two spares: congest node 8 so the ranking prefers node 9
        cluster.congest(8, &crate::cluster::CongestionSpec::mild());
        cluster.fail_node(0);

        let mut placements = [placement];
        let sched = RepairScheduler::new(RepairStrategy::Star, RepairTrigger::Eager);
        let report = sched
            .repair(
                &cluster,
                &code,
                &mut placements,
                &backend,
                &CongestionAwarePolicy,
                1024,
            )
            .unwrap();
        assert_eq!(report.actions[0].new_node, 9, "{:?}", report.actions);
    }

    #[test]
    fn adaptive_sourcing_beats_static_on_congested_survivors() {
        // (8,4) archived on nodes 0..8 of a 10-node sim cluster; survivors
        // 1 and 2 then get clamped 100x and node 3 crashes. The static
        // scheduler sources from the first independent subset of the
        // survey order — which includes the clamped survivors — while the
        // adaptive pass ranks them last and repairs entirely from clean
        // nodes. Same regenerated bytes, much shorter critical path.
        let run = |adaptation: Adaptation| -> (Duration, Vec<u8>) {
            let object = ObjectId(308);
            let cluster = Cluster::start(ClusterSpec::test(10).sim());
            let placement = ReplicaPlacement::new(object, 4, (0..8).collect()).unwrap();
            ingest_object(&cluster, &placement, 8 * 1024).unwrap();
            let code = RapidRaidCode::<Gf256>::with_seed(8, 4, 7).unwrap();
            let backend: BackendHandle = Arc::new(NativeBackend::new());
            let job = PipelineJob::from_code(&code, &placement, 2048, 8 * 1024).unwrap();
            archive_pipeline(&cluster, &backend, &job).unwrap();
            for id in [1usize, 2] {
                cluster.congest(
                    id,
                    &crate::cluster::CongestionSpec {
                        bytes_per_sec: 1e7,
                        extra_latency: Duration::ZERO,
                        jitter: Duration::ZERO,
                    },
                );
            }
            cluster.fail_node(3);
            let mut placements = [placement];
            let sched = RepairScheduler::new(RepairStrategy::Pipelined, RepairTrigger::Eager)
                .with_adaptation(adaptation);
            let report = sched
                .repair(&cluster, &code, &mut placements, &backend, &FifoPolicy, 2048)
                .unwrap();
            assert_eq!(report.actions.len(), 1, "{:?}", report.unschedulable);
            let a = report.actions[0];
            assert_eq!((a.object, a.position), (object, 3));
            let rebuilt = cluster
                .node(a.new_node)
                .peek(BlockKey::coded(object, 3))
                .unwrap()
                .unwrap();
            (report.times[0], (*rebuilt).clone())
        };
        let (t_static, b_static) = run(Adaptation::Off);
        let (t_adaptive, b_adaptive) = run(Adaptation::On);
        assert_eq!(
            b_static, b_adaptive,
            "every independent k-subset regenerates the same lost block"
        );
        assert!(
            t_adaptive < t_static,
            "adaptive {t_adaptive:?} must beat static {t_static:?}"
        );
    }

    #[test]
    fn unrepairable_object_is_reported_without_starving_others() {
        let doomed = ObjectId(304);
        let healthy = ObjectId(305);
        let (cluster, code, doomed_placement, _blocks, backend) =
            archived(10, 8, 4, 4 * 1024, doomed);
        // second object on the same cluster, one repairable missing block
        let healthy_placement = ReplicaPlacement::new(healthy, 4, (0..8).collect()).unwrap();
        ingest_object(&cluster, &healthy_placement, 4 * 1024).unwrap();
        let job = PipelineJob::from_code(&code, &healthy_placement, 2048, 4 * 1024).unwrap();
        archive_pipeline(&cluster, &backend, &job).unwrap();
        cluster.node(7).delete(BlockKey::coded(healthy, 7)).unwrap();
        // lose more than n-k blocks of the doomed object: unrepairable
        for pos in 0..6 {
            cluster.node(pos).delete(BlockKey::coded(doomed, pos)).unwrap();
        }

        let mut placements = [doomed_placement, healthy_placement];
        let sched = RepairScheduler::new(RepairStrategy::Star, RepairTrigger::Eager);
        let report = sched
            .repair(&cluster, &code, &mut placements, &backend, &FifoPolicy, 1024)
            .unwrap();
        // the doomed object is reported, the healthy one still repaired
        assert_eq!(report.unschedulable.len(), 1);
        assert_eq!(report.unschedulable[0].0, doomed);
        let (_, reason) = &report.unschedulable[0];
        assert!(reason.contains("unrepairable"), "{reason}");
        assert_eq!(report.actions.len(), 1);
        assert_eq!(report.actions[0].object, healthy);
        assert!(cluster.node(7).peek(BlockKey::coded(healthy, 7)).unwrap().is_some());
    }
}
