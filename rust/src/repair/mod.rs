//! Failure & repair subsystem: regenerate lost codeword blocks as
//! archival plans.
//!
//! After `Cluster::fail_node` (or plain bitrot) a chain is missing coded
//! blocks. Repair rebuilds each lost block `c_lost` as the linear
//! combination `Σ ψ_i · c_{S[i]}` over an independent k-subset S of
//! survivors, with ψ = g_lost · G_S⁻¹ computed in
//! [`crate::codes::rapidraid::RapidRaidCode::repair_coefficients`]. Two
//! planners lower the *same* combination onto the
//! [`crate::coordinator::plan::ArchivalPlan`] IR and run on the shared
//! [`crate::coordinator::engine::PlanExecutor`] — no bespoke orchestration
//! lives here:
//!
//! * [`star::StarRepairJob`] — the classical baseline: the k survivors all
//!   stream to the newcomer (`Source` steps into one 1×k `Gemm` that
//!   `Store`s locally). The newcomer's download NIC serializes everything:
//!   `T_star ≈ k·τ_block` — repair traffic is exactly the k-transfer cost
//!   Dimakis et al. identify as the dominant price of erasure coding.
//! * [`pipeline::PipelinedRepairJob`] — repair pipelining (Li et al.,
//!   2019) over any aggregation
//!   [`Topology`](crate::coordinator::topology::Topology): the survivors
//!   re-aggregate the ψ-weighted partial sums buffer by buffer toward a
//!   root delivering to the newcomer. The chain shape gives
//!   `T_pipe ≈ τ_block + (k−1)·τ_buf` — single-block repair in about one
//!   blocktime; tree shapes cut the hop tail to the shape depth and
//!   confine slow survivors to their own subtrees.
//!
//! [`scheduler::RepairScheduler`] scans placements for missing blocks,
//! picks newcomers through the executor's
//! [`ChainPolicy`](crate::coordinator::engine::ChainPolicy) ranking, and
//! drives eager or lazy (threshold-triggered) repair through
//! `PlanExecutor::run_many_bounded`.

pub mod pipeline;
pub mod scheduler;
pub mod star;

pub use pipeline::{run_pipelined_repair, PipelinedRepairJob};
pub use scheduler::{
    RepairAction, RepairReport, RepairScheduler, RepairStrategy, RepairTrigger,
};
pub use star::{run_star_repair, StarRepairJob};

use crate::backend::Width;
use crate::cluster::NodeId;
use crate::codes::CodeView;
use crate::gf::{GfElem, SliceOps};
use crate::storage::ObjectId;

/// One single-block repair, field-erased: everything both planners need to
/// lower `c_lost = Σ ψ_i · c_{sources[i].1}` onto a plan.
#[derive(Clone, Debug)]
pub struct RepairJob {
    /// Object being repaired.
    pub object: ObjectId,
    /// GF width.
    pub width: Width,
    /// Codeword index of the lost block.
    pub lost: usize,
    /// Node that will store the regenerated block.
    pub newcomer: NodeId,
    /// The k survivors: (node, codeword position) per repair source.
    pub sources: Vec<(NodeId, usize)>,
    /// Repair coefficients ψ, one per source.
    pub psi: Vec<u32>,
    /// Network frame size.
    pub buf_bytes: usize,
    /// Coded block size.
    pub block_bytes: usize,
}

impl RepairJob {
    /// Bind a repair of `object`'s block `lost` to the cluster: survivors
    /// come from `avail` (their chain positions), the coefficients from the
    /// code's generator — any [`CodeView`], so chain and topology codes
    /// repair through the same path. `chain[pos]` is the node holding
    /// `c_pos`.
    #[allow(clippy::too_many_arguments)]
    pub fn from_code<F: GfElem + SliceOps, C: CodeView<F>>(
        code: &C,
        object: ObjectId,
        chain: &[NodeId],
        lost: usize,
        newcomer: NodeId,
        avail: &[usize],
        buf_bytes: usize,
        block_bytes: usize,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(chain.len() == code.n(), "chain/code mismatch");
        let width = Width::for_bits(F::BITS)?;
        let (subset, psi) = code.repair_coefficients(lost, avail)?;
        let sources = subset.iter().map(|&p| (chain[p], p)).collect();
        Ok(Self {
            object,
            width,
            lost,
            newcomer,
            sources,
            psi: psi.iter().map(|c| c.to_u32()).collect(),
            buf_bytes,
            block_bytes,
        })
    }

    /// Number of repair sources (the code's k).
    pub fn k(&self) -> usize {
        self.sources.len()
    }
}
