//! Pipelined repair — Li et al.'s repair pipelining as a plan builder,
//! over any aggregation [`Topology`].
//!
//! The k survivors form an aggregation shape: each slot folds
//! `ψ_i · c_{s_i}` into the partial sums arriving from its children and
//! forwards toward the root, whose completed `c_lost` lands on the
//! newcomer. The paper-faithful chain gives
//! `T_pipe ≈ τ_block + (k−1)·τ_buf` instead of star repair's `k·τ_block`;
//! tree shapes shorten the hop tail to the shape depth and confine a slow
//! survivor to its own subtree. All wiring lives in
//! [`crate::coordinator::topology::lower_aggregate`] — this module only
//! binds survivors to slots (a survivor co-located with the newcomer
//! becomes the root, so the result is stored without a self-link).

use std::time::Duration;

use crate::backend::BackendHandle;
use crate::cluster::Cluster;
use crate::coordinator::engine::PlanExecutor;
use crate::coordinator::plan::ArchivalPlan;
use crate::coordinator::topology::{lower_aggregate, Topology};
use crate::storage::BlockKey;

use super::RepairJob;

/// Topology-shaped single-block repair: an aggregation of `Fold`/fan-in
/// `Gemm` steps over the survivors, delivering into the newcomer.
#[derive(Clone, Debug)]
pub struct PipelinedRepairJob {
    /// The bound repair.
    pub job: RepairJob,
    /// Aggregation shape over the k survivors.
    pub topology: Topology,
}

impl PipelinedRepairJob {
    /// Wrap a bound repair in the chain-shaped lowering (the paper-faithful
    /// Li et al. pipeline).
    pub fn new(job: RepairJob) -> Self {
        Self {
            job,
            topology: Topology::Chain,
        }
    }

    /// Wrap a bound repair in an arbitrary aggregation shape.
    pub fn with_topology(job: RepairJob, topology: Topology) -> Self {
        Self { job, topology }
    }

    /// Lower onto the plan IR. A survivor co-located with the newcomer
    /// (in-place repair) takes the root slot and stores the result from
    /// its own merge (`ξ = ψ`), since the IR expresses locality without
    /// self-links; otherwise the root streams into a `Store` on the
    /// newcomer.
    pub fn plan(&self) -> anyhow::Result<ArchivalPlan> {
        let j = &self.job;
        anyhow::ensure!(!j.sources.is_empty(), "repair with no sources");
        anyhow::ensure!(j.psi.len() == j.sources.len(), "ψ/source arity mismatch");
        let k = j.sources.len();
        // Slot binding: the co-located survivor (if any) is the root, the
        // rest keep their order.
        let colocated = (0..k).find(|&i| j.sources[i].0 == j.newcomer);
        let mut order: Vec<usize> = Vec::with_capacity(k);
        if let Some(c) = colocated {
            order.push(c);
        }
        order.extend((0..k).filter(|&i| colocated != Some(i)));
        let slot_sources: Vec<_> = order.iter().map(|&i| j.sources[i]).collect();
        let slot_psi: Vec<u32> = order.iter().map(|&i| j.psi[i]).collect();
        let shape = self.topology.shape(k)?;
        lower_aggregate(
            j.object,
            j.width,
            &slot_sources,
            &slot_psi,
            &shape,
            j.newcomer,
            BlockKey::coded(j.object, j.lost),
            j.buf_bytes,
            j.block_bytes,
        )
    }
}

/// Execute one pipelined repair through the shared engine; returns the
/// end-to-end repair time.
pub fn run_pipelined_repair(
    cluster: &Cluster,
    backend: &BackendHandle,
    job: &PipelinedRepairJob,
) -> anyhow::Result<Duration> {
    PlanExecutor::new(cluster, backend.clone()).run(&job.plan()?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::Width;
    use crate::coordinator::plan::StepKind;
    use crate::storage::ObjectId;

    fn job(newcomer: usize) -> PipelinedRepairJob {
        PipelinedRepairJob::new(RepairJob {
            object: ObjectId(2),
            width: Width::W16,
            lost: 5,
            newcomer,
            sources: vec![(0, 0), (1, 1), (2, 2), (3, 3)],
            psi: vec![2, 4, 6, 8],
            buf_bytes: 1024,
            block_bytes: 8192,
        })
    }

    #[test]
    fn plan_is_fold_chain_into_store() {
        let plan = job(9).plan().unwrap();
        plan.validate().unwrap();
        assert_eq!(plan.len(), 5); // 4 folds + 1 store
        assert_eq!(plan.edges.len(), 4); // a line, no fan-out
        let folds: Vec<_> = plan
            .steps
            .iter()
            .filter(|s| matches!(s.kind, StepKind::Fold { .. }))
            .collect();
        assert_eq!(folds.len(), 4);
        let store = plan
            .steps
            .iter()
            .find(|s| matches!(s.kind, StepKind::Store { .. }))
            .expect("store step");
        assert_eq!(store.node, 9);
        // intermediate folds relay only (no store, ξ irrelevant)
        for s in &folds {
            match &s.kind {
                StepKind::Fold { store, .. } => assert!(store.is_none()),
                _ => unreachable!(),
            }
        }
    }

    #[test]
    fn colocated_survivor_stores_from_its_own_fold() {
        // newcomer == survivor node 1: it takes the root slot, merges with
        // ξ = ψ and stores; no separate Store step, no self-link.
        let plan = job(1).plan().unwrap();
        plan.validate().unwrap();
        assert_eq!(plan.len(), 4); // pure fold chain
        assert_eq!(plan.edges.len(), 3);
        let storing: Vec<_> = plan
            .steps
            .iter()
            .filter(|s| matches!(&s.kind, StepKind::Fold { store: Some(_), .. }))
            .collect();
        assert_eq!(storing.len(), 1);
        let root = storing[0];
        assert_eq!(root.node, 1);
        match &root.kind {
            StepKind::Fold { psi, xi, .. } => assert_eq!(psi, xi),
            other => panic!("expected fold root, got {other:?}"),
        }
    }

    #[test]
    fn tree_repair_plan_merges_with_gemm() {
        let mut j = job(9);
        j.topology = Topology::Tree { fanout: 2 };
        let plan = j.plan().unwrap();
        plan.validate().unwrap();
        // tree:2 over 4 slots: the root merges two child partials via a
        // 1-row gemm, slot 1 chains one child, slots 2/3 are leaf folds
        assert_eq!(plan.len(), 5);
        let gemms = plan
            .steps
            .iter()
            .filter(|s| matches!(s.kind, StepKind::Gemm { .. }))
            .count();
        assert_eq!(gemms, 1);
        assert!(plan
            .steps
            .iter()
            .any(|s| matches!(s.kind, StepKind::Store { .. }) && s.node == 9));
    }
}
