//! Pipelined repair — Li et al.'s repair pipelining as a plan builder.
//!
//! The k survivors form a chain of [`StepKind::Fold`] steps: survivor i
//! receives the running ψ-weighted partial sum, folds `ψ_i · c_{s_i}` into
//! it buffer by buffer, and forwards it; the tail delivers the completed
//! `c_lost` to a [`StepKind::Store`] on the newcomer. Exactly like the
//! encode pipeline, the hops overlap: `T_pipe ≈ τ_block + (k−1)·τ_buf`
//! instead of star repair's `k·τ_block` — single-block repair in about one
//! blocktime.

use std::time::Duration;

use crate::backend::BackendHandle;
use crate::cluster::Cluster;
use crate::coordinator::engine::PlanExecutor;
use crate::coordinator::plan::{ArchivalPlan, StepId, StepKind};
use crate::storage::BlockKey;

use super::RepairJob;

/// Chained single-block repair: a head→tail line of `Fold` steps over the
/// survivors, delivering into a `Store` on the newcomer.
#[derive(Clone, Debug)]
pub struct PipelinedRepairJob {
    /// The bound repair.
    pub job: RepairJob,
}

impl PipelinedRepairJob {
    /// Wrap a bound repair in the pipelined lowering.
    pub fn new(job: RepairJob) -> Self {
        Self { job }
    }

    /// Lower onto the plan IR. A survivor co-located with the newcomer
    /// (in-place repair) is ordered last and stores the result from its own
    /// fold (`ξ = ψ`), since the IR expresses locality without self-links;
    /// otherwise the tail fold streams into a `Store` on the newcomer.
    pub fn plan(&self) -> anyhow::Result<ArchivalPlan> {
        let j = &self.job;
        anyhow::ensure!(!j.sources.is_empty(), "repair with no sources");
        anyhow::ensure!(j.psi.len() == j.sources.len(), "ψ/source arity mismatch");
        let mut plan = ArchivalPlan::new(j.object, j.width, j.buf_bytes, j.block_bytes);
        let out_key = BlockKey::coded(j.object, j.lost);

        let local_tail = (0..j.sources.len()).find(|&i| j.sources[i].0 == j.newcomer);
        let mut order: Vec<usize> =
            (0..j.sources.len()).filter(|&i| j.sources[i].0 != j.newcomer).collect();
        if let Some(t) = local_tail {
            order.push(t);
        }

        let mut prev: Option<StepId> = None;
        for &i in &order {
            let (node, pos) = j.sources[i];
            let stores_here = local_tail == Some(i);
            let id = plan.add_step(
                node,
                StepKind::Fold {
                    locals: vec![BlockKey::coded(j.object, pos)],
                    psi: vec![j.psi[i]],
                    xi: vec![if stores_here { j.psi[i] } else { 0 }],
                    store: stores_here.then_some(out_key),
                },
            );
            if let Some(p) = prev {
                plan.connect(p, 0, id, 0);
            }
            prev = Some(id);
        }
        if local_tail.is_none() {
            let store = plan.add_step(j.newcomer, StepKind::Store { key: out_key });
            plan.connect(prev.expect("nonempty sources"), 0, store, 0);
        }
        Ok(plan)
    }
}

/// Execute one pipelined repair through the shared engine; returns the
/// end-to-end repair time.
pub fn run_pipelined_repair(
    cluster: &Cluster,
    backend: &BackendHandle,
    job: &PipelinedRepairJob,
) -> anyhow::Result<Duration> {
    PlanExecutor::new(cluster, backend.clone()).run(&job.plan()?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::Width;
    use crate::storage::ObjectId;

    fn job(newcomer: usize) -> PipelinedRepairJob {
        PipelinedRepairJob::new(RepairJob {
            object: ObjectId(2),
            width: Width::W16,
            lost: 5,
            newcomer,
            sources: vec![(0, 0), (1, 1), (2, 2), (3, 3)],
            psi: vec![2, 4, 6, 8],
            buf_bytes: 1024,
            block_bytes: 8192,
        })
    }

    #[test]
    fn plan_is_fold_chain_into_store() {
        let plan = job(9).plan().unwrap();
        plan.validate().unwrap();
        assert_eq!(plan.len(), 5); // 4 folds + 1 store
        assert_eq!(plan.edges.len(), 4); // a line, no fan-out
        assert!(plan.steps[..4]
            .iter()
            .all(|s| matches!(s.kind, StepKind::Fold { .. })));
        assert!(matches!(plan.steps[4].kind, StepKind::Store { .. }));
        assert_eq!(plan.steps[4].node, 9);
        // intermediate folds relay only (no store, ξ irrelevant)
        for s in &plan.steps[..4] {
            match &s.kind {
                StepKind::Fold { store, .. } => assert!(store.is_none()),
                _ => unreachable!(),
            }
        }
    }

    #[test]
    fn colocated_survivor_stores_from_its_own_fold() {
        // newcomer == survivor node 1: it folds last with ξ = ψ and stores;
        // no separate Store step, no self-link.
        let plan = job(1).plan().unwrap();
        plan.validate().unwrap();
        assert_eq!(plan.len(), 4); // pure fold chain
        assert_eq!(plan.edges.len(), 3);
        let tail = plan.steps.last().unwrap();
        assert_eq!(tail.node, 1);
        match &tail.kind {
            StepKind::Fold { psi, xi, store, .. } => {
                assert_eq!(psi, xi);
                assert!(store.is_some());
            }
            other => panic!("expected fold tail, got {other:?}"),
        }
    }
}
