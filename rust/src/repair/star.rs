//! Star repair — the classical single-block repair baseline.
//!
//! All k survivors stream their coded block to the newcomer, which applies
//! the 1×k repair row ψ as one streamed [`StepKind::Gemm`] and stores the
//! regenerated block locally. The newcomer's download NIC serializes the k
//! arrivals, so `T_star ≈ k·τ_block` — the repair-traffic cost the
//! pipelined planner exists to beat.

use std::time::Duration;

use crate::backend::BackendHandle;
use crate::cluster::Cluster;
use crate::coordinator::engine::PlanExecutor;
use crate::coordinator::plan::{ArchivalPlan, GemmInput, GemmOutput, StepKind};
use crate::storage::BlockKey;

use super::RepairJob;

/// Atomic single-block repair: k `Source` streams into one 1×k `Gemm` on
/// the newcomer (stored in place).
#[derive(Clone, Debug)]
pub struct StarRepairJob {
    /// The bound repair.
    pub job: RepairJob,
}

impl StarRepairJob {
    /// Wrap a bound repair in the star lowering.
    pub fn new(job: RepairJob) -> Self {
        Self { job }
    }

    /// Lower onto the plan IR: one gemm on the newcomer whose row is ψ;
    /// every remote survivor contributes a `Source` stream, a survivor
    /// co-located with the newcomer (in-place repair) is read locally.
    pub fn plan(&self) -> anyhow::Result<ArchivalPlan> {
        let j = &self.job;
        anyhow::ensure!(!j.sources.is_empty(), "repair with no sources");
        anyhow::ensure!(j.psi.len() == j.sources.len(), "ψ/source arity mismatch");
        let mut plan = ArchivalPlan::new(j.object, j.width, j.buf_bytes, j.block_bytes);
        let inputs: Vec<GemmInput> = j
            .sources
            .iter()
            .map(|&(node, pos)| {
                if node == j.newcomer {
                    GemmInput::Local(BlockKey::coded(j.object, pos))
                } else {
                    GemmInput::Stream
                }
            })
            .collect();
        let gemm = plan.add_step(
            j.newcomer,
            StepKind::Gemm {
                rows: vec![j.psi.clone()],
                inputs,
                outputs: vec![GemmOutput::Store(BlockKey::coded(j.object, j.lost))],
            },
        );
        for (i, &(node, pos)) in j.sources.iter().enumerate() {
            if node != j.newcomer {
                let s = plan.add_step(
                    node,
                    StepKind::Source {
                        key: BlockKey::coded(j.object, pos),
                    },
                );
                plan.connect(s, 0, gemm, i);
            }
        }
        Ok(plan)
    }
}

/// Execute one star repair through the shared engine; returns the
/// end-to-end repair time.
pub fn run_star_repair(
    cluster: &Cluster,
    backend: &BackendHandle,
    job: &StarRepairJob,
) -> anyhow::Result<Duration> {
    PlanExecutor::new(cluster, backend.clone()).run(&job.plan()?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::Width;
    use crate::storage::ObjectId;

    fn job(newcomer: usize) -> StarRepairJob {
        StarRepairJob::new(RepairJob {
            object: ObjectId(1),
            width: Width::W8,
            lost: 3,
            newcomer,
            sources: vec![(0, 0), (1, 1), (2, 2)],
            psi: vec![5, 9, 11],
            buf_bytes: 1024,
            block_bytes: 4096,
        })
    }

    #[test]
    fn plan_is_k_sources_into_one_gemm() {
        let plan = job(7).plan().unwrap();
        plan.validate().unwrap();
        assert_eq!(plan.len(), 4); // 3 sources + 1 gemm
        assert_eq!(plan.edges.len(), 3);
        assert!(matches!(plan.steps[0].kind, StepKind::Gemm { .. }));
        assert_eq!(plan.steps[0].node, 7);
    }

    #[test]
    fn colocated_survivor_becomes_local_input() {
        // newcomer == survivor node 1: its block is read locally, 2 streams
        let plan = job(1).plan().unwrap();
        plan.validate().unwrap();
        assert_eq!(plan.len(), 3); // 2 sources + 1 gemm
        assert_eq!(plan.edges.len(), 2);
        match &plan.steps[0].kind {
            StepKind::Gemm { inputs, .. } => {
                assert!(matches!(inputs[1], GemmInput::Local(_)));
                assert!(matches!(inputs[0], GemmInput::Stream));
            }
            other => panic!("expected gemm, got {other:?}"),
        }
    }
}
