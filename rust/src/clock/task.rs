//! Task wakeups for the multiplexed discrete-event runtime.
//!
//! The multiplexed dataplane (`cluster::runtime`) runs every node loop and
//! plan-step worker as a cooperatively-scheduled task on one *driver*
//! thread. The driver is a single `SimClock` participant; when no task is
//! runnable it parks on the clock condvar via [`WakeHub::park`]. Two things
//! can un-park it:
//!
//! * a virtual deadline (the driver registers the earliest task timer as a
//!   clock sleeper, so quiescence advances time exactly like a parked
//!   thread would), or
//! * a message sent to a channel a task is reading — the sender fires the
//!   channel's registered [`TaskWaker`] *under the clock lock*, which both
//!   queues the task id and hands the parked driver a busy **credit** (the
//!   same send→wake handoff `clock::chan` uses for threads), so virtual
//!   time can never slip between the send and the driver resuming.
//!
//! Lock order is always clock state → hub state (the hub mutex is only
//! ever taken while the clock lock is held, mirroring how `clock::chan`
//! nests its queue mutex), so the pair can never deadlock.

use std::sync::{Arc, Mutex};

use super::sim::{SimClock, State};
use super::Tick;

/// Identifier of a task on a multiplexed driver (driver-local, dense).
pub(crate) type TaskId = usize;

#[derive(Debug, Default)]
struct HubState {
    /// Task ids woken since the driver last drained (may hold duplicates;
    /// the driver dedupes with its per-task ready flag).
    pending: Vec<TaskId>,
    /// Driver is parked on the clock condvar.
    parked: bool,
    /// A waker already re-counted the parked driver as busy (at most one
    /// credit per park episode — the driver absorbs it on wakeup).
    credit: bool,
}

/// Wake mailbox shared between one driver thread and the channel senders
/// that feed its tasks.
#[derive(Debug, Default)]
pub(crate) struct WakeHub {
    state: Mutex<HubState>,
}

impl WakeHub {
    pub(crate) fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Queue `task` as runnable. Must be called with the clock state lock
    /// held (`st`); if the driver is parked this re-counts it busy at the
    /// current instant (wake credit). Returns `true` if the caller should
    /// notify the clock condvar once it releases the clock lock.
    pub(crate) fn wake_locked(&self, st: &mut State, task: TaskId) -> bool {
        let mut hub = self.state.lock().unwrap();
        hub.pending.push(task);
        if hub.parked {
            if !hub.credit {
                hub.credit = true;
                st.busy += 1;
            }
            true
        } else {
            false
        }
    }

    /// Park the driver until a waker fires or `deadline` (if any) is
    /// reached on the virtual clock. Returns the drained wake list (empty
    /// on a pure deadline wakeup). The driver must be a counted
    /// participant; its busy slot is released for the duration of the park
    /// so quiescence can advance time.
    pub(crate) fn park(&self, clock: &SimClock, deadline: Option<Tick>) -> Vec<TaskId> {
        let mut st = clock.lock();
        {
            let mut hub = self.state.lock().unwrap();
            if !hub.pending.is_empty() {
                // Wakes raced in before we parked: stay busy, just drain.
                return std::mem::take(&mut hub.pending);
            }
            hub.parked = true;
        }
        st.busy -= 1;
        if let Some(d) = deadline {
            st.add_sleeper(d);
        }
        st.try_advance(clock.cv());
        loop {
            if !self.state.lock().unwrap().pending.is_empty() {
                break;
            }
            if let Some(d) = deadline {
                if st.now >= d {
                    break;
                }
            }
            st = clock.wait(st);
        }
        // Remove our sleeper entry only after reacquiring the lock, so a
        // just-expired deadline keeps pinning `now` until we actually run
        // (same rule as `SimClock::sleep_until`).
        if let Some(d) = deadline {
            st.remove_sleeper(d);
        }
        let woken = {
            let mut hub = self.state.lock().unwrap();
            hub.parked = false;
            if hub.credit {
                hub.credit = false; // a waker already counted us busy
            } else {
                st.busy += 1;
            }
            std::mem::take(&mut hub.pending)
        };
        st.try_advance(clock.cv());
        woken
    }
}

/// A registration that lets a channel sender wake one task on one driver.
#[derive(Clone, Debug)]
pub(crate) struct TaskWaker {
    hub: Arc<WakeHub>,
    task: TaskId,
}

impl TaskWaker {
    pub(crate) fn new(hub: Arc<WakeHub>, task: TaskId) -> Self {
        Self { hub, task }
    }

    /// Fire the waker with the clock state lock held. Returns `true` if
    /// the caller should notify the clock condvar after unlocking.
    pub(crate) fn wake_locked(&self, st: &mut State) -> bool {
        self.hub.wake_locked(st, self.task)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::{BusyToken, Clock, ClockHandle};
    use std::time::Duration;

    #[test]
    fn deadline_park_advances_time() {
        let clock = SimClock::new();
        let handle: ClockHandle = Arc::new(clock.clone());
        let _busy = BusyToken::new(&handle).bind();
        let hub = WakeHub::new();
        let woken = hub.park(&clock, Some(Duration::from_secs(3)));
        assert!(woken.is_empty());
        assert_eq!(clock.now(), Duration::from_secs(3));
    }

    #[test]
    fn wake_credit_reaches_parked_driver() {
        let clock = SimClock::new();
        let handle: ClockHandle = Arc::new(clock.clone());
        let hub = WakeHub::new();
        let (hub2, clock2) = (hub.clone(), clock.clone());
        let token = BusyToken::new(&handle);
        let driver = std::thread::spawn(move || {
            let _busy = token.bind();
            hub2.park(&clock2, Some(Duration::from_secs(60)))
        });
        // Wait until the driver has actually parked, then wake task 7.
        loop {
            std::thread::sleep(Duration::from_millis(1));
            let mut st = clock.lock();
            let fired = hub.wake_locked(&mut st, 7);
            if fired {
                drop(st);
                clock.notify_all();
                break;
            }
            // not parked yet: retract the premature wake and retry
            hub.state.lock().unwrap().pending.clear();
        }
        let woken = driver.join().unwrap();
        assert_eq!(woken, vec![7]);
        assert!(
            clock.now() < Duration::from_secs(60),
            "deadline fired instead of the waker"
        );
    }

    #[test]
    fn pre_park_wakes_drain_without_parking() {
        let clock = SimClock::new();
        let handle: ClockHandle = Arc::new(clock.clone());
        let _busy = BusyToken::new(&handle).bind();
        let hub = WakeHub::new();
        {
            let mut st = clock.lock();
            assert!(!hub.wake_locked(&mut st, 1), "not parked: no notify");
            hub.wake_locked(&mut st, 2);
        }
        let woken = hub.park(&clock, None);
        assert_eq!(woken, vec![1, 2]);
        assert_eq!(clock.now(), Duration::ZERO, "never slept");
    }
}
