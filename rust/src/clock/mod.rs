//! Time as a pluggable dependency: every dataplane wait goes through a
//! [`Clock`], so the same simulator runs in real time ([`RealClock`] — the
//! paper-faithful wall-clock testbeds) or in discrete-event virtual time
//! ([`SimClock`] — paper-scale scenarios in milliseconds, deterministically).
//!
//! A [`Tick`] is a point on the clock's timeline (elapsed time since the
//! clock's epoch). NIC reservations, link delivery instants, node stall
//! deadlines and metric spans are all expressed in ticks; only the clock
//! implementation decides whether a tick costs wall time.
//!
//! ## The discrete-event contract
//!
//! [`SimClock`] advances virtual time to the earliest pending deadline
//! exactly when the whole dataplane is quiescent: no *participant* thread
//! is runnable and no message is in flight on a clock [`channel`].
//! Three accounting primitives uphold that invariant:
//!
//! * [`BusyToken`]/[`BusyGuard`] — a simulation thread (node loop, data
//!   plane worker, plan collector) registers as a participant. Crucially
//!   the token is created by the *parent* before `thread::spawn`, so there
//!   is never a gap in which a child exists but is uncounted.
//! * [`channel`] — a clock-aware mpsc. A queued message counts as pending
//!   work (time cannot advance past it); a participant blocked in `recv`
//!   counts as idle.
//! * [`blocked`] — brackets any other blocking call (e.g. joining a worker
//!   thread) so the waiter does not pin virtual time.
//!
//! Threads *outside* the simulation (tests, the CLI) never register; they
//! may freely send commands, receive replies and sleep on the clock.

pub mod chan;
pub mod sim;
pub(crate) mod task;

pub use chan::{channel, Receiver, RecvError, RecvTimeoutError, SendError, Sender};
pub use sim::SimClock;

use std::cell::Cell;
use std::fmt;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A point on a clock's timeline: time elapsed since the clock's epoch.
pub type Tick = Duration;

/// Shared handle to a clock.
pub type ClockHandle = Arc<dyn Clock>;

/// The time source behind the simulated cluster.
pub trait Clock: Send + Sync + fmt::Debug {
    /// Current time on this clock's timeline.
    fn now(&self) -> Tick;

    /// Block the caller until `deadline` (no-op if already past).
    fn sleep_until(&self, deadline: Tick);

    /// Block the caller for `d`.
    fn sleep(&self, d: Duration) {
        self.sleep_until(self.now() + d);
    }

    /// How far ahead of its NIC reservation a paced sender may run.
    /// Non-zero only where the underlying sleep overshoots (real time);
    /// a discrete-event clock sleeps exactly, so it needs no slack.
    fn pacing_slack(&self) -> Duration {
        Duration::ZERO
    }

    /// Downcast used by clock channels and busy accounting.
    fn as_sim(&self) -> Option<&SimClock> {
        None
    }
}

thread_local! {
    /// Nesting depth of [`BusyGuard`]s held by the current thread (> 0 ⇒
    /// this thread is a counted simulation participant).
    static PARTICIPANT_DEPTH: Cell<u32> = const { Cell::new(0) };
}

/// Whether the calling thread is a registered simulation participant.
pub(crate) fn is_participant() -> bool {
    PARTICIPANT_DEPTH.with(|d| d.get() > 0)
}

/// A participant registration created on the parent thread, to be bound on
/// the child ([`BusyToken::bind`]). Counts as busy from creation, so the
/// spawn window can never let virtual time slip past a nascent worker.
#[must_use = "bind the token on the spawned thread (or drop it to release)"]
pub struct BusyToken {
    sim: Option<SimClock>,
}

impl BusyToken {
    /// Register one (future) participant with `clock`. No-op on real
    /// clocks.
    pub fn new(clock: &ClockHandle) -> Self {
        let sim = clock.as_sim().cloned();
        if let Some(s) = &sim {
            s.add_busy();
        }
        Self { sim }
    }

    /// Bind the registration to the calling thread; the returned guard
    /// keeps it a counted participant until dropped.
    pub fn bind(mut self) -> BusyGuard {
        let sim = self.sim.take();
        if sim.is_some() {
            PARTICIPANT_DEPTH.with(|d| d.set(d.get() + 1));
        }
        BusyGuard { sim }
    }
}

impl Drop for BusyToken {
    fn drop(&mut self) {
        // Never bound (spawn failed): release the busy slot.
        if let Some(s) = self.sim.take() {
            s.sub_busy();
        }
    }
}

/// Active participant registration for the current thread (see
/// [`BusyToken::bind`]).
pub struct BusyGuard {
    sim: Option<SimClock>,
}

impl Drop for BusyGuard {
    fn drop(&mut self) {
        if let Some(s) = self.sim.take() {
            PARTICIPANT_DEPTH.with(|d| d.set(d.get() - 1));
            s.sub_busy();
        }
    }
}

/// Run a blocking operation (`thread::join`, an un-clocked wait) without
/// pinning virtual time: a participant caller is counted idle for the
/// duration of `f`. No-op bracket for non-participants and real clocks.
pub fn blocked<T>(clock: &ClockHandle, f: impl FnOnce() -> T) -> T {
    match clock.as_sim() {
        Some(sim) if is_participant() => {
            sim.sub_busy();
            let v = f();
            sim.add_busy();
            v
        }
        _ => f(),
    }
}

/// Wall-clock time source: ticks are time since construction, sleeps are
/// hybrid OS-sleep + yield-spin (accurate to ~10 µs on the virtualized
/// single-CPU hosts this simulator targets, where a bare `thread::sleep`
/// overshoots by 0.5–4 ms and would swamp sub-millisecond frame pacing).
#[derive(Debug)]
pub struct RealClock {
    epoch: Instant,
}

impl RealClock {
    /// How far ahead of virtual time a paced sender may run under this
    /// clock: `thread::sleep` overshoot (~1 ms on a loaded 1-CPU host)
    /// per 64 KiB frame (~0.5 ms nominal) would otherwise inflate every
    /// stream 3–4×. Aggregate rates stay exact because NIC bookkeeping is
    /// cumulative and receivers wait for each frame's virtual delivery
    /// instant.
    pub const PACING_SLACK: Duration = Duration::from_millis(4);

    /// A clock whose epoch is "now".
    pub fn new() -> Self {
        Self {
            epoch: Instant::now(),
        }
    }

    /// Fresh handle (the usual way to seed a `ClusterSpec`).
    pub fn handle() -> ClockHandle {
        Arc::new(Self::new())
    }
}

impl Default for RealClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for RealClock {
    fn now(&self) -> Tick {
        self.epoch.elapsed()
    }

    /// Hybrid strategy: OS-sleep to ~2 ms before the deadline, yield-spin
    /// the rest (measured accuracy <10 µs — see DESIGN.md §Perf).
    fn sleep_until(&self, deadline: Tick) {
        const SPIN: Duration = Duration::from_micros(2000);
        let target = self.epoch + deadline;
        let now = Instant::now();
        if target <= now {
            return;
        }
        let remaining = target - now;
        if remaining > SPIN {
            std::thread::sleep(remaining - SPIN);
        }
        while Instant::now() < target {
            std::thread::yield_now();
        }
    }

    fn pacing_slack(&self) -> Duration {
        Self::PACING_SLACK
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn real_clock_advances_and_sleeps() {
        let c = RealClock::new();
        let t0 = c.now();
        c.sleep(Duration::from_millis(5));
        let dt = c.now() - t0;
        assert!(dt >= Duration::from_millis(4), "slept only {dt:?}");
        assert!(dt < Duration::from_secs(1), "gross overshoot: {dt:?}");
    }

    #[test]
    fn real_clock_past_deadline_is_noop() {
        let c = RealClock::new();
        c.sleep_until(Duration::ZERO); // epoch is already behind us
    }

    #[test]
    fn busy_token_on_real_clock_is_noop() {
        let clock: ClockHandle = RealClock::handle();
        let token = BusyToken::new(&clock);
        let _guard = token.bind();
        assert!(!is_participant(), "real clocks never register participants");
        blocked(&clock, || ());
    }

    #[test]
    fn participant_depth_nests() {
        let clock: ClockHandle = SimClock::handle();
        assert!(!is_participant());
        {
            let _g1 = BusyToken::new(&clock).bind();
            assert!(is_participant());
            {
                let _g2 = BusyToken::new(&clock).bind();
                assert!(is_participant());
            }
            assert!(is_participant());
        }
        assert!(!is_participant());
    }

    #[test]
    fn unbound_token_releases_on_drop() {
        let clock: ClockHandle = SimClock::handle();
        let token = BusyToken::new(&clock);
        drop(token);
        // with no busy threads left, a sleep must advance instantly
        let t0 = std::time::Instant::now();
        clock.sleep(Duration::from_secs(3600));
        assert!(t0.elapsed() < Duration::from_secs(5));
        assert_eq!(clock.now(), Duration::from_secs(3600));
    }
}
