//! Discrete-event virtual clock: waiter-wakeup time advancement.
//!
//! Virtual `now` is frozen while any participant thread is runnable; when
//! the whole dataplane blocks, the last thread to go idle advances `now`
//! to the earliest pending deadline and wakes its sleepers. Two rules close
//! the classic wake-races of thread-based discrete-event simulators (time
//! jumping past an event whose handler has not been scheduled yet):
//!
//! * a woken sleeper's heap entry is removed only *after* it reacquires
//!   the lock, so a just-expired deadline keeps pinning `now` until its
//!   thread actually runs;
//! * a message sent to a participant blocked in a clock-channel `recv`
//!   re-counts that receiver as busy at the send instant (`clock::chan`'s
//!   wake credit), so the send→wake handoff is seamless.
//!
//! A 50-node, thousand-virtual-second crash/repair trace runs in
//! milliseconds of wall time under this clock (see `workload::longrun`),
//! and — because nothing ever waits on the OS scheduler — the virtual
//! timeline of uncontended workloads is bit-for-bit reproducible.

use std::collections::BTreeMap;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

use super::{is_participant, Clock, ClockHandle, Tick};

/// Shared discrete-event clock (cheaply cloneable handle).
#[derive(Clone, Debug)]
pub struct SimClock {
    pub(crate) inner: Arc<Inner>,
}

#[derive(Debug)]
pub(crate) struct Inner {
    pub(crate) state: Mutex<State>,
    pub(crate) cv: Condvar,
}

#[derive(Debug)]
pub(crate) struct State {
    /// Current virtual time.
    pub(crate) now: Tick,
    /// Runnable participant threads (see `clock::BusyGuard`). A message
    /// sent to a participant blocked in a clock-channel `recv` immediately
    /// re-counts that receiver as runnable (a *wake credit*, managed by
    /// `clock::chan`), so the send→wake window can never let time slip.
    pub(crate) busy: usize,
    /// Pending sleep deadlines → number of threads waiting on each.
    pub(crate) sleepers: BTreeMap<Tick, usize>,
}

impl State {
    /// If the dataplane is fully quiescent, advance `now` to the earliest
    /// pending deadline and wake everyone to re-check their conditions.
    /// Call after every decrement of `busy`.
    pub(crate) fn try_advance(&mut self, cv: &Condvar) {
        if self.busy == 0 {
            if let Some((&deadline, _)) = self.sleepers.iter().next() {
                if deadline > self.now {
                    self.now = deadline;
                    cv.notify_all();
                }
            }
        }
    }

    pub(crate) fn add_sleeper(&mut self, deadline: Tick) {
        *self.sleepers.entry(deadline).or_insert(0) += 1;
    }

    pub(crate) fn remove_sleeper(&mut self, deadline: Tick) {
        if let Some(c) = self.sleepers.get_mut(&deadline) {
            *c -= 1;
            if *c == 0 {
                self.sleepers.remove(&deadline);
            }
        }
    }
}

impl SimClock {
    /// A virtual clock at tick zero.
    pub fn new() -> Self {
        Self {
            inner: Arc::new(Inner {
                state: Mutex::new(State {
                    now: Tick::ZERO,
                    busy: 0,
                    sleepers: BTreeMap::new(),
                }),
                cv: Condvar::new(),
            }),
        }
    }

    /// Fresh handle (the usual way to seed a `ClusterSpec`).
    pub fn handle() -> ClockHandle {
        Arc::new(Self::new())
    }

    pub(crate) fn lock(&self) -> MutexGuard<'_, State> {
        self.inner.state.lock().unwrap()
    }

    /// The clock's condvar (sleepers + deadline-waiters park here).
    pub(crate) fn cv(&self) -> &Condvar {
        &self.inner.cv
    }

    /// Wait on the clock's condvar with the state lock.
    pub(crate) fn wait<'a>(&self, guard: MutexGuard<'a, State>) -> MutexGuard<'a, State> {
        self.inner.cv.wait(guard).unwrap()
    }

    /// Wake every sleeper/deadline-waiter to re-check its condition.
    pub(crate) fn notify_all(&self) {
        self.inner.cv.notify_all();
    }

    /// Count one more runnable participant.
    pub(crate) fn add_busy(&self) {
        self.lock().busy += 1;
    }

    /// Count one participant gone idle (and maybe advance time).
    pub(crate) fn sub_busy(&self) {
        let mut st = self.lock();
        debug_assert!(st.busy > 0, "busy-count underflow");
        st.busy -= 1;
        st.try_advance(&self.inner.cv);
    }
}

impl Default for SimClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for SimClock {
    fn now(&self) -> Tick {
        self.lock().now
    }

    fn sleep_until(&self, deadline: Tick) {
        let counted = is_participant();
        let mut st = self.lock();
        if st.now >= deadline {
            return;
        }
        if counted {
            st.busy -= 1;
        }
        st.add_sleeper(deadline);
        st.try_advance(&self.inner.cv);
        while st.now < deadline {
            st = self.inner.cv.wait(st).unwrap();
        }
        // Removing our entry only now keeps `now` pinned at (or before) our
        // deadline until we are actually running again — see module docs.
        st.remove_sleeper(deadline);
        if counted {
            st.busy += 1;
        }
        st.try_advance(&self.inner.cv);
    }

    fn as_sim(&self) -> Option<&SimClock> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn starts_at_zero_and_sleep_advances_exactly() {
        let c = SimClock::new();
        assert_eq!(c.now(), Duration::ZERO);
        c.sleep_until(Duration::from_secs(5));
        assert_eq!(c.now(), Duration::from_secs(5));
        c.sleep(Duration::from_millis(1));
        assert_eq!(c.now(), Duration::from_millis(5001));
    }

    #[test]
    fn past_deadline_is_noop() {
        let c = SimClock::new();
        c.sleep_until(Duration::from_secs(1));
        c.sleep_until(Duration::from_millis(10)); // already past
        assert_eq!(c.now(), Duration::from_secs(1));
    }

    #[test]
    fn concurrent_sleepers_wake_in_deadline_order() {
        use super::super::BusyToken;
        let clock: ClockHandle = SimClock::handle();
        let order = Arc::new(Mutex::new(Vec::new()));
        // Hold a busy slot while spawning so time can't advance until every
        // sleeper is registered (exactly how node threads are spawned).
        let barrier = BusyToken::new(&clock);
        let mut handles = Vec::new();
        for (label, ms) in [("b", 20u64), ("a", 10), ("c", 30)] {
            let clock2 = clock.clone();
            let order = order.clone();
            let token = BusyToken::new(&clock);
            handles.push(std::thread::spawn(move || {
                let _busy = token.bind();
                clock2.sleep_until(Duration::from_millis(ms));
                order.lock().unwrap().push(label);
            }));
        }
        drop(barrier);
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(*order.lock().unwrap(), vec!["a", "b", "c"]);
        assert_eq!(clock.now(), Duration::from_millis(30));
    }

    #[test]
    fn busy_participant_pins_time() {
        use super::super::BusyToken;
        let clock: ClockHandle = SimClock::handle();
        let token = BusyToken::new(&clock);
        let c2 = clock.clone();
        // a sleeper can't advance time while a participant is runnable
        let sleeper = std::thread::spawn(move || c2.sleep_until(Duration::from_millis(50)));
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(clock.now(), Duration::ZERO, "advanced under a busy thread");
        drop(token); // participant leaves -> quiescent -> advance
        sleeper.join().unwrap();
        assert_eq!(clock.now(), Duration::from_millis(50));
    }
}
