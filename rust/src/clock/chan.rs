//! Clock-aware mpsc channels.
//!
//! On a [`RealClock`](super::RealClock) these are thin wrappers over
//! `std::sync::mpsc`. On a [`SimClock`] every blocking receive participates
//! in the discrete-event accounting:
//!
//! * a *participant* thread blocked in `recv` counts as idle, so it never
//!   pins virtual time;
//! * a send to such a blocked participant immediately re-counts the
//!   receiver as runnable (a **wake credit**), so between the send and the
//!   receiver actually being scheduled the clock cannot advance — the
//!   handoff is atomic under the clock's lock.
//!
//! Messages queued for a receiver that is *running* (or outside the
//! simulation) need no accounting: the receiver is either already counted
//! busy or is not simulated at all. This keeps multi-stream consumers
//! (e.g. a gemm node draining k source links round-robin) deadlock-free:
//! frames parked on the not-currently-polled links never freeze the clock.
//!
//! Plain `recv` parks on a **per-channel** condvar, so a 50-node cluster
//! of idle node loops is not stampeded by every frame on every link; only
//! [`Receiver::recv_deadline`] — "wait for a message OR a virtual
//! deadline", the primitive behind the node worker-pool's stall-overflow
//! logic — shares the clock's condvar with the sleepers, because a time
//! advance must be able to wake it.

use std::collections::VecDeque;
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::sim::{SimClock, State};
use super::task::TaskWaker;
use super::{is_participant, ClockHandle, Tick};

/// The receiver disconnected before (or while) sending.
pub struct SendError<T>(pub T);

impl<T> fmt::Debug for SendError<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("SendError(..)")
    }
}

/// All senders disconnected with the queue empty.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RecvError;

impl fmt::Display for RecvError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("receiving on an empty channel with no senders")
    }
}

impl std::error::Error for RecvError {}

/// Outcome of a bounded receive.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecvTimeoutError {
    /// Deadline passed with no message.
    Timeout,
    /// All senders disconnected with the queue empty.
    Disconnected,
}

/// Outcome of a non-blocking receive ([`Receiver::try_recv`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum TryRecvError {
    /// Queue empty, senders still connected.
    Empty,
    /// All senders disconnected with the queue empty.
    Disconnected,
}

/// Channel state shared between the sim halves. Accounting fields
/// (`consumer_waiting`, `wake_credit`, and the busy bookkeeping they
/// drive) are mutated only while the **clock's** state lock is held; the
/// queue has its own short-lived mutex that is only ever acquired *after*
/// the clock lock (or with no clock lock at all, when parking).
struct SimShared<T> {
    q: Mutex<VecDeque<T>>,
    /// Wakes a parked `recv`/`recv_timeout` consumer (paired with `q`).
    cv: Condvar,
    senders: AtomicUsize,
    recv_alive: AtomicBool,
    /// A counted participant is blocked in `recv`/`recv_deadline`.
    consumer_waiting: AtomicBool,
    /// The blocked consumer is in `recv_deadline`, parked on the *clock's*
    /// condvar: a send must notify that condvar too.
    consumer_on_clock_cv: AtomicBool,
    /// A send already re-counted the waiting consumer as busy; the
    /// consumer absorbs this credit when it resumes.
    wake_credit: AtomicBool,
    /// Multiplexed-runtime consumer: a task to wake (on its driver's
    /// `WakeHub`) whenever a message arrives or the senders disconnect.
    /// Locked only while the clock's state lock is held.
    waker: Mutex<Option<TaskWaker>>,
}

impl<T> SimShared<T> {
    /// Consumer-side resume bookkeeping: called (under the clock lock) by a
    /// counted receiver leaving its waiting state for any reason. Restores
    /// the receiver's busy count unless a wake credit already did.
    fn resume(&self, st: &mut State, counted: bool) {
        self.consumer_waiting.store(false, Ordering::Relaxed);
        self.consumer_on_clock_cv.store(false, Ordering::Relaxed);
        let credited = self.wake_credit.swap(false, Ordering::Relaxed);
        if counted && !credited {
            st.busy += 1;
        }
    }

    /// Fire the registered task waker (if any) with the clock lock held.
    /// Returns `true` if the caller should notify the clock condvar after
    /// unlocking (the waker's driver is parked there).
    fn fire_waker_locked(&self, st: &mut State) -> bool {
        match self.waker.lock().unwrap().as_ref() {
            Some(w) => w.wake_locked(st),
            None => false,
        }
    }
}

/// Sending half of a clock channel.
pub struct Sender<T> {
    imp: SenderImpl<T>,
}

enum SenderImpl<T> {
    Real(mpsc::Sender<T>),
    Sim { clock: SimClock, ch: Arc<SimShared<T>> },
}

/// Receiving half of a clock channel.
pub struct Receiver<T> {
    imp: ReceiverImpl<T>,
}

enum ReceiverImpl<T> {
    Real { rx: mpsc::Receiver<T>, clock: ClockHandle },
    Sim { clock: SimClock, ch: Arc<SimShared<T>> },
}

/// Create an unbounded channel whose blocking semantics follow `clock`.
pub fn channel<T>(clock: &ClockHandle) -> (Sender<T>, Receiver<T>) {
    match clock.as_sim() {
        Some(sim) => {
            let ch = Arc::new(SimShared {
                q: Mutex::new(VecDeque::new()),
                cv: Condvar::new(),
                senders: AtomicUsize::new(1),
                recv_alive: AtomicBool::new(true),
                consumer_waiting: AtomicBool::new(false),
                consumer_on_clock_cv: AtomicBool::new(false),
                wake_credit: AtomicBool::new(false),
                waker: Mutex::new(None),
            });
            (
                Sender {
                    imp: SenderImpl::Sim {
                        clock: sim.clone(),
                        ch: ch.clone(),
                    },
                },
                Receiver {
                    imp: ReceiverImpl::Sim {
                        clock: sim.clone(),
                        ch,
                    },
                },
            )
        }
        None => {
            let (s, r) = mpsc::channel();
            (
                Sender {
                    imp: SenderImpl::Real(s),
                },
                Receiver {
                    imp: ReceiverImpl::Real {
                        rx: r,
                        clock: clock.clone(),
                    },
                },
            )
        }
    }
}

impl<T> Sender<T> {
    /// Queue a message (never blocks; channels are unbounded).
    pub fn send(&self, v: T) -> Result<(), SendError<T>> {
        match &self.imp {
            SenderImpl::Real(s) => s.send(v).map_err(|e| SendError(e.0)),
            SenderImpl::Sim { clock, ch } => {
                let mut st = clock.lock();
                if !ch.recv_alive.load(Ordering::Relaxed) {
                    return Err(SendError(v));
                }
                ch.q.lock().unwrap().push_back(v);
                // Wake credit: a blocked counted consumer becomes runnable
                // *now*, before it is ever scheduled.
                if ch.consumer_waiting.load(Ordering::Relaxed)
                    && !ch.wake_credit.swap(true, Ordering::Relaxed)
                {
                    st.busy += 1;
                }
                let on_clock_cv = ch.consumer_on_clock_cv.load(Ordering::Relaxed);
                let task_woken = ch.fire_waker_locked(&mut st);
                drop(st);
                ch.cv.notify_all();
                if on_clock_cv || task_woken {
                    // recv_deadline waiters and parked task drivers both
                    // wait on the clock's condvar
                    clock.notify_all();
                }
                Ok(())
            }
        }
    }
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        match &self.imp {
            SenderImpl::Real(s) => Sender {
                imp: SenderImpl::Real(s.clone()),
            },
            SenderImpl::Sim { clock, ch } => {
                ch.senders.fetch_add(1, Ordering::AcqRel);
                Sender {
                    imp: SenderImpl::Sim {
                        clock: clock.clone(),
                        ch: ch.clone(),
                    },
                }
            }
        }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        if let SenderImpl::Sim { clock, ch } = &self.imp {
            if ch.senders.fetch_sub(1, Ordering::AcqRel) == 1 {
                // Serialize with BOTH park paths before notifying: a
                // recv_deadline waiter holds the clock lock from its
                // senders-check to its clock-cv wait, a plain recv waiter
                // holds the queue lock from its empty-check to its
                // channel-cv wait. Taking each lock here (clock first —
                // the global order) guarantees the waiter is parked before
                // the notify, so the disconnect can never be missed.
                let mut st = clock.lock();
                drop(ch.q.lock().unwrap());
                ch.fire_waker_locked(&mut st); // disconnect wakes tasks too
                drop(st);
                ch.cv.notify_all();
                clock.notify_all();
            }
        }
    }
}

impl<T> Receiver<T> {
    /// Receive, blocking until a message or disconnection.
    pub fn recv(&self) -> Result<T, RecvError> {
        match &self.imp {
            ReceiverImpl::Real { rx, .. } => rx.recv().map_err(|_| RecvError),
            ReceiverImpl::Sim { clock, ch } => {
                let counted = is_participant();
                let mut waiting = false;
                loop {
                    {
                        let mut st = clock.lock();
                        if let Some(v) = ch.q.lock().unwrap().pop_front() {
                            if waiting {
                                ch.resume(&mut st, counted);
                            }
                            return Ok(v);
                        }
                        if ch.senders.load(Ordering::Acquire) == 0 {
                            if waiting {
                                ch.resume(&mut st, counted);
                            }
                            return Err(RecvError);
                        }
                        if !waiting {
                            waiting = true;
                            // Only counted receivers join the credit
                            // protocol; outside-the-sim threads just park.
                            if counted {
                                ch.consumer_waiting.store(true, Ordering::Relaxed);
                                st.busy -= 1;
                                st.try_advance(clock.cv());
                            }
                        }
                    }
                    // Park on the channel condvar, clock lock released. The
                    // empty-check under the queue lock closes the lost-wake
                    // window: a sender pushes under this same lock.
                    let q = ch.q.lock().unwrap();
                    if q.is_empty() && ch.senders.load(Ordering::Acquire) > 0 {
                        drop(ch.cv.wait(q).unwrap());
                    }
                }
            }
        }
    }

    /// Receive, giving up at virtual instant `deadline` — one atomic wait
    /// on "message arrives OR the clock reaches `deadline`".
    pub fn recv_deadline(&self, deadline: Tick) -> Result<T, RecvTimeoutError> {
        match &self.imp {
            ReceiverImpl::Real { rx, clock } => {
                let remaining = deadline.saturating_sub(clock.now());
                if remaining.is_zero() {
                    return match rx.try_recv() {
                        Ok(v) => Ok(v),
                        Err(mpsc::TryRecvError::Empty) => Err(RecvTimeoutError::Timeout),
                        Err(mpsc::TryRecvError::Disconnected) => {
                            Err(RecvTimeoutError::Disconnected)
                        }
                    };
                }
                rx.recv_timeout(remaining).map_err(|e| match e {
                    mpsc::RecvTimeoutError::Timeout => RecvTimeoutError::Timeout,
                    mpsc::RecvTimeoutError::Disconnected => RecvTimeoutError::Disconnected,
                })
            }
            ReceiverImpl::Sim { clock, ch } => {
                let counted = is_participant();
                let mut st = clock.lock();
                let mut waiting = false;
                loop {
                    if let Some(v) = ch.q.lock().unwrap().pop_front() {
                        if waiting {
                            st.remove_sleeper(deadline);
                            ch.resume(&mut st, counted);
                        }
                        return Ok(v);
                    }
                    if ch.senders.load(Ordering::Acquire) == 0 {
                        if waiting {
                            st.remove_sleeper(deadline);
                            ch.resume(&mut st, counted);
                        }
                        return Err(RecvTimeoutError::Disconnected);
                    }
                    if st.now >= deadline {
                        if waiting {
                            st.remove_sleeper(deadline);
                            ch.resume(&mut st, counted);
                        }
                        st.try_advance(clock.cv());
                        return Err(RecvTimeoutError::Timeout);
                    }
                    if !waiting {
                        waiting = true;
                        ch.consumer_on_clock_cv.store(true, Ordering::Relaxed);
                        if counted {
                            ch.consumer_waiting.store(true, Ordering::Relaxed);
                            st.busy -= 1;
                        }
                        st.add_sleeper(deadline);
                        // The registration itself may advance the clock to
                        // our own deadline; loop to re-check before waiting
                        // or the notify we just issued would be lost.
                        st.try_advance(clock.cv());
                        continue;
                    }
                    st = clock.wait(st);
                }
            }
        }
    }

    /// Receive with a **wall-clock** bound — a hang guard for tests, not a
    /// simulation event (it registers no virtual deadline).
    pub fn recv_timeout(&self, timeout: Duration) -> Result<T, RecvTimeoutError> {
        match &self.imp {
            ReceiverImpl::Real { rx, .. } => rx.recv_timeout(timeout).map_err(|e| match e {
                mpsc::RecvTimeoutError::Timeout => RecvTimeoutError::Timeout,
                mpsc::RecvTimeoutError::Disconnected => RecvTimeoutError::Disconnected,
            }),
            ReceiverImpl::Sim { clock, ch } => {
                let counted = is_participant();
                let wall_deadline = Instant::now() + timeout;
                let mut waiting = false;
                loop {
                    {
                        let mut st = clock.lock();
                        if let Some(v) = ch.q.lock().unwrap().pop_front() {
                            if waiting {
                                ch.resume(&mut st, counted);
                            }
                            return Ok(v);
                        }
                        if ch.senders.load(Ordering::Acquire) == 0 {
                            if waiting {
                                ch.resume(&mut st, counted);
                            }
                            return Err(RecvTimeoutError::Disconnected);
                        }
                        if Instant::now() >= wall_deadline {
                            if waiting {
                                ch.resume(&mut st, counted);
                            }
                            return Err(RecvTimeoutError::Timeout);
                        }
                        if !waiting {
                            waiting = true;
                            if counted {
                                ch.consumer_waiting.store(true, Ordering::Relaxed);
                                st.busy -= 1;
                                st.try_advance(clock.cv());
                            }
                        }
                    }
                    let q = ch.q.lock().unwrap();
                    if q.is_empty() && ch.senders.load(Ordering::Acquire) > 0 {
                        let remaining = wall_deadline.saturating_duration_since(Instant::now());
                        drop(ch.cv.wait_timeout(q, remaining).unwrap());
                    }
                }
            }
        }
    }

    /// Non-blocking receive: the poll primitive behind multiplexed-runtime
    /// tasks. Performs no busy accounting — the calling task's driver is
    /// already counted busy while polling.
    pub(crate) fn try_recv(&self) -> Result<T, TryRecvError> {
        match &self.imp {
            ReceiverImpl::Real { rx, .. } => rx.try_recv().map_err(|e| match e {
                mpsc::TryRecvError::Empty => TryRecvError::Empty,
                mpsc::TryRecvError::Disconnected => TryRecvError::Disconnected,
            }),
            ReceiverImpl::Sim { clock, ch } => {
                let _st = clock.lock();
                if let Some(v) = ch.q.lock().unwrap().pop_front() {
                    return Ok(v);
                }
                if ch.senders.load(Ordering::Acquire) == 0 {
                    Err(TryRecvError::Disconnected)
                } else {
                    Err(TryRecvError::Empty)
                }
            }
        }
    }

    /// Register a task waker: every subsequent send (and the final sender
    /// disconnect) wakes `waker`'s task on its driver. Sim channels only —
    /// the multiplexed runtime never runs on a real clock.
    pub(crate) fn set_waker(&self, waker: TaskWaker) {
        match &self.imp {
            ReceiverImpl::Real { .. } => {
                unreachable!("task wakers are a SimClock-runtime feature")
            }
            ReceiverImpl::Sim { clock, ch } => {
                let _st = clock.lock();
                *ch.waker.lock().unwrap() = Some(waker);
            }
        }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        if let ReceiverImpl::Sim { clock, ch } = &self.imp {
            let _st = clock.lock();
            ch.recv_alive.store(false, Ordering::Relaxed);
            ch.q.lock().unwrap().clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{blocked, BusyToken, Clock, RealClock};
    use super::*;

    #[test]
    fn real_channel_roundtrip() {
        let clock: ClockHandle = RealClock::handle();
        let (tx, rx) = channel(&clock);
        tx.send(7u32).unwrap();
        assert_eq!(rx.recv().unwrap(), 7);
        drop(tx);
        assert!(rx.recv().is_err());
    }

    #[test]
    fn sim_channel_roundtrip_and_disconnect() {
        let clock: ClockHandle = SimClock::handle();
        let (tx, rx) = channel(&clock);
        tx.send(1u8).unwrap();
        tx.send(2).unwrap();
        assert_eq!(rx.recv().unwrap(), 1);
        assert_eq!(rx.recv().unwrap(), 2);
        drop(tx);
        assert_eq!(rx.recv(), Err(RecvError));
    }

    #[test]
    fn sim_recv_blocks_until_cross_thread_send() {
        let clock: ClockHandle = SimClock::handle();
        let (tx, rx) = channel::<u8>(&clock);
        let h = std::thread::spawn(move || rx.recv().unwrap());
        std::thread::sleep(Duration::from_millis(10));
        tx.send(42).unwrap();
        assert_eq!(h.join().unwrap(), 42);
    }

    #[test]
    fn sim_send_to_dropped_receiver_errors() {
        let clock: ClockHandle = SimClock::handle();
        let (tx, rx) = channel(&clock);
        tx.send(1u8).unwrap();
        drop(rx);
        assert!(tx.send(2).is_err());
    }

    #[test]
    fn sim_recv_unblocks_on_sender_drop() {
        let clock: ClockHandle = SimClock::handle();
        let (tx, rx) = channel::<u8>(&clock);
        let h = std::thread::spawn(move || rx.recv());
        std::thread::sleep(Duration::from_millis(10));
        drop(tx);
        assert_eq!(h.join().unwrap(), Err(RecvError));
    }

    #[test]
    fn recv_deadline_times_out_in_virtual_time() {
        let clock: ClockHandle = SimClock::handle();
        let (_tx, rx) = channel::<u8>(&clock);
        let t0 = Instant::now();
        let r = rx.recv_deadline(Duration::from_secs(100));
        assert_eq!(r, Err(RecvTimeoutError::Timeout));
        // virtual time advanced to the deadline without wall-clock cost
        assert_eq!(clock.now(), Duration::from_secs(100));
        assert!(t0.elapsed() < Duration::from_secs(10));
    }

    #[test]
    fn recv_deadline_returns_message_first() {
        let clock: ClockHandle = SimClock::handle();
        let (tx, rx) = channel::<u8>(&clock);
        tx.send(5).unwrap();
        let r = rx.recv_deadline(Duration::from_secs(100));
        assert_eq!(r, Ok(5));
        assert_eq!(clock.now(), Duration::ZERO, "message must win the race");
    }

    #[test]
    fn wake_credit_keeps_woken_consumer_counted() {
        use std::sync::atomic::AtomicBool;
        // A participant blocked in recv is woken by a send; until it is done
        // processing, virtual time must not advance — even though the OS may
        // schedule it arbitrarily late.
        let clock: ClockHandle = SimClock::handle();
        let (tx, rx) = channel::<u8>(&clock);
        let done = Arc::new(AtomicBool::new(false));
        let done2 = done.clone();
        let token = BusyToken::new(&clock);
        let h = std::thread::spawn(move || {
            let _busy = token.bind();
            let v = rx.recv().unwrap();
            // simulate real work after the wake: time must stay pinned
            std::thread::sleep(Duration::from_millis(40));
            done2.store(true, Ordering::SeqCst);
            v
        });
        std::thread::sleep(Duration::from_millis(20)); // let it block
        tx.send(9).unwrap();
        // this virtual sleep may only complete once the consumer went idle
        clock.sleep_until(Duration::from_millis(1));
        assert!(
            done.load(Ordering::SeqCst),
            "clock advanced while the woken consumer was still running"
        );
        assert_eq!(h.join().unwrap(), 9);
    }

    #[test]
    fn parked_frames_on_unpolled_channel_do_not_freeze_time() {
        // Messages queued for a RUNNING (or outside-the-sim) consumer must
        // not pin virtual time — otherwise a multi-stream reader blocked on
        // one link would deadlock the clock via frames parked on another.
        let clock: ClockHandle = SimClock::handle();
        let (tx, _rx) = channel::<u8>(&clock);
        tx.send(1).unwrap(); // parked: nobody is waiting on this channel
        clock.sleep_until(Duration::from_millis(30));
        assert_eq!(clock.now(), Duration::from_millis(30));
    }

    #[test]
    fn participant_blocked_in_recv_lets_time_advance() {
        let clock: ClockHandle = SimClock::handle();
        let (tx, rx) = channel::<u8>(&clock);
        let token = BusyToken::new(&clock);
        let c2 = clock.clone();
        let h = std::thread::spawn(move || {
            let _busy = token.bind();
            rx.recv().unwrap() // idle while waiting: must not pin time
        });
        // give the receiver a moment to block, then sleep virtually
        std::thread::sleep(Duration::from_millis(20));
        c2.sleep_until(Duration::from_millis(5));
        assert_eq!(c2.now(), Duration::from_millis(5));
        tx.send(3).unwrap();
        assert_eq!(h.join().unwrap(), 3);
    }

    #[test]
    fn blocked_bracket_releases_participant() {
        let clock: ClockHandle = SimClock::handle();
        let token = BusyToken::new(&clock);
        let c2 = clock.clone();
        let h = std::thread::spawn(move || {
            let _busy = token.bind();
            // joins/waits wrapped in blocked() must not pin virtual time
            blocked(&c2, || std::thread::sleep(Duration::from_millis(30)));
        });
        std::thread::sleep(Duration::from_millis(5));
        clock.sleep_until(Duration::from_millis(1));
        assert_eq!(clock.now(), Duration::from_millis(1));
        h.join().unwrap();
    }

    #[test]
    fn wall_recv_timeout_fires_on_silent_sim_channel() {
        let clock: ClockHandle = SimClock::handle();
        let (_tx, rx) = channel::<u8>(&clock);
        let r = rx.recv_timeout(Duration::from_millis(30));
        assert_eq!(r, Err(RecvTimeoutError::Timeout));
        assert_eq!(clock.now(), Duration::ZERO, "wall timeout is not an event");
    }
}
