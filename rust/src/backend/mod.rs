//! Pluggable GF compute backends for the archival hot paths.
//!
//! Two implementations of the same byte-level contract:
//!
//! * [`NativeBackend`] — pure-Rust table-based GF arithmetic
//!   ([`crate::gf::slice`]), the Jerasure-equivalent baseline.
//! * [`PjrtBackend`] — executes the AOT-compiled Pallas kernels
//!   (`artifacts/*.hlo.txt`) through the PJRT CPU client
//!   ([`crate::runtime`]); this is the L1/L2/L3 composition path.
//!
//! Both operate on raw byte buffers (the coordinator's network frames);
//! `Width` selects GF(2^8) (*RR8*) vs GF(2^16) (*RR16*) semantics. All
//! coefficients travel as `u32` so node commands stay field-agnostic.

pub mod native;
pub mod pjrt;

pub use native::NativeBackend;
pub use pjrt::PjrtBackend;

use std::sync::Arc;

/// Field word width: GF(2^8) or GF(2^16).
#[derive(Copy, Clone, PartialEq, Eq, Debug)]
pub enum Width {
    /// GF(2^8) — one byte per symbol (paper's RR8 / CEC default).
    W8,
    /// GF(2^16) — two little-endian bytes per symbol (paper's RR16).
    W16,
}

impl Width {
    /// Bytes per field symbol.
    pub fn symbol_bytes(self) -> usize {
        match self {
            Width::W8 => 1,
            Width::W16 => 2,
        }
    }

    /// Width for a field's bit count (`F::BITS`) — how generic coordinator
    /// code erases its field parameter into a plan width.
    pub fn for_bits(bits: u32) -> anyhow::Result<Self> {
        match bits {
            8 => Ok(Width::W8),
            16 => Ok(Width::W16),
            other => anyhow::bail!("unsupported field width {other}"),
        }
    }
}

impl std::fmt::Display for Width {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Width::W8 => write!(f, "gf8"),
            Width::W16 => write!(f, "gf16"),
        }
    }
}

/// GF compute used by storage nodes on the archival hot path.
pub trait EncodeBackend: Send + Sync {
    /// One RapidRAID pipeline stage over one network buffer (paper eqs.
    /// (3)/(4)): returns `(x_out, c)` where
    /// `x_out = x_in ⊕ Σ psi[j]·locals[j]`, `c = x_in ⊕ Σ xi[j]·locals[j]`.
    fn pipeline_step(
        &self,
        w: Width,
        x_in: &[u8],
        locals: &[&[u8]],
        psi: &[u32],
        xi: &[u32],
    ) -> anyhow::Result<(Vec<u8>, Vec<u8>)>;

    /// Fold one source buffer into `m` parity accumulators (classical
    /// streamlined encoding): `parity[i] ^= coeffs[i] · src`.
    fn fold_parity(
        &self,
        w: Width,
        coeffs: &[u32],
        src: &[u8],
        parity: &mut [Vec<u8>],
    ) -> anyhow::Result<()>;

    /// Dense GF matrix application: `out[i] = Σ_j mat[i][j] · data[j]`
    /// (decode inverse application, batch parity generation).
    fn gemm(&self, w: Width, mat: &[Vec<u32>], data: &[&[u8]]) -> anyhow::Result<Vec<Vec<u8>>>;

    /// Backend label for reports.
    fn name(&self) -> &'static str;
}

/// Shared, thread-safe backend handle as stored in node commands.
pub type BackendHandle = Arc<dyn EncodeBackend>;

/// Run the backend conformance suite (also used by the PJRT integration
/// tests in `rust/tests/pjrt_runtime.rs`).
pub fn conformance_entry(be: &dyn EncodeBackend, buf_bytes: usize) {
    conformance::run(be, buf_bytes)
}

pub mod conformance {
    //! Shared conformance suite: any backend must agree with the scalar
    //! field operations bit-for-bit. Called by the native and PJRT tests.
    use super::*;
    use crate::gf::tables::mul_bitwise;
    use crate::util::SplitMix64;

    fn scalar_mul_buf(w: Width, c: u32, src: &[u8]) -> Vec<u8> {
        match w {
            Width::W8 => src.iter().map(|&b| mul_bitwise(c, b as u32, 8) as u8).collect(),
            Width::W16 => {
                let mut out = Vec::with_capacity(src.len());
                for p in src.chunks_exact(2) {
                    let v = u16::from_le_bytes([p[0], p[1]]) as u32;
                    let r = mul_bitwise(c, v, 16) as u16;
                    out.extend_from_slice(&r.to_le_bytes());
                }
                out
            }
        }
    }

    fn xor(a: &[u8], b: &[u8]) -> Vec<u8> {
        a.iter().zip(b).map(|(x, y)| x ^ y).collect()
    }

    /// Run the full conformance suite against `be` with buffers of
    /// `buf_bytes` (must satisfy the backend's shape constraints).
    pub fn run(be: &dyn EncodeBackend, buf_bytes: usize) {
        let mut rng = SplitMix64::new(0xC0FFEE);
        for w in [Width::W8, Width::W16] {
            let cmask = match w {
                Width::W8 => 0xFFu64,
                Width::W16 => 0xFFFFu64,
            };
            // pipeline_step, r = 1 and r = 2
            for r in 1..=2usize {
                let mut x = vec![0u8; buf_bytes];
                rng.fill_bytes(&mut x);
                let mut locs = Vec::new();
                for _ in 0..r {
                    let mut l = vec![0u8; buf_bytes];
                    rng.fill_bytes(&mut l);
                    locs.push(l);
                }
                let loc_refs: Vec<&[u8]> = locs.iter().map(|l| l.as_slice()).collect();
                let psi: Vec<u32> = (0..r).map(|_| (rng.next_u64() & cmask) as u32).collect();
                let xi: Vec<u32> = (0..r).map(|_| (rng.next_u64() & cmask) as u32).collect();
                let (xo, c) = be.pipeline_step(w, &x, &loc_refs, &psi, &xi).unwrap();
                let mut ex = x.clone();
                let mut ec = x.clone();
                for j in 0..r {
                    ex = xor(&ex, &scalar_mul_buf(w, psi[j], &locs[j]));
                    ec = xor(&ec, &scalar_mul_buf(w, xi[j], &locs[j]));
                }
                assert_eq!(xo, ex, "{} pipeline_step x_out w={w:?} r={r}", be.name());
                assert_eq!(c, ec, "{} pipeline_step c w={w:?} r={r}", be.name());
            }

            // fold_parity (m = 3)
            let coeffs: Vec<u32> = (0..3).map(|_| (rng.next_u64() & cmask) as u32).collect();
            let mut src = vec![0u8; buf_bytes];
            rng.fill_bytes(&mut src);
            let mut parity: Vec<Vec<u8>> = (0..3)
                .map(|_| {
                    let mut p = vec![0u8; buf_bytes];
                    rng.fill_bytes(&mut p);
                    p
                })
                .collect();
            let before = parity.clone();
            be.fold_parity(w, &coeffs, &src, &mut parity).unwrap();
            for i in 0..3 {
                let expect = xor(&before[i], &scalar_mul_buf(w, coeffs[i], &src));
                assert_eq!(parity[i], expect, "{} fold_parity row {i} w={w:?}", be.name());
            }

            // gemm (2x3)
            let mat: Vec<Vec<u32>> = (0..2)
                .map(|_| (0..3).map(|_| (rng.next_u64() & cmask) as u32).collect())
                .collect();
            let data: Vec<Vec<u8>> = (0..3)
                .map(|_| {
                    let mut d = vec![0u8; buf_bytes];
                    rng.fill_bytes(&mut d);
                    d
                })
                .collect();
            let data_refs: Vec<&[u8]> = data.iter().map(|d| d.as_slice()).collect();
            let out = be.gemm(w, &mat, &data_refs).unwrap();
            for i in 0..2 {
                let mut expect = vec![0u8; buf_bytes];
                for j in 0..3 {
                    expect = xor(&expect, &scalar_mul_buf(w, mat[i][j], &data[j]));
                }
                assert_eq!(out[i], expect, "{} gemm row {i} w={w:?}", be.name());
            }
        }
    }
}
