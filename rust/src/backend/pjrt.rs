//! PJRT backend: the GF hot-spots execute inside the AOT-compiled Pallas
//! kernels through [`crate::runtime::PjrtEngine`].
//!
//! This is the full three-layer composition: L3 coordinator (Rust) → L2 jax
//! graph → L1 Pallas kernel, with Python long gone by the time any of this
//! runs.

use std::path::Path;
use std::sync::Arc;

use super::{EncodeBackend, Width};
use crate::runtime::PjrtEngine;

/// Backend executing GF compute on the PJRT CPU client.
pub struct PjrtBackend {
    engine: Arc<PjrtEngine>,
}

impl PjrtBackend {
    /// Load artifacts from `dir` and create the engine.
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        Ok(Self {
            engine: Arc::new(PjrtEngine::load(dir)?),
        })
    }

    /// Wrap an existing engine (shared across backends).
    pub fn from_engine(engine: Arc<PjrtEngine>) -> Self {
        Self { engine }
    }

    /// The underlying engine.
    pub fn engine(&self) -> &Arc<PjrtEngine> {
        &self.engine
    }
}

impl EncodeBackend for PjrtBackend {
    fn pipeline_step(
        &self,
        w: Width,
        x_in: &[u8],
        locals: &[&[u8]],
        psi: &[u32],
        xi: &[u32],
    ) -> anyhow::Result<(Vec<u8>, Vec<u8>)> {
        self.engine.pipeline_step(w, x_in, locals, psi, xi)
    }

    fn fold_parity(
        &self,
        w: Width,
        coeffs: &[u32],
        src: &[u8],
        parity: &mut [Vec<u8>],
    ) -> anyhow::Result<()> {
        // fold = gemm with a column vector: parity[i] ^= coeffs[i] ⊗ src.
        anyhow::ensure!(coeffs.len() == parity.len(), "coefficient arity mismatch");
        let mat: Vec<Vec<u32>> = coeffs.iter().map(|&c| vec![c]).collect();
        let prods = self.engine.gemm(w, &mat, &[src])?;
        for (p, prod) in parity.iter_mut().zip(prods) {
            anyhow::ensure!(p.len() == src.len(), "parity buffer length mismatch");
            for (d, s) in p.iter_mut().zip(&prod) {
                *d ^= s;
            }
        }
        Ok(())
    }

    fn gemm(&self, w: Width, mat: &[Vec<u32>], data: &[&[u8]]) -> anyhow::Result<Vec<Vec<u8>>> {
        self.engine.gemm(w, mat, data)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

// Execution tests against real artifacts live in rust/tests/pjrt_runtime.rs
// (they require `make artifacts` to have produced artifacts/).
