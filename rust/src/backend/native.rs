//! Native Rust GF backend — table-based slice operations, the
//! Jerasure-equivalent baseline the paper's implementation uses.

use super::{EncodeBackend, Width};
use crate::gf::simd::{self, Kernel};

/// Pure-Rust GF compute (no PJRT).
#[derive(Default)]
pub struct NativeBackend;

impl NativeBackend {
    /// New native backend.
    pub fn new() -> Self {
        Self
    }
}

/// `dst ^= c * src` over GF(2^16) on raw little-endian byte buffers.
///
/// Works on unaligned `&[u8]` (payloads come straight off network frames);
/// streams through the process-wide [`Kernel`] — split-nibble vector
/// shuffles where the CPU has them, GFNI affine products on the widest
/// tier, the two-256-entry-table scalar pass otherwise.
fn mul_slice_xor16_bytes(c: u16, src: &[u8], dst: &mut [u8]) {
    assert_eq!(src.len(), dst.len());
    assert_eq!(src.len() % 2, 0, "GF(2^16) payload must have even length");
    if c == 0 {
        return;
    }
    if c == 1 {
        simd::xor_bytes(Kernel::active(), src, dst);
        return;
    }
    simd::mul_xor16(Kernel::active(), c, src, dst);
}

/// `dst ^= c * src` dispatched on width, on raw byte buffers.
pub fn mul_xor_bytes(w: Width, c: u32, src: &[u8], dst: &mut [u8]) {
    match w {
        Width::W8 => {
            if c == 0 {
                return;
            }
            if c == 1 {
                simd::xor_bytes(Kernel::active(), src, dst);
                return;
            }
            simd::mul_xor8(Kernel::active(), c as u8, src, dst);
        }
        Width::W16 => mul_slice_xor16_bytes(c as u16, src, dst),
    }
}

/// Fused `x ^= p·src, c ^= q·src` dispatched on width: a zero coefficient
/// degenerates to the single-output path (so the other accumulator still
/// gets a one-read pass), everything else takes the two-accumulator
/// kernels — one read of each source byte feeds both products on EVERY
/// kernel, scalar and vector alike.
fn mul2_xor_bytes(w: Width, p: u32, q: u32, src: &[u8], x: &mut [u8], c: &mut [u8]) {
    match (p, q) {
        (0, 0) => {}
        (_, 0) => mul_xor_bytes(w, p, src, x),
        (0, _) => mul_xor_bytes(w, q, src, c),
        _ => match w {
            Width::W8 => simd::mul2_xor8(Kernel::active(), p as u8, q as u8, src, x, c),
            Width::W16 => simd::mul2_xor16(Kernel::active(), p as u16, q as u16, src, x, c),
        },
    }
}

impl EncodeBackend for NativeBackend {
    fn pipeline_step(
        &self,
        w: Width,
        x_in: &[u8],
        locals: &[&[u8]],
        psi: &[u32],
        xi: &[u32],
    ) -> anyhow::Result<(Vec<u8>, Vec<u8>)> {
        anyhow::ensure!(
            locals.len() == psi.len() && locals.len() == xi.len(),
            "coefficient arity mismatch"
        );
        let mut x_out = x_in.to_vec();
        let mut c = x_in.to_vec();
        for (j, loc) in locals.iter().enumerate() {
            anyhow::ensure!(loc.len() == x_in.len(), "local block length mismatch");
            if w == Width::W16 {
                anyhow::ensure!(loc.len() % 2 == 0, "GF(2^16) length must be even");
            }
            mul2_xor_bytes(w, psi[j], xi[j], loc, &mut x_out, &mut c);
        }
        Ok((x_out, c))
    }

    fn fold_parity(
        &self,
        w: Width,
        coeffs: &[u32],
        src: &[u8],
        parity: &mut [Vec<u8>],
    ) -> anyhow::Result<()> {
        anyhow::ensure!(coeffs.len() == parity.len(), "coefficient arity mismatch");
        for p in parity.iter() {
            anyhow::ensure!(p.len() == src.len(), "parity buffer length mismatch");
        }
        // Parity rows fold in PAIRS so each pair shares one pass over the
        // source block.
        for (cs, ps) in coeffs.chunks(2).zip(parity.chunks_mut(2)) {
            match ps {
                [p0, p1] => mul2_xor_bytes(w, cs[0], cs[1], src, p0, p1),
                [p0] => mul_xor_bytes(w, cs[0], src, p0),
                _ => unreachable!("chunks(2) yields 1- or 2-row groups"),
            }
        }
        Ok(())
    }

    fn gemm(&self, w: Width, mat: &[Vec<u32>], data: &[&[u8]]) -> anyhow::Result<Vec<Vec<u8>>> {
        let k = data.len();
        anyhow::ensure!(mat.iter().all(|r| r.len() == k), "matrix/data shape mismatch");
        let len = data.first().map_or(0, |d| d.len());
        anyhow::ensure!(data.iter().all(|d| d.len() == len), "ragged data blocks");
        let mut out = vec![vec![0u8; len]; mat.len()];
        // Row-batched schedule on every kernel: L1-sized chunks of each
        // source feed output rows in pairs (one read per pair via the
        // fused kernels) and the chunk accumulators stay cache-hot across
        // all k sources — see `gf::simd::gemm_rows8/16`.
        match w {
            Width::W8 => simd::gemm_rows8(Kernel::active(), mat, data, &mut out),
            Width::W16 => {
                anyhow::ensure!(len % 2 == 0, "GF(2^16) length must be even");
                simd::gemm_rows16(Kernel::active(), mat, data, &mut out);
            }
        }
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::conformance;

    #[test]
    fn conformance_suite() {
        conformance::run(&NativeBackend::new(), 4096);
    }

    #[test]
    fn conformance_odd_small_buffer() {
        // W8 path also works on odd lengths; the suite uses even sizes so
        // W16 stays valid — check W8 separately at odd length.
        let be = NativeBackend::new();
        let x = vec![7u8; 33];
        let l = vec![9u8; 33];
        let (xo, c) = be
            .pipeline_step(Width::W8, &x, &[&l], &[1], &[1])
            .unwrap();
        assert_eq!(xo, c);
        assert_eq!(xo[0], 7 ^ 9);
    }

    #[test]
    fn arity_errors() {
        let be = NativeBackend::new();
        let x = vec![0u8; 16];
        let l = vec![0u8; 16];
        assert!(be.pipeline_step(Width::W8, &x, &[&l], &[1, 2], &[1]).is_err());
        let mut p = vec![vec![0u8; 16]];
        assert!(be.fold_parity(Width::W8, &[1, 2], &x, &mut p).is_err());
        assert!(be.gemm(Width::W8, &[vec![1, 2]], &[&x]).is_err());
    }

    #[test]
    fn gf16_identity_and_zero() {
        let be = NativeBackend::new();
        let src = vec![0xAB; 64];
        let mut parity = vec![vec![0u8; 64], vec![0x11; 64]];
        be.fold_parity(Width::W16, &[1, 0], &src, &mut parity).unwrap();
        assert_eq!(parity[0], src);
        assert_eq!(parity[1], vec![0x11; 64]);
    }

    #[test]
    fn fold_parity_odd_row_count_pairs_correctly() {
        // 3 rows → one fused pair + one single; must equal per-row folds.
        let be = NativeBackend::new();
        let src: Vec<u8> = (0..96u32).map(|i| (i * 7 + 3) as u8).collect();
        let coeffs = [3u32, 5, 9];
        let mut parity = vec![vec![0x22u8; 96]; 3];
        be.fold_parity(Width::W8, &coeffs, &src, &mut parity).unwrap();
        for (c, p) in coeffs.iter().zip(&parity) {
            let mut expect = vec![0x22u8; 96];
            mul_xor_bytes(Width::W8, *c, &src, &mut expect);
            assert_eq!(p, &expect, "c={c}");
        }
    }
}
