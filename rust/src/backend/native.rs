//! Native Rust GF backend — table-based slice operations, the
//! Jerasure-equivalent baseline the paper's implementation uses.

use super::{EncodeBackend, Width};
use crate::gf::field::{Gf65536, GfElem};
use crate::gf::simd::{self, Kernel};

/// Pure-Rust GF compute (no PJRT).
#[derive(Default)]
pub struct NativeBackend;

impl NativeBackend {
    /// New native backend.
    pub fn new() -> Self {
        Self
    }
}

/// `dst ^= c * src` over GF(2^16) on raw little-endian byte buffers.
///
/// Works on unaligned `&[u8]` (payloads come straight off network frames);
/// streams through the process-wide [`Kernel`] — split-nibble vector
/// shuffles where the CPU has them, the two-256-entry-table scalar pass
/// otherwise.
fn mul_slice_xor16_bytes(c: u16, src: &[u8], dst: &mut [u8]) {
    assert_eq!(src.len(), dst.len());
    assert_eq!(src.len() % 2, 0, "GF(2^16) payload must have even length");
    if c == 0 {
        return;
    }
    if c == 1 {
        simd::xor_bytes(Kernel::active(), src, dst);
        return;
    }
    simd::mul_xor16(Kernel::active(), c, src, dst);
}

/// `dst ^= c * src` dispatched on width, on raw byte buffers.
pub fn mul_xor_bytes(w: Width, c: u32, src: &[u8], dst: &mut [u8]) {
    match w {
        Width::W8 => {
            if c == 0 {
                return;
            }
            if c == 1 {
                simd::xor_bytes(Kernel::active(), src, dst);
                return;
            }
            simd::mul_xor8(Kernel::active(), c as u8, src, dst);
        }
        Width::W16 => mul_slice_xor16_bytes(c as u16, src, dst),
    }
}

/// Fused dual product table pass for GF(2^8): one read of each local byte
/// feeds BOTH the ψ and ξ lookups (`x ^= tp[s]; c ^= tq[s]`) — mirrors the
/// fused Pallas `pipeline_step` kernel and halves memory traffic vs two
/// `mul_slice_xor` passes (§Perf: 440 → ~900 MiB/s on the bench host).
fn fused_step8(p: u8, q: u8, loc: &[u8], x_out: &mut [u8], c: &mut [u8]) {
    let t8 = crate::gf::field::Gf256::tables();
    let build = |coef: u8| -> [u8; 256] {
        let mut t = [0u8; 256];
        if coef != 0 {
            let lc = t8.log[coef as usize];
            for (s, slot) in t.iter_mut().enumerate().skip(1) {
                *slot = t8.exp[(lc + t8.log[s]) as usize] as u8;
            }
        }
        t
    };
    let tp = build(p);
    let tq = build(q);
    for ((l, x), cc) in loc.iter().zip(x_out.iter_mut()).zip(c.iter_mut()) {
        let s = *l as usize;
        *x ^= tp[s];
        *cc ^= tq[s];
    }
}

/// Fused dual split-table pass for GF(2^16) (two 256-entry tables per
/// coefficient; one read of each 16-bit word feeds both products).
fn fused_step16(p: u16, q: u16, loc: &[u8], x_out: &mut [u8], c: &mut [u8]) {
    let t16 = Gf65536::tables();
    let build = |coef: u16| -> ([u16; 256], [u16; 256]) {
        let mut lo = [0u16; 256];
        let mut hi = [0u16; 256];
        if coef != 0 {
            let lc = t16.log[coef as usize];
            for b in 1usize..256 {
                lo[b] = t16.exp[(lc + t16.log[b]) as usize] as u16;
                hi[b] = t16.exp[(lc + t16.log[b << 8]) as usize] as u16;
            }
        }
        (lo, hi)
    };
    let (plo, phi) = build(p);
    let (qlo, qhi) = build(q);
    for ((l, x), cc) in loc
        .chunks_exact(2)
        .zip(x_out.chunks_exact_mut(2))
        .zip(c.chunks_exact_mut(2))
    {
        let (b0, b1) = (l[0] as usize, l[1] as usize);
        let xp = plo[b0] ^ phi[b1];
        let xq = qlo[b0] ^ qhi[b1];
        let xv = u16::from_le_bytes([x[0], x[1]]) ^ xp;
        x.copy_from_slice(&xv.to_le_bytes());
        let cv = u16::from_le_bytes([cc[0], cc[1]]) ^ xq;
        cc.copy_from_slice(&cv.to_le_bytes());
    }
}

impl EncodeBackend for NativeBackend {
    fn pipeline_step(
        &self,
        w: Width,
        x_in: &[u8],
        locals: &[&[u8]],
        psi: &[u32],
        xi: &[u32],
    ) -> anyhow::Result<(Vec<u8>, Vec<u8>)> {
        anyhow::ensure!(
            locals.len() == psi.len() && locals.len() == xi.len(),
            "coefficient arity mismatch"
        );
        let mut x_out = x_in.to_vec();
        let mut c = x_in.to_vec();
        // On the scalar kernel the fused dual-table pass wins (one read of
        // each local byte feeds both products); on a SIMD kernel two
        // vector passes per local beat it comfortably, so dispatch there.
        let fused = Kernel::active() == Kernel::Scalar;
        for (j, loc) in locals.iter().enumerate() {
            anyhow::ensure!(loc.len() == x_in.len(), "local block length mismatch");
            match w {
                Width::W8 if fused => {
                    fused_step8(psi[j] as u8, xi[j] as u8, loc, &mut x_out, &mut c)
                }
                Width::W16 if fused => {
                    anyhow::ensure!(loc.len() % 2 == 0, "GF(2^16) length must be even");
                    fused_step16(psi[j] as u16, xi[j] as u16, loc, &mut x_out, &mut c)
                }
                _ => {
                    if w == Width::W16 {
                        anyhow::ensure!(loc.len() % 2 == 0, "GF(2^16) length must be even");
                    }
                    mul_xor_bytes(w, psi[j], loc, &mut x_out);
                    mul_xor_bytes(w, xi[j], loc, &mut c);
                }
            }
        }
        Ok((x_out, c))
    }

    fn fold_parity(
        &self,
        w: Width,
        coeffs: &[u32],
        src: &[u8],
        parity: &mut [Vec<u8>],
    ) -> anyhow::Result<()> {
        anyhow::ensure!(coeffs.len() == parity.len(), "coefficient arity mismatch");
        for (c, p) in coeffs.iter().zip(parity.iter_mut()) {
            anyhow::ensure!(p.len() == src.len(), "parity buffer length mismatch");
            mul_xor_bytes(w, *c, src, p);
        }
        Ok(())
    }

    fn gemm(&self, w: Width, mat: &[Vec<u32>], data: &[&[u8]]) -> anyhow::Result<Vec<Vec<u8>>> {
        let k = data.len();
        anyhow::ensure!(mat.iter().all(|r| r.len() == k), "matrix/data shape mismatch");
        let len = data.first().map_or(0, |d| d.len());
        anyhow::ensure!(data.iter().all(|d| d.len() == len), "ragged data blocks");
        let mut out = vec![vec![0u8; len]; mat.len()];
        match w {
            // Row-fused GF(2^8) path (§Perf): per output row, keep the k
            // product tables L1-resident and accumulate in a register —
            // one write per output byte instead of k read-modify-writes.
            // Only worth it on the scalar kernel; the vector shuffles are
            // faster as one dispatched pass per matrix cell.
            Width::W8 if Kernel::active() == Kernel::Scalar => {
                for (row, o) in mat.iter().zip(out.iter_mut()) {
                    let t8 = crate::gf::field::Gf256::tables();
                    let tables: Vec<[u8; 256]> = row
                        .iter()
                        .map(|&coef| {
                            let mut t = [0u8; 256];
                            if coef != 0 {
                                let lc = t8.log[coef as usize];
                                for (s, slot) in t.iter_mut().enumerate().skip(1) {
                                    *slot = t8.exp[(lc + t8.log[s]) as usize] as u8;
                                }
                            }
                            t
                        })
                        .collect();
                    // L1-blocked accumulation: per 4 KiB chunk, one
                    // sequential table pass per source keeps the chunk
                    // accumulator cache-hot and lets the compiler elide
                    // bounds checks on the zipped slices.
                    const CHUNK: usize = 4096;
                    let mut start = 0;
                    while start < len {
                        let end = (start + CHUNK).min(len);
                        let oc = &mut o[start..end];
                        for (t, d) in tables.iter().zip(data) {
                            for (ob, s) in oc.iter_mut().zip(&d[start..end]) {
                                *ob ^= t[*s as usize];
                            }
                        }
                        start = end;
                    }
                }
            }
            _ => {
                for (row, o) in mat.iter().zip(out.iter_mut()) {
                    for (c, d) in row.iter().zip(data) {
                        mul_xor_bytes(w, *c, d, o);
                    }
                }
            }
        }
        Ok(out)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::conformance;

    #[test]
    fn conformance_suite() {
        conformance::run(&NativeBackend::new(), 4096);
    }

    #[test]
    fn conformance_odd_small_buffer() {
        // W8 path also works on odd lengths; the suite uses even sizes so
        // W16 stays valid — check W8 separately at odd length.
        let be = NativeBackend::new();
        let x = vec![7u8; 33];
        let l = vec![9u8; 33];
        let (xo, c) = be
            .pipeline_step(Width::W8, &x, &[&l], &[1], &[1])
            .unwrap();
        assert_eq!(xo, c);
        assert_eq!(xo[0], 7 ^ 9);
    }

    #[test]
    fn arity_errors() {
        let be = NativeBackend::new();
        let x = vec![0u8; 16];
        let l = vec![0u8; 16];
        assert!(be.pipeline_step(Width::W8, &x, &[&l], &[1, 2], &[1]).is_err());
        let mut p = vec![vec![0u8; 16]];
        assert!(be.fold_parity(Width::W8, &[1, 2], &x, &mut p).is_err());
        assert!(be.gemm(Width::W8, &[vec![1, 2]], &[&x]).is_err());
    }

    #[test]
    fn gf16_identity_and_zero() {
        let be = NativeBackend::new();
        let src = vec![0xAB; 64];
        let mut parity = vec![vec![0u8; 64], vec![0x11; 64]];
        be.fold_parity(Width::W16, &[1, 0], &src, &mut parity).unwrap();
        assert_eq!(parity[0], src);
        assert_eq!(parity[1], vec![0x11; 64]);
    }
}
