//! Linear-dependency census over RapidRAID codewords (paper Fig. 3 and
//! Conjecture 1).
//!
//! Fault tolerance of an (n, k) RapidRAID code is governed by how many of
//! the C(n, k) k-subsets of codeword blocks are linearly independent. Two
//! kinds of dependent subsets exist (Section V-A):
//!
//! * **natural** — forced by the pipeline structure itself, present for
//!   every choice of ψ/ξ (the paper detects them symbolically; we detect
//!   them as subsets that stay dependent across `trials` independent random
//!   coefficient draws over GF(2^16), each false positive having probability
//!   ≤ (n/2^16) per trial by Schwartz–Zippel, so ≤ 2^-40-ish overall).
//! * **accidental** — artifacts of one particular coefficient draw.

use crate::codes::rapidraid::{placement, NodeSchedule, RapidRaidCode};
use crate::codes::subsets::{binomial, Combinations};
use crate::gf::{rank, Gf65536, GfElem, Matrix, SliceOps};
use crate::util::SplitMix64;

/// Census of linear dependencies for an (n, k) RapidRAID code.
#[derive(Clone, Debug)]
pub struct CensusReport {
    /// Code length.
    pub n: usize,
    /// Message length.
    pub k: usize,
    /// Total number of k-subsets, C(n, k).
    pub total_subsets: u64,
    /// Subsets dependent under EVERY trial draw — natural dependencies.
    pub natural_dependent: Vec<Vec<usize>>,
    /// Number of trials used for the natural/accidental separation.
    pub trials: usize,
}

impl CensusReport {
    /// Number of naturally dependent k-subsets (paper Fig. 3b).
    pub fn dependent_count(&self) -> u64 {
        self.natural_dependent.len() as u64
    }

    /// Percentage of linearly independent k-subsets (paper Fig. 3a).
    pub fn percent_independent(&self) -> f64 {
        100.0 * (self.total_subsets - self.dependent_count()) as f64 / self.total_subsets as f64
    }

    /// True iff the code is MDS (no natural dependencies).
    pub fn is_mds(&self) -> bool {
        self.natural_dependent.is_empty()
    }
}

/// Run the census for an (n, k) RapidRAID code using `trials` independent
/// GF(2^16) coefficient draws (3 is plenty; each extra trial multiplies the
/// false-positive probability by ~n/65536).
pub fn census(n: usize, k: usize, trials: usize, seed: u64) -> anyhow::Result<CensusReport> {
    anyhow::ensure!(trials >= 1, "need at least one trial");
    let mut generators: Vec<Matrix<Gf65536>> = Vec::with_capacity(trials);
    for t in 0..trials {
        let code = RapidRaidCode::<Gf65536>::with_seed(n, k, seed ^ (t as u64).wrapping_mul(0x9E37_79B9))?;
        generators.push(code.generator().clone());
    }
    let mut natural = Vec::new();
    for sub in Combinations::new(n, k) {
        let dependent_everywhere = generators
            .iter()
            .all(|g| rank(&g.select_rows(&sub)) < k);
        if dependent_everywhere {
            natural.push(sub);
        }
    }
    Ok(CensusReport {
        n,
        k,
        total_subsets: binomial(n, k),
        natural_dependent: natural,
        trials,
    })
}

/// Count dependent k-subsets of ONE concrete code (natural + accidental);
/// used by the coefficient search to score candidate draws.
pub fn dependent_subsets<F: GfElem + SliceOps>(code: &RapidRaidCode<F>) -> u64 {
    Combinations::new(code.n(), code.k())
        .filter(|s| rank(&code.generator().select_rows(s)) < code.k())
        .count() as u64
}

/// Symbolic-ish sanity check used in tests: a subset is *certainly* natural
/// if it is dependent for `trials` fresh draws (distinct from the draws a
/// particular code instance was built with).
pub fn is_natural_dependency(
    n: usize,
    k: usize,
    subset: &[usize],
    trials: usize,
    seed: u64,
) -> anyhow::Result<bool> {
    let place = placement(n, k)?;
    let mut rng = SplitMix64::new(seed);
    for _ in 0..trials {
        let schedule: Vec<NodeSchedule<Gf65536>> = place
            .iter()
            .map(|locals| NodeSchedule {
                locals: locals.clone(),
                psi: locals.iter().map(|_| Gf65536(rng.range(1, 65536) as u16)).collect(),
                xi: locals.iter().map(|_| Gf65536(rng.range(1, 65536) as u16)).collect(),
            })
            .collect();
        let g = crate::codes::rapidraid::generator_matrix(n, k, &schedule);
        if rank(&g.select_rows(subset)) == k {
            return Ok(false);
        }
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_84_census() {
        // Section IV-B: exactly one natural dependency among the 70 subsets.
        let r = census(8, 4, 3, 1).unwrap();
        assert_eq!(r.total_subsets, 70);
        assert_eq!(r.natural_dependent, vec![vec![0, 1, 4, 5]]);
        assert!(!r.is_mds());
        assert!((r.percent_independent() - 100.0 * 69.0 / 70.0).abs() < 1e-9);
    }

    #[test]
    fn conjecture1_mds_iff_k_ge_n_minus_3_n8() {
        // Fig. 3 / Conjecture 1 for n = 8, all k in [n/2, n)
        for k in 4..8 {
            let r = census(8, k, 3, 2).unwrap();
            assert_eq!(r.is_mds(), k >= 8 - 3, "k={k}: {:?}", r.dependent_count());
        }
    }

    #[test]
    fn conjecture1_holds_n12_sampled() {
        for k in [9usize, 10, 11] {
            let r = census(12, k, 2, 3).unwrap();
            assert!(r.is_mds(), "(12,{k}) should be MDS");
        }
        let r = census(12, 8, 2, 3).unwrap();
        assert!(!r.is_mds(), "(12,8) should have natural dependencies");
    }

    #[test]
    fn natural_dependency_checker_agrees() {
        assert!(is_natural_dependency(8, 4, &[0, 1, 4, 5], 4, 10).unwrap());
        assert!(!is_natural_dependency(8, 4, &[0, 1, 2, 3], 4, 10).unwrap());
    }

    #[test]
    fn dependent_subsets_counts_at_least_natural() {
        let code = RapidRaidCode::<Gf65536>::with_seed(8, 4, 3).unwrap();
        assert!(dependent_subsets(&code) >= 1);
        // GF(2^8) with an unlucky seed may add accidental ones; GF(2^16)
        // should essentially never.
        assert_eq!(dependent_subsets(&code), 1);
    }
}
