//! Topology-generic coefficient composition: derive the generator matrix a
//! RapidRAID coefficient schedule implies when the pipeline runs over an
//! arbitrary rooted shape instead of the paper's linear chain.
//!
//! A [`TopologyShape`] is a rooted tree over code positions `0..n-1`
//! (position 0 is the root, every parent index precedes its children). The
//! pipeline *diffuses* down the shape: position i receives its parent's
//! running combination `x`, stores `c_i = x ⊕ Σ ξ·o_local` and forwards
//! `x ⊕ Σ ψ·o_local` to every child — eqs. (3)/(4) with "upstream" meaning
//! "root path" instead of "chain prefix". [`topology_generator`] composes
//! the per-position coefficient rows exactly the way
//! [`generator_matrix`](crate::codes::rapidraid::generator_matrix) does for
//! the chain (the chain shape reproduces it entry for entry), so a
//! [`TopologyCode`] decodes and repairs with the same generator-driven
//! machinery ([`CodeView`]) as the chain code.
//!
//! Decodability floor: positions `0..k-1` hold the first replica of blocks
//! `0..k-1` and every ancestor precedes its descendants, so those k rows
//! are lower-triangular with the nonzero ξ on the diagonal — **any** shape
//! yields a full-rank generator and full availability always decodes.

use crate::codes::classical::decode_with_generator;
use crate::codes::rapidraid::{NodeSchedule, RapidRaidCode};
use crate::codes::{CodeView, DecodeError};
use crate::gf::{GfElem, Matrix, SliceOps};

/// A rooted pipeline shape over code positions `0..n-1`: `parents[0]` is
/// `None` (the root), and every other position's parent precedes it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TopologyShape {
    parents: Vec<Option<usize>>,
}

impl TopologyShape {
    /// Validate and wrap a parent array. Requires position 0 to be the
    /// sole root and `parents[i] < i` for every other position — which
    /// makes the shape acyclic by construction and lets the composition
    /// walk positions in index order.
    pub fn new(parents: Vec<Option<usize>>) -> anyhow::Result<Self> {
        anyhow::ensure!(!parents.is_empty(), "topology shape over zero positions");
        anyhow::ensure!(parents[0].is_none(), "position 0 must be the root");
        for (i, p) in parents.iter().enumerate().skip(1) {
            match p {
                Some(p) => anyhow::ensure!(
                    *p < i,
                    "position {i}: parent {p} must precede its child"
                ),
                None => anyhow::bail!("position {i}: only position 0 may be the root"),
            }
        }
        Ok(Self { parents })
    }

    /// The paper's linear chain over `n` positions.
    pub fn chain(n: usize) -> Self {
        Self {
            parents: (0..n).map(|i| i.checked_sub(1)).collect(),
        }
    }

    /// Number of positions.
    pub fn n(&self) -> usize {
        self.parents.len()
    }

    /// Parent of position `i` (`None` for the root).
    pub fn parent(&self, i: usize) -> Option<usize> {
        self.parents[i]
    }

    /// The raw parent array.
    pub fn parents(&self) -> &[Option<usize>] {
        &self.parents
    }

    /// Children of every position, in ascending order.
    pub fn children(&self) -> Vec<Vec<usize>> {
        let mut kids = vec![Vec::new(); self.parents.len()];
        for (i, p) in self.parents.iter().enumerate() {
            if let Some(p) = p {
                kids[*p].push(i);
            }
        }
        kids
    }

    /// Longest root→leaf path, in edges (0 for a single position; `n-1`
    /// for a chain).
    pub fn depth(&self) -> usize {
        let mut depth = vec![0usize; self.parents.len()];
        let mut max = 0;
        for i in 1..self.parents.len() {
            depth[i] = depth[self.parents[i].expect("non-root")] + 1;
            max = max.max(depth[i]);
        }
        max
    }

    /// Largest child count of any position.
    pub fn max_fanout(&self) -> usize {
        self.children().iter().map(|c| c.len()).max().unwrap_or(0)
    }

    /// True iff the shape is the linear chain.
    pub fn is_chain(&self) -> bool {
        self.parents
            .iter()
            .enumerate()
            .all(|(i, p)| *p == i.checked_sub(1))
    }
}

/// Compose the coefficient schedule over `shape` into the explicit n×k
/// generator matrix: row i is the root-path ψ prefix of position i plus
/// its own ξ contribution. For [`TopologyShape::chain`] this reproduces
/// [`crate::codes::rapidraid::generator_matrix`] entry for entry.
pub fn topology_generator<F: GfElem>(
    k: usize,
    schedule: &[NodeSchedule<F>],
    shape: &TopologyShape,
) -> Matrix<F> {
    assert_eq!(schedule.len(), shape.n(), "schedule/shape length mismatch");
    let n = schedule.len();
    let mut g = Matrix::<F>::zero(n, k);
    // xrow_out[i] = coefficients (over o_0..o_{k-1}) of the combination
    // position i forwards to its children. Parents precede children, so a
    // single index-order walk sees every parent's row before its children.
    let mut xrow_out: Vec<Vec<F>> = Vec::with_capacity(n);
    for (i, sched) in schedule.iter().enumerate() {
        let mut x = match shape.parent(i) {
            Some(p) => xrow_out[p].clone(),
            None => vec![F::ZERO; k],
        };
        // c_i = x_in ⊕ Σ ξ·o — snapshot BEFORE folding ψ into x.
        for (j, &blk) in sched.locals.iter().enumerate() {
            g[(i, blk)] = x[blk].add(sched.xi[j]);
        }
        for (blk, coeff) in (0..k).filter(|b| !sched.locals.contains(b)).map(|b| (b, x[b])) {
            g[(i, blk)] = coeff;
        }
        for (j, &blk) in sched.locals.iter().enumerate() {
            x[blk] = x[blk].add(sched.psi[j]);
        }
        xrow_out.push(x);
    }
    g
}

/// A RapidRAID coefficient schedule bound to a pipeline shape, with the
/// derived generator: the object every non-chain consumer (decode, repair,
/// reliability census) works against.
#[derive(Clone)]
pub struct TopologyCode<F: GfElem> {
    code: RapidRaidCode<F>,
    shape: TopologyShape,
    generator: Matrix<F>,
}

impl<F: GfElem + SliceOps> TopologyCode<F> {
    /// Bind `code`'s schedule to `shape` and derive the generator.
    pub fn new(code: RapidRaidCode<F>, shape: TopologyShape) -> anyhow::Result<Self> {
        anyhow::ensure!(
            shape.n() == code.n(),
            "shape has {} positions, code length is {}",
            shape.n(),
            code.n()
        );
        let generator = topology_generator(code.k(), code.schedule(), &shape);
        Ok(Self {
            code,
            shape,
            generator,
        })
    }

    /// The underlying coefficient schedule.
    pub fn code(&self) -> &RapidRaidCode<F> {
        &self.code
    }

    /// The pipeline shape.
    pub fn shape(&self) -> &TopologyShape {
        &self.shape
    }

    /// Encode by literally diffusing down the shape (reference
    /// implementation of the distributed topology pipeline).
    pub fn encode(&self, object: &[Vec<F>]) -> Vec<Vec<F>> {
        assert_eq!(object.len(), self.code.k(), "object must have k blocks");
        let len = object[0].len();
        assert!(object.iter().all(|b| b.len() == len), "ragged blocks");
        let mut forwarded: Vec<Vec<F>> = Vec::with_capacity(self.code.n());
        let mut out = Vec::with_capacity(self.code.n());
        for i in 0..self.code.n() {
            let x_in = match self.shape.parent(i) {
                Some(p) => forwarded[p].clone(),
                None => vec![F::ZERO; len],
            };
            let locals: Vec<&[F]> = self.code.schedule()[i]
                .locals
                .iter()
                .map(|&b| object[b].as_slice())
                .collect();
            let (x_next, c) = self.code.step(i, &x_in, &locals);
            out.push(c);
            forwarded.push(x_next);
        }
        out
    }

    /// Encode atomically via the derived generator (cross-check path; must
    /// equal [`TopologyCode::encode`] exactly).
    pub fn encode_matrix(&self, object: &[Vec<F>]) -> Vec<Vec<F>> {
        assert_eq!(object.len(), self.code.k());
        let len = object[0].len();
        let mut out = vec![vec![F::ZERO; len]; self.code.n()];
        for (i, row_out) in out.iter_mut().enumerate() {
            for (j, block) in object.iter().enumerate() {
                F::mul_slice_xor(self.generator[(i, j)], block, row_out);
            }
        }
        out
    }

    /// Reconstruct the object from any k independent blocks.
    pub fn decode(&self, have: &[(usize, Vec<F>)]) -> Result<Vec<Vec<F>>, DecodeError> {
        decode_with_generator(&self.generator, self.code.n(), self.code.k(), have)
    }
}

impl<F: GfElem + SliceOps> CodeView<F> for TopologyCode<F> {
    fn n(&self) -> usize {
        self.code.n()
    }

    fn k(&self) -> usize {
        self.code.k()
    }

    fn generator(&self) -> &Matrix<F> {
        &self.generator
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::subsets::Combinations;
    use crate::gf::{gauss, Gf256, Gf65536};
    use crate::util::SplitMix64;

    fn random_object<F: GfElem>(seed: u64, k: usize, len: usize) -> Vec<Vec<F>> {
        let mut rng = SplitMix64::new(seed);
        let mask = (1u64 << F::BITS) - 1;
        (0..k)
            .map(|_| (0..len).map(|_| F::from_u32((rng.next_u64() & mask) as u32)).collect())
            .collect()
    }

    fn binary_tree(n: usize) -> TopologyShape {
        TopologyShape::new((0..n).map(|i| i.checked_sub(1).map(|x| x / 2)).collect()).unwrap()
    }

    #[test]
    fn shape_validation() {
        assert!(TopologyShape::new(vec![]).is_err());
        assert!(TopologyShape::new(vec![Some(0)]).is_err()); // no root
        assert!(TopologyShape::new(vec![None, None]).is_err()); // two roots
        assert!(TopologyShape::new(vec![None, Some(2), Some(0)]).is_err()); // parent after child
        let s = TopologyShape::new(vec![None, Some(0), Some(0), Some(1)]).unwrap();
        assert_eq!(s.n(), 4);
        assert_eq!(s.children(), vec![vec![1, 2], vec![3], vec![], vec![]]);
        assert_eq!(s.depth(), 2);
        assert_eq!(s.max_fanout(), 2);
        assert!(!s.is_chain());
    }

    #[test]
    fn chain_shape_matches_chain_generator() {
        for (n, k) in [(8usize, 4usize), (6, 4), (16, 11)] {
            let code = RapidRaidCode::<Gf256>::with_seed(n, k, 42).unwrap();
            let shape = TopologyShape::chain(n);
            assert!(shape.is_chain());
            assert_eq!(shape.depth(), n - 1);
            let g = topology_generator(k, code.schedule(), &shape);
            assert_eq!(&g, code.generator(), "(n={n},k={k})");
        }
    }

    #[test]
    fn tree_encode_equals_matrix_encode() {
        for (n, k) in [(8usize, 4usize), (6, 4), (16, 11)] {
            let code = RapidRaidCode::<Gf256>::with_seed(n, k, 7).unwrap();
            let tc = TopologyCode::new(code, binary_tree(n)).unwrap();
            let obj = random_object::<Gf256>(1, k, 300);
            assert_eq!(tc.encode(&obj), tc.encode_matrix(&obj), "(n={n},k={k})");
        }
    }

    #[test]
    fn first_k_rows_are_triangular_for_any_shape() {
        // positions 0..k-1 stay independent under every ordered shape: the
        // decodability floor the module docs promise.
        for (n, k) in [(8usize, 4usize), (6, 4), (16, 11), (12, 8)] {
            for shape in [TopologyShape::chain(n), binary_tree(n)] {
                let code = RapidRaidCode::<Gf65536>::with_seed(n, k, 3).unwrap();
                let g = topology_generator(k, code.schedule(), &shape);
                let first_k: Vec<usize> = (0..k).collect();
                assert_eq!(gauss::rank(&g.select_rows(&first_k)), k, "(n={n},k={k})");
            }
        }
    }

    #[test]
    fn tree_code_decodes_every_independent_subset() {
        let code = RapidRaidCode::<Gf65536>::with_seed(8, 4, 12).unwrap();
        let tc = TopologyCode::new(code, binary_tree(8)).unwrap();
        let obj = random_object::<Gf65536>(4, 4, 64);
        let coded = tc.encode(&obj);
        let mut independent = 0usize;
        for sub in Combinations::new(8, 4) {
            let have: Vec<(usize, Vec<Gf65536>)> =
                sub.iter().map(|&i| (i, coded[i].clone())).collect();
            match tc.decode(&have) {
                Ok(rec) => {
                    independent += 1;
                    assert_eq!(rec, obj, "subset {sub:?}");
                }
                Err(DecodeError::DependentSubset { .. }) => {}
                Err(e) => panic!("unexpected error {e:?}"),
            }
        }
        assert!(independent > 0, "no decodable subset at all");
    }

    #[test]
    fn tree_repair_coefficients_reproduce_lost_block() {
        let code = RapidRaidCode::<Gf256>::with_seed(8, 4, 7).unwrap();
        let tc = TopologyCode::new(code, binary_tree(8)).unwrap();
        let obj = random_object::<Gf256>(9, 4, 64);
        let coded = tc.encode(&obj);
        for lost in 0..8usize {
            let avail: Vec<usize> = (0..8).filter(|&p| p != lost).collect();
            let (subset, psi) = match tc.repair_coefficients(lost, &avail) {
                Ok(r) => r,
                // a small-field draw may leave some losses unrepairable
                // from 7 survivors; skip those (the census quantifies them)
                Err(_) => continue,
            };
            let mut rebuilt = vec![Gf256::ZERO; 64];
            for (i, &p) in subset.iter().enumerate() {
                Gf256::mul_slice_xor(psi[i], &coded[p], &mut rebuilt);
            }
            assert_eq!(rebuilt, coded[lost], "lost {lost}");
        }
    }

    #[test]
    fn mismatched_shape_rejected() {
        let code = RapidRaidCode::<Gf256>::with_seed(8, 4, 7).unwrap();
        assert!(TopologyCode::new(code, TopologyShape::chain(6)).is_err());
    }
}
