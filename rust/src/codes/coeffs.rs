//! Coefficient search: pick ψ/ξ values that avoid *accidental* linear
//! dependencies (paper Section V-A).
//!
//! Over GF(2^16) a single random draw is almost always optimal; over
//! GF(2^8) the field is small enough that random draws routinely create
//! accidental dependencies (the paper notes its RR8 build ships with
//! slightly lower reliability for exactly this reason). The search retries
//! seeds and keeps the draw whose dependent-subset count is minimal, i.e.
//! as close to the natural-dependency floor as the budget allows.

use crate::codes::census::dependent_subsets;
use crate::codes::rapidraid::RapidRaidCode;
use crate::gf::{GfElem, SliceOps};

/// Outcome of a coefficient search.
#[derive(Debug, Clone)]
pub struct SearchResult {
    /// Seed of the winning draw (feed to [`RapidRaidCode::with_seed`]).
    pub seed: u64,
    /// Dependent k-subsets under the winning draw (natural + accidental).
    pub dependent: u64,
    /// Seeds examined.
    pub tried: u32,
}

/// Search up to `budget` seeds for the draw with the fewest dependent
/// k-subsets; stops early when `floor` (the known natural-dependency count,
/// e.g. from [`crate::codes::census::census`]) is reached.
///
/// Exhaustive subset scoring costs C(n, k) rank computations per seed — fine
/// for the paper's (16, 11) (4368 subsets) and below.
pub fn search<F: GfElem + SliceOps>(
    n: usize,
    k: usize,
    budget: u32,
    floor: u64,
    seed0: u64,
) -> anyhow::Result<SearchResult> {
    anyhow::ensure!(budget >= 1);
    let mut best: Option<SearchResult> = None;
    for t in 0..budget {
        let seed = seed0.wrapping_add(t as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let code = RapidRaidCode::<F>::with_seed(n, k, seed)?;
        let dep = dependent_subsets(&code);
        let better = best.as_ref().map_or(true, |b| dep < b.dependent);
        if better {
            best = Some(SearchResult {
                seed,
                dependent: dep,
                tried: t + 1,
            });
            if dep <= floor {
                break;
            }
        }
    }
    Ok(best.expect("budget >= 1 guarantees a result"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf::{Gf256, Gf65536};

    #[test]
    fn gf65536_search_hits_floor_immediately() {
        // (8,4) floor = 1 natural dependency; GF(2^16) should reach it fast.
        let r = search::<Gf65536>(8, 4, 8, 1, 42).unwrap();
        assert_eq!(r.dependent, 1);
        assert!(r.tried <= 8);
        // winning seed reproduces the score
        let code = RapidRaidCode::<Gf65536>::with_seed(8, 4, r.seed).unwrap();
        assert_eq!(dependent_subsets(&code), 1);
    }

    #[test]
    fn gf256_search_improves_or_matches_first_draw() {
        let first = {
            let code = RapidRaidCode::<Gf256>::with_seed(8, 4, 0x9E3779B97F4A7C15u64.wrapping_mul(1)).unwrap();
            dependent_subsets(&code)
        };
        let r = search::<Gf256>(8, 4, 12, 1, 0).unwrap();
        assert!(r.dependent <= first);
        assert!(r.dependent >= 1, "cannot beat the natural floor");
    }

    #[test]
    fn search_respects_budget_one() {
        let r = search::<Gf65536>(8, 4, 1, 0, 7).unwrap();
        assert_eq!(r.tried, 1);
    }
}
