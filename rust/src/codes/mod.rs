//! Erasure-code constructions: the classical Cauchy Reed-Solomon baseline
//! (*CEC* in the paper) and the RapidRAID pipelined family, plus the
//! coefficient search and the linear-dependency census behind Fig. 3 /
//! Table I / Conjecture 1.

pub mod census;
pub mod classical;
pub mod coeffs;
pub mod rapidraid;
pub mod subsets;
pub mod topology;

pub use census::{census, CensusReport};
pub use classical::ClassicalCode;
pub use rapidraid::RapidRaidCode;
pub use subsets::Combinations;
pub use topology::{topology_generator, TopologyCode, TopologyShape};

use crate::gf::{gauss, GfElem, Matrix, SliceOps};

/// Greedy search for a decodable k-subset among `avail` generator rows;
/// returns `None` when every k-subset of `avail` is dependent. Greedy
/// rank-building is exact over a field: keep a row iff it increases the
/// rank of the selected set.
pub fn decodable_subset<F: GfElem>(
    generator: &Matrix<F>,
    k: usize,
    avail: &[usize],
) -> Option<Vec<usize>> {
    if avail.len() < k {
        return None;
    }
    let mut chosen: Vec<usize> = Vec::with_capacity(k);
    for &idx in avail {
        let mut trial = chosen.clone();
        trial.push(idx);
        let sub = generator.select_rows(&trial);
        if gauss::rank(&sub) == trial.len() {
            chosen = trial;
            if chosen.len() == k {
                return Some(chosen);
            }
        }
    }
    None
}

/// Repair coefficients for regenerating codeword block `lost` from
/// surviving blocks under an arbitrary n×k `generator`: picks an
/// independent k-subset S of `avail` (minus `lost` itself) and returns
/// `(S, ψ)` with `c_lost = Σ ψ[i]·c_{S[i]}`, i.e. `ψ = g_lost · G_S⁻¹`.
pub fn repair_coefficients_from<F: GfElem>(
    generator: &Matrix<F>,
    n: usize,
    k: usize,
    lost: usize,
    avail: &[usize],
) -> anyhow::Result<(Vec<usize>, Vec<F>)> {
    anyhow::ensure!(lost < n, "lost index {lost} out of range (n={n})");
    let usable: Vec<usize> = avail.iter().copied().filter(|&p| p != lost).collect();
    let subset = decodable_subset(generator, k, &usable).ok_or_else(|| {
        anyhow::anyhow!("block {lost} unrepairable: no independent k-subset among {usable:?}")
    })?;
    let inv = gauss::invert(&generator.select_rows(&subset))
        .ok_or_else(|| anyhow::anyhow!("subset {subset:?} unexpectedly singular"))?;
    let g_lost = generator.row(lost);
    let psi: Vec<F> = (0..k)
        .map(|j| (0..k).fold(F::ZERO, |acc, i| acc.add(g_lost[i].mul(inv[(i, j)]))))
        .collect();
    Ok((subset, psi))
}

/// Generator-level view of a linear code — the surface decode, repair and
/// the reliability census actually consume. [`RapidRaidCode`] (the chain
/// composition) and [`TopologyCode`] (tree/hybrid compositions) both
/// implement it, so every consumer is topology-generic for free.
pub trait CodeView<F: GfElem + SliceOps> {
    /// Codeword length n.
    fn n(&self) -> usize;

    /// Message length k.
    fn k(&self) -> usize;

    /// The n×k generator matrix.
    fn generator(&self) -> &Matrix<F>;

    /// Greedy decodable k-subset among the available block indices.
    fn find_decodable_subset(&self, avail: &[usize]) -> Option<Vec<usize>> {
        decodable_subset(self.generator(), self.k(), avail)
    }

    /// Repair coefficients `ψ = g_lost · G_S⁻¹` over an independent
    /// k-subset S of `avail`.
    fn repair_coefficients(
        &self,
        lost: usize,
        avail: &[usize],
    ) -> anyhow::Result<(Vec<usize>, Vec<F>)> {
        repair_coefficients_from(self.generator(), self.n(), self.k(), lost, avail)
    }
}

/// Erasure decode failure reasons.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DecodeError {
    /// Fewer than k blocks supplied.
    NotEnoughBlocks { got: usize, need: usize },
    /// The supplied k blocks are linearly dependent (non-MDS subset or
    /// duplicate indices).
    DependentSubset { indices: Vec<usize> },
    /// A block index is out of range for the code.
    BadIndex { index: usize, n: usize },
    /// Supplied blocks have inconsistent lengths.
    LengthMismatch,
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::NotEnoughBlocks { got, need } => {
                write!(f, "need {need} blocks to decode, got {got}")
            }
            Self::DependentSubset { indices } => {
                write!(f, "blocks {indices:?} are linearly dependent; pick another subset")
            }
            Self::BadIndex { index, n } => write!(f, "block index {index} out of range (n={n})"),
            Self::LengthMismatch => write!(f, "blocks have inconsistent lengths"),
        }
    }
}

impl std::error::Error for DecodeError {}
