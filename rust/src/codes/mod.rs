//! Erasure-code constructions: the classical Cauchy Reed-Solomon baseline
//! (*CEC* in the paper) and the RapidRAID pipelined family, plus the
//! coefficient search and the linear-dependency census behind Fig. 3 /
//! Table I / Conjecture 1.

pub mod census;
pub mod classical;
pub mod coeffs;
pub mod rapidraid;
pub mod subsets;

pub use census::{census, CensusReport};
pub use classical::ClassicalCode;
pub use rapidraid::RapidRaidCode;
pub use subsets::Combinations;

/// Erasure decode failure reasons.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DecodeError {
    /// Fewer than k blocks supplied.
    NotEnoughBlocks { got: usize, need: usize },
    /// The supplied k blocks are linearly dependent (non-MDS subset or
    /// duplicate indices).
    DependentSubset { indices: Vec<usize> },
    /// A block index is out of range for the code.
    BadIndex { index: usize, n: usize },
    /// Supplied blocks have inconsistent lengths.
    LengthMismatch,
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::NotEnoughBlocks { got, need } => {
                write!(f, "need {need} blocks to decode, got {got}")
            }
            Self::DependentSubset { indices } => {
                write!(f, "blocks {indices:?} are linearly dependent; pick another subset")
            }
            Self::BadIndex { index, n } => write!(f, "block index {index} out of range (n={n})"),
            Self::LengthMismatch => write!(f, "blocks have inconsistent lengths"),
        }
    }
}

impl std::error::Error for DecodeError {}
