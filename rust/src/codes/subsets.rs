//! Lexicographic k-subset enumeration (the census iterates all C(n,k)
//! subsets of codeword indices; no external itertools offline).

/// Iterator over all k-element subsets of `0..n` in lexicographic order.
pub struct Combinations {
    n: usize,
    k: usize,
    current: Vec<usize>,
    done: bool,
}

impl Combinations {
    /// All k-subsets of `0..n`. `k > n` yields nothing; `k == 0` yields one
    /// empty subset.
    pub fn new(n: usize, k: usize) -> Self {
        Self {
            n,
            k,
            current: (0..k).collect(),
            done: k > n,
        }
    }
}

impl Iterator for Combinations {
    type Item = Vec<usize>;

    fn next(&mut self) -> Option<Vec<usize>> {
        if self.done {
            return None;
        }
        let out = self.current.clone();
        // advance: find rightmost index that can grow
        if self.k == 0 {
            self.done = true;
            return Some(out);
        }
        let mut i = self.k;
        loop {
            if i == 0 {
                self.done = true;
                break;
            }
            i -= 1;
            if self.current[i] < self.n - self.k + i {
                self.current[i] += 1;
                for j in i + 1..self.k {
                    self.current[j] = self.current[j - 1] + 1;
                }
                break;
            }
        }
        Some(out)
    }
}

/// Binomial coefficient C(n, k) without overflow for the sizes we enumerate.
pub fn binomial(n: usize, k: usize) -> u64 {
    if k > n {
        return 0;
    }
    let k = k.min(n - k);
    let mut num = 1u128;
    let mut den = 1u128;
    for i in 0..k {
        num *= (n - i) as u128;
        den *= (i + 1) as u128;
    }
    (num / den) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_match_binomial() {
        for (n, k) in [(8, 4), (6, 3), (5, 0), (5, 5), (10, 2)] {
            let count = Combinations::new(n, k).count() as u64;
            assert_eq!(count, binomial(n, k), "(n={n}, k={k})");
        }
    }

    #[test]
    fn lexicographic_order_and_validity() {
        let all: Vec<Vec<usize>> = Combinations::new(6, 3).collect();
        assert_eq!(all.first().unwrap(), &vec![0, 1, 2]);
        assert_eq!(all.last().unwrap(), &vec![3, 4, 5]);
        for w in all.windows(2) {
            assert!(w[0] < w[1], "not lexicographic: {:?} !< {:?}", w[0], w[1]);
        }
        for s in &all {
            assert!(s.windows(2).all(|w| w[0] < w[1]));
            assert!(s.iter().all(|&x| x < 6));
        }
    }

    #[test]
    fn k_greater_than_n_is_empty() {
        assert_eq!(Combinations::new(3, 4).count(), 0);
    }

    #[test]
    fn k_zero_yields_one_empty() {
        let all: Vec<_> = Combinations::new(5, 0).collect();
        assert_eq!(all, vec![Vec::<usize>::new()]);
    }

    #[test]
    fn binomial_values() {
        assert_eq!(binomial(8, 4), 70); // the paper's (8,4) example
        assert_eq!(binomial(16, 11), 4368); // the evaluated (16,11) code
        assert_eq!(binomial(12, 6), 924);
        assert_eq!(binomial(4, 5), 0);
    }
}
