//! Classical systematic Cauchy Reed-Solomon code — the paper's *CEC*
//! baseline (Jerasure's Cauchy RS, per Plank et al. [23]).
//!
//! Generator `G = [I_k ; C]` with `C` an (n−k)×k Cauchy matrix: the first k
//! codeword blocks are the raw object (systematic), the last m = n−k are
//! parity. Any k-subset of rows of G is invertible (MDS).

use crate::codes::DecodeError;
use crate::gf::{gauss, GfElem, Matrix, SliceOps};

/// A systematic (n, k) MDS erasure code.
#[derive(Clone)]
pub struct ClassicalCode<F: GfElem> {
    n: usize,
    k: usize,
    /// Full n×k generator (identity stacked on Cauchy parity rows).
    generator: Matrix<F>,
}

impl<F: GfElem + SliceOps> ClassicalCode<F> {
    /// Build an (n, k) systematic Cauchy-RS code. Requires k < n and the
    /// field to be large enough for an (n−k)+k Cauchy construction.
    pub fn new(n: usize, k: usize) -> anyhow::Result<Self> {
        anyhow::ensure!(k >= 1, "k must be >= 1");
        anyhow::ensure!(k < n, "need k < n, got (n={n}, k={k})");
        let parity = Matrix::<F>::cauchy(n - k, k);
        let generator = Matrix::<F>::identity(k).vstack(&parity);
        Ok(Self { n, k, generator })
    }

    /// Codeword length n.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Message length k.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Parity count m = n − k.
    pub fn m(&self) -> usize {
        self.n - self.k
    }

    /// The n×k generator matrix.
    pub fn generator(&self) -> &Matrix<F> {
        &self.generator
    }

    /// The (n−k)×k parity sub-matrix G′ (what the encoding node actually
    /// applies; the systematic rows are free).
    pub fn parity_matrix(&self) -> Matrix<F> {
        let rows: Vec<usize> = (self.k..self.n).collect();
        self.generator.select_rows(&rows)
    }

    /// Encode a full object: returns only the m parity blocks (the k data
    /// blocks are stored as-is — systematic code).
    pub fn encode_parity(&self, object: &[Vec<F>]) -> Vec<Vec<F>> {
        assert_eq!(object.len(), self.k, "object must have k blocks");
        let len = object[0].len();
        assert!(object.iter().all(|b| b.len() == len), "ragged blocks");
        let mut parity = vec![vec![F::ZERO; len]; self.m()];
        for (pi, p) in parity.iter_mut().enumerate() {
            let row = self.generator.row(self.k + pi);
            for (j, block) in object.iter().enumerate() {
                F::mul_slice_xor(row[j], block, p);
            }
        }
        parity
    }

    /// Incremental parity: fold ONE buffer of source block `j` into the m
    /// parity accumulators — the streamlined encoding loop of Section III
    /// (the coding node encodes network-buffer by network-buffer as the k
    /// downloads progress).
    pub fn fold_parity_buffer(&self, j: usize, src: &[F], parity: &mut [Vec<F>]) {
        debug_assert_eq!(parity.len(), self.m());
        for (pi, p) in parity.iter_mut().enumerate() {
            F::mul_slice_xor(self.generator[(self.k + pi, j)], src, p);
        }
    }

    /// Reconstruct the object from any k available blocks `(index, data)`.
    pub fn decode(&self, have: &[(usize, Vec<F>)]) -> Result<Vec<Vec<F>>, DecodeError> {
        decode_with_generator(&self.generator, self.n, self.k, have)
    }
}

/// Shared decode path: select the k generator rows matching the supplied
/// block indices, invert, and apply the inverse row by row with slice ops.
/// Used by both the classical and the RapidRAID code.
pub(crate) fn decode_with_generator<F: GfElem + SliceOps>(
    generator: &Matrix<F>,
    n: usize,
    k: usize,
    have: &[(usize, Vec<F>)],
) -> Result<Vec<Vec<F>>, DecodeError> {
    if have.len() < k {
        return Err(DecodeError::NotEnoughBlocks {
            got: have.len(),
            need: k,
        });
    }
    let have = &have[..k];
    let mut indices = Vec::with_capacity(k);
    for (idx, _) in have {
        if *idx >= n {
            return Err(DecodeError::BadIndex { index: *idx, n });
        }
        indices.push(*idx);
    }
    let len = have[0].1.len();
    if have.iter().any(|(_, b)| b.len() != len) {
        return Err(DecodeError::LengthMismatch);
    }
    let sub = generator.select_rows(&indices);
    let inv = gauss::invert(&sub).ok_or(DecodeError::DependentSubset {
        indices: indices.clone(),
    })?;
    // object[j] = XOR_i inv[j][i] * coded[i]
    let mut object = vec![vec![F::ZERO; len]; k];
    for (j, out) in object.iter_mut().enumerate() {
        for (i, (_, block)) in have.iter().enumerate() {
            F::mul_slice_xor(inv[(j, i)], block, out);
        }
    }
    Ok(object)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf::{Gf256, Gf65536};
    use crate::util::prop::forall;
    use crate::util::SplitMix64;

    fn random_object<F: GfElem>(rng: &mut SplitMix64, k: usize, len: usize) -> Vec<Vec<F>> {
        let mask = (1u64 << F::BITS) - 1;
        (0..k)
            .map(|_| (0..len).map(|_| F::from_u32((rng.next_u64() & mask) as u32)).collect())
            .collect()
    }

    #[test]
    fn roundtrip_from_systematic_blocks() {
        let code = ClassicalCode::<Gf256>::new(8, 4).unwrap();
        let mut rng = SplitMix64::new(1);
        let obj = random_object::<Gf256>(&mut rng, 4, 256);
        let have: Vec<(usize, Vec<Gf256>)> =
            (0..4).map(|i| (i, obj[i].clone())).collect();
        assert_eq!(code.decode(&have).unwrap(), obj);
    }

    #[test]
    fn roundtrip_from_parity_only() {
        let code = ClassicalCode::<Gf256>::new(8, 4).unwrap();
        let mut rng = SplitMix64::new(2);
        let obj = random_object::<Gf256>(&mut rng, 4, 128);
        let parity = code.encode_parity(&obj);
        let have: Vec<(usize, Vec<Gf256>)> =
            (0..4).map(|i| (4 + i, parity[i].clone())).collect();
        assert_eq!(code.decode(&have).unwrap(), obj);
    }

    #[test]
    fn mds_all_subsets_16_11_sampled() {
        // exhaustive over all C(8,4)=70 subsets for the small code
        let code = ClassicalCode::<Gf256>::new(8, 4).unwrap();
        for sub in crate::codes::subsets::Combinations::new(8, 4) {
            let s = code.generator().select_rows(&sub);
            assert!(gauss::is_invertible(&s), "subset {sub:?} not invertible");
        }
    }

    #[test]
    fn fold_parity_buffer_equals_batch_encode() {
        let code = ClassicalCode::<Gf256>::new(16, 11).unwrap();
        let mut rng = SplitMix64::new(3);
        let obj = random_object::<Gf256>(&mut rng, 11, 512);
        let batch = code.encode_parity(&obj);
        // streamed: two buffers of 256 per block, folded in arbitrary order
        let mut parity = vec![vec![Gf256::ZERO; 512]; 5];
        for j in 0..11 {
            for half in 0..2 {
                let range = half * 256..(half + 1) * 256;
                let mut acc: Vec<Vec<Gf256>> =
                    parity.iter().map(|p| p[range.clone()].to_vec()).collect();
                code.fold_parity_buffer(j, &obj[j][range.clone()], &mut acc);
                for (p, a) in parity.iter_mut().zip(acc) {
                    p[range.clone()].copy_from_slice(&a);
                }
            }
        }
        assert_eq!(parity, batch);
    }

    #[test]
    fn decode_errors() {
        let code = ClassicalCode::<Gf256>::new(6, 3).unwrap();
        let b = vec![Gf256::ZERO; 16];
        // not enough blocks
        assert!(matches!(
            code.decode(&[(0, b.clone())]),
            Err(DecodeError::NotEnoughBlocks { got: 1, need: 3 })
        ));
        // bad index
        assert!(matches!(
            code.decode(&[(0, b.clone()), (1, b.clone()), (9, b.clone())]),
            Err(DecodeError::BadIndex { index: 9, n: 6 })
        ));
        // duplicate indices => dependent
        assert!(matches!(
            code.decode(&[(0, b.clone()), (0, b.clone()), (1, b.clone())]),
            Err(DecodeError::DependentSubset { .. })
        ));
        // ragged lengths
        assert!(matches!(
            code.decode(&[(0, b.clone()), (1, vec![Gf256::ZERO; 8]), (2, b)]),
            Err(DecodeError::LengthMismatch)
        ));
    }

    #[test]
    fn gf65536_roundtrip() {
        let code = ClassicalCode::<Gf65536>::new(16, 11).unwrap();
        let mut rng = SplitMix64::new(4);
        let obj = random_object::<Gf65536>(&mut rng, 11, 64);
        let parity = code.encode_parity(&obj);
        // mixed subset: 7 systematic + 4 parity
        let mut have: Vec<(usize, Vec<Gf65536>)> =
            (0..7).map(|i| (i, obj[i].clone())).collect();
        have.extend((0..4).map(|i| (11 + i, parity[i].clone())));
        assert_eq!(code.decode(&have).unwrap(), obj);
    }

    #[test]
    fn prop_roundtrip_random_subsets() {
        forall(25, 7, |rng| {
            let (n, k) = (10, 6);
            let code = ClassicalCode::<Gf256>::new(n, k).unwrap();
            let obj = random_object::<Gf256>(rng, k, 64);
            let parity = code.encode_parity(&obj);
            let all: Vec<Vec<Gf256>> =
                obj.iter().cloned().chain(parity.iter().cloned()).collect();
            let pick = rng.sample_indices(n, k);
            let have: Vec<(usize, Vec<Gf256>)> =
                pick.iter().map(|&i| (i, all[i].clone())).collect();
            assert_eq!(code.decode(&have).unwrap(), obj, "subset {pick:?}");
        });
    }

    #[test]
    fn invalid_params_rejected() {
        assert!(ClassicalCode::<Gf256>::new(4, 4).is_err());
        assert!(ClassicalCode::<Gf256>::new(3, 0).is_err());
    }
}
