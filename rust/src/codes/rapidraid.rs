//! RapidRAID: the paper's pipelined erasure-code family (Sections IV–V).
//!
//! An (n, k) RapidRAID code (k < n ≤ 2k) encodes a k-block object that is
//! already 2-way replicated over n nodes. Node i holds `locals(i)` object
//! blocks (1 for the symmetric n = 2k placement, 2 in the overlapped middle
//! when n < 2k), and the chain runs:
//!
//! ```text
//! x_{i,i+1} = x_{i-1,i} ⊕ Σ_j ψ_i[j]·o_{locals(i)[j]}      (eq. 3, forwarded)
//! c_i       = x_{i-1,i} ⊕ Σ_j ξ_i[j]·o_{locals(i)[j]}      (eq. 4, stored)
//! ```
//!
//! The code is non-systematic; reconstruction needs any k *linearly
//! independent* codeword blocks. For k ≥ n−3 the code is MDS (Conjecture 1,
//! verified exhaustively by the census for n ≤ 16); below that a few
//! *natural dependencies* exist — e.g. the (8,4) code's unique bad subset
//! {c1, c2, c5, c6} — quantified in [`crate::codes::census`].

use crate::codes::classical::decode_with_generator;
use crate::codes::DecodeError;
use crate::gf::{GfElem, Matrix, SliceOps};
use crate::util::SplitMix64;

/// Per-node encoding schedule: which object blocks the node stores and the
/// ψ/ξ coefficients it applies to each (paper eqs. (3)/(4)).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NodeSchedule<F: GfElem> {
    /// Object-block indices stored locally (len 1 or 2).
    pub locals: Vec<usize>,
    /// Forward (pipeline) coefficients ψ, one per local block.
    pub psi: Vec<F>,
    /// Codeword coefficients ξ, one per local block.
    pub xi: Vec<F>,
}

/// Replica placement (paper Section V): node i stores a block of the first
/// replica if `i < k` (block i) and a block of the second replica if
/// `i >= n - k` (block `i - (n - k)`).
pub fn placement(n: usize, k: usize) -> anyhow::Result<Vec<Vec<usize>>> {
    anyhow::ensure!(
        k < n && n <= 2 * k,
        "RapidRAID needs k < n <= 2k, got (n={n}, k={k})"
    );
    Ok((0..n)
        .map(|i| {
            let mut blocks = Vec::with_capacity(2);
            if i < k {
                blocks.push(i);
            }
            if i >= n - k {
                blocks.push(i - (n - k));
            }
            blocks
        })
        .collect())
}

/// An (n, k) RapidRAID pipelined erasure code with fixed coefficients.
#[derive(Clone)]
pub struct RapidRaidCode<F: GfElem> {
    n: usize,
    k: usize,
    schedule: Vec<NodeSchedule<F>>,
    generator: Matrix<F>,
}

impl<F: GfElem + SliceOps> RapidRaidCode<F> {
    /// Build a code with deterministic pseudo-random nonzero coefficients.
    ///
    /// For fields as large as GF(2^16) almost any draw avoids accidental
    /// dependencies [19]; for GF(2^8) prefer
    /// [`crate::codes::coeffs::search`], which retries seeds and keeps the
    /// draw with the fewest dependent k-subsets.
    pub fn with_seed(n: usize, k: usize, seed: u64) -> anyhow::Result<Self> {
        let place = placement(n, k)?;
        let mut rng = SplitMix64::new(seed);
        let mask = (1u64 << F::BITS) - 1;
        let mut draw = |count: usize| -> Vec<F> {
            (0..count)
                .map(|_| F::from_u32((rng.range(1, mask + 1)) as u32))
                .collect()
        };
        let schedule: Vec<NodeSchedule<F>> = place
            .into_iter()
            .map(|locals| {
                let r = locals.len();
                NodeSchedule {
                    locals,
                    psi: draw(r),
                    xi: draw(r),
                }
            })
            .collect();
        Self::from_schedule(n, k, schedule)
    }

    /// Build from an explicit schedule (used by the coefficient search).
    pub fn from_schedule(
        n: usize,
        k: usize,
        schedule: Vec<NodeSchedule<F>>,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(schedule.len() == n, "schedule must have n entries");
        let place = placement(n, k)?;
        for (i, (s, p)) in schedule.iter().zip(&place).enumerate() {
            anyhow::ensure!(s.locals == *p, "node {i} locals deviate from placement");
            anyhow::ensure!(
                s.psi.len() == s.locals.len() && s.xi.len() == s.locals.len(),
                "node {i} coefficient arity mismatch"
            );
        }
        let generator = generator_matrix(n, k, &schedule);
        Ok(Self {
            n,
            k,
            schedule,
            generator,
        })
    }

    /// Codeword length n.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Message length k.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Per-node schedules (the coordinator distributes these to the chain).
    pub fn schedule(&self) -> &[NodeSchedule<F>] {
        &self.schedule
    }

    /// The n×k generator matrix implied by the pipeline recurrences.
    pub fn generator(&self) -> &Matrix<F> {
        &self.generator
    }

    /// One pipeline stage over a single buffer (the hot-path primitive the
    /// coordinator runs per network buffer per node; the PJRT backend runs
    /// the same math inside the AOT Pallas `pipeline_step` kernel).
    ///
    /// `x_in` is the received partial combination (all-zero for node 0),
    /// `locals` the node's object-block buffers. Returns `(x_out, c_i)`.
    pub fn step(&self, node: usize, x_in: &[F], locals: &[&[F]]) -> (Vec<F>, Vec<F>) {
        let sched = &self.schedule[node];
        assert_eq!(locals.len(), sched.locals.len(), "node {node} arity");
        let mut x_out = x_in.to_vec();
        let mut c = x_in.to_vec();
        for (j, loc) in locals.iter().enumerate() {
            F::mul_slice_xor(sched.psi[j], loc, &mut x_out);
            F::mul_slice_xor(sched.xi[j], loc, &mut c);
        }
        (x_out, c)
    }

    /// Encode a whole object by literally running the chain (reference
    /// implementation of the coordinator's distributed pipeline).
    pub fn encode_chain(&self, object: &[Vec<F>]) -> Vec<Vec<F>> {
        assert_eq!(object.len(), self.k, "object must have k blocks");
        let len = object[0].len();
        assert!(object.iter().all(|b| b.len() == len), "ragged blocks");
        let mut x = vec![F::ZERO; len];
        let mut out = Vec::with_capacity(self.n);
        for i in 0..self.n {
            let locals: Vec<&[F]> = self.schedule[i]
                .locals
                .iter()
                .map(|&b| object[b].as_slice())
                .collect();
            let (x_next, c) = self.step(i, &x, &locals);
            out.push(c);
            x = x_next;
        }
        out
    }

    /// Encode via the generator matrix (cross-check path; must equal
    /// [`Self::encode_chain`] exactly).
    pub fn encode_matrix(&self, object: &[Vec<F>]) -> Vec<Vec<F>> {
        assert_eq!(object.len(), self.k);
        let len = object[0].len();
        let mut out = vec![vec![F::ZERO; len]; self.n];
        for (i, row_out) in out.iter_mut().enumerate() {
            for (j, block) in object.iter().enumerate() {
                F::mul_slice_xor(self.generator[(i, j)], block, row_out);
            }
        }
        out
    }

    /// Reconstruct the object from any k independent blocks `(index, data)`.
    pub fn decode(&self, have: &[(usize, Vec<F>)]) -> Result<Vec<Vec<F>>, DecodeError> {
        decode_with_generator(&self.generator, self.n, self.k, have)
    }

    /// Repair coefficients for regenerating the lost codeword block
    /// `c_lost` from surviving blocks: picks an independent k-subset S of
    /// `avail` (minus `lost` itself) and returns `(S, ψ)` with
    ///
    /// ```text
    /// c_lost = Σ_i ψ[i] · c_{S[i]},   ψ = g_lost · G_S⁻¹
    /// ```
    ///
    /// because the object is `G_S⁻¹ · c_S` and `c_lost = g_lost · object`.
    /// Both repair planners (star and pipelined) lower exactly this linear
    /// combination; they differ only in where the folds run.
    pub fn repair_coefficients(
        &self,
        lost: usize,
        avail: &[usize],
    ) -> anyhow::Result<(Vec<usize>, Vec<F>)> {
        crate::codes::repair_coefficients_from(&self.generator, self.n, self.k, lost, avail)
    }

    /// Greedy search for a decodable k-subset among the available block
    /// indices; returns `None` if every k-subset of `avail` is dependent.
    pub fn find_decodable_subset(&self, avail: &[usize]) -> Option<Vec<usize>> {
        crate::codes::decodable_subset(&self.generator, self.k, avail)
    }
}

impl<F: GfElem + SliceOps> crate::codes::CodeView<F> for RapidRaidCode<F> {
    fn n(&self) -> usize {
        self.n
    }

    fn k(&self) -> usize {
        self.k
    }

    fn generator(&self) -> &Matrix<F> {
        &self.generator
    }
}

/// Expand the pipeline recurrences into the explicit n×k generator matrix
/// (paper Section IV-B shows the (8,4) instance).
pub fn generator_matrix<F: GfElem>(
    n: usize,
    k: usize,
    schedule: &[NodeSchedule<F>],
) -> Matrix<F> {
    let mut g = Matrix::<F>::zero(n, k);
    // xrow = coefficients (over o_0..o_{k-1}) of the running combination x.
    let mut xrow = vec![F::ZERO; k];
    for (i, sched) in schedule.iter().enumerate().take(n) {
        // c_i = x_in ⊕ Σ ξ·o  — snapshot BEFORE folding ψ into xrow.
        for (j, &blk) in sched.locals.iter().enumerate() {
            let v = xrow[blk].add(sched.xi[j]);
            g[(i, blk)] = v;
        }
        for (blk, coeff) in (0..k).filter(|b| !sched.locals.contains(b)).map(|b| (b, xrow[b])) {
            g[(i, blk)] = coeff;
        }
        for (j, &blk) in sched.locals.iter().enumerate() {
            xrow[blk] = xrow[blk].add(sched.psi[j]);
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::subsets::Combinations;
    use crate::gf::{gauss, Gf256, Gf65536};
    use crate::util::prop::forall;

    fn random_object<F: GfElem>(seed: u64, k: usize, len: usize) -> Vec<Vec<F>> {
        let mut rng = SplitMix64::new(seed);
        let mask = (1u64 << F::BITS) - 1;
        (0..k)
            .map(|_| (0..len).map(|_| F::from_u32((rng.next_u64() & mask) as u32)).collect())
            .collect()
    }

    #[test]
    fn placement_matches_paper_examples() {
        // (8,4): two disjoint replicas (Fig. 2)
        assert_eq!(
            placement(8, 4).unwrap(),
            vec![vec![0], vec![1], vec![2], vec![3], vec![0], vec![1], vec![2], vec![3]]
        );
        // (6,4): overlapped middle (Section IV-C)
        assert_eq!(
            placement(6, 4).unwrap(),
            vec![vec![0], vec![1], vec![2, 0], vec![3, 1], vec![2], vec![3]]
        );
        assert!(placement(9, 4).is_err()); // n > 2k
        assert!(placement(4, 4).is_err()); // n == k
    }

    #[test]
    fn every_block_covered_twice() {
        // placement invariant: each object block appears on exactly 2 nodes
        for (n, k) in [(8, 4), (6, 4), (16, 11), (12, 8), (16, 15)] {
            let p = placement(n, k).unwrap();
            let mut count = vec![0usize; k];
            for node in &p {
                for &b in node {
                    count[b] += 1;
                }
            }
            assert!(count.iter().all(|&c| c == 2), "(n={n},k={k}): {count:?}");
        }
    }

    #[test]
    fn chain_equals_matrix_encode() {
        for (n, k) in [(8usize, 4usize), (6, 4), (16, 11), (12, 8)] {
            let code = RapidRaidCode::<Gf256>::with_seed(n, k, 42).unwrap();
            let obj = random_object::<Gf256>(1, k, 300);
            assert_eq!(code.encode_chain(&obj), code.encode_matrix(&obj), "(n={n},k={k})");
        }
    }

    #[test]
    fn chain_equals_matrix_encode_gf65536() {
        let code = RapidRaidCode::<Gf65536>::with_seed(16, 11, 9).unwrap();
        let obj = random_object::<Gf65536>(2, 11, 80);
        assert_eq!(code.encode_chain(&obj), code.encode_matrix(&obj));
    }

    #[test]
    fn decode_recovers_object() {
        let code = RapidRaidCode::<Gf256>::with_seed(8, 4, 7).unwrap();
        let obj = random_object::<Gf256>(3, 4, 200);
        let coded = code.encode_chain(&obj);
        let have: Vec<(usize, Vec<Gf256>)> =
            [2usize, 3, 6, 7].iter().map(|&i| (i, coded[i].clone())).collect();
        assert_eq!(code.decode(&have).unwrap(), obj);
    }

    #[test]
    fn paper_84_natural_dependency_is_rejected() {
        // {c1,c2,c5,c6} (1-based) == {0,1,4,5} is dependent for ANY coeffs.
        for seed in [1u64, 2, 3, 99] {
            let code = RapidRaidCode::<Gf65536>::with_seed(8, 4, seed).unwrap();
            let sub = code.generator.select_rows(&[0, 1, 4, 5]);
            assert!(gauss::rank(&sub) < 4, "seed {seed}: paper dependency missing");
        }
    }

    #[test]
    fn with_good_seed_only_natural_dependency_remains_84() {
        // Over GF(2^16) a random draw should leave exactly the one natural
        // dependency among all 70 subsets (paper Section IV-B).
        let code = RapidRaidCode::<Gf65536>::with_seed(8, 4, 12).unwrap();
        let dependent: Vec<Vec<usize>> = Combinations::new(8, 4)
            .filter(|s| gauss::rank(&code.generator.select_rows(s)) < 4)
            .collect();
        assert_eq!(dependent, vec![vec![0, 1, 4, 5]]);
    }

    #[test]
    fn decode_from_every_independent_subset_84() {
        let code = RapidRaidCode::<Gf65536>::with_seed(8, 4, 12).unwrap();
        let obj = random_object::<Gf65536>(4, 4, 64);
        let coded = code.encode_chain(&obj);
        let mut independent = 0;
        for sub in Combinations::new(8, 4) {
            let have: Vec<(usize, Vec<Gf65536>)> =
                sub.iter().map(|&i| (i, coded[i].clone())).collect();
            match code.decode(&have) {
                Ok(rec) => {
                    independent += 1;
                    assert_eq!(rec, obj, "subset {sub:?}");
                }
                Err(DecodeError::DependentSubset { .. }) => {
                    assert_eq!(sub, vec![0, 1, 4, 5]);
                }
                Err(e) => panic!("unexpected error {e:?}"),
            }
        }
        assert_eq!(independent, 69); // 70 subsets, 1 natural dependency
    }

    #[test]
    fn overlapped_placement_code_roundtrip_64() {
        let code = RapidRaidCode::<Gf65536>::with_seed(6, 4, 5).unwrap();
        let obj = random_object::<Gf65536>(5, 4, 96);
        let coded = code.encode_chain(&obj);
        let subset = code
            .find_decodable_subset(&[0, 1, 2, 3, 4, 5])
            .expect("some independent subset exists");
        let have: Vec<(usize, Vec<Gf65536>)> =
            subset.iter().map(|&i| (i, coded[i].clone())).collect();
        assert_eq!(code.decode(&have).unwrap(), obj);
    }

    #[test]
    fn find_decodable_subset_avoids_natural_dependency() {
        let code = RapidRaidCode::<Gf65536>::with_seed(8, 4, 12).unwrap();
        // availability = exactly the bad subset → None
        assert!(code.find_decodable_subset(&[0, 1, 4, 5]).is_none());
        // one more node available → decodable
        let s = code.find_decodable_subset(&[0, 1, 4, 5, 6]).unwrap();
        let sub = code.generator.select_rows(&s);
        assert_eq!(gauss::rank(&sub), 4);
    }

    #[test]
    fn repair_coefficients_reproduce_lost_block() {
        // ψ = g_lost · G_S⁻¹ must reproduce c_lost exactly, any loss, both
        // fields.
        fn check<F: GfElem + SliceOps>(n: usize, k: usize, seed: u64) {
            let code = RapidRaidCode::<F>::with_seed(n, k, seed).unwrap();
            let obj = random_object::<F>(seed ^ 0xABCD, k, 64);
            let coded = code.encode_chain(&obj);
            for lost in 0..n {
                let avail: Vec<usize> = (0..n).filter(|&p| p != lost).collect();
                let (subset, psi) = code.repair_coefficients(lost, &avail).unwrap();
                assert_eq!(subset.len(), k);
                assert!(!subset.contains(&lost));
                let mut rebuilt = vec![F::ZERO; 64];
                for (i, &p) in subset.iter().enumerate() {
                    F::mul_slice_xor(psi[i], &coded[p], &mut rebuilt);
                }
                assert_eq!(rebuilt, coded[lost], "(n={n},k={k}) lost {lost}");
            }
        }
        check::<Gf256>(8, 4, 7);
        check::<Gf65536>(8, 4, 12);
        check::<Gf65536>(6, 4, 5);
        check::<Gf256>(16, 11, 5);
    }

    #[test]
    fn repair_coefficients_reject_hopeless_availability() {
        let code = RapidRaidCode::<Gf65536>::with_seed(8, 4, 12).unwrap();
        // only the natural dependency survives → unrepairable
        assert!(code.repair_coefficients(7, &[0, 1, 4, 5]).is_err());
        // `lost` itself is filtered from the sources even when listed
        let (subset, _) = code.repair_coefficients(7, &[0, 1, 2, 3, 7]).unwrap();
        assert!(!subset.contains(&7));
        // out-of-range lost index
        assert!(code.repair_coefficients(9, &[0, 1, 2, 3]).is_err());
    }

    #[test]
    fn step_matches_python_semantics_first_node() {
        // node 0: x_in = 0 ⇒ x_out = ψ·o0, c = ξ·o0 (mirrors the pytest case)
        let code = RapidRaidCode::<Gf256>::with_seed(8, 4, 7).unwrap();
        let obj = random_object::<Gf256>(6, 4, 128);
        let zero = vec![Gf256::ZERO; 128];
        let (x_out, c) = code.step(0, &zero, &[&obj[0]]);
        let sched = &code.schedule()[0];
        let mut ex = vec![Gf256::ZERO; 128];
        Gf256::mul_slice_xor(sched.psi[0], &obj[0], &mut ex);
        assert_eq!(x_out, ex);
        let mut ec = vec![Gf256::ZERO; 128];
        Gf256::mul_slice_xor(sched.xi[0], &obj[0], &mut ec);
        assert_eq!(c, ec);
    }

    #[test]
    fn prop_roundtrip_random_params() {
        forall(15, 77, |rng| {
            let k = 3 + rng.below(6) as usize; // 3..8
            let extra = 1 + rng.below(k as u64) as usize; // 1..k
            let n = (k + extra).min(2 * k);
            let code = RapidRaidCode::<Gf65536>::with_seed(n, k, rng.next_u64()).unwrap();
            let obj = random_object::<Gf65536>(rng.next_u64(), k, 32);
            let coded = code.encode_chain(&obj);
            let avail: Vec<usize> = (0..n).collect();
            let sub = code
                .find_decodable_subset(&avail)
                .expect("full availability must be decodable");
            let have: Vec<(usize, Vec<Gf65536>)> =
                sub.iter().map(|&i| (i, coded[i].clone())).collect();
            assert_eq!(code.decode(&have).unwrap(), obj, "(n={n},k={k})");
        });
    }

    #[test]
    fn network_traffic_is_n_minus_1_blocks() {
        // structural property from Section III: the chain forwards exactly
        // n-1 temporal blocks (one per edge)
        let code = RapidRaidCode::<Gf256>::with_seed(16, 11, 1).unwrap();
        assert_eq!(code.schedule().len(), 16); // 15 edges between 16 stages
    }
}
