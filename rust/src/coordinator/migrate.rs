//! Replication→erasure-code migration: archive, verify, drop replicas.
//!
//! The end-to-end operation the paper motivates: once an object has cooled
//! down, run the pipelined encode, prove the coded form can reproduce the
//! object bit-exactly, then reclaim the replicated storage (2× object size
//! replicated → n/k ≈ 1.45× coded).
//!
//! Like every coordinator driver, migration is a thin *plan builder*: it
//! lowers the encode through [`PipelineJob::plan`] and executes it on the
//! shared [`PlanExecutor`]; verification and reclaim are control-plane.

use std::time::Duration;

use crate::backend::BackendHandle;
use crate::cluster::Cluster;
use crate::codes::rapidraid::RapidRaidCode;
use crate::gf::{GfElem, SliceOps};
use crate::storage::{BlockKey, ReplicaPlacement};

use super::decode::reconstruct;
use super::pipeline::{archive_pipeline, PipelineJob};

/// Outcome of one object migration.
#[derive(Clone, Debug)]
pub struct MigrationReport {
    /// Pipelined coding time.
    pub coding_time: Duration,
    /// Bytes held before migration (2 replicas).
    pub bytes_before: usize,
    /// Bytes held after migration (n coded blocks).
    pub bytes_after: usize,
    /// Replica blocks deleted.
    pub replicas_dropped: usize,
}

impl MigrationReport {
    /// Storage overhead after migration relative to object size (n/k).
    pub fn overhead_after(&self, object_bytes: usize) -> f64 {
        self.bytes_after as f64 / object_bytes as f64
    }
}

/// Archive `object` with the pipelined code, verify it decodes bit-exactly,
/// then delete every source replica. Fails (leaving replicas intact) if the
/// verification decode does not reproduce the ingested data.
pub fn migrate_object<F: GfElem + SliceOps>(
    cluster: &Cluster,
    code: &RapidRaidCode<F>,
    placement: &ReplicaPlacement,
    expected: &[Vec<u8>],
    backend: &BackendHandle,
    buf_bytes: usize,
) -> anyhow::Result<MigrationReport> {
    let block_bytes = expected
        .first()
        .map(|b| b.len())
        .ok_or_else(|| anyhow::anyhow!("empty object"))?;
    let bytes_before = 2 * placement.k * block_bytes;

    // 1. encode — archive_pipeline lowers the job onto the plan IR and
    // executes it on the shared engine (one entry point for all callers)
    let job = PipelineJob::from_code(code, placement, buf_bytes, block_bytes)?;
    let coding_time = archive_pipeline(cluster, backend, &job)?;

    // 2. verify BEFORE dropping anything
    let decoded = reconstruct(cluster, code, &placement.chain, placement.object, backend)?;
    anyhow::ensure!(
        decoded == expected,
        "verification decode mismatch for {} — replicas kept",
        placement.object
    );

    // 3. reclaim the replicas
    let mut dropped = 0;
    for (node, block_idx) in placement.replica_map() {
        if cluster
            .node(node)
            .delete(BlockKey::source(placement.object, block_idx))?
        {
            dropped += 1;
        }
    }
    Ok(MigrationReport {
        coding_time,
        bytes_before,
        bytes_after: placement.n * block_bytes,
        replicas_dropped: dropped,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::cluster::ClusterSpec;
    use crate::coordinator::ingest::ingest_object;
    use crate::gf::Gf65536;
    use crate::storage::ObjectId;
    use std::sync::Arc;

    #[test]
    fn full_migration_reclaims_replicas_and_stays_decodable() {
        let cluster = Cluster::start(ClusterSpec::test(8));
        let object = ObjectId(77);
        let placement = ReplicaPlacement::new(object, 4, (0..8).collect()).unwrap();
        let blocks = ingest_object(&cluster, &placement, 16 * 1024).unwrap();
        let code = RapidRaidCode::<Gf65536>::with_seed(8, 4, 12).unwrap();
        let backend: BackendHandle = Arc::new(NativeBackend::new());

        let report =
            migrate_object(&cluster, &code, &placement, &blocks, &backend, 4096).unwrap();
        assert_eq!(report.replicas_dropped, 8); // 4 blocks × 2 replicas
        assert_eq!(report.bytes_before, 2 * 4 * 16 * 1024);
        assert_eq!(report.bytes_after, 8 * 16 * 1024);
        // 2.0× replicated → (8/4)=2.0× coded here; with (16,11) it's 1.45×
        assert!((report.overhead_after(4 * 16 * 1024) - 2.0).abs() < 1e-9);

        // replicas gone
        for (node, b) in placement.replica_map() {
            assert!(cluster.node(node).peek(BlockKey::source(object, b)).unwrap().is_none());
        }
        // still decodable from coded blocks only
        let rec = reconstruct(&cluster, &code, &placement.chain, object, &backend).unwrap();
        assert_eq!(rec, blocks);
    }

    #[test]
    fn verification_failure_keeps_replicas() {
        let cluster = Cluster::start(ClusterSpec::test(8));
        let object = ObjectId(78);
        let placement = ReplicaPlacement::new(object, 4, (0..8).collect()).unwrap();
        let blocks = ingest_object(&cluster, &placement, 4 * 1024).unwrap();
        let code = RapidRaidCode::<Gf65536>::with_seed(8, 4, 12).unwrap();
        let backend: BackendHandle = Arc::new(NativeBackend::new());

        // corrupt the expectation so verification must fail
        let mut wrong = blocks.clone();
        wrong[0][0] ^= 0xFF;
        let err =
            migrate_object(&cluster, &code, &placement, &wrong, &backend, 1024).unwrap_err();
        assert!(err.to_string().contains("verification"), "{err}");
        // replicas still present
        for (node, b) in placement.replica_map() {
            assert!(cluster.node(node).peek(BlockKey::source(object, b)).unwrap().is_some());
        }
    }
}
