//! Analytic coding-time models — the paper's eq. (1) and eq. (2).
//!
//! Used by `examples/analytic_vs_measured.rs` to cross-check the simulator:
//! measured times should track these estimates closely when the network is
//! idle (the models ignore CPU time, per the paper's τ_block ≫ τ_encode
//! assumption).

use std::time::Duration;

/// Network parameters of the analytic model.
#[derive(Clone, Copy, Debug)]
pub struct NetModel {
    /// Per-NIC bandwidth, bytes/second.
    pub bytes_per_sec: f64,
    /// One-way link latency.
    pub latency: Duration,
}

impl NetModel {
    /// Time to move one full block through one NIC.
    pub fn block_time(&self, block_bytes: usize) -> Duration {
        Duration::from_secs_f64(block_bytes as f64 / self.bytes_per_sec) + self.latency
    }

    /// Time to move one network buffer node-to-node (τ_pipe).
    pub fn buffer_time(&self, buf_bytes: usize) -> Duration {
        Duration::from_secs_f64(buf_bytes as f64 / self.bytes_per_sec) + self.latency
    }
}

/// Eq. (1): `T_classical = τ_block · max{k, m−1}` — the coding node
/// serializes k downloads against m−1 uploads (one parity stays local).
pub fn t_classical(net: &NetModel, k: usize, m: usize, block_bytes: usize) -> Duration {
    let factor = k.max(m.saturating_sub(1)) as u32;
    net.block_time(block_bytes) * factor
}

/// Eq. (2): `T_pipe = τ_block + (n−1)·τ_pipe` — one block-time of streaming
/// plus the per-hop buffer delay down the chain.
pub fn t_pipe(net: &NetModel, n: usize, block_bytes: usize, buf_bytes: usize) -> Duration {
    net.block_time(block_bytes) + net.buffer_time(buf_bytes) * (n as u32 - 1)
}

/// Predicted speedup of pipelined over classical coding.
pub fn predicted_speedup(
    net: &NetModel,
    n: usize,
    k: usize,
    block_bytes: usize,
    buf_bytes: usize,
) -> f64 {
    t_classical(net, k, n - k, block_bytes).as_secs_f64()
        / t_pipe(net, n, block_bytes, buf_bytes).as_secs_f64()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn net() -> NetModel {
        NetModel {
            bytes_per_sec: 125e6, // 1 Gbps
            latency: Duration::from_micros(200),
        }
    }

    #[test]
    fn eq1_dominated_by_k_for_16_11() {
        // (16,11): max{11, 4} = 11 block-times
        let t = t_classical(&net(), 11, 5, 64 << 20);
        let one = net().block_time(64 << 20);
        assert!((t.as_secs_f64() / one.as_secs_f64() - 11.0).abs() < 1e-6);
    }

    #[test]
    fn eq2_near_single_block_time() {
        let t = t_pipe(&net(), 16, 64 << 20, 65536);
        let one = net().block_time(64 << 20);
        // 15 buffer hops of 64 KiB are negligible next to a 64 MiB block
        assert!(t < one * 2, "{t:?} vs {one:?}");
        assert!(t >= one);
    }

    #[test]
    fn paper_headline_speedup_shape() {
        // The paper reports ~90% single-object coding-time reduction for
        // (16,11): speedup ≈ 10×. The model must predict that regime.
        let s = predicted_speedup(&net(), 16, 11, 64 << 20, 65536);
        assert!(s > 8.0, "predicted speedup {s}");
        assert!(s < 12.0, "predicted speedup {s}");
    }

    #[test]
    fn classical_beats_pipe_only_in_latency_pathologies() {
        // huge latency, tiny block: the (n-1) hop latencies can dominate
        let slow = NetModel {
            bytes_per_sec: 125e6,
            latency: Duration::from_millis(100),
        };
        let tp = t_pipe(&slow, 16, 65536, 65536);
        let tc = t_classical(&slow, 11, 5, 65536);
        assert!(tp > tc, "latency-dominated regime should favor classical");
    }
}
