//! Object reconstruction from coded blocks — including **degraded reads**.
//!
//! RapidRAID is non-systematic, so every read of an archived object decodes:
//! pick k linearly independent *surviving* blocks, invert the corresponding
//! generator rows (Gauss over the field), and apply the inverse — on the
//! selected backend, i.e. through the AOT `gf_gemm` artifact when PJRT is
//! active. [`survey_coded`] treats crashed chain nodes
//! ([`Cluster::fail_node`]) exactly like missing blocks, so a read keeps
//! working through up to n−k node failures as long as an independent
//! k-subset survives.

use crate::backend::{BackendHandle, Width};
use crate::cluster::Cluster;
use crate::codes::CodeView;
use crate::gf::{gauss, GfElem, SliceOps};
use crate::resources::GfWork;
use crate::storage::{BlockKey, ObjectId};

/// Which coded blocks of `object` survive on `chain` (`chain[i]` holds
/// c_i), and their common size. Crashed nodes and peek errors (a node
/// failing mid-survey) count as "block unavailable", never as a hard
/// error — the degraded-read and repair paths both build on this.
pub fn survey_coded(
    cluster: &Cluster,
    chain: &[usize],
    object: ObjectId,
) -> (Vec<usize>, usize) {
    let mut avail = Vec::new();
    let mut block_bytes = 0usize;
    for (pos, &node) in chain.iter().enumerate() {
        if cluster.is_failed(node) {
            continue;
        }
        if let Ok(Some(b)) = cluster.node(node).peek(BlockKey::coded(object, pos)) {
            avail.push(pos);
            block_bytes = b.len();
        }
    }
    (avail, block_bytes)
}

/// Reconstruct `object` from the coded blocks surviving on `chain`
/// (chain[i] holds c_i) — a degraded read when nodes have crashed or
/// blocks are missing. Generic over [`CodeView`], so chain codes and
/// topology codes decode through the same path. Returns the k source
/// blocks.
pub fn reconstruct<F: GfElem + SliceOps, C: CodeView<F>>(
    cluster: &Cluster,
    code: &C,
    chain: &[usize],
    object: ObjectId,
    backend: &BackendHandle,
) -> anyhow::Result<Vec<Vec<u8>>> {
    anyhow::ensure!(chain.len() == code.n(), "chain/code mismatch");
    let width = Width::for_bits(F::BITS)?;

    // 1. which codeword blocks survived?
    let (avail, _) = survey_coded(cluster, chain, object);

    // 2. pick an independent k-subset
    let subset = code
        .find_decodable_subset(&avail)
        .ok_or_else(|| anyhow::anyhow!("object {object} unrecoverable: available {avail:?}"))?;

    // 3. invert the generator rows. The k×k Gauss-Jordan runs on the
    // first selected survivor (the node anchoring the read); its CpuMeter
    // prices the inversion in virtual time.
    cluster
        .node(chain[subset[0]])
        .cpu
        .charge(&GfWork::invert(code.k()));
    let sub = code.generator().select_rows(&subset);
    let inv = gauss::invert(&sub)
        .ok_or_else(|| anyhow::anyhow!("subset {subset:?} unexpectedly singular"))?;
    let inv_u32: Vec<Vec<u32>> = (0..inv.rows())
        .map(|i| inv.row(i).iter().map(|c| c.to_u32()).collect())
        .collect();

    // 4. gather the blocks and apply the inverse on the backend
    let mut blocks: Vec<std::sync::Arc<Vec<u8>>> = Vec::with_capacity(subset.len());
    for &pos in &subset {
        let b = cluster
            .node(chain[pos])
            .peek(BlockKey::coded(object, pos))?
            .ok_or_else(|| anyhow::anyhow!("block {pos} vanished"))?;
        blocks.push(b);
    }
    let refs: Vec<&[u8]> = blocks.iter().map(|b| b.as_slice()).collect();
    backend.gemm(width, &inv_u32, &refs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::cluster::ClusterSpec;
    use crate::codes::rapidraid::RapidRaidCode;
    use crate::coordinator::ingest::ingest_object;
    use crate::coordinator::pipeline::{archive_pipeline, PipelineJob};
    use crate::gf::Gf256;
    use crate::storage::ReplicaPlacement;
    use std::sync::Arc;

    #[test]
    fn decode_after_pipeline_archival_with_failures() {
        let cluster = Cluster::start(ClusterSpec::test(8));
        let object = ObjectId(42);
        let placement = ReplicaPlacement::new(object, 4, (0..8).collect()).unwrap();
        let blocks = ingest_object(&cluster, &placement, 8 * 1024).unwrap();

        let code = RapidRaidCode::<Gf256>::with_seed(8, 4, 7).unwrap();
        let backend: BackendHandle = Arc::new(NativeBackend::new());
        let job = PipelineJob::from_code(&code, &placement, 2048, 8 * 1024).unwrap();
        archive_pipeline(&cluster, &backend, &job).unwrap();

        // lose 4 of the 8 coded blocks (m = 4 tolerated if subset independent)
        for pos in [1usize, 3, 4, 6] {
            cluster.node(pos).delete(BlockKey::coded(object, pos)).unwrap();
        }
        let rec = reconstruct(&cluster, &code, &placement.chain, object, &backend).unwrap();
        assert_eq!(rec, blocks);
    }

    #[test]
    fn unrecoverable_when_too_few_blocks() {
        let cluster = Cluster::start(ClusterSpec::test(8));
        let object = ObjectId(43);
        let placement = ReplicaPlacement::new(object, 4, (0..8).collect()).unwrap();
        ingest_object(&cluster, &placement, 4 * 1024).unwrap();
        let code = RapidRaidCode::<Gf256>::with_seed(8, 4, 7).unwrap();
        let backend: BackendHandle = Arc::new(NativeBackend::new());
        let job = PipelineJob::from_code(&code, &placement, 1024, 4 * 1024).unwrap();
        archive_pipeline(&cluster, &backend, &job).unwrap();
        for pos in [0usize, 1, 2, 3, 4] {
            cluster.node(pos).delete(BlockKey::coded(object, pos)).unwrap();
        }
        let err = reconstruct(&cluster, &code, &placement.chain, object, &backend).unwrap_err();
        assert!(err.to_string().contains("unrecoverable"), "{err}");
    }
}
