//! RapidRAID pipelined archival (Sections IV–V, Fig. 2).
//!
//! The n nodes that already hold the two replicas form a chain; every
//! network buffer flows head→tail once while each node folds its local
//! block(s) and stores its codeword block — eq. (2):
//! `T_pipe ≈ τ_block + (n−1)·τ_pipe`.
//!
//! This module is a *plan builder*: [`PipelineJob::plan`] lowers the
//! coefficient schedule onto the [`ArchivalPlan`] IR as a linear chain of
//! [`StepKind::Fold`] steps, and [`archive_pipeline`] hands the plan to
//! the shared [`PlanExecutor`]. No node-command plumbing lives here.

use std::time::Duration;

use crate::backend::{BackendHandle, Width};
use crate::cluster::Cluster;
use crate::codes::rapidraid::RapidRaidCode;
use crate::gf::{GfElem, SliceOps};
use crate::storage::{BlockKey, ObjectId, ReplicaPlacement};

use super::engine::PlanExecutor;
use super::plan::{ArchivalPlan, StepKind};

/// One pipelined archival job (field-erased: coefficients as u32).
#[derive(Clone, Debug)]
pub struct PipelineJob {
    /// Object to archive.
    pub object: ObjectId,
    /// GF width.
    pub width: Width,
    /// Message length k.
    pub k: usize,
    /// Per chain position: (local source-block indices, ψ, ξ).
    pub schedule: Vec<(Vec<usize>, Vec<u32>, Vec<u32>)>,
    /// Cluster node at each chain position (len n).
    pub chain: Vec<usize>,
    /// Network buffer size.
    pub buf_bytes: usize,
    /// Source block size.
    pub block_bytes: usize,
}

impl PipelineJob {
    /// Build a job from a code instance and a placement binding.
    pub fn from_code<F: GfElem + SliceOps>(
        code: &RapidRaidCode<F>,
        placement: &ReplicaPlacement,
        buf_bytes: usize,
        block_bytes: usize,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(placement.n == code.n() && placement.k == code.k(), "code/placement mismatch");
        let width = Width::for_bits(F::BITS)?;
        let schedule = code
            .schedule()
            .iter()
            .map(|s| {
                (
                    s.locals.clone(),
                    s.psi.iter().map(|c| c.to_u32()).collect(),
                    s.xi.iter().map(|c| c.to_u32()).collect(),
                )
            })
            .collect();
        Ok(Self {
            object: placement.object,
            width,
            k: code.k(),
            schedule,
            chain: placement.chain.clone(),
            buf_bytes,
            block_bytes,
        })
    }

    /// Code length n.
    pub fn n(&self) -> usize {
        self.chain.len()
    }

    /// Lower the job onto the plan IR: a head→tail chain of fold steps,
    /// each storing its codeword block c_i in place.
    pub fn plan(&self) -> anyhow::Result<ArchivalPlan> {
        let n = self.n();
        anyhow::ensure!(self.schedule.len() == n, "schedule/chain length mismatch");
        let mut plan = ArchivalPlan::new(self.object, self.width, self.buf_bytes, self.block_bytes);
        let mut prev = None;
        for (pos, (locals, psi, xi)) in self.schedule.iter().enumerate() {
            let id = plan.add_step(
                self.chain[pos],
                StepKind::Fold {
                    locals: locals
                        .iter()
                        .map(|&b| BlockKey::source(self.object, b))
                        .collect(),
                    psi: psi.clone(),
                    xi: xi.clone(),
                    store: Some(BlockKey::coded(self.object, pos)),
                },
            );
            if let Some(p) = prev {
                plan.connect(p, 0, id, 0);
            }
            prev = Some(id);
        }
        Ok(plan)
    }
}

/// Execute one pipelined archival through the shared engine; returns the
/// coding time (dispatch → every codeword block durable on its node).
pub fn archive_pipeline(
    cluster: &Cluster,
    backend: &BackendHandle,
    job: &PipelineJob,
) -> anyhow::Result<Duration> {
    PlanExecutor::new(cluster, backend.clone()).run(&job.plan()?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::cluster::ClusterSpec;
    use crate::coordinator::ingest::ingest_object;
    use crate::gf::Gf256;
    use std::sync::Arc;

    #[test]
    fn plan_is_a_linear_chain_of_folds() {
        let placement = ReplicaPlacement::new(ObjectId(6), 4, (0..8).collect()).unwrap();
        let code = RapidRaidCode::<Gf256>::with_seed(8, 4, 7).unwrap();
        let job = PipelineJob::from_code(&code, &placement, 4096, 32 * 1024).unwrap();
        let plan = job.plan().unwrap();
        plan.validate().unwrap();
        assert_eq!(plan.len(), 8);
        assert_eq!(plan.edges.len(), 7); // n-1 hops
        assert!(plan
            .steps
            .iter()
            .all(|s| matches!(s.kind, StepKind::Fold { .. })));
    }

    #[test]
    fn pipeline_archival_equals_library_encode() {
        let cluster = Cluster::start(ClusterSpec::test(8));
        let object = ObjectId(7);
        let placement = ReplicaPlacement::new(object, 4, (0..8).collect()).unwrap();
        let blocks = ingest_object(&cluster, &placement, 32 * 1024).unwrap();

        let code = RapidRaidCode::<Gf256>::with_seed(8, 4, 7).unwrap();
        let backend: BackendHandle = Arc::new(NativeBackend::new());
        let job = PipelineJob::from_code(&code, &placement, 4096, 32 * 1024).unwrap();
        let dt = archive_pipeline(&cluster, &backend, &job).unwrap();
        assert!(dt > Duration::ZERO);

        let obj_gf: Vec<Vec<Gf256>> = blocks
            .iter()
            .map(|b| b.iter().map(|&x| Gf256(x)).collect())
            .collect();
        let expect = code.encode_chain(&obj_gf);
        for i in 0..8 {
            let got = cluster
                .node(i)
                .peek(BlockKey::coded(object, i))
                .unwrap()
                .unwrap_or_else(|| panic!("codeword block {i} missing"));
            let expect_bytes: Vec<u8> = expect[i].iter().map(|g| g.0).collect();
            assert_eq!(*got, expect_bytes, "codeword block {i}");
        }
    }

    #[test]
    fn overlapped_placement_pipeline_64() {
        let cluster = Cluster::start(ClusterSpec::test(6));
        let object = ObjectId(8);
        let placement = ReplicaPlacement::new(object, 4, (0..6).collect()).unwrap();
        let blocks = ingest_object(&cluster, &placement, 16 * 1024).unwrap();

        let code = RapidRaidCode::<Gf256>::with_seed(6, 4, 3).unwrap();
        let backend: BackendHandle = Arc::new(NativeBackend::new());
        let job = PipelineJob::from_code(&code, &placement, 4096, 16 * 1024).unwrap();
        archive_pipeline(&cluster, &backend, &job).unwrap();

        let obj_gf: Vec<Vec<Gf256>> = blocks
            .iter()
            .map(|b| b.iter().map(|&x| Gf256(x)).collect())
            .collect();
        let expect = code.encode_chain(&obj_gf);
        for i in 0..6 {
            let got = cluster.node(i).peek(BlockKey::coded(object, i)).unwrap().unwrap();
            let expect_bytes: Vec<u8> = expect[i].iter().map(|g| g.0).collect();
            assert_eq!(*got, expect_bytes, "codeword block {i}");
        }
    }

    #[test]
    fn pipeline_time_near_one_block_time() {
        // The whole point of the paper: pipelined coding ≈ 1 block-time.
        // 100 MB/s NIC, 1 MB block → τ_block = 10 ms; allow generous slack
        // for per-buffer hops but require way below the classical 4×.
        let mut spec = ClusterSpec::test(8);
        spec.bytes_per_sec = 100e6;
        let cluster = Cluster::start(spec);
        let object = ObjectId(9);
        let placement = ReplicaPlacement::new(object, 4, (0..8).collect()).unwrap();
        ingest_object(&cluster, &placement, 1 << 20).unwrap();
        let code = RapidRaidCode::<Gf256>::with_seed(8, 4, 7).unwrap();
        let backend: BackendHandle = Arc::new(NativeBackend::new());
        let job = PipelineJob::from_code(&code, &placement, 65536, 1 << 20).unwrap();
        let dt = archive_pipeline(&cluster, &backend, &job).unwrap();
        assert!(dt >= Duration::from_millis(9), "faster than τ_block: {dt:?}");
        // τ_block = 10 ms; classical would be ≥ 40 ms (4 serialized block
        // transfers). Generous headroom for 1-CPU scheduling noise.
        assert!(dt <= Duration::from_millis(35), "not pipelined: {dt:?}");
    }
}
