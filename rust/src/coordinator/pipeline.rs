//! RapidRAID pipelined archival (Sections IV–V, Fig. 2) — over any
//! pipeline [`Topology`].
//!
//! The n nodes that already hold the two replicas form a pipeline; every
//! network buffer flows root→leaves once while each node folds its local
//! block(s) and stores its codeword block. The paper's chain gives
//! eq. (2) `T_pipe ≈ τ_block + (n−1)·τ_pipe`; tree/hybrid shapes trade
//! interior fan-out uplink for a logarithmic hop tail and straggler
//! isolation (a slow node paces only its subtree).
//!
//! This module is a *thin builder*: [`PipelineJob::plan`] expands the
//! job's topology to a shape and delegates the whole lowering to
//! [`crate::coordinator::topology::lower_encode`]; [`archive_pipeline`]
//! hands the plan to the shared [`PlanExecutor`]. No wiring lives here.
//! Non-chain jobs decode through the matching
//! [`crate::codes::TopologyCode`] (same ψ/ξ schedule, shape-composed
//! generator).

use std::time::Duration;

use crate::backend::{BackendHandle, Width};
use crate::cluster::Cluster;
use crate::codes::rapidraid::RapidRaidCode;
use crate::gf::{GfElem, SliceOps};
use crate::storage::{ObjectId, ReplicaPlacement};

use super::engine::PlanExecutor;
use super::plan::ArchivalPlan;
use super::topology::{lower_encode, Topology};

/// One pipelined archival job (field-erased: coefficients as u32).
#[derive(Clone, Debug)]
pub struct PipelineJob {
    /// Object to archive.
    pub object: ObjectId,
    /// GF width.
    pub width: Width,
    /// Message length k.
    pub k: usize,
    /// Per chain position: (local source-block indices, ψ, ξ).
    pub schedule: Vec<(Vec<usize>, Vec<u32>, Vec<u32>)>,
    /// Cluster node at each pipeline position (len n).
    pub chain: Vec<usize>,
    /// Network buffer size.
    pub buf_bytes: usize,
    /// Source block size.
    pub block_bytes: usize,
    /// Pipeline shape the position binding is lowered through.
    pub topology: Topology,
}

impl PipelineJob {
    /// Build a chain-shaped job from a code instance and a placement
    /// binding (the paper's layout).
    pub fn from_code<F: GfElem + SliceOps>(
        code: &RapidRaidCode<F>,
        placement: &ReplicaPlacement,
        buf_bytes: usize,
        block_bytes: usize,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(placement.n == code.n() && placement.k == code.k(), "code/placement mismatch");
        let width = Width::for_bits(F::BITS)?;
        let schedule = code
            .schedule()
            .iter()
            .map(|s| {
                (
                    s.locals.clone(),
                    s.psi.iter().map(|c| c.to_u32()).collect(),
                    s.xi.iter().map(|c| c.to_u32()).collect(),
                )
            })
            .collect();
        Ok(Self {
            object: placement.object,
            width,
            k: code.k(),
            schedule,
            chain: placement.chain.clone(),
            buf_bytes,
            block_bytes,
            topology: Topology::Chain,
        })
    }

    /// Build a job lowered through an arbitrary pipeline `topology`.
    pub fn from_code_with_topology<F: GfElem + SliceOps>(
        code: &RapidRaidCode<F>,
        placement: &ReplicaPlacement,
        topology: Topology,
        buf_bytes: usize,
        block_bytes: usize,
    ) -> anyhow::Result<Self> {
        topology.validate()?;
        let mut job = Self::from_code(code, placement, buf_bytes, block_bytes)?;
        job.topology = topology;
        Ok(job)
    }

    /// Code length n.
    pub fn n(&self) -> usize {
        self.chain.len()
    }

    /// Lower the job onto the plan IR through its topology: one fold step
    /// per position, each storing its codeword block c_i in place and
    /// streaming the running ψ-combination to every child position.
    pub fn plan(&self) -> anyhow::Result<ArchivalPlan> {
        let n = self.n();
        anyhow::ensure!(self.schedule.len() == n, "schedule/chain length mismatch");
        let shape = self.topology.shape(n)?;
        lower_encode(
            self.object,
            self.width,
            &self.schedule,
            &self.chain,
            &shape,
            self.buf_bytes,
            self.block_bytes,
        )
    }
}

/// Execute one pipelined archival through the shared engine; returns the
/// coding time (dispatch → every codeword block durable on its node).
pub fn archive_pipeline(
    cluster: &Cluster,
    backend: &BackendHandle,
    job: &PipelineJob,
) -> anyhow::Result<Duration> {
    PlanExecutor::new(cluster, backend.clone()).run(&job.plan()?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::cluster::ClusterSpec;
    use crate::codes::TopologyCode;
    use crate::coordinator::ingest::ingest_object;
    use crate::coordinator::plan::StepKind;
    use crate::gf::Gf256;
    use crate::storage::BlockKey;
    use std::sync::Arc;

    #[test]
    fn plan_is_a_linear_chain_of_folds() {
        let placement = ReplicaPlacement::new(ObjectId(6), 4, (0..8).collect()).unwrap();
        let code = RapidRaidCode::<Gf256>::with_seed(8, 4, 7).unwrap();
        let job = PipelineJob::from_code(&code, &placement, 4096, 32 * 1024).unwrap();
        let plan = job.plan().unwrap();
        plan.validate().unwrap();
        assert_eq!(plan.len(), 8);
        assert_eq!(plan.edges.len(), 7); // n-1 hops
        assert!(plan
            .steps
            .iter()
            .all(|s| matches!(s.kind, StepKind::Fold { .. })));
    }

    #[test]
    fn pipeline_archival_equals_library_encode() {
        let cluster = Cluster::start(ClusterSpec::test(8));
        let object = ObjectId(7);
        let placement = ReplicaPlacement::new(object, 4, (0..8).collect()).unwrap();
        let blocks = ingest_object(&cluster, &placement, 32 * 1024).unwrap();

        let code = RapidRaidCode::<Gf256>::with_seed(8, 4, 7).unwrap();
        let backend: BackendHandle = Arc::new(NativeBackend::new());
        let job = PipelineJob::from_code(&code, &placement, 4096, 32 * 1024).unwrap();
        let dt = archive_pipeline(&cluster, &backend, &job).unwrap();
        assert!(dt > Duration::ZERO);

        let obj_gf: Vec<Vec<Gf256>> = blocks
            .iter()
            .map(|b| b.iter().map(|&x| Gf256(x)).collect())
            .collect();
        let expect = code.encode_chain(&obj_gf);
        for i in 0..8 {
            let got = cluster
                .node(i)
                .peek(BlockKey::coded(object, i))
                .unwrap()
                .unwrap_or_else(|| panic!("codeword block {i} missing"));
            let expect_bytes: Vec<u8> = expect[i].iter().map(|g| g.0).collect();
            assert_eq!(*got, expect_bytes, "codeword block {i}");
        }
    }

    #[test]
    fn overlapped_placement_pipeline_64() {
        let cluster = Cluster::start(ClusterSpec::test(6));
        let object = ObjectId(8);
        let placement = ReplicaPlacement::new(object, 4, (0..6).collect()).unwrap();
        let blocks = ingest_object(&cluster, &placement, 16 * 1024).unwrap();

        let code = RapidRaidCode::<Gf256>::with_seed(6, 4, 3).unwrap();
        let backend: BackendHandle = Arc::new(NativeBackend::new());
        let job = PipelineJob::from_code(&code, &placement, 4096, 16 * 1024).unwrap();
        archive_pipeline(&cluster, &backend, &job).unwrap();

        let obj_gf: Vec<Vec<Gf256>> = blocks
            .iter()
            .map(|b| b.iter().map(|&x| Gf256(x)).collect())
            .collect();
        let expect = code.encode_chain(&obj_gf);
        for i in 0..6 {
            let got = cluster.node(i).peek(BlockKey::coded(object, i)).unwrap().unwrap();
            let expect_bytes: Vec<u8> = expect[i].iter().map(|g| g.0).collect();
            assert_eq!(*got, expect_bytes, "codeword block {i}");
        }
    }

    #[test]
    fn tree_archival_equals_topology_code_encode() {
        // Tree-shaped pipelined archival must land byte-identically on the
        // topology code's atomic (generator) encode — the distributed twin
        // of codes::topology's reference checks.
        let cluster = Cluster::start(ClusterSpec::test(8));
        let object = ObjectId(17);
        let placement = ReplicaPlacement::new(object, 4, (0..8).collect()).unwrap();
        let blocks = ingest_object(&cluster, &placement, 16 * 1024).unwrap();

        let code = RapidRaidCode::<Gf256>::with_seed(8, 4, 7).unwrap();
        let topo = Topology::Tree { fanout: 2 };
        let backend: BackendHandle = Arc::new(NativeBackend::new());
        let job =
            PipelineJob::from_code_with_topology(&code, &placement, topo, 4096, 16 * 1024)
                .unwrap();
        let plan = job.plan().unwrap();
        plan.validate().unwrap();
        assert_eq!(plan.len(), 8);
        assert_eq!(plan.edges.len(), 7); // trees keep n-1 streams
        assert!(plan.steps.iter().all(|s| matches!(s.kind, StepKind::Fold { .. })));
        archive_pipeline(&cluster, &backend, &job).unwrap();

        let tcode = TopologyCode::new(code, topo.shape(8).unwrap()).unwrap();
        let obj_gf: Vec<Vec<Gf256>> = blocks
            .iter()
            .map(|b| b.iter().map(|&x| Gf256(x)).collect())
            .collect();
        let expect = tcode.encode_matrix(&obj_gf);
        for i in 0..8 {
            let got = cluster.node(i).peek(BlockKey::coded(object, i)).unwrap().unwrap();
            let expect_bytes: Vec<u8> = expect[i].iter().map(|g| g.0).collect();
            assert_eq!(*got, expect_bytes, "codeword block {i}");
        }
    }

    #[test]
    fn pipeline_time_near_one_block_time() {
        // The whole point of the paper: pipelined coding ≈ 1 block-time.
        // 100 MB/s NIC, 1 MB block → τ_block = 10 ms; allow generous slack
        // for per-buffer hops but require way below the classical 4×.
        let mut spec = ClusterSpec::test(8);
        spec.bytes_per_sec = 100e6;
        let cluster = Cluster::start(spec);
        let object = ObjectId(9);
        let placement = ReplicaPlacement::new(object, 4, (0..8).collect()).unwrap();
        ingest_object(&cluster, &placement, 1 << 20).unwrap();
        let code = RapidRaidCode::<Gf256>::with_seed(8, 4, 7).unwrap();
        let backend: BackendHandle = Arc::new(NativeBackend::new());
        let job = PipelineJob::from_code(&code, &placement, 65536, 1 << 20).unwrap();
        let dt = archive_pipeline(&cluster, &backend, &job).unwrap();
        assert!(dt >= Duration::from_millis(9), "faster than τ_block: {dt:?}");
        // τ_block = 10 ms; classical would be ≥ 40 ms (4 serialized block
        // transfers). Generous headroom for 1-CPU scheduling noise.
        assert!(dt <= Duration::from_millis(35), "not pipelined: {dt:?}");
    }
}
