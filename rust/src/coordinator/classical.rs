//! Classical (atomic) archival — the paper's baseline (Section III, Fig. 1).
//!
//! One coding node downloads the k source blocks in parallel streams,
//! applies the parity sub-matrix buffer-by-buffer as data arrives
//! (streamlined), keeps one parity block locally (data locality) and
//! uploads the remaining m−1 — hence eq. (1):
//! `T_classical ≈ τ_block · max{k, m−1}` — the coding node's NIC serializes
//! everything.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::backend::{BackendHandle, Width};
use crate::cluster::node::{Command, SourceStream};
use crate::cluster::{Cluster, NodeId};
use crate::storage::{BlockKey, ObjectId};

/// One classical archival job.
#[derive(Clone, Debug)]
pub struct ClassicalJob {
    /// Object to archive.
    pub object: ObjectId,
    /// GF width.
    pub width: Width,
    /// Parity rows G′ (m×k) as u32 coefficients.
    pub parity_rows: Vec<Vec<u32>>,
    /// Node holding source block j (len k). Blocks located on the coding
    /// node itself are read locally (no transfer).
    pub source_nodes: Vec<NodeId>,
    /// The node that performs the encoding.
    pub coding_node: NodeId,
    /// Destination node of each parity block (len m). An entry equal to
    /// `coding_node` keeps that parity local (saves one upload).
    pub parity_nodes: Vec<NodeId>,
    /// Network buffer size.
    pub buf_bytes: usize,
    /// Source block size.
    pub block_bytes: usize,
}

impl ClassicalJob {
    /// Message length k.
    pub fn k(&self) -> usize {
        self.source_nodes.len()
    }

    /// Parity count m.
    pub fn m(&self) -> usize {
        self.parity_nodes.len()
    }
}

/// Execute one classical archival; returns the coding time (dispatch →
/// all parity blocks durable on their destination nodes).
pub fn archive_classical(
    cluster: &Cluster,
    backend: &BackendHandle,
    job: &ClassicalJob,
) -> anyhow::Result<Duration> {
    let k = job.k();
    let m = job.m();
    anyhow::ensure!(
        job.parity_rows.len() == m && job.parity_rows.iter().all(|r| r.len() == k),
        "parity matrix must be m x k"
    );
    let start = Instant::now();
    let mut waits: Vec<mpsc::Receiver<anyhow::Result<()>>> = Vec::new();

    // 1. source streams into the coding node
    let mut sources: Vec<SourceStream> = Vec::with_capacity(k);
    for (j, &src) in job.source_nodes.iter().enumerate() {
        let key = BlockKey::source(job.object, j);
        if src == job.coding_node {
            sources.push(SourceStream::Local(key));
        } else {
            let (tx, rx) = cluster.connect(src, job.coding_node);
            let (done, wait) = mpsc::channel();
            cluster.node(src).send(Command::Upload {
                key,
                tx,
                buf_bytes: job.buf_bytes,
                done,
            })?;
            waits.push(wait);
            sources.push(SourceStream::Remote(rx));
        }
    }

    // 2. parity destinations
    let mut dests = Vec::with_capacity(m);
    let mut local_parity_key = None;
    for (i, &dst) in job.parity_nodes.iter().enumerate() {
        let key = BlockKey::coded(job.object, k + i);
        if dst == job.coding_node {
            anyhow::ensure!(
                local_parity_key.is_none(),
                "at most one parity block can stay on the coding node"
            );
            local_parity_key = Some(key);
            dests.push(None);
        } else {
            let (tx, rx) = cluster.connect(job.coding_node, dst);
            let (done, wait) = mpsc::channel();
            cluster.node(dst).send(Command::Receive { key, rx, done })?;
            waits.push(wait);
            dests.push(Some(tx));
        }
    }

    // 3. the encoding itself
    let (done, wait) = mpsc::channel();
    cluster.node(job.coding_node).send(Command::ClassicalEncode {
        width: job.width,
        sources,
        parity_rows: job.parity_rows.clone(),
        dests,
        local_parity_key,
        buf_bytes: job.buf_bytes,
        block_bytes: job.block_bytes,
        backend: backend.clone(),
        done,
    })?;
    waits.push(wait);

    for w in waits {
        w.recv()??;
    }
    Ok(start.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::cluster::ClusterSpec;
    use crate::codes::ClassicalCode;
    use crate::coordinator::ingest::{ingest_object, object_bytes};
    use crate::gf::{Gf256, GfElem};
    use crate::storage::ReplicaPlacement;
    use std::sync::Arc;

    fn parity_rows_u32(code: &ClassicalCode<Gf256>) -> Vec<Vec<u32>> {
        let p = code.parity_matrix();
        (0..p.rows())
            .map(|i| p.row(i).iter().map(|c| c.to_u32()).collect())
            .collect()
    }

    #[test]
    fn classical_archival_produces_correct_parity() {
        let cluster = Cluster::start(ClusterSpec::test(8));
        let object = ObjectId(1);
        let placement = ReplicaPlacement::new(object, 4, (0..8).collect()).unwrap();
        let blocks = ingest_object(&cluster, &placement, 64 * 1024).unwrap();

        let code = ClassicalCode::<Gf256>::new(8, 4).unwrap();
        let backend: BackendHandle = Arc::new(NativeBackend::new());
        let job = ClassicalJob {
            object,
            width: Width::W8,
            parity_rows: parity_rows_u32(&code),
            source_nodes: vec![0, 1, 2, 3],
            coding_node: 4,
            parity_nodes: vec![4, 5, 6, 7],
            buf_bytes: 8192,
            block_bytes: 64 * 1024,
        };
        let dt = archive_classical(&cluster, &backend, &job).unwrap();
        assert!(dt > Duration::ZERO);

        // verify parity against the library encode
        let obj_gf: Vec<Vec<Gf256>> = blocks
            .iter()
            .map(|b| b.iter().map(|&x| Gf256(x)).collect())
            .collect();
        let expect = code.encode_parity(&obj_gf);
        for i in 0..4 {
            let got = cluster
                .node(4 + i)
                .peek(BlockKey::coded(object, 4 + i))
                .unwrap()
                .unwrap_or_else(|| panic!("parity {i} missing"));
            let expect_bytes: Vec<u8> = expect[i].iter().map(|g| g.0).collect();
            assert_eq!(*got, expect_bytes, "parity {i}");
        }
        // source blocks still replicated (migration not yet finalized)
        assert_eq!(blocks[0], *cluster.node(0).peek(BlockKey::source(object, 0)).unwrap().unwrap());
        // deterministic regeneration helper agrees
        assert_eq!(blocks[2], object_bytes(object, 2, 64 * 1024));
    }

    #[test]
    fn coding_node_bottleneck_scales_with_k() {
        // At 100 MB/s NIC and 1 MB blocks: k=4 downloads ≈ 40 ms minimum
        // through the coding node's download NIC.
        let mut spec = ClusterSpec::test(8);
        spec.bytes_per_sec = 100e6;
        let cluster = Cluster::start(spec);
        let object = ObjectId(2);
        let placement = ReplicaPlacement::new(object, 4, (0..8).collect()).unwrap();
        ingest_object(&cluster, &placement, 1 << 20).unwrap();
        let code = ClassicalCode::<Gf256>::new(8, 4).unwrap();
        let backend: BackendHandle = Arc::new(NativeBackend::new());
        let job = ClassicalJob {
            object,
            width: Width::W8,
            parity_rows: parity_rows_u32(&code),
            source_nodes: vec![0, 1, 2, 3],
            coding_node: 4,
            parity_nodes: vec![4, 5, 6, 7],
            buf_bytes: 65536,
            block_bytes: 1 << 20,
        };
        let dt = archive_classical(&cluster, &backend, &job).unwrap();
        // k * block_time = 4 * (1MB / 100MB/s) = 40 ms lower bound
        assert!(dt >= Duration::from_millis(38), "too fast: {dt:?}");
    }
}
