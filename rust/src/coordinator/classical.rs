//! Classical (atomic) archival — the paper's baseline (Section III, Fig. 1).
//!
//! One coding node downloads the k source blocks in parallel streams,
//! applies the parity sub-matrix buffer-by-buffer as data arrives
//! (streamlined), keeps parity blocks mapped to itself locally (data
//! locality) and uploads the rest — hence eq. (1):
//! `T_classical ≈ τ_block · max{k, m−1}` — the coding node's NIC serializes
//! everything.
//!
//! This module is a *plan builder*: [`ClassicalJob::plan`] lowers the job
//! onto the [`ArchivalPlan`] IR (one [`StepKind::Gemm`] on the coding node,
//! a [`StepKind::Source`] per remote source, a [`StepKind::Store`] per
//! remote parity) and [`archive_classical`] hands the plan to the shared
//! [`PlanExecutor`]. No node-command plumbing lives here.

use std::time::Duration;

use crate::backend::{BackendHandle, Width};
use crate::cluster::{Cluster, NodeId};
use crate::storage::{BlockKey, ObjectId};

use super::engine::PlanExecutor;
use super::plan::{ArchivalPlan, GemmInput, GemmOutput, StepKind};

/// One classical archival job.
#[derive(Clone, Debug)]
pub struct ClassicalJob {
    /// Object to archive.
    pub object: ObjectId,
    /// GF width.
    pub width: Width,
    /// Parity rows G′ (m×k) as u32 coefficients.
    pub parity_rows: Vec<Vec<u32>>,
    /// Node holding source block j (len k). Blocks located on the coding
    /// node itself are read locally (no transfer).
    pub source_nodes: Vec<NodeId>,
    /// The node that performs the encoding.
    pub coding_node: NodeId,
    /// Destination node of each parity block (len m). Entries equal to
    /// `coding_node` keep that parity local (no upload).
    pub parity_nodes: Vec<NodeId>,
    /// Network buffer size.
    pub buf_bytes: usize,
    /// Source block size.
    pub block_bytes: usize,
}

impl ClassicalJob {
    /// Message length k.
    pub fn k(&self) -> usize {
        self.source_nodes.len()
    }

    /// Parity count m.
    pub fn m(&self) -> usize {
        self.parity_nodes.len()
    }

    /// Lower the job onto the plan IR: one gemm step on the coding node,
    /// plus source/store transfer steps for every remote endpoint.
    pub fn plan(&self) -> anyhow::Result<ArchivalPlan> {
        let k = self.k();
        let m = self.m();
        anyhow::ensure!(
            self.parity_rows.len() == m && self.parity_rows.iter().all(|r| r.len() == k),
            "parity matrix must be m x k"
        );
        let mut plan = ArchivalPlan::new(self.object, self.width, self.buf_bytes, self.block_bytes);

        let inputs: Vec<GemmInput> = self
            .source_nodes
            .iter()
            .enumerate()
            .map(|(j, &src)| {
                if src == self.coding_node {
                    GemmInput::Local(BlockKey::source(self.object, j))
                } else {
                    GemmInput::Stream
                }
            })
            .collect();
        let outputs: Vec<GemmOutput> = self
            .parity_nodes
            .iter()
            .enumerate()
            .map(|(i, &dst)| {
                if dst == self.coding_node {
                    GemmOutput::Store(BlockKey::coded(self.object, k + i))
                } else {
                    GemmOutput::Stream
                }
            })
            .collect();
        let gemm = plan.add_step(
            self.coding_node,
            StepKind::Gemm {
                rows: self.parity_rows.clone(),
                inputs,
                outputs,
            },
        );
        for (j, &src) in self.source_nodes.iter().enumerate() {
            if src != self.coding_node {
                let s = plan.add_step(
                    src,
                    StepKind::Source {
                        key: BlockKey::source(self.object, j),
                    },
                );
                plan.connect(s, 0, gemm, j);
            }
        }
        for (i, &dst) in self.parity_nodes.iter().enumerate() {
            if dst != self.coding_node {
                let t = plan.add_step(
                    dst,
                    StepKind::Store {
                        key: BlockKey::coded(self.object, k + i),
                    },
                );
                plan.connect(gemm, i, t, 0);
            }
        }
        Ok(plan)
    }
}

/// Execute one classical archival through the shared engine; returns the
/// coding time (dispatch → all parity blocks durable on their nodes).
pub fn archive_classical(
    cluster: &Cluster,
    backend: &BackendHandle,
    job: &ClassicalJob,
) -> anyhow::Result<Duration> {
    PlanExecutor::new(cluster, backend.clone()).run(&job.plan()?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::cluster::ClusterSpec;
    use crate::codes::ClassicalCode;
    use crate::coordinator::ingest::{ingest_object, object_bytes};
    use crate::gf::{Gf256, GfElem};
    use crate::storage::ReplicaPlacement;
    use std::sync::Arc;

    fn parity_rows_u32(code: &ClassicalCode<Gf256>) -> Vec<Vec<u32>> {
        let p = code.parity_matrix();
        (0..p.rows())
            .map(|i| p.row(i).iter().map(|c| c.to_u32()).collect())
            .collect()
    }

    #[test]
    fn plan_shape_matches_job_topology() {
        // k=4 sources (one local), m=4 parities (one local): 1 gemm +
        // 3 sources + 3 stores, 6 edges.
        let code = ClassicalCode::<Gf256>::new(8, 4).unwrap();
        let job = ClassicalJob {
            object: ObjectId(50),
            width: Width::W8,
            parity_rows: parity_rows_u32(&code),
            source_nodes: vec![0, 1, 2, 4],
            coding_node: 4,
            parity_nodes: vec![4, 5, 6, 7],
            buf_bytes: 4096,
            block_bytes: 16 * 1024,
        };
        let plan = job.plan().unwrap();
        plan.validate().unwrap();
        assert_eq!(plan.len(), 1 + 3 + 3);
        assert_eq!(plan.edges.len(), 6);
        assert!(matches!(plan.steps[0].kind, StepKind::Gemm { .. }));
    }

    #[test]
    fn classical_archival_produces_correct_parity() {
        let cluster = Cluster::start(ClusterSpec::test(8));
        let object = ObjectId(1);
        let placement = ReplicaPlacement::new(object, 4, (0..8).collect()).unwrap();
        let blocks = ingest_object(&cluster, &placement, 64 * 1024).unwrap();

        let code = ClassicalCode::<Gf256>::new(8, 4).unwrap();
        let backend: BackendHandle = Arc::new(NativeBackend::new());
        let job = ClassicalJob {
            object,
            width: Width::W8,
            parity_rows: parity_rows_u32(&code),
            source_nodes: vec![0, 1, 2, 3],
            coding_node: 4,
            parity_nodes: vec![4, 5, 6, 7],
            buf_bytes: 8192,
            block_bytes: 64 * 1024,
        };
        let dt = archive_classical(&cluster, &backend, &job).unwrap();
        assert!(dt > Duration::ZERO);

        // verify parity against the library encode
        let obj_gf: Vec<Vec<Gf256>> = blocks
            .iter()
            .map(|b| b.iter().map(|&x| Gf256(x)).collect())
            .collect();
        let expect = code.encode_parity(&obj_gf);
        for i in 0..4 {
            let got = cluster
                .node(4 + i)
                .peek(BlockKey::coded(object, 4 + i))
                .unwrap()
                .unwrap_or_else(|| panic!("parity {i} missing"));
            let expect_bytes: Vec<u8> = expect[i].iter().map(|g| g.0).collect();
            assert_eq!(*got, expect_bytes, "parity {i}");
        }
        // source blocks still replicated (migration not yet finalized)
        assert_eq!(blocks[0], *cluster.node(0).peek(BlockKey::source(object, 0)).unwrap().unwrap());
        // deterministic regeneration helper agrees
        assert_eq!(blocks[2], object_bytes(object, 2, 64 * 1024));
    }

    #[test]
    fn coding_node_bottleneck_scales_with_k() {
        // At 100 MB/s NIC and 1 MB blocks: k=4 downloads ≈ 40 ms minimum
        // through the coding node's download NIC.
        let mut spec = ClusterSpec::test(8);
        spec.bytes_per_sec = 100e6;
        let cluster = Cluster::start(spec);
        let object = ObjectId(2);
        let placement = ReplicaPlacement::new(object, 4, (0..8).collect()).unwrap();
        ingest_object(&cluster, &placement, 1 << 20).unwrap();
        let code = ClassicalCode::<Gf256>::new(8, 4).unwrap();
        let backend: BackendHandle = Arc::new(NativeBackend::new());
        let job = ClassicalJob {
            object,
            width: Width::W8,
            parity_rows: parity_rows_u32(&code),
            source_nodes: vec![0, 1, 2, 3],
            coding_node: 4,
            parity_nodes: vec![4, 5, 6, 7],
            buf_bytes: 65536,
            block_bytes: 1 << 20,
        };
        let dt = archive_classical(&cluster, &backend, &job).unwrap();
        // k * block_time = 4 * (1MB / 100MB/s) = 40 ms lower bound
        assert!(dt >= Duration::from_millis(38), "too fast: {dt:?}");
    }
}
