//! The archival coordinator — the paper's system contribution, split into
//! a declarative **plan layer** and one **execution engine**.
//!
//! [`plan`] defines the ArchivalPlan IR: a DAG of `Source`/`Fold`/`Gemm`/
//! `Store` steps with field-erased `u32` coefficients, connected by stream
//! edges. [`engine`] provides the single [`PlanExecutor`] that lowers any
//! plan onto the simulated cluster (links + node commands), collects
//! completions, emits per-stage [`crate::metrics::Span`]s and offers
//! pluggable chain-selection policies ([`engine::ChainPolicy`]).
//!
//! Every archival strategy is a thin *plan builder* over that IR:
//!
//! * [`classical`] — the traditional *atomic* encoding (Section III,
//!   Fig. 1): one `Gemm` step on the coding node fed by `Source` streams,
//!   draining into `Store` steps; `T ≈ τ_block · max{k, m−1}` (eq. 1).
//! * [`pipeline`] — RapidRAID (Sections IV–V, Fig. 2) over any
//!   [`topology::Topology`]: fold steps over the n replica holders, shaped
//!   as the paper's chain (`T ≈ τ_block + (n−1)·τ_pipe`, eq. 2), a tree
//!   (logarithmic hop tail, straggler isolation) or a hybrid. The
//!   [`topology`] module owns the shapes, both lowering directions and the
//!   shape-aware placement policies.
//! * [`batch`] — concurrent multi-object archival (Fig. 4b/5b): every job
//!   lowers to a plan, the engine runs them with bounded concurrency.
//! * [`pipeline_decode`] — k concurrent decode chains (`Fold` steps over
//!   inverse coefficients), plus the classical transfer-plan twin.
//!
//! Plus: [`decode`] (degraded reads: reconstruction from any independent
//! k-subset *surviving* crashes), [`ingest`] (replicated object creation,
//! with policy-driven congestion/failure-aware chain placement),
//! [`migrate`] (encode → verify → drop replicas), and [`model`] (the
//! eq. 1/eq. 2 analytic estimates). The failure-repair planners build on
//! the same IR from [`crate::repair`]. `ARCHITECTURE.md` walks one
//! lowering end-to-end.

pub mod batch;
pub mod classical;
pub mod decode;
pub mod engine;
pub mod ingest;
pub mod migrate;
pub mod model;
pub mod pipeline;
pub mod pipeline_decode;
pub mod plan;
pub mod topology;

pub use batch::{
    pipeline_jobs, run_batch, run_batch_adaptive, run_batch_recorded, AdaptiveRun, BatchJob,
};
pub use classical::{archive_classical, ClassicalJob};
pub use decode::{reconstruct, survey_coded};
pub use engine::{
    select_chain, ChainPolicy, CongestionAwarePolicy, FifoPolicy, PlanExecutor, PolicyKind,
};
pub use ingest::{ingest_object, ingest_object_placed, object_bytes, place_object};
pub use migrate::{migrate_object, MigrationReport};
pub use pipeline::{archive_pipeline, PipelineJob};
pub use pipeline_decode::reconstruct_pipelined;
pub use plan::{ArchivalPlan, Edge, GemmInput, GemmOutput, Step, StepId, StepKind};
pub use topology::{
    LoadAwarePolicy, PlacementPolicy, Topology, TopologySelection,
};
