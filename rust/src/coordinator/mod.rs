//! The archival coordinator — the paper's system contribution.
//!
//! Orchestrates replication→erasure-code migration over the simulated
//! cluster, with two interchangeable archival strategies:
//!
//! * [`classical`] — the traditional *atomic* encoding (Section III,
//!   Fig. 1): one coding node streams the k source blocks down, applies the
//!   parity matrix buffer-by-buffer (streamlined) and streams the parity
//!   blocks out; `T ≈ τ_block · max{k, m−1}` (eq. 1).
//! * [`pipeline`] — RapidRAID (Sections IV–V, Fig. 2): the n replica
//!   holders form a chain; each folds its local block(s) into the passing
//!   partial combination and emits its codeword block locally;
//!   `T ≈ τ_block + (n−1)·τ_pipe` (eq. 2).
//!
//! Plus: [`batch`] (concurrent multi-object archival — Fig. 4b/5b),
//! [`decode`] (reconstruction from any independent k-subset),
//! [`ingest`] (replicated object creation), [`migrate`] (encode → verify →
//! drop replicas), and [`model`] (the eq. 1/eq. 2 analytic estimates).

pub mod batch;
pub mod classical;
pub mod decode;
pub mod ingest;
pub mod migrate;
pub mod model;
pub mod pipeline;
pub mod pipeline_decode;

pub use batch::{run_batch, BatchJob};
pub use classical::{archive_classical, ClassicalJob};
pub use decode::reconstruct;
pub use ingest::{ingest_object, object_bytes};
pub use migrate::{migrate_object, MigrationReport};
pub use pipeline::{archive_pipeline, PipelineJob};
pub use pipeline_decode::reconstruct_pipelined;
