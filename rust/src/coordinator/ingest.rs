//! Object ingest: create a replicated object on the cluster, laid out the
//! way RapidRAID expects (two replicas over the n chain nodes).
//!
//! Chains are either fixed by the caller (the paper's rotated layout) or
//! chosen at ingest time by a [`ChainPolicy`] ([`place_object`] /
//! [`ingest_object_placed`]): the policy ranks the currently *alive* nodes
//! — so a [`CongestionAwarePolicy`](crate::coordinator::engine::CongestionAwarePolicy)
//! routes new chains around congested nodes before any replica is placed,
//! and crashed nodes are never selected.

use crate::cluster::Cluster;
use crate::coordinator::engine::{select_chain, ChainPolicy};
use crate::storage::{BlockKey, ObjectId, ReplicaPlacement};
use crate::util::SplitMix64;

/// Deterministic pseudo-random content for block `index` of `object`.
pub fn object_bytes(object: ObjectId, index: usize, block_bytes: usize) -> Vec<u8> {
    let mut rng = SplitMix64::new(object.0.wrapping_mul(0xA24B_AED4_963E_E407) ^ index as u64);
    let mut buf = vec![0u8; block_bytes];
    rng.fill_bytes(&mut buf);
    buf
}

/// Create object blocks and store both replicas on the placement's chain
/// nodes (control-plane ingest; the archival experiments measure the
/// encode, not the initial insertion). Returns the k source blocks.
pub fn ingest_object(
    cluster: &Cluster,
    placement: &ReplicaPlacement,
    block_bytes: usize,
) -> anyhow::Result<Vec<Vec<u8>>> {
    let blocks: Vec<Vec<u8>> = (0..placement.k)
        .map(|i| object_bytes(placement.object, i, block_bytes))
        .collect();
    for (node, block_idx) in placement.replica_map() {
        cluster
            .node(node)
            .put(BlockKey::source(placement.object, block_idx), blocks[block_idx].clone())?;
    }
    Ok(blocks)
}

/// Choose a chain for a new `(n, k)` object under `policy`: rank the alive
/// nodes and take the `n` most preferred (congestion- and failure-aware
/// placement).
pub fn place_object(
    cluster: &Cluster,
    policy: &dyn ChainPolicy,
    object: ObjectId,
    n: usize,
    k: usize,
) -> anyhow::Result<ReplicaPlacement> {
    let alive = cluster.alive_nodes();
    let chain = select_chain(cluster, policy, &alive, n)?;
    ReplicaPlacement::new(object, k, chain)
}

/// Policy-placed ingest: [`place_object`] then [`ingest_object`] in one
/// call. Returns the chosen placement and the k source blocks.
pub fn ingest_object_placed(
    cluster: &Cluster,
    policy: &dyn ChainPolicy,
    object: ObjectId,
    n: usize,
    k: usize,
    block_bytes: usize,
) -> anyhow::Result<(ReplicaPlacement, Vec<Vec<u8>>)> {
    let placement = place_object(cluster, policy, object, n, k)?;
    let blocks = ingest_object(cluster, &placement, block_bytes)?;
    Ok((placement, blocks))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;

    #[test]
    fn deterministic_content() {
        let a = object_bytes(ObjectId(1), 0, 128);
        let b = object_bytes(ObjectId(1), 0, 128);
        let c = object_bytes(ObjectId(1), 1, 128);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn policy_placement_avoids_congested_and_failed_nodes() {
        use crate::cluster::CongestionSpec;
        use crate::coordinator::engine::CongestionAwarePolicy;
        // 10 nodes, need 8: the congested and the crashed one must not be
        // chosen.
        let cluster = Cluster::start(ClusterSpec::test(10));
        cluster.congest(2, &CongestionSpec::mild());
        cluster.fail_node(5);
        let (placement, blocks) = ingest_object_placed(
            &cluster,
            &CongestionAwarePolicy,
            ObjectId(9),
            8,
            4,
            64,
        )
        .unwrap();
        assert_eq!(blocks.len(), 4);
        assert_eq!(placement.chain.len(), 8);
        assert!(!placement.chain.contains(&5), "{:?}", placement.chain);
        assert!(!placement.chain.contains(&2), "{:?}", placement.chain);
        // replicas really landed on the chosen chain
        for (node, b) in placement.replica_map() {
            assert!(cluster
                .node(node)
                .peek(BlockKey::source(ObjectId(9), b))
                .unwrap()
                .is_some());
        }
    }

    #[test]
    fn placement_fails_when_too_few_alive_nodes() {
        use crate::coordinator::engine::FifoPolicy;
        let cluster = Cluster::start(ClusterSpec::test(8));
        cluster.fail_node(0);
        assert!(place_object(&cluster, &FifoPolicy, ObjectId(1), 8, 4).is_err());
    }

    #[test]
    fn ingest_places_two_replicas() {
        let cluster = Cluster::start(ClusterSpec::test(8));
        let p = ReplicaPlacement::new(ObjectId(3), 4, (0..8).collect()).unwrap();
        let blocks = ingest_object(&cluster, &p, 64).unwrap();
        assert_eq!(blocks.len(), 4);
        // replica layout: node i and node i+4 hold o_i
        for i in 0..4 {
            for node in [i, i + 4] {
                let got = cluster
                    .node(node)
                    .peek(BlockKey::source(ObjectId(3), i))
                    .unwrap()
                    .unwrap();
                assert_eq!(*got, blocks[i], "node {node} block {i}");
            }
        }
    }
}
