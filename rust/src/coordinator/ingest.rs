//! Object ingest: create a replicated object on the cluster, laid out the
//! way RapidRAID expects (two replicas over the n chain nodes).

use crate::cluster::Cluster;
use crate::storage::{BlockKey, ObjectId, ReplicaPlacement};
use crate::util::SplitMix64;

/// Deterministic pseudo-random content for block `index` of `object`.
pub fn object_bytes(object: ObjectId, index: usize, block_bytes: usize) -> Vec<u8> {
    let mut rng = SplitMix64::new(object.0.wrapping_mul(0xA24B_AED4_963E_E407) ^ index as u64);
    let mut buf = vec![0u8; block_bytes];
    rng.fill_bytes(&mut buf);
    buf
}

/// Create object blocks and store both replicas on the placement's chain
/// nodes (control-plane ingest; the archival experiments measure the
/// encode, not the initial insertion). Returns the k source blocks.
pub fn ingest_object(
    cluster: &Cluster,
    placement: &ReplicaPlacement,
    block_bytes: usize,
) -> anyhow::Result<Vec<Vec<u8>>> {
    let blocks: Vec<Vec<u8>> = (0..placement.k)
        .map(|i| object_bytes(placement.object, i, block_bytes))
        .collect();
    for (node, block_idx) in placement.replica_map() {
        cluster
            .node(node)
            .put(BlockKey::source(placement.object, block_idx), blocks[block_idx].clone())?;
    }
    Ok(blocks)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;

    #[test]
    fn deterministic_content() {
        let a = object_bytes(ObjectId(1), 0, 128);
        let b = object_bytes(ObjectId(1), 0, 128);
        let c = object_bytes(ObjectId(1), 1, 128);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn ingest_places_two_replicas() {
        let cluster = Cluster::start(ClusterSpec::test(8));
        let p = ReplicaPlacement::new(ObjectId(3), 4, (0..8).collect()).unwrap();
        let blocks = ingest_object(&cluster, &p, 64).unwrap();
        assert_eq!(blocks.len(), 4);
        // replica layout: node i and node i+4 hold o_i
        for i in 0..4 {
            for node in [i, i + 4] {
                let got = cluster
                    .node(node)
                    .peek(BlockKey::source(ObjectId(3), i))
                    .unwrap()
                    .unwrap();
                assert_eq!(*got, blocks[i], "node {node} block {i}");
            }
        }
    }
}
