//! Concurrent multi-object archival (the paper's Fig. 4b / Fig. 5b runs:
//! 16 objects encoded simultaneously on 16 nodes).
//!
//! Each job runs on its own coordinator thread; contention happens where it
//! should — at the simulated NICs. Roles rotate round-robin so every node
//! carries the same mix of source/coding/parity duties, as in the paper's
//! experiment where node i starts the encoding of object i.

use std::time::Duration;

use crate::backend::BackendHandle;
use crate::cluster::Cluster;

use super::classical::{archive_classical, ClassicalJob};
use super::pipeline::{archive_pipeline, PipelineJob};

/// One archival job of either strategy.
#[derive(Clone, Debug)]
pub enum BatchJob {
    /// Classical atomic encoding job.
    Classical(ClassicalJob),
    /// RapidRAID pipelined job.
    Pipeline(PipelineJob),
}

/// Run all jobs concurrently; returns per-job coding times (same order).
pub fn run_batch(
    cluster: &Cluster,
    backend: &BackendHandle,
    jobs: &[BatchJob],
) -> anyhow::Result<Vec<Duration>> {
    std::thread::scope(|scope| {
        let handles: Vec<_> = jobs
            .iter()
            .map(|job| {
                let backend = backend.clone();
                scope.spawn(move || match job {
                    BatchJob::Classical(j) => archive_classical(cluster, &backend, j),
                    BatchJob::Pipeline(j) => archive_pipeline(cluster, &backend, j),
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().map_err(|_| anyhow::anyhow!("job thread panicked"))?)
            .collect()
    })
}

/// Rotate a chain of `n` positions over `nodes` starting at `offset`
/// (object i in the 16-object experiment uses offset i).
pub fn rotated_chain(nodes: usize, n: usize, offset: usize) -> Vec<usize> {
    assert!(n <= nodes);
    (0..n).map(|i| (offset + i) % nodes).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::cluster::ClusterSpec;
    use crate::codes::rapidraid::RapidRaidCode;
    use crate::coordinator::ingest::ingest_object;
    use crate::gf::Gf256;
    use crate::storage::{BlockKey, ObjectId, ReplicaPlacement};
    use std::sync::Arc;

    #[test]
    fn rotated_chain_shape() {
        assert_eq!(rotated_chain(16, 16, 3)[0], 3);
        assert_eq!(rotated_chain(16, 16, 3)[15], 2);
        assert_eq!(rotated_chain(8, 6, 6), vec![6, 7, 0, 1, 2, 3]);
    }

    #[test]
    fn concurrent_pipeline_jobs_all_complete_correctly() {
        let cluster = Cluster::start(ClusterSpec::test(8));
        let code = RapidRaidCode::<Gf256>::with_seed(8, 4, 7).unwrap();
        let backend: BackendHandle = Arc::new(NativeBackend::new());
        let block = 16 * 1024;

        let mut jobs = Vec::new();
        let mut placements = Vec::new();
        for i in 0..4u64 {
            let object = ObjectId(100 + i);
            let chain = rotated_chain(8, 8, i as usize * 2);
            let placement = ReplicaPlacement::new(object, 4, chain).unwrap();
            ingest_object(&cluster, &placement, block).unwrap();
            jobs.push(BatchJob::Pipeline(
                PipelineJob::from_code(&code, &placement, 4096, block).unwrap(),
            ));
            placements.push(placement);
        }
        let times = run_batch(&cluster, &backend, &jobs).unwrap();
        assert_eq!(times.len(), 4);
        // all codeword blocks landed
        for p in &placements {
            for (pos, &node) in p.chain.iter().enumerate() {
                assert!(
                    cluster
                        .node(node)
                        .peek(BlockKey::coded(p.object, pos))
                        .unwrap()
                        .is_some(),
                    "object {} block {pos} missing on node {node}",
                    p.object
                );
            }
        }
    }
}
