//! Concurrent multi-object archival (the paper's Fig. 4b / Fig. 5b runs:
//! 16 objects encoded simultaneously on 16 nodes).
//!
//! Jobs of either strategy lower to [`ArchivalPlan`]s and run through the
//! one shared [`PlanExecutor`] (`run_many`); contention happens where it
//! should — at the simulated NICs and the bounded per-node worker pools.
//! Roles rotate round-robin so every node carries the same mix of
//! source/coding/parity duties, as in the paper's experiment where node i
//! starts the encoding of object i.

use std::time::Duration;

use crate::backend::BackendHandle;
use crate::cluster::Cluster;
use crate::codes::rapidraid::RapidRaidCode;
use crate::gf::{GfElem, SliceOps};
use crate::metrics::Recorder;
use crate::storage::{ObjectId, ReplicaPlacement};

use super::classical::ClassicalJob;
use super::engine::PlanExecutor;
use super::ingest::ingest_object;
use super::pipeline::PipelineJob;
use super::plan::ArchivalPlan;
use super::topology::{PlacementPolicy, Topology};

/// One archival job of either strategy.
#[derive(Clone, Debug)]
pub enum BatchJob {
    /// Classical atomic encoding job.
    Classical(ClassicalJob),
    /// RapidRAID pipelined job.
    Pipeline(PipelineJob),
}

impl BatchJob {
    /// Lower the job onto the plan IR (strategy-specific builder).
    pub fn plan(&self) -> anyhow::Result<ArchivalPlan> {
        match self {
            BatchJob::Classical(j) => j.plan(),
            BatchJob::Pipeline(j) => j.plan(),
        }
    }
}

/// Run all jobs concurrently; returns per-job coding times (same order).
pub fn run_batch(
    cluster: &Cluster,
    backend: &BackendHandle,
    jobs: &[BatchJob],
) -> anyhow::Result<Vec<Duration>> {
    run_batch_recorded(cluster, backend, jobs, None)
}

/// [`run_batch`] with optional per-stage span recording: spans land in the
/// recorder under `<prefix>transfer` / `<prefix>fold` / `<prefix>gemm` /
/// `<prefix>store` series (see [`PlanExecutor::with_spans`]).
pub fn run_batch_recorded(
    cluster: &Cluster,
    backend: &BackendHandle,
    jobs: &[BatchJob],
    spans: Option<(&Recorder, &str)>,
) -> anyhow::Result<Vec<Duration>> {
    let plans: Vec<ArchivalPlan> = jobs.iter().map(|j| j.plan()).collect::<anyhow::Result<_>>()?;
    let mut exec = PlanExecutor::new(cluster, backend.clone());
    if let Some((rec, prefix)) = spans {
        exec = exec.with_spans(rec, prefix);
    }
    exec.run_many(&plans)
}

/// Lower one pipelined job per placement, all through `topology` — the
/// Topology-parameterized bulk builder the `topo-sim` shootout and the
/// long-run harness feed into [`run_batch`] / `run_many_bounded`.
pub fn pipeline_jobs<F: GfElem + SliceOps>(
    code: &RapidRaidCode<F>,
    placements: &[ReplicaPlacement],
    topology: Topology,
    buf_bytes: usize,
    block_bytes: usize,
) -> anyhow::Result<Vec<BatchJob>> {
    placements
        .iter()
        .map(|p| {
            Ok(BatchJob::Pipeline(PipelineJob::from_code_with_topology(
                code,
                p,
                topology,
                buf_bytes,
                block_bytes,
            )?))
        })
        .collect()
}

/// Place, ingest and lower pipelined jobs **one object at a time** under a
/// shape-aware policy: every object gets `policy.select_topology` over the
/// currently alive nodes — a
/// [`LoadAwarePolicy`](super::topology::LoadAwarePolicy) picks the shape
/// *and* the placement from the live congestion/CPU state, re-ranking
/// between objects as earlier placements load nodes up. Returns the
/// per-object placements and jobs; feed the jobs to [`run_batch`] /
/// `PlanExecutor::run_many_bounded`.
pub fn place_and_build_pipeline_jobs<F: GfElem + SliceOps>(
    cluster: &Cluster,
    policy: &dyn PlacementPolicy,
    code: &RapidRaidCode<F>,
    objects: &[ObjectId],
    requested: Topology,
    buf_bytes: usize,
    block_bytes: usize,
) -> anyhow::Result<Vec<(ReplicaPlacement, BatchJob)>> {
    let mut out = Vec::with_capacity(objects.len());
    for &object in objects {
        let alive = cluster.alive_nodes();
        let sel = policy.select_topology(cluster, &alive, code.n(), requested)?;
        let placement = ReplicaPlacement::new(object, code.k(), sel.nodes)?;
        ingest_object(cluster, &placement, block_bytes)?;
        let job = BatchJob::Pipeline(PipelineJob::from_code_with_topology(
            code,
            &placement,
            sel.topology,
            buf_bytes,
            block_bytes,
        )?);
        out.push((placement, job));
    }
    Ok(out)
}

/// One object's outcome from [`run_batch_adaptive`]: which nodes got which
/// slot, which shape the policy settled on, and the measured makespan.
/// Callers need all three to verify decode — different shapes compose
/// different generators, so the coded bytes differ per shape.
#[derive(Clone, Debug)]
pub struct AdaptiveRun {
    /// The per-slot node binding the policy chose.
    pub placement: ReplicaPlacement,
    /// The shape the policy settled on for this object.
    pub topology: Topology,
    /// Dispatch-to-last-store time for this object's wave.
    pub makespan: Duration,
}

/// Mid-batch re-shaping: archive `objects` in waves of `window`, placing
/// each wave at a quiescent plan boundary — the placement policy ranks the
/// then-alive nodes against the load state earlier waves left behind
/// (residual NIC/CPU backlog, in-flight commands, churned rates and
/// profiles), so nodes whose measured load grew sink to leaf slots or out
/// of the selection entirely, and the shape choice tracks the cluster as
/// it degrades. `window == 1` re-ranks after every completion; larger
/// windows trade re-ranking granularity for intra-wave concurrency.
///
/// Snapshots are taken only between waves (inside
/// [`place_and_build_pipeline_jobs`], before anything from the new wave is
/// dispatched), never mid-flight — that is what keeps an adaptive run
/// deterministic per seed: the load state at a plan boundary is a pure
/// function of the schedule so far. With a static policy this degenerates
/// to a windowed [`run_batch`] over the same placements.
#[allow(clippy::too_many_arguments)]
pub fn run_batch_adaptive<F: GfElem + SliceOps>(
    cluster: &Cluster,
    backend: &BackendHandle,
    policy: &dyn PlacementPolicy,
    code: &RapidRaidCode<F>,
    objects: &[ObjectId],
    requested: Topology,
    buf_bytes: usize,
    block_bytes: usize,
    window: usize,
) -> anyhow::Result<Vec<AdaptiveRun>> {
    let window = window.max(1);
    let mut out = Vec::with_capacity(objects.len());
    for wave in objects.chunks(window) {
        let placed = place_and_build_pipeline_jobs(
            cluster,
            policy,
            code,
            wave,
            requested,
            buf_bytes,
            block_bytes,
        )?;
        let jobs: Vec<BatchJob> = placed.iter().map(|(_, j)| j.clone()).collect();
        let times = run_batch(cluster, backend, &jobs)?;
        for ((placement, job), makespan) in placed.into_iter().zip(times) {
            let topology = match &job {
                BatchJob::Pipeline(p) => p.topology,
                BatchJob::Classical(_) => unreachable!("builder emits pipeline jobs"),
            };
            out.push(AdaptiveRun {
                placement,
                topology,
                makespan,
            });
        }
    }
    Ok(out)
}

/// Rotate a chain of `n` positions over `nodes` starting at `offset`
/// (object i in the 16-object experiment uses offset i).
pub fn rotated_chain(nodes: usize, n: usize, offset: usize) -> Vec<usize> {
    assert!(n <= nodes);
    (0..n).map(|i| (offset + i) % nodes).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::cluster::ClusterSpec;
    use crate::codes::rapidraid::RapidRaidCode;
    use crate::coordinator::ingest::ingest_object;
    use crate::gf::Gf256;
    use crate::storage::{BlockKey, ObjectId, ReplicaPlacement};
    use std::sync::Arc;

    #[test]
    fn rotated_chain_shape() {
        assert_eq!(rotated_chain(16, 16, 3)[0], 3);
        assert_eq!(rotated_chain(16, 16, 3)[15], 2);
        assert_eq!(rotated_chain(8, 6, 6), vec![6, 7, 0, 1, 2, 3]);
    }

    #[test]
    fn concurrent_pipeline_jobs_all_complete_correctly() {
        let cluster = Cluster::start(ClusterSpec::test(8));
        let code = RapidRaidCode::<Gf256>::with_seed(8, 4, 7).unwrap();
        let backend: BackendHandle = Arc::new(NativeBackend::new());
        let block = 16 * 1024;

        let mut jobs = Vec::new();
        let mut placements = Vec::new();
        for i in 0..4u64 {
            let object = ObjectId(100 + i);
            let chain = rotated_chain(8, 8, i as usize * 2);
            let placement = ReplicaPlacement::new(object, 4, chain).unwrap();
            ingest_object(&cluster, &placement, block).unwrap();
            jobs.push(BatchJob::Pipeline(
                PipelineJob::from_code(&code, &placement, 4096, block).unwrap(),
            ));
            placements.push(placement);
        }
        let times = run_batch(&cluster, &backend, &jobs).unwrap();
        assert_eq!(times.len(), 4);
        // all codeword blocks landed
        for p in &placements {
            for (pos, &node) in p.chain.iter().enumerate() {
                assert!(
                    cluster
                        .node(node)
                        .peek(BlockKey::coded(p.object, pos))
                        .unwrap()
                        .is_some(),
                    "object {} block {pos} missing on node {node}",
                    p.object
                );
            }
        }
    }

    #[test]
    fn load_aware_batch_places_and_shapes_per_object() {
        use crate::cluster::CongestionSpec;
        use crate::coordinator::topology::{LoadAwarePolicy, Topology};
        // 8 nodes (every one needed), one severely clamped: the load-aware
        // policy must pick a non-chain shape, keep the clamped node on a
        // leaf slot, and the batch must still archive through run_batch.
        let cluster = Cluster::start(ClusterSpec::test(8));
        cluster.congest(
            4,
            &CongestionSpec {
                bytes_per_sec: 1e8,
                extra_latency: std::time::Duration::ZERO,
                jitter: std::time::Duration::ZERO,
            },
        );
        let code = RapidRaidCode::<Gf256>::with_seed(8, 4, 7).unwrap();
        let backend: BackendHandle = Arc::new(NativeBackend::new());
        let objects: Vec<ObjectId> = (0..2).map(|i| ObjectId(400 + i)).collect();
        let placed = place_and_build_pipeline_jobs(
            &cluster,
            &LoadAwarePolicy::default(),
            &code,
            &objects,
            Topology::Chain,
            2048,
            8 * 1024,
        )
        .unwrap();
        assert_eq!(placed.len(), 2);
        for (placement, job) in &placed {
            match job {
                BatchJob::Pipeline(p) => {
                    assert_ne!(p.topology, Topology::Chain, "spread must force a shape");
                    // the clamped node never lands on an interior slot
                    let shape = p.topology.shape(8).unwrap();
                    if let Some(slot) = placement.chain.iter().position(|&n| n == 4) {
                        assert!(shape.children()[slot].is_empty(), "{:?}", placement.chain);
                    }
                }
                other => panic!("expected pipeline job, got {other:?}"),
            }
        }
        let jobs: Vec<BatchJob> = placed.iter().map(|(_, j)| j.clone()).collect();
        let times = run_batch(&cluster, &backend, &jobs).unwrap();
        assert_eq!(times.len(), 2);
        for (placement, _) in &placed {
            for (pos, &node) in placement.chain.iter().enumerate() {
                assert!(
                    cluster
                        .node(node)
                        .peek(BlockKey::coded(placement.object, pos))
                        .unwrap()
                        .is_some(),
                    "object {} block {pos} missing on node {node}",
                    placement.object
                );
            }
        }
    }

    #[test]
    fn adaptive_batch_reranks_each_wave_and_archives_everything() {
        use crate::cluster::CongestionSpec;
        use crate::coordinator::topology::{LoadAwarePolicy, Topology};
        // 11-node pool for an 8-slot pipeline, one straggler clamped 100x:
        // the adaptive driver must keep it out of every wave's placement
        // (spares exist) while all objects archive and decode-verifiably
        // land. window=1 re-places at every completion boundary.
        let cluster = Cluster::start(ClusterSpec::test(11).sim());
        cluster.congest(
            1,
            &CongestionSpec {
                bytes_per_sec: 1e7,
                extra_latency: std::time::Duration::ZERO,
                jitter: std::time::Duration::ZERO,
            },
        );
        let code = RapidRaidCode::<Gf256>::with_seed(8, 4, 7).unwrap();
        let backend: BackendHandle = Arc::new(NativeBackend::new());
        let objects: Vec<ObjectId> = (0..3).map(|i| ObjectId(500 + i)).collect();
        let runs = run_batch_adaptive(
            &cluster,
            &backend,
            &LoadAwarePolicy::adaptive(),
            &code,
            &objects,
            Topology::Chain,
            2048,
            8 * 1024,
            1,
        )
        .unwrap();
        assert_eq!(runs.len(), 3);
        for run in &runs {
            assert!(
                !run.placement.chain.contains(&1),
                "straggler placed: {:?}",
                run.placement.chain
            );
            assert!(run.makespan > Duration::ZERO);
            for (pos, &node) in run.placement.chain.iter().enumerate() {
                assert!(
                    cluster
                        .node(node)
                        .peek(BlockKey::coded(run.placement.object, pos))
                        .unwrap()
                        .is_some(),
                    "object {} block {pos} missing on node {node}",
                    run.placement.object
                );
            }
        }
    }

    #[test]
    fn recorded_batch_collects_fold_spans() {
        let cluster = Cluster::start(ClusterSpec::test(8));
        let code = RapidRaidCode::<Gf256>::with_seed(8, 4, 7).unwrap();
        let backend: BackendHandle = Arc::new(NativeBackend::new());
        let object = ObjectId(200);
        let placement = ReplicaPlacement::new(object, 4, (0..8).collect()).unwrap();
        ingest_object(&cluster, &placement, 8 * 1024).unwrap();
        let jobs = vec![BatchJob::Pipeline(
            PipelineJob::from_code(&code, &placement, 2048, 8 * 1024).unwrap(),
        )];
        let rec = Recorder::new();
        run_batch_recorded(&cluster, &backend, &jobs, Some((&rec, "RR8/"))).unwrap();
        // one span per chain stage
        assert_eq!(rec.candle("RR8/fold").unwrap().samples.len(), 8);
        assert!(rec.candle("RR8/transfer").is_none()); // pure chain: no transfers
    }
}
