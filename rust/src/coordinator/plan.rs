//! The **ArchivalPlan IR**: a declarative dataflow description of one
//! archival (or reconstruction) operation, decoupling *what* an encoding
//! computes from *where* and *how* it runs.
//!
//! A plan is a DAG of [`Step`]s — [`StepKind::Source`] (stream a stored
//! block out), [`StepKind::Fold`] (one GF multiply-accumulate pipeline
//! stage, paper eqs. (3)/(4)), [`StepKind::Gemm`] (an m×k GF matrix applied
//! to k streamed/local inputs) and [`StepKind::Store`] (persist an incoming
//! stream) — connected by [`Edge`]s that lower onto rate-limited cluster
//! links. Coefficients travel field-erased as `u32`, so one IR covers
//! GF(2^8) and GF(2^16) and both compute backends.
//!
//! The classical (atomic) encoder, the RapidRAID pipelined encoder, the
//! batch scheduler, migration and pipelined decode are all *plan builders*
//! over this IR; a single [`crate::coordinator::engine::PlanExecutor`] runs
//! any plan. Lowering examples live in `ARCHITECTURE.md`.
//!
//! Locality is expressed in the IR, not with self-links (the simulated
//! cluster has none): a gemm input already on the coding node is
//! [`GemmInput::Local`], an output kept there is [`GemmOutput::Store`],
//! and a fold's block is always local by RapidRAID's placement
//! precondition.

use crate::backend::Width;
use crate::cluster::NodeId;
use crate::storage::{BlockKey, ObjectId};

/// Index of a step within its plan.
pub type StepId = usize;

/// One gemm input: a stream bound by an edge, or a local block.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum GemmInput {
    /// Bound to exactly one incoming edge (port = input index).
    Stream,
    /// Read from the executing node's store (data locality).
    Local(BlockKey),
}

/// One gemm output: a stream bound by an edge, or a locally stored block.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum GemmOutput {
    /// Bound to exactly one outgoing edge (port = output index).
    Stream,
    /// Stored on the executing node under this key (data locality).
    Store(BlockKey),
}

/// What a plan step computes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StepKind {
    /// Stream the stored block `key` out on port 0 (a transfer's read side).
    Source {
        /// Block to stream.
        key: BlockKey,
    },
    /// Receive the stream on port 0 and store it under `key`.
    Store {
        /// Destination key.
        key: BlockKey,
    },
    /// One pipeline stage: consume the upstream partial combination on
    /// in-port 0 (or synthesize zeros when no in-edge — the pipeline
    /// head), fold the local blocks, forward `x ⊕ Σψ·local` on **every**
    /// bound out-port (one stream per child — tree pipelines fan the same
    /// combination out to several subtrees; a chain stage binds port 0
    /// only; a tail binds none) and optionally store `x ⊕ Σξ·local`.
    Fold {
        /// Local blocks folded at this stage (1 or 2).
        locals: Vec<BlockKey>,
        /// Forward coefficients ψ, one per local.
        psi: Vec<u32>,
        /// Output coefficients ξ, one per local.
        xi: Vec<u32>,
        /// Where to store the ξ output (`None` relays only).
        store: Option<BlockKey>,
    },
    /// Streamed GF matrix application `out[i] = Σ_j rows[i][j] · in[j]`:
    /// the classical coding node, or any atomic lowering of a generator.
    Gemm {
        /// Coefficient rows (m×k).
        rows: Vec<Vec<u32>>,
        /// k inputs; `Stream` entries bind in-edges at port = input index.
        inputs: Vec<GemmInput>,
        /// m outputs; `Stream` entries bind out-edges at port = output index.
        outputs: Vec<GemmOutput>,
    },
}

impl StepKind {
    /// Stage label used for metrics spans (`transfer`/`store`/`fold`/`gemm`).
    pub fn stage(&self) -> &'static str {
        match self {
            StepKind::Source { .. } => "transfer",
            StepKind::Store { .. } => "store",
            StepKind::Fold { .. } => "fold",
            StepKind::Gemm { .. } => "gemm",
        }
    }
}

/// One step of a plan, bound to the cluster node that executes it.
#[derive(Clone, Debug)]
pub struct Step {
    /// Executing node.
    pub node: NodeId,
    /// The computation.
    pub kind: StepKind,
}

/// A stream edge between two step ports; lowers onto one cluster link.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct Edge {
    /// Producing step.
    pub from: StepId,
    /// Producer port (0 for Source/Fold; gemm output index otherwise).
    pub from_port: usize,
    /// Consuming step.
    pub to: StepId,
    /// Consumer port (0 for Store/Fold; gemm input index otherwise).
    pub to_port: usize,
}

/// A declarative archival operation over one object.
#[derive(Clone, Debug)]
pub struct ArchivalPlan {
    /// Object the plan operates on (reporting/debugging).
    pub object: ObjectId,
    /// GF width of every coefficient in the plan.
    pub width: Width,
    /// Network frame size every stream uses.
    pub buf_bytes: usize,
    /// Size of every block entering the plan.
    pub block_bytes: usize,
    /// The steps, indexed by [`StepId`].
    pub steps: Vec<Step>,
    /// Stream edges between step ports.
    pub edges: Vec<Edge>,
}

impl ArchivalPlan {
    /// Empty plan with the given framing parameters.
    pub fn new(object: ObjectId, width: Width, buf_bytes: usize, block_bytes: usize) -> Self {
        Self {
            object,
            width,
            buf_bytes,
            block_bytes,
            steps: Vec::new(),
            edges: Vec::new(),
        }
    }

    /// Append a step on `node`; returns its id for wiring.
    pub fn add_step(&mut self, node: NodeId, kind: StepKind) -> StepId {
        self.steps.push(Step { node, kind });
        self.steps.len() - 1
    }

    /// Add a stream edge `from:from_port → to:to_port`.
    pub fn connect(&mut self, from: StepId, from_port: usize, to: StepId, to_port: usize) {
        self.edges.push(Edge {
            from,
            from_port,
            to,
            to_port,
        });
    }

    /// Number of steps.
    pub fn len(&self) -> usize {
        self.steps.len()
    }

    /// True when the plan has no steps.
    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    /// Structural validation: port/arity correctness, no dangling or
    /// duplicated stream bindings, no self-node edges. The executor calls
    /// this before dispatching anything.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.buf_bytes > 0, "buf_bytes must be positive");
        anyhow::ensure!(self.block_bytes > 0, "block_bytes must be positive");
        anyhow::ensure!(
            self.block_bytes % self.width.symbol_bytes() == 0,
            "block size must be a multiple of the symbol size"
        );

        // Per-step arity invariants.
        for (id, step) in self.steps.iter().enumerate() {
            if let StepKind::Fold { locals, psi, xi, .. } = &step.kind {
                anyhow::ensure!(!locals.is_empty(), "step {id}: fold with no locals");
                anyhow::ensure!(
                    psi.len() == locals.len() && xi.len() == locals.len(),
                    "step {id}: fold coefficient arity mismatch"
                );
            }
            if let StepKind::Gemm { rows, inputs, outputs } = &step.kind {
                anyhow::ensure!(!rows.is_empty() && !inputs.is_empty(), "step {id}: empty gemm");
                anyhow::ensure!(
                    rows.iter().all(|r| r.len() == inputs.len()),
                    "step {id}: gemm row arity != input count"
                );
                anyhow::ensure!(
                    outputs.len() == rows.len(),
                    "step {id}: gemm output count != row count"
                );
            }
        }

        // Edge endpoint validity + binding uniqueness.
        let mut out_bound = std::collections::HashSet::new();
        let mut in_bound = std::collections::HashSet::new();
        for (ei, e) in self.edges.iter().enumerate() {
            anyhow::ensure!(
                e.from < self.steps.len() && e.to < self.steps.len(),
                "edge {ei}: step id out of range"
            );
            anyhow::ensure!(
                self.steps[e.from].node != self.steps[e.to].node,
                "edge {ei}: self-node edge (express locality as Local/Store instead)"
            );
            let from_ok = match &self.steps[e.from].kind {
                StepKind::Source { .. } => e.from_port == 0,
                // A fold forwards the same combination on every bound
                // out-port (multi-port fan-out); ports need not be dense.
                StepKind::Fold { .. } => true,
                StepKind::Gemm { outputs, .. } => {
                    matches!(outputs.get(e.from_port), Some(GemmOutput::Stream))
                }
                StepKind::Store { .. } => false,
            };
            anyhow::ensure!(from_ok, "edge {ei}: invalid producer port");
            let to_ok = match &self.steps[e.to].kind {
                StepKind::Store { .. } | StepKind::Fold { .. } => e.to_port == 0,
                StepKind::Gemm { inputs, .. } => {
                    matches!(inputs.get(e.to_port), Some(GemmInput::Stream))
                }
                StepKind::Source { .. } => false,
            };
            anyhow::ensure!(to_ok, "edge {ei}: invalid consumer port");
            anyhow::ensure!(
                out_bound.insert((e.from, e.from_port)),
                "edge {ei}: producer port bound twice"
            );
            anyhow::ensure!(
                in_bound.insert((e.to, e.to_port)),
                "edge {ei}: consumer port bound twice"
            );
        }

        // Completeness: every mandatory stream port is bound.
        for (id, step) in self.steps.iter().enumerate() {
            match &step.kind {
                StepKind::Source { .. } => anyhow::ensure!(
                    out_bound.contains(&(id, 0)),
                    "step {id}: source stream unbound"
                ),
                StepKind::Store { .. } => anyhow::ensure!(
                    in_bound.contains(&(id, 0)),
                    "step {id}: store stream unbound"
                ),
                // A fold with no in-edge is a chain head, none out a tail.
                StepKind::Fold { .. } => {}
                StepKind::Gemm { inputs, outputs, .. } => {
                    for (j, inp) in inputs.iter().enumerate() {
                        if matches!(inp, GemmInput::Stream) {
                            anyhow::ensure!(
                                in_bound.contains(&(id, j)),
                                "step {id}: gemm input {j} unbound"
                            );
                        }
                    }
                    for (i, out) in outputs.iter().enumerate() {
                        if matches!(out, GemmOutput::Stream) {
                            anyhow::ensure!(
                                out_bound.contains(&(id, i)),
                                "step {id}: gemm output {i} unbound"
                            );
                        }
                    }
                }
            }
        }

        // Reject cyclic stream dependencies (Kahn's algorithm): every stage
        // blocks on its upstream's first frame, so a cycle of edges would
        // hang the executor forever instead of erroring.
        let n = self.steps.len();
        let mut indegree = vec![0usize; n];
        let mut adjacent: Vec<Vec<usize>> = vec![Vec::new(); n];
        for e in &self.edges {
            adjacent[e.from].push(e.to);
            indegree[e.to] += 1;
        }
        let mut ready: Vec<usize> = (0..n).filter(|&i| indegree[i] == 0).collect();
        let mut ordered = 0usize;
        while let Some(i) = ready.pop() {
            ordered += 1;
            for &j in &adjacent[i] {
                indegree[j] -= 1;
                if indegree[j] == 0 {
                    ready.push(j);
                }
            }
        }
        anyhow::ensure!(ordered == n, "plan has a cyclic stream dependency");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> ArchivalPlan {
        ArchivalPlan::new(ObjectId(1), Width::W8, 1024, 4096)
    }

    fn fold(store: Option<BlockKey>) -> StepKind {
        StepKind::Fold {
            locals: vec![BlockKey::source(ObjectId(1), 0)],
            psi: vec![3],
            xi: vec![7],
            store,
        }
    }

    #[test]
    fn valid_two_stage_chain() {
        let mut p = base();
        let a = p.add_step(0, fold(Some(BlockKey::coded(ObjectId(1), 0))));
        let b = p.add_step(1, fold(Some(BlockKey::coded(ObjectId(1), 1))));
        p.connect(a, 0, b, 0);
        p.validate().unwrap();
        assert_eq!(p.len(), 2);
        assert!(!p.is_empty());
    }

    #[test]
    fn fold_fanout_binds_multiple_out_ports() {
        // tree pipelines: one fold streams the same combination to two
        // children on ports 0 and 1
        let mut p = base();
        let root = p.add_step(0, fold(Some(BlockKey::coded(ObjectId(1), 0))));
        let a = p.add_step(1, fold(Some(BlockKey::coded(ObjectId(1), 1))));
        let b = p.add_step(2, fold(Some(BlockKey::coded(ObjectId(1), 2))));
        p.connect(root, 0, a, 0);
        p.connect(root, 1, b, 0);
        p.validate().unwrap();
        // double-binding one producer port is still rejected
        let mut bad = p.clone();
        let c = bad.add_step(3, fold(None));
        bad.connect(root, 1, c, 0);
        assert!(bad.validate().unwrap_err().to_string().contains("bound twice"));
    }

    #[test]
    fn rejects_self_node_edge() {
        let mut p = base();
        let a = p.add_step(0, fold(None));
        let b = p.add_step(0, fold(None));
        p.connect(a, 0, b, 0);
        let err = p.validate().unwrap_err();
        assert!(err.to_string().contains("self-node"), "{err}");
    }

    #[test]
    fn rejects_unbound_source_and_store() {
        let mut p = base();
        p.add_step(0, StepKind::Source {
            key: BlockKey::source(ObjectId(1), 0),
        });
        assert!(p.validate().unwrap_err().to_string().contains("unbound"));
        let mut p = base();
        p.add_step(0, StepKind::Store {
            key: BlockKey::coded(ObjectId(1), 0),
        });
        assert!(p.validate().unwrap_err().to_string().contains("unbound"));
    }

    #[test]
    fn rejects_double_binding_and_bad_gemm_port() {
        let mut p = base();
        let s = p.add_step(0, StepKind::Source {
            key: BlockKey::source(ObjectId(1), 0),
        });
        let g = p.add_step(1, StepKind::Gemm {
            rows: vec![vec![2]],
            inputs: vec![GemmInput::Stream],
            outputs: vec![GemmOutput::Store(BlockKey::coded(ObjectId(1), 0))],
        });
        p.connect(s, 0, g, 0);
        p.validate().unwrap();

        // double-bind the same consumer port
        let mut bad = p.clone();
        let s2 = bad.add_step(2, StepKind::Source {
            key: BlockKey::source(ObjectId(1), 0),
        });
        bad.connect(s2, 0, g, 0);
        assert!(bad.validate().unwrap_err().to_string().contains("bound twice"));

        // edge into a Local (non-stream) gemm port
        let mut bad = base();
        let s = bad.add_step(0, StepKind::Source {
            key: BlockKey::source(ObjectId(1), 0),
        });
        let g = bad.add_step(1, StepKind::Gemm {
            rows: vec![vec![2]],
            inputs: vec![GemmInput::Local(BlockKey::source(ObjectId(1), 0))],
            outputs: vec![GemmOutput::Store(BlockKey::coded(ObjectId(1), 0))],
        });
        bad.connect(s, 0, g, 0);
        assert!(bad.validate().is_err());
    }

    #[test]
    fn rejects_arity_mismatches() {
        let mut p = base();
        p.add_step(0, StepKind::Fold {
            locals: vec![BlockKey::source(ObjectId(1), 0)],
            psi: vec![1, 2], // arity mismatch
            xi: vec![3],
            store: None,
        });
        assert!(p.validate().is_err());

        let mut p = base();
        p.add_step(0, StepKind::Gemm {
            rows: vec![vec![1, 2]], // 2 columns
            inputs: vec![GemmInput::Local(BlockKey::source(ObjectId(1), 0))], // 1 input
            outputs: vec![GemmOutput::Store(BlockKey::coded(ObjectId(1), 0))],
        });
        assert!(p.validate().is_err());
    }

    #[test]
    fn rejects_cyclic_stream_dependency() {
        // a→b and b→a between fold steps: ports and nodes are all valid,
        // but the executor would deadlock — validate must reject it.
        let mut p = base();
        let a = p.add_step(0, fold(None));
        let b = p.add_step(1, fold(None));
        p.connect(a, 0, b, 0);
        p.connect(b, 0, a, 0);
        let err = p.validate().unwrap_err();
        assert!(err.to_string().contains("cyclic"), "{err}");
    }

    #[test]
    fn rejects_bad_framing() {
        let mut p = ArchivalPlan::new(ObjectId(1), Width::W16, 1024, 4097);
        p.add_step(0, fold(None));
        let err = p.validate().unwrap_err();
        assert!(err.to_string().contains("symbol"), "{err}");
        let p = ArchivalPlan::new(ObjectId(1), Width::W8, 0, 4096);
        assert!(p.validate().is_err());
    }

    #[test]
    fn stage_labels() {
        assert_eq!(
            StepKind::Source { key: BlockKey::source(ObjectId(1), 0) }.stage(),
            "transfer"
        );
        assert_eq!(
            StepKind::Store { key: BlockKey::coded(ObjectId(1), 0) }.stage(),
            "store"
        );
        assert_eq!(fold(None).stage(), "fold");
        assert_eq!(
            StepKind::Gemm {
                rows: vec![vec![1]],
                inputs: vec![GemmInput::Stream],
                outputs: vec![GemmOutput::Stream],
            }
            .stage(),
            "gemm"
        );
    }
}
