//! Pipelined decoding — the paper's unreported extension ("our RapidRAID
//! implementation also includes a fast pipelined decoding mechanism that is
//! not discussed here because of space restrictions", Section VI-A).
//!
//! Classical decoding mirrors classical encoding: one node downloads k
//! coded blocks (k serialized block-times through its NIC), inverts, and
//! reconstructs. The pipelined variant mirrors pipelined encoding: to
//! recover source block o_j, a chain through the k holders of an
//! independent subset accumulates `Σ_i inv[j][i]·c_i` buffer by buffer, and
//! the tail stores o_j. All k chains run concurrently with rotated
//! starting offsets so every NIC carries a balanced share — per-node
//! traffic ≈ k−1 block transmissions spread over k parallel chains instead
//! of k serialized arrivals at one node.
//!
//! Both variants are *plan builders*: the k decode chains lower onto one
//! [`ArchivalPlan`] of fold steps (the same IR the encoders use — repair
//! pipelining and tree chains are further builders over it), and the
//! classical twin lowers its block gathering onto source/store transfer
//! steps. Execution is the shared [`PlanExecutor`] in both cases.

use std::time::Duration;

use crate::backend::{BackendHandle, Width};
use crate::clock::Clock;
use crate::cluster::Cluster;
use crate::codes::rapidraid::RapidRaidCode;
use crate::gf::{gauss, GfElem, SliceOps};
use crate::storage::{BlockKey, ObjectId};

use super::engine::PlanExecutor;
use super::plan::{ArchivalPlan, StepKind};

/// Reconstruct all k source blocks of `object` by running k concurrent
/// decode pipelines over the surviving coded blocks. Returns the blocks
/// and the wall-clock decode time.
///
/// The recovered blocks are also left on the tail node of each chain under
/// their `BlockKind::Source` key, restoring one full replica in place —
/// the building block of a replication "un-migration".
pub fn reconstruct_pipelined<F: GfElem + SliceOps>(
    cluster: &Cluster,
    code: &RapidRaidCode<F>,
    chain: &[usize],
    object: ObjectId,
    backend: &BackendHandle,
    buf_bytes: usize,
) -> anyhow::Result<(Vec<Vec<u8>>, Duration)> {
    anyhow::ensure!(chain.len() == code.n(), "chain/code mismatch");
    let k = code.k();
    let width = Width::for_bits(F::BITS)?;

    // survivors + an independent k-subset + the inverse of its rows
    // (degraded: crashed nodes count as missing blocks)
    let (avail, block_bytes) = super::decode::survey_coded(cluster, chain, object);
    anyhow::ensure!(!avail.is_empty(), "object {object}: no coded blocks survive");
    let subset = code
        .find_decodable_subset(&avail)
        .ok_or_else(|| anyhow::anyhow!("object {object} unrecoverable: available {avail:?}"))?;
    // The k×k inversion runs on the first selected survivor; its meter
    // prices the Gauss-Jordan in virtual time before any chain starts.
    cluster
        .node(chain[subset[0]])
        .cpu
        .charge(&crate::resources::GfWork::invert(k));
    let inv = gauss::invert(&code.generator().select_rows(&subset))
        .ok_or_else(|| anyhow::anyhow!("subset {subset:?} unexpectedly singular"))?;

    // Lower the k decode chains onto one plan: chain j recovers o_j with
    // fold coefficients taken from row j of the inverse; only its tail
    // stores (ξ = coefficient there, ψ unused past the last hop).
    let mut plan = ArchivalPlan::new(object, width, buf_bytes, block_bytes);
    let mut tails = Vec::with_capacity(k);
    for j in 0..k {
        // chain for o_j: the k holders, rotated by j to balance NIC load
        let order: Vec<usize> = (0..k).map(|i| subset[(i + j) % k]).collect();
        let tail_pos = *order.last().unwrap();
        tails.push((chain[tail_pos], BlockKey::source(object, j)));

        let mut prev = None;
        for (stage, &pos) in order.iter().enumerate() {
            let col = subset.iter().position(|&p| p == pos).unwrap();
            let coeff = inv[(j, col)].to_u32();
            let is_tail = stage == k - 1;
            let id = plan.add_step(
                chain[pos],
                StepKind::Fold {
                    locals: vec![BlockKey::coded(object, pos)],
                    psi: vec![coeff],
                    xi: vec![if is_tail { coeff } else { 0 }],
                    store: is_tail.then_some(BlockKey::source(object, j)),
                },
            );
            if let Some(p) = prev {
                plan.connect(p, 0, id, 0);
            }
            prev = Some(id);
        }
    }
    let elapsed = PlanExecutor::new(cluster, backend.clone()).run(&plan)?;

    let mut out = Vec::with_capacity(k);
    for (node, key) in tails {
        let block = cluster
            .node(node)
            .peek(key)?
            .ok_or_else(|| anyhow::anyhow!("decoded block {key:?} missing on node {node}"))?;
        out.push((*block).clone());
    }
    Ok((out, elapsed))
}

/// Classical decode timing twin: one node streams the k selected coded
/// blocks down (a transfer plan, metered), applies the inverse locally,
/// stores the object. Used by tests/benches to compare against
/// [`reconstruct_pipelined`].
pub fn reconstruct_classical_timed<F: GfElem + SliceOpsBound>(
    cluster: &Cluster,
    code: &RapidRaidCode<F>,
    chain: &[usize],
    object: ObjectId,
    decode_node: usize,
    backend: &BackendHandle,
    buf_bytes: usize,
) -> anyhow::Result<(Vec<Vec<u8>>, Duration)> {
    let k = code.k();
    let width = Width::for_bits(F::BITS)?;
    let (avail, block_bytes) = super::decode::survey_coded(cluster, chain, object);
    anyhow::ensure!(!avail.is_empty(), "object {object}: no coded blocks survive");
    let subset = code
        .find_decodable_subset(&avail)
        .ok_or_else(|| anyhow::anyhow!("object {object} unrecoverable"))?;
    // classical decode inverts on the decode node itself
    cluster
        .node(decode_node)
        .cpu
        .charge(&crate::resources::GfWork::invert(k));
    let inv = gauss::invert(&code.generator().select_rows(&subset))
        .ok_or_else(|| anyhow::anyhow!("singular subset"))?;
    let inv_u32: Vec<Vec<u32>> = (0..k)
        .map(|i| inv.row(i).iter().map(|c| c.to_u32()).collect())
        .collect();

    let clock = cluster.clock().clone();
    let start = clock.now();
    // transfer plan: stream each selected block to the decode node (metered)
    let mut plan = ArchivalPlan::new(object, width, buf_bytes, block_bytes);
    for &pos in &subset {
        let src = chain[pos];
        if src == decode_node {
            continue;
        }
        let key = BlockKey::coded(object, pos);
        let s = plan.add_step(src, StepKind::Source { key });
        let t = plan.add_step(decode_node, StepKind::Store { key });
        plan.connect(s, 0, t, 0);
    }
    PlanExecutor::new(cluster, backend.clone()).run(&plan)?;

    // local inverse application on the decode node's store
    let blocks: Vec<std::sync::Arc<Vec<u8>>> = subset
        .iter()
        .map(|&pos| {
            cluster
                .node(decode_node)
                .peek(BlockKey::coded(object, pos))
                .ok()
                .flatten()
                .ok_or_else(|| anyhow::anyhow!("block {pos} missing on decode node"))
        })
        .collect::<anyhow::Result<_>>()?;
    let refs: Vec<&[u8]> = blocks.iter().map(|b| b.as_slice()).collect();
    let out = backend.gemm(width, &inv_u32, &refs)?;
    Ok((out, clock.now().saturating_sub(start)))
}

/// Bound alias so the classical twin shares the generic signature.
pub trait SliceOpsBound: SliceOps {}
impl<T: SliceOps> SliceOpsBound for T {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::cluster::ClusterSpec;
    use crate::coordinator::ingest::ingest_object;
    use crate::coordinator::pipeline::{archive_pipeline, PipelineJob};
    use crate::gf::Gf256;
    use crate::storage::{BlockKind, ReplicaPlacement};
    use std::sync::Arc;

    fn archived_cluster(
        object: ObjectId,
        n: usize,
        k: usize,
        block: usize,
    ) -> (Cluster, RapidRaidCode<Gf256>, ReplicaPlacement, Vec<Vec<u8>>, BackendHandle) {
        let cluster = Cluster::start(ClusterSpec::test(n));
        let placement = ReplicaPlacement::new(object, k, (0..n).collect()).unwrap();
        let blocks = ingest_object(&cluster, &placement, block).unwrap();
        let code = RapidRaidCode::<Gf256>::with_seed(n, k, 7).unwrap();
        let backend: BackendHandle = Arc::new(NativeBackend::new());
        let job = PipelineJob::from_code(&code, &placement, 4096, block).unwrap();
        archive_pipeline(&cluster, &backend, &job).unwrap();
        // drop the replicas: decode must work from coded blocks alone
        for (node, b) in placement.replica_map() {
            cluster.node(node).delete(BlockKey::source(object, b)).unwrap();
        }
        (cluster, code, placement, blocks, backend)
    }

    #[test]
    fn pipelined_decode_recovers_object() {
        let (cluster, code, placement, blocks, backend) =
            archived_cluster(ObjectId(1), 8, 4, 32 * 1024);
        let (rec, dt) =
            reconstruct_pipelined(&cluster, &code, &placement.chain, ObjectId(1), &backend, 4096)
                .unwrap();
        assert_eq!(rec, blocks);
        assert!(dt > Duration::ZERO);
        // a full source replica was restored in place (distributed)
        let mut restored = 0;
        for node in cluster.nodes() {
            for key in node.store.keys() {
                if key.object == ObjectId(1) && matches!(key.kind, BlockKind::Source) {
                    restored += 1;
                }
            }
        }
        assert_eq!(restored, 4);
    }

    #[test]
    fn pipelined_decode_with_failures_and_rotated_tails() {
        let (cluster, code, placement, blocks, backend) =
            archived_cluster(ObjectId(2), 8, 4, 16 * 1024);
        for pos in [1usize, 4, 6] {
            cluster.node(pos).delete(BlockKey::coded(ObjectId(2), pos)).unwrap();
        }
        let (rec, _) =
            reconstruct_pipelined(&cluster, &code, &placement.chain, ObjectId(2), &backend, 2048)
                .unwrap();
        assert_eq!(rec, blocks);
    }

    #[test]
    fn pipelined_matches_classical_decode() {
        let (cluster, code, placement, blocks, backend) =
            archived_cluster(ObjectId(3), 16, 11, 8 * 1024);
        let (a, _) =
            reconstruct_pipelined(&cluster, &code, &placement.chain, ObjectId(3), &backend, 2048)
                .unwrap();
        let (b, _) = reconstruct_classical_timed(
            &cluster,
            &code,
            &placement.chain,
            ObjectId(3),
            0,
            &backend,
            2048,
        )
        .unwrap();
        assert_eq!(a, blocks);
        assert_eq!(b, blocks);
    }

    #[test]
    fn pipelined_decode_faster_than_classical_on_slow_network() {
        // k-chain parallel decode vs k serialized downloads into one node.
        // Under the SimClock the comparison is purely the network model —
        // no 1-CPU host noise — so the paper's qualitative claim is checked
        // deterministically and the test runs in wall-clock milliseconds.
        let mut spec = ClusterSpec::test(16).sim();
        spec.bytes_per_sec = 25e6;
        let cluster = Cluster::start(spec);
        let object = ObjectId(4);
        let block = 1 << 20;
        let placement = ReplicaPlacement::new(object, 11, (0..16).collect()).unwrap();
        let blocks = ingest_object(&cluster, &placement, block).unwrap();
        let code = RapidRaidCode::<Gf256>::with_seed(16, 11, 7).unwrap();
        let backend: BackendHandle = Arc::new(NativeBackend::new());
        let job = PipelineJob::from_code(&code, &placement, 65536, block).unwrap();
        archive_pipeline(&cluster, &backend, &job).unwrap();

        // hard virtual budget: the k rotated chains must beat k serialized
        // block transfers by construction, whatever the jitter seed does
        let clock = cluster.clock().clone();
        let serial_bound = Duration::from_secs_f64(block as f64 / 25e6) * 11;
        let (a, t_pipe) = crate::util::assert_virtual_within(&clock, serial_bound, || {
            reconstruct_pipelined(&cluster, &code, &placement.chain, object, &backend, 65536)
                .unwrap()
        });
        let (b, t_cls) = reconstruct_classical_timed(
            &cluster,
            &code,
            &placement.chain,
            object,
            15, // a node without a selected coded block
            &backend,
            65536,
        )
        .unwrap();
        assert_eq!(a, blocks);
        assert_eq!(b, blocks);
        assert!(
            t_pipe < t_cls,
            "pipelined decode {t_pipe:?} not faster than classical {t_cls:?}"
        );
    }

    #[test]
    fn unrecoverable_reports_error() {
        let (cluster, code, placement, _blocks, backend) =
            archived_cluster(ObjectId(5), 8, 4, 4 * 1024);
        for pos in 0..5 {
            cluster.node(pos).delete(BlockKey::coded(ObjectId(5), pos)).unwrap();
        }
        assert!(reconstruct_pipelined(
            &cluster,
            &code,
            &placement.chain,
            ObjectId(5),
            &backend,
            1024
        )
        .is_err());
    }
}
