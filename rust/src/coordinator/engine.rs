//! The **PlanExecutor**: one execution engine for every archival strategy.
//!
//! Takes any [`ArchivalPlan`], lowers its edges onto rate-limited cluster
//! links, its steps onto node commands (`Upload`/`Receive`/`PipelineStage`/
//! `ClassicalEncode`), dispatches everything and collects completions.
//! All the mpsc/command plumbing the classical, pipelined, batch and
//! decode drivers used to hand-roll lives here exactly once.
//!
//! Concurrency is bounded at two levels: per node by the worker pool cap
//! (`ClusterSpec::max_workers`), and across plans by
//! [`PlanExecutor::run_many_bounded`], which runs at most `max_concurrent`
//! plans at a time off a shared work queue.
//!
//! Every step is wrapped in a [`Span`] (dispatch → step completion) so an
//! attached [`Recorder`] receives per-stage series — `<prefix>transfer`,
//! `<prefix>fold`, `<prefix>gemm`, `<prefix>store` — which the Fig. 4/5
//! harnesses turn into stage breakdowns. Spans of concurrent streaming
//! steps overlap by design: they measure critical-path occupancy, not
//! exclusive CPU time.
//!
//! Node selection is pluggable via the shape-aware
//! [`PlacementPolicy`](super::topology::PlacementPolicy) (re-exported here
//! under its historical name [`ChainPolicy`]): [`FifoPolicy`] keeps the
//! caller's order; [`CongestionAwarePolicy`] ranks candidate nodes by
//! current load (queued + running data-plane commands), CPU-meter backlog
//! and NIC rate; [`super::topology::LoadAwarePolicy`] additionally picks
//! the pipeline *shape* per object — and in its
//! [`adaptive`](super::topology::LoadAwarePolicy::adaptive) variant does
//! both from a plan-boundary [`LoadSnapshot`](crate::control::LoadSnapshot)
//! plus the analytic makespan predictor (the closed-loop control plane;
//! see [`crate::control`] and the wave-placing
//! [`run_batch_adaptive`](super::batch::run_batch_adaptive) driver).
//! Policies live in `coordinator::topology::policy`; the engine only
//! consumes them.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::backend::BackendHandle;
use crate::clock::{self, BusyToken, Clock};
use crate::cluster::node::{Command, ParityDest, SourceStream, StepStats};
use crate::cluster::{Cluster, NodeId, Rx, Tx};
use crate::metrics::{Recorder, Span};

use super::plan::{ArchivalPlan, GemmInput, GemmOutput, StepKind};
use super::topology::Topology;

pub use super::topology::policy::{
    select_chain, CongestionAwarePolicy, FifoPolicy, PlacementPolicy,
    PlacementPolicy as ChainPolicy, PolicyKind, TopologySelection,
};

/// Executes [`ArchivalPlan`]s against a cluster with one backend.
pub struct PlanExecutor<'a> {
    cluster: &'a Cluster,
    backend: BackendHandle,
    recorder: Option<&'a Recorder>,
    prefix: String,
    policy: Arc<dyn PlacementPolicy>,
}

impl<'a> PlanExecutor<'a> {
    /// Executor without span recording, FIFO chain policy.
    pub fn new(cluster: &'a Cluster, backend: BackendHandle) -> Self {
        Self {
            cluster,
            backend,
            recorder: None,
            prefix: String::new(),
            policy: Arc::new(FifoPolicy),
        }
    }

    /// Record per-step spans into `rec` under `<prefix><stage>` series.
    pub fn with_spans(mut self, rec: &'a Recorder, prefix: impl Into<String>) -> Self {
        self.recorder = Some(rec);
        self.prefix = prefix.into();
        self
    }

    /// Substitute the placement policy.
    pub fn with_policy(mut self, policy: Arc<dyn PlacementPolicy>) -> Self {
        self.policy = policy;
        self
    }

    /// Pick `n` chain nodes from `candidates` under this executor's policy.
    pub fn select_chain(&self, candidates: &[NodeId], n: usize) -> anyhow::Result<Vec<NodeId>> {
        select_chain(self.cluster, self.policy.as_ref(), candidates, n)
    }

    /// Pick a shape and its per-slot node binding for an n-position
    /// pipeline under this executor's policy (a policy that chooses shapes
    /// may override `requested`).
    pub fn select_topology(
        &self,
        candidates: &[NodeId],
        n: usize,
        requested: Topology,
    ) -> anyhow::Result<TopologySelection> {
        self.policy
            .select_topology(self.cluster, candidates, n, requested)
    }

    /// Execute one plan to completion; returns the wall-clock time from
    /// dispatch to the last step's completion.
    pub fn run(&self, plan: &ArchivalPlan) -> anyhow::Result<Duration> {
        plan.validate()?;
        // The cluster-dependent half of validation: node ids must exist
        // (validate() alone can't know the cluster size).
        for (id, step) in plan.steps.iter().enumerate() {
            anyhow::ensure!(
                step.node < self.cluster.len(),
                "plan step {id} targets node {} but the cluster has {} nodes",
                step.node,
                self.cluster.len()
            );
            anyhow::ensure!(
                !self.cluster.is_failed(step.node),
                "plan step {id} targets failed node {}",
                step.node
            );
        }
        let clock = self.cluster.clock();
        let start = clock.now();
        // Control-plane trace bracket: the critical-path analyzer carves
        // [PlanStart, PlanEnd] into per-slot compute/transfer/wait.
        crate::trace_emit!(
            clock,
            None::<NodeId>,
            crate::trace::EventKind::PlanStart {
                object: plan.object.0,
                nodes: plan.steps.iter().map(|s| s.node).collect(),
            }
        );

        // Lower every edge onto a cluster link.
        let mut txs: HashMap<(usize, usize), Tx> = HashMap::new();
        let mut rxs: HashMap<(usize, usize), Rx> = HashMap::new();
        for e in &plan.edges {
            let (tx, rx) = self
                .cluster
                .connect(plan.steps[e.from].node, plan.steps[e.to].node)?;
            txs.insert((e.from, e.from_port), tx);
            rxs.insert((e.to, e.to_port), rx);
        }

        // Lower every step onto one node command.
        struct InFlight<'r> {
            span: Span<'r>,
            wait: clock::Receiver<anyhow::Result<StepStats>>,
        }
        let mut inflight: Vec<InFlight<'_>> = Vec::with_capacity(plan.steps.len());
        let mut cmds: Vec<(crate::cluster::NodeId, Command)> =
            Vec::with_capacity(plan.steps.len());
        for (id, step) in plan.steps.iter().enumerate() {
            let (done, wait) = clock::channel(clock);
            let span = Span::start(
                clock,
                self.recorder,
                format!("{}{}", self.prefix, step.kind.stage()),
            );
            let cmd = match &step.kind {
                StepKind::Source { key } => Command::Upload {
                    key: *key,
                    tx: txs.remove(&(id, 0)).expect("validated: source bound"),
                    buf_bytes: plan.buf_bytes,
                    done,
                },
                StepKind::Store { key } => Command::Receive {
                    key: *key,
                    rx: rxs.remove(&(id, 0)).expect("validated: store bound"),
                    expect_bytes: plan.block_bytes,
                    done,
                },
                StepKind::Fold {
                    locals,
                    psi,
                    xi,
                    store,
                } => {
                    // Collect every bound out-port in port order: a chain
                    // stage has one downstream, a tree interior stage one
                    // per child, a tail none.
                    let mut ports: Vec<usize> = plan
                        .edges
                        .iter()
                        .filter(|e| e.from == id)
                        .map(|e| e.from_port)
                        .collect();
                    ports.sort_unstable();
                    let next: Vec<Tx> = ports
                        .into_iter()
                        .map(|p| txs.remove(&(id, p)).expect("validated: fold out bound"))
                        .collect();
                    Command::PipelineStage {
                        width: plan.width,
                        locals: locals.clone(),
                        psi: psi.clone(),
                        xi: xi.clone(),
                        prev: rxs.remove(&(id, 0)),
                        next,
                        out_key: *store,
                        buf_bytes: plan.buf_bytes,
                        backend: self.backend.clone(),
                        done,
                    }
                }
                StepKind::Gemm {
                    rows,
                    inputs,
                    outputs,
                } => {
                    let sources = inputs
                        .iter()
                        .enumerate()
                        .map(|(j, inp)| match inp {
                            GemmInput::Stream => SourceStream::Remote(
                                rxs.remove(&(id, j)).expect("validated: gemm input bound"),
                            ),
                            GemmInput::Local(key) => SourceStream::Local(*key),
                        })
                        .collect();
                    let dests = outputs
                        .iter()
                        .enumerate()
                        .map(|(i, out)| match out {
                            GemmOutput::Stream => ParityDest::Stream(
                                txs.remove(&(id, i)).expect("validated: gemm output bound"),
                            ),
                            GemmOutput::Store(key) => ParityDest::Store(*key),
                        })
                        .collect();
                    Command::ClassicalEncode {
                        width: plan.width,
                        sources,
                        parity_rows: rows.clone(),
                        dests,
                        buf_bytes: plan.buf_bytes,
                        block_bytes: plan.block_bytes,
                        backend: self.backend.clone(),
                        done,
                    }
                }
            };
            cmds.push((step.node, cmd));
            inflight.push(InFlight { span, wait });
        }

        // Dispatch everything, then collect completions from this thread,
        // in step order — no collector threads (the old engine burned one
        // OS thread per step, which a 2,000-node multiplexed run cannot
        // afford). Two invariants make single-threaded collection exact:
        //
        //  * The engine binds itself as a clock participant for the whole
        //    dispatch+collect phase, so virtual time is pinned while
        //    commands are lowered (no node can race ahead mid-dispatch —
        //    the job the collectors' pre-dispatch busy tokens used to do),
        //    and the clock-channel recv protocol releases the slot while
        //    parked on each completion channel.
        //  * Every span closes at its worker's self-stamped completion tick
        //    ([`StepStats::finished_at`]), not at collection time, so the
        //    recorded stage times don't depend on when this thread gets
        //    around to reading a result that was sent while it was parked
        //    on an earlier step — and are identical across the threaded and
        //    multiplexed runtimes.
        //
        // Broken links propagate failure to every dependent step, so every
        // channel completes (or disconnects) even on error; a dispatch
        // error is reported first, then the first step error in step order,
        // always after every step has been drained.
        let _engine = BusyToken::new(clock).bind();
        let dispatch: anyhow::Result<()> = cmds
            .into_iter()
            .try_for_each(|(node, cmd)| self.cluster.node(node).send(cmd));
        let mut end = start;
        let mut step_err: Option<anyhow::Error> = None;
        for (i, f) in inflight.into_iter().enumerate() {
            let res = f
                .wait
                .recv()
                .unwrap_or_else(|_| Err(anyhow::anyhow!("plan step {i} worker vanished")));
            match res {
                Ok(stats) => {
                    end = end.max(stats.finished_at);
                    // The worker reports its charged compute ticks; the
                    // span splits them out from transfer occupancy.
                    f.span.finish_split_at(stats.finished_at, stats.compute);
                }
                Err(e) => {
                    // no completion stamp to trust: close at the current tick
                    f.span.finish_split(Duration::ZERO);
                    if step_err.is_none() {
                        step_err = Some(e);
                    }
                }
            }
        }
        dispatch?;
        if let Some(e) = step_err {
            return Err(e);
        }
        let makespan = end.saturating_sub(start);
        // Only successful plans close their bracket; a failed plan leaves
        // an unmatched PlanStart, which the analyzer skips. Emitted at the
        // last step's completion tick (time may already have moved on).
        crate::trace_emit!(
            @at end,
            clock,
            None::<NodeId>,
            crate::trace::EventKind::PlanEnd {
                object: plan.object.0,
                makespan
            }
        );
        Ok(makespan)
    }

    /// Execute all plans concurrently (one coordinator thread each) and
    /// return per-plan times in input order.
    pub fn run_many(&self, plans: &[ArchivalPlan]) -> anyhow::Result<Vec<Duration>> {
        self.run_many_bounded(plans, plans.len().max(1))
    }

    /// Execute plans with at most `max_concurrent` running at a time
    /// (FIFO over the input order); the first error (in input order) fails
    /// the whole call after every plan has finished.
    pub fn run_many_bounded(
        &self,
        plans: &[ArchivalPlan],
        max_concurrent: usize,
    ) -> anyhow::Result<Vec<Duration>> {
        self.run_many_results(plans, max_concurrent)?
            .into_iter()
            .collect()
    }

    /// Like [`PlanExecutor::run_many_bounded`], but reports every plan's
    /// individual outcome instead of collapsing to the first error — for
    /// callers that must commit the successes of a partially failed batch
    /// (e.g. the repair scheduler: one crashed repair must not discard the
    /// blocks the other repairs already regenerated). The outer error only
    /// covers invalid arguments.
    pub fn run_many_results(
        &self,
        plans: &[ArchivalPlan],
        max_concurrent: usize,
    ) -> anyhow::Result<Vec<anyhow::Result<Duration>>> {
        anyhow::ensure!(max_concurrent >= 1, "need at least one plan worker");
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<anyhow::Result<Duration>>>> =
            plans.iter().map(|_| Mutex::new(None)).collect();
        std::thread::scope(|scope| {
            for _ in 0..max_concurrent.min(plans.len()) {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= plans.len() {
                        break;
                    }
                    *slots[i].lock().unwrap() = Some(self.run(&plans[i]));
                });
            }
        });
        Ok(slots
            .into_iter()
            .map(|s| {
                s.into_inner()
                    .expect("plan worker panicked")
                    .expect("every slot filled")
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{NativeBackend, Width};
    use crate::cluster::{ClusterSpec, CongestionSpec};
    use crate::storage::{BlockKey, ObjectId};

    fn native() -> BackendHandle {
        Arc::new(NativeBackend::new())
    }

    #[test]
    fn transfer_plan_moves_block_and_records_spans() {
        let cluster = Cluster::start(ClusterSpec::test(2));
        let object = ObjectId(1);
        let key = BlockKey::source(object, 0);
        let data: Vec<u8> = (0..32_768u32).map(|i| (i * 11) as u8).collect();
        cluster.node(0).put(key, data.clone()).unwrap();

        let mut plan = ArchivalPlan::new(object, Width::W8, 4096, data.len());
        let s = plan.add_step(0, StepKind::Source { key });
        let t = plan.add_step(1, StepKind::Store { key });
        plan.connect(s, 0, t, 0);

        let rec = Recorder::new();
        let exec = PlanExecutor::new(&cluster, native()).with_spans(&rec, "x/");
        let dt = exec.run(&plan).unwrap();
        assert!(dt > Duration::ZERO);
        assert_eq!(*cluster.node(1).peek(key).unwrap().unwrap(), data);
        assert_eq!(rec.candle("x/transfer").unwrap().samples.len(), 1);
        assert_eq!(rec.candle("x/store").unwrap().samples.len(), 1);
    }

    #[test]
    fn empty_plan_runs_instantly() {
        let cluster = Cluster::start(ClusterSpec::test(1));
        let plan = ArchivalPlan::new(ObjectId(9), Width::W8, 1024, 1024);
        let exec = PlanExecutor::new(&cluster, native());
        exec.run(&plan).unwrap();
    }

    #[test]
    fn plan_targeting_missing_node_errors_cleanly() {
        let cluster = Cluster::start(ClusterSpec::test(2));
        let mut plan = ArchivalPlan::new(ObjectId(3), Width::W8, 1024, 2048);
        plan.add_step(
            5,
            StepKind::Fold {
                locals: vec![BlockKey::source(ObjectId(3), 0)],
                psi: vec![1],
                xi: vec![1],
                store: None,
            },
        );
        let exec = PlanExecutor::new(&cluster, native());
        let err = exec.run(&plan).unwrap_err();
        assert!(err.to_string().contains("node 5"), "{err}");
    }

    #[test]
    fn failing_step_reports_error() {
        // Upload of a block that was never ingested must fail the plan and
        // fail it cleanly (the paired Store errors out too, not hangs).
        let cluster = Cluster::start(ClusterSpec::test(2));
        let object = ObjectId(404);
        let key = BlockKey::source(object, 0);
        let mut plan = ArchivalPlan::new(object, Width::W8, 1024, 4096);
        let s = plan.add_step(0, StepKind::Source { key });
        let t = plan.add_step(1, StepKind::Store { key });
        plan.connect(s, 0, t, 0);
        let exec = PlanExecutor::new(&cluster, native());
        assert!(exec.run(&plan).is_err());
    }

    #[test]
    fn run_many_bounded_completes_all_in_order() {
        let cluster = Cluster::start(ClusterSpec::test(4));
        let object = ObjectId(5);
        let data: Vec<u8> = (0..8192u32).map(|i| i as u8).collect();
        let mut plans = Vec::new();
        for i in 0..3usize {
            let key = BlockKey::source(object, i);
            cluster.node(0).put(key, data.clone()).unwrap();
            let mut plan = ArchivalPlan::new(object, Width::W8, 1024, data.len());
            let s = plan.add_step(0, StepKind::Source { key });
            let t = plan.add_step(1 + i % 3, StepKind::Store { key });
            plan.connect(s, 0, t, 0);
            plans.push(plan);
        }
        let exec = PlanExecutor::new(&cluster, native());
        let times = exec.run_many_bounded(&plans, 2).unwrap();
        assert_eq!(times.len(), 3);
        for i in 0..3usize {
            assert!(cluster
                .node(1 + i % 3)
                .peek(BlockKey::source(object, i))
                .unwrap()
                .is_some());
        }
    }

    #[test]
    fn executor_select_topology_honors_policy_shape_choice() {
        // The executor-level surface: a load-aware policy on a cluster
        // with one clamped node must override the requested chain with a
        // tree and bind all requested slots.
        let cluster = Cluster::start(ClusterSpec::test(8));
        cluster.congest(
            5,
            &CongestionSpec {
                bytes_per_sec: 1e8,
                extra_latency: Duration::ZERO,
                jitter: Duration::ZERO,
            },
        );
        let exec = PlanExecutor::new(&cluster, native())
            .with_policy(Arc::new(crate::coordinator::topology::LoadAwarePolicy::default()));
        let sel = exec
            .select_topology(&(0..8).collect::<Vec<_>>(), 8, Topology::Chain)
            .unwrap();
        assert_eq!(sel.topology, Topology::Tree { fanout: 2 });
        assert_eq!(sel.nodes.len(), 8);
        // and the FIFO default keeps the request
        let exec = PlanExecutor::new(&cluster, native());
        let sel = exec
            .select_topology(&(0..8).collect::<Vec<_>>(), 8, Topology::Chain)
            .unwrap();
        assert_eq!(sel.topology, Topology::Chain);
    }

    #[test]
    fn congestion_aware_policy_ranks_congested_node_last() {
        let cluster = Cluster::start(ClusterSpec::test(3));
        cluster.congest(1, &CongestionSpec::mild());
        let ranked = CongestionAwarePolicy.rank(&cluster, &[0, 1, 2]);
        assert_eq!(*ranked.last().unwrap(), 1, "{ranked:?}");

        let chain = select_chain(&cluster, &CongestionAwarePolicy, &[0, 1, 2], 2).unwrap();
        assert!(!chain.contains(&1), "{chain:?}");
        assert!(select_chain(&cluster, &FifoPolicy, &[0, 1], 3).is_err());
    }
}
