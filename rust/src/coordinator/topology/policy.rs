//! Shape-aware placement: rank cluster nodes and bind them to topology
//! slots.
//!
//! [`PlacementPolicy`] generalizes the PR 1 `ChainPolicy`: `rank` orders
//! candidate nodes (the legacy surface ingest and the repair scheduler's
//! newcomer selection still use directly), and `select_topology` maps the
//! ranking onto a whole shape — interior slots pace their entire subtree,
//! so [`assign_slots`] hands the best-ranked nodes to the heaviest slots
//! (largest subtree first) and pushes the worst nodes to leaves, where a
//! straggler delays only itself. For a chain every slot weight is
//! distinct, so the binding degenerates to the PR 1 behavior exactly.
//!
//! [`FifoPolicy`] and [`CongestionAwarePolicy`] keep their names and
//! ranking semantics (the latter now also reads each node's
//! [`CpuMeter`](crate::resources::CpuMeter) backlog, the compute twin of
//! the NIC load signal); [`LoadAwarePolicy`] additionally *chooses the
//! shape* per object from the live congestion/CPU state.

use std::sync::Arc;

use crate::clock::Tick;
use crate::cluster::{Cluster, NodeId};
use crate::codes::TopologyShape;
use crate::control::{
    candidate_shapes, Adaptation, Flow, LoadSnapshot, REF_BLOCK_BYTES, REF_BUF_BYTES,
};

use super::Topology;

/// Ranks candidate nodes and binds them to pipeline-topology slots.
pub trait PlacementPolicy: Send + Sync {
    /// Rank `candidates` (a permutation of the input), best first.
    fn rank(&self, cluster: &Cluster, candidates: &[NodeId]) -> Vec<NodeId>;

    /// Choose the pipeline shape for an n-position archival over `ranked`
    /// (this policy's own ranking of the candidates, best first — computed
    /// once by [`PlacementPolicy::select_topology`]); the default keeps
    /// the caller's request, [`LoadAwarePolicy`] overrides it.
    fn choose_topology(
        &self,
        _cluster: &Cluster,
        _ranked: &[NodeId],
        _n: usize,
        requested: Topology,
    ) -> Topology {
        requested
    }

    /// Pick nodes for every slot of the (possibly policy-overridden)
    /// topology: the n most preferred candidates, heaviest slots first.
    /// Ranks exactly once; the ranking feeds both the shape choice and
    /// the slot binding.
    fn select_topology(
        &self,
        cluster: &Cluster,
        candidates: &[NodeId],
        n: usize,
        requested: Topology,
    ) -> anyhow::Result<TopologySelection> {
        anyhow::ensure!(
            candidates.len() >= n,
            "need {n} pipeline nodes, only {} candidates",
            candidates.len()
        );
        let ranked = self.rank(cluster, candidates);
        let topology = self.choose_topology(cluster, &ranked, n, requested);
        let shape = topology.shape(n)?;
        Ok(TopologySelection {
            topology,
            nodes: assign_slots(&shape, &ranked[..n]),
        })
    }
}

/// A chosen shape plus its node binding (`nodes[i]` runs slot i).
#[derive(Clone, Debug)]
pub struct TopologySelection {
    /// The shape the policy settled on.
    pub topology: Topology,
    /// One cluster node per topology slot.
    pub nodes: Vec<NodeId>,
}

/// Bind ranked nodes (best first) to shape slots, heaviest slot first.
/// A slot's weight is its subtree size — the number of positions a slow
/// node there would pace — with index order as the deterministic
/// tie-break, so leaves collect the worst-ranked nodes.
pub fn assign_slots(shape: &TopologyShape, ranked: &[NodeId]) -> Vec<NodeId> {
    let n = shape.n();
    assert_eq!(ranked.len(), n, "need exactly one node per slot");
    let mut weight = vec![1usize; n];
    for i in (1..n).rev() {
        weight[shape.parent(i).expect("non-root has a parent")] += weight[i];
    }
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by_key(|&i| (std::cmp::Reverse(weight[i]), i));
    let mut nodes = vec![0usize; n];
    for (rank, &slot) in order.iter().enumerate() {
        nodes[slot] = ranked[rank];
    }
    nodes
}

/// Keep the caller's order (the paper's fixed rotated chains).
pub struct FifoPolicy;

impl PlacementPolicy for FifoPolicy {
    fn rank(&self, _cluster: &Cluster, candidates: &[NodeId]) -> Vec<NodeId> {
        candidates.to_vec()
    }
}

/// Prefer idle, fast nodes: ascending in-flight command count, then
/// ascending CPU-meter backlog (queued compute reservations), then
/// descending effective NIC rate (min of up/down — a congested node's
/// clamped direction is what throttles a pipeline hop).
pub struct CongestionAwarePolicy;

impl PlacementPolicy for CongestionAwarePolicy {
    fn rank(&self, cluster: &Cluster, candidates: &[NodeId]) -> Vec<NodeId> {
        let mut scored: Vec<(usize, Tick, f64, NodeId)> = candidates
            .iter()
            .map(|&id| {
                let n = cluster.node(id);
                (
                    n.inflight(),
                    n.cpu.backlog(),
                    n.up.rate().min(n.down.rate()),
                    id,
                )
            })
            .collect();
        scored.sort_by(|a, b| {
            a.0.cmp(&b.0)
                .then(a.1.cmp(&b.1))
                .then(b.2.partial_cmp(&a.2).unwrap_or(std::cmp::Ordering::Equal))
        });
        scored.into_iter().map(|(_, _, _, id)| id).collect()
    }
}

/// Picks the shape *and* the placement per object from the live cluster
/// state: an idle pool with uniform NIC rates keeps the traffic-optimal
/// [`Topology::Chain`]; visible CPU backlog or a wide rate spread switches
/// to a tree (stragglers land on leaf slots where they pace only
/// themselves); a moderate spread takes the hybrid middle ground.
///
/// With [`Adaptation::On`] (see [`LoadAwarePolicy::adaptive`]) the static
/// threshold heuristic is replaced by the control plane's closed loop: a
/// plan-boundary [`LoadSnapshot`] ranks the candidates by measured
/// CPU/NIC backlog, in-flight load and priced GF throughput, and the
/// analytic predictor picks the candidate shape with the smallest
/// predicted makespan ([`LoadSnapshot::choose_topology`]). `Off` (the
/// default) is bit-for-bit the static behavior — no snapshot is taken.
pub struct LoadAwarePolicy {
    /// Fanout used for the tree/hybrid shapes this policy picks.
    pub tree_fanout: usize,
    /// Gate for the snapshot-predicted closed loop (default [`Adaptation::Off`]).
    pub adaptation: Adaptation,
}

impl Default for LoadAwarePolicy {
    fn default() -> Self {
        Self {
            tree_fanout: 2,
            adaptation: Adaptation::Off,
        }
    }
}

impl LoadAwarePolicy {
    /// The closed-loop variant: snapshot-ranked placement and
    /// predicted-makespan shape choice ([`Adaptation::On`]).
    pub fn adaptive() -> Self {
        Self {
            adaptation: Adaptation::On,
            ..Self::default()
        }
    }
}

impl PlacementPolicy for LoadAwarePolicy {
    fn rank(&self, cluster: &Cluster, candidates: &[NodeId]) -> Vec<NodeId> {
        if self.adaptation.is_on() {
            LoadSnapshot::take(cluster).rank(candidates)
        } else {
            CongestionAwarePolicy.rank(cluster, candidates)
        }
    }

    fn choose_topology(
        &self,
        cluster: &Cluster,
        ranked: &[NodeId],
        n: usize,
        _requested: Topology,
    ) -> Topology {
        if self.adaptation.is_on() {
            // Closed loop: predict each candidate shape's makespan from a
            // fresh plan-boundary snapshot (same quiescent state `rank`
            // read — nothing dispatched in between) and keep the argmin.
            let snap = LoadSnapshot::take(cluster);
            let shapes = candidate_shapes(n, self.tree_fanout);
            if let Ok((topology, _, _)) = snap.choose_topology(
                ranked,
                n,
                &shapes,
                Flow::Diffusion,
                REF_BLOCK_BYTES,
                REF_BUF_BYTES,
            ) {
                return topology;
            }
            // degenerate pools fall through to the static heuristic
        }
        // Signals over the n best-ranked candidates (the nodes the shape
        // will actually run on), all deterministic reads of cluster state.
        let pool = &ranked[..n.min(ranked.len())];
        let mut inflight_total = 0usize;
        let mut cpu_backlogged = false;
        let mut min_rate = f64::INFINITY;
        let mut max_rate: f64 = 0.0;
        for &id in pool {
            let node = cluster.node(id);
            inflight_total += node.inflight();
            cpu_backlogged |= node.cpu.backlog() > Tick::ZERO;
            let rate = node.up.rate().min(node.down.rate());
            min_rate = min_rate.min(rate);
            max_rate = max_rate.max(rate);
        }
        let spread = if min_rate > 0.0 { max_rate / min_rate } else { f64::INFINITY };
        let heavily_loaded = cpu_backlogged || inflight_total >= pool.len();
        if !heavily_loaded && inflight_total == 0 && spread <= 1.5 {
            Topology::Chain
        } else if heavily_loaded || spread > 4.0 {
            Topology::Tree {
                fanout: self.tree_fanout,
            }
        } else {
            Topology::Hybrid {
                chain_prefix: n / 2,
                tree_fanout: self.tree_fanout,
            }
        }
    }
}

/// Value-level selector for the built-in placement policies, for places
/// that carry policy choice as data (long-run configs, the `rapidraid
/// sweep` grid) rather than as a trait object.
#[derive(Copy, Clone, PartialEq, Eq, Debug)]
pub enum PolicyKind {
    /// Keep the caller's order ([`FifoPolicy`]).
    Fifo,
    /// Load/CPU/NIC-aware ranking ([`CongestionAwarePolicy`]).
    CongestionAware,
    /// Shape-choosing placement ([`LoadAwarePolicy`], fanout 2).
    LoadAware,
    /// The closed-loop control plane ([`LoadAwarePolicy::adaptive`]):
    /// snapshot-ranked placement, predicted-makespan shape choice, and —
    /// where the consumer supports it — straggler-aware repair sourcing.
    Adaptive,
}

impl PolicyKind {
    /// Instantiate the selected policy.
    pub fn policy(&self) -> Arc<dyn PlacementPolicy> {
        match self {
            PolicyKind::Fifo => Arc::new(FifoPolicy),
            PolicyKind::CongestionAware => Arc::new(CongestionAwarePolicy),
            PolicyKind::LoadAware => Arc::new(LoadAwarePolicy::default()),
            PolicyKind::Adaptive => Arc::new(LoadAwarePolicy::adaptive()),
        }
    }

    /// The adaptation gate this policy choice implies for consumers that
    /// carry one (the repair scheduler, the adaptive batch driver).
    pub fn adaptation(&self) -> Adaptation {
        match self {
            PolicyKind::Adaptive => Adaptation::On,
            _ => Adaptation::Off,
        }
    }

    /// Short label for report tables.
    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::Fifo => "fifo",
            PolicyKind::CongestionAware => "congestion-aware",
            PolicyKind::LoadAware => "load-aware",
            PolicyKind::Adaptive => "adaptive",
        }
    }
}

/// Pick the `n` most preferred of `candidates` under `policy`, bound as a
/// chain (the legacy selection surface — replica placement and newcomer
/// ranking stay shape-agnostic).
pub fn select_chain(
    cluster: &Cluster,
    policy: &dyn PlacementPolicy,
    candidates: &[NodeId],
    n: usize,
) -> anyhow::Result<Vec<NodeId>> {
    anyhow::ensure!(
        candidates.len() >= n,
        "need {n} chain nodes, only {} candidates",
        candidates.len()
    );
    let mut ranked = policy.rank(cluster, candidates);
    ranked.truncate(n);
    Ok(ranked)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterSpec, CongestionSpec};

    #[test]
    fn assign_slots_chain_keeps_rank_order() {
        let shape = Topology::Chain.shape(4).unwrap();
        assert_eq!(assign_slots(&shape, &[9, 7, 5, 3]), vec![9, 7, 5, 3]);
    }

    #[test]
    fn assign_slots_tree_puts_best_nodes_interior() {
        // tree:2 over 7: weights [7,3,3,1,1,1,1] — slots 0,1,2 are
        // interior, leaves 3..6 get the tail of the ranking
        let shape = Topology::Tree { fanout: 2 }.shape(7).unwrap();
        let nodes = assign_slots(&shape, &[10, 11, 12, 13, 14, 15, 16]);
        assert_eq!(nodes[0], 10, "root gets the best-ranked node");
        assert_eq!(&nodes[1..3], &[11, 12], "interior slots next");
        assert_eq!(&nodes[3..], &[13, 14, 15, 16], "leaves take the rest");
    }

    #[test]
    fn load_aware_picks_chain_on_idle_uniform_cluster() {
        let cluster = Cluster::start(ClusterSpec::test(8));
        let policy = LoadAwarePolicy::default();
        let sel = policy
            .select_topology(&cluster, &(0..8).collect::<Vec<_>>(), 8, Topology::Chain)
            .unwrap();
        assert_eq!(sel.topology, Topology::Chain);
        assert_eq!(sel.nodes.len(), 8);
    }

    #[test]
    fn load_aware_switches_shape_under_rate_spread() {
        let cluster = Cluster::start(ClusterSpec::test(8));
        // one severely clamped node: spread > 4 ⇒ tree
        cluster.congest(
            3,
            &CongestionSpec {
                bytes_per_sec: 1e8, // 10x below the 1e9 test preset
                extra_latency: std::time::Duration::ZERO,
                jitter: std::time::Duration::ZERO,
            },
        );
        let policy = LoadAwarePolicy::default();
        let sel = policy
            .select_topology(&cluster, &(0..8).collect::<Vec<_>>(), 8, Topology::Chain)
            .unwrap();
        assert_eq!(sel.topology, Topology::Tree { fanout: 2 });
        // the clamped node ranks last, i.e. lands on a leaf slot
        let shape = sel.topology.shape(8).unwrap();
        let slot_of_congested = sel.nodes.iter().position(|&n| n == 3).unwrap();
        assert!(
            shape.children()[slot_of_congested].is_empty(),
            "straggler must sit on a leaf: {:?}",
            sel.nodes
        );
    }

    #[test]
    fn adaptive_policy_keeps_chain_on_idle_uniform_cluster() {
        let cluster = Cluster::start(ClusterSpec::test(8).sim());
        let policy = LoadAwarePolicy::adaptive();
        let sel = policy
            .select_topology(&cluster, &(0..8).collect::<Vec<_>>(), 8, Topology::Chain)
            .unwrap();
        assert_eq!(sel.topology, Topology::Chain);
        assert_eq!(sel.nodes, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn adaptive_policy_routes_around_stragglers_given_spare_nodes() {
        // 8-slot pipeline over a 12-node pool with two clamped nodes: the
        // snapshot ranking must keep both stragglers out of the selection
        // entirely (the static heuristic can only re-shape, not avoid).
        let cluster = Cluster::start(ClusterSpec::test(12).sim());
        for id in [2, 5] {
            cluster.congest(
                id,
                &CongestionSpec {
                    bytes_per_sec: 1e7,
                    extra_latency: std::time::Duration::ZERO,
                    jitter: std::time::Duration::ZERO,
                },
            );
        }
        let policy = LoadAwarePolicy::adaptive();
        let sel = policy
            .select_topology(&cluster, &(0..12).collect::<Vec<_>>(), 8, Topology::Chain)
            .unwrap();
        assert!(
            !sel.nodes.contains(&2) && !sel.nodes.contains(&5),
            "stragglers must not be placed: {:?}",
            sel.nodes
        );
    }

    #[test]
    fn off_mode_is_the_static_heuristic() {
        // Adaptation::Off must produce exactly the pre-control-plane
        // selection — same ranking, same shape — on any cluster state.
        let cluster = Cluster::start(ClusterSpec::test(8).sim());
        cluster.congest(
            3,
            &CongestionSpec {
                bytes_per_sec: 1e8,
                extra_latency: std::time::Duration::ZERO,
                jitter: std::time::Duration::ZERO,
            },
        );
        let off = LoadAwarePolicy::default();
        assert_eq!(off.adaptation, Adaptation::Off);
        let candidates: Vec<NodeId> = (0..8).collect();
        let sel = off
            .select_topology(&cluster, &candidates, 8, Topology::Chain)
            .unwrap();
        // the static heuristic's documented outputs, unchanged
        assert_eq!(sel.topology, Topology::Tree { fanout: 2 });
        assert_eq!(
            off.rank(&cluster, &candidates),
            CongestionAwarePolicy.rank(&cluster, &candidates),
            "Off-mode ranking must be the CongestionAware ranking"
        );
    }

    #[test]
    fn select_chain_needs_enough_candidates() {
        let cluster = Cluster::start(ClusterSpec::test(3));
        assert!(select_chain(&cluster, &FifoPolicy, &[0, 1], 3).is_err());
        let chain = select_chain(&cluster, &FifoPolicy, &[2, 0, 1], 2).unwrap();
        assert_eq!(chain, vec![2, 0]);
    }
}
