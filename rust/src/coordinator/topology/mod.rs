//! First-class pipeline **topologies**: the shape of an encoding (or
//! repair) pipeline as data, decoupled from the coefficient schedule that
//! runs over it.
//!
//! The paper's §VII notes the chain is only one point of a free design
//! axis; Li et al.'s repair pipelining shows tree/hybrid layouts dominate
//! chains when links or CPUs are heterogeneous. A [`Topology`] names a
//! shape family, [`Topology::shape`] expands it into the ordered
//! [`TopologyShape`] the codes layer composes coefficients over, and
//! [`lower`] turns shape + schedule + node binding into an
//! [`crate::coordinator::plan::ArchivalPlan`] the one shared executor
//! runs. [`policy`] generalizes chain selection into shape-aware
//! placement: interior slots (big subtrees) pace everything beneath them,
//! so they get the best-ranked nodes.
//!
//! Shape intuition (what each family trades):
//!
//! * [`Topology::Chain`] — traffic-optimal (every node uplinks one block)
//!   but the critical path crosses all n stages: one slow stage paces the
//!   whole pipeline, and the hop tail grows linearly in n.
//! * [`Topology::Tree`] — depth log_f(n): a slow node paces only its own
//!   subtree and the hop tail shrinks, at the price of interior uplinks
//!   carrying `fanout` copies of the stream.
//! * [`Topology::Hybrid`] — a chain prefix feeding a tree: tunes between
//!   the two (the prefix keeps uplinks single, the tree caps the tail).

pub mod lower;
pub mod policy;

pub use lower::{lower_aggregate, lower_encode};
pub use policy::{
    assign_slots, select_chain, CongestionAwarePolicy, FifoPolicy, LoadAwarePolicy,
    PlacementPolicy, PolicyKind, TopologySelection,
};

use crate::codes::TopologyShape;

/// A pipeline shape family, expanded to a concrete [`TopologyShape`] per
/// code length n.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Topology {
    /// The paper's linear chain (position i feeds position i+1).
    Chain,
    /// Heap-ordered tree: position i's parent is `(i-1)/fanout`, so every
    /// interior position feeds up to `fanout` subtrees.
    Tree {
        /// Children per interior position (≥ 1; 1 degenerates to a chain).
        fanout: usize,
    },
    /// A chain head feeding a heap-ordered tree: positions
    /// `0..=chain_prefix` form the chain (the tree's root *is* position
    /// `chain_prefix`), positions beyond hang off it with `tree_fanout`
    /// children each.
    Hybrid {
        /// Position of the tree root, i.e. the number of chain *hops*
        /// before branching starts. `0` degenerates to the pure tree,
        /// anything ≥ n−1 to the pure chain.
        chain_prefix: usize,
        /// Fanout of the trailing tree segment (≥ 1).
        tree_fanout: usize,
    },
}

impl Topology {
    /// Parameter sanity (independent of n).
    pub fn validate(&self) -> anyhow::Result<()> {
        match *self {
            Topology::Chain => Ok(()),
            Topology::Tree { fanout } => {
                anyhow::ensure!(fanout >= 1, "tree fanout must be >= 1");
                Ok(())
            }
            Topology::Hybrid { tree_fanout, .. } => {
                anyhow::ensure!(tree_fanout >= 1, "hybrid tree fanout must be >= 1");
                Ok(())
            }
        }
    }

    /// Expand to the ordered shape over `n` positions.
    pub fn shape(&self, n: usize) -> anyhow::Result<TopologyShape> {
        self.validate()?;
        anyhow::ensure!(n >= 1, "topology over zero positions");
        let parents = (0..n)
            .map(|i| {
                if i == 0 {
                    return None;
                }
                Some(match *self {
                    Topology::Chain => i - 1,
                    Topology::Tree { fanout } => (i - 1) / fanout,
                    Topology::Hybrid {
                        chain_prefix,
                        tree_fanout,
                    } => {
                        if i <= chain_prefix {
                            i - 1
                        } else {
                            chain_prefix + (i - chain_prefix - 1) / tree_fanout
                        }
                    }
                })
            })
            .collect();
        TopologyShape::new(parents)
    }

    /// Parse a report/CLI label: `chain`, `tree:<fanout>`,
    /// `hybrid:<prefix>:<fanout>`.
    pub fn parse(s: &str) -> anyhow::Result<Topology> {
        let mut parts = s.split(':');
        let topo = match parts.next() {
            Some("chain") => Topology::Chain,
            Some("tree") => Topology::Tree {
                fanout: parts
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("tree needs a fanout: tree:<f>"))?
                    .parse()?,
            },
            Some("hybrid") => Topology::Hybrid {
                chain_prefix: parts
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("hybrid needs hybrid:<prefix>:<fanout>"))?
                    .parse()?,
                tree_fanout: parts
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("hybrid needs hybrid:<prefix>:<fanout>"))?
                    .parse()?,
            },
            other => anyhow::bail!("unknown topology {other:?} (chain | tree:<f> | hybrid:<p>:<f>)"),
        };
        anyhow::ensure!(parts.next().is_none(), "trailing topology parameters in {s:?}");
        topo.validate()?;
        Ok(topo)
    }
}

impl std::fmt::Display for Topology {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            Topology::Chain => write!(f, "chain"),
            Topology::Tree { fanout } => write!(f, "tree:{fanout}"),
            Topology::Hybrid {
                chain_prefix,
                tree_fanout,
            } => write!(f, "hybrid:{chain_prefix}:{tree_fanout}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_shape_is_a_chain() {
        let s = Topology::Chain.shape(5).unwrap();
        assert!(s.is_chain());
        assert_eq!(s.depth(), 4);
    }

    #[test]
    fn tree_shape_is_heap_ordered() {
        let s = Topology::Tree { fanout: 2 }.shape(7).unwrap();
        assert_eq!(
            s.parents(),
            &[None, Some(0), Some(0), Some(1), Some(1), Some(2), Some(2)]
        );
        assert_eq!(s.depth(), 2);
        assert_eq!(s.max_fanout(), 2);
        // fanout 1 degenerates to the chain
        assert!(Topology::Tree { fanout: 1 }.shape(5).unwrap().is_chain());
    }

    #[test]
    fn hybrid_shape_chains_then_branches() {
        let s = Topology::Hybrid {
            chain_prefix: 2,
            tree_fanout: 2,
        }
        .shape(7)
        .unwrap();
        assert_eq!(
            s.parents(),
            &[None, Some(0), Some(1), Some(2), Some(2), Some(3), Some(3)]
        );
        // prefix 0 is the pure tree; a prefix >= n-1 is the pure chain
        assert_eq!(
            Topology::Hybrid { chain_prefix: 0, tree_fanout: 2 }.shape(7).unwrap(),
            Topology::Tree { fanout: 2 }.shape(7).unwrap()
        );
        assert!(Topology::Hybrid { chain_prefix: 9, tree_fanout: 2 }
            .shape(7)
            .unwrap()
            .is_chain());
    }

    #[test]
    fn validation_rejects_zero_fanout() {
        assert!(Topology::Tree { fanout: 0 }.validate().is_err());
        assert!(Topology::Hybrid { chain_prefix: 1, tree_fanout: 0 }.shape(4).is_err());
        assert!(Topology::Chain.shape(0).is_err());
    }

    #[test]
    fn display_parse_roundtrip() {
        for t in [
            Topology::Chain,
            Topology::Tree { fanout: 3 },
            Topology::Hybrid { chain_prefix: 4, tree_fanout: 2 },
        ] {
            assert_eq!(Topology::parse(&t.to_string()).unwrap(), t);
        }
        assert!(Topology::parse("ring").is_err());
        assert!(Topology::parse("tree").is_err());
        assert!(Topology::parse("tree:0").is_err());
        assert!(Topology::parse("hybrid:1:2:3").is_err());
    }
}
