//! Lower a topology-shaped pipeline onto the [`ArchivalPlan`] IR.
//!
//! Two dataflow directions share every shape:
//!
//! * [`lower_encode`] — **diffusion** (archival): the running ψ-combination
//!   flows root→leaves; every position is one [`StepKind::Fold`] that
//!   stores its codeword block and fans the same `x_out` stream to each
//!   child via the fold's multi-port fan-out (compute once, one frame copy
//!   per extra child).
//! * [`lower_aggregate`] — **aggregation** (repair): ψ-weighted partials
//!   flow leaves→root; a slot with one child is a `Fold`, a slot merging
//!   several children is a 1-row [`StepKind::Gemm`] (`[1,…,1,ψ]` over the
//!   child streams plus its local block), and the root's completed sum
//!   lands on the newcomer — in place when the root slot *is* the
//!   newcomer, through a trailing [`StepKind::Store`] otherwise.
//!
//! Both lowerings produce plans the unchanged `PlanExecutor` runs; the
//! chain shape reproduces the PR 1/PR 2 chain plans step for step.

use crate::backend::Width;
use crate::cluster::NodeId;
use crate::codes::TopologyShape;
use crate::coordinator::plan::{ArchivalPlan, GemmInput, GemmOutput, StepId, StepKind};
use crate::storage::{BlockKey, ObjectId};

/// Lower an encode schedule bound to `nodes` over `shape`: position i runs
/// `schedule[i]` on `nodes[i]`, stores `c_i` and streams its ψ-combination
/// to every child position.
pub fn lower_encode(
    object: ObjectId,
    width: Width,
    schedule: &[(Vec<usize>, Vec<u32>, Vec<u32>)],
    nodes: &[NodeId],
    shape: &TopologyShape,
    buf_bytes: usize,
    block_bytes: usize,
) -> anyhow::Result<ArchivalPlan> {
    anyhow::ensure!(
        schedule.len() == nodes.len(),
        "schedule/node binding length mismatch"
    );
    anyhow::ensure!(
        shape.n() == nodes.len(),
        "shape has {} positions, binding has {}",
        shape.n(),
        nodes.len()
    );
    let mut plan = ArchivalPlan::new(object, width, buf_bytes, block_bytes);
    let ids: Vec<StepId> = schedule
        .iter()
        .enumerate()
        .map(|(i, (locals, psi, xi))| {
            plan.add_step(
                nodes[i],
                StepKind::Fold {
                    locals: locals.iter().map(|&b| BlockKey::source(object, b)).collect(),
                    psi: psi.clone(),
                    xi: xi.clone(),
                    store: Some(BlockKey::coded(object, i)),
                },
            )
        })
        .collect();
    for (parent, kids) in shape.children().iter().enumerate() {
        for (port, &child) in kids.iter().enumerate() {
            plan.connect(ids[parent], port, ids[child], 0);
        }
    }
    Ok(plan)
}

/// Lower a ψ-weighted aggregation `Σ ψ[i]·c_{sources[i].1}` over `shape`
/// (one slot per source): leaves fold their coded block into a fresh
/// partial, interior slots merge child partials, and the root's sum is
/// stored under `out_key` on `newcomer` (directly when the root slot's
/// node *is* the newcomer).
#[allow(clippy::too_many_arguments)]
pub fn lower_aggregate(
    object: ObjectId,
    width: Width,
    sources: &[(NodeId, usize)],
    psi: &[u32],
    shape: &TopologyShape,
    newcomer: NodeId,
    out_key: BlockKey,
    buf_bytes: usize,
    block_bytes: usize,
) -> anyhow::Result<ArchivalPlan> {
    anyhow::ensure!(!sources.is_empty(), "aggregation with no sources");
    anyhow::ensure!(psi.len() == sources.len(), "ψ/source arity mismatch");
    anyhow::ensure!(
        shape.n() == sources.len(),
        "shape has {} slots, {} sources given",
        shape.n(),
        sources.len()
    );
    let children = shape.children();
    let root_in_place = sources[0].0 == newcomer;
    let mut plan = ArchivalPlan::new(object, width, buf_bytes, block_bytes);

    // Slots in reverse index order (leaves before their parents) purely
    // for readability of dumped plans; edges are wired by id afterwards.
    let mut ids = vec![usize::MAX; sources.len()];
    for slot in (0..sources.len()).rev() {
        let (node, pos) = sources[slot];
        let key = BlockKey::coded(object, pos);
        let is_root = slot == 0;
        let stores_here = is_root && root_in_place;
        let kind = if children[slot].len() >= 2 {
            // Merge several child partials: one Gemm row XORs them (coeff
            // 1) and folds the local block with ψ.
            let fan_in = children[slot].len();
            let mut row = vec![1u32; fan_in];
            row.push(psi[slot]);
            let mut inputs = vec![GemmInput::Stream; fan_in];
            inputs.push(GemmInput::Local(key));
            let outputs = vec![if stores_here {
                GemmOutput::Store(out_key)
            } else {
                GemmOutput::Stream
            }];
            StepKind::Gemm {
                rows: vec![row],
                inputs,
                outputs,
            }
        } else {
            StepKind::Fold {
                locals: vec![key],
                psi: vec![psi[slot]],
                xi: vec![if stores_here { psi[slot] } else { 0 }],
                store: stores_here.then_some(out_key),
            }
        };
        ids[slot] = plan.add_step(node, kind);
    }
    for (parent, kids) in children.iter().enumerate() {
        for (in_port, &child) in kids.iter().enumerate() {
            // a single-child fold consumes on in-port 0 (== in_port); a
            // fan-in gemm binds one child stream per input index
            plan.connect(ids[child], 0, ids[parent], in_port);
        }
    }
    if !root_in_place {
        let store = plan.add_step(newcomer, StepKind::Store { key: out_key });
        plan.connect(ids[0], 0, store, 0);
    }
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::topology::Topology;

    fn schedule(n: usize) -> Vec<(Vec<usize>, Vec<u32>, Vec<u32>)> {
        (0..n).map(|i| (vec![i % 4], vec![3], vec![7])).collect()
    }

    #[test]
    fn chain_encode_lowering_matches_pr1_shape() {
        let shape = Topology::Chain.shape(8).unwrap();
        let plan = lower_encode(
            ObjectId(1),
            Width::W8,
            &schedule(8),
            &(0..8).collect::<Vec<_>>(),
            &shape,
            1024,
            4096,
        )
        .unwrap();
        plan.validate().unwrap();
        assert_eq!(plan.len(), 8);
        assert_eq!(plan.edges.len(), 7);
        assert!(plan.steps.iter().all(|s| matches!(s.kind, StepKind::Fold { .. })));
    }

    #[test]
    fn tree_encode_lowering_fans_out_folds() {
        let shape = Topology::Tree { fanout: 2 }.shape(8).unwrap();
        let plan = lower_encode(
            ObjectId(2),
            Width::W8,
            &schedule(8),
            &(0..8).collect::<Vec<_>>(),
            &shape,
            1024,
            4096,
        )
        .unwrap();
        plan.validate().unwrap();
        // still n steps / n-1 edges — trees keep the chain's traffic
        // optimality, they just reshape it
        assert_eq!(plan.len(), 8);
        assert_eq!(plan.edges.len(), 7);
        assert!(plan.steps.iter().all(|s| matches!(s.kind, StepKind::Fold { .. })));
        // the root binds two producer ports
        let root_ports: Vec<usize> = plan
            .edges
            .iter()
            .filter(|e| e.from == 0)
            .map(|e| e.from_port)
            .collect();
        assert_eq!(root_ports.len(), 2);
    }

    #[test]
    fn aggregate_tree_merges_with_gemm() {
        // 4 slots, fanout 2: root (slot 0) merges slots 1+2, slot 1 also
        // feeds from slot 3
        let shape = Topology::Tree { fanout: 2 }.shape(4).unwrap();
        let sources = vec![(0usize, 0usize), (1, 1), (2, 2), (3, 3)];
        let plan = lower_aggregate(
            ObjectId(3),
            Width::W8,
            &sources,
            &[2, 4, 6, 8],
            &shape,
            9,
            BlockKey::coded(ObjectId(3), 5),
            1024,
            4096,
        )
        .unwrap();
        plan.validate().unwrap();
        assert_eq!(plan.len(), 5); // 4 slots + newcomer store
        let gemms = plan
            .steps
            .iter()
            .filter(|s| matches!(s.kind, StepKind::Gemm { .. }))
            .count();
        assert_eq!(gemms, 1, "only the fan-in root merges via gemm");
        assert!(matches!(plan.steps.last().unwrap().kind, StepKind::Store { .. }));
    }

    #[test]
    fn aggregate_in_place_root_stores_locally() {
        let shape = Topology::Chain.shape(3).unwrap();
        // root slot's node IS the newcomer: no separate Store step
        let sources = vec![(7usize, 0usize), (1, 1), (2, 2)];
        let plan = lower_aggregate(
            ObjectId(4),
            Width::W8,
            &sources,
            &[2, 4, 6],
            &shape,
            7,
            BlockKey::coded(ObjectId(4), 9),
            1024,
            4096,
        )
        .unwrap();
        plan.validate().unwrap();
        assert_eq!(plan.len(), 3);
        let storing: Vec<_> = plan
            .steps
            .iter()
            .filter(|s| matches!(&s.kind, StepKind::Fold { store: Some(_), .. }))
            .collect();
        assert_eq!(storing.len(), 1);
        assert_eq!(storing[0].node, 7);
    }

    #[test]
    fn arity_mismatches_rejected() {
        let shape = Topology::Chain.shape(3).unwrap();
        assert!(lower_encode(
            ObjectId(5),
            Width::W8,
            &schedule(3),
            &[0, 1],
            &shape,
            1024,
            4096
        )
        .is_err());
        assert!(lower_aggregate(
            ObjectId(5),
            Width::W8,
            &[(0, 0), (1, 1)],
            &[1],
            &shape,
            5,
            BlockKey::coded(ObjectId(5), 0),
            1024,
            4096
        )
        .is_err());
    }
}
