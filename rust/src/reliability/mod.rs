//! Static resilience analysis (paper Table I).
//!
//! *Static resilience* is the probability that a stored object remains
//! reconstructable when every storage node fails independently with
//! probability `p`, reported in the paper's "number of 9's" metric
//! (`three nines` = survival probability 0.999).
//!
//! Three schemes are compared, as in Table I:
//! * 3-way replication — survives unless all replicas fail: 1 − p³.
//! * (n, k) classical MDS — survives iff ≤ n−k nodes fail (binomial tail).
//! * (n, k) RapidRAID — survives iff the surviving generator rows still
//!   have rank k; computed EXACTLY by enumerating all 2^n failure patterns
//!   against the code's generator matrix (n ≤ 20 is instantaneous).

pub mod nines;
pub mod resilience;

pub use nines::nines;
pub use resilience::{
    census_survival_prob, code_survival_prob, mds_survival_prob, replication_survival_prob,
    table1, Table1Row,
};
