//! Exact static-resilience computations for the three Table I schemes.

use super::nines::nines;
use crate::gf::{rank, GfElem, Matrix};

/// Survival probability of an object stored as `replicas` full copies on
/// distinct nodes, each failing i.i.d. with probability `p`: 1 − p^replicas.
pub fn replication_survival_prob(replicas: u32, p: f64) -> f64 {
    1.0 - p.powi(replicas as i32)
}

/// Survival probability of an (n, k) MDS code: the object survives iff at
/// most n−k of the n nodes fail (binomial tail).
pub fn mds_survival_prob(n: usize, k: usize, p: f64) -> f64 {
    assert!(k <= n);
    let mut total = 0.0;
    for failures in 0..=(n - k) {
        total += binom_pmf(n, failures, p);
    }
    total
}

/// EXACT survival probability of an arbitrary linear code given its n×k
/// generator matrix: enumerate all 2^n failure patterns; the object survives
/// a pattern iff the surviving rows have rank k.
///
/// 2^n patterns with an n×k Gauss each — instantaneous for the paper's
/// n ≤ 16 and still fine up to n ≈ 22.
pub fn code_survival_prob<F: GfElem>(generator: &Matrix<F>, p: f64) -> f64 {
    let n = generator.rows();
    let k = generator.cols();
    assert!(n <= 26, "2^n enumeration not sensible beyond n≈26");
    let mut survive = 0.0;
    for mask in 0u64..(1u64 << n) {
        let alive = mask.count_ones() as usize;
        if alive < k {
            continue;
        }
        let rows: Vec<usize> = (0..n).filter(|&i| mask >> i & 1 == 1).collect();
        if rank(&generator.select_rows(&rows)) == k {
            // P(this exact pattern): alive nodes survive, the rest fail.
            survive += (1.0 - p).powi(alive as i32) * p.powi((n - alive) as i32);
        }
    }
    survive
}

/// EXACT survival probability of an object given its CURRENT survivor
/// census: only the generator rows in `avail` still exist (the rest are
/// already lost), and each surviving holder fails i.i.d. with probability
/// `p` before the next repair round. The object survives a pattern iff the
/// rows that remain alive keep rank k.
///
/// This is the scheduler-facing form of [`code_survival_prob`]: the repair
/// scheduler's `ReliabilityBudget` trigger converts it to a number of 9's
/// and fires eager repair when a degraded object's budget is breached.
/// 2^|avail| patterns with a Gauss each — fine for the paper's n ≤ 16.
pub fn census_survival_prob<F: GfElem>(
    generator: &Matrix<F>,
    avail: &[usize],
    p: f64,
) -> f64 {
    let k = generator.cols();
    let m = avail.len();
    assert!(m <= 26, "2^m enumeration not sensible beyond m≈26");
    assert!((0.0..=1.0).contains(&p), "probability out of range");
    if m < k {
        return 0.0;
    }
    let mut survive = 0.0;
    for mask in 0u64..(1u64 << m) {
        let alive = mask.count_ones() as usize;
        if alive < k {
            continue;
        }
        let rows: Vec<usize> = (0..m)
            .filter(|&i| mask >> i & 1 == 1)
            .map(|i| avail[i])
            .collect();
        if rank(&generator.select_rows(&rows)) == k {
            survive += (1.0 - p).powi(alive as i32) * p.powi((m - alive) as i32);
        }
    }
    survive
}

fn binom_pmf(n: usize, x: usize, p: f64) -> f64 {
    crate::codes::subsets::binomial(n, x) as f64 * p.powi(x as i32) * (1.0 - p).powi((n - x) as i32)
}

/// One row of the reproduced Table I: nines for each failure probability.
#[derive(Clone, Debug)]
pub struct Table1Row {
    /// Scheme label as printed.
    pub scheme: String,
    /// Number of 9's for each entry of `ps` (same order).
    pub nines: Vec<u32>,
}

/// Reproduce Table I for the standard failure probabilities
/// p ∈ {0.2, 0.1, 0.01, 0.001}: 3-replica vs (n,k) classical MDS vs the
/// given RapidRAID generator.
pub fn table1<F: GfElem>(n: usize, k: usize, rapidraid_generator: &Matrix<F>) -> Vec<Table1Row> {
    let ps = [0.2, 0.1, 0.01, 0.001];
    vec![
        Table1Row {
            scheme: "3-replica system".into(),
            nines: ps.iter().map(|&p| nines(replication_survival_prob(3, p))).collect(),
        },
        Table1Row {
            scheme: format!("({n},{k}) classical EC"),
            nines: ps.iter().map(|&p| nines(mds_survival_prob(n, k, p))).collect(),
        },
        Table1Row {
            scheme: format!("({n},{k}) RapidRAID"),
            nines: ps
                .iter()
                .map(|&p| nines(code_survival_prob(rapidraid_generator, p)))
                .collect(),
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::rapidraid::RapidRaidCode;
    use crate::codes::ClassicalCode;
    use crate::gf::{Gf256, Gf65536};

    #[test]
    fn replication_matches_closed_form() {
        assert!((replication_survival_prob(3, 0.1) - 0.999).abs() < 1e-12);
        assert!((replication_survival_prob(1, 0.25) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn mds_survival_sums_binomial_tail() {
        // (3,1) MDS == 3-replica
        for p in [0.2, 0.1, 0.01] {
            assert!((mds_survival_prob(3, 1, p) - replication_survival_prob(3, p)).abs() < 1e-12);
        }
        // k == n: no redundancy — all nodes must survive
        assert!((mds_survival_prob(4, 4, 0.1) - 0.9f64.powi(4)).abs() < 1e-12);
    }

    #[test]
    fn code_survival_of_mds_generator_matches_binomial() {
        // A classical Cauchy generator IS MDS: exact enumeration must equal
        // the binomial tail.
        let code = ClassicalCode::<Gf256>::new(8, 4).unwrap();
        for p in [0.2, 0.1, 0.05] {
            let exact = code_survival_prob(code.generator(), p);
            let tail = mds_survival_prob(8, 4, p);
            assert!((exact - tail).abs() < 1e-12, "p={p}: {exact} vs {tail}");
        }
    }

    #[test]
    fn rapidraid_84_survival_slightly_below_mds() {
        let code = RapidRaidCode::<Gf65536>::with_seed(8, 4, 12).unwrap();
        let p = 0.1;
        let rr = code_survival_prob(code.generator(), p);
        let mds = mds_survival_prob(8, 4, p);
        assert!(rr < mds, "one natural dependency must cost something");
        // …but only by the probability weight of that one bad 4-subset
        // pattern: the gap is tiny.
        assert!(mds - rr < 1e-3, "gap too large: {}", mds - rr);
    }

    #[test]
    fn census_with_all_rows_matches_full_code_survival() {
        let code = ClassicalCode::<Gf256>::new(8, 4).unwrap();
        let all: Vec<usize> = (0..8).collect();
        for p in [0.2, 0.1, 0.01] {
            let full = code_survival_prob(code.generator(), p);
            let census = census_survival_prob(code.generator(), &all, p);
            assert!((full - census).abs() < 1e-12, "p={p}: {full} vs {census}");
        }
    }

    #[test]
    fn census_degrades_as_survivors_are_lost() {
        let code = ClassicalCode::<Gf256>::new(8, 4).unwrap();
        let p = 0.1;
        let mut last = 1.0;
        // drop rows one by one: survival must be monotonically non-increasing
        for lost in 0..5 {
            let avail: Vec<usize> = (lost..8).collect();
            let s = census_survival_prob(code.generator(), &avail, p);
            assert!(s <= last + 1e-12, "lost={lost}: {s} > {last}");
            last = s;
        }
        // below k survivors the object is already gone
        assert_eq!(census_survival_prob(code.generator(), &[0, 1, 2], p), 0.0);
    }

    #[test]
    fn table1_replication_row_matches_paper() {
        let code = RapidRaidCode::<Gf65536>::with_seed(8, 4, 12).unwrap();
        let rows = table1(8, 4, code.generator());
        assert_eq!(rows[0].nines, vec![2, 3, 6, 9]); // paper Table I row 1
    }

    #[test]
    fn rapidraid_never_beats_classical_same_params() {
        let code = RapidRaidCode::<Gf65536>::with_seed(8, 4, 12).unwrap();
        let rows = table1(8, 4, code.generator());
        for (c, r) in rows[1].nines.iter().zip(&rows[2].nines) {
            assert!(r <= c, "RapidRAID cannot out-survive MDS at equal (n,k)");
        }
    }
}
