//! The "number of 9's" availability metric (paper Table I, footnote 1).

/// Number of leading nines of a survival probability:
/// 0.999 → 3, 0.992 → 2, 0.5 → 0.
///
/// Computed as ⌊−log₁₀(1 − p_survive)⌋, clamped at 0, with a small epsilon
/// so exact decimals (0.999…) don't lose a nine to floating-point error.
pub fn nines(p_survive: f64) -> u32 {
    assert!((0.0..=1.0).contains(&p_survive), "probability out of range");
    let p_loss = 1.0 - p_survive;
    if p_loss <= 0.0 {
        return u32::MAX; // certain survival
    }
    let raw = -p_loss.log10();
    if raw < 0.0 {
        0
    } else {
        (raw + 1e-9).floor() as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_footnote_example() {
        assert_eq!(nines(0.999), 3); // "three nines"
    }

    #[test]
    fn replication_row_of_table1() {
        // 3-replica survival = 1 - p^3 for p = 0.2, 0.1, 0.01, 0.001
        assert_eq!(nines(1.0 - 0.2f64.powi(3)), 2);
        assert_eq!(nines(1.0 - 0.1f64.powi(3)), 3);
        assert_eq!(nines(1.0 - 0.01f64.powi(3)), 6);
        assert_eq!(nines(1.0 - 0.001f64.powi(3)), 9);
    }

    #[test]
    fn edge_cases() {
        assert_eq!(nines(0.0), 0);
        assert_eq!(nines(0.5), 0);
        assert_eq!(nines(0.89), 0);
        assert_eq!(nines(0.9), 1);
        assert_eq!(nines(1.0), u32::MAX);
    }

    #[test]
    #[should_panic(expected = "probability out of range")]
    fn rejects_bad_probability() {
        nines(1.5);
    }
}
