//! Machine-readable bench output: every `rapidraid bench-*` / sim preset
//! writes a `BENCH_<preset>.json` next to its human-readable table so the
//! performance trajectory is trackable across PRs (diff two files, plot a
//! series) without scraping stdout.
//!
//! The emitter is hand-rolled (the offline build has no serde): the shape
//! is deliberately flat —
//!
//! ```json
//! {
//!   "preset": "table2-sim",
//!   "params": {"block_bytes": "1048576", …},
//!   "series": [{"name": "…", "n": 3, "median_s": …, "samples_s": […]}, …],
//!   "spans":  [same shape — the per-stage tick breakdown],
//!   "wall_s": 0.42
//! }
//! ```

use std::path::{Path, PathBuf};
use std::time::Duration;

use crate::util::bench::Candle;

/// Escape a string for a JSON string literal.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn candle_json(c: &Candle) -> String {
    let samples: Vec<String> = c
        .samples
        .iter()
        .map(|s| format!("{:.9}", s.as_secs_f64()))
        .collect();
    format!(
        "{{\"name\":\"{}\",\"n\":{},\"median_s\":{:.9},\"mean_s\":{:.9},\"min_s\":{:.9},\"max_s\":{:.9},\"stddev_s\":{:.9},\"samples_s\":[{}]}}",
        escape(&c.name),
        c.samples.len(),
        c.median().as_secs_f64(),
        c.mean().as_secs_f64(),
        c.min().as_secs_f64(),
        c.max().as_secs_f64(),
        c.stddev_secs(),
        samples.join(",")
    )
}

/// One bench invocation's machine-readable report.
#[derive(Clone, Debug)]
pub struct BenchJson {
    /// Preset label; also names the output file (`BENCH_<preset>.json`).
    pub preset: String,
    /// Invocation parameters, as key/value strings.
    pub params: Vec<(String, String)>,
    /// End-to-end result series (coding times, repair times, …).
    pub series: Vec<Candle>,
    /// Per-span tick breakdown (`<impl>/fold`, `<impl>/gemm.compute`, …).
    pub spans: Vec<Candle>,
    /// Wall time of the whole invocation.
    pub wall: Duration,
}

impl BenchJson {
    /// Empty report for `preset`.
    pub fn new(preset: impl Into<String>) -> Self {
        Self {
            preset: preset.into(),
            params: Vec::new(),
            series: Vec::new(),
            spans: Vec::new(),
            wall: Duration::ZERO,
        }
    }

    /// Append one parameter.
    pub fn param(mut self, key: &str, value: impl ToString) -> Self {
        self.params.push((key.to_string(), value.to_string()));
        self
    }

    /// The whole report as one JSON document.
    pub fn to_json(&self) -> String {
        let params: Vec<String> = self
            .params
            .iter()
            .map(|(k, v)| format!("\"{}\":\"{}\"", escape(k), escape(v)))
            .collect();
        let series: Vec<String> = self.series.iter().map(candle_json).collect();
        let spans: Vec<String> = self.spans.iter().map(candle_json).collect();
        format!(
            "{{\"preset\":\"{}\",\"params\":{{{}}},\"series\":[{}],\"spans\":[{}],\"wall_s\":{:.6}}}\n",
            escape(&self.preset),
            params.join(","),
            series.join(","),
            spans.join(","),
            self.wall.as_secs_f64()
        )
    }

    /// The output file name: `BENCH_<preset>.json`, preset sanitized to
    /// `[A-Za-z0-9._-]`.
    pub fn file_name(&self) -> String {
        let safe: String = self
            .preset
            .chars()
            .map(|c| {
                if c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.') {
                    c
                } else {
                    '_'
                }
            })
            .collect();
        format!("BENCH_{safe}.json")
    }

    /// Write the report into `dir`; returns the file path.
    pub fn write_to_dir(&self, dir: &Path) -> anyhow::Result<PathBuf> {
        let path = dir.join(self.file_name());
        std::fs::write(&path, self.to_json())?;
        Ok(path)
    }

    /// Find a result series by exact name (searches `series`, then
    /// `spans`) — how calibration consumers pull the `calibrate/*`
    /// candles back out of a report.
    pub fn find_series(&self, name: &str) -> Option<&Candle> {
        self.series
            .iter()
            .chain(self.spans.iter())
            .find(|c| c.name == name)
    }

    /// Look up a parameter value by key.
    pub fn get_param(&self, key: &str) -> Option<&str> {
        self.params
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn candle(name: &str, ms: &[u64]) -> Candle {
        let mut samples: Vec<Duration> = ms.iter().map(|&m| Duration::from_millis(m)).collect();
        samples.sort_unstable();
        Candle {
            name: name.to_string(),
            samples,
        }
    }

    #[test]
    fn escape_handles_specials() {
        assert_eq!(escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(escape("plain"), "plain");
    }

    #[test]
    fn report_serializes_all_sections() {
        let mut r = BenchJson::new("table2-sim").param("block_bytes", 1 << 20);
        r.series.push(candle("n11k8/classical", &[10, 30, 20]));
        r.spans.push(candle("CEC/gemm.compute", &[5]));
        r.wall = Duration::from_millis(1500);
        let j = r.to_json();
        assert!(j.starts_with('{') && j.ends_with("}\n"), "{j}");
        assert!(j.contains("\"preset\":\"table2-sim\""));
        assert!(j.contains("\"block_bytes\":\"1048576\""));
        assert!(j.contains("\"name\":\"n11k8/classical\""));
        assert!(j.contains("\"median_s\":0.020000000"));
        assert!(j.contains("CEC/gemm.compute"));
        assert!(j.contains("\"wall_s\":1.500000"));
    }

    #[test]
    fn file_name_is_sanitized() {
        assert_eq!(BenchJson::new("fig4-tpc-sim").file_name(), "BENCH_fig4-tpc-sim.json");
        assert_eq!(BenchJson::new("a/b c").file_name(), "BENCH_a_b_c.json");
    }

    #[test]
    fn find_series_and_get_param() {
        let mut r = BenchJson::new("cal").param("calibrate_bytes", 1 << 20);
        r.series.push(candle("calibrate/mac", &[4]));
        r.spans.push(candle("CEC/gemm.compute", &[5]));
        assert_eq!(r.find_series("calibrate/mac").unwrap().samples.len(), 1);
        // spans are searched too
        assert!(r.find_series("CEC/gemm.compute").is_some());
        assert!(r.find_series("nope").is_none());
        assert_eq!(r.get_param("calibrate_bytes"), Some("1048576"));
        assert_eq!(r.get_param("missing"), None);
    }

    #[test]
    fn write_roundtrips_to_disk() {
        let dir = std::env::temp_dir().join(format!("rr-benchjson-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let r = BenchJson::new("smoke").param("k", 11);
        let path = r.write_to_dir(&dir).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"preset\":\"smoke\""));
        std::fs::remove_dir_all(&dir).ok();
    }
}
