//! Machine-readable bench output: every `rapidraid bench-*` / sim preset
//! writes a `BENCH_<preset>.json` next to its human-readable table so the
//! performance trajectory is trackable across PRs (diff two files, plot a
//! series) without scraping stdout.
//!
//! The emitter is hand-rolled (the offline build has no serde): the shape
//! is deliberately flat —
//!
//! ```json
//! {
//!   "schema_version": 2,
//!   "preset": "table2-sim",
//!   "params": {"preset": "table2-sim", "block_bytes": "1048576", …},
//!   "series": [{"name": "…", "n": 3, "median_s": …, "samples_s": […]}, …],
//!   "spans":  [same shape — the per-stage tick breakdown],
//!   "wall_s": 0.42
//! }
//! ```
//!
//! Reports are also *readable*: [`parse_json`] is a minimal serde-free
//! JSON reader and [`BenchJson::from_json`] reconstitutes a report from
//! its own output, which is how `--calibration <BENCH_gf-hotpath.json>`
//! feeds measured GF kernel costs back into the simulators and how
//! `trace-report` consumes saved traces.

use std::path::{Path, PathBuf};
use std::time::Duration;

use crate::util::bench::Candle;

/// Version of the `BENCH_*.json` document shape. Bumped when fields are
/// added or change meaning; every emitted report carries it so downstream
/// consumers can detect stale files.
pub const SCHEMA_VERSION: u32 = 2;

/// Escape a string for a JSON string literal.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn candle_json(c: &Candle) -> String {
    let samples: Vec<String> = c
        .samples
        .iter()
        .map(|s| format!("{:.9}", s.as_secs_f64()))
        .collect();
    format!(
        "{{\"name\":\"{}\",\"n\":{},\"median_s\":{:.9},\"mean_s\":{:.9},\"min_s\":{:.9},\"max_s\":{:.9},\"stddev_s\":{:.9},\"samples_s\":[{}]}}",
        escape(&c.name),
        c.samples.len(),
        c.median().as_secs_f64(),
        c.mean().as_secs_f64(),
        c.min().as_secs_f64(),
        c.max().as_secs_f64(),
        c.stddev_secs(),
        samples.join(",")
    )
}

/// One bench invocation's machine-readable report.
#[derive(Clone, Debug)]
pub struct BenchJson {
    /// Preset label; also names the output file (`BENCH_<preset>.json`).
    pub preset: String,
    /// Invocation parameters, as key/value strings.
    pub params: Vec<(String, String)>,
    /// End-to-end result series (coding times, repair times, …).
    pub series: Vec<Candle>,
    /// Per-span tick breakdown (`<impl>/fold`, `<impl>/gemm.compute`, …).
    pub spans: Vec<Candle>,
    /// Wall time of the whole invocation.
    pub wall: Duration,
}

impl BenchJson {
    /// Empty report for `preset`.
    pub fn new(preset: impl Into<String>) -> Self {
        Self {
            preset: preset.into(),
            params: Vec::new(),
            series: Vec::new(),
            spans: Vec::new(),
            wall: Duration::ZERO,
        }
    }

    /// Append one parameter.
    pub fn param(mut self, key: &str, value: impl ToString) -> Self {
        self.params.push((key.to_string(), value.to_string()));
        self
    }

    /// Set (or replace) one parameter in place — the mutating counterpart
    /// of the builder-style [`BenchJson::param`], used by consumers that
    /// fold derived data (e.g. trace counters) into an existing report.
    pub fn set_param(&mut self, key: &str, value: impl ToString) {
        let value = value.to_string();
        if let Some(slot) = self.params.iter_mut().find(|(k, _)| k == key) {
            slot.1 = value;
        } else {
            self.params.push((key.to_string(), value));
        }
    }

    /// The whole report as one JSON document (self-describing: carries
    /// [`SCHEMA_VERSION`] and repeats the preset as a param).
    pub fn to_json(&self) -> String {
        let mut params: Vec<String> = Vec::with_capacity(self.params.len() + 1);
        if self.get_param("preset").is_none() {
            params.push(format!("\"preset\":\"{}\"", escape(&self.preset)));
        }
        params.extend(
            self.params
                .iter()
                .map(|(k, v)| format!("\"{}\":\"{}\"", escape(k), escape(v))),
        );
        let series: Vec<String> = self.series.iter().map(candle_json).collect();
        let spans: Vec<String> = self.spans.iter().map(candle_json).collect();
        format!(
            "{{\"schema_version\":{SCHEMA_VERSION},\"preset\":\"{}\",\"params\":{{{}}},\"series\":[{}],\"spans\":[{}],\"wall_s\":{:.6}}}\n",
            escape(&self.preset),
            params.join(","),
            series.join(","),
            spans.join(","),
            self.wall.as_secs_f64()
        )
    }

    /// The output file name: `BENCH_<preset>.json`, preset sanitized to
    /// `[A-Za-z0-9._-]`.
    pub fn file_name(&self) -> String {
        let safe: String = self
            .preset
            .chars()
            .map(|c| {
                if c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.') {
                    c
                } else {
                    '_'
                }
            })
            .collect();
        format!("BENCH_{safe}.json")
    }

    /// Write the report into `dir`; returns the file path.
    pub fn write_to_dir(&self, dir: &Path) -> anyhow::Result<PathBuf> {
        let path = dir.join(self.file_name());
        std::fs::write(&path, self.to_json())?;
        Ok(path)
    }

    /// Find a result series by exact name (searches `series`, then
    /// `spans`) — how calibration consumers pull the `calibrate/*`
    /// candles back out of a report.
    pub fn find_series(&self, name: &str) -> Option<&Candle> {
        self.series
            .iter()
            .chain(self.spans.iter())
            .find(|c| c.name == name)
    }

    /// Like [`BenchJson::find_series`] but fails with an error naming the
    /// series the report *does* have — so a calibration file with the
    /// wrong preset produces an actionable message instead of a bare
    /// "missing".
    pub fn series(&self, name: &str) -> anyhow::Result<&Candle> {
        self.find_series(name).ok_or_else(|| {
            let available: Vec<&str> = self
                .series
                .iter()
                .chain(self.spans.iter())
                .map(|c| c.name.as_str())
                .collect();
            anyhow::anyhow!(
                "no series {name:?} in report {:?} (available: {})",
                self.preset,
                if available.is_empty() {
                    "none".to_string()
                } else {
                    available.join(", ")
                }
            )
        })
    }

    /// Look up a parameter value by key.
    pub fn get_param(&self, key: &str) -> Option<&str> {
        self.params
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    /// Reconstitute a report from its own [`BenchJson::to_json`] output.
    /// Tolerant of missing optional sections; `schema_version` is accepted
    /// but not required (pre-PR-7 reports parse too).
    pub fn from_json(text: &str) -> anyhow::Result<Self> {
        let doc = parse_json(text)?;
        let preset = doc
            .get("preset")
            .and_then(JsonValue::as_str)
            .unwrap_or("unknown")
            .to_string();
        let mut report = BenchJson::new(preset);
        if let Some(JsonValue::Obj(entries)) = doc.get("params") {
            for (k, v) in entries {
                let v = match v {
                    JsonValue::Str(s) => s.clone(),
                    JsonValue::Num(n) => format!("{n}"),
                    JsonValue::Bool(b) => b.to_string(),
                    other => anyhow::bail!("param {k:?} has non-scalar value {other:?}"),
                };
                report.params.push((k.clone(), v));
            }
        }
        report.series = candles_field(&doc, "series")?;
        report.spans = candles_field(&doc, "spans")?;
        if let Some(w) = doc.get("wall_s").and_then(JsonValue::as_f64) {
            report.wall = Duration::from_secs_f64(w.max(0.0));
        }
        Ok(report)
    }
}

fn candles_field(doc: &JsonValue, key: &str) -> anyhow::Result<Vec<Candle>> {
    let Some(entries) = doc.get(key).and_then(JsonValue::as_arr) else {
        return Ok(Vec::new());
    };
    entries.iter().map(candle_from_json).collect()
}

fn candle_from_json(v: &JsonValue) -> anyhow::Result<Candle> {
    let name = v
        .get("name")
        .and_then(JsonValue::as_str)
        .ok_or_else(|| anyhow::anyhow!("series entry without \"name\""))?
        .to_string();
    let mut samples = match v.get("samples_s") {
        Some(JsonValue::Arr(xs)) => xs
            .iter()
            .map(|x| {
                x.as_f64()
                    .map(|s| Duration::from_secs_f64(s.max(0.0)))
                    .ok_or_else(|| anyhow::anyhow!("non-numeric sample in series {name:?}"))
            })
            .collect::<anyhow::Result<Vec<_>>>()?,
        _ => Vec::new(),
    };
    samples.sort_unstable();
    Ok(Candle { name, samples })
}

/// A parsed JSON value — the minimal serde-free reader counterpart of the
/// crate's hand-rolled emitters ([`BenchJson::to_json`],
/// [`Event::to_json_line`](crate::trace::Event::to_json_line),
/// [`chrome_trace`](crate::trace::chrome_trace)).
#[derive(Clone, Debug, PartialEq)]
pub enum JsonValue {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number (stored as `f64`; integers exact up to 2^53).
    Num(f64),
    /// A string, with escapes resolved.
    Str(String),
    /// An array.
    Arr(Vec<JsonValue>),
    /// An object (insertion order preserved; lookups take the last
    /// occurrence of a duplicate key, matching serde/JS semantics).
    Obj(Vec<(String, JsonValue)>),
}

impl JsonValue {
    /// Object field lookup (None on non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Obj(entries) => {
                entries.iter().rev().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    /// The number, if this is one.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The number as an unsigned integer (None on negatives/fractions).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            JsonValue::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= u64::MAX as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    /// The string contents, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The array elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Parse one JSON document (object, array, or scalar). Trailing
/// non-whitespace after the document is an error.
pub fn parse_json(text: &str) -> anyhow::Result<JsonValue> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        anyhow::bail!("trailing data after JSON document at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while matches!(self.bytes.get(self.pos), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn peek(&mut self) -> anyhow::Result<u8> {
        self.skip_ws();
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of JSON at byte {}", self.pos))
    }

    fn expect(&mut self, want: u8) -> anyhow::Result<()> {
        let got = self.peek()?;
        if got != want {
            anyhow::bail!(
                "expected {:?} at byte {}, got {:?}",
                want as char,
                self.pos,
                got as char
            );
        }
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> anyhow::Result<JsonValue> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(JsonValue::Str(self.string()?)),
            b't' => {
                self.literal("true")?;
                Ok(JsonValue::Bool(true))
            }
            b'f' => {
                self.literal("false")?;
                Ok(JsonValue::Bool(false))
            }
            b'n' => {
                self.literal("null")?;
                Ok(JsonValue::Null)
            }
            b'-' | b'0'..=b'9' => self.number(),
            c => anyhow::bail!("unexpected {:?} at byte {}", c as char, self.pos),
        }
    }

    fn literal(&mut self, lit: &str) -> anyhow::Result<()> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(())
        } else {
            anyhow::bail!("bad literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> anyhow::Result<JsonValue> {
        let start = self.pos;
        if self.bytes.get(self.pos) == Some(&b'-') {
            self.pos += 1;
        }
        while matches!(
            self.bytes.get(self.pos),
            Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).expect("digits are ASCII");
        let n: f64 = text
            .parse()
            .map_err(|_| anyhow::anyhow!("bad number {text:?} at byte {start}"))?;
        Ok(JsonValue::Num(n))
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let Some(&b) = self.bytes.get(self.pos) else {
                anyhow::bail!("unterminated string at byte {}", self.pos);
            };
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(&esc) = self.bytes.get(self.pos) else {
                        anyhow::bail!("unterminated escape at byte {}", self.pos);
                    };
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| anyhow::anyhow!("truncated \\u escape"))?;
                            let code =
                                u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            self.pos += 4;
                            // lone surrogates (never emitted by our writers)
                            // degrade to the replacement character
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        other => anyhow::bail!("bad escape \\{}", other as char),
                    }
                }
                _ => {
                    // raw UTF-8 run up to the next quote or escape
                    let run_start = self.pos - 1;
                    while let Some(&c) = self.bytes.get(self.pos) {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[run_start..self.pos])?);
                }
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<JsonValue> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(JsonValue::Arr(items));
        }
        loop {
            items.push(self.value()?);
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(JsonValue::Arr(items));
                }
                c => anyhow::bail!(
                    "expected ',' or ']' at byte {}, got {:?}",
                    self.pos,
                    c as char
                ),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<JsonValue> {
        self.expect(b'{')?;
        let mut entries = Vec::new();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(JsonValue::Obj(entries));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.expect(b':')?;
            let v = self.value()?;
            entries.push((key, v));
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(JsonValue::Obj(entries));
                }
                c => anyhow::bail!(
                    "expected ',' or '}}' at byte {}, got {:?}",
                    self.pos,
                    c as char
                ),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn candle(name: &str, ms: &[u64]) -> Candle {
        let mut samples: Vec<Duration> = ms.iter().map(|&m| Duration::from_millis(m)).collect();
        samples.sort_unstable();
        Candle {
            name: name.to_string(),
            samples,
        }
    }

    #[test]
    fn escape_handles_specials() {
        assert_eq!(escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(escape("plain"), "plain");
    }

    #[test]
    fn report_serializes_all_sections() {
        let mut r = BenchJson::new("table2-sim").param("block_bytes", 1 << 20);
        r.series.push(candle("n11k8/classical", &[10, 30, 20]));
        r.spans.push(candle("CEC/gemm.compute", &[5]));
        r.wall = Duration::from_millis(1500);
        let j = r.to_json();
        assert!(j.starts_with('{') && j.ends_with("}\n"), "{j}");
        assert!(j.contains("\"preset\":\"table2-sim\""));
        assert!(j.contains("\"block_bytes\":\"1048576\""));
        assert!(j.contains("\"name\":\"n11k8/classical\""));
        assert!(j.contains("\"median_s\":0.020000000"));
        assert!(j.contains("CEC/gemm.compute"));
        assert!(j.contains("\"wall_s\":1.500000"));
    }

    #[test]
    fn file_name_is_sanitized() {
        assert_eq!(BenchJson::new("fig4-tpc-sim").file_name(), "BENCH_fig4-tpc-sim.json");
        assert_eq!(BenchJson::new("a/b c").file_name(), "BENCH_a_b_c.json");
    }

    #[test]
    fn find_series_and_get_param() {
        let mut r = BenchJson::new("cal").param("calibrate_bytes", 1 << 20);
        r.series.push(candle("calibrate/mac", &[4]));
        r.spans.push(candle("CEC/gemm.compute", &[5]));
        assert_eq!(r.find_series("calibrate/mac").unwrap().samples.len(), 1);
        // spans are searched too
        assert!(r.find_series("CEC/gemm.compute").is_some());
        assert!(r.find_series("nope").is_none());
        assert_eq!(r.get_param("calibrate_bytes"), Some("1048576"));
        assert_eq!(r.get_param("missing"), None);
    }

    #[test]
    fn reports_are_self_describing() {
        let j = BenchJson::new("topo-sim").param("width", 8).to_json();
        assert!(j.contains(&format!("\"schema_version\":{SCHEMA_VERSION}")), "{j}");
        // the preset rides along inside params too
        assert!(j.contains("\"params\":{\"preset\":\"topo-sim\",\"width\":\"8\""), "{j}");
        // an explicit preset param is not duplicated
        let j = BenchJson::new("x").param("preset", "custom").to_json();
        assert_eq!(j.matches("\"preset\":\"custom\"").count(), 1, "{j}");
    }

    #[test]
    fn set_param_replaces_in_place() {
        let mut r = BenchJson::new("p").param("a", 1);
        r.set_param("a", 2);
        r.set_param("b", "x");
        assert_eq!(r.get_param("a"), Some("2"));
        assert_eq!(r.get_param("b"), Some("x"));
        assert_eq!(r.params.len(), 2);
    }

    #[test]
    fn series_lookup_error_names_available_series() {
        let mut r = BenchJson::new("cal");
        r.series.push(candle("calibrate/mac", &[4]));
        r.spans.push(candle("CEC/gemm.compute", &[5]));
        assert!(r.series("calibrate/mac").is_ok());
        let err = r.series("calibrate/xor").unwrap_err().to_string();
        assert!(err.contains("calibrate/xor"), "{err}");
        assert!(err.contains("calibrate/mac"), "{err}");
        assert!(err.contains("CEC/gemm.compute"), "{err}");
        let empty = BenchJson::new("e").series("nope").unwrap_err().to_string();
        assert!(empty.contains("none"), "{empty}");
    }

    #[test]
    fn from_json_round_trips_a_report() {
        let mut r = BenchJson::new("table2-sim").param("block_bytes", 1 << 20);
        r.series.push(candle("n11k8/classical", &[10, 30, 20]));
        r.spans.push(candle("CEC/gemm.compute", &[5]));
        r.wall = Duration::from_millis(1500);
        let back = BenchJson::from_json(&r.to_json()).unwrap();
        assert_eq!(back.preset, "table2-sim");
        assert_eq!(back.get_param("preset"), Some("table2-sim"));
        assert_eq!(back.get_param("block_bytes"), Some("1048576"));
        assert_eq!(back.series.len(), 1);
        assert_eq!(back.series[0].name, "n11k8/classical");
        assert_eq!(back.series[0].samples.len(), 3);
        assert_eq!(back.series[0].median(), Duration::from_millis(20));
        assert_eq!(back.spans[0].name, "CEC/gemm.compute");
        assert!((back.wall.as_secs_f64() - 1.5).abs() < 1e-6);
        // pre-schema_version documents (no preset param, no spans) parse too
        let old = BenchJson::from_json(
            "{\"preset\":\"legacy\",\"params\":{},\"series\":[],\"wall_s\":0.1}",
        )
        .unwrap();
        assert_eq!(old.preset, "legacy");
        assert!(old.spans.is_empty());
    }

    #[test]
    fn parse_json_handles_nesting_and_escapes() {
        let v = parse_json(
            " {\"a\": [1, 2.5, -3e2, true, false, null], \"s\": \"x\\n\\\"y\\u0041\", \"o\": {\"k\": 7}} ",
        )
        .unwrap();
        let a = v.get("a").and_then(JsonValue::as_arr).unwrap();
        assert_eq!(a.len(), 6);
        assert_eq!(a[0].as_u64(), Some(1));
        assert_eq!(a[1].as_f64(), Some(2.5));
        assert_eq!(a[2].as_f64(), Some(-300.0));
        assert_eq!(a[2].as_u64(), None, "negative is not u64");
        assert_eq!(a[3], JsonValue::Bool(true));
        assert_eq!(a[5], JsonValue::Null);
        assert_eq!(v.get("s").and_then(JsonValue::as_str), Some("x\n\"yA"));
        assert_eq!(v.get("o").unwrap().get("k").and_then(JsonValue::as_u64), Some(7));
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn parse_json_rejects_malformed_documents() {
        assert!(parse_json("").is_err());
        assert!(parse_json("{\"a\":1} extra").is_err());
        assert!(parse_json("{\"a\"}").is_err());
        assert!(parse_json("[1,]").is_err());
        assert!(parse_json("\"unterminated").is_err());
        assert!(parse_json("{\"a\":12x4}").is_err());
    }

    #[test]
    fn write_roundtrips_to_disk() {
        let dir = std::env::temp_dir().join(format!("rr-benchjson-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let r = BenchJson::new("smoke").param("k", 11);
        let path = r.write_to_dir(&dir).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"preset\":\"smoke\""));
        std::fs::remove_dir_all(&dir).ok();
    }
}
