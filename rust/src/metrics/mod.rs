//! Measurement collection and report emission for the benchmark harnesses.
//!
//! The paper reports coding times as candles (median, 25–75 percentile box,
//! min–max whiskers — Fig. 4) or mean ± stddev (Fig. 5); [`Recorder`]
//! gathers named samples and emits both, plus aligned markdown/CSV tables
//! for EXPERIMENTS.md. [`Span`] is the timing primitive the plan executor
//! wraps around every archival-plan step, feeding per-stage series
//! (`<label>/transfer`, `<label>/fold`, `<label>/gemm`, `<label>/store`)
//! into a recorder so the Fig. 4/5 harnesses can break end-to-end coding
//! times down by stage.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

use crate::clock::{ClockHandle, Tick};

pub mod json;

pub use crate::util::bench::{bench, once, throughput_mib_s, Candle};
pub use json::{parse_json, BenchJson, JsonValue};

/// Thread-safe named-sample collector.
#[derive(Default)]
pub struct Recorder {
    samples: Mutex<BTreeMap<String, Vec<Duration>>>,
}

impl Recorder {
    /// Empty recorder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append one sample under `name`.
    pub fn record(&self, name: &str, d: Duration) {
        self.samples
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .push(d);
    }

    /// Snapshot a candle for one series (None if unknown).
    pub fn candle(&self, name: &str) -> Option<Candle> {
        let map = self.samples.lock().unwrap();
        let mut samples = map.get(name)?.clone();
        samples.sort_unstable();
        Some(Candle {
            name: name.to_string(),
            samples,
        })
    }

    /// All series as candles, sorted by name.
    pub fn candles(&self) -> Vec<Candle> {
        let map = self.samples.lock().unwrap();
        map.iter()
            .map(|(name, s)| {
                let mut samples = s.clone();
                samples.sort_unstable();
                Candle {
                    name: name.clone(),
                    samples,
                }
            })
            .collect()
    }

    /// Markdown table: one row per series with candle stats.
    pub fn markdown(&self) -> String {
        let mut out = String::from(
            "| series | median | p25 | p75 | min | max | mean | stddev | n |\n|---|---|---|---|---|---|---|---|---|\n",
        );
        for c in self.candles() {
            out.push_str(&format!(
                "| {} | {:.3?} | {:.3?} | {:.3?} | {:.3?} | {:.3?} | {:.3?} | {:.4}s | {} |\n",
                c.name,
                c.median(),
                c.percentile(0.25),
                c.percentile(0.75),
                c.min(),
                c.max(),
                c.mean(),
                c.stddev_secs(),
                c.samples.len()
            ));
        }
        out
    }

    /// CSV with raw samples (`series,sample_idx,seconds`).
    pub fn csv(&self) -> String {
        let mut out = String::from("series,sample,seconds\n");
        for c in self.candles() {
            for (i, s) in c.samples.iter().enumerate() {
                out.push_str(&format!("{},{},{:.9}\n", c.name, i, s.as_secs_f64()));
            }
        }
        out
    }
}

/// An in-flight timing span on a [`ClockHandle`], optionally attached to a
/// [`Recorder`].
///
/// `start` stamps the open tick on the given clock; [`Span::finish`]
/// measures the elapsed clock time, records it under the span's series
/// name (when a recorder is attached) and returns it. On a `RealClock`
/// that is wall time; on a `SimClock` it is virtual time, so the Fig. 4/5
/// stage breakdowns come out of a simulated run with zero timer noise.
/// Detached spans (`rec = None`) still measure — the executor uses them so
/// timing logic never branches on whether a recorder is present.
#[must_use = "a span measures nothing until finished"]
pub struct Span<'a> {
    clock: ClockHandle,
    rec: Option<&'a Recorder>,
    series: String,
    t0: Tick,
}

impl<'a> Span<'a> {
    /// Open a span named `series` on `clock`, recording into `rec` on
    /// finish.
    pub fn start(
        clock: &ClockHandle,
        rec: Option<&'a Recorder>,
        series: impl Into<String>,
    ) -> Self {
        Self {
            clock: clock.clone(),
            rec,
            series: series.into(),
            t0: clock.now(),
        }
    }

    /// The series this span records under.
    pub fn series(&self) -> &str {
        &self.series
    }

    /// Close the span: record the elapsed clock time (if attached) and
    /// return it.
    pub fn finish(self) -> Duration {
        self.finish_split(Duration::ZERO)
    }

    /// Close the span with a compute/transfer split: the total elapsed
    /// clock time is recorded under the span's series as before, and when
    /// `compute` is non-zero (a CPU cost model charged the step) two extra
    /// series land next to it — `<series>.compute` (the charged compute
    /// ticks) and `<series>.transfer` (the remainder: NIC pacing, link
    /// latency, upstream waits). Zero-compute runs therefore produce
    /// reports byte-identical to the pre-resource-model ones.
    pub fn finish_split(self, compute: Duration) -> Duration {
        let end = self.clock.now();
        self.finish_split_at(end, compute)
    }

    /// [`Span::finish_split`] against an explicit end tick instead of the
    /// clock's current instant. The plan executor uses this: each step's
    /// completion tick is stamped by the worker that finished it, so the
    /// recorded stage time is identical whether the result is collected by
    /// a dedicated thread (threaded runtime) or read later by the
    /// dispatching thread (multiplexed runtime).
    pub fn finish_split_at(self, end: Tick, compute: Duration) -> Duration {
        let dt = end.saturating_sub(self.t0);
        if let Some(rec) = self.rec {
            rec.record(&self.series, dt);
            if !compute.is_zero() {
                rec.record(&format!("{}.compute", self.series), compute);
                rec.record(&format!("{}.transfer", self.series), dt.saturating_sub(compute));
            }
        }
        dt
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::{Clock, RealClock, SimClock};

    #[test]
    fn span_records_into_recorder() {
        let clock = RealClock::handle();
        let r = Recorder::new();
        let s = Span::start(&clock, Some(&r), "stage/fold");
        assert_eq!(s.series(), "stage/fold");
        let dt = s.finish();
        let c = r.candle("stage/fold").unwrap();
        assert_eq!(c.samples.len(), 1);
        assert_eq!(c.samples[0], dt);
    }

    #[test]
    fn detached_span_still_measures() {
        let clock = RealClock::handle();
        let s = Span::start(&clock, None, "unrecorded");
        clock.sleep(Duration::from_millis(2));
        assert!(s.finish() >= Duration::from_millis(1));
    }

    #[test]
    fn sim_span_measures_virtual_time_exactly() {
        let clock = SimClock::handle();
        let r = Recorder::new();
        let s = Span::start(&clock, Some(&r), "virt");
        clock.sleep(Duration::from_millis(250));
        assert_eq!(s.finish(), Duration::from_millis(250));
        assert_eq!(
            r.candle("virt").unwrap().samples,
            vec![Duration::from_millis(250)]
        );
    }

    #[test]
    fn finish_split_records_compute_and_transfer() {
        let clock = SimClock::handle();
        let r = Recorder::new();
        let s = Span::start(&clock, Some(&r), "fold");
        clock.sleep(Duration::from_millis(10));
        let dt = s.finish_split(Duration::from_millis(4));
        assert_eq!(dt, Duration::from_millis(10));
        assert_eq!(r.candle("fold").unwrap().samples, vec![Duration::from_millis(10)]);
        assert_eq!(
            r.candle("fold.compute").unwrap().samples,
            vec![Duration::from_millis(4)]
        );
        assert_eq!(
            r.candle("fold.transfer").unwrap().samples,
            vec![Duration::from_millis(6)]
        );
        // zero compute: no split series — reports stay PR-3-identical
        let s = Span::start(&clock, Some(&r), "idle");
        s.finish_split(Duration::ZERO);
        assert!(r.candle("idle.compute").is_none());
        assert!(r.candle("idle.transfer").is_none());
    }

    #[test]
    fn record_and_report() {
        let r = Recorder::new();
        r.record("a", Duration::from_millis(10));
        r.record("a", Duration::from_millis(30));
        r.record("b", Duration::from_millis(5));
        let c = r.candle("a").unwrap();
        assert_eq!(c.samples.len(), 2);
        assert_eq!(c.min(), Duration::from_millis(10));
        assert!(r.candle("zzz").is_none());
        let md = r.markdown();
        assert!(md.contains("| a |"));
        assert!(md.contains("| b |"));
        let csv = r.csv();
        assert_eq!(csv.lines().count(), 4); // header + 3 samples
    }

    #[test]
    fn candles_sorted_by_name() {
        let r = Recorder::new();
        r.record("z", Duration::from_millis(1));
        r.record("a", Duration::from_millis(1));
        let names: Vec<String> = r.candles().into_iter().map(|c| c.name).collect();
        assert_eq!(names, vec!["a", "z"]);
    }
}
