//! Experiment drivers that regenerate the paper's evaluation (Section VI).
//!
//! Shared by the CLI (`rapidraid bench-*`), the examples and the bench
//! binaries (`cargo bench`), so every table/figure has exactly one
//! implementation:
//!
//! * [`table2_cpu`] — Table II: CPU-only coding time of CEC / RR8 / RR16
//!   (all compute on one node, no network).
//! * [`table2_sim`] — the `table2-sim` preset: the same classical-vs-
//!   pipelined coding-time comparison *in the simulator*, with compute
//!   charged in virtual time by [`UniformCost`]/[`ProfileCost`] models
//!   (uniform and heterogeneous EC2-class hardware, k=8/n=11 and
//!   k=16/n=22).
//! * [`topo_sim`] — the `topo-sim` preset: the pipeline-shape shootout —
//!   chain vs tree vs hybrid encoding of the same objects under
//!   uniform/heterogeneous cost models on the SimClock, with per-cell
//!   decode verification through the topology-composed generator.
//! * [`fig4_coding_times`] — Fig. 4: single-object and 16-concurrent-object
//!   coding times on the TPC / EC2 presets.
//! * [`fig5_congestion`] — Fig. 5: coding time vs number of congested
//!   nodes (netem-equivalent profile).
//! * [`fig_repair`] — beyond the paper: single-block repair time, star vs
//!   pipelined (Li et al. 2019), under the same netem congestion sweep.
//!
//! Every harness returns a [`BenchJson`] alongside its human-readable
//! table; the CLI and bench binaries write it out as
//! `BENCH_<preset>.json` so the perf trajectory is trackable across PRs.

use std::io::Write;
use std::time::Duration;

use crate::backend::{BackendHandle, Width};
use crate::clock::{Clock, RealClock, SimClock};
use crate::cluster::{Cluster, ClusterSpec, CongestionSpec, RuntimeKind};
use crate::codes::rapidraid::RapidRaidCode;
use crate::codes::{ClassicalCode, TopologyCode};
use crate::coordinator::batch::{
    pipeline_jobs, place_and_build_pipeline_jobs, rotated_chain, run_batch, run_batch_adaptive,
    run_batch_recorded, BatchJob,
};
use crate::coordinator::topology::{LoadAwarePolicy, Topology};
use crate::coordinator::{ingest_object, object_bytes, reconstruct, ClassicalJob, PipelineJob};
use crate::gf::{Gf256, Gf65536, GfElem};
use crate::metrics::{BenchJson, Candle, Recorder};
use crate::resources::{CostModelHandle, NodeProfile, ProfileCost, UniformCost};
use crate::storage::{BlockKey, ObjectId, ReplicaPlacement};
use crate::util::SplitMix64;

/// Evaluation code parameters: the paper's (16, 11).
pub const N: usize = 16;
/// Message length of the evaluation code.
pub const K: usize = 11;
/// Default network buffer (one streaming frame, matches the AOT artifacts).
pub const BUF_BYTES: usize = 65536;

/// The three implementations of Table II / Fig. 4.
#[derive(Copy, Clone, PartialEq, Eq, Debug)]
pub enum Impl {
    /// Classical (16,11) Cauchy Reed-Solomon (*CEC*).
    Cec,
    /// 8-bit RapidRAID (*RR8*).
    Rr8,
    /// 16-bit RapidRAID (*RR16*).
    Rr16,
}

impl std::fmt::Display for Impl {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Impl::Cec => write!(f, "CEC"),
            Impl::Rr8 => write!(f, "RR8"),
            Impl::Rr16 => write!(f, "RR16"),
        }
    }
}

/// Parity rows of an arbitrary (n, k) Cauchy code as u32 (for node
/// commands).
pub fn parity_rows_for(n: usize, k: usize) -> anyhow::Result<Vec<Vec<u32>>> {
    let code = ClassicalCode::<Gf256>::new(n, k)?;
    let p = code.parity_matrix();
    Ok((0..p.rows())
        .map(|i| p.row(i).iter().map(|c| c.to_u32()).collect())
        .collect())
}

/// Parity rows of the (N, K) Cauchy code as u32 (for node commands).
pub fn cec_parity_rows() -> Vec<Vec<u32>> {
    parity_rows_for(N, K).expect("(16,11) code")
}

/// The evaluation RR8 code (coefficients via the documented search seed).
pub fn rr8_code() -> RapidRaidCode<Gf256> {
    RapidRaidCode::<Gf256>::with_seed(N, K, 5).expect("(16,11) rr8")
}

/// The evaluation RR16 code.
pub fn rr16_code() -> RapidRaidCode<Gf65536> {
    RapidRaidCode::<Gf65536>::with_seed(N, K, 12).expect("(16,11) rr16")
}

// ---------------------------------------------------------------------------
// Table II — CPU-only coding time
// ---------------------------------------------------------------------------

/// In-process encode of one (16,11) object with no network I/O, mirroring
/// the paper's Table II methodology ("the execution of the n = 16 nodes
/// occur in a single node, avoiding all the network I/O").
pub fn cpu_encode_once(backend: &BackendHandle, imp: Impl, object: &[Vec<u8>]) -> Duration {
    // Table II measures real compute, so this path is pinned to a wall
    // clock regardless of any simulation preset.
    let clock = RealClock::new();
    let block_bytes = object[0].len();
    let t0 = clock.now();
    match imp {
        Impl::Cec => {
            let rows = cec_parity_rows();
            let mut offset = 0;
            while offset < block_bytes {
                let len = BUF_BYTES.min(block_bytes - offset);
                let bufs: Vec<&[u8]> =
                    object.iter().map(|b| &b[offset..offset + len]).collect();
                let parity = backend.gemm(Width::W8, &rows, &bufs).expect("gemm");
                std::hint::black_box(parity);
                offset += len;
            }
        }
        Impl::Rr8 => cpu_pipeline_chain(backend, Width::W8, &rr8_schedule(), object),
        Impl::Rr16 => cpu_pipeline_chain(backend, Width::W16, &rr16_schedule(), object),
    }
    clock.now().saturating_sub(t0)
}

fn rr8_schedule() -> Vec<(Vec<usize>, Vec<u32>, Vec<u32>)> {
    rr8_code()
        .schedule()
        .iter()
        .map(|s| {
            (
                s.locals.clone(),
                s.psi.iter().map(|c| c.to_u32()).collect(),
                s.xi.iter().map(|c| c.to_u32()).collect(),
            )
        })
        .collect()
}

fn rr16_schedule() -> Vec<(Vec<usize>, Vec<u32>, Vec<u32>)> {
    rr16_code()
        .schedule()
        .iter()
        .map(|s| {
            (
                s.locals.clone(),
                s.psi.iter().map(|c| c.to_u32()).collect(),
                s.xi.iter().map(|c| c.to_u32()).collect(),
            )
        })
        .collect()
}

fn cpu_pipeline_chain(
    backend: &BackendHandle,
    width: Width,
    schedule: &[(Vec<usize>, Vec<u32>, Vec<u32>)],
    object: &[Vec<u8>],
) {
    let block_bytes = object[0].len();
    let mut offset = 0;
    while offset < block_bytes {
        let len = BUF_BYTES.min(block_bytes - offset);
        let mut x = vec![0u8; len];
        for (locals, psi, xi) in schedule {
            let locs: Vec<&[u8]> = locals.iter().map(|&b| &object[b][offset..offset + len]).collect();
            let (x_next, c) = backend.pipeline_step(width, &x, &locs, psi, xi).expect("step");
            std::hint::black_box(c);
            x = x_next;
        }
        offset += len;
    }
}

/// Table II: CPU-only coding time of CEC / RR8 / RR16 for one object of
/// K×`block_bytes` (the paper used 11 × 64 MB on three CPUs; we sweep the
/// implementation on the host CPU — see DESIGN.md §3).
pub fn table2_cpu(
    backend: &BackendHandle,
    block_bytes: usize,
    out: &mut dyn Write,
) -> anyhow::Result<BenchJson> {
    let wall = RealClock::new();
    writeln!(out, "# Table II — CPU-only (16,11) coding time, no network I/O")?;
    writeln!(
        out,
        "# object: {} x {} MiB = {} MiB; backend: {}",
        K,
        block_bytes >> 20,
        (K * block_bytes) >> 20,
        backend.name()
    )?;
    let object: Vec<Vec<u8>> = (0..K)
        .map(|i| crate::coordinator::object_bytes(ObjectId(0xC0DE), i, block_bytes))
        .collect();
    let mut report = BenchJson::new(format!("table2-{}", backend.name()))
        .param("block_bytes", block_bytes)
        .param("n", N)
        .param("k", K);
    writeln!(out, "{:>6} {:>12} {:>12}", "impl", "seconds", "MiB/s")?;
    for imp in [Impl::Cec, Impl::Rr8, Impl::Rr16] {
        let mut times: Vec<Duration> = (0..3)
            .map(|_| cpu_encode_once(backend, imp, &object))
            .collect();
        times.sort_unstable();
        let med = times[times.len() / 2];
        writeln!(
            out,
            "{:>6} {:>12.3} {:>12.1}",
            imp.to_string(),
            med.as_secs_f64(),
            (K * block_bytes) as f64 / (1 << 20) as f64 / med.as_secs_f64()
        )?;
        report.series.push(Candle {
            name: imp.to_string(),
            samples: times,
        });
    }
    report.wall = wall.now();
    Ok(report)
}

// ---------------------------------------------------------------------------
// Table II (simulated) — the `table2-sim` preset: compute charged in
// virtual time
// ---------------------------------------------------------------------------

/// One row of the `table2-sim` comparison.
#[derive(Clone, Debug, PartialEq)]
pub struct Table2SimRow {
    /// Code length.
    pub n: usize,
    /// Message length.
    pub k: usize,
    /// Cost-model label (`uniform` / `ec2-mix`).
    pub cost: &'static str,
    /// Virtual coding time of the classical atomic encoding.
    pub classical: Duration,
    /// Virtual coding time of the pipelined RapidRAID encoding.
    pub pipelined: Duration,
}

impl Table2SimRow {
    /// Classical/pipelined coding-time ratio (> 1 ⇒ pipelining wins).
    pub fn ratio(&self) -> f64 {
        self.classical.as_secs_f64() / self.pipelined.as_secs_f64()
    }
}

/// The `table2-sim` preset: the paper's Table-II coding-time comparison
/// reproduced *inside the discrete-event simulator*, with per-node GF
/// compute charged in virtual time.
///
/// Classical (atomic Cauchy-RS) vs pipelined (RapidRAID RR8) archival of
/// one object, under k=8/n=11 and k=16/n=22, on two cost models:
/// [`UniformCost::calibrated`] (homogeneous EC2-small hardware) and a
/// heterogeneous [`ProfileCost`] over [`NodeProfile::ec2_mix`]
/// (small/medium/large classes round-robin). Runs on a `SimClock` TPC
/// topology with jitter disabled, so the virtual timeline — and hence
/// every reported duration — is an exact function of `(block_bytes,
/// seed)`: the same invocation reproduces tick-identical rows.
pub fn table2_sim(
    backend: &BackendHandle,
    block_bytes: usize,
    seed: u64,
    out: &mut dyn Write,
) -> anyhow::Result<(Vec<Table2SimRow>, BenchJson)> {
    table2_sim_calibrated(backend, block_bytes, seed, None, RuntimeKind::Auto, out)
}

/// [`table2_sim`] with the compute baseline swapped for measured rates
/// (`--calibration` / `RAPIDRAID_CALIBRATION` on the CLI): `None` keeps
/// the built-in [`UniformCost::calibrated`] constants, `Some(rates)` —
/// typically [`UniformCost::from_measured`] over a `gf-hotpath` report —
/// prices both cost models over this machine's throughput. The report
/// records which baseline ran under the `calibration` param. `runtime`
/// picks the dataplane execution runtime (`--runtime` on the CLI; `Auto`
/// resolves to the multiplexed driver on these SimClock presets) and is
/// recorded under the `runtime` param — the virtual timeline is
/// runtime-invariant, so this is a parity axis, not a result axis.
pub fn table2_sim_calibrated(
    backend: &BackendHandle,
    block_bytes: usize,
    seed: u64,
    calibration: Option<UniformCost>,
    runtime: RuntimeKind,
    out: &mut dyn Write,
) -> anyhow::Result<(Vec<Table2SimRow>, BenchJson)> {
    let wall = RealClock::new();
    let base_rates = calibration
        .clone()
        .unwrap_or_else(UniformCost::calibrated);
    let mut report = BenchJson::new("table2-sim")
        .param("block_bytes", block_bytes)
        .param("seed", seed)
        .param("runtime", runtime.name())
        .param(
            "calibration",
            if calibration.is_some() { "measured" } else { "builtin" },
        );
    writeln!(
        out,
        "# Table II (simulated) — classical vs pipelined virtual coding time, compute charged"
    )?;
    writeln!(
        out,
        "# SimClock TPC topology (jitter off), block={} KiB, code seed {seed}, backend={}",
        block_bytes >> 10,
        backend.name()
    )?;
    writeln!(
        out,
        "{:>3} {:>3} {:>8} {:>12} {:>12} {:>7}",
        "n", "k", "cost", "classical_s", "pipelined_s", "ratio"
    )?;

    // Fresh per-run cluster: virtual timelines must not share NIC state.
    let sim_cluster = |n: usize, cost: CostModelHandle| -> Cluster {
        let mut spec = ClusterSpec::tpc(n).sim().with_cost(cost).with_runtime(runtime);
        // Table II isolates compute: jitter off keeps the discrete-event
        // timeline an exact function of the inputs.
        spec.jitter = Duration::ZERO;
        Cluster::start(spec)
    };
    let costs: Vec<(&'static str, CostModelHandle)> = vec![
        ("uniform", std::sync::Arc::new(base_rates.clone())),
        (
            "ec2-mix",
            std::sync::Arc::new(ProfileCost::new(base_rates, NodeProfile::ec2_mix())?),
        ),
    ];

    let stages = Recorder::new();
    let mut rows = Vec::new();
    let mut id = 0u64; // distinct object id per run
    for (n, k) in [(11usize, 8usize), (22, 16)] {
        for (cost_name, cost) in &costs {
            let cost_name = *cost_name;
            let tag = format!("n{n}k{k}/{cost_name}");

            // Classical: fresh cluster, one atomic Cauchy-RS job.
            let cluster = sim_cluster(n, cost.clone());
            id += 1;
            let placement =
                ReplicaPlacement::new(ObjectId(0x7AB2_0000 + id), k, (0..n).collect())?;
            ingest_object(&cluster, &placement, block_bytes)?;
            let job = BatchJob::Classical(ClassicalJob {
                object: placement.object,
                width: Width::W8,
                parity_rows: parity_rows_for(n, k)?,
                source_nodes: placement.chain[..k].to_vec(),
                coding_node: placement.chain[k],
                parity_nodes: placement.chain[k..].to_vec(),
                buf_bytes: BUF_BYTES,
                block_bytes,
            });
            let prefix = format!("{tag}/CEC/");
            let times =
                run_batch_recorded(&cluster, backend, &[job], Some((&stages, &prefix)))?;
            let classical = times[0];

            // Pipelined: fresh cluster, one RapidRAID RR8 chain.
            let cluster = sim_cluster(n, cost.clone());
            id += 1;
            let placement =
                ReplicaPlacement::new(ObjectId(0x7AB2_0000 + id), k, (0..n).collect())?;
            ingest_object(&cluster, &placement, block_bytes)?;
            let code = RapidRaidCode::<Gf256>::with_seed(n, k, seed)?;
            let job = BatchJob::Pipeline(PipelineJob::from_code(
                &code,
                &placement,
                BUF_BYTES,
                block_bytes,
            )?);
            let prefix = format!("{tag}/RR8/");
            let times =
                run_batch_recorded(&cluster, backend, &[job], Some((&stages, &prefix)))?;
            let pipelined = times[0];

            let row = Table2SimRow {
                n,
                k,
                cost: cost_name,
                classical,
                pipelined,
            };
            writeln!(
                out,
                "{:>3} {:>3} {:>8} {:>12.4} {:>12.4} {:>6.2}x",
                row.n,
                row.k,
                row.cost,
                row.classical.as_secs_f64(),
                row.pipelined.as_secs_f64(),
                row.ratio()
            )?;
            report.series.push(Candle {
                name: format!("{tag}/classical"),
                samples: vec![classical],
            });
            report.series.push(Candle {
                name: format!("{tag}/pipelined"),
                samples: vec![pipelined],
            });
            rows.push(row);
        }
    }
    writeln!(
        out,
        "# per-stage spans (…/fold.compute and …/gemm.compute are the charged CPU ticks):"
    )?;
    for c in stages.candles() {
        writeln!(out, "# {}", c.report())?;
    }
    report.spans = stages.candles();
    report.wall = wall.now();
    Ok((rows, report))
}

// ---------------------------------------------------------------------------
// topo-sim — the pipeline-shape shootout: chain vs tree vs hybrid
// ---------------------------------------------------------------------------

/// One cell of the `topo-sim` shootout.
#[derive(Clone, Debug, PartialEq)]
pub struct TopoSimRow {
    /// Code length.
    pub n: usize,
    /// Message length.
    pub k: usize,
    /// Cost-model label (`uniform` / `ec2-mix`).
    pub cost: &'static str,
    /// Pipeline shape of this cell.
    pub topology: Topology,
    /// True for the load-aware placed cell (the policy chose shape and
    /// placement on a clamped cluster; not comparable to the fixed cells).
    pub placed: bool,
    /// Virtual coding time of the shaped pipeline.
    pub coding: Duration,
}

/// The shapes the shootout compares.
pub fn topo_sim_topologies() -> Vec<Topology> {
    vec![
        Topology::Chain,
        Topology::Tree { fanout: 2 },
        Topology::Hybrid {
            chain_prefix: 4,
            tree_fanout: 2,
        },
    ]
}

/// The `topo-sim` preset: archive the same object through chain, tree and
/// hybrid pipelines — k=8/n=11 and k=16/n=22, under
/// [`UniformCost::calibrated`] and a heterogeneous [`ProfileCost`] over
/// [`NodeProfile::ec2_mix`] — on a jitter-free `SimClock` TPC topology, so
/// every reported duration is an exact function of `(block_bytes, seed)`.
/// Each cell is decode-verified through the topology-composed generator:
/// the reconstructed object must equal the ingested bytes, whatever the
/// shape. The chain's hop tail grows with n while a tree's grows with its
/// depth, so under stragglers (and even uniform compute at paper-scale n)
/// the non-chain shapes win — exactly the §VII trade this preset
/// quantifies.
pub fn topo_sim(
    backend: &BackendHandle,
    block_bytes: usize,
    seed: u64,
    out: &mut dyn Write,
) -> anyhow::Result<(Vec<TopoSimRow>, BenchJson)> {
    topo_sim_calibrated(backend, block_bytes, seed, None, RuntimeKind::Auto, out)
}

/// [`topo_sim`] with the compute baseline swapped for measured rates and
/// the execution runtime selectable — same contract as
/// [`table2_sim_calibrated`].
pub fn topo_sim_calibrated(
    backend: &BackendHandle,
    block_bytes: usize,
    seed: u64,
    calibration: Option<UniformCost>,
    runtime: RuntimeKind,
    out: &mut dyn Write,
) -> anyhow::Result<(Vec<TopoSimRow>, BenchJson)> {
    let wall = RealClock::new();
    let base_rates = calibration
        .clone()
        .unwrap_or_else(UniformCost::calibrated);
    let mut report = BenchJson::new("topo-sim")
        .param("block_bytes", block_bytes)
        .param("seed", seed)
        .param("runtime", runtime.name())
        .param(
            "calibration",
            if calibration.is_some() { "measured" } else { "builtin" },
        );
    writeln!(
        out,
        "# topo-sim — pipeline-shape shootout: chain vs tree vs hybrid virtual coding time"
    )?;
    writeln!(
        out,
        "# SimClock TPC topology (jitter off), block={} KiB, code seed {seed}, backend={}",
        block_bytes >> 10,
        backend.name()
    )?;
    writeln!(
        out,
        "{:>3} {:>3} {:>8} {:>12} {:>12} {:>9}",
        "n", "k", "cost", "topology", "coding_s", "vs_chain"
    )?;

    // Fresh per-cell cluster: virtual timelines must not share NIC or
    // meter state.
    let sim_cluster = |n: usize, cost: CostModelHandle| -> Cluster {
        let mut spec = ClusterSpec::tpc(n).sim().with_cost(cost).with_runtime(runtime);
        spec.jitter = Duration::ZERO;
        Cluster::start(spec)
    };
    let costs: Vec<(&'static str, CostModelHandle)> = vec![
        ("uniform", std::sync::Arc::new(base_rates.clone())),
        (
            "ec2-mix",
            std::sync::Arc::new(ProfileCost::new(base_rates, NodeProfile::ec2_mix())?),
        ),
    ];

    let stages = Recorder::new();
    let mut rows: Vec<TopoSimRow> = Vec::new();
    let mut id = 0u64;
    for (n, k) in [(11usize, 8usize), (22, 16)] {
        let code = RapidRaidCode::<Gf256>::with_seed(n, k, seed)?;
        for (cost_name, cost) in &costs {
            let cost_name = *cost_name;
            let mut chain_time: Option<Duration> = None;
            for topo in topo_sim_topologies() {
                let cluster = sim_cluster(n, cost.clone());
                id += 1;
                let placement =
                    ReplicaPlacement::new(ObjectId(0x7090_0000 + id), k, (0..n).collect())?;
                let blocks = ingest_object(&cluster, &placement, block_bytes)?;
                let job = BatchJob::Pipeline(PipelineJob::from_code_with_topology(
                    &code,
                    &placement,
                    topo,
                    BUF_BYTES,
                    block_bytes,
                )?);
                let tag = format!("n{n}k{k}/{cost_name}/{topo}");
                let prefix = format!("{tag}/");
                let times =
                    run_batch_recorded(&cluster, backend, &[job], Some((&stages, &prefix)))?;
                let coding = times[0];

                // Decode verification through the topology generator: the
                // shape must never change the object.
                let tcode = TopologyCode::new(code.clone(), topo.shape(n)?)?;
                let rec =
                    reconstruct(&cluster, &tcode, &placement.chain, placement.object, backend)?;
                anyhow::ensure!(
                    rec == blocks,
                    "topo-sim {tag}: decoded object differs from ingested bytes"
                );

                if topo == Topology::Chain {
                    chain_time = Some(coding);
                }
                let vs_chain = chain_time
                    .map(|c| format!("{:.2}x", c.as_secs_f64() / coding.as_secs_f64()))
                    .unwrap_or_else(|| "-".into());
                writeln!(
                    out,
                    "{:>3} {:>3} {:>8} {:>12} {:>12.4} {:>9}",
                    n,
                    k,
                    cost_name,
                    topo.to_string(),
                    coding.as_secs_f64(),
                    vs_chain
                )?;
                report.series.push(Candle {
                    name: tag,
                    samples: vec![coding],
                });
                rows.push(TopoSimRow {
                    n,
                    k,
                    cost: cost_name,
                    topology: topo,
                    placed: false,
                    coding,
                });
            }

            // Load-aware placed cell: one node's NIC clamped to a tenth —
            // the policy must pick a non-chain shape on its own and sink
            // the clamped node to a leaf slot. This drives
            // `place_and_build_pipeline_jobs` (per-object shape AND
            // placement) end to end; the cell is reported separately
            // because its cluster state differs from the fixed cells.
            let cluster = sim_cluster(n, cost.clone());
            cluster.congest(
                2,
                &CongestionSpec {
                    bytes_per_sec: 12.5e6,
                    extra_latency: Duration::ZERO,
                    jitter: Duration::ZERO,
                },
            );
            id += 1;
            let object = ObjectId(0x7090_0000 + id);
            let placed = place_and_build_pipeline_jobs(
                &cluster,
                &LoadAwarePolicy::default(),
                &code,
                &[object],
                Topology::Chain,
                BUF_BYTES,
                block_bytes,
            )?;
            let (placement, job) = placed.into_iter().next().expect("one placed object");
            let topo = match &job {
                BatchJob::Pipeline(p) => p.topology,
                other => unreachable!("placed builder emits pipeline jobs, got {other:?}"),
            };
            anyhow::ensure!(
                topo != Topology::Chain,
                "load-aware policy kept the chain despite a 10x NIC spread"
            );
            let tag = format!("n{n}k{k}/{cost_name}/load-aware");
            let prefix = format!("{tag}/");
            let times = run_batch_recorded(&cluster, backend, &[job], Some((&stages, &prefix)))?;
            let coding = times[0];
            let expect: Vec<Vec<u8>> =
                (0..k).map(|i| object_bytes(object, i, block_bytes)).collect();
            let tcode = TopologyCode::new(code.clone(), topo.shape(n)?)?;
            let rec = reconstruct(&cluster, &tcode, &placement.chain, object, backend)?;
            anyhow::ensure!(rec == expect, "topo-sim {tag}: placed cell decode mismatch");
            writeln!(
                out,
                "{:>3} {:>3} {:>8} {:>12} {:>12.4} {:>9}",
                n,
                k,
                cost_name,
                "placed",
                coding.as_secs_f64(),
                "-"
            )?;
            writeln!(
                out,
                "# load-aware {tag}: policy chose {topo}, clamped node on a leaf slot"
            )?;
            report.series.push(Candle {
                name: tag,
                samples: vec![coding],
            });
            rows.push(TopoSimRow {
                n,
                k,
                cost: cost_name,
                topology: topo,
                placed: true,
                coding,
            });
        }
    }
    writeln!(
        out,
        "# per-stage spans (…/fold.compute are the charged CPU ticks; fan-out copies included):"
    )?;
    for c in stages.candles() {
        writeln!(out, "# {}", c.report())?;
    }
    report.spans = stages.candles();
    report.wall = wall.now();
    Ok((rows, report))
}

// ---------------------------------------------------------------------------
// straggler-sim — static shapes vs the adaptive control plane
// ---------------------------------------------------------------------------

/// One cell of the `straggler-sim` comparison.
#[derive(Clone, Debug, PartialEq)]
pub struct StragglerSimRow {
    /// Code length.
    pub n: usize,
    /// Message length.
    pub k: usize,
    /// Cell label: a static shape (`chain` / `tree:2` / `hybrid:4:2`) or
    /// `adaptive`.
    pub cell: String,
    /// True for the adaptive (control-plane) cell.
    pub adaptive: bool,
    /// End-to-end virtual makespan of the cell's whole batch (ingest
    /// through last store, read off the cluster clock).
    pub makespan: Duration,
}

/// Spare nodes beyond `n` in every straggler-sim pool — the headroom the
/// adaptive policy can route into; the static cells ignore them.
pub const STRAGGLER_SIM_SPARES: usize = 5;
/// Objects archived per cell (window 1 on the adaptive cell, so every
/// object re-ranks against the load the previous wave left behind).
pub const STRAGGLER_SIM_OBJECTS: usize = 3;
/// Node ids whose NICs get the 10x congestion clamp. Both sit inside the
/// first `n` ids for both code sizes, so the identity-placed static cells
/// always eat them.
const STRAGGLER_NET: [usize; 2] = [1, 4];
/// Node id re-priced as a `THINCLIENT`-class CPU straggler (the long-run
/// harness's CPU-churn mechanism, applied statically here so the timeline
/// stays a pure function of the config).
const STRAGGLER_CPU: usize = 2;

/// The `straggler-sim` preset: the adaptive control plane against every
/// static pipeline shape on a deliberately lopsided cluster. For each code
/// size (k=8/n=11 and k=16/n=22) the pool is `n + 5` nodes on a jitter-free
/// `SimClock` TPC topology with heterogeneous [`NodeProfile::ec2_mix`]
/// compute, two NICs clamped to a tenth and one node re-priced
/// `THINCLIENT` — all three stragglers inside the first `n` ids. The three
/// static cells (`chain`, `tree:2`, `hybrid:4:2`) archive
/// [`STRAGGLER_SIM_OBJECTS`] objects on the identity placement `0..n`
/// (stragglers included, as a placement-blind coordinator would); the
/// adaptive cell runs the same objects through
/// [`run_batch_adaptive`] with [`LoadAwarePolicy::adaptive`], whose
/// plan-boundary [`LoadSnapshot`](crate::control::LoadSnapshot)s rank the
/// stragglers out of the selection and pick the predicted-fastest shape.
/// Every cell decode-verifies each object through the topology-composed
/// generator before its makespan counts. Deterministic: same
/// `(block_bytes, seed)` ⇒ tick-identical rows on either runtime.
pub fn straggler_sim(
    backend: &BackendHandle,
    block_bytes: usize,
    seed: u64,
    runtime: RuntimeKind,
    out: &mut dyn Write,
) -> anyhow::Result<(Vec<StragglerSimRow>, BenchJson)> {
    straggler_sim_calibrated(backend, block_bytes, seed, None, runtime, out)
}

/// [`straggler_sim`] with the compute baseline swapped for measured rates —
/// same contract as [`table2_sim_calibrated`].
pub fn straggler_sim_calibrated(
    backend: &BackendHandle,
    block_bytes: usize,
    seed: u64,
    calibration: Option<UniformCost>,
    runtime: RuntimeKind,
    out: &mut dyn Write,
) -> anyhow::Result<(Vec<StragglerSimRow>, BenchJson)> {
    let wall = RealClock::new();
    let base_rates = calibration
        .clone()
        .unwrap_or_else(UniformCost::calibrated);
    let mut report = BenchJson::new("straggler-sim")
        .param("block_bytes", block_bytes)
        .param("seed", seed)
        .param("objects", STRAGGLER_SIM_OBJECTS)
        .param("spares", STRAGGLER_SIM_SPARES)
        .param("runtime", runtime.name())
        .param(
            "calibration",
            if calibration.is_some() { "measured" } else { "builtin" },
        );
    writeln!(
        out,
        "# straggler-sim — adaptive control plane vs static shapes on a lopsided cluster"
    )?;
    writeln!(
        out,
        "# SimClock TPC (jitter off), ec2-mix compute, NIC clamp on {STRAGGLER_NET:?}, \
         thinclient CPU on {STRAGGLER_CPU}, block={} KiB, seed {seed}, runtime={}",
        block_bytes >> 10,
        runtime.name()
    )?;
    writeln!(
        out,
        "{:>3} {:>3} {:>12} {:>12} {:>9}",
        "n", "k", "cell", "makespan_s", "vs_best"
    )?;

    // Fresh cluster (and fresh cost model — `set_profile` is stateful) per
    // cell: virtual timelines must not share NIC, meter or profile state.
    let clamp = CongestionSpec {
        bytes_per_sec: 12.5e6,
        extra_latency: Duration::ZERO,
        jitter: Duration::ZERO,
    };
    let lopsided_cluster = |pool: usize| -> anyhow::Result<(Cluster, crate::clock::ClockHandle)> {
        let cost = std::sync::Arc::new(ProfileCost::new(
            base_rates.clone(),
            NodeProfile::ec2_mix(),
        )?);
        cost.set_profile(STRAGGLER_CPU, NodeProfile::THINCLIENT);
        let clock = SimClock::handle();
        let mut spec = ClusterSpec::tpc(pool)
            .with_clock(clock.clone())
            .with_cost(cost)
            .with_runtime(runtime);
        spec.jitter = Duration::ZERO;
        let cluster = Cluster::start(spec);
        for &node in &STRAGGLER_NET {
            cluster.congest(node, &clamp);
        }
        Ok((cluster, clock))
    };

    let mut rows: Vec<StragglerSimRow> = Vec::new();
    let mut id = 0u64;
    for (n, k) in [(11usize, 8usize), (22, 16)] {
        let pool = n + STRAGGLER_SIM_SPARES;
        let code = RapidRaidCode::<Gf256>::with_seed(n, k, seed)?;
        let mut size_rows: Vec<StragglerSimRow> = Vec::new();

        // Static cells: identity placement 0..n (stragglers included).
        for topo in topo_sim_topologies() {
            let (cluster, clock) = lopsided_cluster(pool)?;
            let t0 = clock.now();
            let mut placements = Vec::with_capacity(STRAGGLER_SIM_OBJECTS);
            let mut expected = Vec::with_capacity(STRAGGLER_SIM_OBJECTS);
            for _ in 0..STRAGGLER_SIM_OBJECTS {
                id += 1;
                let placement =
                    ReplicaPlacement::new(ObjectId(0x57A6_0000 + id), k, (0..n).collect())?;
                expected.push(ingest_object(&cluster, &placement, block_bytes)?);
                placements.push(placement);
            }
            let jobs = pipeline_jobs(&code, &placements, topo, BUF_BYTES, block_bytes)?;
            run_batch(&cluster, backend, &jobs)?;
            let makespan = clock.now().saturating_sub(t0);
            let tcode = TopologyCode::new(code.clone(), topo.shape(n)?)?;
            for (p, blocks) in placements.iter().zip(&expected) {
                let rec = reconstruct(&cluster, &tcode, &p.chain, p.object, backend)?;
                anyhow::ensure!(
                    rec == *blocks,
                    "straggler-sim n{n}k{k}/{topo}: decode mismatch for {:?}",
                    p.object
                );
            }
            size_rows.push(StragglerSimRow {
                n,
                k,
                cell: topo.to_string(),
                adaptive: false,
                makespan,
            });
        }

        // Adaptive cell: same objects' worth of work, but the control plane
        // places, shapes and re-ranks wave by wave.
        let (cluster, clock) = lopsided_cluster(pool)?;
        let objects: Vec<ObjectId> = (0..STRAGGLER_SIM_OBJECTS)
            .map(|_| {
                id += 1;
                ObjectId(0x57A6_0000 + id)
            })
            .collect();
        let t0 = clock.now();
        let runs = run_batch_adaptive(
            &cluster,
            backend,
            &LoadAwarePolicy::adaptive(),
            &code,
            &objects,
            Topology::Chain,
            BUF_BYTES,
            block_bytes,
            1,
        )?;
        let makespan = clock.now().saturating_sub(t0);
        for run in &runs {
            let expect: Vec<Vec<u8>> = (0..k)
                .map(|i| object_bytes(run.placement.object, i, block_bytes))
                .collect();
            let tcode = TopologyCode::new(code.clone(), run.topology.shape(n)?)?;
            let rec =
                reconstruct(&cluster, &tcode, &run.placement.chain, run.placement.object, backend)?;
            anyhow::ensure!(
                rec == expect,
                "straggler-sim n{n}k{k}/adaptive: decode mismatch for {:?}",
                run.placement.object
            );
        }
        size_rows.push(StragglerSimRow {
            n,
            k,
            cell: "adaptive".into(),
            adaptive: true,
            makespan,
        });

        let best = size_rows
            .iter()
            .map(|r| r.makespan)
            .min()
            .expect("non-empty cells");
        for r in &size_rows {
            writeln!(
                out,
                "{:>3} {:>3} {:>12} {:>12.4} {:>8.2}x",
                r.n,
                r.k,
                r.cell,
                r.makespan.as_secs_f64(),
                r.makespan.as_secs_f64() / best.as_secs_f64()
            )?;
            report.series.push(Candle {
                name: format!("n{n}k{k}/{}", r.cell),
                samples: vec![r.makespan],
            });
        }
        let best_static = size_rows
            .iter()
            .filter(|r| !r.adaptive)
            .map(|r| r.makespan)
            .min()
            .expect("three static cells");
        let adaptive = size_rows
            .iter()
            .find(|r| r.adaptive)
            .expect("one adaptive cell")
            .makespan;
        writeln!(
            out,
            "# n{n}k{k}: adaptive {:.2}x vs best static",
            best_static.as_secs_f64() / adaptive.as_secs_f64()
        )?;
        rows.extend(size_rows);
    }
    report.wall = wall.now();
    Ok((rows, report))
}

// ---------------------------------------------------------------------------
// Fig. 4 — cluster coding times
// ---------------------------------------------------------------------------

/// Build a cluster for a preset name. A `-sim` suffix (e.g. `tpc-sim`)
/// runs the identical topology on a discrete-event `SimClock`: reported
/// times are then *virtual* network times (these presets keep the default
/// `ZeroCost` model, so compute stays free — [`table2_sim`] is the preset
/// that charges it), the run costs milliseconds of wall clock, and a
/// paper-scale sweep becomes CI-affordable.
fn cluster_for(preset: &str, nodes: usize) -> anyhow::Result<Cluster> {
    let (base, sim) = match preset.strip_suffix("-sim") {
        Some(b) => (b, true),
        None => (preset, false),
    };
    let spec = match base {
        "tpc" => ClusterSpec::tpc(nodes),
        "ec2" => ClusterSpec::ec2(nodes),
        "test" => ClusterSpec::test(nodes),
        other => anyhow::bail!("unknown preset {other} (tpc|ec2|test, optional -sim suffix)"),
    };
    Ok(Cluster::start(if sim { spec.sim() } else { spec }))
}

/// Build the jobs for `objects` concurrent encodings of implementation
/// `imp`, with roles rotated so object i starts at node i (the paper's
/// 16-object experiment layout). Ingests the objects first.
pub fn build_jobs(
    cluster: &Cluster,
    imp: Impl,
    objects: usize,
    block_bytes: usize,
    id_base: u64,
) -> anyhow::Result<Vec<BatchJob>> {
    let nodes = cluster.len();
    let mut jobs = Vec::with_capacity(objects);
    for i in 0..objects {
        let object = ObjectId(id_base + i as u64);
        let chain = rotated_chain(nodes, N, i);
        let placement = ReplicaPlacement::new(object, K, chain.clone())?;
        ingest_object(cluster, &placement, block_bytes)?;
        let job = match imp {
            Impl::Cec => {
                // coding node = first parity holder (keeps one parity local;
                // downloads all k source blocks): eq. (1) layout.
                BatchJob::Classical(ClassicalJob {
                    object,
                    width: Width::W8,
                    parity_rows: cec_parity_rows(),
                    source_nodes: chain[..K].to_vec(),
                    coding_node: chain[K],
                    parity_nodes: chain[K..].to_vec(),
                    buf_bytes: BUF_BYTES,
                    block_bytes,
                })
            }
            Impl::Rr8 => BatchJob::Pipeline(PipelineJob::from_code(
                &rr8_code(),
                &placement,
                BUF_BYTES,
                block_bytes,
            )?),
            Impl::Rr16 => BatchJob::Pipeline(PipelineJob::from_code(
                &rr16_code(),
                &placement,
                BUF_BYTES,
                block_bytes,
            )?),
        };
        jobs.push(job);
    }
    Ok(jobs)
}

/// Fig. 4: coding times of CEC/RR8/RR16 for `objects` concurrent encodings
/// on a 16-node cluster of the given preset; `samples` repetitions feed the
/// candles (median, 25–75%, min–max) like the paper's box plots.
pub fn fig4_coding_times(
    backend: &BackendHandle,
    preset: &str,
    objects: usize,
    block_bytes: usize,
    samples: usize,
    out: &mut dyn Write,
) -> anyhow::Result<BenchJson> {
    let wall = RealClock::new();
    writeln!(
        out,
        "# Fig. 4{} — {} object(s), preset={preset}, block={} MiB, backend={}",
        if objects == 1 { "a" } else { "b" },
        objects,
        block_bytes >> 20,
        backend.name()
    )?;
    let rec = Recorder::new();
    // Separate recorder for the executor's per-step spans so the stage
    // breakdown never pollutes the end-to-end candle series.
    let stages = Recorder::new();
    let mut id_base = 1000;
    for imp in [Impl::Cec, Impl::Rr8, Impl::Rr16] {
        for _ in 0..samples {
            // fresh cluster per sample: no leftover queue state
            let cluster = cluster_for(preset, N)?;
            let jobs = build_jobs(&cluster, imp, objects, block_bytes, id_base)?;
            id_base += objects as u64;
            let prefix = format!("{imp}/");
            let times = run_batch_recorded(&cluster, backend, &jobs, Some((&stages, &prefix)))?;
            for t in times {
                rec.record(&imp.to_string(), t);
            }
        }
    }
    let candles = rec.candles();
    for c in &candles {
        writeln!(out, "{}", c.report())?;
    }
    writeln!(
        out,
        "# per-stage spans (dispatch → step completion; concurrent steps overlap):"
    )?;
    for c in stages.candles() {
        writeln!(out, "# {}", c.report())?;
    }
    let cec = rec.candle("CEC").unwrap();
    for name in ["RR8", "RR16"] {
        if let Some(c) = rec.candle(name) {
            writeln!(
                out,
                "# {name} vs CEC: {:.1}% coding-time reduction",
                100.0 * (1.0 - c.median().as_secs_f64() / cec.median().as_secs_f64())
            )?;
        }
    }
    let mut report = BenchJson::new(format!("fig4-{preset}-{objects}obj"))
        .param("preset", preset)
        .param("objects", objects)
        .param("block_bytes", block_bytes)
        .param("samples", samples);
    report.series = candles;
    report.spans = stages.candles();
    report.wall = wall.now();
    Ok(report)
}

// ---------------------------------------------------------------------------
// Fig. 5 — congested networks
// ---------------------------------------------------------------------------

/// Fig. 5: mean ± stddev coding time of CEC vs RR8 as 0..=`max_congested`
/// nodes get the netem profile (500 Mbps + 100±10 ms). `objects` = 1
/// reproduces Fig. 5a, 16 reproduces Fig. 5b. `preset` accepts the same
/// names as Fig. 4, including `-sim` variants (`tpc-sim` runs the sweep on
/// the discrete-event clock in wall-clock seconds).
pub fn fig5_congestion(
    backend: &BackendHandle,
    preset: &str,
    max_congested: usize,
    objects: usize,
    block_bytes: usize,
    samples: usize,
    out: &mut dyn Write,
) -> anyhow::Result<BenchJson> {
    let wall = RealClock::new();
    let mut report = BenchJson::new(format!("fig5-{preset}-{objects}obj"))
        .param("preset", preset)
        .param("max_congested", max_congested)
        .param("objects", objects)
        .param("block_bytes", block_bytes)
        .param("samples", samples);
    writeln!(
        out,
        "# Fig. 5{} — preset={preset}, netem profile on 0..={max_congested} nodes, {} object(s), block={} MiB",
        if objects == 1 { "a" } else { "b" },
        objects,
        block_bytes >> 20
    )?;
    writeln!(
        out,
        "{:>10} {:>6} {:>12} {:>12} {:>11} {:>11} {:>11}",
        "congested", "impl", "mean_s", "stddev_s", "transfer_s", "encode_s", "store_s"
    )?;
    let profile = CongestionSpec::paper_netem();
    let mut id_base = 100_000;
    for congested in 0..=max_congested {
        for imp in [Impl::Cec, Impl::Rr8] {
            let rec = Recorder::new();
            let stages = Recorder::new();
            for _ in 0..samples {
                let cluster = cluster_for(preset, N)?;
                for node in 0..congested {
                    cluster.congest(node, &profile);
                }
                let jobs = build_jobs(&cluster, imp, objects, block_bytes, id_base)?;
                id_base += objects as u64;
                let prefix = format!("{imp}/");
                let times =
                    run_batch_recorded(&cluster, backend, &jobs, Some((&stages, &prefix)))?;
                for t in times {
                    rec.record(&imp.to_string(), t);
                }
            }
            // Mean span per stage: transfers/stores exist only for the
            // classical plan; the pipelined plan is pure folds.
            let stage_mean = |name: &str| -> String {
                match stages.candle(&format!("{imp}/{name}")) {
                    Some(c) => format!("{:.3}", c.mean().as_secs_f64()),
                    None => "-".into(),
                }
            };
            let encode = match imp {
                Impl::Cec => stage_mean("gemm"),
                _ => stage_mean("fold"),
            };
            let c = rec.candle(&imp.to_string()).unwrap();
            writeln!(
                out,
                "{:>10} {:>6} {:>12.3} {:>12.4} {:>11} {:>11} {:>11}",
                congested,
                imp.to_string(),
                c.mean().as_secs_f64(),
                c.stddev_secs(),
                stage_mean("transfer"),
                encode,
                stage_mean("store")
            )?;
            report.series.push(Candle {
                name: format!("c{congested}/{imp}"),
                samples: c.samples,
            });
            for s in stages.candles() {
                report.spans.push(Candle {
                    name: format!("c{congested}/{}", s.name),
                    samples: s.samples,
                });
            }
        }
    }
    report.wall = wall.now();
    Ok(report)
}

// ---------------------------------------------------------------------------
// Fig. R — single-block repair, star vs pipelined
// ---------------------------------------------------------------------------

/// Single-block repair time of the evaluation (16,11) RR8 code on the TPC
/// preset, star vs pipelined, as 0..=`max_congested` chain nodes get the
/// paper's netem profile. A 17th node acts as the newcomer; the crashed
/// node (and hence the repaired block) is the last chain position so the
/// congested prefix stays among the survivors. Reports mean ± stddev per
/// strategy plus the pipelined speedup.
///
/// Same caveat as Fig. 5: at small blocks the +100 ms/hop netem latency
/// dominates the fold chain and can flip the comparison; the paper-faithful
/// block sizes (≥ 16 MiB) keep it bandwidth-bound.
pub fn fig_repair(
    backend: &BackendHandle,
    preset: &str,
    max_congested: usize,
    block_bytes: usize,
    samples: usize,
    out: &mut dyn Write,
) -> anyhow::Result<BenchJson> {
    use crate::coordinator::survey_coded;
    use crate::repair::{
        run_pipelined_repair, run_star_repair, PipelinedRepairJob, RepairJob, StarRepairJob,
    };

    let wall = RealClock::new();
    let mut report = BenchJson::new(format!("figR-{preset}"))
        .param("preset", preset)
        .param("max_congested", max_congested)
        .param("block_bytes", block_bytes)
        .param("samples", samples);
    let samples = samples.max(1);
    writeln!(
        out,
        "# Fig. R — (16,11) RR8 single-block repair, preset={preset}, netem on 0..={max_congested} nodes, block={} MiB",
        block_bytes >> 20
    )?;
    writeln!(
        out,
        "{:>10} {:>10} {:>12} {:>12} {:>9}",
        "congested", "strategy", "mean_s", "stddev_s", "speedup"
    )?;
    let profile = CongestionSpec::paper_netem();
    let code = rr8_code();
    let lost = N - 1; // crash the chain tail; congested nodes are survivors
    let newcomer = N; // the spare 17th node
    let mut id_base = 900_000u64;
    for congested in 0..=max_congested {
        let rec = Recorder::new();
        for _ in 0..samples {
            // one archived object per sample; both strategies repair the
            // SAME lost block on the same cluster state, so the comparison
            // is paired.
            let cluster = cluster_for(preset, N + 1)?;
            for node in 0..congested.min(N - 1) {
                cluster.congest(node, &profile);
            }
            let object = ObjectId(id_base);
            id_base += 1;
            let placement = ReplicaPlacement::new(object, K, (0..N).collect())?;
            ingest_object(&cluster, &placement, block_bytes)?;
            let job = PipelineJob::from_code(&code, &placement, BUF_BYTES, block_bytes)?;
            crate::coordinator::archive_pipeline(&cluster, backend, &job)?;
            cluster.fail_node(lost);
            let (avail, bb) = survey_coded(&cluster, &placement.chain, object);
            let rjob = RepairJob::from_code(
                &code, object, &placement.chain, lost, newcomer, &avail, BUF_BYTES, bb,
            )?;
            let t = run_star_repair(&cluster, backend, &StarRepairJob::new(rjob.clone()))?;
            rec.record("star", t);
            cluster
                .node(newcomer)
                .delete(crate::storage::BlockKey::coded(object, lost))?;
            let t = run_pipelined_repair(&cluster, backend, &PipelinedRepairJob::new(rjob))?;
            rec.record("pipelined", t);
        }
        let star = rec.candle("star").expect("star samples");
        let pipe = rec.candle("pipelined").expect("pipelined samples");
        for (name, c) in [("star", &star), ("pipelined", &pipe)] {
            let speedup = match name {
                "pipelined" => format!(
                    "{:.2}x",
                    star.mean().as_secs_f64() / pipe.mean().as_secs_f64()
                ),
                _ => "-".into(),
            };
            writeln!(
                out,
                "{:>10} {:>10} {:>12.3} {:>12.4} {:>9}",
                congested,
                name,
                c.mean().as_secs_f64(),
                c.stddev_secs(),
                speedup
            )?;
            report.series.push(Candle {
                name: format!("c{congested}/{name}"),
                samples: c.samples.clone(),
            });
        }
    }
    report.wall = wall.now();
    Ok(report)
}

// ---------------------------------------------------------------------------
// scale-sim — multiplexed-runtime scale acceptance
// ---------------------------------------------------------------------------

/// Rack-local chain for object `i` on a cluster of `nodes` nodes grouped
/// into racks of `rack`: the whole `n`-node chain lives inside rack
/// `i % racks` (archival traffic never crosses the rack boundary — the
/// oversubscribed links of a real datacenter fabric), rotated inside the
/// rack by `i / racks` so the head role cycles over rack members.
pub fn rack_local_chain(nodes: usize, rack: usize, n: usize, i: usize) -> Vec<usize> {
    assert!(rack >= n, "chain must fit in one rack");
    assert!(nodes >= rack && nodes % rack == 0, "whole racks only");
    let racks = nodes / rack;
    let base = (i % racks) * rack;
    (0..n).map(|j| base + (i / racks + j) % rack).collect()
}

/// Configuration of the `scale-sim` preset: an epoch loop of concurrent
/// rack-local archivals on a cluster far past thread-per-node scale.
#[derive(Clone, Debug)]
pub struct ScaleSimConfig {
    /// Cluster size (the multiplexed runtime runs all of these on one
    /// driver thread — a threaded run would need this many OS threads).
    pub nodes: usize,
    /// Nodes per rack; chains are placed rack-locally.
    pub rack: usize,
    /// Code length per object.
    pub n: usize,
    /// Message length per object.
    pub k: usize,
    /// Coefficient-search seed of the (n, k) code.
    pub code_seed: u64,
    /// Concurrent archivals per epoch.
    pub objects_per_epoch: usize,
    /// Bytes per source block.
    pub block_bytes: usize,
    /// Network frame size.
    pub buf_bytes: usize,
    /// Total virtual runtime, seconds.
    pub virtual_secs: u64,
    /// Virtual length of one epoch, seconds.
    pub epoch_secs: u64,
    /// Seed of the per-epoch verification sampling.
    pub seed: u64,
    /// Dataplane execution runtime (`Auto` resolves to the multiplexed
    /// driver on the preset's SimClock; `Threaded` forces thread-per-node
    /// — only sensible at small `nodes`).
    pub runtime: RuntimeKind,
}

impl ScaleSimConfig {
    /// The acceptance-scale preset: 2,048 nodes in 64 racks of 32 living
    /// through one virtual day, archiving a rack-local (16,11) batch every
    /// 20 virtual minutes — thousands of objects per run, finishing in
    /// wall-clock seconds on the multiplexed runtime.
    pub fn paper_scale() -> Self {
        Self {
            nodes: 2048,
            rack: 32,
            n: 16,
            k: 11,
            code_seed: 5,
            objects_per_epoch: 32,
            block_bytes: 8 * 1024,
            buf_bytes: 4 * 1024,
            virtual_secs: 86_400,
            epoch_secs: 1200,
            seed: 0xACE5_CA1E,
            runtime: RuntimeKind::Auto,
        }
    }

    /// CI smoke: the same 2,048-node cluster and full virtual day (the
    /// scale floors stay honest in CI), but hourly epochs of small batches
    /// so the whole run costs a few wall seconds.
    pub fn smoke() -> Self {
        Self {
            objects_per_epoch: 8,
            block_bytes: 4 * 1024,
            buf_bytes: 2 * 1024,
            epoch_secs: 7200,
            ..Self::paper_scale()
        }
    }
}

/// What a `scale-sim` run did, for acceptance assertions.
#[derive(Clone, Debug)]
pub struct ScaleSimReport {
    /// Cluster size of the run.
    pub nodes: usize,
    /// Rack count.
    pub racks: usize,
    /// Epochs executed.
    pub epochs: u64,
    /// Objects archived over the whole run.
    pub objects_archived: usize,
    /// Coded bytes produced (n × block per object).
    pub bytes_coded: u64,
    /// Virtual time the run covered.
    pub virtual_elapsed: Duration,
    /// Sampled objects that decode-verified byte-identically (one/epoch).
    pub verified: usize,
    /// Largest per-epoch batch makespan in virtual time.
    pub peak_epoch_makespan: Duration,
}

/// The `scale-sim` preset: `nodes` SimClock nodes (Auto-resolved to the
/// multiplexed runtime — the whole dataplane cooperatively scheduled on
/// one driver thread) run an epoch loop for ≥ a virtual day. Each epoch
/// ingests and pipeline-archives `objects_per_epoch` objects on rotating
/// rack-local chains, decode-verifies one seeded sample through the
/// topology generator, then drops the epoch's blocks so memory stays
/// bounded however long the virtual run. Jitter is off: every reported
/// virtual duration is an exact function of the config.
pub fn scale_sim(
    cfg: &ScaleSimConfig,
    backend: &BackendHandle,
    out: &mut dyn Write,
) -> anyhow::Result<(ScaleSimReport, BenchJson)> {
    anyhow::ensure!(cfg.rack >= cfg.n, "chain longer than a rack");
    anyhow::ensure!(
        cfg.nodes >= cfg.rack && cfg.nodes % cfg.rack == 0,
        "cluster must be whole racks"
    );
    anyhow::ensure!(cfg.k < cfg.n, "need redundancy (k < n)");
    anyhow::ensure!(cfg.epoch_secs > 0, "epochs must have positive length");
    anyhow::ensure!(cfg.objects_per_epoch > 0, "need at least one object per epoch");

    let wall = RealClock::new();
    let clock = SimClock::handle();
    let mut spec = ClusterSpec::tpc(cfg.nodes)
        .with_clock(clock.clone())
        .with_runtime(cfg.runtime);
    spec.jitter = Duration::ZERO;
    let expected_runtime = spec.resolved_runtime();
    let cluster = Cluster::start(spec);
    anyhow::ensure!(
        cluster.runtime_kind() == expected_runtime,
        "scale-sim cluster came up on {:?}, spec resolved to {expected_runtime:?}",
        cluster.runtime_kind()
    );
    let code = RapidRaidCode::<Gf256>::with_seed(cfg.n, cfg.k, cfg.code_seed)?;
    let tcode = TopologyCode::new(code.clone(), Topology::Chain.shape(cfg.n)?)?;

    let racks = cfg.nodes / cfg.rack;
    let epochs = cfg.virtual_secs.div_ceil(cfg.epoch_secs);
    let epoch_len = Duration::from_secs(cfg.epoch_secs);
    writeln!(
        out,
        "# scale-sim — {} nodes / {racks} racks of {}, {} epochs x {} objects, block={} KiB, runtime={:?}",
        cfg.nodes,
        cfg.rack,
        epochs,
        cfg.objects_per_epoch,
        cfg.block_bytes >> 10,
        cluster.runtime_kind()
    )?;

    let mut rng = SplitMix64::new(cfg.seed);
    let makespans = Recorder::new();
    let mut report = ScaleSimReport {
        nodes: cfg.nodes,
        racks,
        epochs,
        objects_archived: 0,
        bytes_coded: 0,
        virtual_elapsed: Duration::ZERO,
        verified: 0,
        peak_epoch_makespan: Duration::ZERO,
    };
    let t0 = clock.now();
    let print_every = (epochs / 12).max(1);
    for e in 0..epochs {
        let epoch_start = clock.now();
        // ingest this epoch's batch on rotating rack-local chains
        let mut placements = Vec::with_capacity(cfg.objects_per_epoch);
        let sample = rng.below(cfg.objects_per_epoch as u64) as usize;
        let mut sample_blocks: Vec<Vec<u8>> = Vec::new();
        for i in 0..cfg.objects_per_epoch {
            let idx = report.objects_archived + i;
            let object = ObjectId(0x5CA1_0000 + idx as u64);
            let chain = rack_local_chain(cfg.nodes, cfg.rack, cfg.n, idx);
            let placement = ReplicaPlacement::new(object, cfg.k, chain)?;
            let blocks = ingest_object(&cluster, &placement, cfg.block_bytes)?;
            if i == sample {
                sample_blocks = blocks;
            }
            placements.push(placement);
        }
        let jobs = pipeline_jobs(
            &code,
            &placements,
            Topology::Chain,
            cfg.buf_bytes,
            cfg.block_bytes,
        )?;
        let times = run_batch(&cluster, backend, &jobs)?;
        let makespan = times.iter().copied().max().unwrap_or(Duration::ZERO);
        anyhow::ensure!(
            makespan <= epoch_len,
            "epoch {e} batch overran its epoch: {makespan:?} > {epoch_len:?}"
        );
        makespans.record("epoch_makespan", makespan);
        report.peak_epoch_makespan = report.peak_epoch_makespan.max(makespan);

        // decode-verify one seeded sample, then drop the whole epoch's
        // blocks — memory stays bounded regardless of run length
        let p = &placements[sample];
        let rec = reconstruct(&cluster, &tcode, &p.chain, p.object, backend)?;
        anyhow::ensure!(
            rec == sample_blocks,
            "epoch {e}: sampled object {:?} decode mismatch",
            p.object
        );
        report.verified += 1;
        for p in &placements {
            for (node, idx) in p.replica_map() {
                cluster.node(node).delete(BlockKey::source(p.object, idx))?;
            }
            for (i, &node) in p.chain.iter().enumerate() {
                cluster.node(node).delete(BlockKey::coded(p.object, i))?;
            }
        }
        report.objects_archived += cfg.objects_per_epoch;
        report.bytes_coded += (cfg.objects_per_epoch * cfg.n * cfg.block_bytes) as u64;

        if e % print_every == 0 {
            writeln!(
                out,
                "epoch {e:>4} @ {:>8.0}s: {} objects archived, makespan {:.3}s",
                epoch_start.saturating_sub(t0).as_secs_f64(),
                report.objects_archived,
                makespan.as_secs_f64()
            )?;
        }
        // epochs have a fixed virtual length; the idle tail is free
        clock.sleep_until(epoch_start + epoch_len);
    }
    report.virtual_elapsed = clock.now().saturating_sub(t0);

    let mut bench = BenchJson::new("scale-sim")
        .param("nodes", cfg.nodes)
        .param("rack", cfg.rack)
        .param("epochs", epochs)
        .param("objects_per_epoch", cfg.objects_per_epoch)
        .param("objects_archived", report.objects_archived)
        .param("block_bytes", cfg.block_bytes)
        .param("virtual_secs", cfg.virtual_secs)
        .param("seed", cfg.seed)
        .param("runtime", format!("{:?}", cluster.runtime_kind()));
    bench.series = makespans.candles();
    bench.wall = wall.now();
    writeln!(
        out,
        "# {} objects ({} MiB coded) over {:.0} virtual s on {} nodes: {:.2} s wall",
        report.objects_archived,
        report.bytes_coded >> 20,
        report.virtual_elapsed.as_secs_f64(),
        cfg.nodes,
        bench.wall.as_secs_f64()
    )?;
    Ok((report, bench))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use std::sync::Arc;

    #[test]
    fn cpu_encode_all_impls_run() {
        let be: BackendHandle = Arc::new(NativeBackend::new());
        let object: Vec<Vec<u8>> = (0..K).map(|i| vec![i as u8; 65536]).collect();
        for imp in [Impl::Cec, Impl::Rr8, Impl::Rr16] {
            let dt = cpu_encode_once(&be, imp, &object);
            assert!(dt > Duration::ZERO);
        }
    }

    #[test]
    fn fig4_smoke_single_object_test_preset() {
        let be: BackendHandle = Arc::new(NativeBackend::new());
        let mut out = Vec::new();
        let report = fig4_coding_times(&be, "test", 1, 256 * 1024, 1, &mut out).unwrap();
        assert_eq!(report.series.len(), 3);
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("CEC") && text.contains("RR8") && text.contains("RR16"));
        // machine-readable twin carries the same series plus the metadata;
        // the objects variant is part of the name so 4a/4b files coexist
        assert_eq!(report.preset, "fig4-test-1obj");
        let json = report.to_json();
        assert!(json.contains("\"CEC\"") && json.contains("\"objects\":\"1\""), "{json}");
    }

    #[test]
    fn build_jobs_rotates_roles() {
        let cluster = Cluster::start(ClusterSpec::test(N));
        let jobs = build_jobs(&cluster, Impl::Cec, 2, 4096, 1).unwrap();
        match (&jobs[0], &jobs[1]) {
            (BatchJob::Classical(a), BatchJob::Classical(b)) => {
                assert_eq!(a.coding_node, K); // chain offset 0
                assert_eq!(b.coding_node, (K + 1) % N); // offset 1
            }
            _ => panic!("expected classical jobs"),
        }
    }

    #[test]
    fn fig_repair_smoke() {
        let be: BackendHandle = Arc::new(NativeBackend::new());
        let mut out = Vec::new();
        fig_repair(&be, "test", 0, 256 * 1024, 1, &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("star") && text.contains("pipelined"), "{text}");
    }

    #[test]
    fn fig4_smoke_on_simulated_tpc_preset() {
        // paper-scale preset under the SimClock: virtual timings, wall-fast
        let be: BackendHandle = Arc::new(NativeBackend::new());
        let mut out = Vec::new();
        let report = fig4_coding_times(&be, "tpc-sim", 1, 256 * 1024, 1, &mut out).unwrap();
        assert_eq!(report.series.len(), 3);
        for c in &report.series {
            assert!(c.median() > Duration::ZERO, "virtual time missing: {}", c.name);
        }
    }

    #[test]
    fn table2_sim_reports_nonzero_compute_and_sane_ratios() {
        let be: BackendHandle = Arc::new(NativeBackend::new());
        let mut out = Vec::new();
        let (rows, report) = table2_sim(&be, 128 * 1024, 5, &mut out).unwrap();
        // 2 code sizes × 2 cost models
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert!(r.classical > Duration::ZERO && r.pipelined > Duration::ZERO, "{r:?}");
            assert!(r.ratio() > 0.0);
        }
        assert!(rows.iter().any(|r| r.cost == "uniform"));
        assert!(rows.iter().any(|r| r.cost == "ec2-mix"));
        assert!(rows.iter().any(|r| (r.n, r.k) == (11, 8)));
        assert!(rows.iter().any(|r| (r.n, r.k) == (22, 16)));
        // the cost models actually charged compute: split spans exist and
        // are nonzero
        let compute: Vec<_> = report
            .spans
            .iter()
            .filter(|c| c.name.ends_with(".compute"))
            .collect();
        assert!(!compute.is_empty(), "no compute spans recorded");
        assert!(
            compute.iter().any(|c| c.max() > Duration::ZERO),
            "compute spans all zero"
        );
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("uniform") && text.contains("ec2-mix"), "{text}");
    }

    #[test]
    fn topo_sim_covers_grid_and_nonchain_wins_under_stragglers() {
        let be: BackendHandle = Arc::new(NativeBackend::new());
        let mut out = Vec::new();
        let (rows, report) = topo_sim(&be, 128 * 1024, 5, &mut out).unwrap();
        // 2 code sizes × 2 cost models × (3 fixed shapes + 1 placed cell)
        assert_eq!(rows.len(), 16);
        for r in &rows {
            assert!(r.coding > Duration::ZERO, "{r:?}");
        }
        // acceptance: under the heterogeneous ec2-mix cost model at least
        // one non-chain shape beats the chain on makespan (every cell
        // already decode-verified byte-identically inside topo_sim)
        for (n, k) in [(11usize, 8usize), (22, 16)] {
            let cell = |topo: Topology| {
                rows.iter()
                    .find(|r| {
                        r.n == n && r.k == k && r.cost == "ec2-mix" && !r.placed
                            && r.topology == topo
                    })
                    .unwrap()
                    .coding
            };
            let chain = cell(Topology::Chain);
            let best_nonchain = topo_sim_topologies()
                .into_iter()
                .filter(|t| *t != Topology::Chain)
                .map(cell)
                .min()
                .unwrap();
            assert!(
                best_nonchain < chain,
                "(n={n},k={k}) ec2-mix: no non-chain shape beat the chain \
                 ({best_nonchain:?} vs {chain:?})"
            );
        }
        // the load-aware placed cells ran and chose a non-chain shape
        let placed: Vec<_> = rows.iter().filter(|r| r.placed).collect();
        assert_eq!(placed.len(), 4);
        assert!(placed.iter().all(|r| r.topology != Topology::Chain));
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("tree:2") && text.contains("hybrid:4:2"), "{text}");
        assert!(text.contains("load-aware"), "{text}");
        assert_eq!(report.preset, "topo-sim");
    }

    #[test]
    fn topo_sim_is_deterministic_per_seed() {
        let be: BackendHandle = Arc::new(NativeBackend::new());
        let (a, _) = topo_sim(&be, 64 * 1024, 5, &mut Vec::<u8>::new()).unwrap();
        let (b, _) = topo_sim(&be, 64 * 1024, 5, &mut Vec::<u8>::new()).unwrap();
        assert_eq!(a, b, "virtual topo-sim rows diverged between identical runs");
    }

    #[test]
    fn table2_sim_is_deterministic_per_seed() {
        let be: BackendHandle = Arc::new(NativeBackend::new());
        let (a, _) = table2_sim(&be, 64 * 1024, 5, &mut Vec::<u8>::new()).unwrap();
        let (b, _) = table2_sim(&be, 64 * 1024, 5, &mut Vec::<u8>::new()).unwrap();
        assert_eq!(a, b, "virtual Table-II rows diverged between identical runs");
    }

    #[test]
    fn straggler_sim_adaptive_beats_every_static_cell() {
        let be: BackendHandle = Arc::new(NativeBackend::new());
        let mut out = Vec::new();
        let (rows, report) =
            straggler_sim(&be, 32 * 1024, 5, RuntimeKind::Auto, &mut out).unwrap();
        // 2 code sizes × (3 static shapes + 1 adaptive)
        assert_eq!(rows.len(), 8);
        for (n, k) in [(11usize, 8usize), (22, 16)] {
            let adaptive = rows
                .iter()
                .find(|r| r.n == n && r.adaptive)
                .expect("adaptive cell")
                .makespan;
            for r in rows.iter().filter(|r| r.n == n && !r.adaptive) {
                assert!(
                    adaptive < r.makespan,
                    "(n={n},k={k}) adaptive {adaptive:?} did not beat static {} at {:?}",
                    r.cell,
                    r.makespan
                );
            }
        }
        assert_eq!(report.preset, "straggler-sim");
        assert_eq!(report.get_param("runtime"), Some("auto"));
        assert_eq!(report.series.len(), 8);
        assert!(report.series.iter().any(|c| c.name == "n11k8/adaptive"));
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("adaptive") && text.contains("hybrid:4:2"), "{text}");
    }

    #[test]
    fn straggler_sim_is_deterministic_per_seed() {
        let be: BackendHandle = Arc::new(NativeBackend::new());
        let (a, _) =
            straggler_sim(&be, 16 * 1024, 5, RuntimeKind::Auto, &mut Vec::<u8>::new()).unwrap();
        let (b, _) =
            straggler_sim(&be, 16 * 1024, 5, RuntimeKind::Auto, &mut Vec::<u8>::new()).unwrap();
        assert_eq!(a, b, "straggler-sim rows diverged between identical runs");
    }

    #[test]
    fn scale_sim_tiny_archives_verifies_and_bounds_memory() {
        let be: BackendHandle = Arc::new(NativeBackend::new());
        let cfg = ScaleSimConfig {
            nodes: 64,
            rack: 16,
            n: 8,
            k: 4,
            code_seed: 7,
            objects_per_epoch: 3,
            block_bytes: 4 * 1024,
            buf_bytes: 2 * 1024,
            virtual_secs: 60,
            epoch_secs: 20,
            seed: 11,
            runtime: RuntimeKind::Auto,
        };
        let mut out = Vec::new();
        let (report, bench) = scale_sim(&cfg, &be, &mut out).unwrap();
        assert_eq!(report.epochs, 3);
        assert_eq!(report.objects_archived, 9);
        assert_eq!(report.verified, 3);
        assert!(report.virtual_elapsed >= Duration::from_secs(60));
        assert!(report.peak_epoch_makespan > Duration::ZERO);
        assert_eq!(bench.preset, "scale-sim");
        assert_eq!(bench.get_param("runtime"), Some("Multiplexed"));
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("scale-sim"), "{text}");
    }

    #[test]
    fn rack_local_chains_stay_inside_one_rack() {
        for i in 0..40 {
            let chain = rack_local_chain(64, 16, 8, i);
            assert_eq!(chain.len(), 8);
            let rack = chain[0] / 16;
            assert!(chain.iter().all(|&n| n / 16 == rack), "{chain:?}");
            // all distinct
            let mut sorted = chain.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 8);
        }
        // consecutive objects land on consecutive racks
        assert_ne!(
            rack_local_chain(64, 16, 8, 0)[0] / 16,
            rack_local_chain(64, 16, 8, 1)[0] / 16
        );
    }

    #[test]
    fn unknown_preset_rejected() {
        let be: BackendHandle = Arc::new(NativeBackend::new());
        let mut out = Vec::new();
        assert!(fig4_coding_times(&be, "lan", 1, 4096, 1, &mut out).is_err());
    }
}
