//! Unified resource accounting: CPU cost as a first-class peer of network
//! bandwidth.
//!
//! PR 3's virtual-time core made every *network* wait a discrete event on
//! the cluster clock, but compute stayed free: under a `SimClock` a GF
//! multiply-accumulate over a megabyte took zero virtual time, so every
//! `-sim` preset modeled an infinitely fast CPU. The paper's Table II
//! shows that is the wrong model — archival speedups are shaped by
//! per-node GF throughput as much as by link bandwidth, and on
//! heterogeneous hardware the bottleneck flips between network and
//! compute (Li et al.'s repair-pipelining analysis makes the same point).
//!
//! This module closes the gap with three pieces:
//!
//! * [`GfWork`] — the unit of GF effort: multiply-accumulate bytes,
//!   XOR/copy bytes, store traffic and matrix-inversion element ops.
//!   The slice layer ([`crate::gf::slice`]) reports the work each op
//!   *actually* performed (zero-coefficient skips and XOR shortcuts
//!   included), and the dataplane derives per-frame work from the same
//!   coefficient rules.
//! * [`CostModel`] — maps `(node, GfWork)` to virtual time. [`ZeroCost`]
//!   is the old behavior expressed inside the new model (compute is free —
//!   the default, and the right choice under a `RealClock` where compute
//!   already costs real time); [`UniformCost`] charges calibrated
//!   ns-per-byte rates; [`ProfileCost`] scales those rates per node
//!   through [`NodeProfile`]s (EC2 small/medium/large classes).
//! * [`CpuMeter`] — the compute twin of the NIC
//!   [`RateLimiter`](crate::cluster::RateLimiter): one per node,
//!   cumulative FIFO reservation over the node's core lanes
//!   ([`CostModel::cores`], from its profile — multi-core profiles let
//!   concurrent commands genuinely overlap). Every data-plane worker
//!   charges its frame's work *before* forwarding the result, so compute
//!   occupies virtual time in the middle of the pipeline — exactly where
//!   it throttles a real chain — and concurrent workers on one node
//!   contend for the cores like they contend for the NIC. The meter's
//!   `backlog()` is the compute load signal placement policies rank by.
//!   [`ProfileCost::set_profile`] re-prices a node at runtime (the
//!   long-run harness churns CPU profiles over epochs like netem
//!   profiles).
//!
//! There is no parallel "network-only" accounting path left: every worker
//! always charges its meter, and `ZeroCost` simply makes the charge free.

pub mod cost;
pub mod meter;
pub mod profile;
pub mod work;

pub use cost::{CostModel, CostModelHandle, ProfileCost, UniformCost, ZeroCost};
pub use meter::CpuMeter;
pub use profile::NodeProfile;
pub use work::GfWork;
