//! [`NodeProfile`]: the relative CPU capability of one storage node.
//!
//! Profiles scale the calibrated [`UniformCost`](super::UniformCost)
//! baseline per node, which is how the Table-II-style hardware sweep —
//! the paper ran its CPU measurements on an Atom, a Core 2 and a Xeon,
//! and its cluster experiments on EC2 small instances — enters the
//! simulation: a heterogeneous [`ProfileCost`](super::ProfileCost) makes
//! the chain's bottleneck land on its slowest stage instead of on the
//! network.

/// Relative CPU speed class of one storage node.
#[derive(Copy, Clone, Debug, PartialEq)]
pub struct NodeProfile {
    /// Class label used in reports (`ec2-small`, …).
    pub name: &'static str,
    /// Speed multiplier over the calibrated baseline: 1.0 = the EC2
    /// small instance the defaults are calibrated to; 2.0 halves every
    /// compute charge. Must be > 0.
    pub speed: f64,
}

impl NodeProfile {
    /// EC2 small instance — the calibration baseline (speed 1.0).
    pub const EC2_SMALL: NodeProfile = NodeProfile {
        name: "ec2-small",
        speed: 1.0,
    };

    /// EC2 medium class: ~2× the small instance's GF throughput.
    pub const EC2_MEDIUM: NodeProfile = NodeProfile {
        name: "ec2-medium",
        speed: 2.0,
    };

    /// EC2 large class: ~4× the small instance's GF throughput.
    pub const EC2_LARGE: NodeProfile = NodeProfile {
        name: "ec2-large",
        speed: 4.0,
    };

    /// HP ThinClient (the paper's 50-node testbed): Atom-class, about
    /// half the small instance's throughput.
    pub const THINCLIENT: NodeProfile = NodeProfile {
        name: "thinclient",
        speed: 0.5,
    };

    /// A custom profile (testing stragglers, hypothetical hardware).
    pub fn custom(name: &'static str, speed: f64) -> Self {
        assert!(speed > 0.0, "profile speed must be positive");
        NodeProfile { name, speed }
    }

    /// The heterogeneous EC2 mix used by the Table-II sim preset and the
    /// sweep grid: small/medium/large round-robin.
    pub fn ec2_mix() -> Vec<NodeProfile> {
        vec![Self::EC2_SMALL, Self::EC2_MEDIUM, Self::EC2_LARGE]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_ordered_by_speed() {
        assert!(NodeProfile::THINCLIENT.speed < NodeProfile::EC2_SMALL.speed);
        assert!(NodeProfile::EC2_SMALL.speed < NodeProfile::EC2_MEDIUM.speed);
        assert!(NodeProfile::EC2_MEDIUM.speed < NodeProfile::EC2_LARGE.speed);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_speed_rejected() {
        let _ = NodeProfile::custom("broken", 0.0);
    }

    #[test]
    fn ec2_mix_has_all_three_classes() {
        let mix = NodeProfile::ec2_mix();
        assert_eq!(mix.len(), 3);
        assert_eq!(mix[0], NodeProfile::EC2_SMALL);
    }
}
