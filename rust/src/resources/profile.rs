//! [`NodeProfile`]: the relative CPU capability of one storage node.
//!
//! Profiles scale the calibrated [`UniformCost`](super::UniformCost)
//! baseline per node, which is how the Table-II-style hardware sweep —
//! the paper ran its CPU measurements on an Atom, a Core 2 and a Xeon,
//! and its cluster experiments on EC2 small instances — enters the
//! simulation: a heterogeneous [`ProfileCost`](super::ProfileCost) makes
//! the chain's bottleneck land on its slowest stage instead of on the
//! network.

/// Relative CPU speed class of one storage node.
#[derive(Copy, Clone, Debug, PartialEq)]
pub struct NodeProfile {
    /// Class label used in reports (`ec2-small`, …).
    pub name: &'static str,
    /// Per-core speed multiplier over the calibrated baseline: 1.0 = one
    /// core of the EC2 small instance the defaults are calibrated to; 2.0
    /// halves every compute charge. Must be > 0.
    pub speed: f64,
    /// Independent charge lanes: how many data-plane workers of the node
    /// can occupy compute simultaneously before reservations queue
    /// (`CpuMeter` reserves per core). Must be ≥ 1. Read once at node
    /// spawn — profile churn swaps pricing, not the lane count.
    pub cores: usize,
}

impl NodeProfile {
    /// EC2 small instance — the calibration baseline (speed 1.0, 1 core).
    pub const EC2_SMALL: NodeProfile = NodeProfile {
        name: "ec2-small",
        speed: 1.0,
        cores: 1,
    };

    /// EC2 medium class: ~2× the small instance's GF throughput.
    pub const EC2_MEDIUM: NodeProfile = NodeProfile {
        name: "ec2-medium",
        speed: 2.0,
        cores: 1,
    };

    /// EC2 large class: ~4× the per-core throughput AND a second core, so
    /// concurrent Gemm rows and Fold frames on a large node genuinely
    /// overlap instead of queueing on one simulated core.
    pub const EC2_LARGE: NodeProfile = NodeProfile {
        name: "ec2-large",
        speed: 4.0,
        cores: 2,
    };

    /// HP ThinClient (the paper's 50-node testbed): Atom-class, about
    /// half the small instance's throughput.
    pub const THINCLIENT: NodeProfile = NodeProfile {
        name: "thinclient",
        speed: 0.5,
        cores: 1,
    };

    /// A custom single-core profile (testing stragglers, hypothetical
    /// hardware).
    pub fn custom(name: &'static str, speed: f64) -> Self {
        assert!(speed > 0.0, "profile speed must be positive");
        NodeProfile {
            name,
            speed,
            cores: 1,
        }
    }

    /// The same profile with a different core count.
    pub fn with_cores(mut self, cores: usize) -> Self {
        assert!(cores >= 1, "profiles need at least one core");
        self.cores = cores;
        self
    }

    /// The heterogeneous EC2 mix used by the Table-II sim preset and the
    /// sweep grid: small/medium/large round-robin.
    pub fn ec2_mix() -> Vec<NodeProfile> {
        vec![Self::EC2_SMALL, Self::EC2_MEDIUM, Self::EC2_LARGE]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_ordered_by_speed() {
        assert!(NodeProfile::THINCLIENT.speed < NodeProfile::EC2_SMALL.speed);
        assert!(NodeProfile::EC2_SMALL.speed < NodeProfile::EC2_MEDIUM.speed);
        assert!(NodeProfile::EC2_MEDIUM.speed < NodeProfile::EC2_LARGE.speed);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_speed_rejected() {
        let _ = NodeProfile::custom("broken", 0.0);
    }

    #[test]
    fn ec2_mix_has_all_three_classes() {
        let mix = NodeProfile::ec2_mix();
        assert_eq!(mix.len(), 3);
        assert_eq!(mix[0], NodeProfile::EC2_SMALL);
    }

    #[test]
    fn cores_default_to_one_and_large_is_multicore() {
        assert_eq!(NodeProfile::EC2_SMALL.cores, 1);
        assert_eq!(NodeProfile::EC2_LARGE.cores, 2);
        assert_eq!(NodeProfile::custom("x", 1.5).cores, 1);
        assert_eq!(NodeProfile::custom("x", 1.5).with_cores(4).cores, 4);
    }

    #[test]
    #[should_panic(expected = "at least one core")]
    fn zero_cores_rejected() {
        let _ = NodeProfile::custom("broken", 1.0).with_cores(0);
    }
}
