//! [`CostModel`]: map GF work to virtual time.
//!
//! The model is consulted by every data-plane worker through its node's
//! [`CpuMeter`](super::CpuMeter); the returned duration is slept on the
//! cluster clock, so under a `SimClock` compute becomes discrete events
//! exactly like NIC reservations. [`ZeroCost`] (the default) prices
//! everything at zero — that *is* PR 3's network-only accounting,
//! expressed inside the unified model instead of as a separate code path.

use std::sync::Arc;
use std::time::Duration;

use crate::cluster::NodeId;

use super::profile::NodeProfile;
use super::work::GfWork;

/// Prices [`GfWork`] in virtual time, per node.
pub trait CostModel: Send + Sync + std::fmt::Debug {
    /// Virtual compute time `node` needs to perform `work`.
    fn cost(&self, node: NodeId, work: &GfWork) -> Duration;

    /// Independent compute lanes of `node` (its
    /// [`CpuMeter`](super::CpuMeter) reserves per core). Read once at
    /// node spawn; defaults to a single core.
    fn cores(&self, _node: NodeId) -> usize {
        1
    }

    /// Model label for reports.
    fn name(&self) -> &'static str;
}

/// Shared cost-model handle as carried by `ClusterSpec`.
pub type CostModelHandle = Arc<dyn CostModel>;

/// Compute is free (the pre-resource-model behavior). The right model
/// under a `RealClock`, where compute already costs real time.
#[derive(Debug, Default, Clone, Copy)]
pub struct ZeroCost;

impl ZeroCost {
    /// Fresh handle (the `ClusterSpec` preset default).
    pub fn handle() -> CostModelHandle {
        Arc::new(ZeroCost)
    }
}

impl CostModel for ZeroCost {
    fn cost(&self, _node: NodeId, _work: &GfWork) -> Duration {
        Duration::ZERO
    }

    fn name(&self) -> &'static str {
        "zero"
    }
}

/// Every node runs the same calibrated hardware: throughput per work
/// category, charged linearly.
#[derive(Clone, Debug)]
pub struct UniformCost {
    /// Table-lookup multiply-accumulate throughput, bytes/second.
    pub mac_bytes_per_sec: f64,
    /// Plain XOR/copy/memset throughput, bytes/second.
    pub xor_bytes_per_sec: f64,
    /// Block-store write throughput, bytes/second.
    pub store_bytes_per_sec: f64,
    /// Matrix-inversion throughput, element operations/second.
    pub invert_elems_per_sec: f64,
}

impl UniformCost {
    /// Rates calibrated to one core of the paper-era EC2 small instance
    /// (≈ 1 ECU): a single-threaded table-lookup GF(2^8) MAC pass runs at
    /// a few hundred MiB/s, plain XOR near memory speed, stores at memcpy
    /// speed. These put one (16,11) pipeline stage's per-frame compute in
    /// the same order as a 1 Gbps frame time, which is exactly the regime
    /// Table II shows (compute and network both matter).
    pub fn calibrated() -> Self {
        Self {
            mac_bytes_per_sec: 250e6,
            xor_bytes_per_sec: 2e9,
            store_bytes_per_sec: 4e9,
            invert_elems_per_sec: 25e6,
        }
    }

    /// Fresh handle of the calibrated rates.
    pub fn handle() -> CostModelHandle {
        Arc::new(Self::calibrated())
    }

    /// Rates measured on the machine the crate actually runs on, from a
    /// `gf_hotpath` bench report: the bench times one MAC / XOR / store
    /// pass over `calibrate_bytes` bytes and one Gauss-Jordan inversion of
    /// a `calibrate_invert_dim`-square matrix, publishing them as the
    /// `calibrate/{mac,xor,store,invert}` series. Each rate is the
    /// category's work divided by its median sample — so `-sim` presets
    /// track measured throughput instead of hardcoded EC2-era guesses.
    pub fn from_measured(bench: &crate::metrics::BenchJson) -> anyhow::Result<Self> {
        let bytes: f64 = bench
            .get_param("calibrate_bytes")
            .ok_or_else(|| anyhow::anyhow!("report has no calibrate_bytes param"))?
            .parse::<u64>()? as f64;
        let dim: f64 = bench
            .get_param("calibrate_invert_dim")
            .ok_or_else(|| anyhow::anyhow!("report has no calibrate_invert_dim param"))?
            .parse::<u64>()? as f64;
        anyhow::ensure!(bytes > 0.0 && dim > 0.0, "degenerate calibration sizes");
        let rate = |name: &str, work: f64| -> anyhow::Result<f64> {
            let c = bench.series(name)?;
            let secs = c.median().as_secs_f64();
            anyhow::ensure!(secs > 0.0, "{name} median is zero");
            Ok(work / secs)
        };
        Ok(Self {
            mac_bytes_per_sec: rate("calibrate/mac", bytes)?,
            xor_bytes_per_sec: rate("calibrate/xor", bytes)?,
            store_bytes_per_sec: rate("calibrate/store", bytes)?,
            invert_elems_per_sec: rate("calibrate/invert", dim * dim * dim)?,
        })
    }

    fn secs(&self, work: &GfWork) -> f64 {
        work.mac_bytes as f64 / self.mac_bytes_per_sec
            + work.xor_bytes as f64 / self.xor_bytes_per_sec
            + work.store_bytes as f64 / self.store_bytes_per_sec
            + work.invert_elems as f64 / self.invert_elems_per_sec
    }
}

impl CostModel for UniformCost {
    fn cost(&self, _node: NodeId, work: &GfWork) -> Duration {
        if work.is_zero() {
            return Duration::ZERO;
        }
        Duration::from_secs_f64(self.secs(work))
    }

    fn name(&self) -> &'static str {
        "uniform"
    }
}

/// Heterogeneous hardware: per-node [`NodeProfile`]s scaling a
/// [`UniformCost`] baseline. Node `i` gets `profiles[i % len]`, so a
/// short mix (e.g. [`NodeProfile::ec2_mix`]) tiles any cluster size
/// deterministically. Individual nodes can be re-profiled at runtime
/// ([`ProfileCost::set_profile`]) — the long-run harness churns CPU
/// profiles over epochs the way it churns netem profiles. Overrides swap
/// *pricing* only: a node's core count is read once at spawn.
#[derive(Debug)]
pub struct ProfileCost {
    base: UniformCost,
    profiles: Vec<NodeProfile>,
    overrides: std::sync::Mutex<std::collections::HashMap<NodeId, NodeProfile>>,
}

impl Clone for ProfileCost {
    fn clone(&self) -> Self {
        Self {
            base: self.base.clone(),
            profiles: self.profiles.clone(),
            overrides: std::sync::Mutex::new(self.overrides.lock().unwrap().clone()),
        }
    }
}

impl ProfileCost {
    /// Profile the `base` rates. Errors on an empty or non-positive mix.
    pub fn new(base: UniformCost, profiles: Vec<NodeProfile>) -> anyhow::Result<Self> {
        anyhow::ensure!(!profiles.is_empty(), "need at least one node profile");
        anyhow::ensure!(
            profiles.iter().all(|p| p.speed > 0.0),
            "profile speeds must be positive"
        );
        Ok(Self {
            base,
            profiles,
            overrides: std::sync::Mutex::new(std::collections::HashMap::new()),
        })
    }

    /// Calibrated baseline + the given mix, as a handle.
    pub fn handle(profiles: Vec<NodeProfile>) -> anyhow::Result<CostModelHandle> {
        Ok(Arc::new(Self::new(UniformCost::calibrated(), profiles)?))
    }

    /// The profile charged to `node` (override, else the tiled mix).
    pub fn profile(&self, node: NodeId) -> NodeProfile {
        if let Some(p) = self.overrides.lock().unwrap().get(&node) {
            return *p;
        }
        self.profiles[node % self.profiles.len()]
    }

    /// Re-profile one node at runtime (CPU churn: a VM migration, thermal
    /// throttling, a noisy neighbor). Future charges — including work
    /// already queued on the node's meter but not yet priced — use the
    /// new speed.
    pub fn set_profile(&self, node: NodeId, profile: NodeProfile) {
        assert!(profile.speed > 0.0, "profile speed must be positive");
        self.overrides.lock().unwrap().insert(node, profile);
    }

    /// Drop a node's override, restoring its tiled mix profile.
    pub fn reset_profile(&self, node: NodeId) {
        self.overrides.lock().unwrap().remove(&node);
    }
}

impl CostModel for ProfileCost {
    fn cost(&self, node: NodeId, work: &GfWork) -> Duration {
        if work.is_zero() {
            return Duration::ZERO;
        }
        Duration::from_secs_f64(self.base.secs(work) / self.profile(node).speed)
    }

    fn cores(&self, node: NodeId) -> usize {
        self.profile(node).cores
    }

    fn name(&self) -> &'static str {
        "profile"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_cost_prices_everything_at_zero() {
        let m = ZeroCost::handle();
        assert_eq!(m.cost(0, &GfWork::mac(1 << 30)), Duration::ZERO);
        assert_eq!(m.name(), "zero");
    }

    #[test]
    fn uniform_cost_is_linear_in_work() {
        let m = UniformCost::calibrated();
        let one = m.cost(0, &GfWork::mac(1 << 20));
        let two = m.cost(5, &GfWork::mac(2 << 20));
        assert!(one > Duration::ZERO);
        assert_eq!(two, one * 2);
        // a MiB of MAC at 250 MB/s is ~4 ms
        assert!(one > Duration::from_millis(2) && one < Duration::from_millis(8), "{one:?}");
    }

    #[test]
    fn uniform_cost_charges_all_categories() {
        let m = UniformCost::calibrated();
        for w in [
            GfWork::mac(1000),
            GfWork::xor(1000),
            GfWork::store(1000),
            GfWork::invert(8),
        ] {
            assert!(m.cost(0, &w) > Duration::ZERO, "{w:?} priced at zero");
        }
        assert_eq!(m.cost(0, &GfWork::ZERO), Duration::ZERO);
    }

    #[test]
    fn from_measured_converts_medians_to_rates() {
        use crate::metrics::BenchJson;
        use crate::util::bench::Candle;
        let candle = |name: &str, ms: &[u64]| {
            let mut samples: Vec<Duration> =
                ms.iter().map(|&m| Duration::from_millis(m)).collect();
            samples.sort_unstable();
            Candle {
                name: name.to_string(),
                samples,
            }
        };
        let mut r = BenchJson::new("gf-hotpath")
            .param("calibrate_bytes", 1_000_000u64)
            .param("calibrate_invert_dim", 100u64);
        // medians: mac 4 ms, xor 1 ms, store 2 ms, invert 10 ms
        r.series.push(candle("calibrate/mac", &[8, 4, 3]));
        r.series.push(candle("calibrate/xor", &[1]));
        r.series.push(candle("calibrate/store", &[2]));
        r.series.push(candle("calibrate/invert", &[10]));
        let m = UniformCost::from_measured(&r).unwrap();
        assert!((m.mac_bytes_per_sec - 250e6).abs() < 1e3, "{}", m.mac_bytes_per_sec);
        assert!((m.xor_bytes_per_sec - 1e9).abs() < 1e3);
        assert!((m.store_bytes_per_sec - 500e6).abs() < 1e3);
        // 100³ elems / 10 ms = 1e8 elems/s
        assert!((m.invert_elems_per_sec - 1e8).abs() < 1e3);
        // and the result prices work like any uniform model
        assert!(m.cost(0, &GfWork::mac(1 << 20)) > Duration::ZERO);
    }

    #[test]
    fn from_measured_rejects_incomplete_reports() {
        use crate::metrics::BenchJson;
        // no params at all
        assert!(UniformCost::from_measured(&BenchJson::new("x")).is_err());
        // params but missing series
        let r = BenchJson::new("x")
            .param("calibrate_bytes", 1024u64)
            .param("calibrate_invert_dim", 8u64);
        let err = UniformCost::from_measured(&r).unwrap_err();
        assert!(err.to_string().contains("calibrate/mac"), "{err}");
    }

    #[test]
    fn profile_cost_scales_per_node() {
        let m = ProfileCost::new(UniformCost::calibrated(), NodeProfile::ec2_mix()).unwrap();
        let w = GfWork::mac(1 << 20);
        let small = m.cost(0, &w); // ec2-small, speed 1
        let medium = m.cost(1, &w); // ec2-medium, speed 2
        let large = m.cost(2, &w); // ec2-large, speed 4
        assert_eq!(small, medium * 2);
        assert_eq!(small, large * 4);
        // the mix tiles: node 3 wraps back to small
        assert_eq!(m.cost(3, &w), small);
        assert_eq!(m.profile(4).name, "ec2-medium");
    }

    #[test]
    fn profile_cost_rejects_bad_mixes() {
        assert!(ProfileCost::new(UniformCost::calibrated(), vec![]).is_err());
        let neg = NodeProfile {
            name: "neg",
            speed: -1.0,
            cores: 1,
        };
        assert!(ProfileCost::new(UniformCost::calibrated(), vec![neg]).is_err());
    }

    #[test]
    fn profile_cost_reports_cores_and_defaults_to_one() {
        let m = ProfileCost::new(UniformCost::calibrated(), NodeProfile::ec2_mix()).unwrap();
        assert_eq!(m.cores(0), 1); // small
        assert_eq!(m.cores(2), 2); // large is multicore
        assert_eq!(UniformCost::calibrated().cores(7), 1); // trait default
        assert_eq!(ZeroCost.cores(0), 1);
    }

    #[test]
    fn runtime_override_swaps_pricing_and_restores() {
        let m = ProfileCost::new(UniformCost::calibrated(), vec![NodeProfile::EC2_SMALL]).unwrap();
        let w = GfWork::mac(1 << 20);
        let before = m.cost(3, &w);
        m.set_profile(3, NodeProfile::THINCLIENT); // half speed ⇒ double cost
        assert_eq!(m.cost(3, &w), before * 2);
        assert_eq!(m.profile(3).name, "thinclient");
        // other nodes untouched
        assert_eq!(m.cost(4, &w), before);
        m.reset_profile(3);
        assert_eq!(m.cost(3, &w), before);
    }
}
