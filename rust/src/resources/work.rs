//! [`GfWork`]: the unit of GF compute effort reported by the slice layer
//! and charged by [`CostModel`](super::CostModel)s.

use std::ops::{Add, AddAssign};

/// Work performed by GF operations, in the units the cost models price.
///
/// The categories mirror the real cost structure of the table-based
/// kernels in [`crate::gf::slice`]:
///
/// * `mac_bytes` — bytes pushed through a table-lookup
///   multiply-accumulate pass (one product-table lookup + XOR per byte;
///   the dominant term of every encode/repair).
/// * `xor_bytes` — bytes pushed through a plain XOR, copy or memset pass
///   (the coefficient-0/1 shortcuts, buffer clones, zero fills).
/// * `store_bytes` — bytes appended to a node's block store (the memcpy
///   that lands a received or generated block).
/// * `invert_elems` — Gauss-Jordan element operations of matrix
///   inversions, counted as dim³ per inversion (decode setup, repair
///   coefficient derivation).
#[derive(Copy, Clone, Debug, Default, PartialEq, Eq)]
pub struct GfWork {
    /// Table-lookup multiply-accumulate bytes.
    pub mac_bytes: u64,
    /// Plain XOR / copy / memset bytes.
    pub xor_bytes: u64,
    /// Block-store write traffic in bytes.
    pub store_bytes: u64,
    /// Matrix-inversion element operations (Σ dim³).
    pub invert_elems: u64,
}

impl GfWork {
    /// No work at all.
    pub const ZERO: GfWork = GfWork {
        mac_bytes: 0,
        xor_bytes: 0,
        store_bytes: 0,
        invert_elems: 0,
    };

    /// A multiply-accumulate pass over `bytes`.
    pub fn mac(bytes: usize) -> Self {
        GfWork {
            mac_bytes: bytes as u64,
            ..Self::ZERO
        }
    }

    /// An XOR/copy/memset pass over `bytes`.
    pub fn xor(bytes: usize) -> Self {
        GfWork {
            xor_bytes: bytes as u64,
            ..Self::ZERO
        }
    }

    /// A block-store write of `bytes`.
    pub fn store(bytes: usize) -> Self {
        GfWork {
            store_bytes: bytes as u64,
            ..Self::ZERO
        }
    }

    /// One `dim`×`dim` matrix inversion (dim³ element operations).
    pub fn invert(dim: usize) -> Self {
        GfWork {
            invert_elems: (dim as u64).pow(3),
            ..Self::ZERO
        }
    }

    /// Work of applying one field-erased coefficient to a `bytes`-long
    /// buffer — the same shortcut rules the slice ops take: 0 does
    /// nothing, 1 is an XOR pass, anything else a table MAC pass.
    pub fn coeff(c: u32, bytes: usize) -> Self {
        match c {
            0 => Self::ZERO,
            1 => Self::xor(bytes),
            _ => Self::mac(bytes),
        }
    }

    /// Work of one fused pipeline stage (paper eqs. (3)/(4)) over one
    /// `bytes`-long frame: the two incoming-buffer clones plus a ψ and a ξ
    /// coefficient application per local block.
    pub fn pipeline_step(psi: &[u32], xi: &[u32], bytes: usize) -> Self {
        let mut w = Self::xor(2 * bytes); // x_out and c start as copies of x_in
        for &c in psi.iter().chain(xi) {
            w += Self::coeff(c, bytes);
        }
        w
    }

    /// Work of applying an m×k coefficient matrix to one row of k
    /// `bytes`-long frames (the classical coding node's streamed gemm):
    /// the m output-accumulator fills plus one coefficient application per
    /// matrix cell.
    pub fn gemm(rows: &[Vec<u32>], bytes: usize) -> Self {
        let mut w = Self::xor(rows.len() * bytes);
        for row in rows {
            for &c in row {
                w += Self::coeff(c, bytes);
            }
        }
        w
    }

    /// True iff every category is zero.
    pub fn is_zero(&self) -> bool {
        *self == Self::ZERO
    }
}

impl AddAssign for GfWork {
    fn add_assign(&mut self, rhs: Self) {
        self.mac_bytes += rhs.mac_bytes;
        self.xor_bytes += rhs.xor_bytes;
        self.store_bytes += rhs.store_bytes;
        self.invert_elems += rhs.invert_elems;
    }
}

impl Add for GfWork {
    type Output = GfWork;
    fn add(mut self, rhs: Self) -> Self {
        self += rhs;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coeff_takes_the_shortcut_rules() {
        assert_eq!(GfWork::coeff(0, 100), GfWork::ZERO);
        assert_eq!(GfWork::coeff(1, 100), GfWork::xor(100));
        assert_eq!(GfWork::coeff(7, 100), GfWork::mac(100));
    }

    #[test]
    fn pipeline_step_counts_psi_and_xi() {
        // 2 locals, all coefficients ≥ 2: 4 MAC passes + the 2 clones.
        let w = GfWork::pipeline_step(&[3, 5], &[7, 9], 1000);
        assert_eq!(w.mac_bytes, 4000);
        assert_eq!(w.xor_bytes, 2000);
        // zero coefficients cost nothing beyond the clones
        let w = GfWork::pipeline_step(&[0], &[1], 1000);
        assert_eq!(w.mac_bytes, 0);
        assert_eq!(w.xor_bytes, 3000);
    }

    #[test]
    fn gemm_counts_every_cell() {
        let rows = vec![vec![2u32, 3, 0], vec![1, 4, 5]];
        let w = GfWork::gemm(&rows, 10);
        assert_eq!(w.mac_bytes, 40); // cells 2,3,4,5
        assert_eq!(w.xor_bytes, 20 + 10); // 2 accumulator fills + cell 1
    }

    #[test]
    fn invert_is_cubic() {
        assert_eq!(GfWork::invert(4).invert_elems, 64);
    }

    #[test]
    fn addition_accumulates() {
        let mut w = GfWork::mac(5);
        w += GfWork::xor(7) + GfWork::store(11) + GfWork::invert(2);
        assert_eq!(
            w,
            GfWork {
                mac_bytes: 5,
                xor_bytes: 7,
                store_bytes: 11,
                invert_elems: 8
            }
        );
        assert!(!w.is_zero());
        assert!(GfWork::ZERO.is_zero());
    }
}
