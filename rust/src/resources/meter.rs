//! [`CpuMeter`]: per-node CPU reservation on the cluster clock — the
//! compute twin of the NIC [`RateLimiter`](crate::cluster::RateLimiter).
//!
//! A node's workers all charge the same meter, so concurrent data-plane
//! commands contend for the node's (single) simulated core with the same
//! cumulative-FIFO semantics that make NIC bandwidth sharing honest:
//! reservations serialize through a mutex, the blocking happens on the
//! clock, and under a `SimClock` a charge is a discrete event with zero
//! wall cost. A zero-priced charge ([`ZeroCost`](super::ZeroCost), or
//! genuinely zero work) returns without touching the reservation state,
//! so the default configuration is tick-for-tick identical to the
//! pre-resource-model dataplane.
//!
//! Determinism caveat (the same one the NIC limiter carries): the meter's
//! *aggregate* schedule is order-independent — the sum of reservations
//! commutes — but when several workers of one node charge at the same
//! virtual instant, mutex-acquisition order decides which completes
//! first. Fine-grained tick determinism therefore holds in
//! single-charger-per-node regimes (one data-plane command per node at a
//! time — the `table2-sim` preset and the determinism tests), not for
//! arbitrary concurrent workloads; seeded long-run traces remain
//! *schedule*-deterministic (crash/revive draws are a function of the
//! seed alone) regardless.

use std::sync::Mutex;

use crate::clock::{Clock, ClockHandle, Tick};
use crate::cluster::NodeId;

use super::cost::CostModelHandle;
use super::work::GfWork;

/// Cumulative CPU-time reservation for one node.
pub struct CpuMeter {
    clock: ClockHandle,
    model: CostModelHandle,
    node: NodeId,
    /// Tick at which the node's core becomes free.
    next_free: Mutex<Tick>,
}

impl CpuMeter {
    /// Meter for `node`, pricing work with `model` on `clock`.
    pub fn new(clock: ClockHandle, model: CostModelHandle, node: NodeId) -> Self {
        let next_free = clock.now();
        Self {
            clock,
            model,
            node,
            next_free: Mutex::new(next_free),
        }
    }

    /// The cost model behind this meter.
    pub fn model(&self) -> &CostModelHandle {
        &self.model
    }

    /// The node this meter accounts for.
    pub fn node(&self) -> NodeId {
        self.node
    }

    /// Charge `work`: reserve the core for its priced duration (FIFO
    /// behind earlier charges) and sleep until the reservation ends.
    /// Returns the compute time charged — `ZERO` charges are free and do
    /// not serialize.
    pub fn charge(&self, work: &GfWork) -> Tick {
        let cost = self.model.cost(self.node, work);
        if cost.is_zero() {
            return Tick::ZERO;
        }
        let done = {
            let mut next = self.next_free.lock().unwrap();
            let now = self.clock.now();
            let start = if *next > now { *next } else { now };
            let done = start + cost;
            *next = done;
            done
        };
        self.clock.sleep_until(done);
        cost
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::SimClock;
    use crate::resources::{ProfileCost, UniformCost, ZeroCost};
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn zero_cost_charge_is_free_and_instant() {
        let clock = SimClock::handle();
        let m = CpuMeter::new(clock.clone(), ZeroCost::handle(), 0);
        assert_eq!(m.charge(&GfWork::mac(1 << 30)), Duration::ZERO);
        assert_eq!(clock.now(), Duration::ZERO, "free charge must not advance time");
    }

    #[test]
    fn uniform_charge_occupies_virtual_time() {
        let clock = SimClock::handle();
        let m = CpuMeter::new(clock.clone(), UniformCost::handle(), 0);
        // 250 MB of MAC at 250 MB/s = exactly 1 virtual second
        let dt = m.charge(&GfWork::mac(250_000_000));
        assert_eq!(dt, Duration::from_secs(1));
        assert_eq!(clock.now(), Duration::from_secs(1));
    }

    #[test]
    fn charges_serialize_like_one_core() {
        // two concurrent half-second charges on one meter end at 1 s of
        // virtual time total, regardless of arrival order.
        let clock = SimClock::handle();
        let m = Arc::new(CpuMeter::new(clock.clone(), UniformCost::handle(), 0));
        let hs: Vec<_> = (0..2)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    m.charge(&GfWork::mac(125_000_000));
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(clock.now(), Duration::from_secs(1));
    }

    #[test]
    fn profiled_meter_charges_its_nodes_speed() {
        let clock = SimClock::handle();
        let model = ProfileCost::handle(crate::resources::NodeProfile::ec2_mix()).unwrap();
        let slow = CpuMeter::new(clock.clone(), model.clone(), 0); // small
        let fast = CpuMeter::new(clock.clone(), model, 2); // large, 4x
        let w = GfWork::mac(100_000_000);
        let a = slow.charge(&w);
        let b = fast.charge(&w);
        assert_eq!(a, b * 4);
        assert_eq!(slow.node(), 0);
    }
}
