//! [`CpuMeter`]: per-node CPU reservation on the cluster clock — the
//! compute twin of the NIC [`RateLimiter`](crate::cluster::RateLimiter).
//!
//! A node's workers all charge the same meter, so concurrent data-plane
//! commands contend for the node's simulated cores with the same
//! cumulative-FIFO semantics that make NIC bandwidth sharing honest:
//! reservations serialize through a mutex onto the earliest-free core
//! lane (the model's [`CostModel::cores`](super::CostModel::cores) for
//! the node, read once at spawn — 1 unless the node's profile says
//! otherwise), the blocking happens on the clock, and under a `SimClock`
//! a charge is a discrete event with zero wall cost. On a multi-core
//! profile (e.g. `EC2_LARGE`) concurrent Gemm rows and Fold frames
//! genuinely overlap instead of queueing behind one core. A zero-priced
//! charge ([`ZeroCost`](super::ZeroCost), or genuinely zero work) returns
//! without touching the reservation state, so the default configuration
//! is tick-for-tick identical to the pre-resource-model dataplane.
//!
//! Determinism caveat (the same one the NIC limiter carries): the meter's
//! *aggregate* schedule is order-independent — the sum of reservations
//! commutes — but when several workers of one node charge at the same
//! virtual instant, mutex-acquisition order decides which completes
//! first. Fine-grained tick determinism therefore holds in
//! single-charger-per-node regimes (one data-plane command per node at a
//! time — the `table2-sim` preset and the determinism tests), not for
//! arbitrary concurrent workloads; seeded long-run traces remain
//! *schedule*-deterministic (crash/revive draws are a function of the
//! seed alone) regardless.

use std::sync::Mutex;

use crate::clock::{Clock, ClockHandle, Tick};
use crate::cluster::NodeId;

use super::cost::CostModelHandle;
use super::work::GfWork;

/// Cumulative CPU-time reservation for one node's core lanes.
pub struct CpuMeter {
    clock: ClockHandle,
    model: CostModelHandle,
    node: NodeId,
    cores: usize,
    /// Tick at which each core lane becomes free.
    lanes: Mutex<Vec<Tick>>,
}

impl CpuMeter {
    /// Meter for `node`, pricing work with `model` on `clock`. The lane
    /// count is `model.cores(node)` at construction time (profile churn
    /// later swaps pricing, never lanes).
    pub fn new(clock: ClockHandle, model: CostModelHandle, node: NodeId) -> Self {
        let cores = model.cores(node).max(1);
        let now = clock.now();
        Self {
            clock,
            model,
            node,
            cores,
            lanes: Mutex::new(vec![now; cores]),
        }
    }

    /// The cost model behind this meter.
    pub fn model(&self) -> &CostModelHandle {
        &self.model
    }

    /// The node this meter accounts for.
    pub fn node(&self) -> NodeId {
        self.node
    }

    /// The clock this meter charges on — handed to worker-side trace
    /// emits so dataplane events carry the same virtual timeline.
    pub fn clock(&self) -> &ClockHandle {
        &self.clock
    }

    /// Number of core lanes this meter reserves over.
    pub fn cores(&self) -> usize {
        self.cores
    }

    /// How long a new charge would queue before any core frees up — the
    /// compute analogue of the NIC load signal, read by placement
    /// policies (`ZERO` on an idle or never-charged meter).
    pub fn backlog(&self) -> Tick {
        let lanes = self.lanes.lock().unwrap();
        let earliest = *lanes.iter().min().expect("at least one lane");
        earliest.saturating_sub(self.clock.now())
    }

    /// Charge `work`: reserve the earliest-free core lane for its priced
    /// duration (FIFO behind earlier charges on that lane) and sleep until
    /// the reservation ends. Returns the compute time charged — `ZERO`
    /// charges are free and do not serialize.
    pub fn charge(&self, work: &GfWork) -> Tick {
        let (cost, done) = self.charge_reserve(work);
        if let Some(done) = done {
            self.clock.sleep_until(done);
        }
        cost
    }

    /// [`CpuMeter::charge`] without the sleep: price, emit and reserve the
    /// lane, returning `(cost, completion tick)`. The caller owes the wait
    /// until the completion tick (`None` for free charges) — this is the
    /// primitive cooperatively-scheduled tasks use, where "sleep" means
    /// yielding to the driver with a deadline instead of blocking a
    /// thread.
    pub fn charge_reserve(&self, work: &GfWork) -> (Tick, Option<Tick>) {
        let cost = self.model.cost(self.node, work);
        if cost.is_zero() {
            // zero charges stay emit-free too: a ZeroCost run's trace (and
            // tick schedule) is identical to the pre-resource-model one
            return (Tick::ZERO, None);
        }
        crate::trace_emit!(
            self.clock,
            self.node,
            crate::trace::EventKind::CpuCharge { work: *work, cost }
        );
        let done = {
            let mut lanes = self.lanes.lock().unwrap();
            let now = self.clock.now();
            // earliest-free lane; lowest index wins ties deterministically
            let lane = (0..lanes.len())
                .min_by_key(|&i| lanes[i])
                .expect("at least one lane");
            let start = if lanes[lane] > now { lanes[lane] } else { now };
            let done = start + cost;
            lanes[lane] = done;
            done
        };
        (cost, Some(done))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::SimClock;
    use crate::resources::{ProfileCost, UniformCost, ZeroCost};
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn zero_cost_charge_is_free_and_instant() {
        let clock = SimClock::handle();
        let m = CpuMeter::new(clock.clone(), ZeroCost::handle(), 0);
        assert_eq!(m.charge(&GfWork::mac(1 << 30)), Duration::ZERO);
        assert_eq!(clock.now(), Duration::ZERO, "free charge must not advance time");
    }

    #[test]
    fn uniform_charge_occupies_virtual_time() {
        let clock = SimClock::handle();
        let m = CpuMeter::new(clock.clone(), UniformCost::handle(), 0);
        // 250 MB of MAC at 250 MB/s = exactly 1 virtual second
        let dt = m.charge(&GfWork::mac(250_000_000));
        assert_eq!(dt, Duration::from_secs(1));
        assert_eq!(clock.now(), Duration::from_secs(1));
    }

    #[test]
    fn charges_serialize_like_one_core() {
        // two concurrent half-second charges on one meter end at 1 s of
        // virtual time total, regardless of arrival order.
        let clock = SimClock::handle();
        let m = Arc::new(CpuMeter::new(clock.clone(), UniformCost::handle(), 0));
        let hs: Vec<_> = (0..2)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    m.charge(&GfWork::mac(125_000_000));
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(clock.now(), Duration::from_secs(1));
    }

    #[test]
    fn profiled_meter_charges_its_nodes_speed() {
        let clock = SimClock::handle();
        let model = ProfileCost::handle(crate::resources::NodeProfile::ec2_mix()).unwrap();
        let slow = CpuMeter::new(clock.clone(), model.clone(), 0); // small
        let fast = CpuMeter::new(clock.clone(), model, 2); // large, 4x
        let w = GfWork::mac(100_000_000);
        let a = slow.charge(&w);
        let b = fast.charge(&w);
        assert_eq!(a, b * 4);
        assert_eq!(slow.node(), 0);
        assert_eq!(slow.cores(), 1);
        assert_eq!(fast.cores(), 2, "large profile is multicore");
    }

    #[test]
    fn multicore_meter_overlaps_concurrent_charges() {
        use crate::resources::{CostModel, NodeProfile, ProfileCost, UniformCost};
        // one-core twin: two 1-second charges serialize to 2 s; the
        // two-core meter finishes both in 1 s of virtual time.
        let run = |cores: usize| -> Duration {
            let clock = SimClock::handle();
            let profile = NodeProfile::custom("lab", 1.0).with_cores(cores);
            let model: Arc<dyn CostModel> =
                Arc::new(ProfileCost::new(UniformCost::calibrated(), vec![profile]).unwrap());
            let m = Arc::new(CpuMeter::new(clock.clone(), model, 0));
            // Busy tokens created BEFORE the spawns pin virtual time at 0
            // until both threads have issued their charge, so the overlap
            // is exercised deterministically (the node worker pattern).
            let hs: Vec<_> = (0..2)
                .map(|_| {
                    let m = m.clone();
                    let token = crate::clock::BusyToken::new(&clock);
                    std::thread::spawn(move || {
                        let _busy = token.bind();
                        m.charge(&GfWork::mac(250_000_000)); // 1 s at 250 MB/s
                    })
                })
                .collect();
            for h in hs {
                h.join().unwrap();
            }
            clock.now()
        };
        assert_eq!(run(1), Duration::from_secs(2));
        assert_eq!(run(2), Duration::from_secs(1));
    }

    #[test]
    fn charges_emit_trace_events_except_zero_priced_ones() {
        let clock = SimClock::handle();
        let sink = crate::trace::JsonlSink::shared();
        let _guard = crate::trace::install(&clock, sink.clone());
        let m = CpuMeter::new(clock.clone(), UniformCost::handle(), 3);
        m.charge(&GfWork::mac(250_000_000)); // 1 s at 250 MB/s
        let zero = CpuMeter::new(clock.clone(), ZeroCost::handle(), 3);
        zero.charge(&GfWork::mac(1 << 20));
        let events = sink.events();
        assert_eq!(events.len(), 1, "zero-priced charges must not emit");
        assert_eq!(events[0].node, Some(3));
        assert!(matches!(
            events[0].kind,
            crate::trace::EventKind::CpuCharge { cost, .. } if cost == Duration::from_secs(1)
        ));
    }

    #[test]
    fn backlog_reports_queued_compute() {
        let clock = SimClock::handle();
        let m = CpuMeter::new(clock.clone(), UniformCost::handle(), 0);
        assert_eq!(m.backlog(), Duration::ZERO, "idle meter has no backlog");
        m.charge(&GfWork::mac(250_000_000)); // sleeps until t=1s
        // after the charge completes the lane frees exactly at `now`
        assert_eq!(m.backlog(), Duration::ZERO);
    }

    #[test]
    fn backlog_sees_reserved_but_unslept_charges() {
        // charge_reserve books the lane without sleeping — exactly the
        // state a plan-boundary LoadSnapshot reads on a busy node.
        let clock = SimClock::handle();
        let m = CpuMeter::new(clock.clone(), UniformCost::handle(), 0);
        let (cost, done) = m.charge_reserve(&GfWork::mac(250_000_000)); // 1 s
        assert_eq!(cost, Duration::from_secs(1));
        assert_eq!(done, Some(Duration::from_secs(1)));
        assert_eq!(m.backlog(), Duration::from_secs(1));
        // a second reservation queues FIFO behind the first
        m.charge_reserve(&GfWork::mac(125_000_000)); // +0.5 s
        assert_eq!(m.backlog(), Duration::from_millis(1500));
        assert_eq!(clock.now(), Duration::ZERO, "backlog must not sleep");
        // zero-priced charges never touch the lanes
        let z = CpuMeter::new(clock, ZeroCost::handle(), 1);
        z.charge_reserve(&GfWork::mac(1 << 30));
        assert_eq!(z.backlog(), Duration::ZERO);
    }
}
