//! `rapidraid` CLI — drive the archival system and regenerate every table
//! and figure of the paper's evaluation from the command line.
//!
//! ```text
//! rapidraid census       [--max-n 16] [--trials 3]            # Fig. 3
//! rapidraid resilience   [--n 16 --k 11]                      # Table I
//! rapidraid bench-cpu    [--block-mib 4] [--pjrt]             # Table II
//! rapidraid bench-coding [--preset tpc|ec2] [--objects 1|16]
//!                        [--block-mib 1] [--samples 5]        # Fig. 4
//! rapidraid bench-congestion [--preset tpc|tpc-sim] [--max-congested 8]
//!                        [--objects 1] [--block-mib 1] [--samples 3] # Fig. 5
//! rapidraid bench-repair [--preset tpc|tpc-sim] [--max-congested 4]
//!                        [--block-mib 16] [--samples 3]       # star vs pipelined repair
//! rapidraid bench-table2-sim [--block-kib 1024] [--seed 5]    # Table II on the SimClock,
//!                                                             # compute charged (uniform +
//!                                                             # heterogeneous cost models)
//! rapidraid bench-topo-sim [--block-kib 512] [--seed 5]       # pipeline-shape shootout:
//!                                                             # chain vs tree vs hybrid ×
//!                                                             # uniform/ec2-mix cost, SimClock
//! rapidraid bench-straggler-sim [--block-kib 256] [--seed 5]  # adaptive control plane vs
//!                                                             # every static shape on a
//!                                                             # straggler-seeded SimClock pool
//! rapidraid bench-scale-sim [--smoke] [--nodes 2048] [--rack 32]
//!                        [--virtual-secs 86400] [--epoch-secs 1200]
//!                        [--objects-per-epoch 32] [--block-kib 8]
//!                        [--seed N]                           # one virtual day of rack-local
//!                                                             # archival on 2,048 multiplexed
//!                                                             # SimClock nodes
//! rapidraid sim-longrun  [--virtual-secs 1000] [--epoch-secs 10]
//!                        [--nodes 50] [--objects 8] [--seed N]
//!                        [--topology chain|tree:F|hybrid:P:F]
//!                        [--smoke]                            # DES failure trace
//! rapidraid trace-report <trace.jsonl>                        # per-node/link counters +
//!                                                             # critical-path attribution
//!                                                             # of a recorded trace
//! ```
//!
//! The SimClock presets (`bench-table2-sim`, `bench-topo-sim`,
//! `bench-straggler-sim`, `bench-scale-sim`, `sim-longrun`) additionally
//! accept:
//!
//! ```text
//! --runtime auto|threaded|multiplexed     dataplane execution runtime
//!                                         (default auto: SimClock specs
//!                                         resolve to the multiplexed
//!                                         single-driver scheduler; virtual
//!                                         timelines are runtime-invariant)
//! --trace <out.jsonl|out.perfetto.json>   record the dataplane event trace:
//!                                         a `.jsonl` path gets the canonical
//!                                         deterministic event log (input of
//!                                         `trace-report`), any other path a
//!                                         Chrome-trace/Perfetto timeline for
//!                                         ui.perfetto.dev
//! --trace-cap <events>                    bound the recorder: keep only the
//!                                         newest N events in memory (default
//!                                         2^20; env RAPIDRAID_TRACE_CAP) —
//!                                         scale presets emit more events
//!                                         than fit in RAM
//! --calibration <BENCH_gf-hotpath.json>   price compute with rates measured
//!                                         by `cargo bench gf_hotpath` on THIS
//!                                         machine instead of the built-in
//!                                         EC2-era constants (also read from
//!                                         the RAPIDRAID_CALIBRATION env var)
//! rapidraid sweep        [--smoke] [--virtual-secs N] [--nodes N]
//!                        [--objects N] [--seed N]             # triggers × policies × cost
//!                                                             # profiles × topologies
//!                                                             # (chain + tree:2) over traces
//! rapidraid demo         [--pjrt]                             # quick e2e
//! ```
//!
//! Every `bench-*` preset accepts a `-sim` suffix (`tpc-sim`, `ec2-sim`,
//! `test-sim`): the identical workload then runs on the discrete-event
//! `SimClock` — reported times are virtual network times and a paper-scale
//! sweep finishes in wall-clock seconds. `sim-longrun`, `sweep` and
//! `bench-table2-sim` always run under the SimClock; the latter charges
//! CPU cost models so compute occupies virtual time too.
//!
//! `bench-coding` / `bench-congestion` report per-stage time breakdowns
//! (transfer vs fold/gemm vs store) alongside the end-to-end candles —
//! the spans come from the coordinator's PlanExecutor. Every `bench-*`
//! command (and `sweep`) also writes a machine-readable
//! `BENCH_<preset>.json` into the working directory.
//!
//! (Hand-rolled argument parsing: the offline build has no clap.)

use std::collections::HashMap;
use std::sync::Arc;

use rapidraid::backend::{BackendHandle, NativeBackend, PjrtBackend};
use rapidraid::bench_scenarios as scenarios;
use rapidraid::codes::{census, rapidraid::RapidRaidCode};
use rapidraid::gf::Gf65536;
use rapidraid::reliability::table1;
use rapidraid::runtime::artifacts::default_dir;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, opts) = parse(&args);
    let code = match cmd.as_deref() {
        Some("census") => cmd_census(&opts),
        Some("resilience") => cmd_resilience(&opts),
        Some("bench-cpu") => cmd_bench_cpu(&opts),
        Some("bench-coding") => cmd_bench_coding(&opts),
        Some("bench-congestion") => cmd_bench_congestion(&opts),
        Some("bench-repair") => cmd_bench_repair(&opts),
        Some("bench-table2-sim") => cmd_bench_table2_sim(&opts),
        Some("bench-topo-sim") => cmd_bench_topo_sim(&opts),
        Some("bench-straggler-sim") => cmd_bench_straggler_sim(&opts),
        Some("bench-scale-sim") => cmd_bench_scale_sim(&opts),
        Some("sim-longrun") => cmd_sim_longrun(&opts),
        Some("trace-report") => cmd_trace_report(&opts),
        Some("sweep") => cmd_sweep(&opts),
        Some("demo") => cmd_demo(&opts),
        Some(other) => {
            eprintln!("unknown command: {other}\n");
            usage();
            Err(anyhow::anyhow!("bad usage"))
        }
        None => {
            usage();
            Ok(())
        }
    };
    if let Err(e) = code {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() {
    eprintln!(
        "rapidraid — pipelined erasure codes for fast data archival\n\
         commands:\n\
         \x20 census            dependency census, Fig. 3\n\
         \x20 resilience        static resilience, Table I\n\
         \x20 bench-cpu         CPU-only coding time, Table II\n\
         \x20 bench-coding      cluster coding times, Fig. 4\n\
         \x20 bench-congestion  congested-network sweep, Fig. 5\n\
         \x20 bench-repair      single-block repair, star vs pipelined\n\
         \x20 bench-table2-sim  Table II on the SimClock, CPU cost models charged\n\
         \x20 bench-topo-sim    pipeline-shape shootout: chain vs tree vs hybrid\n\
         \x20 bench-straggler-sim adaptive control plane vs static shapes on a\n\
         \x20                   straggler-seeded pool\n\
         \x20 bench-scale-sim   2,048-node virtual-day archival on the\n\
         \x20                   multiplexed runtime\n\
         \x20 sim-longrun       long-run crash/repair trace on the SimClock\n\
         \x20 sweep             repair triggers x policies x cost profiles x\n\
         \x20                   pipeline topologies (chain + tree:2) grid\n\
         \x20 trace-report      counters + critical-path attribution of a\n\
         \x20                   --trace'd .jsonl event log\n\
         \x20 demo              end-to-end migrate+decode demo\n\
         sim presets take --trace <out.jsonl|out.perfetto.json> and\n\
         --calibration <BENCH_gf-hotpath.json> (or RAPIDRAID_CALIBRATION);\n\
         see the doc comment in rust/src/main.rs for all options"
    );
}

fn parse(args: &[String]) -> (Option<String>, HashMap<String, String>) {
    let mut cmd = None;
    let mut opts = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string()
            };
            opts.insert(key.to_string(), val);
        } else if cmd.is_none() {
            cmd = Some(a.clone());
        } else {
            // First bare operand after the command becomes the `file`
            // option (e.g. the trace file of `trace-report <path>`).
            opts.entry("file".to_string()).or_insert_with(|| a.clone());
        }
        i += 1;
    }
    (cmd, opts)
}

fn get<T: std::str::FromStr>(opts: &HashMap<String, String>, key: &str, default: T) -> T {
    opts.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn backend(opts: &HashMap<String, String>) -> anyhow::Result<BackendHandle> {
    if opts.contains_key("pjrt") {
        println!("# backend: pjrt (artifacts: {})", default_dir().display());
        Ok(Arc::new(PjrtBackend::load(&default_dir())?))
    } else {
        println!("# backend: native");
        Ok(Arc::new(NativeBackend::new()))
    }
}

fn cmd_census(opts: &HashMap<String, String>) -> anyhow::Result<()> {
    let max_n: usize = get(opts, "max-n", 16);
    let trials: usize = get(opts, "trials", 3);
    println!("# Fig. 3 — linear dependencies of (n,k) RapidRAID codes");
    println!(
        "{:>4} {:>4} {:>10} {:>12} {:>14}",
        "n", "k", "subsets", "dependent", "%independent"
    );
    for n in [8usize, 12, 16] {
        if n > max_n {
            continue;
        }
        for k in (n / 2)..n {
            let r = census(n, k, trials, 1)?;
            println!(
                "{:>4} {:>4} {:>10} {:>12} {:>13.4}%",
                n,
                k,
                r.total_subsets,
                r.dependent_count(),
                r.percent_independent()
            );
        }
    }
    println!("# Conjecture 1: MDS iff k >= n-3 — verify the zeros above");
    Ok(())
}

fn cmd_resilience(opts: &HashMap<String, String>) -> anyhow::Result<()> {
    let n: usize = get(opts, "n", 16);
    let k: usize = get(opts, "k", 11);
    let code = RapidRaidCode::<Gf65536>::with_seed(n, k, 12)?;
    println!("# Table I — static resiliency (number of 9's)");
    println!(
        "{:<24} {:>7} {:>7} {:>7} {:>8}",
        "scheme", "p=0.2", "p=0.1", "p=0.01", "p=0.001"
    );
    for row in table1(n, k, code.generator()) {
        print!("{:<24}", row.scheme);
        for v in row.nines {
            print!(" {v:>7}");
        }
        println!();
    }
    Ok(())
}

/// Write a bench command's machine-readable twin next to its stdout table.
fn emit_json(report: &rapidraid::metrics::BenchJson) -> anyhow::Result<()> {
    let path = report.write_to_dir(std::path::Path::new("."))?;
    println!("# wrote {}", path.display());
    Ok(())
}

/// Measured compute rates from `--calibration <BENCH_gf-hotpath.json>` or
/// the `RAPIDRAID_CALIBRATION` env var; `None` when neither is set.
fn calibration_from(
    opts: &HashMap<String, String>,
) -> anyhow::Result<Option<rapidraid::resources::UniformCost>> {
    let path = opts
        .get("calibration")
        .cloned()
        .or_else(|| std::env::var("RAPIDRAID_CALIBRATION").ok());
    let Some(path) = path else { return Ok(None) };
    let text = std::fs::read_to_string(&path)
        .map_err(|e| anyhow::anyhow!("reading calibration report {path}: {e}"))?;
    let bench = rapidraid::metrics::BenchJson::from_json(&text)?;
    let rates = rapidraid::resources::UniformCost::from_measured(&bench)?;
    println!("# calibration: measured GF rates from {path}");
    Ok(Some(rates))
}

/// `--runtime auto|threaded|multiplexed` (default `auto`) for the SimClock
/// presets — picks the dataplane execution runtime; virtual timelines are
/// runtime-invariant, so this swaps the engine, not the results.
fn runtime_from(opts: &HashMap<String, String>) -> anyhow::Result<rapidraid::cluster::RuntimeKind> {
    match opts.get("runtime") {
        Some(s) => rapidraid::cluster::RuntimeKind::parse(s),
        None => Ok(rapidraid::cluster::RuntimeKind::Auto),
    }
}

/// Default `--trace` ring capacity: one million events (~100 MB retained
/// worst-case) — far beyond a paper-scale scenario, small enough that a
/// scale_sim run over millions of objects cannot exhaust memory.
const TRACE_CAP_DEFAULT: usize = 1 << 20;

/// An installed `--trace` recording session: a process-global *bounded*
/// ring (capacity `--trace-cap` / `RAPIDRAID_TRACE_CAP`) plus the output
/// path its newest events flush to when finished. Bounding the recorder
/// keeps `--trace` usable on scale-preset runs whose full event streams
/// would not fit in memory; until the ring overflows the flushed JSONL is
/// byte-identical to the old unbounded recording.
struct TraceSession {
    sink: std::sync::Arc<rapidraid::trace::RingSink>,
    guard: rapidraid::trace::TraceGuard,
    path: std::path::PathBuf,
}

/// Install a process-global trace recorder when `--trace <path>` is given.
fn trace_from(opts: &HashMap<String, String>) -> Option<TraceSession> {
    let path = std::path::PathBuf::from(opts.get("trace")?);
    let cap = opts
        .get("trace-cap")
        .cloned()
        .or_else(|| std::env::var("RAPIDRAID_TRACE_CAP").ok())
        .and_then(|v| v.parse().ok())
        .unwrap_or(TRACE_CAP_DEFAULT);
    let sink = rapidraid::trace::RingSink::shared(cap);
    let guard = rapidraid::trace::install_global(sink.clone());
    Some(TraceSession { sink, guard, path })
}

/// Uninstall the recorder, fold its counters into `report` (when given)
/// and write the trace out — canonical JSONL for a `.jsonl` path, a
/// Chrome-trace/Perfetto timeline for anything else.
fn finish_trace(
    trace: Option<TraceSession>,
    report: Option<&mut rapidraid::metrics::BenchJson>,
) -> anyhow::Result<()> {
    let Some(t) = trace else { return Ok(()) };
    drop(t.guard);
    if t.sink.overflowed() {
        println!(
            "# trace ring overflowed: kept the newest {} of {} events \
             (raise --trace-cap / RAPIDRAID_TRACE_CAP for a full recording)",
            t.sink.snapshot().len(),
            t.sink.recorded()
        );
    }
    let events = rapidraid::trace::canonical_order(t.sink.snapshot());
    if let Some(r) = report {
        rapidraid::trace::derive_counters(&events).fold_into(r);
    }
    if t.path.extension().and_then(|e| e.to_str()) == Some("jsonl") {
        t.sink.write_jsonl(&t.path)?;
    } else {
        std::fs::write(&t.path, rapidraid::trace::chrome_trace(&events))
            .map_err(|e| anyhow::anyhow!("writing trace {}: {e}", t.path.display()))?;
    }
    println!("# wrote trace {}", t.path.display());
    Ok(())
}

fn cmd_bench_cpu(opts: &HashMap<String, String>) -> anyhow::Result<()> {
    let block_mib: usize = get(opts, "block-mib", 4);
    let be = backend(opts)?;
    let report = scenarios::table2_cpu(&be, block_mib << 20, &mut std::io::stdout().lock())?;
    emit_json(&report)
}

fn cmd_bench_coding(opts: &HashMap<String, String>) -> anyhow::Result<()> {
    let preset = opts.get("preset").cloned().unwrap_or_else(|| "ec2".into());
    let objects: usize = get(opts, "objects", 1);
    let block_mib: usize = get(opts, "block-mib", 1);
    let samples: usize = get(opts, "samples", 5);
    let be = backend(opts)?;
    let report = scenarios::fig4_coding_times(
        &be,
        &preset,
        objects,
        block_mib << 20,
        samples,
        &mut std::io::stdout().lock(),
    )?;
    emit_json(&report)
}

fn cmd_bench_congestion(opts: &HashMap<String, String>) -> anyhow::Result<()> {
    let preset = opts.get("preset").cloned().unwrap_or_else(|| "tpc".into());
    let max_congested: usize = get(opts, "max-congested", 8);
    let objects: usize = get(opts, "objects", 1);
    let block_mib: usize = get(opts, "block-mib", 1);
    let samples: usize = get(opts, "samples", 3);
    let be = backend(opts)?;
    let report = scenarios::fig5_congestion(
        &be,
        &preset,
        max_congested,
        objects,
        block_mib << 20,
        samples,
        &mut std::io::stdout().lock(),
    )?;
    emit_json(&report)
}

fn cmd_bench_repair(opts: &HashMap<String, String>) -> anyhow::Result<()> {
    let preset = opts.get("preset").cloned().unwrap_or_else(|| "tpc".into());
    let max_congested: usize = get(opts, "max-congested", 4);
    let block_mib: usize = get(opts, "block-mib", 16);
    let samples: usize = get(opts, "samples", 3);
    let be = backend(opts)?;
    let report = scenarios::fig_repair(
        &be,
        &preset,
        max_congested,
        block_mib << 20,
        samples,
        &mut std::io::stdout().lock(),
    )?;
    emit_json(&report)
}

fn cmd_bench_table2_sim(opts: &HashMap<String, String>) -> anyhow::Result<()> {
    let block_kib: usize = get(opts, "block-kib", 1024);
    let seed: u64 = get(opts, "seed", 5);
    let be = backend(opts)?;
    let calibration = calibration_from(opts)?;
    let trace = trace_from(opts);
    let (_rows, mut report) = scenarios::table2_sim_calibrated(
        &be,
        block_kib << 10,
        seed,
        calibration,
        runtime_from(opts)?,
        &mut std::io::stdout().lock(),
    )?;
    finish_trace(trace, Some(&mut report))?;
    emit_json(&report)
}

fn cmd_bench_topo_sim(opts: &HashMap<String, String>) -> anyhow::Result<()> {
    let block_kib: usize = get(opts, "block-kib", 512);
    let seed: u64 = get(opts, "seed", 5);
    let be = backend(opts)?;
    let calibration = calibration_from(opts)?;
    let trace = trace_from(opts);
    let (_rows, mut report) = scenarios::topo_sim_calibrated(
        &be,
        block_kib << 10,
        seed,
        calibration,
        runtime_from(opts)?,
        &mut std::io::stdout().lock(),
    )?;
    finish_trace(trace, Some(&mut report))?;
    emit_json(&report)
}

fn cmd_bench_straggler_sim(opts: &HashMap<String, String>) -> anyhow::Result<()> {
    let block_kib: usize = get(opts, "block-kib", 256);
    let seed: u64 = get(opts, "seed", 5);
    let be = backend(opts)?;
    let calibration = calibration_from(opts)?;
    let trace = trace_from(opts);
    let (rows, mut report) = scenarios::straggler_sim_calibrated(
        &be,
        block_kib << 10,
        seed,
        calibration,
        runtime_from(opts)?,
        &mut std::io::stdout().lock(),
    )?;
    finish_trace(trace, Some(&mut report))?;
    // The preset's reason to exist: the closed loop must win on this pool.
    for (n, k) in [(11usize, 8usize), (22, 16)] {
        let adaptive = rows
            .iter()
            .find(|r| r.n == n && r.adaptive)
            .map(|r| r.makespan)
            .ok_or_else(|| anyhow::anyhow!("no adaptive cell for n={n}"))?;
        for r in rows.iter().filter(|r| r.n == n && !r.adaptive) {
            anyhow::ensure!(
                adaptive <= r.makespan,
                "(n={n},k={k}) adaptive {adaptive:?} lost to static {} at {:?}",
                r.cell,
                r.makespan
            );
        }
    }
    emit_json(&report)
}

fn cmd_bench_scale_sim(opts: &HashMap<String, String>) -> anyhow::Result<()> {
    use rapidraid::bench_scenarios::{scale_sim, ScaleSimConfig};
    let mut cfg = if opts.contains_key("smoke") {
        ScaleSimConfig::smoke()
    } else {
        ScaleSimConfig::paper_scale()
    };
    cfg.nodes = get(opts, "nodes", cfg.nodes);
    cfg.rack = get(opts, "rack", cfg.rack);
    cfg.virtual_secs = get(opts, "virtual-secs", cfg.virtual_secs);
    cfg.epoch_secs = get(opts, "epoch-secs", cfg.epoch_secs);
    cfg.objects_per_epoch = get(opts, "objects-per-epoch", cfg.objects_per_epoch);
    cfg.block_bytes = get::<usize>(opts, "block-kib", cfg.block_bytes >> 10) << 10;
    cfg.seed = get(opts, "seed", cfg.seed);
    cfg.runtime = runtime_from(opts)?;
    let be = backend(opts)?;
    let trace = trace_from(opts);
    let (report, mut bench) = {
        let out = &mut std::io::stdout().lock();
        scale_sim(&cfg, &be, out)?
    };
    finish_trace(trace, Some(&mut bench))?;
    anyhow::ensure!(
        report.verified == report.epochs as usize,
        "scale-sim: {}/{} epochs verified",
        report.verified,
        report.epochs
    );
    emit_json(&bench)
}

fn cmd_sweep(opts: &HashMap<String, String>) -> anyhow::Result<()> {
    use rapidraid::workload::{run_sweep, LongRunConfig, SweepConfig};
    let mut base = if opts.contains_key("smoke") {
        LongRunConfig::smoke()
    } else {
        LongRunConfig::paper_scale()
    };
    base.virtual_secs = get(opts, "virtual-secs", base.virtual_secs);
    base.epoch_secs = get(opts, "epoch-secs", base.epoch_secs);
    base.nodes = get(opts, "nodes", base.nodes);
    base.objects = get(opts, "objects", base.objects);
    base.seed = get(opts, "seed", base.seed);
    let grid = if opts.contains_key("smoke") {
        let mut g = SweepConfig::smoke();
        g.base = base;
        g
    } else {
        SweepConfig::default_grid(base)
    };
    let be = backend(opts)?;
    let (rows, report) = run_sweep(&grid, &be, &mut std::io::stdout().lock())?;
    anyhow::ensure!(
        rows.iter().all(|r| r.report.all_decodable()),
        "data loss in a sweep cell"
    );
    emit_json(&report)
}

fn cmd_sim_longrun(opts: &HashMap<String, String>) -> anyhow::Result<()> {
    use rapidraid::workload::{run_long_run, LongRunConfig};
    let mut cfg = if opts.contains_key("smoke") {
        LongRunConfig::smoke()
    } else {
        LongRunConfig::paper_scale()
    };
    cfg.virtual_secs = get(opts, "virtual-secs", cfg.virtual_secs);
    cfg.epoch_secs = get(opts, "epoch-secs", cfg.epoch_secs);
    cfg.nodes = get(opts, "nodes", cfg.nodes);
    cfg.objects = get(opts, "objects", cfg.objects);
    cfg.seed = get(opts, "seed", cfg.seed);
    if let Some(t) = opts.get("topology") {
        cfg.topology = rapidraid::coordinator::Topology::parse(t)?;
    }
    cfg.runtime = runtime_from(opts)?;
    cfg.calibration = calibration_from(opts)?;
    let be = backend(opts)?;
    let trace = trace_from(opts);
    let report = {
        let out = &mut std::io::stdout().lock();
        run_long_run(&cfg, &be, Some(out))?
    };
    finish_trace(trace, None)?;
    anyhow::ensure!(
        report.all_decodable(),
        "data loss in the trace: {}",
        report.summary()
    );
    Ok(())
}

fn cmd_trace_report(opts: &HashMap<String, String>) -> anyhow::Result<()> {
    let path = opts.get("file").ok_or_else(|| {
        anyhow::anyhow!("trace-report needs a trace file: rapidraid trace-report <trace.jsonl>")
    })?;
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading trace {path}: {e}"))?;
    let events = rapidraid::trace::parse_jsonl(&text)?;
    println!("# trace-report — {path} ({} events)", events.len());
    let counters = rapidraid::trace::derive_counters(&events);
    for line in counters.summary_lines() {
        println!("{line}");
    }
    let plans = rapidraid::trace::attribute_plans(&events);
    if plans.is_empty() {
        println!("# no complete PlanStart/PlanEnd window in the trace");
    } else {
        print!("{}", rapidraid::trace::render_attribution(&plans));
    }
    Ok(())
}

fn cmd_demo(opts: &HashMap<String, String>) -> anyhow::Result<()> {
    use rapidraid::cluster::{Cluster, ClusterSpec};
    use rapidraid::coordinator::{ingest_object, migrate_object, reconstruct};
    use rapidraid::storage::{ObjectId, ReplicaPlacement};

    let be = backend(opts)?;
    let cluster = Cluster::start(ClusterSpec::tpc(16));
    let object = ObjectId(1);
    let placement = ReplicaPlacement::new(object, 11, (0..16).collect())?;
    let blocks = ingest_object(&cluster, &placement, 1 << 20)?;
    let code = RapidRaidCode::<Gf65536>::with_seed(16, 11, 12)?;
    println!("archiving obj-1 (11 x 1 MiB) with a (16,11) RapidRAID pipeline…");
    let report = migrate_object(&cluster, &code, &placement, &blocks, &be, 65536)?;
    println!(
        "coding time: {:?}; storage 2.00x replicated -> {:.2}x coded; {} replicas reclaimed",
        report.coding_time,
        report.overhead_after(11 << 20),
        report.replicas_dropped
    );
    let rec = reconstruct(&cluster, &code, &placement.chain, object, &be)?;
    anyhow::ensure!(rec == blocks, "decode mismatch");
    println!("decode verified bit-exact. demo OK");
    Ok(())
}
