//! Multiplexed discrete-event dataplane: every node command loop and every
//! plan-step worker as a cooperatively-scheduled task on ONE driver thread.
//!
//! The threaded dataplane spawns an OS thread per node plus one per
//! in-flight data-plane command. That is the paper-faithful shape for
//! `RealClock` testbeds (real concurrency, real wall time), but under a
//! `SimClock` the threads do nothing except park on condvars and take
//! turns — a 2,000-node cluster burns its wall time on context switches.
//! The [`MultiplexedRuntime`] replaces all of them with resumable state
//! machines ([`Task`]) driven by a single OS thread:
//!
//! * the driver is exactly one clock *participant*; while it runs tasks the
//!   virtual clock is pinned, and when every task is waiting it parks on
//!   the clock via [`WakeHub::park`], registering the earliest task
//!   deadline as a clock sleeper — so quiescence advances virtual time
//!   exactly as it would with parked threads;
//! * channel sends wake tasks through a registered [`TaskWaker`] (with the
//!   same busy-credit handoff `clock::chan` gives threads), so the
//!   send→resume window can never let time slip;
//! * each task mirrors its blocking twin in `node.rs` **wait point for
//!   wait point**: `Tx::send` splits into [`Tx::begin_send`] → sleep →
//!   [`Tx::commit_send`], `Rx::recv` into [`Rx::poll`] → sleep →
//!   [`Rx::note_recvd`], `CpuMeter::charge` into
//!   [`CpuMeter::charge_reserve`] → sleep. Every reservation, RNG draw and
//!   trace emit happens at the same virtual tick as in the threaded
//!   runtime — that is the determinism contract the parity tests in
//!   `tests/scale.rs` lock in: same seed ⇒ byte-identical blocks and
//!   tick-identical traces under either runtime.
//!
//! Scheduling is deterministic: a FIFO ready queue, a `(deadline, seq)`
//! B-tree for sleepers (same-tick tasks run in registration order), and
//! wake delivery ordered by send order. Task polls are spurious-wake safe —
//! every wait point re-checks its condition on resume — so a stray waker
//! firing while a task sleeps on a deadline costs one no-op poll, nothing
//! else.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::mem;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::link::{Frame, Payload, PendingSend, Rx, RxPoll, Tx};
use super::node::{
    fold_frame, reject, stamp_finished, Command, Msg, NodeCore, ParityDest, SourceStream,
    StepResult, StepStats, QUEUE_STALL_OVERFLOW,
};
use crate::backend::{BackendHandle, Width};
use crate::clock::chan::TryRecvError;
use crate::clock::task::{TaskId, TaskWaker, WakeHub};
use crate::clock::{self, BusyToken, Clock, ClockHandle, SimClock, Tick};
use crate::resources::{CpuMeter, GfWork};
use crate::storage::{BlockKey, BlockStore};
use crate::trace::EventKind;

/// Which execution runtime a [`Cluster`](super::Cluster) drives its nodes
/// with.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RuntimeKind {
    /// Pick from the spec's clock: a `SimClock` gets the [`Multiplexed`]
    /// fast path, a `RealClock` the paper-faithful [`Threaded`] dataplane.
    ///
    /// [`Multiplexed`]: RuntimeKind::Multiplexed
    /// [`Threaded`]: RuntimeKind::Threaded
    #[default]
    Auto,
    /// One OS thread per node plus one per in-flight data-plane command —
    /// required for `RealClock` (real concurrency costs real time).
    Threaded,
    /// Every node loop and worker as a cooperatively-scheduled task on one
    /// driver thread. `SimClock` only.
    Multiplexed,
}

impl RuntimeKind {
    /// Resolve `Auto` against a clock.
    pub fn resolve(self, clock: &ClockHandle) -> RuntimeKind {
        match self {
            RuntimeKind::Auto => {
                if clock.as_sim().is_some() {
                    RuntimeKind::Multiplexed
                } else {
                    RuntimeKind::Threaded
                }
            }
            k => k,
        }
    }

    /// Parse a CLI label (`auto` | `threaded` | `multiplexed`).
    pub fn parse(s: &str) -> anyhow::Result<RuntimeKind> {
        match s {
            "auto" => Ok(RuntimeKind::Auto),
            "threaded" => Ok(RuntimeKind::Threaded),
            "multiplexed" => Ok(RuntimeKind::Multiplexed),
            other => {
                anyhow::bail!("unknown runtime {other:?} (auto | threaded | multiplexed)")
            }
        }
    }

    /// Short label for report tables (the inverse of [`RuntimeKind::parse`]).
    pub fn name(self) -> &'static str {
        match self {
            RuntimeKind::Auto => "auto",
            RuntimeKind::Threaded => "threaded",
            RuntimeKind::Multiplexed => "multiplexed",
        }
    }
}

impl std::fmt::Display for RuntimeKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// What a task reports back to the driver from one poll.
enum TaskPoll {
    /// Blocked on a channel: sleep until the registered waker fires.
    Park,
    /// Wake at the given tick (a channel waker may still fire earlier; the
    /// poll re-checks its condition either way).
    Sleep(Tick),
    /// Task complete; drop it.
    Done,
}

/// A resumable state machine scheduled by the [`Driver`].
trait Task: Send {
    /// Attach `waker` to every channel this task will ever wait on (called
    /// once, when the driver adopts the task).
    fn register(&self, waker: TaskWaker);

    /// Run until the next wait point. Tasks spawned by this poll (worker
    /// tasks of a node loop) are pushed onto `spawn` and adopted by the
    /// driver immediately after.
    fn poll(&mut self, spawn: &mut Vec<Box<dyn Task>>) -> TaskPoll;
}

struct TaskEntry {
    /// `None` only transiently while the task is being polled.
    task: Option<Box<dyn Task>>,
    /// Already in the ready queue (dedupes redundant wakes).
    queued: bool,
    /// Key of this task's entry in the sleeping tree, if any.
    sleep_key: Option<(Tick, u64)>,
}

/// The single-threaded cooperative scheduler behind a
/// [`MultiplexedRuntime`].
struct Driver {
    clock: ClockHandle,
    sim: SimClock,
    hub: Arc<WakeHub>,
    tasks: HashMap<TaskId, TaskEntry>,
    ready: VecDeque<TaskId>,
    /// Tasks waiting on a deadline, ordered by `(tick, registration seq)`
    /// so same-tick wakeups replay in a deterministic order.
    sleeping: BTreeMap<(Tick, u64), TaskId>,
    seq: u64,
    next_id: TaskId,
}

impl Driver {
    fn new(clock: ClockHandle, sim: SimClock) -> Self {
        Self {
            clock,
            sim,
            hub: WakeHub::new(),
            tasks: HashMap::new(),
            ready: VecDeque::new(),
            sleeping: BTreeMap::new(),
            seq: 0,
            next_id: 0,
        }
    }

    /// Adopt a task: register its waker, queue it for an immediate first
    /// poll (the moral equivalent of the threaded runtime creating a
    /// `BusyToken` before `thread::spawn` — the driver is already busy, so
    /// no virtual time can pass before the task first runs).
    fn spawn(&mut self, task: Box<dyn Task>) {
        let id = self.next_id;
        self.next_id += 1;
        task.register(TaskWaker::new(self.hub.clone(), id));
        self.tasks.insert(
            id,
            TaskEntry {
                task: Some(task),
                queued: true,
                sleep_key: None,
            },
        );
        self.ready.push_back(id);
    }

    /// Queue a woken task (no-op for completed or already-queued tasks).
    fn enqueue(&mut self, id: TaskId) {
        let Some(entry) = self.tasks.get_mut(&id) else {
            return;
        };
        if entry.queued {
            return;
        }
        entry.queued = true;
        if let Some(key) = entry.sleep_key.take() {
            self.sleeping.remove(&key);
        }
        self.ready.push_back(id);
    }

    fn poll_one(&mut self, id: TaskId) {
        let Some(entry) = self.tasks.get_mut(&id) else {
            return;
        };
        entry.queued = false;
        if let Some(key) = entry.sleep_key.take() {
            self.sleeping.remove(&key);
        }
        let mut task = entry.task.take().expect("task polled reentrantly");
        let mut spawned = Vec::new();
        match task.poll(&mut spawned) {
            TaskPoll::Done => {
                self.tasks.remove(&id);
            }
            TaskPoll::Park => {
                self.tasks.get_mut(&id).expect("entry still present").task = Some(task);
            }
            TaskPoll::Sleep(at) => {
                let key = (at, self.seq);
                self.seq += 1;
                self.sleeping.insert(key, id);
                let entry = self.tasks.get_mut(&id).expect("entry still present");
                entry.task = Some(task);
                entry.sleep_key = Some(key);
            }
        }
        for t in spawned {
            self.spawn(t);
        }
    }

    /// Run until every task has completed (each node task completes on
    /// `Shutdown`, sent by its `NodeHandle`'s drop).
    fn run(&mut self) {
        loop {
            while let Some(id) = self.ready.pop_front() {
                self.poll_one(id);
            }
            if self.tasks.is_empty() {
                break;
            }
            // Park on the clock: the earliest task deadline (if any) is
            // registered as a clock sleeper, so a quiescent dataplane
            // advances virtual time straight to it; channel wakers (with
            // their busy credit) cut the park short.
            let deadline = self.sleeping.keys().next().map(|&(at, _)| at);
            for id in self.hub.park(&self.sim, deadline) {
                self.enqueue(id);
            }
            let now = self.clock.now();
            while let Some((&key, &id)) = self.sleeping.iter().next() {
                if key.0 > now {
                    break;
                }
                self.sleeping.remove(&key);
                if let Some(entry) = self.tasks.get_mut(&id) {
                    entry.sleep_key = None;
                }
                self.enqueue(id);
            }
        }
    }
}

/// Handle to a running multiplexed dataplane: one driver OS thread
/// cooperatively scheduling all node loops and workers of a cluster.
///
/// Drop order matters for the owner: the driver exits when every node task
/// has processed its `Shutdown`, so the owning [`Cluster`](super::Cluster)
/// must drop its `NodeHandle`s (whose drops send `Shutdown`) *before* this
/// handle's drop joins the driver.
pub(crate) struct MultiplexedRuntime {
    driver: Option<JoinHandle<()>>,
}

impl MultiplexedRuntime {
    /// Launch the driver thread over one task per [`NodeCore`].
    pub(crate) fn launch(clock: &ClockHandle, cores: Vec<NodeCore>) -> Self {
        assert!(
            clock.as_sim().is_some(),
            "the multiplexed runtime requires a SimClock"
        );
        let sim = clock.as_sim().expect("checked above").clone();
        let clock2 = clock.clone();
        // Token created before the spawn: the driver counts as busy from
        // the instant it exists, so virtual time can't slip during startup.
        let token = BusyToken::new(clock);
        let driver = std::thread::Builder::new()
            .name("mux-driver".into())
            .spawn(move || {
                let _busy = token.bind();
                let mut driver = Driver::new(clock2, sim);
                for core in cores {
                    driver.spawn(Box::new(NodeTask::new(core)));
                }
                driver.run();
            })
            .expect("spawn multiplexed driver thread");
        Self {
            driver: Some(driver),
        }
    }
}

impl Drop for MultiplexedRuntime {
    fn drop(&mut self) {
        if let Some(driver) = self.driver.take() {
            let _ = driver.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Split-wait building blocks shared by the worker state machines.
// ---------------------------------------------------------------------------

/// Outcome of driving one split [`Tx::send`] forward.
enum SendDrive {
    /// Sleep until the tick, then drive again.
    Wait(Tick),
    /// Frame committed (enqueued with its delivery tick).
    Sent,
}

/// Drive a begun frame ([`Tx::begin_send`] already called, `slot` holds the
/// [`PendingSend`]) to its commit, mirroring the threaded `Tx::send` pace:
/// sleep to `ready_at - pacing_slack`, then commit. Idempotent across
/// spurious wakes — the deadline is re-checked on every call.
fn drive_send(tx: &mut Tx, slot: &mut Option<PendingSend>, clock: &ClockHandle) -> anyhow::Result<SendDrive> {
    let pending = slot.take().expect("drive_send without a begun frame");
    if pending.paced() {
        let wake = pending.ready_at.saturating_sub(clock.pacing_slack());
        if wake > clock.now() {
            *slot = Some(pending);
            return Ok(SendDrive::Wait(wake));
        }
    }
    tx.commit_send(pending)?;
    Ok(SendDrive::Sent)
}

/// Outcome of driving one split [`Rx::recv`] forward.
enum RecvDrive {
    /// Sleep until the frame's delivery tick, then drive again.
    Wait(Tick),
    /// Nothing queued: park until the channel waker fires.
    Channel,
    /// The threaded `Rx::recv` return value: `Some(frame)` consumed at its
    /// delivery tick (trace event emitted), `None` for a dropped sender.
    Got(Option<Frame>),
}

/// Drive one frame receive: poll the queue, hold the frame in `stash`
/// across the wait to its delivery tick, then emit the receive trace event
/// exactly as the threaded path does.
fn drive_recv(rx: &Rx, stash: &mut Option<(Tick, Frame)>, clock: &ClockHandle) -> RecvDrive {
    if stash.is_none() {
        match rx.poll() {
            RxPoll::Ready(at, frame) => *stash = Some((at, frame)),
            RxPoll::Empty => return RecvDrive::Channel,
            RxPoll::Disconnected => return RecvDrive::Got(None),
        }
    }
    let (at, frame) = stash.take().expect("stash just filled");
    if at > clock.now() {
        *stash = Some((at, frame));
        return RecvDrive::Wait(at);
    }
    rx.note_recvd(at, &frame);
    RecvDrive::Got(Some(frame))
}

/// A [`CpuMeter::charge_reserve`] whose completion wait is owed to the
/// driver (the task twin of the sleep inside `CpuMeter::charge`).
#[derive(Default)]
struct ChargeWait(Option<Tick>);

impl ChargeWait {
    /// Price and reserve `work`, accumulating the charged compute time.
    fn begin(&mut self, cpu: &CpuMeter, work: &GfWork, compute: &mut Tick) {
        let (cost, done) = cpu.charge_reserve(work);
        *compute += cost;
        self.0 = done;
    }

    /// `Some(t)`: keep sleeping until `t`. `None`: the charge is complete.
    fn pending(&mut self, clock: &ClockHandle) -> Option<Tick> {
        match self.0 {
            Some(t) if t > clock.now() => Some(t),
            _ => {
                self.0 = None;
                None
            }
        }
    }
}

/// Per-worker clones of the node state the threaded `spawn_worker` closure
/// captures, plus the completion protocol shared by all worker tasks.
struct WorkerEnv {
    clock: ClockHandle,
    store: BlockStore,
    cpu: Arc<CpuMeter>,
    inflight: Arc<std::sync::atomic::AtomicUsize>,
    loopback: clock::Sender<Msg>,
    failed: Arc<std::sync::atomic::AtomicBool>,
}

impl WorkerEnv {
    /// Worker epilogue, in the exact threaded order: stamp and send the
    /// result, release the inflight slot, hand the worker slot back to the
    /// node loop (which may already be gone — ignored, as in the threaded
    /// runtime).
    fn complete(&self, done: &clock::Sender<StepResult>, r: StepResult) {
        let _ = done.send(stamp_finished(r, &self.clock));
        self.inflight.fetch_sub(1, Ordering::Relaxed);
        let _ = self.loopback.send(Msg::WorkerDone);
    }
}

/// Build the worker task for a data-plane command (the task twin of
/// `run_dataplane`'s dispatch).
fn worker_task(env: WorkerEnv, cmd: Command) -> Box<dyn Task> {
    match cmd {
        Command::Upload {
            key,
            tx,
            buf_bytes,
            done,
        } => Box::new(UploadTask {
            env,
            key,
            tx,
            buf_bytes,
            done,
            payload: None,
            off: 0,
            end_sent: false,
            pending: None,
        }),
        Command::Receive {
            key,
            rx,
            expect_bytes,
            done,
        } => Box::new(ReceiveTask {
            env,
            key,
            rx,
            done,
            data: Vec::with_capacity(expect_bytes),
            stash: None,
            streamed: false,
            charged: false,
            charge: ChargeWait::default(),
            compute: Tick::ZERO,
        }),
        Command::PipelineStage {
            width,
            locals,
            psi,
            xi,
            prev,
            next,
            out_key,
            buf_bytes,
            backend,
            done,
        } => Box::new(PipelineStageTask {
            env,
            width,
            locals,
            psi,
            xi,
            prev,
            next,
            out_key,
            buf_bytes,
            backend,
            done,
            state: StageState::Recv,
            init: None,
            out: Vec::new(),
            frame_no: 0,
            compute: Tick::ZERO,
            offset: 0,
            stash: None,
            pending: None,
            charge: ChargeWait::default(),
            fold: None,
            fwd: None,
            fwd_idx: 0,
            close_idx: 0,
        }),
        Command::ClassicalEncode {
            width,
            sources,
            parity_rows,
            dests,
            buf_bytes,
            block_bytes,
            backend,
            done,
        } => Box::new(ClassicalEncodeTask {
            env,
            width,
            sources,
            parity_rows,
            dests,
            buf_bytes,
            block_bytes,
            backend,
            done,
            started: false,
            local_blocks: Vec::new(),
            local_acc: Vec::new(),
            compute: Tick::ZERO,
            offset: 0,
            frame_no: 0,
            state: EncState::Gather,
            row: Vec::new(),
            src_idx: 0,
            stash: None,
            pending: None,
            charge: ChargeWait::default(),
            parity: Vec::new(),
            dest_idx: 0,
            drain_idx: 0,
            final_idx: 0,
            final_store: None,
        }),
        Command::Put { .. } | Command::Peek { .. } | Command::Delete { .. } | Command::Shutdown => {
            unreachable!("control-plane command on data plane")
        }
    }
}

// ---------------------------------------------------------------------------
// Node command loop.
// ---------------------------------------------------------------------------

/// The task twin of `node_loop`: identical queueing, stall-overflow
/// backoff, crash-flush and trace behaviour, but worker "threads" are
/// tasks pushed onto the driver.
struct NodeTask {
    core: NodeCore,
    clock: ClockHandle,
    pending_cmds: VecDeque<Command>,
    active: usize,
    stall: Duration,
    stall_deadline: Option<Tick>,
}

impl NodeTask {
    fn new(core: NodeCore) -> Self {
        let clock = core.cpu.clock().clone();
        Self {
            core,
            clock,
            pending_cmds: VecDeque::new(),
            active: 0,
            stall: QUEUE_STALL_OVERFLOW,
            stall_deadline: None,
        }
    }

    fn spawn_worker(&self, cmd: Command, spawn: &mut Vec<Box<dyn Task>>) {
        let env = WorkerEnv {
            clock: self.clock.clone(),
            store: self.core.store.clone(),
            cpu: self.core.cpu.clone(),
            inflight: self.core.inflight.clone(),
            loopback: self.core.loopback.clone(),
            failed: self.core.failed.clone(),
        };
        spawn.push(worker_task(env, cmd));
    }
}

impl Task for NodeTask {
    fn register(&self, waker: TaskWaker) {
        self.core.rx.set_waker(waker);
    }

    fn poll(&mut self, spawn: &mut Vec<Box<dyn Task>>) -> TaskPoll {
        let max_stall = QUEUE_STALL_OVERFLOW * 20;
        loop {
            // Crash-flush, exactly as in `node_loop` (see the comments
            // there): reject everything queued, keep running workers going.
            if self.core.failed.load(Ordering::SeqCst) {
                let flushed = !self.pending_cmds.is_empty();
                while let Some(cmd) = self.pending_cmds.pop_front() {
                    self.core.inflight.fetch_sub(1, Ordering::Relaxed);
                    reject(self.core.id, cmd);
                }
                if flushed {
                    crate::trace_emit!(self.clock, self.core.id, EventKind::QueueDepth {
                        depth: self.active
                    });
                }
                self.stall_deadline = None;
            }
            let msg = if self.pending_cmds.is_empty() {
                self.stall_deadline = None;
                match self.core.rx.try_recv() {
                    Ok(m) => m,
                    Err(TryRecvError::Empty) => return TaskPoll::Park,
                    Err(TryRecvError::Disconnected) => return TaskPoll::Done,
                }
            } else {
                // Stall-overflow deadline, anchored to the last progress
                // event — the task analogue of `recv_deadline`.
                let deadline = match self.stall_deadline {
                    Some(d) => d,
                    None => {
                        let d = self.clock.now() + self.stall;
                        self.stall_deadline = Some(d);
                        d
                    }
                };
                if self.clock.now() >= deadline {
                    if let Some(cmd) = self.pending_cmds.pop_front() {
                        self.active += 1;
                        self.spawn_worker(cmd, spawn);
                    }
                    self.stall = (self.stall * 2).min(max_stall);
                    self.stall_deadline = Some(self.clock.now() + self.stall);
                    continue;
                }
                match self.core.rx.try_recv() {
                    Ok(m) => m,
                    Err(TryRecvError::Empty) => return TaskPoll::Sleep(deadline),
                    Err(TryRecvError::Disconnected) => return TaskPoll::Done,
                }
            };
            match msg {
                Msg::Cmd(cmd)
                    if self.core.failed.load(Ordering::SeqCst)
                        && !matches!(cmd, Command::Shutdown) =>
                {
                    reject(self.core.id, cmd);
                }
                Msg::WorkerDone => {
                    self.stall = QUEUE_STALL_OVERFLOW;
                    self.stall_deadline = None;
                    self.active -= 1;
                    if self.active < self.core.max_workers {
                        if let Some(cmd) = self.pending_cmds.pop_front() {
                            self.active += 1;
                            self.spawn_worker(cmd, spawn);
                        }
                    }
                    crate::trace_emit!(self.clock, self.core.id, EventKind::QueueDepth {
                        depth: self.active + self.pending_cmds.len()
                    });
                }
                Msg::Cmd(Command::Shutdown) => {
                    // Flush the queue (briefly exceeding the cap) so every
                    // dispatched command still completes and signals `done`.
                    while let Some(cmd) = self.pending_cmds.pop_front() {
                        self.spawn_worker(cmd, spawn);
                    }
                    return TaskPoll::Done;
                }
                Msg::Cmd(Command::Put { key, data, done }) => {
                    self.core.store.put(key, data);
                    let _ = done.send(Ok(()));
                }
                Msg::Cmd(Command::Peek { key, reply }) => {
                    let _ = reply.send(self.core.store.get(&key));
                }
                Msg::Cmd(Command::Delete { key, done }) => {
                    let _ = done.send(self.core.store.delete(&key));
                }
                Msg::Cmd(other) => {
                    self.core.inflight.fetch_add(1, Ordering::Relaxed);
                    if self.active < self.core.max_workers {
                        self.active += 1;
                        self.spawn_worker(other, spawn);
                    } else {
                        self.pending_cmds.push_back(other);
                    }
                    crate::trace_emit!(self.clock, self.core.id, EventKind::QueueDepth {
                        depth: self.active + self.pending_cmds.len()
                    });
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Worker state machines (one per data-plane command kind).
// ---------------------------------------------------------------------------

/// Task twin of `do_upload`.
struct UploadTask {
    env: WorkerEnv,
    key: BlockKey,
    tx: Tx,
    buf_bytes: usize,
    done: clock::Sender<StepResult>,
    payload: Option<Payload>,
    off: usize,
    end_sent: bool,
    pending: Option<PendingSend>,
}

impl UploadTask {
    fn drive(&mut self) -> anyhow::Result<Option<Tick>> {
        if self.payload.is_none() {
            let key = self.key;
            let data = self
                .env
                .store
                .get(&key)
                .ok_or_else(|| anyhow::anyhow!("upload: missing block {key:?}"))?;
            self.payload = Some(Payload::from_shared(data));
        }
        loop {
            if self.pending.is_none() {
                let payload = self.payload.as_ref().expect("payload fetched above");
                let total = payload.len();
                if self.off < total {
                    let end = (self.off + self.buf_bytes).min(total);
                    let frame = Frame::Data(payload.slice(self.off, end));
                    self.off = end;
                    self.pending = Some(self.tx.begin_send(frame)?);
                } else if !self.end_sent {
                    self.end_sent = true;
                    self.pending = Some(self.tx.begin_send(Frame::End)?);
                } else {
                    return Ok(None);
                }
            }
            match drive_send(&mut self.tx, &mut self.pending, &self.env.clock)? {
                SendDrive::Wait(at) => return Ok(Some(at)),
                SendDrive::Sent => {}
            }
        }
    }
}

impl Task for UploadTask {
    fn register(&self, _waker: TaskWaker) {}

    fn poll(&mut self, _spawn: &mut Vec<Box<dyn Task>>) -> TaskPoll {
        match self.drive() {
            Ok(Some(at)) => TaskPoll::Sleep(at),
            Ok(None) => {
                self.env.complete(&self.done, Ok(StepStats::default()));
                TaskPoll::Done
            }
            Err(e) => {
                self.env.complete(&self.done, Err(e));
                TaskPoll::Done
            }
        }
    }
}

/// Task twin of `do_receive`.
struct ReceiveTask {
    env: WorkerEnv,
    key: BlockKey,
    rx: Rx,
    done: clock::Sender<StepResult>,
    data: Vec<u8>,
    stash: Option<(Tick, Frame)>,
    streamed: bool,
    charged: bool,
    charge: ChargeWait,
    compute: Tick,
}

impl ReceiveTask {
    fn drive(&mut self) -> anyhow::Result<Option<TaskPoll>> {
        while !self.streamed {
            match drive_recv(&self.rx, &mut self.stash, &self.env.clock) {
                RecvDrive::Channel => return Ok(Some(TaskPoll::Park)),
                RecvDrive::Wait(at) => return Ok(Some(TaskPoll::Sleep(at))),
                RecvDrive::Got(Some(Frame::Data(d))) => self.data.extend_from_slice(&d),
                RecvDrive::Got(Some(Frame::End)) => self.streamed = true,
                RecvDrive::Got(None) => anyhow::bail!("stream ended without End frame"),
            }
        }
        if !self.charged {
            self.charged = true;
            self.charge.begin(
                &self.env.cpu,
                &GfWork::store(self.data.len()),
                &mut self.compute,
            );
        }
        if let Some(at) = self.charge.pending(&self.env.clock) {
            return Ok(Some(TaskPoll::Sleep(at)));
        }
        let bytes = self.data.len();
        anyhow::ensure!(
            self.env
                .store
                .put_unless(self.key, mem::take(&mut self.data), &self.env.failed),
            "receive aborted: node has failed"
        );
        crate::trace_emit!(
            self.env.cpu.clock(),
            self.env.cpu.node(),
            EventKind::StoreDone {
                object: self.key.object.0,
                index: self.key.index,
                bytes
            }
        );
        Ok(None)
    }
}

impl Task for ReceiveTask {
    fn register(&self, waker: TaskWaker) {
        self.rx.set_waker(waker);
    }

    fn poll(&mut self, _spawn: &mut Vec<Box<dyn Task>>) -> TaskPoll {
        match self.drive() {
            Ok(Some(wait)) => wait,
            Ok(None) => {
                self.env.complete(
                    &self.done,
                    Ok(StepStats {
                        compute: self.compute,
                        ..Default::default()
                    }),
                );
                TaskPoll::Done
            }
            Err(e) => {
                self.env.complete(&self.done, Err(e));
                TaskPoll::Done
            }
        }
    }
}

enum StageState {
    /// Waiting for (or synthesizing) the next incoming buffer.
    Recv,
    /// GF work charged; waiting out the lane reservation.
    Fold,
    /// Forwarding `x_out` to the children, one send at a time.
    Forward,
    /// Incoming stream ended: close downstream streams.
    Close,
    /// Store-charge wait before landing the accumulated output block.
    Store,
}

/// Task twin of `do_pipeline_stage`.
struct PipelineStageTask {
    env: WorkerEnv,
    width: Width,
    locals: Vec<BlockKey>,
    psi: Vec<u32>,
    xi: Vec<u32>,
    prev: Option<Rx>,
    next: Vec<Tx>,
    out_key: Option<BlockKey>,
    buf_bytes: usize,
    backend: BackendHandle,
    done: clock::Sender<StepResult>,
    state: StageState,
    /// `(local_blocks, block_bytes)`, fetched on the first poll.
    init: Option<(Vec<Arc<Vec<u8>>>, usize)>,
    out: Vec<u8>,
    frame_no: usize,
    compute: Tick,
    offset: usize,
    stash: Option<(Tick, Frame)>,
    pending: Option<PendingSend>,
    charge: ChargeWait,
    /// `(x_out, c, len)` held across the fold-charge wait.
    fold: Option<(Vec<u8>, Vec<u8>, usize)>,
    /// `(frame, len)` held across the fan-out sends.
    fwd: Option<(Payload, usize)>,
    fwd_idx: usize,
    close_idx: usize,
}

impl PipelineStageTask {
    fn trace_identity(&self) -> (Option<u64>, Option<usize>) {
        match &self.out_key {
            Some(k) => (Some(k.object.0), Some(k.index)),
            None => (None, None),
        }
    }

    fn drive(&mut self) -> anyhow::Result<Option<TaskPoll>> {
        if self.init.is_none() {
            let local_blocks: Vec<Arc<Vec<u8>>> = self
                .locals
                .iter()
                .map(|k| {
                    self.env.store.get(k).ok_or_else(|| {
                        anyhow::anyhow!("pipeline stage: missing local block {k:?}")
                    })
                })
                .collect::<anyhow::Result<_>>()?;
            let block_bytes = local_blocks
                .first()
                .map(|b| b.len())
                .ok_or_else(|| anyhow::anyhow!("pipeline stage with no local blocks"))?;
            anyhow::ensure!(
                local_blocks.iter().all(|b| b.len() == block_bytes),
                "local blocks of unequal size"
            );
            self.out = Vec::with_capacity(if self.out_key.is_some() { block_bytes } else { 0 });
            self.init = Some((local_blocks, block_bytes));
        }
        let block_bytes = self.init.as_ref().expect("init set above").1;
        let (trace_obj, trace_idx) = self.trace_identity();
        loop {
            match self.state {
                StageState::Recv => {
                    let x_in: Payload = match &self.prev {
                        Some(rx) => match drive_recv(rx, &mut self.stash, &self.env.clock) {
                            RecvDrive::Channel => return Ok(Some(TaskPoll::Park)),
                            RecvDrive::Wait(at) => return Ok(Some(TaskPoll::Sleep(at))),
                            RecvDrive::Got(Some(Frame::Data(d))) => d,
                            RecvDrive::Got(Some(Frame::End)) => {
                                self.state = StageState::Close;
                                continue;
                            }
                            RecvDrive::Got(None) => {
                                anyhow::bail!("upstream link dropped mid-stream")
                            }
                        },
                        None => {
                            if self.offset >= block_bytes {
                                self.state = StageState::Close;
                                continue;
                            }
                            Payload::new(vec![0u8; self.buf_bytes.min(block_bytes - self.offset)])
                        }
                    };
                    let len = x_in.len();
                    anyhow::ensure!(
                        self.offset + len <= block_bytes,
                        "incoming stream longer than local blocks"
                    );
                    let local_blocks = &self.init.as_ref().expect("init set above").0;
                    let loc_slices: Vec<&[u8]> = local_blocks
                        .iter()
                        .map(|b| &b[self.offset..self.offset + len])
                        .collect();
                    crate::trace_emit!(
                        self.env.cpu.clock(),
                        self.env.cpu.node(),
                        EventKind::FoldStart {
                            object: trace_obj,
                            index: trace_idx,
                            frame: self.frame_no
                        }
                    );
                    let (x_out, c, work) = fold_frame(
                        &self.backend,
                        self.width,
                        &x_in,
                        &loc_slices,
                        &self.psi,
                        &self.xi,
                        self.next.len(),
                    )?;
                    self.charge.begin(&self.env.cpu, &work, &mut self.compute);
                    self.fold = Some((x_out, c, len));
                    self.state = StageState::Fold;
                }
                StageState::Fold => {
                    if let Some(at) = self.charge.pending(&self.env.clock) {
                        return Ok(Some(TaskPoll::Sleep(at)));
                    }
                    let (x_out, c, len) = self.fold.take().expect("fold state without frame");
                    crate::trace_emit!(
                        self.env.cpu.clock(),
                        self.env.cpu.node(),
                        EventKind::FoldEnd {
                            object: trace_obj,
                            index: trace_idx,
                            frame: self.frame_no
                        }
                    );
                    self.frame_no += 1;
                    if self.out_key.is_some() {
                        self.out.extend_from_slice(&c);
                    }
                    if self.next.is_empty() {
                        self.offset += len;
                        self.state = StageState::Recv;
                    } else {
                        self.fwd = Some((Payload::new(x_out), len));
                        self.fwd_idx = 0;
                        self.state = StageState::Forward;
                    }
                }
                StageState::Forward => {
                    if self.fwd_idx >= self.next.len() {
                        let (_, len) = self.fwd.take().expect("forward state without frame");
                        self.offset += len;
                        self.state = StageState::Recv;
                        continue;
                    }
                    if self.pending.is_none() {
                        let frame = Frame::Data(
                            self.fwd.as_ref().expect("forward state without frame").0.clone(),
                        );
                        self.pending = Some(self.next[self.fwd_idx].begin_send(frame)?);
                    }
                    match drive_send(
                        &mut self.next[self.fwd_idx],
                        &mut self.pending,
                        &self.env.clock,
                    )? {
                        SendDrive::Wait(at) => return Ok(Some(TaskPoll::Sleep(at))),
                        SendDrive::Sent => self.fwd_idx += 1,
                    }
                }
                StageState::Close => {
                    if self.close_idx < self.next.len() {
                        if self.pending.is_none() {
                            self.pending = Some(self.next[self.close_idx].begin_send(Frame::End)?);
                        }
                        match drive_send(
                            &mut self.next[self.close_idx],
                            &mut self.pending,
                            &self.env.clock,
                        )? {
                            SendDrive::Wait(at) => return Ok(Some(TaskPoll::Sleep(at))),
                            SendDrive::Sent => self.close_idx += 1,
                        }
                        continue;
                    }
                    anyhow::ensure!(self.offset == block_bytes, "stream/block length mismatch");
                    if self.out_key.is_none() {
                        return Ok(None);
                    }
                    self.charge.begin(
                        &self.env.cpu,
                        &GfWork::store(self.out.len()),
                        &mut self.compute,
                    );
                    self.state = StageState::Store;
                }
                StageState::Store => {
                    if let Some(at) = self.charge.pending(&self.env.clock) {
                        return Ok(Some(TaskPoll::Sleep(at)));
                    }
                    let key = self.out_key.expect("store state without out_key");
                    let bytes = self.out.len();
                    anyhow::ensure!(
                        self.env
                            .store
                            .put_unless(key, mem::take(&mut self.out), &self.env.failed),
                        "pipeline stage aborted: node has failed"
                    );
                    crate::trace_emit!(
                        self.env.cpu.clock(),
                        self.env.cpu.node(),
                        EventKind::StoreDone {
                            object: key.object.0,
                            index: key.index,
                            bytes
                        }
                    );
                    return Ok(None);
                }
            }
        }
    }
}

impl Task for PipelineStageTask {
    fn register(&self, waker: TaskWaker) {
        if let Some(rx) = &self.prev {
            rx.set_waker(waker);
        }
    }

    fn poll(&mut self, _spawn: &mut Vec<Box<dyn Task>>) -> TaskPoll {
        match self.drive() {
            Ok(Some(wait)) => wait,
            Ok(None) => {
                self.env.complete(
                    &self.done,
                    Ok(StepStats {
                        compute: self.compute,
                        ..Default::default()
                    }),
                );
                TaskPoll::Done
            }
            Err(e) => {
                self.env.complete(&self.done, Err(e));
                TaskPoll::Done
            }
        }
    }
}

enum EncState {
    /// Collecting one row of k source buffers.
    Gather,
    /// Gemm charged; waiting out the lane reservation.
    Gemm,
    /// Shipping/accumulating the m parity buffers, one dest at a time.
    Ship,
    /// All rows folded: drain the `End` frame of every remote source.
    Drain,
    /// Closing parity streams / landing local parities, one at a time.
    Final,
    /// Store-charge wait for one locally-kept parity.
    FinalStore,
}

/// Task twin of `do_classical_encode`.
struct ClassicalEncodeTask {
    env: WorkerEnv,
    width: Width,
    sources: Vec<SourceStream>,
    parity_rows: Vec<Vec<u32>>,
    dests: Vec<ParityDest>,
    buf_bytes: usize,
    block_bytes: usize,
    backend: BackendHandle,
    done: clock::Sender<StepResult>,
    started: bool,
    local_blocks: Vec<Option<Arc<Vec<u8>>>>,
    local_acc: Vec<Vec<u8>>,
    compute: Tick,
    offset: usize,
    frame_no: usize,
    state: EncState,
    row: Vec<Payload>,
    src_idx: usize,
    stash: Option<(Tick, Frame)>,
    pending: Option<PendingSend>,
    charge: ChargeWait,
    /// The current row's parity buffers, consumed by `Ship`.
    parity: Vec<Vec<u8>>,
    dest_idx: usize,
    drain_idx: usize,
    final_idx: usize,
    /// `(key, accumulated block)` held across the final store-charge wait.
    final_store: Option<(BlockKey, Vec<u8>)>,
}

impl ClassicalEncodeTask {
    fn drive(&mut self) -> anyhow::Result<Option<TaskPoll>> {
        let k = self.sources.len();
        let m = self.parity_rows.len();
        if !self.started {
            self.started = true;
            anyhow::ensure!(self.dests.len() == m, "dests/parity arity mismatch");
            anyhow::ensure!(
                self.parity_rows.iter().all(|r| r.len() == k),
                "parity row arity mismatch"
            );
            self.local_blocks = self
                .sources
                .iter()
                .map(|s| match s {
                    SourceStream::Local(key) => {
                        self.env.store.get(key).map(Some).ok_or_else(|| {
                            anyhow::anyhow!("classical encode: missing local source {key:?}")
                        })
                    }
                    SourceStream::Remote(_) => Ok(None),
                })
                .collect::<anyhow::Result<_>>()?;
            self.local_acc = self
                .dests
                .iter()
                .map(|d| match d {
                    ParityDest::Store(_) => Vec::with_capacity(self.block_bytes),
                    ParityDest::Stream(_) => Vec::new(),
                })
                .collect();
        }
        loop {
            match self.state {
                EncState::Gather => {
                    if self.offset >= self.block_bytes {
                        self.state = EncState::Drain;
                        continue;
                    }
                    let len = self.buf_bytes.min(self.block_bytes - self.offset);
                    while self.src_idx < k {
                        let j = self.src_idx;
                        match &self.sources[j] {
                            SourceStream::Remote(rx) => {
                                match drive_recv(rx, &mut self.stash, &self.env.clock) {
                                    RecvDrive::Channel => return Ok(Some(TaskPoll::Park)),
                                    RecvDrive::Wait(at) => return Ok(Some(TaskPoll::Sleep(at))),
                                    RecvDrive::Got(Some(Frame::Data(buf))) => {
                                        anyhow::ensure!(
                                            buf.len() == len,
                                            "source {j} frame size mismatch"
                                        );
                                        self.row.push(buf);
                                        self.src_idx += 1;
                                    }
                                    RecvDrive::Got(other) => {
                                        anyhow::bail!("source {j} stream broke: {other:?}")
                                    }
                                }
                            }
                            SourceStream::Local(_) => {
                                let b = self.local_blocks[j]
                                    .as_ref()
                                    .expect("local source fetched at start");
                                let view = Payload::from_shared(b.clone())
                                    .slice(self.offset, self.offset + len);
                                self.row.push(view);
                                self.src_idx += 1;
                            }
                        }
                    }
                    let row_refs: Vec<&[u8]> = self.row.iter().map(|b| b.as_slice()).collect();
                    crate::trace_emit!(
                        self.env.cpu.clock(),
                        self.env.cpu.node(),
                        EventKind::GemmStart {
                            rows: m,
                            frame: self.frame_no
                        }
                    );
                    self.parity = self.backend.gemm(self.width, &self.parity_rows, &row_refs)?;
                    self.charge.begin(
                        &self.env.cpu,
                        &GfWork::gemm(&self.parity_rows, len),
                        &mut self.compute,
                    );
                    self.state = EncState::Gemm;
                }
                EncState::Gemm => {
                    if let Some(at) = self.charge.pending(&self.env.clock) {
                        return Ok(Some(TaskPoll::Sleep(at)));
                    }
                    crate::trace_emit!(
                        self.env.cpu.clock(),
                        self.env.cpu.node(),
                        EventKind::GemmEnd {
                            rows: m,
                            frame: self.frame_no
                        }
                    );
                    self.frame_no += 1;
                    self.dest_idx = 0;
                    self.state = EncState::Ship;
                }
                EncState::Ship => {
                    if self.dest_idx < m {
                        let i = self.dest_idx;
                        match &mut self.dests[i] {
                            ParityDest::Stream(tx) => {
                                if self.pending.is_none() {
                                    let pb = mem::take(&mut self.parity[i]);
                                    self.pending =
                                        Some(tx.begin_send(Frame::Data(Payload::new(pb)))?);
                                }
                                match drive_send(tx, &mut self.pending, &self.env.clock)? {
                                    SendDrive::Wait(at) => return Ok(Some(TaskPoll::Sleep(at))),
                                    SendDrive::Sent => self.dest_idx += 1,
                                }
                            }
                            ParityDest::Store(_) => {
                                let pb = mem::take(&mut self.parity[i]);
                                self.local_acc[i].extend_from_slice(&pb);
                                self.dest_idx += 1;
                            }
                        }
                        continue;
                    }
                    let len = self.buf_bytes.min(self.block_bytes - self.offset);
                    self.offset += len;
                    self.row.clear();
                    self.src_idx = 0;
                    self.state = EncState::Gather;
                }
                EncState::Drain => {
                    while self.drain_idx < k {
                        let j = self.drain_idx;
                        if let SourceStream::Remote(rx) = &self.sources[j] {
                            match drive_recv(rx, &mut self.stash, &self.env.clock) {
                                RecvDrive::Channel => return Ok(Some(TaskPoll::Park)),
                                RecvDrive::Wait(at) => return Ok(Some(TaskPoll::Sleep(at))),
                                RecvDrive::Got(Some(Frame::End)) => self.drain_idx += 1,
                                RecvDrive::Got(other) => {
                                    anyhow::bail!("source stream missing End: {other:?}")
                                }
                            }
                        } else {
                            self.drain_idx += 1;
                        }
                    }
                    self.final_idx = 0;
                    self.state = EncState::Final;
                }
                EncState::Final => {
                    if self.final_idx >= self.dests.len() {
                        return Ok(None);
                    }
                    let i = self.final_idx;
                    match &mut self.dests[i] {
                        ParityDest::Stream(tx) => {
                            if self.pending.is_none() {
                                self.pending = Some(tx.begin_send(Frame::End)?);
                            }
                            match drive_send(tx, &mut self.pending, &self.env.clock)? {
                                SendDrive::Wait(at) => return Ok(Some(TaskPoll::Sleep(at))),
                                SendDrive::Sent => self.final_idx += 1,
                            }
                        }
                        ParityDest::Store(key) => {
                            let key = *key;
                            let acc = mem::take(&mut self.local_acc[i]);
                            self.charge.begin(
                                &self.env.cpu,
                                &GfWork::store(acc.len()),
                                &mut self.compute,
                            );
                            self.final_store = Some((key, acc));
                            self.state = EncState::FinalStore;
                        }
                    }
                }
                EncState::FinalStore => {
                    if let Some(at) = self.charge.pending(&self.env.clock) {
                        return Ok(Some(TaskPoll::Sleep(at)));
                    }
                    let (key, acc) = self
                        .final_store
                        .take()
                        .expect("final-store state without block");
                    let bytes = acc.len();
                    anyhow::ensure!(
                        self.env.store.put_unless(key, acc, &self.env.failed),
                        "classical encode aborted: node has failed"
                    );
                    crate::trace_emit!(
                        self.env.cpu.clock(),
                        self.env.cpu.node(),
                        EventKind::StoreDone {
                            object: key.object.0,
                            index: key.index,
                            bytes
                        }
                    );
                    self.final_idx += 1;
                    self.state = EncState::Final;
                }
            }
        }
    }
}

impl Task for ClassicalEncodeTask {
    fn register(&self, waker: TaskWaker) {
        for s in &self.sources {
            if let SourceStream::Remote(rx) = s {
                rx.set_waker(waker.clone());
            }
        }
    }

    fn poll(&mut self, _spawn: &mut Vec<Box<dyn Task>>) -> TaskPoll {
        match self.drive() {
            Ok(Some(wait)) => wait,
            Ok(None) => {
                self.env.complete(
                    &self.done,
                    Ok(StepStats {
                        compute: self.compute,
                        ..Default::default()
                    }),
                );
                TaskPoll::Done
            }
            Err(e) => {
                self.env.complete(&self.done, Err(e));
                TaskPoll::Done
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::link::{link, LinkSpec};
    use crate::cluster::nic::RateLimiter;
    use crate::cluster::node::{NodeHandle, DEFAULT_MAX_WORKERS};
    use crate::resources::{UniformCost, ZeroCost};
    use crate::storage::ObjectId;

    fn nic(clock: &ClockHandle, rate: f64) -> Arc<RateLimiter> {
        Arc::new(RateLimiter::new(clock.clone(), rate))
    }

    fn meter(clock: &ClockHandle, id: super::super::NodeId, priced: bool) -> Arc<CpuMeter> {
        let model = if priced {
            UniformCost::handle()
        } else {
            ZeroCost::handle()
        };
        Arc::new(CpuMeter::new(clock.clone(), model, id))
    }

    #[test]
    fn multiplexed_control_plane_roundtrip() {
        let clock = SimClock::handle();
        let (node, core) = NodeHandle::multiplexed(
            0,
            nic(&clock, 1e9),
            nic(&clock, 1e9),
            meter(&clock, 0, false),
            DEFAULT_MAX_WORKERS,
        );
        let rt = MultiplexedRuntime::launch(&clock, vec![core]);
        let key = BlockKey::source(ObjectId(1), 0);
        node.put(key, vec![1, 2, 3]).unwrap();
        assert_eq!(*node.peek(key).unwrap().unwrap(), vec![1, 2, 3]);
        assert!(node.delete(key).unwrap());
        assert!(node.peek(key).unwrap().is_none());
        drop(node); // sends Shutdown: the driver may now exit
        drop(rt); // joins the driver
    }

    /// One rate-limited upload→receive transfer, identical under both
    /// runtimes: same bytes, same final virtual tick.
    fn transfer(multiplexed: bool) -> (Vec<u8>, Tick) {
        let clock = SimClock::handle();
        let mk = |id: usize| {
            (
                nic(&clock, 10_000_000.0),
                nic(&clock, 1e9),
                meter(&clock, id, true),
            )
        };
        let (a, b, rt) = if multiplexed {
            let (u, d, c) = mk(0);
            let (a, ca) = NodeHandle::multiplexed(0, u, d, c, DEFAULT_MAX_WORKERS);
            let (u, d, c) = mk(1);
            let (b, cb) = NodeHandle::multiplexed(1, u, d, c, DEFAULT_MAX_WORKERS);
            let rt = MultiplexedRuntime::launch(&clock, vec![ca, cb]);
            (a, b, Some(rt))
        } else {
            let (u, d, c) = mk(0);
            let a = NodeHandle::spawn(0, u, d, c, DEFAULT_MAX_WORKERS);
            let (u, d, c) = mk(1);
            let b = NodeHandle::spawn(1, u, d, c, DEFAULT_MAX_WORKERS);
            (a, b, None)
        };
        let src = BlockKey::source(ObjectId(1), 0);
        let dst = BlockKey::source(ObjectId(1), 1);
        let data: Vec<u8> = (0..200_000u32).map(|i| (i % 251) as u8).collect();
        a.put(src, data.clone()).unwrap();
        let spec = LinkSpec {
            latency: Duration::from_millis(1),
            jitter: Duration::from_micros(50),
        };
        let (tx, rx) = link(a.up.clone(), b.down.clone(), spec, 7);
        let (d1, w1) = clock::channel(&clock);
        let (d2, w2) = clock::channel(&clock);
        b.send(Command::Receive {
            key: dst,
            rx,
            expect_bytes: data.len(),
            done: d1,
        })
        .unwrap();
        a.send(Command::Upload {
            key: src,
            tx,
            buf_bytes: 16_384,
            done: d2,
        })
        .unwrap();
        w2.recv().unwrap().unwrap();
        w1.recv().unwrap().unwrap();
        let out = b.peek(dst).unwrap().unwrap().to_vec();
        let end = clock.now();
        drop(a);
        drop(b);
        drop(rt);
        (out, end)
    }

    #[test]
    fn upload_receive_tick_parity_with_threaded() {
        let (bytes_t, end_t) = transfer(false);
        let (bytes_m, end_m) = transfer(true);
        assert_eq!(bytes_t, bytes_m, "payload bytes diverged across runtimes");
        assert_eq!(end_t, end_m, "virtual end tick diverged across runtimes");
        assert!(end_t > Duration::from_millis(20), "transfer was not paced");
    }

    #[test]
    fn multiplexed_queue_overflows_past_cap() {
        // cap 1, two concurrent receives: the second command queues, then
        // runs after the first completes (WorkerDone refill) — exercising
        // the node task's queue/refill path end to end.
        let clock = SimClock::handle();
        let (src, csrc) = NodeHandle::multiplexed(
            0,
            nic(&clock, 1e9),
            nic(&clock, 1e9),
            meter(&clock, 0, false),
            DEFAULT_MAX_WORKERS,
        );
        let (dst, cdst) = NodeHandle::multiplexed(
            1,
            nic(&clock, 1e9),
            nic(&clock, 1e9),
            meter(&clock, 1, false),
            1,
        );
        let rt = MultiplexedRuntime::launch(&clock, vec![csrc, cdst]);
        let k0 = BlockKey::source(ObjectId(1), 0);
        let k1 = BlockKey::source(ObjectId(1), 1);
        src.put(k0, vec![7u8; 4096]).unwrap();
        src.put(k1, vec![9u8; 4096]).unwrap();
        let mut waits = Vec::new();
        for (i, k) in [k0, k1].into_iter().enumerate() {
            let (tx, rx) = link(
                src.up.clone(),
                dst.down.clone(),
                LinkSpec::instant(),
                40 + i as u64,
            );
            let (d1, w1) = clock::channel(&clock);
            let (d2, w2) = clock::channel(&clock);
            dst.send(Command::Receive {
                key: k,
                rx,
                expect_bytes: 4096,
                done: d1,
            })
            .unwrap();
            src.send(Command::Upload {
                key: k,
                tx,
                buf_bytes: 1024,
                done: d2,
            })
            .unwrap();
            waits.push(w1);
            waits.push(w2);
        }
        for w in waits {
            w.recv().unwrap().unwrap();
        }
        assert_eq!(*dst.peek(k0).unwrap().unwrap(), vec![7u8; 4096]);
        assert_eq!(*dst.peek(k1).unwrap().unwrap(), vec![9u8; 4096]);
        drop(src);
        drop(dst);
        drop(rt);
    }
}
