//! Cluster assembly: node registry, link factory, testbed presets.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::congestion::CongestionSpec;
use super::link::{link, with_endpoints, LinkSpec, Rx, Tx};
use super::nic::RateLimiter;
use super::node::{NodeHandle, DEFAULT_MAX_WORKERS};
use super::runtime::{MultiplexedRuntime, RuntimeKind};
use super::NodeId;
use crate::clock::{ClockHandle, RealClock, SimClock};
use crate::resources::{CostModelHandle, CpuMeter, NodeProfile, ProfileCost, UniformCost, ZeroCost};

/// Static description of a homogeneous cluster (per-node NIC + base link).
#[derive(Clone, Debug)]
pub struct ClusterSpec {
    /// Number of storage nodes.
    pub nodes: usize,
    /// Per-NIC bandwidth, bytes/second (full duplex: up and down each get
    /// this rate).
    pub bytes_per_sec: f64,
    /// Base one-way link latency.
    pub latency: Duration,
    /// Uniform latency jitter amplitude.
    pub jitter: Duration,
    /// Per-node soft cap on concurrently executing data-plane worker
    /// threads; commands beyond the cap queue FIFO on the node, with an
    /// anti-deadlock stall overflow (see `cluster::node` docs).
    pub max_workers: usize,
    /// Time source the whole cluster runs on: every NIC reservation, link
    /// delivery, worker stall and metric span uses this clock. Presets
    /// default to a fresh [`RealClock`]; swap in a [`SimClock`] (via
    /// [`ClusterSpec::with_clock`] / [`ClusterSpec::sim`]) to run the same
    /// workload as a deterministic discrete-event simulation.
    pub clock: ClockHandle,
    /// CPU cost model charged by every data-plane worker through its
    /// node's [`CpuMeter`]. Presets default to [`ZeroCost`] (compute is
    /// free — correct under a `RealClock`, where compute already costs
    /// wall time); swap in [`UniformCost`]/[`ProfileCost`] (via
    /// [`ClusterSpec::with_cost`] / [`ClusterSpec::with_profiles`]) so a
    /// `SimClock` run charges Table-II-style compute in virtual time.
    pub cost: CostModelHandle,
    /// Execution runtime for the node dataplanes. The default
    /// [`RuntimeKind::Auto`] resolves from the clock — `SimClock` runs get
    /// the single-threaded multiplexed event loop (thousands of nodes at
    /// negligible wall cost), `RealClock` runs keep the thread-per-node
    /// dataplane — so every existing preset transparently picks the fast
    /// path the moment it goes `.sim()`.
    pub runtime: RuntimeKind,
}

impl ClusterSpec {
    /// The paper's ThinClient cluster (*TPC*): 1 Gbps LAN, sub-millisecond
    /// switch latency.
    pub fn tpc(nodes: usize) -> Self {
        Self {
            nodes,
            bytes_per_sec: 125e6, // 1 Gbps
            latency: Duration::from_micros(200),
            jitter: Duration::from_micros(50),
            max_workers: DEFAULT_MAX_WORKERS,
            clock: RealClock::handle(),
            cost: ZeroCost::handle(),
            runtime: RuntimeKind::Auto,
        }
    }

    /// The paper's Amazon EC2 small-instance testbed: ~300 Mbps effective,
    /// millisecond-scale, jittery virtualized network.
    pub fn ec2(nodes: usize) -> Self {
        Self {
            nodes,
            bytes_per_sec: 37.5e6, // 300 Mbps
            latency: Duration::from_millis(1),
            jitter: Duration::from_micros(300),
            max_workers: DEFAULT_MAX_WORKERS,
            clock: RealClock::handle(),
            cost: ZeroCost::handle(),
            runtime: RuntimeKind::Auto,
        }
    }

    /// Very fast spec for unit tests (keeps simulated time negligible).
    pub fn test(nodes: usize) -> Self {
        Self {
            nodes,
            bytes_per_sec: 1e9,
            latency: Duration::ZERO,
            jitter: Duration::ZERO,
            max_workers: DEFAULT_MAX_WORKERS,
            clock: RealClock::handle(),
            cost: ZeroCost::handle(),
            runtime: RuntimeKind::Auto,
        }
    }

    /// Substitute the time source (e.g. a shared [`SimClock`]).
    pub fn with_clock(mut self, clock: ClockHandle) -> Self {
        self.clock = clock;
        self
    }

    /// Switch this spec onto a fresh discrete-event [`SimClock`].
    pub fn sim(self) -> Self {
        self.with_clock(SimClock::handle())
    }

    /// Substitute the CPU cost model.
    pub fn with_cost(mut self, cost: CostModelHandle) -> Self {
        self.cost = cost;
        self
    }

    /// Charge compute at the calibrated [`UniformCost`] rates.
    pub fn with_uniform_cost(self) -> Self {
        self.with_cost(UniformCost::handle())
    }

    /// Charge compute through heterogeneous per-node [`NodeProfile`]s
    /// over the calibrated baseline (node i gets `profiles[i % len]`).
    pub fn with_profiles(self, profiles: Vec<NodeProfile>) -> anyhow::Result<Self> {
        Ok(self.with_cost(ProfileCost::handle(profiles)?))
    }

    /// Pin the execution runtime instead of resolving it from the clock
    /// (e.g. force [`RuntimeKind::Threaded`] under a `SimClock` for a
    /// runtime-parity A/B).
    pub fn with_runtime(mut self, runtime: RuntimeKind) -> Self {
        self.runtime = runtime;
        self
    }

    /// The runtime this spec will actually start:
    /// [`RuntimeKind::Auto`] resolved against the spec's clock.
    pub fn resolved_runtime(&self) -> RuntimeKind {
        self.runtime.resolve(&self.clock)
    }
}

struct NodeNet {
    extra_latency: Duration,
    extra_jitter: Duration,
}

/// A running simulated cluster.
pub struct Cluster {
    spec: ClusterSpec,
    /// Declared before `runtime`: fields drop in declaration order, so the
    /// node handles (whose drops send `Shutdown`) go down before the
    /// multiplexed driver is joined — reordering these deadlocks shutdown.
    nodes: Vec<NodeHandle>,
    net: Mutex<Vec<NodeNet>>,
    link_seed: Mutex<u64>,
    /// The multiplexed driver, when the resolved runtime is
    /// [`RuntimeKind::Multiplexed`] (`None` for the threaded dataplane).
    runtime: Option<MultiplexedRuntime>,
}

impl Cluster {
    /// Start all nodes for `spec` on its resolved runtime: one OS thread
    /// per node (threaded), or one shared driver thread scheduling every
    /// node as a task (multiplexed).
    pub fn start(spec: ClusterSpec) -> Self {
        let kind = spec.resolved_runtime();
        let mk_parts = |id: NodeId| {
            (
                Arc::new(RateLimiter::new(spec.clock.clone(), spec.bytes_per_sec)),
                Arc::new(RateLimiter::new(spec.clock.clone(), spec.bytes_per_sec)),
                Arc::new(CpuMeter::new(spec.clock.clone(), spec.cost.clone(), id)),
            )
        };
        let (nodes, runtime) = match kind {
            RuntimeKind::Threaded => {
                let nodes = (0..spec.nodes)
                    .map(|id| {
                        let (up, down, cpu) = mk_parts(id);
                        NodeHandle::spawn(id, up, down, cpu, spec.max_workers)
                    })
                    .collect();
                (nodes, None)
            }
            RuntimeKind::Multiplexed => {
                let mut cores = Vec::with_capacity(spec.nodes);
                let nodes = (0..spec.nodes)
                    .map(|id| {
                        let (up, down, cpu) = mk_parts(id);
                        let (node, core) =
                            NodeHandle::multiplexed(id, up, down, cpu, spec.max_workers);
                        cores.push(core);
                        node
                    })
                    .collect();
                let rt = MultiplexedRuntime::launch(&spec.clock, cores);
                (nodes, Some(rt))
            }
            RuntimeKind::Auto => unreachable!("resolved_runtime never returns Auto"),
        };
        let net = (0..spec.nodes)
            .map(|_| NodeNet {
                extra_latency: Duration::ZERO,
                extra_jitter: Duration::ZERO,
            })
            .collect();
        Self {
            spec,
            nodes,
            net: Mutex::new(net),
            link_seed: Mutex::new(0x5EED),
            runtime,
        }
    }

    /// The execution runtime this cluster is running on.
    pub fn runtime_kind(&self) -> RuntimeKind {
        if self.runtime.is_some() {
            RuntimeKind::Multiplexed
        } else {
            RuntimeKind::Threaded
        }
    }

    /// The cluster spec.
    pub fn spec(&self) -> &ClusterSpec {
        &self.spec
    }

    /// The clock every node, NIC and link of this cluster runs on.
    pub fn clock(&self) -> &ClockHandle {
        &self.spec.clock
    }

    /// The CPU cost model every node's workers charge.
    pub fn cost(&self) -> &CostModelHandle {
        &self.spec.cost
    }

    /// Number of nodes.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// True if no nodes (never, in practice).
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Node handle by id.
    pub fn node(&self, id: NodeId) -> &NodeHandle {
        &self.nodes[id]
    }

    /// All node handles.
    pub fn nodes(&self) -> &[NodeHandle] {
        &self.nodes
    }

    /// Create a data link from `src` to `dst`, paced by src-up and dst-down
    /// NICs, with latency = base + max(extra of either endpoint).
    ///
    /// Refuses to lower a link onto a failed endpoint, and guards the
    /// returned sender with both endpoints' failure flags so a crash
    /// mid-stream breaks the link with an error instead of hanging or
    /// silently completing.
    pub fn connect(&self, src: NodeId, dst: NodeId) -> anyhow::Result<(Tx, Rx)> {
        assert_ne!(src, dst, "no self-links");
        for id in [src, dst] {
            anyhow::ensure!(
                !self.nodes[id].is_failed(),
                "cannot lower link {src}->{dst}: node {id} has failed"
            );
        }
        let net = self.net.lock().unwrap();
        let extra_lat = net[src].extra_latency.max(net[dst].extra_latency);
        let extra_jit = net[src].extra_jitter.max(net[dst].extra_jitter);
        drop(net);
        let spec = LinkSpec {
            latency: self.spec.latency + extra_lat,
            jitter: self.spec.jitter + extra_jit,
        };
        let seed = {
            let mut s = self.link_seed.lock().unwrap();
            *s = s.wrapping_add(0x9E3779B97F4A7C15);
            *s
        };
        let (tx, rx) = link(
            self.nodes[src].up.clone(),
            self.nodes[dst].down.clone(),
            spec,
            seed,
        );
        let tx = tx.guard([
            self.nodes[src].failure_flag(),
            self.nodes[dst].failure_flag(),
        ]);
        // endpoint identity makes the link's frames traceable
        Ok(with_endpoints(tx, rx, src, dst))
    }

    /// Crash-stop a node ([`crate::cluster::node::NodeHandle::fail`]):
    /// commands to it error fast, its stored blocks are lost, links
    /// touching it refuse lowering and break mid-stream.
    pub fn fail_node(&self, id: NodeId) {
        self.nodes[id].fail();
        crate::trace_emit!(self.spec.clock, id, crate::trace::EventKind::NodeFailed);
    }

    /// Bring a crashed node back as an empty newcomer; its pre-crash
    /// blocks stay lost until repair regenerates them.
    pub fn revive_node(&self, id: NodeId) {
        self.nodes[id].revive();
        crate::trace_emit!(self.spec.clock, id, crate::trace::EventKind::NodeRevived);
    }

    /// Whether a node is currently crashed.
    pub fn is_failed(&self, id: NodeId) -> bool {
        self.nodes[id].is_failed()
    }

    /// Ids of all currently alive nodes (newcomer/chain candidates).
    pub fn alive_nodes(&self) -> Vec<NodeId> {
        (0..self.nodes.len())
            .filter(|&id| !self.nodes[id].is_failed())
            .collect()
    }

    /// Apply a congestion profile to one node (paper's netem runs):
    /// clamps both NIC directions and adds latency ± jitter to every link
    /// touching the node.
    pub fn congest(&self, id: NodeId, c: &CongestionSpec) {
        self.nodes[id].up.set_rate(c.bytes_per_sec);
        self.nodes[id].down.set_rate(c.bytes_per_sec);
        let mut net = self.net.lock().unwrap();
        net[id].extra_latency = c.extra_latency;
        net[id].extra_jitter = c.jitter;
    }

    /// Remove congestion from a node, restoring the cluster preset.
    pub fn uncongest(&self, id: NodeId) {
        self.nodes[id].up.set_rate(self.spec.bytes_per_sec);
        self.nodes[id].down.set_rate(self.spec.bytes_per_sec);
        let mut net = self.net.lock().unwrap();
        net[id].extra_latency = Duration::ZERO;
        net[id].extra_jitter = Duration::ZERO;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::Clock;
    use crate::resources::CostModel;

    #[test]
    fn presets_have_expected_shape() {
        let t = ClusterSpec::tpc(50);
        assert_eq!(t.nodes, 50);
        assert!(t.bytes_per_sec > ClusterSpec::ec2(16).bytes_per_sec);
        assert!(t.latency < ClusterSpec::ec2(16).latency);
        // compute is free by default: ZeroCost is the RealClock-correct model
        assert_eq!(t.cost.name(), "zero");
    }

    #[test]
    fn cost_model_reaches_every_node_meter() {
        use crate::resources::NodeProfile;
        let spec = ClusterSpec::test(3)
            .sim()
            .with_profiles(NodeProfile::ec2_mix())
            .unwrap();
        assert_eq!(spec.cost.name(), "profile");
        let c = Cluster::start(spec);
        assert_eq!(c.cost().name(), "profile");
        for id in 0..3 {
            assert_eq!(c.node(id).cpu.node(), id);
            assert_eq!(c.node(id).cpu.model().name(), "profile");
        }
        // uniform builder variant
        let spec = ClusterSpec::test(1).with_uniform_cost();
        assert_eq!(spec.cost.name(), "uniform");
    }

    #[test]
    fn connect_moves_bytes() {
        let c = Cluster::start(ClusterSpec::test(3).sim());
        let (mut tx, rx) = c.connect(0, 2).unwrap();
        tx.send_data(vec![42; 10]).unwrap();
        tx.finish().unwrap();
        assert_eq!(rx.recv_all().unwrap(), vec![42; 10]);
    }

    #[test]
    fn congestion_slows_and_delays() {
        let c = Cluster::start(ClusterSpec::test(2).sim());
        let clock = c.clock().clone();
        c.congest(
            1,
            &CongestionSpec {
                bytes_per_sec: 1e6, // 1 MB/s
                extra_latency: Duration::from_millis(40),
                jitter: Duration::ZERO,
            },
        );
        let (mut tx, rx) = c.connect(0, 1).unwrap();
        let t0 = clock.now();
        tx.send_data(vec![0; 100_000]).unwrap(); // 100 ms at 1 MB/s
        tx.finish().unwrap();
        rx.recv_all().unwrap();
        let dt = clock.now() - t0;
        assert!(dt >= Duration::from_millis(120), "congestion ignored: {dt:?}");

        c.uncongest(1);
        let (mut tx, rx) = c.connect(0, 1).unwrap();
        let t0 = clock.now();
        tx.send_data(vec![0; 100_000]).unwrap();
        tx.finish().unwrap();
        rx.recv_all().unwrap();
        let dt = clock.now() - t0;
        assert!(dt < Duration::from_millis(50), "uncongest failed: {dt:?}");
    }

    #[test]
    fn failed_node_refuses_links_and_revives_empty() {
        use crate::storage::{BlockKey, ObjectId};
        let c = Cluster::start(ClusterSpec::test(3).sim());
        let key = BlockKey::coded(ObjectId(9), 1);
        c.node(1).put(key, vec![7; 16]).unwrap();

        c.fail_node(1);
        assert!(c.is_failed(1));
        assert_eq!(c.alive_nodes(), vec![0, 2]);
        // links touching the failed node refuse lowering, either direction
        assert!(c.connect(0, 1).is_err());
        assert!(c.connect(1, 2).is_err());
        // other links still work
        assert!(c.connect(0, 2).is_ok());
        // commands error fast
        assert!(c.node(1).peek(key).is_err());

        c.revive_node(1);
        assert_eq!(c.alive_nodes(), vec![0, 1, 2]);
        assert!(c.connect(0, 1).is_ok());
        // the crash lost the stored block: the newcomer comes back empty
        assert!(c.node(1).peek(key).unwrap().is_none());
    }

    #[test]
    fn mid_stream_failure_breaks_guarded_link() {
        let c = Cluster::start(ClusterSpec::test(2).sim());
        let (mut tx, _rx) = c.connect(0, 1).unwrap();
        tx.send_data(vec![1; 8]).unwrap();
        c.fail_node(1);
        assert!(tx.send_data(vec![2; 8]).is_err());
    }

    #[test]
    fn sim_cluster_accounts_transfers_in_virtual_time() {
        // 10 MB through a 1 MB/s NIC would be 10 wall seconds; under the
        // SimClock the virtual elapsed time reports the full transfer
        // (the wall-clock speed bound is asserted in tests/longrun.rs).
        let mut spec = ClusterSpec::test(2).sim();
        spec.bytes_per_sec = 1e6;
        let c = Cluster::start(spec);
        let clock = c.clock().clone();
        let (mut tx, rx) = c.connect(0, 1).unwrap();
        for _ in 0..10 {
            tx.send_data(vec![0; 1_000_000]).unwrap();
        }
        tx.finish().unwrap();
        rx.recv_all().unwrap();
        assert!(clock.now() >= Duration::from_secs(10), "{:?}", clock.now());
        assert!(clock.now() < Duration::from_secs(11), "{:?}", clock.now());
    }

    #[test]
    #[should_panic(expected = "no self-links")]
    fn self_link_rejected() {
        let c = Cluster::start(ClusterSpec::test(2));
        let _ = c.connect(1, 1);
    }

    #[test]
    fn auto_runtime_follows_the_clock() {
        // RealClock preset: threads. The same preset gone .sim(): tasks.
        let real = Cluster::start(ClusterSpec::test(2));
        assert_eq!(real.runtime_kind(), super::RuntimeKind::Threaded);
        let sim = Cluster::start(ClusterSpec::test(2).sim());
        assert_eq!(sim.runtime_kind(), super::RuntimeKind::Multiplexed);
        // pinning Threaded under a SimClock is allowed (parity A/Bs)
        let pinned =
            Cluster::start(ClusterSpec::test(2).sim().with_runtime(super::RuntimeKind::Threaded));
        assert_eq!(pinned.runtime_kind(), super::RuntimeKind::Threaded);
        // the multiplexed cluster still moves bytes end to end
        let (mut tx, rx) = sim.connect(0, 1).unwrap();
        tx.send_data(vec![9; 64]).unwrap();
        tx.finish().unwrap();
        assert_eq!(rx.recv_all().unwrap(), vec![9; 64]);
    }
}
