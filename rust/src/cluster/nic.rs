//! Per-node NIC model: a wall-clock token bucket.
//!
//! All transfers that cross a node's NIC (in either direction) reserve
//! bytes on the same limiter, so concurrent streams share — and contend
//! for — the node's bandwidth exactly as the paper's analysis assumes.

use std::sync::Mutex;
use std::time::{Duration, Instant};

struct State {
    bytes_per_sec: f64,
    /// Virtual time at which the NIC becomes free.
    next_free: Instant,
}

/// How far ahead of virtual time a paced sender may run (see
/// [`RateLimiter::acquire`]).
pub const PACING_SLACK: Duration = Duration::from_millis(4);

/// Wall-clock token-bucket rate limiter (one per NIC direction).
pub struct RateLimiter {
    state: Mutex<State>,
}

impl RateLimiter {
    /// New limiter at `bytes_per_sec`.
    pub fn new(bytes_per_sec: f64) -> Self {
        assert!(bytes_per_sec > 0.0);
        Self {
            state: Mutex::new(State {
                bytes_per_sec,
                next_free: Instant::now(),
            }),
        }
    }

    /// Change the rate (congestion injection). Takes effect for subsequent
    /// reservations.
    pub fn set_rate(&self, bytes_per_sec: f64) {
        assert!(bytes_per_sec > 0.0);
        self.state.lock().unwrap().bytes_per_sec = bytes_per_sec;
    }

    /// Current rate in bytes/second.
    pub fn rate(&self) -> f64 {
        self.state.lock().unwrap().bytes_per_sec
    }

    /// Reserve NIC time for `bytes`, pace the caller, and return the
    /// (virtual) completion instant.
    ///
    /// Serialization through the mutex gives FIFO-ish fairness between
    /// competing streams. Pacing allows up to [`PACING_SLACK`] of
    /// ahead-of-virtual-time progress: `thread::sleep` on a loaded 1-CPU
    /// host overshoots by ~1 ms, so sleeping per 64 KiB buffer (~0.5 ms
    /// nominal) would inflate every stream ~3-4×. Aggregate rate stays
    /// exact because `next_free` bookkeeping is cumulative and receivers
    /// wait for the *virtual* delivery instant of every frame.
    pub fn acquire(&self, bytes: usize) -> Instant {
        let done = self.reserve(bytes);
        let now = Instant::now();
        if done > now + PACING_SLACK {
            sleep_until(done - PACING_SLACK);
        }
        done
    }

    /// Reserve without sleeping (delivery-side accounting); returns the
    /// completion instant the caller should delay to.
    pub fn reserve(&self, bytes: usize) -> Instant {
        let mut s = self.state.lock().unwrap();
        let now = Instant::now();
        let start = if s.next_free > now { s.next_free } else { now };
        let cost = Duration::from_secs_f64(bytes as f64 / s.bytes_per_sec);
        let done = start + cost;
        s.next_free = done;
        done
    }
}

/// Sleep until `deadline` (no-op if already past).
///
/// Hybrid strategy: `thread::sleep` overshoots by 0.5–4 ms on this class of
/// host (virtualized, single CPU), which would swamp the sub-millisecond
/// per-buffer timing the simulation depends on. We therefore sleep only to
/// ~2 ms before the deadline and yield-spin the rest — measured accuracy
/// <10 µs (see DESIGN.md §Perf).
pub fn sleep_until(deadline: Instant) {
    const SPIN: Duration = Duration::from_micros(2000);
    let now = Instant::now();
    if deadline <= now {
        return;
    }
    let remaining = deadline - now;
    if remaining > SPIN {
        std::thread::sleep(remaining - SPIN);
    }
    while Instant::now() < deadline {
        std::thread::yield_now();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paces_to_the_configured_rate() {
        // 10 MB/s, 1 MB => ~100 ms
        let l = RateLimiter::new(10_000_000.0);
        let t0 = Instant::now();
        l.acquire(1_000_000);
        let dt = t0.elapsed();
        assert!(dt >= Duration::from_millis(95), "too fast: {dt:?}");
        assert!(dt < Duration::from_millis(400), "too slow: {dt:?}");
    }

    #[test]
    fn concurrent_streams_share_bandwidth() {
        use std::sync::Arc;
        // two concurrent 500 KB transfers through a 10 MB/s NIC: ~100 ms total
        let l = Arc::new(RateLimiter::new(10_000_000.0));
        let t0 = Instant::now();
        let hs: Vec<_> = (0..2)
            .map(|_| {
                let l = l.clone();
                std::thread::spawn(move || {
                    l.acquire(500_000);
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        let dt = t0.elapsed();
        assert!(dt >= Duration::from_millis(95), "shared NIC not serialized: {dt:?}");
    }

    #[test]
    fn rate_change_applies() {
        let l = RateLimiter::new(1_000_000.0);
        l.set_rate(20_000_000.0);
        assert!((l.rate() - 20_000_000.0).abs() < 1.0);
        let t0 = Instant::now();
        l.acquire(200_000); // 10 ms at the new rate
        assert!(t0.elapsed() < Duration::from_millis(100));
    }

    #[test]
    fn reserve_does_not_sleep() {
        let l = RateLimiter::new(1_000.0); // very slow
        let t0 = Instant::now();
        let done = l.reserve(10_000); // would be 10 s
        assert!(t0.elapsed() < Duration::from_millis(50));
        assert!(done > Instant::now());
    }
}
