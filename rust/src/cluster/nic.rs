//! Per-node NIC model: a token bucket on the cluster's [`Clock`].
//!
//! All transfers that cross a node's NIC (in either direction) reserve
//! bytes on the same limiter, so concurrent streams share — and contend
//! for — the node's bandwidth exactly as the paper's analysis assumes.
//! Reservations are pure tick arithmetic; only [`RateLimiter::acquire`]
//! blocks, and it blocks on the clock — wall time under
//! [`RealClock`](crate::clock::RealClock), a discrete event under
//! [`SimClock`](crate::clock::SimClock).

use std::sync::Mutex;
use std::time::Duration;

use crate::clock::{Clock, ClockHandle, Tick};

struct State {
    bytes_per_sec: f64,
    /// Tick at which the NIC becomes free.
    next_free: Tick,
}

/// One NIC reservation, decomposed for tracing: the transfer queued behind
/// earlier reservations until `start` (stall), then occupied the wire until
/// `done` (busy).
#[derive(Clone, Copy, Debug)]
pub struct Reservation {
    /// Tick the reservation was requested.
    pub requested: Tick,
    /// Tick the wire actually starts carrying these bytes.
    pub start: Tick,
    /// Tick the transfer completes.
    pub done: Tick,
}

impl Reservation {
    /// Time spent queued behind earlier reservations.
    pub fn stall(&self) -> Tick {
        self.start.saturating_sub(self.requested)
    }

    /// Wire-occupancy time (serialization at the NIC rate).
    pub fn busy(&self) -> Tick {
        self.done.saturating_sub(self.start)
    }
}

/// Token-bucket rate limiter (one per NIC direction) on a shared clock.
pub struct RateLimiter {
    clock: ClockHandle,
    state: Mutex<State>,
}

impl RateLimiter {
    /// New limiter at `bytes_per_sec` on `clock`.
    pub fn new(clock: ClockHandle, bytes_per_sec: f64) -> Self {
        assert!(bytes_per_sec > 0.0);
        let next_free = clock.now();
        Self {
            clock,
            state: Mutex::new(State {
                bytes_per_sec,
                next_free,
            }),
        }
    }

    /// The clock this limiter reserves time on.
    pub fn clock(&self) -> &ClockHandle {
        &self.clock
    }

    /// Change the rate (congestion injection). Takes effect for subsequent
    /// reservations.
    pub fn set_rate(&self, bytes_per_sec: f64) {
        assert!(bytes_per_sec > 0.0);
        self.state.lock().unwrap().bytes_per_sec = bytes_per_sec;
    }

    /// Current rate in bytes/second.
    pub fn rate(&self) -> f64 {
        self.state.lock().unwrap().bytes_per_sec
    }

    /// How long a new reservation would queue behind the ones already
    /// booked — the NIC twin of
    /// [`CpuMeter::backlog`](crate::resources::CpuMeter::backlog), and the
    /// link-load signal the adaptive control plane snapshots at plan
    /// boundaries (`ZERO` on an idle or drained NIC). Pure state read: no
    /// reservation, no sleep, no trace emit.
    pub fn backlog(&self) -> Tick {
        let s = self.state.lock().unwrap();
        s.next_free.saturating_sub(self.clock.now())
    }

    /// Reserve NIC time for `bytes`, pace the caller, and return the
    /// (virtual) completion tick.
    ///
    /// Serialization through the mutex gives FIFO-ish fairness between
    /// competing streams. Pacing allows up to the clock's
    /// [`pacing_slack`](crate::clock::Clock::pacing_slack) of
    /// ahead-of-virtual-time progress (non-zero only on real clocks, where
    /// OS sleep overshoot would otherwise inflate every stream — see
    /// `RealClock::PACING_SLACK`). Aggregate rate stays exact because
    /// `next_free` bookkeeping is cumulative and receivers wait for the
    /// *virtual* delivery instant of every frame.
    pub fn acquire(&self, bytes: usize) -> Tick {
        self.acquire_traced(bytes).done
    }

    /// [`RateLimiter::acquire`] with the reservation's stall/busy split
    /// exposed (the dataplane's `NicStall` trace events come from here).
    pub fn acquire_traced(&self, bytes: usize) -> Reservation {
        let r = self.reserve_traced(bytes);
        let now = self.clock.now();
        if r.done > now + self.clock.pacing_slack() {
            self.clock.sleep_until(r.done - self.clock.pacing_slack());
        }
        r
    }

    /// Reserve without sleeping (delivery-side accounting); returns the
    /// completion tick the caller should delay to.
    pub fn reserve(&self, bytes: usize) -> Tick {
        self.reserve_traced(bytes).done
    }

    /// [`RateLimiter::reserve`] with the stall/busy split exposed.
    pub fn reserve_traced(&self, bytes: usize) -> Reservation {
        let mut s = self.state.lock().unwrap();
        let now = self.clock.now();
        let start = if s.next_free > now { s.next_free } else { now };
        let cost = Duration::from_secs_f64(bytes as f64 / s.bytes_per_sec);
        let done = start + cost;
        s.next_free = done;
        Reservation {
            requested: now,
            start,
            done,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::{RealClock, SimClock};
    use std::sync::Arc;

    #[test]
    fn paces_to_the_configured_rate() {
        // 10 MB/s, 1 MB => exactly 100 ms of virtual time
        let clock = SimClock::handle();
        let l = RateLimiter::new(clock.clone(), 10_000_000.0);
        l.acquire(1_000_000);
        assert_eq!(clock.now(), Duration::from_millis(100));
    }

    #[test]
    fn concurrent_streams_share_bandwidth() {
        // two concurrent 500 KB transfers through a 10 MB/s NIC: the
        // cumulative reservation ends at exactly 100 ms regardless of
        // arrival order.
        let clock = SimClock::handle();
        let l = Arc::new(RateLimiter::new(clock.clone(), 10_000_000.0));
        let hs: Vec<_> = (0..2)
            .map(|_| {
                let l = l.clone();
                std::thread::spawn(move || {
                    l.acquire(500_000);
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(clock.now(), Duration::from_millis(100));
    }

    #[test]
    fn rate_change_applies() {
        let clock = SimClock::handle();
        let l = RateLimiter::new(clock.clone(), 1_000_000.0);
        l.set_rate(20_000_000.0);
        assert!((l.rate() - 20_000_000.0).abs() < 1.0);
        l.acquire(200_000); // 10 ms at the new rate
        assert_eq!(clock.now(), Duration::from_millis(10));
    }

    #[test]
    fn reserve_does_not_sleep() {
        let clock = SimClock::handle();
        let l = RateLimiter::new(clock.clone(), 1_000.0); // very slow
        let done = l.reserve(10_000); // would be 10 s
        assert_eq!(clock.now(), Duration::ZERO, "reserve must not block");
        assert_eq!(done, Duration::from_secs(10));
    }

    #[test]
    fn backlog_reports_booked_wire_time_without_reserving() {
        let clock = SimClock::handle();
        let l = RateLimiter::new(clock.clone(), 1_000_000.0); // 1 MB/s
        assert_eq!(l.backlog(), Duration::ZERO, "idle NIC has no backlog");
        l.reserve(500_000); // books 500 ms of wire time
        assert_eq!(l.backlog(), Duration::from_millis(500));
        l.reserve(250_000); // cumulative: 750 ms booked
        assert_eq!(l.backlog(), Duration::from_millis(750));
        // reading the backlog reserves nothing
        assert_eq!(l.backlog(), Duration::from_millis(750));
        assert_eq!(clock.now(), Duration::ZERO, "backlog must not sleep");
        // once an acquire paces past the booked time the backlog drains
        l.acquire(250_000); // sleeps to the 1 s mark
        assert_eq!(clock.now(), Duration::from_secs(1));
        assert_eq!(l.backlog(), Duration::ZERO);
    }

    #[test]
    fn traced_reservation_splits_stall_and_busy() {
        let clock = SimClock::handle();
        let l = RateLimiter::new(clock, 1_000.0);
        let a = l.reserve_traced(1_000); // 1 s on the wire, no queueing
        assert_eq!(a.stall(), Duration::ZERO);
        assert_eq!(a.busy(), Duration::from_secs(1));
        let b = l.reserve_traced(1_000); // queued behind `a`
        assert_eq!(b.stall(), Duration::from_secs(1));
        assert_eq!(b.busy(), Duration::from_secs(1));
        assert_eq!(b.done, Duration::from_secs(2));
    }

    #[test]
    fn real_clock_pacing_stays_within_slack() {
        // 10 MB/s, 100 KB => 10 ms nominal; the real clock may run at most
        // PACING_SLACK ahead but never report completion early.
        let clock = RealClock::handle();
        let l = RateLimiter::new(clock.clone(), 10_000_000.0);
        let t0 = clock.now();
        let done = l.acquire(100_000);
        let now = clock.now();
        assert!(done >= t0 + Duration::from_millis(10));
        assert!(
            now + RealClock::PACING_SLACK >= done,
            "paced too far behind: now {now:?} done {done:?}"
        );
    }
}
