//! Point-to-point data links: paced by both endpoint NICs, delayed by
//! propagation latency (+jitter), carrying real byte frames.
//!
//! Frames travel on a clock channel stamped with their delivery [`Tick`];
//! the receiver sleeps on the cluster clock until that tick. Under a
//! `SimClock` an undelivered frame pins virtual time (it counts as pending
//! work), so delivery order is honored without any wall-clock wait.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::nic::{RateLimiter, Reservation};
use super::NodeId;
use crate::clock::task::TaskWaker;
use crate::clock::{self, Clock, ClockHandle, Tick};
use crate::trace::{Direction, EventKind};
use crate::util::SplitMix64;

/// Propagation characteristics of a link.
#[derive(Clone, Copy, Debug)]
pub struct LinkSpec {
    /// One-way propagation latency.
    pub latency: Duration,
    /// Uniform jitter amplitude (delivery latency ∈ latency ± jitter).
    pub jitter: Duration,
}

impl LinkSpec {
    /// Zero-latency spec (unit tests).
    pub fn instant() -> Self {
        Self {
            latency: Duration::ZERO,
            jitter: Duration::ZERO,
        }
    }
}

/// A shareable view into a payload buffer — the zero-copy frame body.
///
/// Wraps `Arc<Vec<u8>>` (the same shape [`BlockStore`](crate::storage::BlockStore)
/// hands out, so a stored block streams with no conversion copy) plus a
/// byte range. Cloning bumps a refcount; [`Payload::slice`] carves
/// sub-views of the same allocation — an upload chunks one buffer and a
/// fan-out sends one frame to F children without ever duplicating the
/// bytes. The *modeled* copy charges (the XOR-priced fan-out term, the
/// store-priced landing copy) are the dataplane's business; this type only
/// guarantees no physical memcpy hides underneath them.
#[derive(Clone)]
pub struct Payload {
    buf: Arc<Vec<u8>>,
    start: usize,
    end: usize,
}

impl Payload {
    /// Take ownership of a buffer (no copy).
    pub fn new(data: Vec<u8>) -> Self {
        Self::from_shared(Arc::new(data))
    }

    /// View an already-shared buffer (no copy; refcount bump).
    pub fn from_shared(buf: Arc<Vec<u8>>) -> Self {
        let end = buf.len();
        Self { buf, start: 0, end }
    }

    /// Sub-view of this payload's byte range (same allocation).
    pub fn slice(&self, start: usize, end: usize) -> Self {
        assert!(start <= end && self.start + end <= self.end, "slice out of range");
        Self {
            buf: self.buf.clone(),
            start: self.start + start,
            end: self.start + end,
        }
    }

    /// View length in bytes.
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    /// True if the view is empty.
    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }

    /// The viewed bytes.
    pub fn as_slice(&self) -> &[u8] {
        &self.buf[self.start..self.end]
    }

    /// Whether two payloads view the same allocation (zero-copy tests).
    pub fn shares_buffer(&self, other: &Payload) -> bool {
        Arc::ptr_eq(&self.buf, &other.buf)
    }
}

impl std::ops::Deref for Payload {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl From<Vec<u8>> for Payload {
    fn from(data: Vec<u8>) -> Self {
        Payload::new(data)
    }
}

impl From<Arc<Vec<u8>>> for Payload {
    fn from(buf: Arc<Vec<u8>>) -> Self {
        Payload::from_shared(buf)
    }
}

impl std::fmt::Debug for Payload {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // frames end up in error messages; print the shape, not the bytes
        write!(f, "Payload({} bytes)", self.len())
    }
}

/// A unit of payload on the wire.
#[derive(Debug)]
pub enum Frame {
    /// One network buffer of payload (shared, zero-copy).
    Data(Payload),
    /// End of stream.
    End,
}

impl Frame {
    fn wire_bytes(&self) -> usize {
        match self {
            Frame::Data(d) => d.len(),
            Frame::End => 0,
        }
    }
}

/// Sending half of a link.
pub struct Tx {
    sender: clock::Sender<(Tick, Frame)>,
    clock: ClockHandle,
    up: Arc<RateLimiter>,
    down: Arc<RateLimiter>,
    spec: LinkSpec,
    rng: SplitMix64,
    /// Failure flags of the endpoint nodes (crash injection): when any is
    /// set, further sends error instead of delivering. Empty for raw links.
    guards: Vec<Arc<AtomicBool>>,
    /// Endpoint node ids for tracing (`None` on raw links, which emit no
    /// frame events).
    src: Option<NodeId>,
    dst: Option<NodeId>,
}

/// Receiving half of a link.
pub struct Rx {
    receiver: clock::Receiver<(Tick, Frame)>,
    clock: ClockHandle,
    src: Option<NodeId>,
    dst: Option<NodeId>,
}

/// A frame whose uplink time is reserved but not yet elapsed — the state
/// carried between [`Tx::begin_send`] and [`Tx::commit_send`].
pub(crate) struct PendingSend {
    frame: Frame,
    bytes: usize,
    up: Option<Reservation>,
    /// Tick the sender must reach before committing (the uplink completion
    /// tick; `now` for zero-byte control frames).
    pub(crate) ready_at: Tick,
}

impl PendingSend {
    /// Whether the sender owes a pacing wait before the commit (zero-byte
    /// control frames reserve nothing and commit immediately).
    pub(crate) fn paced(&self) -> bool {
        self.up.is_some()
    }
}

/// Outcome of a non-blocking [`Rx::poll`].
pub(crate) enum RxPoll {
    /// A frame is queued; consume it at tick `.0` (its delivery instant) —
    /// wait there, then call [`Rx::note_recvd`].
    Ready(Tick, Frame),
    /// Nothing queued yet; register a waker and yield.
    Empty,
    /// Sender hung up without `End` (the threaded `recv`'s `None`).
    Disconnected,
}

/// Create a link between a sender NIC (`up`) and a receiver NIC (`down`);
/// both must share one clock, which also times frame delivery.
pub fn link(up: Arc<RateLimiter>, down: Arc<RateLimiter>, spec: LinkSpec, seed: u64) -> (Tx, Rx) {
    let clock = up.clock().clone();
    let (s, r) = clock::channel(&clock);
    (
        Tx {
            sender: s,
            clock: clock.clone(),
            up,
            down,
            spec,
            rng: SplitMix64::new(seed),
            guards: Vec::new(),
            src: None,
            dst: None,
        },
        Rx {
            receiver: r,
            clock,
            src: None,
            dst: None,
        },
    )
}

/// Stamp both halves of a link with their endpoint node ids so the trace
/// layer can attribute frames and NIC reservations. `Cluster::connect`
/// does this for every cluster link; raw [`link`]s stay anonymous.
pub fn with_endpoints(tx: Tx, rx: Rx, src: NodeId, dst: NodeId) -> (Tx, Rx) {
    (
        Tx {
            src: Some(src),
            dst: Some(dst),
            ..tx
        },
        Rx {
            src: Some(src),
            dst: Some(dst),
            ..rx
        },
    )
}

impl Tx {
    /// Attach endpoint failure flags (crash injection): every subsequent
    /// [`Tx::send`] errors while any flag is set, so a node failure breaks
    /// in-flight streams instead of letting them complete silently. The
    /// cluster's `connect` attaches both endpoints' flags; raw links built
    /// with [`link`] carry none.
    pub fn guard(mut self, flags: impl IntoIterator<Item = Arc<AtomicBool>>) -> Self {
        self.guards.extend(flags);
        self
    }

    /// Transmit a frame: blocks the sender for the NIC transmission time
    /// (both endpoint NICs reserve the bytes — the slower one paces the
    /// stream), then enqueues the frame stamped with its delivery tick
    /// (completion + propagation latency ± jitter).
    pub fn send(&mut self, frame: Frame) -> anyhow::Result<()> {
        let pending = self.begin_send(frame)?;
        // Pace exactly like `RateLimiter::acquire_traced`: sleep up to the
        // clock's slack short of the uplink completion tick.
        if pending.up.is_some() {
            let now = self.clock.now();
            if pending.ready_at > now + self.clock.pacing_slack() {
                self.clock
                    .sleep_until(pending.ready_at - self.clock.pacing_slack());
            }
        }
        self.commit_send(pending)
    }

    /// First half of a split [`Tx::send`] for cooperatively-scheduled
    /// tasks: failure-guard check plus the **uplink** reservation (the
    /// sender-pacing half). The caller must wait until
    /// [`PendingSend::ready_at`] on the clock, then [`Tx::commit_send`].
    /// Downlink booking, trace events and enqueueing all happen in the
    /// commit, at the same tick the threaded path reaches them — that is
    /// what keeps the two runtimes tick-identical.
    pub(crate) fn begin_send(&mut self, frame: Frame) -> anyhow::Result<PendingSend> {
        if self.guards.iter().any(|g| g.load(Ordering::SeqCst)) {
            anyhow::bail!("link endpoint node has failed");
        }
        let bytes = frame.wire_bytes();
        let (up, ready_at) = if bytes > 0 {
            let up = self.up.reserve_traced(bytes);
            let ready_at = up.done;
            (Some(up), ready_at)
        } else {
            (None, self.clock.now())
        };
        Ok(PendingSend {
            frame,
            bytes,
            up,
            ready_at,
        })
    }

    /// Second half of a split [`Tx::send`]: books the receiver NIC, emits
    /// the NIC/frame trace events, draws the per-send jitter and enqueues
    /// the frame with its delivery tick. Call with the clock at (or past)
    /// [`PendingSend::ready_at`].
    pub(crate) fn commit_send(&mut self, pending: PendingSend) -> anyhow::Result<()> {
        let PendingSend {
            frame,
            bytes,
            up,
            ready_at: _,
        } = pending;
        let done = if let Some(up) = up {
            // Receiver NIC books the same bytes; delivery waits for it, and
            // competing inbound streams at the receiver serialize here.
            let down = self.down.reserve_traced(bytes);
            if let Some(src) = self.src {
                crate::trace_emit!(
                    self.clock,
                    src,
                    EventKind::NicStall {
                        dir: Direction::Up,
                        stall: up.stall(),
                        busy: up.busy(),
                        bytes,
                    }
                );
            }
            if let Some(dst) = self.dst {
                crate::trace_emit!(
                    self.clock,
                    dst,
                    EventKind::NicStall {
                        dir: Direction::Down,
                        stall: down.stall(),
                        busy: down.busy(),
                        bytes,
                    }
                );
            }
            down.done
        } else {
            self.clock.now()
        };
        // The jitter draw happens unconditionally per send (End frames
        // included) so the per-link RNG stream is identical no matter how
        // sends interleave with waits.
        let jitter = if self.spec.jitter > Duration::ZERO {
            let amp = self.spec.jitter.as_secs_f64();
            Duration::from_secs_f64(amp * self.rng.f64() * 2.0)
        } else {
            Duration::ZERO
        };
        // latency - jitter_amp + uniform(0, 2*jitter_amp) == latency ± jitter
        let lat = self.spec.latency.saturating_sub(self.spec.jitter) + jitter;
        let deliver_at = done + lat;
        if bytes > 0 {
            if let (Some(src), Some(dst)) = (self.src, self.dst) {
                crate::trace_emit!(
                    self.clock,
                    src,
                    EventKind::FrameSent {
                        dst,
                        bytes,
                        deliver_at,
                    }
                );
            }
        }
        self.sender
            .send((deliver_at, frame))
            .map_err(|_| anyhow::anyhow!("link receiver dropped"))
    }

    /// Convenience: send a payload buffer (anything cheaply convertible to
    /// a [`Payload`] — an owned `Vec<u8>`, a shared `Arc<Vec<u8>>`, or an
    /// existing view).
    pub fn send_data(&mut self, data: impl Into<Payload>) -> anyhow::Result<()> {
        self.send(Frame::Data(data.into()))
    }

    /// Convenience: close the stream.
    pub fn finish(&mut self) -> anyhow::Result<()> {
        self.send(Frame::End)
    }
}

impl Rx {
    /// Receive the next frame, waiting for its simulated delivery time.
    /// Returns `None` when the sender hung up without `End`.
    pub fn recv(&self) -> Option<Frame> {
        let (at, frame) = self.receiver.recv().ok()?;
        self.clock.sleep_until(at);
        if let Frame::Data(d) = &frame {
            if let (Some(src), Some(dst)) = (self.src, self.dst) {
                crate::trace_emit!(
                    @at at,
                    self.clock,
                    dst,
                    EventKind::FrameRecvd {
                        src,
                        bytes: d.len(),
                    }
                );
            }
        }
        Some(frame)
    }

    /// Drain the stream (until `End`) appending into `out` — the streaming
    /// primitive under [`Rx::recv_all`] and the node's `Receive` command,
    /// which pre-sizes `out` to skip growth reallocations.
    pub fn recv_into(&self, out: &mut Vec<u8>) -> anyhow::Result<()> {
        loop {
            match self.recv() {
                Some(Frame::Data(d)) => out.extend_from_slice(&d),
                Some(Frame::End) => return Ok(()),
                None => anyhow::bail!("stream ended without End frame"),
            }
        }
    }

    /// Drain an entire stream into one buffer (until `End`).
    pub fn recv_all(&self) -> anyhow::Result<Vec<u8>> {
        let mut out = Vec::new();
        self.recv_into(&mut out)?;
        Ok(out)
    }

    /// Non-blocking receive for cooperatively-scheduled tasks: pops the
    /// next frame (with its delivery tick) if one is queued. The caller
    /// owns the wait-until-delivery step the threaded [`Rx::recv`] does
    /// inline.
    pub(crate) fn poll(&self) -> RxPoll {
        match self.receiver.try_recv() {
            Ok((at, frame)) => RxPoll::Ready(at, frame),
            Err(clock::chan::TryRecvError::Empty) => RxPoll::Empty,
            Err(clock::chan::TryRecvError::Disconnected) => RxPoll::Disconnected,
        }
    }

    /// Emit the `frame_recvd` trace event for a frame consumed at its
    /// delivery tick `at` — the task-path twin of the emit inside
    /// [`Rx::recv`].
    pub(crate) fn note_recvd(&self, at: Tick, frame: &Frame) {
        if let Frame::Data(d) = frame {
            if let (Some(src), Some(dst)) = (self.src, self.dst) {
                crate::trace_emit!(
                    @at at,
                    self.clock,
                    dst,
                    EventKind::FrameRecvd {
                        src,
                        bytes: d.len(),
                    }
                );
            }
        }
    }

    /// Register a task waker on the underlying channel: every subsequent
    /// frame (and the sender's disconnect) wakes the task on its driver.
    pub(crate) fn set_waker(&self, waker: TaskWaker) {
        self.receiver.set_waker(waker);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::SimClock;

    fn sim() -> ClockHandle {
        SimClock::handle()
    }

    fn nic(clock: &ClockHandle) -> Arc<RateLimiter> {
        Arc::new(RateLimiter::new(clock.clone(), 1e9))
    }

    #[test]
    fn roundtrip_payload() {
        let c = sim();
        let (mut tx, rx) = link(nic(&c), nic(&c), LinkSpec::instant(), 1);
        tx.send_data(vec![1, 2, 3]).unwrap();
        tx.send_data(vec![4]).unwrap();
        tx.finish().unwrap();
        assert_eq!(rx.recv_all().unwrap(), vec![1, 2, 3, 4]);
    }

    #[test]
    fn latency_delays_delivery() {
        let c = sim();
        let spec = LinkSpec {
            latency: Duration::from_millis(50),
            jitter: Duration::ZERO,
        };
        let (mut tx, rx) = link(nic(&c), nic(&c), spec, 2);
        tx.send_data(vec![0; 8]).unwrap();
        let _ = rx.recv().unwrap();
        // delivery = NIC completion (8 ns at 1 GB/s) + 50 ms exactly
        assert!(c.now() >= Duration::from_millis(50), "{:?}", c.now());
        assert!(c.now() < Duration::from_millis(51), "{:?}", c.now());
    }

    #[test]
    fn bandwidth_paces_sender() {
        // 1 MB through a 10 MB/s uplink: ≈ 104.9 ms of send-side pacing
        let c = sim();
        let up = Arc::new(RateLimiter::new(c.clone(), 10_000_000.0));
        let (mut tx, _rx) = link(up, nic(&c), LinkSpec::instant(), 3);
        for _ in 0..16 {
            tx.send_data(vec![0; 65536]).unwrap();
        }
        assert!(c.now() >= Duration::from_millis(100), "{:?}", c.now());
        assert!(c.now() <= Duration::from_millis(110), "{:?}", c.now());
    }

    #[test]
    fn receiver_nic_serializes_competing_streams() {
        // two senders, one receiver NIC at 10 MB/s, 500 KB each => 100 ms
        let c = sim();
        let down = nic(&c);
        down.set_rate(10_000_000.0);
        let mut handles = Vec::new();
        let mut rxs = Vec::new();
        for s in 0..2 {
            let (mut tx, rx) = link(nic(&c), down.clone(), LinkSpec::instant(), 4 + s);
            rxs.push(rx);
            handles.push(std::thread::spawn(move || {
                for _ in 0..8 {
                    tx.send_data(vec![0; 62_500]).unwrap();
                }
                tx.finish().unwrap();
            }));
        }
        for rx in &rxs {
            rx.recv_all().unwrap();
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.now(), Duration::from_millis(100));
    }

    #[test]
    fn recv_into_presized_buffer_appends() {
        let c = sim();
        let (mut tx, rx) = link(nic(&c), nic(&c), LinkSpec::instant(), 8);
        tx.send_data(vec![5; 10]).unwrap();
        tx.send_data(vec![6; 6]).unwrap();
        tx.finish().unwrap();
        let mut out = Vec::with_capacity(16);
        rx.recv_into(&mut out).unwrap();
        assert_eq!(out.len(), 16);
        assert_eq!(&out[..10], &[5; 10]);
        assert_eq!(&out[10..], &[6; 6]);
    }

    #[test]
    fn recv_none_after_sender_drop() {
        let c = sim();
        let (tx, rx) = link(nic(&c), nic(&c), LinkSpec::instant(), 9);
        drop(tx);
        assert!(rx.recv().is_none());
        assert!(rx.recv_all().is_err());
    }

    #[test]
    fn guarded_link_breaks_when_endpoint_fails() {
        let c = sim();
        let failed = Arc::new(AtomicBool::new(false));
        let (tx, rx) = link(nic(&c), nic(&c), LinkSpec::instant(), 11);
        let mut tx = tx.guard([failed.clone()]);
        tx.send_data(vec![1, 2]).unwrap();
        failed.store(true, Ordering::SeqCst);
        let err = tx.send_data(vec![3]).unwrap_err();
        assert!(err.to_string().contains("failed"), "{err}");
        // the receiver sees the already-delivered frame, then a broken stream
        assert!(matches!(rx.recv(), Some(Frame::Data(_))));
        drop(tx);
        assert!(rx.recv().is_none());
    }

    #[test]
    fn payload_views_share_one_allocation() {
        let p = Payload::new((0..100u8).collect());
        let a = p.slice(10, 60);
        let b = a.slice(5, 20);
        let c = p.clone();
        assert_eq!(a.len(), 50);
        assert_eq!(a[0], 10);
        assert_eq!(b.as_slice(), &(15..30).collect::<Vec<u8>>()[..]);
        assert!(a.shares_buffer(&p) && b.shares_buffer(&p) && c.shares_buffer(&p));
        assert!(p.slice(100, 100).is_empty());
        assert_eq!(format!("{p:?}"), "Payload(100 bytes)");
        // an Arc straight out of a block store also shares
        let shared = Arc::new(vec![7u8; 4]);
        let q = Payload::from_shared(shared.clone());
        assert!(q.shares_buffer(&Payload::from_shared(shared)));
    }

    #[test]
    #[should_panic(expected = "slice out of range")]
    fn payload_slice_bounds_checked() {
        let _ = Payload::new(vec![0; 4]).slice(2, 6);
    }

    #[test]
    fn frames_deliver_payload_views_without_copying() {
        let c = sim();
        let (mut tx, rx) = link(nic(&c), nic(&c), LinkSpec::instant(), 21);
        let p = Payload::new(vec![9u8; 32]);
        tx.send_data(p.slice(0, 16)).unwrap();
        tx.send_data(p.slice(16, 32)).unwrap();
        match rx.recv().unwrap() {
            Frame::Data(d) => assert!(d.shares_buffer(&p)),
            Frame::End => panic!("expected data"),
        }
        match rx.recv().unwrap() {
            Frame::Data(d) => {
                assert!(d.shares_buffer(&p));
                assert_eq!(d.as_slice(), &[9u8; 16]);
            }
            Frame::End => panic!("expected data"),
        }
    }

    #[test]
    fn endpoint_stamped_link_emits_trace_events() {
        let c = sim();
        let sink = crate::trace::JsonlSink::shared();
        let _guard = crate::trace::install(&c, sink.clone());
        let (tx, rx) = link(nic(&c), nic(&c), LinkSpec::instant(), 31);
        let (mut tx, rx) = with_endpoints(tx, rx, 4, 7);
        tx.send_data(vec![1; 64]).unwrap();
        tx.finish().unwrap();
        rx.recv_all().unwrap();
        let events = sink.events();
        let names: Vec<&str> = events.iter().map(|e| e.kind.name()).collect();
        assert!(names.contains(&"frame_sent"), "{names:?}");
        assert!(names.contains(&"frame_recvd"), "{names:?}");
        // one up + one down reservation for the single data frame
        assert_eq!(names.iter().filter(|n| **n == "nic_stall").count(), 2);
        // End frames are control, not wire traffic
        assert_eq!(names.iter().filter(|n| **n == "frame_sent").count(), 1);
        for e in &events {
            match &e.kind {
                crate::trace::EventKind::FrameSent { dst, bytes, .. } => {
                    assert_eq!((e.node, *dst, *bytes), (Some(4), 7, 64));
                }
                crate::trace::EventKind::FrameRecvd { src, bytes } => {
                    assert_eq!((e.node, *src, *bytes), (Some(7), 4, 64));
                }
                _ => {}
            }
        }
    }

    #[test]
    fn raw_links_stay_anonymous_and_silent() {
        let c = sim();
        let sink = crate::trace::JsonlSink::shared();
        let _guard = crate::trace::install(&c, sink.clone());
        let (mut tx, rx) = link(nic(&c), nic(&c), LinkSpec::instant(), 32);
        tx.send_data(vec![1; 8]).unwrap();
        tx.finish().unwrap();
        rx.recv_all().unwrap();
        assert!(sink.is_empty(), "anonymous links must not emit");
    }

    #[test]
    fn jitter_stays_within_band() {
        let c = sim();
        let spec = LinkSpec {
            latency: Duration::from_millis(20),
            jitter: Duration::from_millis(5),
        };
        let (mut tx, rx) = link(nic(&c), nic(&c), spec, 10);
        let mut last = Duration::ZERO;
        for _ in 0..5 {
            let t0 = c.now();
            tx.send_data(vec![0; 8]).unwrap();
            let _ = rx.recv().unwrap();
            let dt = c.now() - t0;
            assert!(dt >= Duration::from_millis(15), "{dt:?}");
            assert!(dt <= Duration::from_millis(25), "{dt:?}");
            last = dt;
        }
        assert!(last > Duration::ZERO);
    }
}
